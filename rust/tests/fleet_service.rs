//! Heterogeneous fleet (ISSUE 3): capability routing, per-variant
//! metrics, baseline fallback, and the registry-backed serving path.

use flexgrip::coordinator::{
    customize, FleetConfig, GpgpuService, Request, VariantSpec,
};
use flexgrip::gpgpu::GpgpuConfig;
use flexgrip::kernels::BenchId;

fn variant(label: &str, depth: u32, mul: bool) -> VariantSpec {
    let mut cfg = GpgpuConfig::new(1, 8);
    cfg.sm.warp_stack_depth = depth;
    cfg.sm.has_multiplier = mul;
    if !mul {
        cfg.sm.read_operands = 2;
    }
    VariantSpec::new(label, cfg)
}

/// Baseline + the three distinct Table-6 variants.
fn paper_fleet() -> GpgpuService {
    let svc = GpgpuService::start_fleet(FleetConfig {
        variants: vec![
            variant("baseline", 32, true),
            variant("stack16", 16, true),
            variant("stack0", 0, true),
            variant("nomul", 2, false),
        ],
        queue_depth: 16,
    });
    for id in BenchId::PAPER {
        let r = customize::profile(id, 32, 5).expect("profile");
        svc.register_profile(id, r.refined_signature());
    }
    svc
}

#[test]
fn jobs_route_to_the_cheapest_covering_variant() {
    let svc = paper_fleet();
    let expect = [
        (BenchId::Autocorr, "stack16"),
        (BenchId::Bitonic, "nomul"),
        (BenchId::MatMul, "stack0"),
        (BenchId::Reduction, "stack0"),
        (BenchId::Transpose, "stack0"),
    ];
    for (id, want) in expect {
        let out = svc
            .submit(Request::Bench { id, n: 32, seed: 9 })
            .wait()
            .unwrap_or_else(|e| panic!("{}: {e}", id.name()));
        assert!(out.verified, "{}", id.name());
        assert_eq!(out.variant, want, "{} routed wrong", id.name());
    }
    // Per-variant metrics: every customized variant did work; the
    // baseline fallback stayed idle.
    let by_label: std::collections::HashMap<String, u64> = svc
        .variant_metrics()
        .into_iter()
        .map(|(l, m)| (l, m.jobs_completed))
        .collect();
    assert_eq!(by_label["baseline"], 0);
    assert_eq!(by_label["stack16"], 1);
    assert_eq!(by_label["stack0"], 3);
    assert_eq!(by_label["nomul"], 1);
    assert_eq!(svc.metrics().jobs_completed, 5);
}

#[test]
fn unprofiled_jobs_fall_back_to_the_most_capable_variant() {
    // Without a registered profile, the static signature of every looping
    // benchmark is stack-Unbounded: only the full-depth baseline covers
    // it, so the router must fall back there — and the job still runs.
    let svc = GpgpuService::start_fleet(FleetConfig {
        variants: vec![variant("nomul", 2, false), variant("baseline", 32, true)],
        queue_depth: 16,
    });
    let out = svc
        .submit(Request::Bench { id: BenchId::MatMul, n: 32, seed: 1 })
        .wait()
        .unwrap();
    assert!(out.verified);
    assert_eq!(out.variant, "baseline");
    // A straight-line, multiplier-free kernel routes off the fallback
    // even statically.
    let out = svc
        .submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 1 })
        .wait()
        .unwrap();
    assert_eq!(out.variant, "nomul");
}

#[test]
fn misrouted_profile_fails_structured_not_silent() {
    // Register a bogus profile that routes matmul onto the
    // multiplier-less variant. The shard launches admit on the routed
    // (lying) signature, so the failure surfaces as the structured
    // mid-run removed-unit trap — failing only that ticket, never
    // silently corrupting.
    let svc = GpgpuService::start_fleet(FleetConfig {
        variants: vec![variant("baseline", 32, true), variant("nomul", 2, false)],
        queue_depth: 16,
    });
    let r = customize::profile(BenchId::Bitonic, 32, 5).unwrap();
    // bitonic's (mul-free) signature attached to matmul — a lying profile.
    svc.register_profile(BenchId::MatMul, r.refined_signature());
    let err = svc
        .submit(Request::Bench { id: BenchId::MatMul, n: 32, seed: 2 })
        .wait()
        .expect_err("matmul cannot run without a multiplier");
    assert!(err.contains("multiplier"), "{err}");
    // The shard survives and the aggregate counters record the failure.
    let ok = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 2 }).wait();
    assert!(ok.unwrap().verified);
    assert_eq!(svc.metrics().jobs_failed, 1);
    assert_eq!(svc.metrics().jobs_completed, 1);
}

#[test]
fn variant_power_orders_the_routing() {
    let svc = paper_fleet();
    let power: std::collections::HashMap<String, f64> =
        svc.variant_power().into_iter().collect();
    assert!(power["nomul"] < power["stack0"]);
    assert!(power["stack0"] < power["stack16"]);
    assert!(power["stack16"] < power["baseline"]);
}
