//! Heterogeneous fleet (ISSUE 3): capability routing, per-variant
//! metrics, baseline fallback, and the registry-backed serving path —
//! plus the self-healing plane (ISSUE 7): sick-shard fault campaigns,
//! retry/re-route recovery, quarantine, and DMR.

use flexgrip::coordinator::{
    customize, FleetConfig, GpgpuService, RecoveryPolicy, Request, ServiceError, VariantSpec,
};
use flexgrip::gpgpu::GpgpuConfig;
use flexgrip::kernels::BenchId;
use flexgrip::sim::{FaultPlan, FaultTargets, SimError};

fn variant(label: &str, depth: u32, mul: bool) -> VariantSpec {
    let mut cfg = GpgpuConfig::new(1, 8);
    cfg.sm.warp_stack_depth = depth;
    cfg.sm.has_multiplier = mul;
    if !mul {
        cfg.sm.read_operands = 2;
    }
    VariantSpec::new(label, cfg)
}

/// Baseline + the three distinct Table-6 variants.
fn paper_fleet() -> GpgpuService {
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![
            variant("baseline", 32, true),
            variant("stack16", 16, true),
            variant("stack0", 0, true),
            variant("nomul", 2, false),
        ])
        .with_depth(16),
    );
    for id in BenchId::PAPER {
        let r = customize::profile(id, 32, 5).expect("profile");
        svc.register_profile(id, r.refined_signature());
    }
    svc
}

#[test]
fn jobs_route_to_the_cheapest_covering_variant() {
    let svc = paper_fleet();
    let expect = [
        (BenchId::Autocorr, "stack16"),
        (BenchId::Bitonic, "nomul"),
        (BenchId::MatMul, "stack0"),
        (BenchId::Reduction, "stack0"),
        (BenchId::Transpose, "stack0"),
    ];
    for (id, want) in expect {
        let out = svc
            .submit(Request::Bench { id, n: 32, seed: 9 })
            .wait()
            .unwrap_or_else(|e| panic!("{}: {e}", id.name()));
        assert!(out.verified, "{}", id.name());
        assert_eq!(out.variant, want, "{} routed wrong", id.name());
    }
    // Per-variant metrics: every customized variant did work; the
    // baseline fallback stayed idle.
    let by_label: std::collections::HashMap<String, u64> = svc
        .variant_metrics()
        .into_iter()
        .map(|(l, m)| (l, m.jobs_completed))
        .collect();
    assert_eq!(by_label["baseline"], 0);
    assert_eq!(by_label["stack16"], 1);
    assert_eq!(by_label["stack0"], 3);
    assert_eq!(by_label["nomul"], 1);
    assert_eq!(svc.metrics().jobs_completed, 5);
}

#[test]
fn unprofiled_jobs_fall_back_to_the_most_capable_variant() {
    // Without a registered profile, the static signature of every looping
    // benchmark is stack-Unbounded: only the full-depth baseline covers
    // it, so the router must fall back there — and the job still runs.
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![variant("nomul", 2, false), variant("baseline", 32, true)])
            .with_depth(16),
    );
    let out = svc
        .submit(Request::Bench { id: BenchId::MatMul, n: 32, seed: 1 })
        .wait()
        .unwrap();
    assert!(out.verified);
    assert_eq!(out.variant, "baseline");
    // A straight-line, multiplier-free kernel routes off the fallback
    // even statically.
    let out = svc
        .submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 1 })
        .wait()
        .unwrap();
    assert_eq!(out.variant, "nomul");
}

#[test]
fn misrouted_profile_fails_structured_not_silent() {
    // Register a bogus profile that routes matmul onto the
    // multiplier-less variant. The shard launches admit on the routed
    // (lying) signature, so the failure surfaces as the structured
    // mid-run removed-unit trap — failing only that ticket, never
    // silently corrupting.
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![variant("baseline", 32, true), variant("nomul", 2, false)])
            .with_depth(16),
    );
    let r = customize::profile(BenchId::Bitonic, 32, 5).unwrap();
    // bitonic's (mul-free) signature attached to matmul — a lying profile.
    svc.register_profile(BenchId::MatMul, r.refined_signature());
    let err = svc
        .submit(Request::Bench { id: BenchId::MatMul, n: 32, seed: 2 })
        .wait()
        .expect_err("matmul cannot run without a multiplier");
    assert!(err.to_string().contains("multiplier"), "{err}");
    // The shard survives and the aggregate counters record the failure.
    let ok = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 2 }).wait();
    assert!(ok.unwrap().verified);
    assert_eq!(svc.metrics().jobs_failed, 1);
    assert_eq!(svc.metrics().jobs_completed, 1);
}

#[test]
fn variant_power_orders_the_routing() {
    let svc = paper_fleet();
    let power: std::collections::HashMap<String, f64> =
        svc.variant_power().into_iter().collect();
    assert!(power["nomul"] < power["stack0"]);
    assert!(power["stack0"] < power["stack16"]);
    assert!(power["stack16"] < power["baseline"]);
}

/// Instruction-image upsets at mean interval 1 cycle: parity-detected
/// within the first issues of any launch, so every job on the sick shard
/// fails with `SimError::SoftError` — deterministically.
fn sick_plan() -> FaultPlan {
    FaultPlan::new(0xBAD5EED, 1_000_000.0)
        .with_targets(FaultTargets { instr_image: true, ..FaultTargets::none() })
}

#[test]
fn no_recovery_loses_every_job_on_a_sick_shard() {
    // Default policy = pre-resilience behavior: the fault fails the
    // ticket outright.
    let svc = GpgpuService::start_fleet(FleetConfig::new(vec![
        variant("sick", 32, true).with_fault(0, sick_plan()),
    ]));
    let tickets: Vec<_> = (0..4)
        .map(|i| svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: i }))
        .collect();
    for t in tickets {
        let err = t.wait().expect_err("no recovery policy: faults lose the job");
        assert!(matches!(err, ServiceError::Sim(SimError::SoftError { .. })), "{err:?}");
    }
    let m = svc.metrics();
    assert_eq!(m.jobs_failed, 4);
    assert_eq!(m.soft_errors, 4);
    assert_eq!(m.jobs_retried, 0);
    assert_eq!(m.jobs_completed, 0);
}

#[test]
fn retry_quarantine_completes_the_mix_and_heals_around_the_sick_shard() {
    // The two variants tie bit-for-bit on modeled power, so the QoS
    // router spreads jobs across both round-robin (and steers off the
    // sick shard once it is quarantined). What must hold regardless of
    // which variant any individual job lands on first: every job
    // completes verified, every sick-shard fault is rescued by re-route,
    // and the quarantine plane engages on the sick shard only.
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![
            variant("sick", 32, true).with_fault(0, sick_plan()),
            variant("healthy", 32, true),
        ])
        .with_policy(RecoveryPolicy::retry_quarantine(3, 2)),
    );
    let mix = [BenchId::VecAdd, BenchId::Reduction, BenchId::Bitonic, BenchId::Autocorr];
    let tickets: Vec<_> = (0..8u64)
        .map(|i| {
            let id = mix[i as usize % mix.len()];
            svc.submit(Request::Bench { id, n: 32, seed: i + 1 })
        })
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let out = t.wait().unwrap_or_else(|e| panic!("job {i}: {e}"));
        assert!(out.verified, "job {i}: zero corrupted outputs");
        assert_eq!(out.variant, "healthy", "job {i} must complete on the healthy peer");
        assert!(out.attempts <= 2, "job {i}: at most one fault + one rescue");
    }
    // 100% completion on the healthy peer; every job the sick shard
    // faulted was re-admitted rather than lost.
    let by_label: std::collections::HashMap<_, _> = svc.variant_metrics().into_iter().collect();
    let sick = &by_label["sick"];
    let healthy = &by_label["healthy"];
    assert_eq!(svc.metrics().jobs_failed, 0);
    assert_eq!(healthy.jobs_completed, 8);
    assert_eq!(sick.jobs_completed, 0);
    assert!(sick.soft_errors >= 1, "the round-robin must feed the sick shard: {sick:?}");
    assert_eq!(sick.jobs_retried, sick.soft_errors, "every fault is rescued: {sick:?}");
    // Quarantined after 2 consecutive faults, then reinstated on
    // probation (where later faults re-quarantine immediately).
    assert!(sick.quarantines >= 1, "{sick:?}");
    assert!(sick.reinstatements >= 1, "{sick:?}");
    assert_eq!(healthy.quarantines, 0);
    // shard_metrics exposes the same counters at shard granularity
    // (global index 0 = the sick variant's only shard).
    let shards = svc.shard_metrics();
    assert_eq!(shards[0].jobs_retried, sick.jobs_retried);
    assert!(shards[0].quarantines >= 1);
    assert_eq!(shards[1].jobs_completed, 8);
}

#[test]
fn dmr_agrees_when_healthy_and_is_rescued_when_sick() {
    // Healthy: both replicas are deterministic and identical — agree,
    // and the ticket reports one completed job.
    let svc =
        GpgpuService::start_fleet(FleetConfig::new(vec![variant("baseline", 32, true)]));
    let out = svc
        .submit(Request::Bench { id: BenchId::Reduction, n: 32, seed: 1 }.dmr())
        .wait()
        .expect("healthy DMR replicas agree");
    assert!(out.verified);
    assert_eq!(svc.metrics().jobs_completed, 1);
    drop(svc);

    // Sick shard (detected-class campaign): a replica faults, and with a
    // retry policy + healthy peer the DMR job is still rescued.
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![
            variant("sick", 32, true).with_fault(0, sick_plan()),
            variant("healthy", 32, true),
        ])
        .with_policy(RecoveryPolicy::retry(2)),
    );
    let out = svc
        .submit(Request::Bench { id: BenchId::Reduction, n: 32, seed: 2 }.dmr())
        .wait()
        .expect("DMR job must be rescued by re-route");
    assert_eq!(out.variant, "healthy");
    assert_eq!(out.attempts, 2);
}

#[test]
fn fleet_watchdog_override_budgets_every_job() {
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![variant("baseline", 32, true)]).with_watchdog(10),
    );
    let err = svc
        .submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 1 })
        .wait()
        .expect_err("a 10-cycle budget must trip the watchdog");
    assert!(matches!(err, ServiceError::Sim(SimError::Watchdog { .. })), "{err:?}");
    // Watchdog expiry is deterministic, not transient: never retried.
    assert_eq!(svc.metrics().jobs_retried, 0);
    assert_eq!(svc.metrics().jobs_failed, 1);
}
