//! Whole-model calibration against every published number, plus the
//! *shape* acceptance criteria from DESIGN.md §4 (who wins, by roughly
//! what factor, where the orderings fall).

use flexgrip::harness::{paper, Evaluation};
use flexgrip::kernels::BenchId;
use flexgrip::model::{area::area, power::power, ArchParams};

#[test]
fn table2_all_cells_within_tolerance() {
    for ((sms, sp), (luts, ffs, bram, dsp)) in paper::TABLE2 {
        let a = area(&ArchParams { num_sms: sms, num_sp: sp, ..ArchParams::baseline() });
        assert_eq!(a.luts, luts, "{sms}x{sp} LUT");
        assert_eq!(a.ffs, ffs, "{sms}x{sp} FF");
        assert_eq!(a.bram, bram, "{sms}x{sp} BRAM");
        assert_eq!(a.dsp, dsp, "{sms}x{sp} DSP");
    }
}

#[test]
fn table4_dynamic_power_exact() {
    for (label, dyn_w, _) in paper::TABLE4 {
        if label == "MicroBlaze" {
            continue;
        }
        let sp: u32 = label.split(", ").nth(1).unwrap().split(' ').next().unwrap().parse().unwrap();
        let got = power(&ArchParams { num_sp: sp, ..ArchParams::baseline() }).dynamic_w;
        assert!((got - dyn_w).abs() < 1e-9, "{label}: {got} vs {dyn_w}");
    }
}

#[test]
fn shape_flexgrip_beats_microblaze_everywhere() {
    let mut ev = Evaluation::new(128);
    for id in BenchId::PAPER {
        for (sms, sp) in [(1u32, 8u32), (1, 32), (2, 8), (2, 32)] {
            let s = ev.speedup(id, sms, sp);
            assert!(s > 1.0, "{} {sms}x{sp}: {s:.2}", id.name());
        }
    }
}

#[test]
fn shape_speedup_monotonic_in_sp_and_sm() {
    let mut ev = Evaluation::new(128);
    for id in BenchId::PAPER {
        let s8 = ev.speedup(id, 1, 8);
        let s16 = ev.speedup(id, 1, 16);
        let s32 = ev.speedup(id, 1, 32);
        assert!(s8 < s16 && s16 < s32, "{}: {s8:.1}/{s16:.1}/{s32:.1}", id.name());
        assert!(ev.speedup(id, 2, 8) > s8, "{}", id.name());
    }
}

#[test]
fn shape_table3_sm_scaling_band_and_ordering() {
    // Paper: 1.77 (reduction) .. 1.98 (matmul/transpose); the low-diverg
    // benchmarks split most evenly.
    let mut ev = Evaluation::new(256);
    let mut vals = Vec::new();
    for id in BenchId::PAPER {
        let s = ev.sm_scaling(id, 8);
        assert!((1.4..=2.05).contains(&s), "{}: {s:.2}", id.name());
        vals.push((id, s));
    }
    let matmul = vals.iter().find(|(i, _)| *i == BenchId::MatMul).unwrap().1;
    let transpose = vals.iter().find(|(i, _)| *i == BenchId::Transpose).unwrap().1;
    assert!(matmul > 1.9 && transpose > 1.9, "paper: ~1.98 for both");
}

#[test]
fn shape_energy_reduction_band() {
    // Paper Table 5: 66-87% dynamic energy reduction. Accept 50-95%.
    let mut ev = Evaluation::new(256);
    for id in BenchId::PAPER {
        let mb_ms = ev.mb(id).exec_time_ms(flexgrip::gpgpu::CLOCK_HZ);
        let mb_mj = mb_ms * flexgrip::model::MICROBLAZE_DYNAMIC_W;
        let fg_ms = ev.fg(id, 1, 8).exec_time_ms();
        let fg_mj = fg_ms * power(&ArchParams::baseline()).dynamic_w;
        let red = flexgrip::model::energy_reduction_pct(mb_mj, fg_mj);
        assert!((50.0..95.0).contains(&red), "{}: {red:.0}%", id.name());
    }
}

#[test]
fn shape_customization_reductions_ordered_like_table6() {
    // bitonic(2-op) > matmul-class (depth 0) > autocorr (depth 16) in
    // LUT reduction, as in the paper.
    let base = area(&ArchParams::baseline());
    let lut_red = |depth: u32, mul: bool| {
        area(&ArchParams {
            num_sms: 1,
            num_sp: 8,
            warp_stack_depth: depth,
            has_multiplier: mul,
            l1: None,
        })
        .lut_reduction_pct(&base)
    };
    let autocorr = lut_red(16, true);
    let matclass = lut_red(0, true);
    let bitonic2 = lut_red(2, false);
    assert!(bitonic2 > matclass && matclass > autocorr);
    assert!((10.0..20.0).contains(&autocorr), "paper 14%: {autocorr:.0}");
    assert!((25.0..35.0).contains(&matclass), "paper 30%: {matclass:.0}");
    assert!((50.0..70.0).contains(&bitonic2), "paper 62%: {bitonic2:.0}");
}

#[test]
fn paper_conclusion_averages() {
    // "architectural optimization can reduce dynamic energy consumption by
    // 14% and LUT area by 33%, on average" over the Table 6 configs.
    let base = area(&ArchParams::baseline());
    let base_p = power(&ArchParams::baseline()).dynamic_w;
    let configs = [(16u32, true), (0, true), (0, true), (0, true), (2, false)];
    let (mut area_sum, mut dyn_sum) = (0.0, 0.0);
    for (depth, mul) in configs {
        let p = ArchParams {
            num_sms: 1,
            num_sp: 8,
            warp_stack_depth: depth,
            has_multiplier: mul,
            l1: None,
        };
        area_sum += area(&p).lut_reduction_pct(&base);
        dyn_sum += 100.0 * (1.0 - power(&p).dynamic_w / base_p);
    }
    let (area_avg, dyn_avg) = (area_sum / 5.0, dyn_sum / 5.0);
    assert!((25.0..40.0).contains(&area_avg), "paper ~33%: {area_avg:.0}");
    assert!((8.0..20.0).contains(&dyn_avg), "paper ~14%: {dyn_avg:.0}");
}
