//! Coordinator service: job queue, driver-style kernel submission,
//! metrics, failure isolation, and the sharded device pool.

use flexgrip::asm::assemble;
use flexgrip::coordinator::{
    GpgpuService, MetricsSnapshot, Request, ServiceConfig, ServiceError,
};
use flexgrip::gpgpu::{GpgpuConfig, LaunchConfig};
use flexgrip::kernels::BenchId;
use std::sync::Arc;
use std::time::Duration;

#[test]
fn bench_jobs_complete_and_verify() {
    let svc = GpgpuService::start(GpgpuConfig::new(1, 16));
    let tickets: Vec<_> = BenchId::PAPER
        .iter()
        .map(|id| svc.submit(Request::Bench { id: *id, n: 32, seed: 3 }))
        .collect();
    for t in tickets {
        let out = t.wait().expect("job succeeds");
        assert!(out.verified);
        assert!(out.cycles > 0);
    }
    let m = svc.metrics();
    assert_eq!(m.jobs_completed, 5);
    assert_eq!(m.jobs_failed, 0);
    assert!(m.total_cycles > 0 && m.total_instructions > 0);
}

#[test]
fn driver_style_kernel_submission_roundtrip() {
    let svc = GpgpuService::start(GpgpuConfig::new(1, 8));
    let kernel = assemble(
        r#"
        .entry addone
        .regs 6
            S2R R1, SR_GTID
            SHL R2, R1, #2
            IADD R2, R2, #4096
            GLD R3, [R2]
            IADD R3, R3, #1
            GST [R2], R3
            EXIT
        "#,
    )
    .unwrap();
    let data: Vec<i32> = (0..64).map(|v| v * 10).collect();
    let t = svc.submit(Request::Kernel {
        kernel: Box::new(kernel),
        launch: LaunchConfig::linear(1, 64),
        params: vec![],
        gmem_bytes: 1 << 14,
        inputs: vec![(4096, data.clone())],
        read_back: (4096, 64),
    });
    let out = t.wait().unwrap();
    assert_eq!(out.label, "addone");
    let want: Vec<i32> = data.iter().map(|v| v + 1).collect();
    assert_eq!(out.data, want);
}

#[test]
fn failed_jobs_do_not_take_down_the_service() {
    let svc = GpgpuService::start(GpgpuConfig::new(1, 8));
    let bad = assemble("JOIN\nEXIT").unwrap();
    let t_bad = svc.submit(Request::Kernel {
        kernel: Box::new(bad),
        launch: LaunchConfig::linear(1, 32),
        params: vec![],
        gmem_bytes: 4096,
        inputs: vec![],
        read_back: (0, 1),
    });
    assert!(t_bad.wait().is_err());
    // The service keeps accepting work.
    let t_ok = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 1 });
    assert!(t_ok.wait().unwrap().verified);
    let m = svc.metrics();
    assert_eq!(m.jobs_failed, 1);
    assert_eq!(m.jobs_completed, 1);
}

#[test]
fn many_queued_jobs_fifo_complete() {
    let svc = GpgpuService::start(GpgpuConfig::new(2, 8));
    let tickets: Vec<_> = (0..20)
        .map(|i| svc.submit(Request::Bench { id: BenchId::Reduction, n: 32, seed: i }))
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let out = t.wait().unwrap_or_else(|e| panic!("job {i}: {e}"));
        assert!(out.verified);
    }
    assert_eq!(svc.metrics().jobs_completed, 20);
}

#[test]
fn shutdown_joins_worker() {
    let svc = GpgpuService::start(GpgpuConfig::new(1, 8));
    let t = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 1 });
    t.wait().unwrap();
    drop(svc); // must join cleanly, not hang
}

#[test]
fn pool_absorbs_concurrent_mixed_jobs_across_shards() {
    // 32 concurrent mixed jobs over 4 shards: every ticket resolves and
    // the per-shard metrics sum to the aggregate snapshot.
    let svc = GpgpuService::start_pool(
        GpgpuConfig::new(2, 8),
        ServiceConfig { shards: 4, queue_depth: 8 },
    );
    let mix = [
        BenchId::VecAdd,
        BenchId::Reduction,
        BenchId::Bitonic,
        BenchId::Autocorr,
        BenchId::Transpose,
    ];
    let tickets: Vec<_> = (0..32)
        .map(|i| {
            svc.submit(Request::Bench {
                id: mix[i as usize % mix.len()],
                n: 32,
                seed: i + 1,
            })
        })
        .collect();
    let mut seen_shards = std::collections::HashSet::new();
    for (i, t) in tickets.into_iter().enumerate() {
        let out = t.wait().unwrap_or_else(|e| panic!("job {i}: {e}"));
        assert!(out.verified, "job {i}");
        assert!(out.shard < 4, "job {i} shard {}", out.shard);
        seen_shards.insert(out.shard);
    }
    let shards = svc.shard_metrics();
    assert_eq!(shards.len(), 4);
    let summed = shards
        .iter()
        .fold(MetricsSnapshot::default(), |acc, s| acc.merged(s));
    let agg = svc.metrics();
    assert_eq!(summed, agg, "shard metrics must sum to the aggregate");
    assert_eq!(agg.jobs_completed, 32);
    assert_eq!(agg.jobs_failed, 0);
    assert!(agg.total_cycles > 0 && agg.total_instructions > 0);
    assert!(
        seen_shards.len() > 1,
        "32 jobs on 4 shards must not all land on one worker"
    );
}

#[test]
fn pool_backpressure_blocks_then_completes() {
    // queue_depth 2 with 1 shard: submits beyond the depth must block
    // until the worker drains, and every job must still complete.
    let svc = GpgpuService::start_pool(
        GpgpuConfig::new(1, 8),
        ServiceConfig { shards: 1, queue_depth: 2 },
    );
    let tickets: Vec<_> = (0..8)
        .map(|i| svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: i }))
        .collect();
    for t in tickets {
        assert!(t.wait().unwrap().verified);
    }
    assert_eq!(svc.metrics().jobs_completed, 8);
}

#[test]
fn pool_failures_isolated_per_shard() {
    let svc = GpgpuService::start_pool(
        GpgpuConfig::new(1, 8),
        ServiceConfig { shards: 2, queue_depth: 8 },
    );
    let bad = assemble("JOIN\nEXIT").unwrap();
    let t_bad = svc.submit(Request::Kernel {
        kernel: Box::new(bad),
        launch: LaunchConfig::linear(1, 32),
        params: vec![],
        gmem_bytes: 4096,
        inputs: vec![],
        read_back: (0, 1),
    });
    let t_ok = svc.submit(Request::Bench { id: BenchId::Reduction, n: 64, seed: 2 });
    assert!(t_bad.wait().is_err());
    assert!(t_ok.wait().unwrap().verified);
    let agg = svc.metrics();
    assert_eq!(agg.jobs_failed, 1);
    assert_eq!(agg.jobs_completed, 1);
}

#[test]
fn kernel_with_overlapping_writes_falls_back_to_sequential() {
    // Both blocks (one per SM) store their value to the same address:
    // the parallel launch mode rejects the merge, and the shard must
    // retry on the sequential path (SM order, last writer wins) instead
    // of failing.
    let svc = GpgpuService::start(GpgpuConfig::new(2, 8));
    let k = assemble(
        r#"
        .entry clash
        .regs 6
            S2R R1, SR_CTAID
            MOV R2, #0
            GST [R2], R1
            EXIT
        "#,
    )
    .unwrap();
    let t = svc.submit(Request::Kernel {
        kernel: Box::new(k),
        launch: LaunchConfig::linear(2, 32),
        params: vec![],
        gmem_bytes: 4096,
        inputs: vec![],
        read_back: (0, 1),
    });
    let out = t.wait().expect("conflicting kernel must fall back, not fail");
    // Sequential order: SM 0 runs block 0 (stores 0), then SM 1 runs
    // block 1 (stores 1) — last writer is block 1.
    assert_eq!(out.data, vec![1]);
    assert_eq!(svc.metrics().jobs_failed, 0);
}

#[test]
fn panicking_job_fails_its_ticket_but_not_the_shard() {
    // kernels::prepare asserts on non-power-of-two sizes; that panic must
    // be contained to the job, leaving the shard alive for later work.
    let svc = GpgpuService::start(GpgpuConfig::new(1, 8));
    let t_bad = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 48, seed: 1 });
    let err = t_bad.wait().expect_err("invalid size must fail the ticket");
    assert!(matches!(err, ServiceError::Panic(_)), "{err:?}");
    assert!(err.to_string().contains("panicked"), "{err}");
    let t_ok = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 1 });
    assert!(t_ok.wait().expect("shard must survive the panic").verified);
    let m = svc.metrics();
    assert_eq!(m.jobs_failed, 1);
    assert_eq!(m.jobs_completed, 1);
}

#[test]
fn job_failures_preserve_the_structured_sim_error() {
    // The bad kernel's failure must travel the channel as the typed
    // SimError it was, not a stringified copy.
    let svc = GpgpuService::start(GpgpuConfig::new(1, 8));
    let bad = assemble("JOIN\nEXIT").unwrap();
    let t = svc.submit(Request::Kernel {
        kernel: Box::new(bad),
        launch: LaunchConfig::linear(1, 32),
        params: vec![],
        gmem_bytes: 4096,
        inputs: vec![],
        read_back: (0, 1),
    });
    let err = t.wait().expect_err("JOIN with an empty warp stack must fail");
    assert!(matches!(err, ServiceError::Sim(_)), "{err:?}");
}

#[test]
fn submit_timeout_sheds_load_when_saturated() {
    // 1 shard, depth 1: one slow job running, one queued — the routed
    // queue stays full, so a timed submit must give up with `Saturated`
    // instead of blocking behind the slow job.
    let svc = GpgpuService::start_pool(
        GpgpuConfig::new(1, 8),
        ServiceConfig { shards: 1, queue_depth: 1 },
    );
    let t_slow = svc.submit(Request::Bench { id: BenchId::MatMul, n: 128, seed: 1 });
    let t_queued = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 1 });
    let err = svc
        .submit_timeout(
            Request::Bench { id: BenchId::VecAdd, n: 32, seed: 2 },
            Duration::from_millis(30),
        )
        .expect_err("full queue + busy shard must shed within the timeout");
    assert_eq!(err, ServiceError::Saturated);
    // The shed submit left no trace: both accepted jobs still complete.
    assert!(t_slow.wait().unwrap().verified);
    assert!(t_queued.wait().unwrap().verified);
    assert_eq!(svc.metrics().jobs_completed, 2);
}

#[test]
fn shutdown_under_load_wakes_blocked_submitters_with_structured_error() {
    // 1 shard, depth 1: a slow job occupies the worker and a second fills
    // the queue, so a third submitter blocks in `submit`. Stopping intake
    // mid-drain must wake it with ServiceError::Shutdown — not leave it
    // hanging on the condvar.
    let svc = Arc::new(GpgpuService::start_pool(
        GpgpuConfig::new(1, 8),
        ServiceConfig { shards: 1, queue_depth: 1 },
    ));
    let t_slow = svc.submit(Request::Bench { id: BenchId::MatMul, n: 128, seed: 3 });
    let t_queued = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 3 });
    let blocked = {
        let svc = svc.clone();
        std::thread::spawn(move || {
            svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 4 }).wait()
        })
    };
    // Let the submitter reach the backpressure wait (the slow matmul keeps
    // the queue full far longer than this), then stop intake.
    std::thread::sleep(Duration::from_millis(100));
    svc.shutdown();
    let res = blocked.join().expect("submitter thread must not panic");
    assert_eq!(res.expect_err("blocked submit must observe shutdown"), ServiceError::Shutdown);
    // Already-accepted work still drains.
    assert!(t_slow.wait().unwrap().verified);
    assert!(t_queued.wait().unwrap().verified);
    // Submits after shutdown resolve structurally too.
    let late = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 5 });
    assert_eq!(late.wait().expect_err("post-shutdown submit"), ServiceError::Shutdown);
}

#[test]
fn multi_shard_steal_drains_every_ticket_under_shutdown() {
    // 4 shards over one work-stealing queue: submits round-robin across
    // the per-shard deques and an idle shard steals from its siblings.
    // Stopping intake immediately after a burst races the steal scan
    // against the drain — every accepted ticket must still resolve
    // exactly once, and nothing may be popped twice (jobs_completed
    // would overcount).
    let svc = GpgpuService::start_pool(
        GpgpuConfig::new(1, 8),
        ServiceConfig { shards: 4, queue_depth: 64 },
    );
    let tickets: Vec<_> = (0..16)
        .map(|i| svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: i }))
        .collect();
    svc.shutdown();
    for (i, t) in tickets.into_iter().enumerate() {
        let out = t.wait().unwrap_or_else(|e| panic!("drained job {i}: {e}"));
        assert!(out.verified, "job {i}");
    }
    let m = svc.metrics();
    assert_eq!(m.jobs_completed, 16);
    assert_eq!(m.jobs_failed, 0);
}

#[test]
fn queue_wait_metric_accumulates_on_dispatch() {
    // The sharded queue stamps jobs at submit and the dispatching shard
    // accumulates the wait: after a burst behind one slow job the pool's
    // aggregate queue_wait_ns must be visibly nonzero.
    let svc = GpgpuService::start_pool(
        GpgpuConfig::new(1, 8),
        ServiceConfig { shards: 1, queue_depth: 16 },
    );
    let tickets: Vec<_> = (0..4)
        .map(|i| svc.submit(Request::Bench { id: BenchId::MatMul, n: 64, seed: i }))
        .collect();
    for t in tickets {
        assert!(t.wait().unwrap().verified);
    }
    let m = svc.metrics();
    assert_eq!(m.jobs_completed, 4);
    // Jobs 2..4 each waited at least as long as a matmul run.
    assert!(m.queue_wait_ns > 0, "queue wait never accumulated");
}

#[test]
fn pool_drop_drains_queued_jobs() {
    // Tickets taken before shutdown must resolve even if the service is
    // dropped immediately after submission (graceful drain).
    let svc = GpgpuService::start_pool(
        GpgpuConfig::new(1, 8),
        ServiceConfig { shards: 2, queue_depth: 16 },
    );
    let tickets: Vec<_> = (0..6)
        .map(|i| svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: i }))
        .collect();
    drop(svc);
    for (i, t) in tickets.into_iter().enumerate() {
        let out = t.wait().unwrap_or_else(|e| panic!("drained job {i}: {e}"));
        assert!(out.verified, "drained job {i}");
    }
}
