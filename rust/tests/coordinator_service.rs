//! Coordinator service: job queue, driver-style kernel submission,
//! metrics, failure isolation.

use flexgrip::asm::assemble;
use flexgrip::coordinator::{GpgpuService, Request};
use flexgrip::gpgpu::{GpgpuConfig, LaunchConfig};
use flexgrip::kernels::BenchId;

#[test]
fn bench_jobs_complete_and_verify() {
    let svc = GpgpuService::start(GpgpuConfig::new(1, 16));
    let tickets: Vec<_> = BenchId::PAPER
        .iter()
        .map(|id| svc.submit(Request::Bench { id: *id, n: 32, seed: 3 }))
        .collect();
    for t in tickets {
        let out = t.wait().expect("job succeeds");
        assert!(out.verified);
        assert!(out.cycles > 0);
    }
    let m = svc.metrics();
    assert_eq!(m.jobs_completed, 5);
    assert_eq!(m.jobs_failed, 0);
    assert!(m.total_cycles > 0 && m.total_instructions > 0);
}

#[test]
fn driver_style_kernel_submission_roundtrip() {
    let svc = GpgpuService::start(GpgpuConfig::new(1, 8));
    let kernel = assemble(
        r#"
        .entry addone
        .regs 6
            S2R R1, SR_GTID
            SHL R2, R1, #2
            IADD R2, R2, #4096
            GLD R3, [R2]
            IADD R3, R3, #1
            GST [R2], R3
            EXIT
        "#,
    )
    .unwrap();
    let data: Vec<i32> = (0..64).map(|v| v * 10).collect();
    let t = svc.submit(Request::Kernel {
        kernel: Box::new(kernel),
        launch: LaunchConfig::linear(1, 64),
        params: vec![],
        gmem_bytes: 1 << 14,
        inputs: vec![(4096, data.clone())],
        read_back: (4096, 64),
    });
    let out = t.wait().unwrap();
    assert_eq!(out.label, "addone");
    let want: Vec<i32> = data.iter().map(|v| v + 1).collect();
    assert_eq!(out.data, want);
}

#[test]
fn failed_jobs_do_not_take_down_the_service() {
    let svc = GpgpuService::start(GpgpuConfig::new(1, 8));
    let bad = assemble("JOIN\nEXIT").unwrap();
    let t_bad = svc.submit(Request::Kernel {
        kernel: Box::new(bad),
        launch: LaunchConfig::linear(1, 32),
        params: vec![],
        gmem_bytes: 4096,
        inputs: vec![],
        read_back: (0, 1),
    });
    assert!(t_bad.wait().is_err());
    // The service keeps accepting work.
    let t_ok = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 1 });
    assert!(t_ok.wait().unwrap().verified);
    let m = svc.metrics();
    assert_eq!(m.jobs_failed, 1);
    assert_eq!(m.jobs_completed, 1);
}

#[test]
fn many_queued_jobs_fifo_complete() {
    let svc = GpgpuService::start(GpgpuConfig::new(2, 8));
    let tickets: Vec<_> = (0..20)
        .map(|i| svc.submit(Request::Bench { id: BenchId::Reduction, n: 32, seed: i }))
        .collect();
    for (i, t) in tickets.into_iter().enumerate() {
        let out = t.wait().unwrap_or_else(|e| panic!("job {i}: {e}"));
        assert!(out.verified);
    }
    assert_eq!(svc.metrics().jobs_completed, 20);
}

#[test]
fn shutdown_joins_worker() {
    let svc = GpgpuService::start(GpgpuConfig::new(1, 8));
    let t = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 1 });
    t.wait().unwrap();
    drop(svc); // must join cleanly, not hang
}
