//! Assembler integration: source-level programs, diagnostics, and
//! binary-layout invariants.

use flexgrip::asm::{assemble, AsmError};
use flexgrip::isa::{Cond, Op, Operand};

#[test]
fn benchmark_sources_all_assemble_and_predecode() {
    for id in flexgrip::kernels::BenchId::ALL {
        let k = assemble(id.source()).unwrap_or_else(|e| panic!("{}: {e}", id.name()));
        // Pre-decode must accept everything the assembler emits.
        let pre = flexgrip::isa::decode_stream(&k.code).unwrap();
        assert_eq!(pre.len(), k.instrs.len(), "{}", id.name());
        // Every kernel ends with EXIT on all paths we emit.
        assert!(
            k.instrs.iter().any(|(_, i)| i.op == Op::Exit),
            "{} must contain EXIT",
            id.name()
        );
    }
}

#[test]
fn labels_resolve_across_long_programs() {
    // 1000 instructions with branches spanning the whole image.
    let mut src = String::from("start:\n");
    for i in 0..500 {
        src.push_str(&format!("IADD R1, R1, #{i}\n"));
    }
    src.push_str("ISETP P0, R1, #0\n@P0.GT BRA start\nBRA end\n");
    for _ in 0..500 {
        src.push_str("NOP\n");
    }
    src.push_str("end:\nEXIT\n");
    let k = assemble(&src).unwrap();
    assert_eq!(k.labels["start"], 0);
    let bra_end = k
        .instrs
        .iter()
        .find(|(_, i)| i.op == Op::Bra && i.guard.is_unconditional())
        .unwrap();
    assert_eq!(bra_end.1.branch_target(), Some(k.labels["end"]));
}

#[test]
fn diagnostics_carry_line_numbers() {
    let cases: [(&str, &str); 6] = [
        ("IADD R1, R2", "expected"),
        ("BOGUS R1, R2, R3", "unknown mnemonic"),
        ("IADD R99, R1, R2", "expected register"), // R99 lexes as ident
        ("@P9 IADD R1, R1, #1", "expected predicate register"),
        (".regs 200", "out of range"),
        ("GLD R1, [R2+99999]", "out of i16 range"),
    ];
    for (src, want) in cases {
        let full = format!("NOP\nNOP\n{src}\nEXIT");
        let err: AsmError = assemble(&full).unwrap_err();
        assert_eq!(err.line, 3, "line for `{src}`");
        assert!(
            err.msg.contains(want),
            "`{src}` -> `{}` (wanted `{want}`)",
            err.msg
        );
    }
}

#[test]
fn immediates_all_radixes_and_signs() {
    let k = assemble(
        "MOV R1, #0x7fffffff\nMOV R2, #-2147483648\nMOV R3, #1_000_000\nEXIT",
    )
    .unwrap();
    let imm = |i: usize| match k.instrs[i].1.src2 {
        Operand::Imm(v) => v,
        other => panic!("{other:?}"),
    };
    assert_eq!(imm(0), i32::MAX);
    assert_eq!(imm(1), i32::MIN);
    assert_eq!(imm(2), 1_000_000);
}

#[test]
fn guard_conditions_parse_each_variant() {
    for cond in ["EQ", "NE", "LT", "LE", "GT", "GE"] {
        let k = assemble(&format!("@P2.{cond} IADD R1, R1, #1\nEXIT")).unwrap();
        let g = k.instrs[0].1.guard;
        assert_eq!(g.preg, 2);
        assert_eq!(g.cond, Cond::from_name(cond).unwrap());
    }
}

#[test]
fn mixed_size_layout_matches_spec() {
    // short(4): NOP, MOV reg, S2R, NOT, EXIT; long(8): imm/mem/branch ops.
    let k = assemble(
        "NOP\nMOV R1, R2\nS2R R3, SR_TID\nNOT R4, R4\nMOV R5, #9\nGLD R6, [R1]\nBRA fin\nfin:\nEXIT",
    )
    .unwrap();
    let pcs: Vec<u32> = k.instrs.iter().map(|(pc, _)| *pc).collect();
    assert_eq!(pcs, vec![0, 4, 8, 12, 16, 24, 32, 40]);
    assert_eq!(k.code.len(), 44);
}

#[test]
fn comments_and_blank_lines_ignored_everywhere() {
    let k = assemble(
        "; header\n\n  // indented comment\nNOP ; trailing\nEXIT // done\n\n",
    )
    .unwrap();
    assert_eq!(k.instrs.len(), 2);
}
