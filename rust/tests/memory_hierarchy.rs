//! Memory-hierarchy differential suite: the tags-only L1 cache and the
//! SM<->memory interconnect model may change *when* things happen, never
//! *what* happens. Every benchmark, at every swept geometry and SM
//! count, must produce a memory image bit-identical to the flat-memory
//! run — on both the sequential reference path and the COW parallel
//! path — and the two cached paths must agree on simulated cycles.

use flexgrip::asm::assemble;
use flexgrip::gpgpu::{Gpgpu, GpgpuConfig, LaunchConfig, LaunchRequest};
use flexgrip::kernels::{self, BenchId, RunOptions, Workload};
use flexgrip::rng::XorShift64;
use flexgrip::sim::{CacheGeometry, GlobalMem, MemoryConfig};

const GEOMETRIES: [&str; 3] = ["2x16x32", "4x64x32", "4x256x64"];

fn image(g: &GlobalMem) -> Vec<i32> {
    g.read_words(0, g.size_bytes() as usize / 4).unwrap()
}

fn run_with(w: &Workload, cfg: GpgpuConfig, parallel: bool) -> (Vec<i32>, u64) {
    let gpgpu = Gpgpu::new(cfg);
    let mut g = w.make_gmem();
    let opts = if parallel { RunOptions::new().parallel() } else { RunOptions::default() };
    let run = w.run(&gpgpu, &mut g, opts).expect("run");
    w.verify(&g).expect("verifies");
    (image(&g), run.cycles)
}

/// Flat vs cached (sequential and parallel) on one configuration.
fn assert_cache_transparent(id: BenchId, n: u32, seed: u64, sms: u32, geom: CacheGeometry) {
    let w = kernels::prepare(id, n, seed);
    let flat = GpgpuConfig::new(sms, 8);
    let cached = GpgpuConfig::new(sms, 8).with_memory(MemoryConfig::with_l1(geom));
    let (flat_img, _) = run_with(&w, flat, false);
    let (seq_img, seq_cycles) = run_with(&w, cached, false);
    let (par_img, par_cycles) = run_with(&w, cached, true);
    let label = format!("{} n={n} {sms}sm l1 {}", id.name(), geom.label());
    assert!(seq_img == flat_img, "{label}: cached sequential image diverged from flat");
    assert!(par_img == flat_img, "{label}: cached parallel image diverged from flat");
    assert_eq!(seq_cycles, par_cycles, "{label}: cached seq/par cycle models disagree");
}

#[test]
fn cache_is_functionally_invisible_across_benchmarks_geometries_and_sms() {
    for id in BenchId::ALL {
        for sms in [1u32, 2, 4, 8] {
            for geom in GEOMETRIES {
                assert_cache_transparent(id, 32, 0xCAC4E, sms, CacheGeometry::parse(geom).unwrap());
            }
        }
    }
}

#[test]
fn prop_cache_transparent_on_randomized_configurations() {
    // Random benchmark x SM count x cache shape x problem size x data
    // seed: the bit-identity contract has no corner cases.
    let mut rng = XorShift64::new(0x11CACE);
    for case in 0..24 {
        let id = BenchId::ALL[rng.below(BenchId::ALL.len() as u64) as usize];
        let sms = [1u32, 2, 4, 8][rng.below(4) as usize];
        let geom = CacheGeometry {
            ways: [1u32, 2, 3, 4, 8][rng.below(5) as usize],
            sets: [1u32, 8, 64, 256][rng.below(4) as usize],
            line_bytes: [16u32, 32, 64, 128][rng.below(4) as usize],
        };
        geom.validate().expect("generator emits valid geometries");
        let n = if id.is_matrix() { 32 } else { [32u32, 64][rng.below(2) as usize] };
        let seed = rng.next_u64();
        eprintln!("case {case}: {} n={n} {sms}sm l1 {}", id.name(), geom.label());
        assert_cache_transparent(id, n, seed, sms, geom);
    }
}

#[test]
fn flat_runs_report_zero_mem_stats() {
    let w = kernels::prepare(BenchId::MatMul, 32, 5);
    let gpgpu = Gpgpu::new(GpgpuConfig::new(2, 8));
    let mut g = w.make_gmem();
    let run = w.run(&gpgpu, &mut g, RunOptions::default()).unwrap();
    let m = run.stats.mem;
    assert_eq!(m.hits + m.misses + m.evictions + m.mshr_merges, 0);
    assert_eq!(m.fill_stall_cycles + m.contention_cycles, 0);
}

#[test]
fn cached_runs_populate_mem_stats() {
    let geom = CacheGeometry::parse("4x64x32").unwrap();
    let cfg = GpgpuConfig::new(2, 8).with_memory(MemoryConfig::with_l1(geom));
    let w = kernels::prepare(BenchId::MatMul, 32, 5);
    let gpgpu = Gpgpu::new(cfg);
    let mut g = w.make_gmem();
    let run = w.run(&gpgpu, &mut g, RunOptions::default()).unwrap();
    let m = run.stats.mem;
    assert!(m.misses > 0, "cold cache must miss");
    assert!(m.hits > 0, "matmul reuses rows: must hit");
    assert!(m.fill_stall_cycles > 0, "misses park warps on the fill port");
}

#[test]
fn launch_request_memory_overrides_the_device_default() {
    // A per-launch `.memory()` turns the cache on for that launch only,
    // and the result surfaces through `LaunchResult::mem_stats`.
    let k = assemble("S2R R1, SR_GTID\nSHL R2, R1, #2\nGLD R3, [R2]\nGST [R2], R3\nEXIT").unwrap();
    let gp = Gpgpu::new(GpgpuConfig::new(1, 8)); // device default: flat
    let geom = CacheGeometry::parse("2x16x32").unwrap();

    let mut g = GlobalMem::new(1 << 14);
    let flat = gp.launch(LaunchRequest::new(&k, LaunchConfig::linear(2, 64), &mut g)).unwrap();
    assert_eq!(flat.mem_stats().hits + flat.mem_stats().misses, 0);

    let mut g = GlobalMem::new(1 << 14);
    let cached = gp
        .launch(
            LaunchRequest::new(&k, LaunchConfig::linear(2, 64), &mut g)
                .memory(MemoryConfig::with_l1(geom)),
        )
        .unwrap();
    assert!(cached.mem_stats().misses > 0, "{:?}", cached.mem_stats());
}

#[test]
fn larger_line_size_lowers_miss_count_on_streaming_access() {
    // memstress stride 1 streams adjacent words: doubling the line size
    // halves the number of distinct lines fetched, so misses must drop.
    let run_misses = |line_bytes: u32| {
        let geom = CacheGeometry { ways: 4, sets: 64, line_bytes };
        let cfg = GpgpuConfig::new(1, 8).with_memory(MemoryConfig::with_l1(geom));
        let w = kernels::prepare_memstress(64, 9, 1);
        let gpgpu = Gpgpu::new(cfg);
        let mut g = w.make_gmem();
        let run = w.run(&gpgpu, &mut g, RunOptions::default()).unwrap();
        w.verify(&g).unwrap();
        run.stats.mem.misses
    };
    let (m32, m64, m128) = (run_misses(32), run_misses(64), run_misses(128));
    assert!(m32 > m64 && m64 > m128, "misses must fall with line size: {m32} {m64} {m128}");
}
