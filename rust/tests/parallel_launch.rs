//! Parallel multi-SM launch: the scoped-thread simulate phase must be
//! observationally identical to the sequential reference path — same
//! memory image, same per-SM statistics, same simulated cycles — and the
//! merge phase must catch kernels that violate the disjoint-write
//! contract.

use flexgrip::asm::assemble;
use flexgrip::gpgpu::{Gpgpu, GpgpuConfig, LaunchConfig, LaunchRequest};
use flexgrip::kernels::{self, BenchId, RunOptions};
use flexgrip::rng::XorShift64;
use flexgrip::sim::{GlobalMem, SimError};

/// Run one paper workload both ways and compare everything observable.
fn assert_deterministic(id: BenchId, n: u32, sms: u32, sp: u32, seed: u64) {
    assert_deterministic_cfg(id, n, GpgpuConfig::new(sms, sp), seed);
}

fn assert_deterministic_cfg(id: BenchId, n: u32, cfg: GpgpuConfig, seed: u64) {
    let gpgpu = Gpgpu::new(cfg);
    let w = kernels::prepare(id, n, seed);

    let mut g_seq = w.make_gmem();
    let seq = w.run(&gpgpu, &mut g_seq, RunOptions::default()).expect("sequential run");
    w.verify(&g_seq).expect("sequential verifies");

    let mut g_par = w.make_gmem();
    let par = w
        .run(&gpgpu, &mut g_par, RunOptions::new().parallel())
        .expect("parallel run");
    w.verify(&g_par).expect("parallel verifies");

    assert_eq!(seq.cycles, par.cycles, "{} n={n}: total cycles", id.name());
    assert_eq!(seq.phases.len(), par.phases.len());
    for (pi, (ps, pp)) in seq.phases.iter().zip(&par.phases).enumerate() {
        assert_eq!(ps.total.cycles, pp.total.cycles, "{} phase {pi}", id.name());
        assert_eq!(
            ps.total.instructions,
            pp.total.instructions,
            "{} phase {pi}",
            id.name()
        );
        assert_eq!(ps.per_sm.len(), pp.per_sm.len());
        for (si, (ss, sp_stats)) in ps.per_sm.iter().zip(&pp.per_sm).enumerate() {
            assert_eq!(ss.cycles, sp_stats.cycles, "{} phase {pi} SM {si}", id.name());
            assert_eq!(ss.blocks, sp_stats.blocks, "{} phase {pi} SM {si}", id.name());
            assert_eq!(
                ss.thread_instructions,
                sp_stats.thread_instructions,
                "{} phase {pi} SM {si}",
                id.name()
            );
        }
    }
    assert_eq!(
        seq.stats.max_stack_depth, par.stats.max_stack_depth,
        "{} stack depth",
        id.name()
    );

    let words = (g_seq.size_bytes() / 4) as usize;
    assert_eq!(
        g_seq.read_words(0, words).unwrap(),
        g_par.read_words(0, words).unwrap(),
        "{} n={n}: memory images must be byte-identical",
        id.name()
    );
}

#[test]
fn two_sm_parallel_identical_to_sequential_all_paper_benchmarks() {
    for id in BenchId::PAPER {
        assert_deterministic(id, 64, 2, 8, 0xDE7E);
    }
}

#[test]
fn parallel_path_identical_on_one_sm_too() {
    for id in BenchId::PAPER {
        assert_deterministic(id, 32, 1, 16, 0xDE7E);
    }
}

#[test]
fn prop_cow_parallel_matches_sequential_on_randomized_geometries() {
    // The COW-snapshot parallel path must be observationally identical to
    // the sequential reference for every paper benchmark across random
    // SM counts (including >2, where the snapshot is the only thing that
    // keeps setup cheap), SP widths, problem sizes and data seeds.
    let mut rng = XorShift64::new(0xC0_57A9E5);
    for case in 0..4 {
        for id in BenchId::PAPER {
            let sms = [1u32, 2, 3, 4, 6, 8][rng.below(6) as usize];
            let sp = [8u32, 16, 32][rng.below(3) as usize];
            // Matrix workloads are n x n threads: keep debug runtime sane.
            let n = if id.is_matrix() {
                [32u32, 64][rng.below(2) as usize]
            } else {
                [32u32, 64, 128, 256][rng.below(4) as usize]
            };
            let seed = rng.next_u64();
            eprintln!("case {case}: {} n={n} {sms}sm {sp}sp seed={seed:#x}", id.name());
            assert_deterministic(id, n, sms, sp, seed);
        }
    }
}

#[test]
fn customized_variants_stay_deterministic() {
    // ISSUE-3 acceptance: the sequential-vs-parallel determinism contract
    // holds on the paper's customized variants too — bitonic on the
    // multiplier-less depth-2 device, autocorr on the depth-16 one.
    for (id, depth, mul) in [(BenchId::Bitonic, 2u32, false), (BenchId::Autocorr, 16, true)] {
        let mut cfg = GpgpuConfig::new(2, 8);
        cfg.sm.warp_stack_depth = depth;
        cfg.sm.has_multiplier = mul;
        if !mul {
            cfg.sm.read_operands = 2;
        }
        assert_deterministic_cfg(id, 64, cfg, 0xC057);
    }
}

#[test]
fn parallel_path_stable_across_repeated_runs() {
    // Thread scheduling must never leak into simulation results.
    let gpgpu = Gpgpu::new(GpgpuConfig::new(2, 16));
    let w = kernels::prepare(BenchId::Bitonic, 128, 9);
    let run = |w: &kernels::Workload| {
        let mut g = w.make_gmem();
        let r = w.run(&gpgpu, &mut g, RunOptions::new().parallel()).unwrap();
        let words = (g.size_bytes() / 4) as usize;
        (r.cycles, g.read_words(0, words).unwrap())
    };
    let (c1, m1) = run(&w);
    let (c2, m2) = run(&w);
    assert_eq!(c1, c2);
    assert_eq!(m1, m2);
}

#[test]
fn conflicting_writes_across_sms_are_detected() {
    // Both blocks (one per SM) store to the same address: the merge phase
    // must refuse rather than silently pick a winner.
    let k = assemble(
        r#"
        .entry clash
        .regs 4
            MOV R1, #64
            MOV R2, #1
            GST [R1], R2
            EXIT
        "#,
    )
    .unwrap();
    let mut g = GlobalMem::new(4096);
    let err = Gpgpu::new(GpgpuConfig::new(2, 8))
        .launch(LaunchRequest::new(&k, LaunchConfig::linear(2, 32), &mut g).parallel())
        .unwrap_err();
    match err {
        SimError::WriteConflict { addr, first_sm, second_sm } => {
            assert_eq!(addr, 64);
            assert_ne!(first_sm, second_sm);
        }
        other => panic!("want WriteConflict, got {other}"),
    }
    // A rejected merge must leave device memory untouched, so callers can
    // fall back to the sequential path on the same image.
    assert_eq!(g.load(64).unwrap(), 0, "no partial merge on conflict");
}

#[test]
fn disjoint_writes_across_sms_pass_the_conflict_check() {
    // Per-thread disjoint stores (every paper kernel's shape) must merge
    // cleanly on many geometries, including odd splits.
    let k = assemble(
        r#"
        .entry cover
        .regs 6
            S2R R1, SR_GTID
            SHL R2, R1, #2
            IADD R3, R1, #5
            GST [R2], R3
            EXIT
        "#,
    )
    .unwrap();
    for (grid, block) in [(2u32, 32u32), (5, 64), (9, 100)] {
        let mut g = GlobalMem::new((grid * block * 4 + 4096).next_power_of_two());
        Gpgpu::new(GpgpuConfig::new(2, 8))
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(grid, block), &mut g).parallel())
            .unwrap_or_else(|e| panic!("{grid}x{block}: {e}"));
        for t in 0..grid * block {
            assert_eq!(g.load(t * 4).unwrap(), t as i32 + 5, "thread {t}");
        }
    }
}
