//! Cross-configuration benchmark correctness: every paper benchmark, at
//! every paper input size, on every (SM, SP) configuration the paper
//! evaluates — all verified against the host golden references, plus
//! output equivalence across configurations (the overlay promise: same
//! binary, same answer, any hardware configuration).

use flexgrip::gpgpu::{Gpgpu, GpgpuConfig};
use flexgrip::kernels::{self, BenchId, RunOptions, PAPER_SIZES};
use flexgrip::sim::NativeAlu;

#[test]
fn every_benchmark_every_size_every_config() {
    // 5 benchmarks x 4 sizes x 4 configs (256-size matmul on the two big
    // configs is exercised in the release-mode harness; debug tests cap
    // the largest combination to keep CI time sane).
    for id in BenchId::PAPER {
        for n in PAPER_SIZES {
            for (sms, sp) in [(1u32, 8u32), (1, 32), (2, 8), (2, 16)] {
                if id == BenchId::MatMul && n == 256 {
                    continue; // covered in harness + release benches
                }
                let gpgpu = Gpgpu::new(GpgpuConfig::new(sms, sp));
                let mut alu = NativeAlu;
                let run = kernels::run_verified(id, n, &gpgpu, &mut alu, 0xC0FFEE)
                    .unwrap_or_else(|e| panic!("{} n={n} {sms}x{sp}: {e}", id.name()));
                assert!(run.cycles > 0);
            }
        }
    }
}

#[test]
fn outputs_identical_across_configurations() {
    // The same kernel binary must produce bit-identical results on any
    // configuration (only timing may differ).
    for id in BenchId::PAPER {
        let mut outputs: Vec<Vec<i32>> = Vec::new();
        for (sms, sp) in [(1u32, 8u32), (2, 32)] {
            let w = kernels::prepare(id, 64, 7);
            let mut g = w.make_gmem();
            w.run(&Gpgpu::new(GpgpuConfig::new(sms, sp)), &mut g, RunOptions::default())
                .unwrap();
            outputs.push(g.read_words(0x1000, id.input_elems(64)).unwrap());
        }
        assert_eq!(outputs[0], outputs[1], "{}", id.name());
    }
}

#[test]
fn timing_shape_matmul_scales_cubically() {
    let cycles = |n: u32| {
        let gpgpu = Gpgpu::new(GpgpuConfig::new(1, 8));
        let mut alu = NativeAlu;
        kernels::run_verified(BenchId::MatMul, n, &gpgpu, &mut alu, 1).unwrap().cycles
    };
    let (c32, c64) = (cycles(32), cycles(64));
    let ratio = c64 as f64 / c32 as f64;
    assert!((6.0..10.0).contains(&ratio), "~8x expected, got {ratio:.1}");
}

#[test]
fn divergence_statistics_match_paper_characterization() {
    // Table 6 characterization at a non-trivial size on 2 SMs.
    let gpgpu = Gpgpu::new(GpgpuConfig::new(2, 8));
    let stats = |id| {
        let mut alu = NativeAlu;
        kernels::run_verified(id, 128, &gpgpu, &mut alu, 5).unwrap().stats
    };
    assert_eq!(stats(BenchId::MatMul).max_stack_depth, 0);
    assert_eq!(stats(BenchId::Reduction).max_stack_depth, 0);
    assert_eq!(stats(BenchId::Transpose).max_stack_depth, 0);
    assert_eq!(stats(BenchId::Bitonic).max_stack_depth, 2);
    assert_eq!(stats(BenchId::Autocorr).max_stack_depth, 16);
    assert_eq!(stats(BenchId::Bitonic).multiplier_ops(), 0);
    assert!(stats(BenchId::MatMul).multiplier_ops() > 0);
}

#[test]
fn workload_memory_is_self_contained() {
    // Inputs + outputs fit the declared gmem size for all benchmarks/sizes.
    for id in BenchId::ALL {
        for n in PAPER_SIZES {
            let w = kernels::prepare(id, n, 9);
            let g = w.make_gmem();
            assert!(g.size_bytes() >= 0x1000 + 4 * id.input_elems(n) as u32, "{} {n}", id.name());
        }
    }
}

#[test]
fn expected_values_stable_for_fixed_seed() {
    // Golden pinning: data generation is part of the experiment contract.
    let w = kernels::prepare(BenchId::Reduction, 32, 0xF1E6);
    let total: i64 = w.input.iter().map(|&v| v as i64).sum();
    assert_eq!(w.expected(), vec![total as i32]);
    let w2 = kernels::prepare(BenchId::Reduction, 32, 0xF1E6);
    assert_eq!(w.input, w2.input);
}
