//! Launch admission (ISSUE 3): kernels meet devices through the
//! capability signature. Pre-flight rejection is structured
//! (`SimError::Unsupported`), profiled signatures route the Table-6
//! variants, and admission is *sound*: it never rejects a kernel the
//! baseline device can run.

use flexgrip::asm::assemble;
use flexgrip::coordinator::customize;
use flexgrip::gpgpu::{Gpgpu, GpgpuConfig, LaunchConfig, LaunchRequest};
use flexgrip::isa::{
    encode::instr_size, Capability, CapabilitySignature, Cond, Guard, Instr, Op, Operand,
    StackBound, MAX_STACK_BOUND,
};
use flexgrip::kernels::{BenchId, RunOptions};
use flexgrip::registry::PreparedKernel;
use flexgrip::rng::XorShift64;
use flexgrip::sim::{GlobalMem, SimError, SmConfig};

fn launch_on(src: &str, cfg: GpgpuConfig) -> Result<(), SimError> {
    let k = assemble(src).unwrap();
    let mut g = GlobalMem::new(4096);
    Gpgpu::new(cfg)
        .launch(LaunchRequest::new(&k, LaunchConfig::linear(1, 32), &mut g))
        .map(|_| ())
}

fn multiplierless() -> GpgpuConfig {
    let mut cfg = GpgpuConfig::new(1, 8);
    cfg.sm.has_multiplier = false;
    cfg.sm.read_operands = 2;
    cfg
}

#[test]
fn imul_and_imad_kernels_rejected_at_launch() {
    // Satellite: an IMUL/IMAD kernel on a multiplier-less device is
    // rejected *at launch* (pc: None — nothing was simulated).
    let err = launch_on("IMUL R1, R2, R3\nEXIT", multiplierless()).unwrap_err();
    assert!(
        matches!(
            err,
            SimError::Unsupported { capability: Capability::Multiplier, pc: None, .. }
        ),
        "{err}"
    );
    let err = launch_on("IMAD R1, R2, R3, R4\nEXIT", multiplierless()).unwrap_err();
    assert!(
        matches!(
            err,
            SimError::Unsupported {
                capability: Capability::Multiplier | Capability::ThirdReadOperand,
                pc: None,
                ..
            }
        ),
        "{err}"
    );
    // The same kernels pass on the baseline.
    launch_on("IMUL R1, R2, R3\nEXIT", GpgpuConfig::new(1, 8)).unwrap();
}

#[test]
fn provable_stack_shortfall_rejected_at_launch() {
    // Three nested SSYs have an exact static bound of 3: a depth-2 device
    // refuses them pre-flight with the structured need/have payload.
    let src = "SSY a\nSSY a\nSSY a\na:\nJOIN\nJOIN\nJOIN\nEXIT";
    let mut cfg = GpgpuConfig::new(1, 8);
    cfg.sm.warp_stack_depth = 2;
    let err = launch_on(src, cfg).unwrap_err();
    assert!(
        matches!(
            err,
            SimError::Unsupported {
                capability: Capability::StackDepth { need: 3, have: 2 },
                pc: None,
                ..
            }
        ),
        "{err}"
    );
    let mut cfg = GpgpuConfig::new(1, 8);
    cfg.sm.warp_stack_depth = 3;
    launch_on(src, cfg).unwrap();
}

#[test]
fn autocorr_profile_admits_depth_16_rejects_depth_8() {
    // Satellite: autocorr's measured Table-6 depth is 16. The refined
    // signature is admitted at depth 16 and rejected at depth 8 — by
    // both the public capability check and the admission error path.
    let r = customize::profile(BenchId::Autocorr, 64, 7).unwrap();
    let sig = r.refined_signature();
    assert_eq!(sig.stack_bound, StackBound::AtMost(16));

    let mut cfg16 = GpgpuConfig::new(1, 8);
    cfg16.sm.warp_stack_depth = 16;
    assert!(Gpgpu::new(cfg16).supports(&sig));
    cfg16.sm.admit(&sig).unwrap();

    let mut cfg8 = GpgpuConfig::new(1, 8);
    cfg8.sm.warp_stack_depth = 8;
    assert!(!Gpgpu::new(cfg8).supports(&sig));
    let err = cfg8.sm.admit(&sig).unwrap_err();
    assert!(
        matches!(
            err,
            SimError::Unsupported {
                capability: Capability::StackDepth { need: 16, have: 8 },
                ..
            }
        ),
        "{err}"
    );
}

#[test]
fn refined_signature_admits_where_the_static_one_rejects() {
    // A uniform guarded branch makes the static bound over-approximate
    // (AtMost(2)) while the measured high-water is 1. The routed-launch
    // path (`LaunchRequest::admit` with the refined signature — what the
    // coordinator's shards do) must accept the depth-1 variant that
    // static admission refuses; this is the regression test for routing
    // and admission disagreeing about the same job.
    let src = "S2R R0, SR_TID\nISETP P0, R0, #100\nSSY e\n@P0.LT BRA t\nJOIN\nt:\nJOIN\ne:\nEXIT";
    let pk = PreparedKernel::new(assemble(src).unwrap());
    assert_eq!(pk.sig.stack_bound, StackBound::AtMost(2), "static over-approximates");
    let mut cfg = GpgpuConfig::new(1, 8);
    cfg.sm.warp_stack_depth = 1;
    let gp = Gpgpu::new(cfg);
    let mut g = GlobalMem::new(4096);
    let err = gp
        .launch(LaunchRequest::new(&pk, LaunchConfig::linear(1, 32), &mut g))
        .unwrap_err();
    assert!(
        matches!(
            err,
            SimError::Unsupported {
                capability: Capability::StackDepth { need: 2, have: 1 },
                ..
            }
        ),
        "{err}"
    );
    let refined = pk.sig.refined(1, 0);
    gp.launch(LaunchRequest::new(&pk, LaunchConfig::linear(1, 32), &mut g).admit(refined))
        .unwrap();
}

#[test]
fn statically_unbounded_stack_admits_and_runs_on_profiled_depth() {
    // Loops saturate the static bound, so admission lets the launch
    // through and the measured depth is what actually matters: bitonic
    // (static Unbounded, measured 2) must run on its depth-2 variant.
    let w = flexgrip::kernels::prepare(BenchId::Bitonic, 64, 7);
    assert_eq!(w.kernel.sig.stack_bound, StackBound::Unbounded);
    let mut cfg = GpgpuConfig::new(1, 8);
    cfg.sm.warp_stack_depth = 2;
    cfg.sm.has_multiplier = false;
    cfg.sm.read_operands = 2;
    let gpgpu = Gpgpu::new(cfg);
    let mut gmem = w.make_gmem();
    w.run(&gpgpu, &mut gmem, RunOptions::default()).unwrap();
    w.verify(&gmem).unwrap();
}

/// Random instruction program over every opcode, with branch targets
/// resolved to real instruction addresses so the signature walk sees a
/// plausible CFG.
fn random_program(rng: &mut XorShift64) -> Vec<(u32, Instr)> {
    let len = 1 + rng.below(40) as usize;
    let mut instrs: Vec<Instr> = Vec::with_capacity(len);
    for _ in 0..len {
        let op = Op::ALL[rng.below(Op::ALL.len() as u64) as usize];
        let mut i = Instr { op, ..Instr::NOP };
        if rng.below(3) == 0 {
            i.guard = Guard { preg: rng.below(4) as u8, cond: Cond::Lt };
        }
        // Operand detail does not affect the signature; branches get a
        // placeholder immediate so instr_size is the 8-byte form.
        if matches!(op, Op::Bra | Op::Ssy) {
            i.src2 = Operand::Imm(0);
        }
        i.size = instr_size(op, matches!(i.src2, Operand::Imm(_)));
        instrs.push(i);
    }
    let mut pcs = Vec::with_capacity(len);
    let mut at = 0u32;
    for i in &instrs {
        pcs.push(at);
        at += i.size as u32;
    }
    for i in instrs.iter_mut() {
        if matches!(i.op, Op::Bra | Op::Ssy) {
            let target = pcs[rng.below(len as u64) as usize];
            i.src2 = Operand::Imm(target as i32);
        }
    }
    pcs.into_iter().zip(instrs).collect()
}

#[test]
fn prop_admission_never_rejects_what_the_baseline_runs_500() {
    // Satellite property: whatever the static analysis concludes, the
    // full baseline device (multiplier, 3 operands, 32-deep stack) must
    // admit and cover every program — the bound clamps at 32 instead of
    // ever over-claiming past the architectural maximum.
    let mut rng = XorShift64::new(0xAD317);
    let baseline = SmConfig::baseline();
    for case in 0..500 {
        let prog = random_program(&mut rng);
        let sig = CapabilitySignature::of_program(&prog);
        if let StackBound::AtMost(b) = sig.stack_bound {
            assert!(b <= MAX_STACK_BOUND, "case {case}: bound {b}");
        }
        baseline
            .admit(&sig)
            .unwrap_or_else(|e| panic!("case {case}: baseline rejected: {e}"));
        assert!(baseline.covers(&sig), "case {case}: baseline must cover");
    }
}

#[test]
fn every_paper_benchmark_admitted_on_the_baseline() {
    let baseline = Gpgpu::new(GpgpuConfig::new(1, 8));
    for id in BenchId::ALL {
        let k = assemble(id.source()).unwrap();
        assert!(baseline.supports(&k.signature()), "{}", id.name());
    }
}
