//! SM-level integration: timing-model invariants, nested divergence,
//! address registers, predicate machinery, multi-block residency.

use flexgrip::asm::assemble;
use flexgrip::gpgpu::{Gpgpu, GpgpuConfig, LaunchConfig, LaunchRequest};
use flexgrip::kernels::{self, BenchId, RunOptions};
use flexgrip::sim::{GlobalMem, MemTiming};

fn run(src: &str, cfg: GpgpuConfig, grid: u32, block: u32) -> (GlobalMem, u64) {
    let k = assemble(src).unwrap();
    let mut g = GlobalMem::new(1 << 16);
    let r = Gpgpu::new(cfg)
        .launch(LaunchRequest::new(&k, LaunchConfig::linear(grid, block), &mut g))
        .unwrap();
    (g, r.total.cycles)
}

#[test]
fn nested_divergence_three_deep() {
    // 8-way value assignment from 3 nested conditions on tid bits.
    let src = r#"
        .regs 10
        S2R R0, SR_TID
        MOV R1, #0
        AND R2, R0, #4
        ISETP P0, R2, #0
        SSY e1
        @P0.EQ BRA b1_then
        ; bit2 set path
        AND R2, R0, #2
        ISETP P1, R2, #0
        SSY e2a
        @P1.EQ BRA b2a_then
        IADD R1, R1, #4
        JOIN
    b2a_then:
        IADD R1, R1, #40
        JOIN
    e2a:
        JOIN
    b1_then:
        AND R2, R0, #1
        ISETP P2, R2, #0
        SSY e2b
        @P2.EQ BRA b2b_then
        IADD R1, R1, #1
        JOIN
    b2b_then:
        IADD R1, R1, #100
        JOIN
    e2b:
        JOIN
    e1:
        SHL R3, R0, #2
        GST [R3], R1
        EXIT
    "#;
    let (g, _) = run(src, GpgpuConfig::new(1, 8), 1, 32);
    for t in 0..32i32 {
        let want = if t & 4 != 0 {
            if t & 2 != 0 { 4 } else { 40 }
        } else if t & 1 != 0 {
            1
        } else {
            100
        };
        assert_eq!(g.load(t as u32 * 4).unwrap(), want, "tid {t}");
    }
}

#[test]
fn address_registers_roundtrip_through_r2a_a2r() {
    let src = r#"
        .regs 8
        .smem 256
        S2R R0, SR_TID
        SHL R1, R0, #2
        IADD R1, R1, #64
        R2A A1, R1          ; address register holds &shared[tid]
        IMUL R2, R0, R0
        SST [A1], R2        ; store via A-reg base
        SLD R3, [A1]
        A2R R4, A1
        GST [R1-64], R3     ; out[tid] = tid^2 (R1-64 = tid*4)
        SHL R5, R0, #2
        IADD R5, R5, #512
        GST [R5], R4        ; out2[tid] = the address itself
        EXIT
    "#;
    let (g, _) = run(src, GpgpuConfig::new(1, 8), 1, 32);
    for t in 0..32i32 {
        assert_eq!(g.load(t as u32 * 4).unwrap(), t * t, "sq tid {t}");
        assert_eq!(g.load(512 + t as u32 * 4).unwrap(), t * 4 + 64, "addr tid {t}");
    }
}

#[test]
fn iset_and_sel_machinery() {
    let src = r#"
        .regs 8
        S2R R0, SR_TID
        ISET R1, R0, #16, LT      ; -1 if tid<16 else 0
        ISETP P1, R0, #8
        SEL R2, R0, R1, P1.GE     ; tid>=8 ? tid : R1
        SHL R3, R0, #2
        GST [R3], R2
        EXIT
    "#;
    let (g, _) = run(src, GpgpuConfig::new(1, 8), 1, 32);
    for t in 0..32i32 {
        let r1 = if t < 16 { -1 } else { 0 };
        let want = if t >= 8 { t } else { r1 };
        assert_eq!(g.load(t as u32 * 4).unwrap(), want, "tid {t}");
    }
}

#[test]
fn cycle_model_invariants_across_sp_counts() {
    // More SPs -> monotonically fewer (or equal) cycles; halving is the
    // theoretical best when compute-bound.
    let compute = r#"
        .regs 6
        S2R R0, SR_TID
        MOV R1, #0
        MOV R2, #0
    top:
        IMAD R1, R0, R0, R1
        IADD R2, R2, #1
        ISETP P0, R2, #200
        @P0.LT BRA top
        SHL R3, R0, #2
        GST [R3], R1
        EXIT
    "#;
    let c8 = run(compute, GpgpuConfig::new(1, 8), 4, 256).1;
    let c16 = run(compute, GpgpuConfig::new(1, 16), 4, 256).1;
    let c32 = run(compute, GpgpuConfig::new(1, 32), 4, 256).1;
    assert!(c8 > c16 && c16 > c32, "{c8} > {c16} > {c32}");
    let ratio = c8 as f64 / c16 as f64;
    assert!((1.5..=2.05).contains(&ratio), "compute-bound halving: {ratio}");
}

#[test]
fn memory_timing_scales_with_latency_parameters() {
    let src = "S2R R1, SR_GTID\nSHL R2, R1, #2\nGLD R3, [R2]\nGST [R2], R3\nEXIT";
    let k = assemble(src).unwrap();
    let mut cycles = Vec::new();
    for row_overhead in [50u32, 200, 800] {
        let mut cfg = GpgpuConfig::new(1, 8);
        cfg.sm.mem = MemTiming { global_row_overhead: row_overhead, ..MemTiming::default() };
        let mut g = GlobalMem::new(1 << 14);
        let r = Gpgpu::new(cfg)
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(2, 64), &mut g))
            .unwrap();
        cycles.push(r.total.cycles);
    }
    assert!(cycles[0] < cycles[1] && cycles[1] < cycles[2], "{cycles:?}");
}

#[test]
fn residency_affects_latency_hiding() {
    // A shared-memory-light, global-heavy kernel: more resident blocks
    // cannot make the (blocking) memory path slower.
    let (_, few) = run(
        ".regs 30\nS2R R1, SR_GTID\nSHL R2, R1, #2\nGLD R3, [R2]\nGST [R2], R3\nEXIT",
        GpgpuConfig::new(1, 8),
        8,
        64,
    );
    let (_, many) = run(
        ".regs 4\nS2R R1, SR_GTID\nSHL R2, R1, #2\nGLD R3, [R2]\nGST [R2], R3\nEXIT",
        GpgpuConfig::new(1, 8),
        8,
        64,
    );
    assert!(many <= few, "more residency must not slow down: {many} vs {few}");
}

#[test]
fn per_sm_stats_sum_to_totals() {
    let gpgpu = Gpgpu::new(GpgpuConfig::new(2, 16));
    let w = kernels::prepare(BenchId::Transpose, 64, 3);
    let mut g = w.make_gmem();
    let run = w.run(&gpgpu, &mut g, RunOptions::default()).unwrap();
    let lr = &run.phases[0];
    let sum: u64 = lr.per_sm.iter().map(|s| s.instructions).sum();
    assert_eq!(sum, lr.total.instructions);
    let max = lr.per_sm.iter().map(|s| s.cycles).max().unwrap();
    assert_eq!(max, lr.total.cycles, "kernel time = slowest SM");
}

#[test]
fn gtid_covers_2d_grids() {
    let src = r#"
        .regs 6
        S2R R1, SR_GTID
        SHL R2, R1, #2
        GST [R2], R1
        EXIT
    "#;
    let k = assemble(src).unwrap();
    let mut g = GlobalMem::new(1 << 14);
    Gpgpu::new(GpgpuConfig::new(1, 8))
        .launch(LaunchRequest::new(
            &k,
            LaunchConfig { grid_x: 3, grid_y: 2, block_threads: 32 },
            &mut g,
        ))
        .unwrap();
    for t in 0..(3 * 2 * 32) {
        assert_eq!(g.load(t * 4).unwrap(), t as i32);
    }
}
