//! SEU fault-injection differential suite (ISSUE 7). Two contracts:
//!
//! 1. **Zero-cost when disabled**: a rate-0 (or target-less) `FaultPlan`
//!    must be bit- and cycle-identical to running with no plan at all —
//!    across every benchmark, flat and cached memory, 1..8 SMs, and both
//!    launch paths.
//! 2. **Deterministic when enabled**: the same seed draws the same fault
//!    sites on every run and on both the sequential and parallel launch
//!    paths (the per-SM cycle streams the injector keys on are
//!    path-independent). Detected campaigns fail with the identical
//!    `SimError::SoftError`; silent campaigns produce byte-identical
//!    outcomes.

use flexgrip::gpgpu::{Gpgpu, GpgpuConfig};
use flexgrip::kernels::{self, BenchId, RunOptions, Workload};
use flexgrip::sim::{CacheGeometry, FaultPlan, FaultTargets, GlobalMem, MemoryConfig, SimError};

fn image(g: &GlobalMem) -> Vec<i32> {
    g.read_words(0, g.size_bytes() as usize / 4).unwrap()
}

/// Run without golden verification (silent campaigns corrupt on purpose);
/// returns the full memory image + cycle count, or the structured error.
fn run_fault(
    w: &Workload,
    cfg: GpgpuConfig,
    parallel: bool,
    plan: Option<&FaultPlan>,
) -> Result<(Vec<i32>, u64), SimError> {
    let gpgpu = Gpgpu::new(cfg);
    let mut g = w.make_gmem();
    let mut opts = if parallel { RunOptions::new().parallel() } else { RunOptions::default() };
    if let Some(p) = plan {
        opts = opts.fault(p);
    }
    let run = w.run(&gpgpu, &mut g, opts)?;
    Ok((image(&g), run.cycles))
}

#[test]
fn disabled_plans_are_bit_and_cycle_identical_to_no_plan() {
    let zero_rate = FaultPlan::new(0xDEAD, 0.0);
    let no_targets = FaultPlan::new(0xDEAD, 100.0).with_targets(FaultTargets::none());
    let geom = CacheGeometry::parse("4x64x32").unwrap();
    for id in BenchId::ALL {
        let w = kernels::prepare(id, 32, 0x5EED);
        for sms in [1u32, 2, 4, 8] {
            for cached in [false, true] {
                let mut cfg = GpgpuConfig::new(sms, 8);
                if cached {
                    cfg = cfg.with_memory(MemoryConfig::with_l1(geom));
                }
                for parallel in [false, true] {
                    let label =
                        format!("{} {sms}sm cached={cached} par={parallel}", id.name());
                    let base = run_fault(&w, cfg, parallel, None).expect("clean run");
                    let z = run_fault(&w, cfg, parallel, Some(&zero_rate)).expect("rate-0");
                    assert_eq!(base, z, "{label}: rate-0 plan must be invisible");
                    let t = run_fault(&w, cfg, parallel, Some(&no_targets))
                        .expect("target-less");
                    assert_eq!(base, t, "{label}: target-less plan must be invisible");
                }
            }
        }
    }
}

#[test]
fn detected_campaigns_fail_identically_across_runs_and_paths() {
    // Instruction-image upsets at mean interval 5 cycles: parity-detected
    // within the first issues, so every run fails — and with the same
    // seed, every run (and both launch paths) must report the *same*
    // structured SoftError.
    let plan = FaultPlan::new(0xC0FFEE, 200_000.0)
        .with_targets(FaultTargets { instr_image: true, ..FaultTargets::none() });
    let w = kernels::prepare(BenchId::MatMul, 32, 0x5EED);
    let cfg = GpgpuConfig::new(2, 8);
    let seq0 = run_fault(&w, cfg, false, Some(&plan));
    let seq1 = run_fault(&w, cfg, false, Some(&plan));
    let par = run_fault(&w, cfg, true, Some(&plan));
    match seq0.as_ref().expect_err("mean-5-cycle instruction upsets must be detected") {
        SimError::SoftError { .. } => {}
        other => panic!("expected SoftError, got {other:?}"),
    }
    assert_eq!(seq0.as_ref().err(), seq1.as_ref().err(), "repeat runs must agree");
    assert_eq!(seq0.as_ref().err(), par.as_ref().err(), "seq/par paths must agree");
}

#[test]
fn silent_campaigns_are_deterministic_and_path_independent() {
    // Register-file / shared-memory flips corrupt without detection (by
    // design); determinism still holds: same seed => byte-identical
    // outcome, whether that outcome is a corrupted image or a downstream
    // architectural fault.
    let plan = FaultPlan::new(0x51EE7, 50_000.0).with_targets(FaultTargets::silent());
    let w = kernels::prepare(BenchId::VecAdd, 32, 0x5EED);
    let cfg = GpgpuConfig::new(2, 8);
    let a = run_fault(&w, cfg, false, Some(&plan));
    let b = run_fault(&w, cfg, false, Some(&plan));
    assert_eq!(a, b, "same seed must be byte-identical across runs");
    let p = run_fault(&w, cfg, true, Some(&plan));
    assert_eq!(a, p, "silent campaign must agree across launch paths");
}

#[test]
fn different_seeds_draw_different_fault_sites() {
    let targets = FaultTargets { instr_image: true, ..FaultTargets::none() };
    let w = kernels::prepare(BenchId::MatMul, 32, 0x5EED);
    let cfg = GpgpuConfig::new(1, 8);
    let e = |seed: u64| {
        let plan = FaultPlan::new(seed, 200_000.0).with_targets(targets);
        run_fault(&w, cfg, false, Some(&plan)).expect_err("campaign must detect")
    };
    // Two seeds landing the first upset on the exact same (cycle, pc, bit)
    // would mean the schedule ignores the seed.
    assert_ne!(e(1), e(2), "seed must steer the fault schedule");
}
