//! Property tests: encode/decode are exact inverses over the canonical
//! instruction space, the full toolchain loop
//! `encode -> decode -> disasm -> parse` closes (pinning `isa/encode.rs`,
//! `isa/decode.rs`, `isa/disasm.rs` and `asm/parser.rs` against each
//! other), and the decoder never panics on arbitrary bytes (proptest is
//! unavailable offline; generators are seeded xorshift — deterministic
//! and reproducible).

use flexgrip::isa::{
    decode, encode::encode, Cond, Guard, Instr, Op, OpClass, Operand, SpecialReg, NUM_AREGS,
};
use flexgrip::rng::XorShift64;

/// Generate a random *canonical* instruction (the forms the assembler can
/// produce — unused fields normalized exactly as the decoder emits them).
fn random_instr(rng: &mut XorShift64) -> Instr {
    let op = Op::ALL[rng.below(Op::ALL.len() as u64) as usize];
    let mut i = Instr { op, ..Instr::NOP };

    // Guard on everything but: keep canonical (guard allowed everywhere).
    if !matches!(op.class(), OpClass::Control) && rng.bool() {
        i.guard = Guard {
            preg: rng.below(4) as u8,
            cond: Cond::ALL[1 + rng.below(6) as usize], // EQ..GE
        };
    }
    let reg = |rng: &mut XorShift64| rng.below(64) as u8;
    let dreg = |rng: &mut XorShift64| rng.below(63) as u8; // not RZ for dst field roundtrip
    match op.class() {
        OpClass::Control => {
            i.guard = Guard::NONE;
        }
        OpClass::Unary => match op {
            Op::S2r => {
                i.dst = dreg(rng);
                i.src1 = Operand::Special(
                    SpecialReg::ALL[rng.below(SpecialReg::ALL.len() as u64) as usize],
                );
            }
            Op::R2a => {
                i.dst = rng.below(NUM_AREGS as u64) as u8;
                i.src1 = Operand::Reg(reg(rng));
            }
            Op::A2r => {
                i.dst = dreg(rng);
                i.src1 = Operand::AReg(rng.below(NUM_AREGS as u64) as u8);
            }
            Op::Mov if rng.bool() => {
                i.dst = dreg(rng);
                i.src2 = Operand::Imm(rng.next_u64() as i32);
            }
            _ => {
                i.dst = dreg(rng);
                i.src1 = Operand::Reg(reg(rng));
            }
        },
        OpClass::Binary => {
            i.dst = dreg(rng);
            i.src1 = Operand::Reg(reg(rng));
            i.src2 = if rng.bool() {
                Operand::Imm(rng.next_u64() as i32)
            } else {
                Operand::Reg(reg(rng))
            };
            if op == Op::Isetp {
                i.dst = 0;
                i.setp_en = true;
                i.setp_idx = rng.below(4) as u8;
            }
            if matches!(op, Op::Iset | Op::Sel) {
                i.cond = Cond::ALL[rng.below(8) as usize];
                if op == Op::Sel {
                    i.setp_idx = rng.below(4) as u8;
                }
            }
        }
        OpClass::Ternary => {
            i.dst = dreg(rng);
            i.src1 = Operand::Reg(reg(rng));
            i.src2 = Operand::Reg(reg(rng));
            i.src3 = Operand::Reg(reg(rng));
        }
        OpClass::Branch => {
            i.src2 = Operand::Imm((rng.below(1 << 20) as i32) & !3);
        }
        OpClass::Mem => {
            i.src1 = if rng.bool() {
                Operand::Reg(reg(rng))
            } else {
                Operand::AReg(rng.below(NUM_AREGS as u64) as u8)
            };
            i.offset = rng.next_u64() as i16;
            if i.is_store() {
                i.src2 = Operand::Reg(reg(rng));
            } else {
                i.dst = dreg(rng);
            }
        }
    }
    let s2imm = matches!(i.src2, Operand::Imm(_));
    i.size = flexgrip::isa::encode::instr_size(op, s2imm);
    i
}

#[test]
fn prop_encode_decode_roundtrip_10k() {
    let mut rng = XorShift64::new(0x150_150);
    for case in 0..10_000 {
        let i = random_instr(&mut rng);
        let bytes = encode(&i);
        assert_eq!(bytes.len() as u8, i.size, "case {case}: size, instr {i:?}");
        let back = decode(&bytes, 0).unwrap_or_else(|e| panic!("case {case}: {e} for {i:?}"));
        assert_eq!(back, i, "case {case}");
    }
}

#[test]
fn prop_encode_decode_disasm_parse_roundtrip_5k() {
    // The four-stage closure over all opcodes and operand kinds: the
    // binary decodes, its disassembly re-parses, and the re-parsed
    // instruction is bit-identical to the original.
    let mut rng = XorShift64::new(0xD15A_57E9);
    for case in 0..5_000 {
        let i = random_instr(&mut rng);
        let decoded = decode(&encode(&i), 0).unwrap();
        assert_eq!(decoded, i, "case {case}");
        let text = flexgrip::isa::disassemble(&decoded);
        let k = flexgrip::asm::assemble(&text)
            .unwrap_or_else(|e| panic!("case {case}: `{text}`: {e}"));
        assert_eq!(k.instrs.len(), 1, "case {case}: `{text}`");
        assert_eq!(k.instrs[0].1, i, "case {case}: `{text}`");
    }
}

#[test]
fn full_pipeline_covers_every_opcode() {
    // Statistical coverage is not enough for a pin: walk Op::ALL with a
    // canonical operand shape each and close the loop once per opcode.
    let mut rng = XorShift64::new(0x0C0DE);
    let mut seen = std::collections::HashSet::new();
    while seen.len() < Op::ALL.len() {
        let i = random_instr(&mut rng);
        if !seen.insert(i.op) {
            continue;
        }
        let text = flexgrip::isa::disassemble(&decode(&encode(&i), 0).unwrap());
        let k = flexgrip::asm::assemble(&text)
            .unwrap_or_else(|e| panic!("{:?}: `{text}`: {e}", i.op));
        assert_eq!(k.instrs[0].1, i, "{:?}: `{text}`", i.op);
    }
}

#[test]
fn prop_decoder_total_on_random_bytes_10k() {
    // The decoder must never panic: every byte pattern either decodes or
    // returns a structured error (fetch faults surface to the driver).
    let mut rng = XorShift64::new(0xF22);
    for _ in 0..10_000 {
        let bytes: Vec<u8> = (0..8).map(|_| rng.next_u64() as u8).collect();
        let _ = decode(&bytes, 0);
        let _ = decode(&bytes[..4], 0);
    }
}

#[test]
fn prop_stream_layout_consistent_1k() {
    // Random programs: stream decode walks exactly the encoded layout.
    let mut rng = XorShift64::new(0x57_12);
    for _ in 0..1_000 {
        let n = 1 + rng.below(32) as usize;
        let prog: Vec<Instr> = (0..n).map(|_| random_instr(&mut rng)).collect();
        let code = flexgrip::isa::encode::encode_program(&prog);
        let decoded = flexgrip::isa::decode_stream(&code).unwrap();
        assert_eq!(decoded.len(), n);
        let mut pc = 0u32;
        for ((got_pc, got), want) in decoded.iter().zip(&prog) {
            assert_eq!(*got_pc, pc);
            assert_eq!(got, want);
            pc += want.size as u32;
        }
    }
}
