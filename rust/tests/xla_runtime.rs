//! Three-layer integration: the AOT-compiled JAX/Pallas artifacts
//! executed from Rust through PJRT.
//!
//! These tests are **hermetic**: when the AOT artifacts are absent or the
//! PJRT executor is not compiled into this build (the offline image does
//! not vendor the `xla` crate), every executor-dependent test prints why
//! and skips instead of failing, so `cargo test` passes from a clean
//! checkout. Run `make artifacts` and build with the PJRT bindings to
//! exercise the full differential suite.

use flexgrip::gpgpu::{Gpgpu, GpgpuConfig};
use flexgrip::isa::Cond;
use flexgrip::kernels::{self, BenchId};
use flexgrip::rng::XorShift64;
use flexgrip::runtime::{golden, Artifacts, RuntimeError, XlaAlu, XlaBatchAlu, XLA_BATCH};
use flexgrip::sim::{AluBackend, AluFunc, NativeAlu, WarpAluIn, WARP_SIZE};
use std::sync::Arc;

/// Open the artifact store and prove the executor works; `None` (with a
/// logged reason) when artifacts are missing or PJRT is stubbed out.
fn runtime() -> Option<Arc<Artifacts>> {
    let arts = match Artifacts::open_default() {
        Ok(a) => Arc::new(a),
        Err(e) => {
            eprintln!("skipping XLA runtime test: {e}");
            return None;
        }
    };
    match XlaAlu::new(arts.clone()) {
        Ok(_) => Some(arts),
        Err(e) => {
            eprintln!("skipping XLA runtime test: {e}");
            None
        }
    }
}

const ALL_FUNCS: [AluFunc; 19] = [
    AluFunc::Add, AluFunc::Sub, AluFunc::Mul, AluFunc::Mad, AluFunc::Min,
    AluFunc::Max, AluFunc::And, AluFunc::Or, AluFunc::Xor, AluFunc::Not,
    AluFunc::Shl, AluFunc::Shr, AluFunc::Sar, AluFunc::Abs, AluFunc::Neg,
    AluFunc::Mov, AluFunc::Setp, AluFunc::Set, AluFunc::Sel,
];

const ALL_CONDS: [Cond; 8] = [
    Cond::Always, Cond::Eq, Cond::Ne, Cond::Lt, Cond::Le, Cond::Gt, Cond::Ge,
    Cond::Never,
];

fn random_bundle(rng: &mut XorShift64, func: AluFunc, cond: Cond) -> WarpAluIn {
    let mut mk = |edge: bool| {
        let mut v = [0i32; WARP_SIZE];
        for (i, slot) in v.iter_mut().enumerate() {
            *slot = if edge && i % 7 == 0 {
                [i32::MIN, i32::MAX, 0, -1, 33][i % 5]
            } else {
                rng.next_u64() as i32
            };
        }
        v
    };
    WarpAluIn { func, cond, a: mk(true), b: mk(true), c: mk(false) }
}

#[test]
fn platform_reported() {
    let Some(arts) = runtime() else { return };
    assert!(!arts.platform().is_empty());
}

#[test]
fn xla_alu_differential_vs_native_all_funcs() {
    let Some(arts) = runtime() else { return };
    let mut xla = XlaAlu::new(arts).unwrap();
    let mut native = NativeAlu;
    let mut rng = XorShift64::new(0xA10);
    for func in ALL_FUNCS {
        for cond in ALL_CONDS {
            let input = random_bundle(&mut rng, func, cond);
            let got = xla.execute(&input);
            let want = native.execute(&input);
            assert_eq!(got, want, "func {func:?} cond {cond:?}");
        }
    }
    assert_eq!(xla.calls(), (ALL_FUNCS.len() * ALL_CONDS.len()) as u64);
}

#[test]
fn xla_batch_matches_native() {
    let Some(arts) = runtime() else { return };
    let batch = XlaBatchAlu::new(arts).unwrap();
    let mut native = NativeAlu;
    let mut rng = XorShift64::new(0xBA7C);
    let inputs: Vec<WarpAluIn> = (0..XLA_BATCH)
        .map(|i| {
            random_bundle(
                &mut rng,
                ALL_FUNCS[i % ALL_FUNCS.len()],
                ALL_CONDS[i % ALL_CONDS.len()],
            )
        })
        .collect();
    let got = batch.execute_batch(&inputs).unwrap();
    for (i, input) in inputs.iter().enumerate() {
        assert_eq!(got[i], native.execute(input), "slot {i}");
    }
}

#[test]
fn full_benchmark_on_xla_backend() {
    // The paper's headline property — one binary, any kernel — holds with
    // the execute stage running on the AOT Pallas artifact end to end.
    let Some(arts) = runtime() else { return };
    let mut xla = XlaAlu::new(arts).unwrap();
    let gpgpu = Gpgpu::new(GpgpuConfig::new(1, 32));
    let run = kernels::run_verified(BenchId::VecAdd, 32, &gpgpu, &mut xla, 0xE2E).unwrap();
    assert!(run.cycles > 0);
    assert!(xla.calls() > 0, "ALU work must have crossed into XLA");
}

#[test]
fn golden_models_agree_with_host_references() {
    let Some(arts) = runtime() else { return };
    for id in BenchId::ALL {
        for n in [32u32, 64] {
            let w = kernels::prepare(id, n, 0x601D);
            let compared = golden::crosscheck(&arts, id, n, &w.input, &w.expected())
                .unwrap_or_else(|e| panic!("{e}"));
            assert!(compared > 0, "{} n={n}", id.name());
        }
    }
}

#[test]
fn golden_models_catch_corruption() {
    // The crosscheck must detect wrong output, not just confirm agreement.
    let Some(arts) = runtime() else { return };
    let w = kernels::prepare(BenchId::Reduction, 32, 1);
    let mut wrong = w.expected();
    wrong[0] ^= 1;
    assert!(golden::crosscheck(&arts, BenchId::Reduction, 32, &w.input, &wrong).is_err());
}

#[test]
fn golden_crosscheck_reports_unavailable_runtime_as_error() {
    // Even without PJRT, the cross-check API must fail loudly (with the
    // reason) rather than claim agreement.
    let arts = Artifacts::open("/nonexistent-dir").unwrap();
    let w = kernels::prepare(BenchId::Reduction, 32, 1);
    let err = golden::crosscheck(&arts, BenchId::Reduction, 32, &w.input, &w.expected())
        .unwrap_err();
    assert!(err.contains("make artifacts") || err.contains("unavailable"), "{err}");
}

#[test]
fn missing_artifact_reports_path() {
    let arts = Artifacts::open("/nonexistent-dir").unwrap();
    let err = match arts.artifact_path("warp_alu") {
        Ok(_) => panic!("must fail without artifacts"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("make artifacts"));
}

#[test]
fn unavailable_runtime_is_reported_not_panicked() {
    // With an artifact present but no PJRT executor, construction must
    // return a structured error telling the operator how to enable it.
    let dir = std::env::temp_dir().join("flexgrip-xla-runtime-test");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("warp_alu.hlo.txt"), "HloModule warp_alu").unwrap();
    let arts = Arc::new(Artifacts::open(&dir).unwrap());
    if arts.available() {
        return; // real PJRT build: covered by the differential tests above
    }
    match XlaAlu::new(arts) {
        Ok(_) => panic!("stub build must not construct an XlaAlu"),
        Err(RuntimeError::Unavailable { reason }) => {
            assert!(reason.contains("xla"), "{reason}");
        }
        Err(other) => panic!("want Unavailable, got {other}"),
    }
}
