//! Three-layer integration: the AOT-compiled JAX/Pallas artifacts
//! executed from Rust through PJRT.
//!
//! Requires `make artifacts` (the Makefile guarantees artifacts exist
//! before `cargo test`).

use flexgrip::gpgpu::{Gpgpu, GpgpuConfig};
use flexgrip::isa::Cond;
use flexgrip::kernels::{self, BenchId};
use flexgrip::rng::XorShift64;
use flexgrip::runtime::{golden, Artifacts, XlaAlu, XlaBatchAlu, XLA_BATCH};
use flexgrip::sim::{AluBackend, AluFunc, NativeAlu, WarpAluIn, WARP_SIZE};
use std::sync::Arc;

fn artifacts() -> Arc<Artifacts> {
    Arc::new(Artifacts::open_default().expect("run `make artifacts` first"))
}

const ALL_FUNCS: [AluFunc; 19] = [
    AluFunc::Add, AluFunc::Sub, AluFunc::Mul, AluFunc::Mad, AluFunc::Min,
    AluFunc::Max, AluFunc::And, AluFunc::Or, AluFunc::Xor, AluFunc::Not,
    AluFunc::Shl, AluFunc::Shr, AluFunc::Sar, AluFunc::Abs, AluFunc::Neg,
    AluFunc::Mov, AluFunc::Setp, AluFunc::Set, AluFunc::Sel,
];

const ALL_CONDS: [Cond; 8] = [
    Cond::Always, Cond::Eq, Cond::Ne, Cond::Lt, Cond::Le, Cond::Gt, Cond::Ge,
    Cond::Never,
];

fn random_bundle(rng: &mut XorShift64, func: AluFunc, cond: Cond) -> WarpAluIn {
    let mut mk = |edge: bool| {
        let mut v = [0i32; WARP_SIZE];
        for (i, slot) in v.iter_mut().enumerate() {
            *slot = if edge && i % 7 == 0 {
                [i32::MIN, i32::MAX, 0, -1, 33][i % 5]
            } else {
                rng.next_u64() as i32
            };
        }
        v
    };
    WarpAluIn { func, cond, a: mk(true), b: mk(true), c: mk(false) }
}

#[test]
fn platform_is_cpu_pjrt() {
    let arts = artifacts();
    assert!(!arts.platform().is_empty());
}

#[test]
fn xla_alu_differential_vs_native_all_funcs() {
    let arts = artifacts();
    let mut xla = XlaAlu::new(arts).unwrap();
    let mut native = NativeAlu;
    let mut rng = XorShift64::new(0xA10);
    for func in ALL_FUNCS {
        for cond in ALL_CONDS {
            let input = random_bundle(&mut rng, func, cond);
            let got = xla.execute(&input);
            let want = native.execute(&input);
            assert_eq!(got, want, "func {func:?} cond {cond:?}");
        }
    }
    assert_eq!(xla.calls(), (ALL_FUNCS.len() * ALL_CONDS.len()) as u64);
}

#[test]
fn xla_batch_matches_native() {
    let arts = artifacts();
    let batch = XlaBatchAlu::new(arts).unwrap();
    let mut native = NativeAlu;
    let mut rng = XorShift64::new(0xBA7C);
    let inputs: Vec<WarpAluIn> = (0..XLA_BATCH)
        .map(|i| {
            random_bundle(
                &mut rng,
                ALL_FUNCS[i % ALL_FUNCS.len()],
                ALL_CONDS[i % ALL_CONDS.len()],
            )
        })
        .collect();
    let got = batch.execute_batch(&inputs).unwrap();
    for (i, input) in inputs.iter().enumerate() {
        assert_eq!(got[i], native.execute(input), "slot {i}");
    }
}

#[test]
fn full_benchmark_on_xla_backend() {
    // The paper's headline property — one binary, any kernel — holds with
    // the execute stage running on the AOT Pallas artifact end to end.
    let arts = artifacts();
    let mut xla = XlaAlu::new(arts).unwrap();
    let gpgpu = Gpgpu::new(GpgpuConfig::new(1, 32));
    let run = kernels::run_verified(BenchId::VecAdd, 32, &gpgpu, &mut xla, 0xE2E).unwrap();
    assert!(run.cycles > 0);
    assert!(xla.calls() > 0, "ALU work must have crossed into XLA");
}

#[test]
fn divergent_kernel_on_xla_backend() {
    let arts = artifacts();
    let mut xla = XlaAlu::new(arts).unwrap();
    let gpgpu = Gpgpu::new(GpgpuConfig::new(1, 32));
    let run = kernels::run_verified(BenchId::Bitonic, 32, &gpgpu, &mut xla, 0xE2E).unwrap();
    assert!(run.stats.divergences > 0);
}

#[test]
fn golden_models_agree_with_host_references() {
    let arts = artifacts();
    for id in BenchId::ALL {
        for n in [32u32, 64] {
            let w = kernels::prepare(id, n, 0x601D);
            let compared = golden::crosscheck(&arts, id, n, &w.input, &w.expected())
                .unwrap_or_else(|e| panic!("{e}"));
            assert!(compared > 0, "{} n={n}", id.name());
        }
    }
}

#[test]
fn golden_models_catch_corruption() {
    let arts = artifacts();
    let w = kernels::prepare(BenchId::Reduction, 32, 1);
    let mut wrong = w.expected();
    wrong[0] ^= 1;
    assert!(golden::crosscheck(&arts, BenchId::Reduction, 32, &w.input, &wrong).is_err());
}

#[test]
fn missing_artifact_reports_path() {
    let arts = Artifacts::open("/nonexistent-dir").unwrap();
    let err = match arts.executable("warp_alu") {
        Ok(_) => panic!("must fail without artifacts"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("make artifacts"));
}

#[test]
fn artifact_cache_reuses_executables() {
    let arts = artifacts();
    let a = arts.executable("warp_alu").unwrap();
    let b = arts.executable("warp_alu").unwrap();
    assert!(Arc::ptr_eq(&a, &b));
}
