//! Randomized block-scheduler properties (proptest-style, seeded):
//! coverage (every thread of every block executes exactly once),
//! round-robin balance across SMs, residency-limit respect, and
//! determinism.

use flexgrip::asm::assemble;
use flexgrip::gpgpu::{Gpgpu, GpgpuConfig, KernelResources, LaunchConfig, LaunchRequest};
use flexgrip::rng::XorShift64;
use flexgrip::sim::{GlobalMem, NativeAlu};

/// out[gtid] = gtid * 3 + 1 — written exactly once per thread.
const COVER: &str = r#"
    .entry cover
    .regs 6
        S2R R1, SR_GTID
        SHL R2, R1, #2
        IMUL R3, R1, R1
        IADD R3, R1, R1
        IADD R3, R3, R1
        IADD R3, R3, #1
        GLD R4, [R2]
        IADD R3, R3, R4   ; accumulate: double-execution would corrupt
        GST [R2], R3
        EXIT
"#;

#[test]
fn prop_every_thread_executes_exactly_once_100_geometries() {
    let mut rng = XorShift64::new(0x5CED);
    for case in 0..100 {
        let sms = 1 + rng.below(2) as u32;
        let sp = [8u32, 16, 32][rng.below(3) as usize];
        let grid = 1 + rng.below(20) as u32;
        let block = [17u32, 32, 50, 64, 100, 256][rng.below(6) as usize];
        let total = grid * block;
        let k = assemble(COVER).unwrap();
        let mut g = GlobalMem::new((total * 4 + 4096).next_power_of_two());
        let r = Gpgpu::new(GpgpuConfig::new(sms, sp))
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(grid, block), &mut g))
            .unwrap_or_else(|e| panic!("case {case} ({sms}x{sp} {grid}x{block}): {e}"));
        for t in 0..total {
            assert_eq!(
                g.load(t * 4).unwrap(),
                (t * 3 + 1) as i32,
                "case {case} thread {t} ({sms} SM x {sp} SP, grid {grid}, block {block})"
            );
        }
        assert_eq!(r.total.blocks as u32, grid, "case {case}: all blocks retired");
    }
}

#[test]
fn prop_round_robin_balance_across_sms() {
    let mut rng = XorShift64::new(0xBA1);
    for _ in 0..50 {
        let grid = 1 + rng.below(33) as u32;
        let k = assemble(COVER).unwrap();
        let mut g = GlobalMem::new((grid * 64 * 4 + 4096).next_power_of_two());
        let r = Gpgpu::new(GpgpuConfig::new(2, 8))
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(grid, 64), &mut g))
            .unwrap();
        let (a, b) = (r.per_sm[0].blocks, r.per_sm[1].blocks);
        assert!(a.abs_diff(b) <= 1, "grid {grid}: split {a}/{b}");
        assert_eq!(a + b, grid as u64);
    }
}

#[test]
fn prop_determinism_same_seed_same_cycles() {
    for id in flexgrip::kernels::BenchId::PAPER {
        let run = |seed| {
            let gpgpu = Gpgpu::new(GpgpuConfig::new(2, 16));
            let mut alu = NativeAlu;
            flexgrip::kernels::run_verified(id, 64, &gpgpu, &mut alu, seed)
                .unwrap()
                .cycles
        };
        assert_eq!(run(42), run(42), "{}", id.name());
    }
}

#[test]
fn prop_residency_limits_hold_for_random_kernels() {
    let mut rng = XorShift64::new(0x11F);
    for _ in 0..200 {
        let res = KernelResources {
            regs_per_thread: 1 + rng.below(32) as u32,
            smem_bytes: (rng.below(64) * 256) as u32,
            block_threads: 1 + rng.below(256) as u32,
        };
        if res.validate().is_err() {
            continue;
        }
        let m = res.max_resident_blocks();
        assert!(m >= 1, "validated kernels must schedule: {res:?}");
        assert!(m <= 8, "Table 1 cap: {res:?}");
        assert!(m * res.block_threads <= 768, "threads/SM: {res:?}");
        assert!(m * res.regs_per_thread * res.block_threads <= 8192, "regs/SM: {res:?}");
        assert!(m * res.smem_alloc_bytes() <= 16384, "smem/SM: {res:?}");
    }
}

#[test]
fn multi_block_barrier_kernels_interleave_safely() {
    // Shared-memory reverse with barriers, many blocks resident at once.
    let src = r#"
        .regs 8
        .smem 256
            S2R R0, SR_TID
            S2R R1, SR_NTID
            SHL R2, R0, #2
            SST [R2+64], R0
            BAR
            ISUB R3, R1, R0
            ISUB R3, R3, #1
            SHL R3, R3, #2
            SLD R4, [R3+64]
            S2R R5, SR_GTID
            SHL R5, R5, #2
            GST [R5], R4
            EXIT
    "#;
    let k = assemble(src).unwrap();
    let mut g = GlobalMem::new(1 << 14);
    Gpgpu::new(GpgpuConfig::new(2, 8))
        .launch(LaunchRequest::new(&k, LaunchConfig::linear(6, 64), &mut g))
        .unwrap();
    for b in 0..6u32 {
        for t in 0..64u32 {
            assert_eq!(
                g.load((b * 64 + t) * 4).unwrap(),
                (63 - t) as i32,
                "block {b} thread {t}"
            );
        }
    }
}
