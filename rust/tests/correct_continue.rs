//! Correct-and-continue differential suite (ISSUE 10). Contracts:
//!
//! 1. **Inert by default**: ECC/scrub protection, a stuck-at fraction and
//!    a checkpoint policy riding on a *disabled* campaign must be bit-
//!    and cycle-identical to the untouched engine — across every
//!    benchmark, flat and cached memory, 1/4 SMs, and both launch paths.
//!    (Enabled default-parity plans are pinned separately by
//!    `tests/fault_injection.rs`, whose goldens this PR must not move.)
//! 2. **ECC corrects what parity only detects**: the campaign that kills
//!    a parity run completes under ECC, bit-identical to the clean
//!    image, with the correction latency visible in the cycle count.
//! 3. **Stuck-at aging**: aged sites re-corrupt until the background
//!    scrubber retires them; scrubbed runs still serve the clean image.
//! 4. **Checkpoint/restart**: a detected upset under a checkpoint policy
//!    resumes from the snapshot and completes bit-identically, with
//!    restarts and replayed cycles accounted.

use flexgrip::gpgpu::{Gpgpu, GpgpuConfig};
use flexgrip::kernels::{self, BenchId, RunOptions, Workload};
use flexgrip::sim::{
    CacheGeometry, CheckpointPolicy, FaultPlan, FaultState, FaultTargets, GlobalMem,
    MemoryConfig, ProtectionConfig, SimError,
};

fn image(g: &GlobalMem) -> Vec<i32> {
    g.read_words(0, g.size_bytes() as usize / 4).unwrap()
}

/// Run without golden verification; returns the final memory plus the
/// full run record (cycles + stats), or the structured error.
fn run_with(
    w: &Workload,
    cfg: GpgpuConfig,
    parallel: bool,
    plan: Option<&FaultPlan>,
    checkpoint: Option<CheckpointPolicy>,
) -> Result<(GlobalMem, flexgrip::kernels::BenchRun), SimError> {
    let gpgpu = Gpgpu::new(cfg);
    let mut g = w.make_gmem();
    let mut opts = if parallel { RunOptions::new().parallel() } else { RunOptions::default() };
    if let Some(p) = plan {
        opts = opts.fault(p);
    }
    if let Some(policy) = checkpoint {
        opts = opts.checkpoint(policy);
    }
    let run = w.run(&gpgpu, &mut g, opts)?;
    Ok((g, run))
}

#[test]
fn protection_and_checkpoint_are_inert_on_clean_runs() {
    // The heaviest decoration we offer — ECC+scrub, a stuck-at fraction,
    // and an armed checkpoint policy — on a rate-0 campaign must leave
    // no trace: same bits, same cycles, zeroed resilience counters.
    let decorated = FaultPlan::new(0xDEAD, 0.0)
        .with_protection(ProtectionConfig::ecc_scrub())
        .with_stuck_at(0.7);
    let geom = CacheGeometry::parse("4x64x32").unwrap();
    for id in BenchId::ALL {
        let w = kernels::prepare(id, 32, 0x5EED);
        for sms in [1u32, 4] {
            for cached in [false, true] {
                let mut cfg = GpgpuConfig::new(sms, 8);
                if cached {
                    cfg = cfg.with_memory(MemoryConfig::with_l1(geom));
                }
                for parallel in [false, true] {
                    let label = format!("{} {sms}sm cached={cached} par={parallel}", id.name());
                    let (bg, base) = run_with(&w, cfg, parallel, None, None).expect("clean run");
                    let (dg, dec) = run_with(
                        &w,
                        cfg,
                        parallel,
                        Some(&decorated),
                        Some(CheckpointPolicy::at_barriers()),
                    )
                    .expect("decorated run");
                    assert_eq!(image(&bg), image(&dg), "{label}: bits must not move");
                    assert_eq!(base.cycles, dec.cycles, "{label}: cycles must not move");
                    assert!(!dec.stats.fault.any(), "{label}: fault counters must stay zero");
                    assert_eq!(dec.stats.restarts, 0, "{label}: no restarts without faults");
                    assert_eq!(dec.stats.replayed_cycles, 0, "{label}: no replay");
                }
            }
        }
    }
}

#[test]
fn ecc_completes_detected_campaigns_and_serves_the_clean_image() {
    // Instruction-image upsets at mean interval 5 cycles: parity aborts
    // on the first one; SECDED corrects every one of them in place at
    // the modeled latency, so the run completes bit-identical to the
    // fault-free image — just slower.
    let targets = FaultTargets { instr_image: true, ..FaultTargets::none() };
    let parity = FaultPlan::new(0xC0FFEE, 200_000.0).with_targets(targets);
    let ecc = parity.with_protection(ProtectionConfig::ecc());
    let w = kernels::prepare(BenchId::VecAdd, 64, 0x5EED);
    let cfg = GpgpuConfig::new(2, 8);

    let (cg, clean) = run_with(&w, cfg, false, None, None).expect("clean run");
    let err = run_with(&w, cfg, false, Some(&parity), None)
        .err()
        .expect("parity must detect a mean-5-cycle instruction campaign");
    assert!(matches!(err, SimError::SoftError { .. }), "{err}");

    let (eg, run) = run_with(&w, cfg, false, Some(&ecc), None)
        .expect("ECC must correct every single-bit instruction upset");
    assert_eq!(image(&cg), image(&eg), "corrected run must serve the clean image");
    assert!(w.verify(&eg).is_ok(), "corrected run must verify against the host golden");
    let f = run.stats.fault;
    assert!(f.corrected > 0, "corrections must be counted");
    assert_eq!(f.detected, f.corrected, "every detected upset was correctable");
    assert_eq!(f.uncorrectable, 0);
    assert!(
        run.cycles > clean.cycles,
        "correction latency must show up in the cycle count ({} vs {})",
        run.cycles,
        clean.cycles
    );

    // Determinism across runs and launch paths still holds under ECC.
    let (eg2, run2) = run_with(&w, cfg, false, Some(&ecc), None).expect("repeat");
    assert_eq!((image(&eg), run.cycles), (image(&eg2), run2.cycles));
    assert_eq!(run.stats.fault, run2.stats.fault);
    let (ep, runp) = run_with(&w, cfg, true, Some(&ecc), None).expect("parallel path");
    assert_eq!((image(&eg), run.cycles), (image(&ep), runp.cycles));
    assert_eq!(run.stats.fault, runp.stats.fault);
}

#[test]
fn stuck_at_sites_recorrupt_until_the_scrubber_retires_them() {
    let w = kernels::prepare(BenchId::VecAdd, 64, 0x5EED);
    let cfg = GpgpuConfig::default();
    let (cg, clean) = run_with(&w, cfg, false, None, None).expect("clean run");
    // Mean inter-arrival of clean_cycles/8: several upsets land well
    // before the end of the run, all aged into stuck-at sites.
    let rate = 8.0e6 / clean.cycles as f64;
    // Seed-search for a campaign the scrubber demonstrably services
    // (at least one aged site retired and the run completing) — the
    // search is deterministic, so the test is too.
    let (plan, sg, scrub_run) = (0u64..)
        .find_map(|seed| {
            let plan = FaultPlan::new(0x51C2 + seed, rate)
                .with_targets(FaultTargets::silent())
                .with_protection(ProtectionConfig::ecc_scrub())
                .with_stuck_at(1.0);
            let (g, run) = run_with(&w, cfg, false, Some(&plan), None).ok()?;
            (run.stats.fault.scrubbed > 0).then_some((plan, g, run))
        })
        .expect("seed search is unbounded");
    // ECC corrects in place: aged re-corruptions cost cycles but never
    // flip state, so the served image is the clean one.
    assert_eq!(image(&cg), image(&sg), "scrubbed run must serve the clean image");
    assert!(w.verify(&sg).is_ok());
    let f = scrub_run.stats.fault;
    assert!(f.corrected > 0 && f.scrubbed > 0, "{f:?}");
    assert!(scrub_run.cycles > clean.cycles, "per-access correction cost must be visible");

    // Same campaign without the scrubber: aged sites persist, so every
    // later issue of the slot pays the correction again — strictly more
    // corrections than the scrubbed run — unless a second upset lands on
    // an aged word first, which SECDED cannot repair.
    let no_scrub = plan.with_protection(ProtectionConfig::ecc());
    match run_with(&w, cfg, false, Some(&no_scrub), None) {
        Ok((g, run)) => {
            assert_eq!(image(&cg), image(&g));
            assert_eq!(run.stats.fault.scrubbed, 0);
            assert!(
                run.stats.fault.corrected > f.corrected,
                "unscrubbed aged sites must keep paying corrections ({} vs {})",
                run.stats.fault.corrected,
                f.corrected
            );
        }
        Err(e) => assert!(matches!(e, SimError::SoftError { .. }), "{e}"),
    }
}

#[test]
fn checkpoint_restart_rescues_a_detected_upset_end_to_end() {
    let w = kernels::prepare(BenchId::VecAdd, 32, 0x5EED);
    let cfg = GpgpuConfig::default();
    let (cg, clean) = run_with(&w, cfg, false, None, None).expect("clean run");
    let c = clean.cycles;
    // One-shot schedule: the first upset lands in the first half of the
    // run and the second far beyond even a full replay.
    let targets = FaultTargets { instr_image: true, ..FaultTargets::none() };
    let plan = (0u64..)
        .map(|n| FaultPlan::new(0xCC + n, 50.0).with_targets(targets))
        .find(|p| {
            let mut st = FaultState::new(p, 0).unwrap();
            let e1 = st.next_event();
            e1 < c / 2 && {
                st.poll(e1);
                st.next_event() > e1 + 4 * c
            }
        })
        .expect("seed search is unbounded");
    // Without a checkpoint the parity-detected upset kills the launch...
    let err = run_with(&w, cfg, false, Some(&plan), None).err().expect("must detect");
    assert!(matches!(err, SimError::SoftError { .. }), "{err}");
    // ...with one, the SM rolls back, replays, and completes clean.
    let (g, run) = run_with(&w, cfg, false, Some(&plan), Some(CheckpointPolicy::at_barriers()))
        .expect("checkpointed run must complete");
    assert_eq!(image(&cg), image(&g), "replayed completion must be bit-identical");
    assert!(w.verify(&g).is_ok());
    assert_eq!(run.stats.restarts, 1, "exactly one restart for a one-shot schedule");
    assert!(run.stats.replayed_cycles > 0);
    assert!(run.cycles > c, "replayed progress is paid twice ({} vs {c})", run.cycles);
    // A zero-budget policy must surface the original error instead.
    let err = run_with(
        &w,
        cfg,
        false,
        Some(&plan),
        Some(CheckpointPolicy::at_barriers().with_max_restarts(0)),
    )
    .err()
    .expect("exhausted restart budget must fail");
    assert!(matches!(err, SimError::SoftError { .. }), "{err}");
}
