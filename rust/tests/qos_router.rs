//! QoS router + elastic rebalancer (ISSUE 9): round-robin tie spread,
//! latency-class admission gating, the sick-fleet spill case the static
//! router fails, queue-wait stamping, and the scale-up/down lifecycle
//! racing shutdown.

use flexgrip::coordinator::{
    ElasticConfig, FleetConfig, GpgpuService, QosClass, RecoveryPolicy, Request, RouterMode,
    ServiceConfig, ServiceError, VariantSpec,
};
use flexgrip::gpgpu::GpgpuConfig;
use flexgrip::kernels::BenchId;
use flexgrip::sim::{FaultPlan, FaultTargets};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Two variants tying bit-for-bit on modeled dynamic power.
fn tie_pair() -> FleetConfig {
    let base = GpgpuConfig::new(1, 8);
    FleetConfig::new(vec![VariantSpec::new("tie-a", base), VariantSpec::new("tie-b", base)])
}

/// Instruction-image upsets at mean interval 1 cycle: every job on the
/// sick shard fails parity-detected, deterministically.
fn sick_plan() -> FaultPlan {
    FaultPlan::new(0xBAD5EED, 1_000_000.0)
        .with_targets(FaultTargets { instr_image: true, ..FaultTargets::none() })
}

#[test]
fn equal_power_ties_spread_round_robin_instead_of_pinning() {
    // The old router's `min_by` kept the first minimum, so a bit-equal
    // power tie starved every variant after the first. Serial submits
    // against an idle pair must now alternate exactly.
    let svc = GpgpuService::start_fleet(tie_pair().with_depth(8));
    for k in 0..6u64 {
        let out =
            svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: k }).wait().unwrap();
        assert!(out.verified);
    }
    let by_label: std::collections::HashMap<_, _> = svc.variant_metrics().into_iter().collect();
    assert_eq!(by_label["tie-a"].jobs_completed, 3, "tie must not pin to the first variant");
    assert_eq!(by_label["tie-b"].jobs_completed, 3, "tie must not starve the second variant");
    let rs = svc.routing_stats();
    assert_eq!(rs.tie_broken(), 6);
    assert_eq!(rs.spilled(), 0);
    assert_eq!(rs.shed(), 0);
}

#[test]
fn homogeneous_fleet_routing_is_identical_across_router_modes() {
    // A single covering variant short-circuits the QoS scorer before any
    // signal is read: both modes must produce the same pure pass-through
    // admission stream, whatever classes the jobs carry.
    for mode in [RouterMode::Static, RouterMode::Qos] {
        let pool = VariantSpec::new("pool", GpgpuConfig::new(1, 8)).with_shards(2);
        let svc = GpgpuService::start_fleet(
            FleetConfig::new(vec![pool]).with_depth(8).with_router(mode),
        );
        let classes = [QosClass::Latency, QosClass::Throughput, QosClass::BestEffort];
        let tickets: Vec<_> = (0..6u64)
            .map(|k| {
                let req = Request::Bench { id: BenchId::VecAdd, n: 32, seed: k };
                svc.submit(req.qos(classes[k as usize % classes.len()]))
            })
            .collect();
        for t in tickets {
            assert!(t.wait().unwrap().verified);
        }
        let rs = svc.routing_stats();
        assert_eq!(rs.variants[0].routed, 6, "{mode:?}: every admission is a plain route");
        assert_eq!(rs.tie_broken(), 0, "{mode:?}");
        assert_eq!(rs.spilled(), 0, "{mode:?}");
        assert_eq!(rs.shed(), 0, "{mode:?}");
    }
}

#[test]
fn deadlined_latency_submit_sheds_immediately_when_nothing_has_slack() {
    // Fill a depth-1 tie pair until occupancy == depth + healthy on both
    // variants. A deadline'd Latency submit must then shed at admission
    // (the gate), not after burning its generous queue timeout.
    let svc = GpgpuService::start_fleet(tie_pair().with_depth(1));
    let busy: Vec<_> = (0..4u64)
        .map(|k| svc.submit(Request::Bench { id: BenchId::MatMul, n: 64, seed: k }))
        .collect();
    let t0 = Instant::now();
    let err = svc
        .submit_timeout(
            Request::Bench { id: BenchId::VecAdd, n: 32, seed: 9 }.qos(QosClass::Latency),
            Duration::from_secs(5),
        )
        .expect_err("latency admission gate must shed");
    assert_eq!(err, ServiceError::Saturated);
    assert!(
        t0.elapsed() < Duration::from_secs(2),
        "the gate sheds at admission, not after the 5 s queue timeout"
    );
    assert_eq!(svc.routing_stats().shed(), 1);
    for t in busy {
        assert!(t.wait().unwrap().verified, "the shed left no trace on accepted work");
    }
}

#[test]
fn backpressure_blocking_is_excluded_from_queue_wait() {
    // 1 shard, depth 1: a slow matmul runs, a vecadd queues behind it,
    // and a third submitter blocks on the full queue for ~the whole
    // matmul. The blocked job's wait clock must start when its queue
    // slot opened — the old stamp-before-push bug counted the blocking
    // too, doubling the aggregate.
    let svc = Arc::new(GpgpuService::start_pool(
        GpgpuConfig::new(1, 8),
        ServiceConfig { shards: 1, queue_depth: 1 },
    ));
    let start = Instant::now();
    let t_slow = svc.submit(Request::Bench { id: BenchId::MatMul, n: 64, seed: 1 });
    let t_queued = svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 1 });
    let blocked = {
        let svc = svc.clone();
        std::thread::spawn(move || {
            svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 2 }).wait()
        })
    };
    assert!(t_slow.wait().unwrap().verified);
    let matmul_wall = start.elapsed();
    assert!(t_queued.wait().unwrap().verified);
    assert!(blocked.join().unwrap().unwrap().verified);
    let wait_ns = u128::from(svc.metrics().queue_wait_ns);
    // Queued vecadd waited ~one matmul; the blocked job only ~one vecadd.
    // With the bug the blocked job also waited ~one matmul, pushing the
    // aggregate toward 2x.
    assert!(wait_ns > 0, "the queued job's residency must accumulate");
    assert!(
        wait_ns < matmul_wall.as_nanos() * 3 / 2,
        "queue wait {wait_ns} ns vs matmul wall {} ns: submit blocking leaked into the metric",
        matmul_wall.as_nanos()
    );
}

#[test]
fn per_class_wait_quantiles_follow_the_submitted_mix() {
    let svc = GpgpuService::start(GpgpuConfig::default());
    let submit = |req: Request| assert!(svc.submit(req).wait().unwrap().verified);
    for k in 0..2u64 {
        submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: k }.qos(QosClass::Latency));
    }
    // Untagged requests default to Throughput.
    submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 7 });
    for k in 0..3u64 {
        let req = Request::Bench { id: BenchId::VecAdd, n: 32, seed: 10 + k };
        submit(req.qos(QosClass::BestEffort));
    }
    let rs = svc.routing_stats();
    assert_eq!(rs.class(QosClass::Latency).jobs, 2);
    assert_eq!(rs.class(QosClass::Throughput).jobs, 1);
    assert_eq!(rs.class(QosClass::BestEffort).jobs, 3);
    assert_eq!(rs.overall.jobs, 6);
    assert!(rs.overall.p95_ns >= rs.overall.p50_ns);
}

/// Run the sick-fleet scenario: an equal-power pair whose static
/// favorite faults every job and quarantines, tight queues, deadline'd
/// submits. Returns (completed, shed, spilled) over 8 measured jobs.
fn sick_fleet_outcome(mode: RouterMode) -> (u64, u64, u64) {
    let base = GpgpuConfig::new(1, 8);
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![
            VariantSpec::new("sick", base).with_fault(0, sick_plan()),
            VariantSpec::new("healthy", base),
        ])
        .with_depth(2)
        .with_policy(RecoveryPolicy { max_attempts: 2, quarantine_after: 1, quarantine_ms: 500 })
        .with_router(mode),
    );
    // Warm-up: faults on the sick favorite, rescued on the healthy peer,
    // trips the 500 ms quarantine that the measured loop runs inside.
    svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: 1 })
        .wait()
        .expect("warm-up rescued on the healthy peer");
    std::thread::sleep(Duration::from_millis(10));
    let mut tickets = Vec::new();
    let mut shed = 0u64;
    for k in 0..8u64 {
        let req = Request::Bench { id: BenchId::VecAdd, n: 32, seed: 2 + k };
        match svc.submit_timeout(req, Duration::from_millis(30)) {
            Ok(t) => tickets.push(t),
            Err(e) => {
                assert_eq!(e, ServiceError::Saturated);
                shed += 1;
            }
        }
    }
    let completed = tickets.into_iter().filter_map(|t| t.wait().ok()).count() as u64;
    (completed, shed, svc.routing_stats().spilled())
}

#[test]
fn qos_router_completes_the_mix_the_static_router_sheds() {
    // The ISSUE-9 acceptance case: the static router keeps pinning jobs
    // to its quarantined power favorite and sheds most of the mix; the
    // QoS router sees the quarantine and spills the same mix to the
    // healthy peer, completing >= 95% of it.
    let (static_done, static_shed, _) = sick_fleet_outcome(RouterMode::Static);
    assert!(
        static_shed >= 4,
        "static router must shed into the quarantine (completed {static_done}, \
         shed {static_shed})"
    );
    let (qos_done, qos_shed, qos_spilled) = sick_fleet_outcome(RouterMode::Qos);
    assert!(
        qos_done * 100 >= 8 * 95,
        "QoS router must complete >= 95% of the mix (completed {qos_done}, shed {qos_shed})"
    );
    assert!(qos_spilled >= 8, "the rescue is visible as spills to the healthy peer");
}

#[test]
fn elastic_fleet_scales_up_under_backlog_and_retires_when_idle() {
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![VariantSpec::new("elastic", GpgpuConfig::new(1, 8))])
            .with_depth(64)
            .with_elastic(ElasticConfig::new(1, 3).with_sample_ms(1)),
    );
    assert_eq!(svc.variant_shards(), vec![("elastic".to_string(), 1, 3)]);
    let tickets: Vec<_> = (0..10u64)
        .map(|k| svc.submit(Request::Bench { id: BenchId::MatMul, n: 64, seed: k }))
        .collect();
    for t in tickets {
        assert!(t.wait().unwrap().verified);
    }
    assert!(
        svc.routing_stats().scale_ups >= 1,
        "a 10-job backlog on one live shard must spin up capacity"
    );
    // Drain-then-retire is asynchronous; poll for the idle retirement.
    let deadline = Instant::now() + Duration::from_secs(2);
    while svc.routing_stats().scale_downs == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(svc.routing_stats().scale_downs >= 1, "idle fleet must retire the extra shards");
    let (_, live, slots) = svc.variant_shards().remove(0);
    assert!((1..=slots).contains(&live), "live {live} outside [1, {slots}]");
    assert_eq!(svc.metrics().jobs_completed, 10);
}

#[test]
fn shutdown_races_the_rebalancer_without_losing_tickets() {
    // Race `shutdown()` against three phases of the elastic lifecycle
    // (mid-burst scale-up, mid-drain, post-drain retirement): every
    // accepted ticket must still resolve, none may hang or be lost to a
    // retiring shard.
    for settle_ms in [0u64, 5, 60] {
        let svc = GpgpuService::start_fleet(
            FleetConfig::new(vec![VariantSpec::new("elastic", GpgpuConfig::new(1, 8))])
                .with_depth(64)
                .with_elastic(ElasticConfig::new(1, 2).with_sample_ms(1)),
        );
        let tickets: Vec<_> = (0..8u64)
            .map(|k| svc.submit(Request::Bench { id: BenchId::VecAdd, n: 32, seed: k }))
            .collect();
        std::thread::sleep(Duration::from_millis(settle_ms));
        svc.shutdown();
        for (i, t) in tickets.into_iter().enumerate() {
            let out = t
                .wait()
                .unwrap_or_else(|e| panic!("settle {settle_ms} ms: job {i} lost: {e}"));
            assert!(out.verified, "settle {settle_ms} ms: job {i}");
        }
        assert_eq!(svc.metrics().jobs_completed, 8, "settle {settle_ms} ms");
        drop(svc);
    }
}
