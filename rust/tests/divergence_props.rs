//! Differential property test for SIMT control flow: randomly generated
//! structured programs (nested if/else over SSY/BRA/JOIN, predicated ops,
//! optional divergent EXITs) are executed
//!
//!  1. by the warp-based SM simulator (32 threads, one warp), and
//!  2. by an independent per-thread scalar interpreter in this file,
//!
//! and every architectural register each thread stores must agree. This
//! pins the warp-stack semantics of §4.1 far beyond the hand-written
//! kernels (1,500 random programs, seeded, deterministic).

use flexgrip::asm::assemble;
use flexgrip::isa::{Flags, Op, Operand};
use flexgrip::rng::XorShift64;
use flexgrip::sim::{
    eval_lane, AluFunc, BlockDesc, GlobalMem, NativeAlu, PreDecoded, Sm, SmConfig, SmLaunch,
};

const DATA_REGS: [u8; 5] = [1, 2, 3, 4, 5];
const OUT_BASE: u32 = 0x1000;

/// Random structured program source. R0 = tid (controller-seeded).
struct Gen {
    rng: XorShift64,
    src: String,
    label: u32,
}

impl Gen {
    fn fresh(&mut self) -> String {
        self.label += 1;
        format!("L{}", self.label)
    }

    fn alu(&mut self) {
        let ops = ["IADD", "ISUB", "IMUL", "AND", "OR", "XOR", "IMIN", "IMAX", "SHL", "SHR"];
        let op = ops[self.rng.below(ops.len() as u64) as usize];
        let d = DATA_REGS[self.rng.below(5) as usize];
        let a = DATA_REGS[self.rng.below(5) as usize];
        if self.rng.bool() {
            let imm = self.rng.range(-64, 64);
            self.src.push_str(&format!("    {op} R{d}, R{a}, #{imm}\n"));
        } else {
            let b = DATA_REGS[self.rng.below(5) as usize];
            self.src.push_str(&format!("    {op} R{d}, R{a}, R{b}\n"));
        }
    }

    fn setp(&mut self) {
        let a = DATA_REGS[self.rng.below(5) as usize];
        let imm = self.rng.range(-32, 32);
        self.src.push_str(&format!("    ISETP P0, R{a}, #{imm}\n"));
    }

    fn body(&mut self, depth: u32, allow_exit: bool) {
        let n = 1 + self.rng.below(4);
        for _ in 0..n {
            match self.rng.below(if depth < 3 { 10 } else { 7 }) {
                0..=4 => self.alu(),
                5 => {
                    // predicated ALU (condition-code path, no stack)
                    self.setp();
                    let conds = ["LT", "GE", "EQ", "NE", "GT", "LE"];
                    let c = conds[self.rng.below(6) as usize];
                    let d = DATA_REGS[self.rng.below(5) as usize];
                    self.src
                        .push_str(&format!("    @P0.{c} IADD R{d}, R{d}, #1\n"));
                }
                6 => {
                    if allow_exit && self.rng.below(8) == 0 && depth > 0 {
                        // divergent exit: some lanes retire early
                        self.setp();
                        self.src.push_str("    @P0.LT EXIT\n");
                    } else {
                        self.alu();
                    }
                }
                _ => self.if_else(depth + 1, allow_exit),
            }
        }
    }

    /// SSY end; @P0.c BRA then; <else>; JOIN; then: <then>; JOIN; end:
    fn if_else(&mut self, depth: u32, allow_exit: bool) {
        let (then_l, end_l) = (self.fresh(), self.fresh());
        self.setp();
        let conds = ["LT", "GE", "EQ", "NE", "GT", "LE"];
        let c = conds[self.rng.below(6) as usize];
        self.src.push_str(&format!("    SSY {end_l}\n"));
        self.src.push_str(&format!("    @P0.{c} BRA {then_l}\n"));
        self.body(depth, allow_exit); // else path
        self.src.push_str("    JOIN\n");
        self.src.push_str(&format!("{then_l}:\n"));
        self.body(depth, allow_exit); // then path
        self.src.push_str("    JOIN\n");
        self.src.push_str(&format!("{end_l}:\n"));
    }

    fn finish(mut self) -> String {
        // Epilogue: store R1..R5 to OUT_BASE + tid*32.
        self.src.push_str("    SHL R8, R0, #5\n");
        self.src
            .push_str(&format!("    IADD R8, R8, #{OUT_BASE}\n"));
        for (i, r) in DATA_REGS.iter().enumerate() {
            self.src
                .push_str(&format!("    GST [R8+{}], R{r}\n", i * 4));
        }
        self.src.push_str("    EXIT\n");
        self.src
    }
}

fn random_program(seed: u64) -> String {
    let mut g = Gen {
        rng: XorShift64::new(seed),
        src: String::from(".regs 12\n    IADD R1, R0, #3\n    IMUL R2, R0, R0\n    ISUB R3, R0, #7\n    MOV R4, #100\n    XOR R5, R0, #0x55\n"),
        label: 0,
    };
    let allow_exit = g.rng.bool();
    g.body(0, allow_exit);
    g.finish()
}

/// Independent scalar interpreter: one thread, uniform-branch semantics,
/// explicit SSY/JOIN stack (per paper §4.1 but degenerate for 1 thread).
fn scalar_run(code: &flexgrip::asm::Kernel, tid: i32) -> Option<[i32; 5]> {
    let mut regs = [0i32; 16];
    regs[0] = tid;
    let mut pred = Flags::default();
    let mut stack: Vec<u32> = Vec::new();
    let by_pc: std::collections::HashMap<u32, flexgrip::isa::Instr> =
        code.instrs.iter().cloned().collect();
    let mut pc = 0u32;
    let mut out = None;
    let mut steps = 0;
    loop {
        steps += 1;
        assert!(steps < 100_000, "scalar interpreter runaway");
        let i = by_pc[&pc];
        let guard_ok = i.guard.is_unconditional() || pred.eval(i.guard.cond);
        let rd = |o: Operand, regs: &[i32; 16]| -> i32 {
            match o {
                Operand::Reg(r) if r == flexgrip::isa::RZ => 0,
                Operand::Reg(r) => regs[r as usize],
                Operand::Imm(v) => v,
                _ => 0,
            }
        };
        let mut next = pc + i.size as u32;
        match i.op {
            Op::Exit => {
                if guard_ok {
                    break;
                }
            }
            Op::Ssy => {
                stack.push(i.branch_target().unwrap());
            }
            Op::Bra => {
                if guard_ok {
                    next = i.branch_target().unwrap();
                }
            }
            Op::Join => {
                next = stack.pop().expect("balanced SSY/JOIN");
            }
            Op::Gst => {
                if guard_ok {
                    let base = rd(i.src1, &regs);
                    let addr = base.wrapping_add(i.offset as i32) as u32;
                    let idx = (addr - OUT_BASE) as usize / 4 % 8;
                    let slot = out.get_or_insert([0i32; 5]);
                    if idx < 5 {
                        slot[idx] = rd(i.src2, &regs);
                    }
                }
            }
            Op::Isetp => {
                if guard_ok {
                    pred = Flags::of_sub(rd(i.src1, &regs), rd(i.src2, &regs));
                }
            }
            _ => {
                if guard_ok {
                    let f = AluFunc::from_op(i.op).expect("generator emits ALU ops");
                    // MOV #imm carries its immediate in src2 (src1 = None).
                    let a = match i.src1 {
                        Operand::None => rd(i.src2, &regs),
                        o => rd(o, &regs),
                    };
                    let v = eval_lane(f, i.cond, a, rd(i.src2, &regs), rd(i.src3, &regs));
                    if i.dst != flexgrip::isa::RZ {
                        regs[i.dst as usize] = v;
                    }
                }
            }
        }
        pc = next;
    }
    out
}

#[test]
fn prop_simt_equals_scalar_1500_random_programs() {
    for seed in 0..1500u64 {
        let src = random_program(seed ^ 0xD17E_u64);
        let kernel = assemble(&src).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{src}"));
        // SIMT run: one 32-thread warp.
        let pre = PreDecoded::from_kernel(&kernel);
        let sm = Sm::new(SmConfig::baseline(), 0);
        let mut gmem = GlobalMem::new(OUT_BASE + 32 * 32 + 64);
        let blocks =
            [BlockDesc { ctaid_x: 0, ctaid_y: 0, nctaid_x: 1, nctaid_y: 1, ntid: 32 }];
        let mut alu = NativeAlu;
        let launch = SmLaunch {
            pre: &pre,
            regs_per_thread: kernel.regs_per_thread,
            smem_bytes: 0,
            params: &[],
            blocks: &blocks,
            max_resident: 8,
            fault: None,
        };
        sm.run(&launch, &mut gmem, &mut alu)
            .unwrap_or_else(|e| panic!("seed {seed}: SIMT fault {e}\n{src}"));

        for tid in 0..32i32 {
            let want = scalar_run(&kernel, tid);
            let base = OUT_BASE + tid as u32 * 32;
            match want {
                Some(regs) => {
                    let got = gmem.read_words(base, 5).unwrap();
                    assert_eq!(
                        got,
                        regs.to_vec(),
                        "seed {seed} tid {tid} diverged\n{src}"
                    );
                }
                None => {
                    // thread exited before the epilogue: must not store
                    let got = gmem.read_words(base, 5).unwrap();
                    assert_eq!(got, vec![0; 5], "seed {seed} tid {tid} stored after EXIT\n{src}");
                }
            }
        }
    }
}
