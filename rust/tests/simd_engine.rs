//! Differential suite for the lane-vectorized execute engine (ISSUE 8).
//!
//! The vector engine batch-issues guard-free, fully-uniform micro-ops
//! over contiguous SoA lane slices; the scalar engine walks lanes
//! one-by-one and is the oracle. The two share every line of timing code
//! and the same `AluBackend`, so the contract is total: **bit-identical
//! memory images, cycle counts and statistics** (the `batched_uops`
//! counter excepted — it is the one observable allowed to differ and is
//! zeroed before comparison) across
//!
//! * every benchmark (`BenchId::ALL`) ×
//! * 1/2/4/8 SMs ×
//! * flat and L1-cached memory ×
//! * no-fault and a seeded silent SEU campaign,
//!
//! plus a randomized structured-program sweep that forces divergence and
//! guarded issues to exercise the batch/fallback switch mid-warp.

use flexgrip::asm::assemble;
use flexgrip::gpgpu::{Gpgpu, GpgpuConfig};
use flexgrip::kernels::{self, BenchId, RunOptions, Workload};
use flexgrip::rng::XorShift64;
use flexgrip::sim::{
    BlockDesc, CacheGeometry, EngineMode, FaultPlan, FaultTargets, GlobalMem, MemoryConfig,
    NativeAlu, PreDecoded, Sm, SmConfig, SmLaunch, SmStats,
};

fn image(g: &GlobalMem) -> Vec<i32> {
    g.read_words(0, g.size_bytes() as usize / 4).unwrap()
}

/// One run of a workload on the given engine; golden verification is
/// skipped (fault campaigns corrupt on purpose — identity is the claim
/// here, not correctness, which `benchmarks_correctness.rs` owns).
fn run_engine(
    w: &Workload,
    cfg: GpgpuConfig,
    engine: EngineMode,
    plan: Option<&FaultPlan>,
) -> (Vec<i32>, u64, SmStats) {
    let gpgpu = Gpgpu::new(cfg);
    let mut g = w.make_gmem();
    let mut opts = RunOptions::new().engine(engine);
    if let Some(p) = plan {
        opts = opts.fault(p);
    }
    let run = w.run(&gpgpu, &mut g, opts).expect("engine run");
    (image(&g), run.cycles, run.stats)
}

/// `batched_uops` is the only counter the two engines may disagree on.
fn comparable(mut s: SmStats) -> SmStats {
    s.batched_uops = 0;
    s
}

#[test]
fn vector_engine_is_bit_identical_to_scalar_across_the_matrix() {
    let plan = FaultPlan::new(0x51D_E5EED, 40_000.0).with_targets(FaultTargets::silent());
    let geom = CacheGeometry::parse("4x64x32").unwrap();
    for id in BenchId::ALL {
        let w = kernels::prepare(id, 32, 0xABCD);
        for sms in [1u32, 2, 4, 8] {
            for cached in [false, true] {
                let mut cfg = GpgpuConfig::new(sms, 8);
                if cached {
                    cfg = cfg.with_memory(MemoryConfig::with_l1(geom));
                }
                for fault in [None, Some(&plan)] {
                    let label = format!(
                        "{} {sms}sm cached={cached} fault={}",
                        id.name(),
                        fault.is_some()
                    );
                    let (vi, vc, vs) = run_engine(&w, cfg, EngineMode::Vector, fault);
                    let (si, sc, ss) = run_engine(&w, cfg, EngineMode::Scalar, fault);
                    assert_eq!(vi, si, "{label}: memory images diverge");
                    assert_eq!(vc, sc, "{label}: cycle counts diverge");
                    assert_eq!(
                        comparable(vs.clone()),
                        comparable(ss.clone()),
                        "{label}: stats diverge"
                    );
                    assert_eq!(ss.batched_uops, 0, "{label}: scalar engine batched");
                    if fault.is_none() {
                        // Every benchmark issues at least its uniform
                        // prologue (S2R/address math) down the batch path.
                        assert!(vs.batched_uops > 0, "{label}: vector engine never batched");
                    }
                }
            }
        }
    }
}

#[test]
fn uniform_benchmarks_batch_nearly_everything() {
    // vecadd at a warp-multiple size has no divergence and no guards
    // outside EXIT: the batch rate must dominate.
    let w = kernels::prepare(BenchId::VecAdd, 64, 7);
    let (_, _, stats) = run_engine(&w, GpgpuConfig::new(1, 8), EngineMode::Vector, None);
    assert!(
        stats.batched_uop_pct() > 80.0,
        "vecadd batched only {:.1}% of issues",
        stats.batched_uop_pct()
    );
    assert!((stats.lane_occupancy() - 1.0).abs() < 1e-12, "vecadd is fully uniform");
}

#[test]
fn default_options_run_the_vector_engine() {
    // RunOptions::default() must inherit the device default (Vector) —
    // the perf win ships on, not behind a flag.
    let w = kernels::prepare(BenchId::VecAdd, 32, 1);
    let gpgpu = Gpgpu::new(GpgpuConfig::new(1, 8));
    let mut g = w.make_gmem();
    let run = w.run(&gpgpu, &mut g, RunOptions::default()).unwrap();
    assert!(run.stats.batched_uops > 0);
    w.verify(&g).expect("default run verifies");
}

// --------------------------------------------------------------------
// Randomized divergence/guard sweep: structured programs with nested
// SSY/BRA/JOIN regions, predicated ops and divergent EXITs, run on both
// engines through `Sm::run` directly (one warp, 32 threads). Divergent
// regions force the scalar fallback; reconverged stretches re-enter the
// batch path — the switch itself is what this exercises.
// --------------------------------------------------------------------

const DATA_REGS: [u8; 5] = [1, 2, 3, 4, 5];
const OUT_BASE: u32 = 0x1000;

struct Gen {
    rng: XorShift64,
    src: String,
    label: u32,
}

impl Gen {
    fn fresh(&mut self) -> String {
        self.label += 1;
        format!("L{}", self.label)
    }

    fn alu(&mut self) {
        let ops = ["IADD", "ISUB", "IMUL", "AND", "OR", "XOR", "IMIN", "IMAX", "SHL", "SHR"];
        let op = ops[self.rng.below(ops.len() as u64) as usize];
        let d = DATA_REGS[self.rng.below(5) as usize];
        let a = DATA_REGS[self.rng.below(5) as usize];
        if self.rng.bool() {
            let imm = self.rng.range(-64, 64);
            self.src.push_str(&format!("    {op} R{d}, R{a}, #{imm}\n"));
        } else {
            let b = DATA_REGS[self.rng.below(5) as usize];
            self.src.push_str(&format!("    {op} R{d}, R{a}, R{b}\n"));
        }
    }

    fn setp(&mut self) {
        let a = DATA_REGS[self.rng.below(5) as usize];
        let imm = self.rng.range(-32, 32);
        self.src.push_str(&format!("    ISETP P0, R{a}, #{imm}\n"));
    }

    fn guarded_alu(&mut self) {
        self.setp();
        let conds = ["LT", "GE", "EQ", "NE", "GT", "LE"];
        let c = conds[self.rng.below(6) as usize];
        let d = DATA_REGS[self.rng.below(5) as usize];
        self.src.push_str(&format!("    @P0.{c} IADD R{d}, R{d}, #1\n"));
    }

    fn if_else(&mut self, depth: u32) {
        let (then_l, end_l) = (self.fresh(), self.fresh());
        self.setp();
        let conds = ["LT", "GE", "EQ", "NE", "GT", "LE"];
        let c = conds[self.rng.below(6) as usize];
        self.src.push_str(&format!("    SSY {end_l}\n"));
        self.src.push_str(&format!("    @P0.{c} BRA {then_l}\n"));
        self.body(depth);
        self.src.push_str("    JOIN\n");
        self.src.push_str(&format!("{then_l}:\n"));
        self.body(depth);
        self.src.push_str("    JOIN\n");
        self.src.push_str(&format!("{end_l}:\n"));
    }

    fn body(&mut self, depth: u32) {
        let n = 1 + self.rng.below(4);
        for _ in 0..n {
            match self.rng.below(if depth < 2 { 8 } else { 6 }) {
                0..=3 => self.alu(),
                4 | 5 => self.guarded_alu(),
                _ => self.if_else(depth + 1),
            }
        }
    }

    fn finish(mut self) -> String {
        self.src.push_str("    SHL R8, R0, #5\n");
        self.src.push_str(&format!("    IADD R8, R8, #{OUT_BASE}\n"));
        for (i, r) in DATA_REGS.iter().enumerate() {
            self.src.push_str(&format!("    GST [R8+{}], R{r}\n", i * 4));
        }
        self.src.push_str("    EXIT\n");
        self.src
    }
}

fn random_program(seed: u64) -> String {
    let mut g = Gen {
        rng: XorShift64::new(seed),
        src: String::from(
            ".regs 12\n    IADD R1, R0, #3\n    IMUL R2, R0, R0\n    ISUB R3, R0, #7\n    MOV R4, #100\n    XOR R5, R0, #0x55\n",
        ),
        label: 0,
    };
    g.body(0);
    g.finish()
}

fn sm_run(kernel: &flexgrip::asm::Kernel, engine: EngineMode) -> (Vec<i32>, SmStats) {
    let pre = PreDecoded::from_kernel(kernel);
    let sm = Sm::new(SmConfig::baseline().with_engine(engine), 0);
    let mut gmem = GlobalMem::new(OUT_BASE + 32 * 32 + 64);
    let blocks = [BlockDesc { ctaid_x: 0, ctaid_y: 0, nctaid_x: 1, nctaid_y: 1, ntid: 32 }];
    let mut alu = NativeAlu;
    let launch = SmLaunch {
        pre: &pre,
        regs_per_thread: kernel.regs_per_thread,
        smem_bytes: 0,
        params: &[],
        blocks: &blocks,
        max_resident: 8,
        fault: None,
    };
    let stats = sm.run(&launch, &mut gmem, &mut alu).expect("random program runs");
    (image(&gmem), stats)
}

#[test]
fn random_divergent_programs_agree_across_engines() {
    let mut fell_back = 0u32;
    let mut batched = 0u32;
    for seed in 0..200u64 {
        let src = random_program(seed ^ 0x51D0_u64);
        let kernel = assemble(&src).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{src}"));
        let (vi, vs) = sm_run(&kernel, EngineMode::Vector);
        let (si, ss) = sm_run(&kernel, EngineMode::Scalar);
        assert_eq!(vi, si, "seed {seed}: memory images diverge\n{src}");
        assert_eq!(
            comparable(vs.clone()),
            comparable(ss),
            "seed {seed}: stats diverge\n{src}"
        );
        if vs.batched_uops > 0 {
            batched += 1;
        }
        if vs.batched_uops < vs.instructions {
            fell_back += 1;
        }
    }
    // The sweep must genuinely exercise both paths, not degenerate into
    // all-uniform or all-divergent programs.
    assert!(batched > 150, "only {batched}/200 programs hit the batch path");
    assert!(fell_back > 150, "only {fell_back}/200 programs hit the scalar fallback");
}
