//! Failure injection: every architectural fault class must surface as a
//! structured error (never a panic, never silent corruption) — the
//! driver-facing error contract of §3.1.

use flexgrip::asm::assemble;
use flexgrip::gpgpu::{Gpgpu, GpgpuConfig, LaunchConfig, LaunchRequest};
use flexgrip::isa::Capability;
use flexgrip::sim::{GlobalMem, MemoryConfig, SimError, SmConfig};

fn launch_src(src: &str, cfg: GpgpuConfig, block: u32) -> Result<(), SimError> {
    let k = assemble(src).unwrap();
    let mut g = GlobalMem::new(4096);
    Gpgpu::new(cfg)
        .launch(LaunchRequest::new(&k, LaunchConfig::linear(1, block), &mut g))
        .map(|_| ())
}

#[test]
fn global_oob_load_faults_with_address() {
    let err = launch_src("MOV R1, #0x100000\nGLD R2, [R1]\nEXIT", GpgpuConfig::default(), 32)
        .unwrap_err();
    match err {
        SimError::MemFault { space, addr, reason } => {
            assert_eq!(space, "global");
            assert_eq!(addr, 0x100000);
            assert_eq!(reason, "out of bounds");
        }
        other => panic!("{other}"),
    }
}

#[test]
fn misaligned_store_faults() {
    let err = launch_src("MOV R1, #6\nMOV R2, #1\nGST [R1], R2\nEXIT", GpgpuConfig::default(), 32)
        .unwrap_err();
    assert!(matches!(err, SimError::MemFault { reason: "misaligned", .. }));
}

#[test]
fn shared_oob_faults_independently_of_global() {
    let err = launch_src("MOV R1, #0x2000\nSLD R2, [R1]\nEXIT", GpgpuConfig::default(), 32)
        .unwrap_err();
    assert!(matches!(err, SimError::MemFault { space: "shared", .. }));
}

#[test]
fn stack_overflow_names_warp_and_depth() {
    // A push-per-iteration loop defeats the static bound (it saturates to
    // Unbounded, so pre-flight admission lets the launch through — see
    // tests/admission.rs for the statically-provable case), and the
    // runtime trap is the backstop that names warp and depth.
    let mut cfg = GpgpuConfig::new(1, 8);
    cfg.sm.warp_stack_depth = 2;
    let err = launch_src("a:\nSSY b\nBRA a\nb:\nEXIT", cfg, 32).unwrap_err();
    assert!(matches!(err, SimError::StackOverflow { depth: 2, .. }), "{err}");
}

#[test]
fn stack_underflow_detected() {
    let err = launch_src("JOIN\nEXIT", GpgpuConfig::default(), 32).unwrap_err();
    assert!(matches!(err, SimError::StackUnderflow { pc: 0, .. }));
}

#[test]
fn barrier_is_warp_granular_like_hardware() {
    // A BAR reached inside a divergent region synchronizes at *warp*
    // granularity (the warp unit tracks warps, not lanes — same as the
    // FPGA hardware and G80). With one warp the barrier releases
    // immediately and the kernel completes; it must not deadlock or
    // corrupt the divergence stack.
    let src = r#"
        S2R R0, SR_TID
        ISETP P0, R0, #16
        SSY end
        @P0.LT BRA exit_path
        BAR                  ; upper half arrives as "the warp"
        JOIN
    exit_path:
        EXIT
    end:
        EXIT
    "#;
    launch_src(src, GpgpuConfig::default(), 32).expect("warp-granular barrier releases");
}

#[test]
fn watchdog_stops_infinite_loops() {
    let mut cfg = GpgpuConfig::default();
    cfg.sm.watchdog_cycles = 10_000;
    let err = launch_src("top:\nBRA top\nEXIT", cfg, 32).unwrap_err();
    assert!(matches!(err, SimError::Watchdog { .. }));
}

#[test]
fn run_off_code_end_detected() {
    let err = launch_src("NOP\nNOP", GpgpuConfig::default(), 32).unwrap_err();
    assert!(matches!(err, SimError::RanOffCode { .. }));
}

#[test]
fn illegal_opcode_in_binary_faults_at_fetch() {
    // Corrupt an encoded image: overwrite an opcode with 0x7f.
    let mut k = assemble("NOP\nNOP\nEXIT").unwrap();
    k.code[4] = 0x7f;
    let err = flexgrip::isa::decode_stream(&k.code).unwrap_err();
    assert!(matches!(err, flexgrip::isa::DecodeError::BadOpcode(0x7f)));
}

#[test]
fn capability_mismatch_is_a_structured_preflight_error() {
    let mut cfg = GpgpuConfig::new(1, 8);
    cfg.sm.has_multiplier = false;
    cfg.sm.read_operands = 2;
    let err = launch_src("IMUL R1, R2, R3\nEXIT", cfg, 32).unwrap_err();
    assert!(matches!(
        err,
        SimError::Unsupported { capability: Capability::Multiplier, pc: None, .. }
    ));
    let err = launch_src("IMAD R1, R2, R3, R4\nEXIT", cfg, 32).unwrap_err();
    // IMAD is caught by the multiplier check first (it multiplies).
    assert!(matches!(
        err,
        SimError::Unsupported {
            capability: Capability::Multiplier | Capability::ThirdReadOperand,
            pc: None,
            ..
        }
    ));
}

#[test]
fn invalid_configs_rejected_before_execution() {
    let bad_sp = GpgpuConfig::new(1, 9);
    assert!(matches!(bad_sp.validate(), Err(SimError::LimitExceeded(_))));
    let mut bad_stack = GpgpuConfig::default();
    bad_stack.sm.warp_stack_depth = 64;
    assert!(bad_stack.validate().is_err());
    let zero_sms = GpgpuConfig {
        num_sms: 0,
        sm: SmConfig::baseline(),
        memory: MemoryConfig::default(),
    };
    assert!(zero_sms.validate().is_err());
    let mut bad_cache = GpgpuConfig::default();
    bad_cache.memory.l1 = Some(flexgrip::sim::L1Config::new(flexgrip::sim::CacheGeometry {
        ways: 4,
        sets: 48, // not a power of two
        line_bytes: 32,
    }));
    assert!(bad_cache.validate().is_err());
}

#[test]
fn empty_grid_and_oversized_block_rejected() {
    let k = assemble("EXIT").unwrap();
    let mut g = GlobalMem::new(1024);
    let gp = Gpgpu::new(GpgpuConfig::default());
    assert!(matches!(
        gp.launch(LaunchRequest::new(&k, LaunchConfig::linear(0, 32), &mut g)),
        Err(SimError::LimitExceeded(_))
    ));
    assert!(matches!(
        gp.launch(LaunchRequest::new(&k, LaunchConfig::linear(1, 300), &mut g)),
        Err(SimError::LimitExceeded(_))
    ));
}

#[test]
fn faults_do_not_poison_subsequent_launches() {
    let gp = Gpgpu::new(GpgpuConfig::default());
    let bad = assemble("JOIN\nEXIT").unwrap();
    let good = assemble("S2R R1, SR_GTID\nSHL R2, R1, #2\nGST [R2], R1\nEXIT").unwrap();
    let mut g = GlobalMem::new(4096);
    assert!(gp
        .launch(LaunchRequest::new(&bad, LaunchConfig::linear(1, 32), &mut g))
        .is_err());
    gp.launch(LaunchRequest::new(&good, LaunchConfig::linear(1, 32), &mut g)).unwrap();
    assert_eq!(g.load(31 * 4).unwrap(), 31);
}
