//! `cargo bench --bench paper_tables` — regenerates every table in the
//! paper (Tables 1, 2, 4 from the calibrated models; Tables 3, 5, 6 from
//! full simulator + baseline runs at the paper's 256 input size) and
//! times the regeneration. Output mirrors the paper's layout with
//! measured-vs-paper columns.

use flexgrip::harness::{bench, tables, Evaluation};

fn main() {
    println!("=== paper table regeneration (measured | paper) ===\n");

    bench("table1_physical_limits", 32, || tables::table1().render());
    bench("table2_area_model", 32, || tables::table2().render());
    bench("table4_power_model", 32, || tables::table4().render());
    println!();
    println!("{}", tables::table1().render());
    println!("{}", tables::table2().render());
    println!("{}", tables::table4().render());

    // End-to-end tables: one timed sample (each regeneration simulates
    // every benchmark at size 256 on up to 6 configurations).
    let r3 = bench("table3_2sm_scaling_size256", 1, || {
        let mut ev = Evaluation::new(256);
        tables::table3(&mut ev).render()
    });
    let r5 = bench("table5_energy_size256", 1, || {
        let mut ev = Evaluation::new(256);
        tables::table5(&mut ev).render()
    });
    let r6 = bench("table6_customization_size256", 1, || {
        let mut ev = Evaluation::new(256);
        tables::table6(&mut ev).render()
    });
    println!();
    let mut ev = Evaluation::new(256);
    println!("{}", tables::table3(&mut ev).render());
    println!("{}", tables::table5(&mut ev).render());
    println!("{}", tables::table6(&mut ev).render());
    let _ = (r3, r5, r6);
    println!("paper_tables bench OK");
}
