//! `cargo bench --bench hot_path` — microbenchmarks of the simulator's
//! hot paths (the §Perf targets in EXPERIMENTS.md):
//!
//! * SM issue loop throughput (simulated warp-instructions / second)
//! * native ALU lane throughput
//! * multi-SM scaling: 1-SM vs 2-SM sequential vs 2-SM parallel vs a
//!   4-shard coordinator pool on the largest paper benchmark, emitted as
//!   machine-readable `BENCH_scaling.json` for cross-PR tracking
//! * XLA ALU backend (skipped gracefully when PJRT is unavailable)
//! * assembler + pre-decode throughput
//! * MicroBlaze VM throughput

use flexgrip::asm::assemble;
use flexgrip::baseline::{self, MbTiming};
use flexgrip::gpgpu::{Gpgpu, GpgpuConfig};
use flexgrip::harness::{bench, scaling_report};
use flexgrip::isa::Cond;
use flexgrip::kernels::{self, BenchId};
use flexgrip::runtime::{Artifacts, XlaAlu, XlaBatchAlu, XLA_BATCH};
use flexgrip::sim::{AluBackend, AluFunc, NativeAlu, WarpAluIn};
use std::sync::Arc;

fn main() {
    println!("=== hot-path microbenchmarks ===\n");

    // Simulator issue loop: matmul-64 on the baseline config.
    let gpgpu = Gpgpu::new(GpgpuConfig::new(1, 8));
    let w = kernels::prepare(BenchId::MatMul, 64, 1);
    let instrs = {
        let mut alu = NativeAlu;
        let mut g = w.make_gmem();
        w.run(&gpgpu, &mut g, &mut alu).unwrap().stats.instructions
    };
    let r = bench("sim_matmul64_1sm8sp", 10, || {
        let mut alu = NativeAlu;
        let mut g = w.make_gmem();
        w.run(&gpgpu, &mut g, &mut alu).unwrap().cycles
    });
    let wi_per_s = instrs as f64 / r.median().as_secs_f64();
    println!(
        "  -> {instrs} warp-instrs / run = {:.2} M warp-instrs/s ({:.1} M lane-ops/s)\n",
        wi_per_s / 1e6,
        wi_per_s * 32.0 / 1e6
    );

    // Divergence-heavy path.
    let wd = kernels::prepare(BenchId::Bitonic, 256, 1);
    bench("sim_bitonic256_divergent", 10, || {
        let mut alu = NativeAlu;
        let mut g = wd.make_gmem();
        wd.run(&gpgpu, &mut g, &mut alu).unwrap().cycles
    });

    // Multi-SM scaling on the largest paper benchmark: sequential vs the
    // scoped-thread parallel path vs the sharded coordinator pool.
    println!("\n--- multi-SM / pool scaling (matmul-256) ---");
    let report = scaling_report(BenchId::MatMul, 256, 1, 3);
    for p in &report.points {
        println!(
            "{:<44} {:>10.1} ms wall  ({} jobs, {} simulated cycles)",
            p.label, p.wall_ms, p.jobs, p.sim_cycles
        );
    }
    if let Some(s) = report.speedup("2sm_parallel", "2sm_sequential") {
        println!("  -> 2-SM parallel over 2-SM sequential: {s:.2}x wall-clock");
    }
    if let Some(s) = report.speedup("2sm_parallel", "1sm_sequential") {
        println!("  -> 2-SM parallel over 1-SM sequential: {s:.2}x wall-clock");
    }
    report
        .write_json("BENCH_scaling.json")
        .expect("write BENCH_scaling.json");
    println!("  -> wrote BENCH_scaling.json\n");

    // Native ALU throughput.
    let input = WarpAluIn {
        func: AluFunc::Mad,
        cond: Cond::Always,
        a: [7; 32],
        b: [9; 32],
        c: [1; 32],
    };
    bench("native_alu_1M_mads", 10, || {
        let mut alu = NativeAlu;
        let mut acc = 0i64;
        for _ in 0..1_000_000 {
            acc += alu.execute(&input)[0] as i64;
        }
        acc
    });

    // XLA backends (need AOT artifacts + the PJRT bindings).
    let xla_ready = Artifacts::open_default()
        .map(Arc::new)
        .and_then(|arts| XlaAlu::new(arts.clone()).map(|alu| (arts, alu)));
    match xla_ready {
        Ok((arts, mut xla)) => {
            bench("xla_alu_single_slot_x100", 5, || {
                let mut acc = 0i64;
                for _ in 0..100 {
                    acc += xla.execute(&input)[0] as i64;
                }
                acc
            });
            let batch = XlaBatchAlu::new(arts).unwrap();
            let inputs: Vec<WarpAluIn> = (0..XLA_BATCH).map(|_| input.clone()).collect();
            bench("xla_alu_batch64_x100", 5, || {
                let mut acc = 0i64;
                for _ in 0..100 {
                    acc += batch.execute_batch(&inputs).unwrap()[0][0] as i64;
                }
                acc
            });
            println!("  -> batch64 amortizes the PJRT call ~64x per slot\n");
        }
        Err(e) => println!("skipping XLA benches: {e}"),
    }

    // Assembler + pre-decode.
    let src = BenchId::MatMul.source();
    bench("assemble_matmul_x1000", 10, || {
        let mut n = 0;
        for _ in 0..1000 {
            n += assemble(src).unwrap().instrs.len();
        }
        n
    });

    // MicroBlaze VM.
    bench("microblaze_matmul64", 10, || {
        baseline::run_verified(BenchId::MatMul, 64, 1, MbTiming::default())
            .unwrap()
            .cycles
    });

    println!("hot_path bench OK");
}
