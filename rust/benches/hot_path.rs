//! `cargo bench --bench hot_path` — microbenchmarks of the simulator's
//! hot paths (the §Perf targets in EXPERIMENTS.md):
//!
//! * **engine throughput** on all five paper benchmarks, reported as
//!   simulated warp-instructions per second and emitted as
//!   machine-readable `BENCH_hot_path.json` for cross-PR tracking (the
//!   ISSUE-2 acceptance metric);
//! * multi-SM / SP-width scaling: 1/2-SM sequential vs 2/4/8-SM parallel
//!   vs 16/32-SP widths vs a 4-shard coordinator pool, swept over three
//!   benchmark shapes and emitted as `BENCH_scaling.json` (one report
//!   object per benchmark);
//! * memory-hierarchy sweep: the paper benchmarks + the memstress stride
//!   variants under flat memory and three L1/BRAM geometries, emitted as
//!   `BENCH_memory.json` (hit rate, stall/contention cycles, modeled
//!   dynamic energy per point);
//! * resilience sweep: recovery policies (no-recovery / retry /
//!   retry+quarantine / DMR) replaying a job mix against seeded SEU
//!   campaign rates on a sick shard, emitted as `BENCH_resilience.json`
//!   (jobs rescued/lost, corrupted outputs, retry latency overhead,
//!   quarantine events);
//! * native ALU lane throughput;
//! * XLA ALU backend (skipped gracefully when PJRT is unavailable);
//! * assembler + pre-decode throughput;
//! * MicroBlaze VM throughput.
//!
//! Set `FLEXGRIP_BENCH_FAST=1` (the CI bench-smoke job does) to shrink
//! problem sizes and sample counts so the run fits in a smoke budget
//! while still exercising every code path and emitting both JSON files.

use flexgrip::asm::assemble;
use flexgrip::baseline::{self, MbTiming};
use flexgrip::coordinator::{GpgpuService, Request, ServiceConfig};
use flexgrip::gpgpu::{Gpgpu, GpgpuConfig};
use flexgrip::harness::{
    bench, memory_report, resilience_report, scaling_suite, write_suite_json, HotPathPoint,
    HotPathReport,
};
use flexgrip::isa::Cond;
use flexgrip::kernels::{self, BenchId, RunOptions};
use flexgrip::runtime::{Artifacts, XlaAlu, XlaBatchAlu, XLA_BATCH};
use flexgrip::sim::{AluBackend, AluFunc, NativeAlu, WarpAluIn};
use std::sync::Arc;

fn main() {
    let fast = std::env::var("FLEXGRIP_BENCH_FAST").is_ok_and(|v| v != "0" && !v.is_empty());
    println!("=== hot-path microbenchmarks{} ===\n", if fast { " (fast mode)" } else { "" });

    // Engine throughput: every paper benchmark on the baseline 1-SM/8-SP
    // config, sequential reference path. The per-benchmark median run is
    // converted to simulated warp-instructions per second — the ISSUE-2
    // acceptance metric, recorded in BENCH_hot_path.json and
    // EXPERIMENTS.md §Perf.
    println!("--- engine throughput (warp-instructions / second) ---");
    let gpgpu = Gpgpu::new(GpgpuConfig::new(1, 8));
    let (ips_n, samples) = if fast { (64, 3) } else { (256, 10) };
    // Service-plane latency probe: a short burst per benchmark through a
    // 2-shard pool measures submit-to-dispatch wait on the sharded queue
    // (the queue_wait_ns column of BENCH_hot_path.json).
    let svc = GpgpuService::start_pool(
        GpgpuConfig::new(1, 8),
        ServiceConfig { shards: 2, queue_depth: 8 },
    );
    let burst = if fast { 2u64 } else { 8 };
    let mut points = Vec::new();
    for id in BenchId::PAPER {
        let w = kernels::prepare(id, ips_n, 1);
        let stats = {
            let mut g = w.make_gmem();
            w.run(&gpgpu, &mut g, RunOptions::default()).unwrap().stats
        };
        let (warp_instrs, thread_instrs) = (stats.instructions, stats.thread_instructions);
        let r = bench(&format!("sim_{}{}_1sm8sp", id.name(), ips_n), samples, || {
            let mut g = w.make_gmem();
            w.run(&gpgpu, &mut g, RunOptions::default()).unwrap().cycles
        });
        let wall_ms = r.median().as_secs_f64() * 1e3;
        let instrs_per_sec = warp_instrs as f64 / r.median().as_secs_f64();
        let queue_wait_ns = {
            let before = svc.metrics();
            let tickets: Vec<_> = (0..burst)
                .map(|seed| svc.submit(Request::Bench { id, n: 32, seed }))
                .collect();
            for t in tickets {
                t.wait().expect("queue-probe job");
            }
            let after = svc.metrics();
            let done = after.jobs_completed - before.jobs_completed;
            if done == 0 { 0 } else { (after.queue_wait_ns - before.queue_wait_ns) / done }
        };
        println!(
            "  -> {warp_instrs} warp-instrs / run = {:.2} M warp-instrs/s \
             ({:.1} M lane-ops/s, {:.0}% lanes, {:.0}% batched, {queue_wait_ns} ns queue)",
            instrs_per_sec / 1e6,
            thread_instrs as f64 / r.median().as_secs_f64() / 1e6,
            100.0 * stats.lane_occupancy(),
            stats.batched_uop_pct(),
        );
        points.push(HotPathPoint {
            bench: id.name(),
            n: ips_n,
            warp_instrs,
            thread_instrs,
            wall_ms,
            instrs_per_sec,
            lane_occupancy: stats.lane_occupancy(),
            batched_uop_pct: stats.batched_uop_pct(),
            queue_wait_ns,
        });
    }
    drop(svc);
    let report = HotPathReport { fast, points };
    report
        .write_json("BENCH_hot_path.json")
        .expect("write BENCH_hot_path.json");
    println!(
        "  -> geomean {:.2} M warp-instrs/s; wrote BENCH_hot_path.json\n",
        report.geomean_instrs_per_sec() / 1e6
    );

    // Divergence-heavy path.
    let wd = kernels::prepare(BenchId::Bitonic, if fast { 64 } else { 256 }, 1);
    bench("sim_bitonic_divergent", samples, || {
        let mut g = wd.make_gmem();
        wd.run(&gpgpu, &mut g, RunOptions::default()).unwrap().cycles
    });

    // Multi-SM / SP-width scaling suite: sequential vs the scoped-thread
    // parallel path (2/4/8 SM, COW snapshots) vs the 16/32-SP widths vs
    // the sharded coordinator pool, swept over three benchmark shapes
    // (compute-heavy matmul, divergence-heavy bitonic, two-phase
    // reduction — the ROADMAP follow-up to the matmul-only study).
    let (scale_n, scale_samples) = if fast { (64, 1) } else { (256, 3) };
    let scale_benches = [BenchId::MatMul, BenchId::Bitonic, BenchId::Reduction];
    println!("\n--- multi-SM / SP / pool scaling (n={scale_n}) ---");
    let reports = scaling_suite(&scale_benches, scale_n, 1, scale_samples);
    for report in &reports {
        println!("[{}]", report.bench);
        for p in &report.points {
            println!(
                "{:<44} {:>10.1} ms wall  ({} jobs, {} simulated cycles, ~{} LUTs)",
                p.label, p.wall_ms, p.jobs, p.sim_cycles, p.luts
            );
        }
    }
    let matmul = &reports[0];
    if let Some(s) = matmul.speedup("2sm_parallel", "2sm_sequential") {
        println!("  -> 2-SM parallel over 2-SM sequential: {s:.2}x wall-clock");
    }
    if let Some(s) = matmul.speedup("2sm_parallel", "1sm_sequential") {
        println!("  -> 2-SM parallel over 1-SM sequential: {s:.2}x wall-clock");
    }
    for label in ["4sm_parallel", "8sm_parallel", "1sm_16sp_sequential", "1sm_32sp_sequential"] {
        if let Some(s) = matmul.sim_speedup(label, "1sm_sequential") {
            println!("  -> {label} over 1-SM/8-SP: {s:.2}x simulated cycles");
        }
    }
    write_suite_json("BENCH_scaling.json", &reports).expect("write BENCH_scaling.json");
    println!("  -> wrote BENCH_scaling.json\n");

    // Memory-hierarchy sweep: every cached point is verified against the
    // golden reference AND asserted bit-identical to the flat run.
    let mem_n = if fast { 64 } else { 256 };
    println!("--- memory hierarchy sweep (n={mem_n}) ---");
    let mem = memory_report(mem_n, 1);
    for p in &mem.points {
        println!(
            "{:<16} {:<12} {:>8} hits {:>8} misses ({:>5.1}% hit)  \
             {:>10} cycles  {:.3} mJ",
            p.bench,
            p.cache,
            p.hits,
            p.misses,
            100.0 * p.hit_rate,
            p.cycles,
            p.energy_mj
        );
    }
    mem.write_json("BENCH_memory.json").expect("write BENCH_memory.json");
    println!("  -> wrote BENCH_memory.json\n");

    // Resilience sweep: recovery policies vs seeded SEU campaigns on a
    // sick shard (EXPERIMENTS.md §Resilience).
    let res_jobs = if fast { 3 } else { 9 };
    println!("--- resilience sweep (n=32, {res_jobs} jobs/point) ---");
    let res = resilience_report(32, res_jobs, 1);
    for p in &res.points {
        println!(
            "{:<18} rate {:>9.0}  {}/{} completed ({} rescued, {} lost)  \
             {} soft errors, {} quarantines",
            p.policy, p.fault_rate, p.completed, p.jobs, p.rescued, p.lost, p.soft_errors,
            p.quarantines
        );
    }
    res.write_json("BENCH_resilience.json").expect("write BENCH_resilience.json");
    println!("  -> wrote BENCH_resilience.json\n");

    // Native ALU throughput.
    let input = WarpAluIn {
        func: AluFunc::Mad,
        cond: Cond::Always,
        a: [7; 32],
        b: [9; 32],
        c: [1; 32],
    };
    bench("native_alu_1M_mads", if fast { 3 } else { 10 }, || {
        let mut alu = NativeAlu;
        let mut acc = 0i64;
        for _ in 0..1_000_000 {
            acc += alu.execute(&input)[0] as i64;
        }
        acc
    });

    // XLA backends (need AOT artifacts + the PJRT bindings).
    let xla_ready = Artifacts::open_default()
        .map(Arc::new)
        .and_then(|arts| XlaAlu::new(arts.clone()).map(|alu| (arts, alu)));
    match xla_ready {
        Ok((arts, mut xla)) => {
            bench("xla_alu_single_slot_x100", 5, || {
                let mut acc = 0i64;
                for _ in 0..100 {
                    acc += xla.execute(&input)[0] as i64;
                }
                acc
            });
            let batch = XlaBatchAlu::new(arts).unwrap();
            let inputs: Vec<WarpAluIn> = (0..XLA_BATCH).map(|_| input.clone()).collect();
            bench("xla_alu_batch64_x100", 5, || {
                let mut acc = 0i64;
                for _ in 0..100 {
                    acc += batch.execute_batch(&inputs).unwrap()[0][0] as i64;
                }
                acc
            });
            println!("  -> batch64 amortizes the PJRT call ~64x per slot\n");
        }
        Err(e) => println!("skipping XLA benches: {e}"),
    }

    // Assembler + pre-decode.
    let src = BenchId::MatMul.source();
    bench("assemble_matmul_x1000", if fast { 3 } else { 10 }, || {
        let mut n = 0;
        for _ in 0..1000 {
            n += assemble(src).unwrap().instrs.len();
        }
        n
    });

    // MicroBlaze VM.
    bench("microblaze_matmul64", if fast { 3 } else { 10 }, || {
        baseline::run_verified(BenchId::MatMul, 64, 1, MbTiming::default())
            .unwrap()
            .cycles
    });

    println!("hot_path bench OK");
}
