//! `cargo bench --bench paper_figures` — regenerates Figures 4 and 5
//! (speedup vs MicroBlaze at size 256 for 1 and 2 SMs across 8/16/32
//! SPs) plus the §5.1.1 input-size sweep, with timing.

use flexgrip::harness::{bench, tables, Evaluation};
use flexgrip::kernels::PAPER_SIZES;

fn main() {
    println!("=== paper figure regeneration (measured | paper) ===\n");

    let _ = bench("fig4_1sm_speedups_size256", 1, || {
        let mut ev = Evaluation::new(256);
        tables::fig4(&mut ev).render()
    });
    let _ = bench("fig5_2sm_speedups_size256", 1, || {
        let mut ev = Evaluation::new(256);
        tables::fig5(&mut ev).render()
    });
    let _ = bench("input_size_sweep", 1, || tables::sweep(&PAPER_SIZES).render());

    println!();
    let mut ev = Evaluation::new(256);
    println!("{}", tables::fig4(&mut ev).render());
    println!("{}", tables::fig5(&mut ev).render());
    println!("{}", tables::sweep(&PAPER_SIZES).render());
    println!("paper_figures bench OK");
}
