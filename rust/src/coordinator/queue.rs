//! The fleet's submit path: a bounded, shutdown-aware, work-stealing
//! queue sharded across per-worker deques.
//!
//! The PR-5 coordinator funneled every variant's submits and pops through
//! one `Mutex<VecDeque>` guarded by two `Condvar`s — correct, but every
//! submitter and every shard contended on the same lock word, so the
//! service plane stopped scaling past a few cores. This module keeps the
//! exact external semantics (bounded depth, blocking and deadline'd
//! pushes, shutdown wakeups, drain-after-shutdown) while splitting the
//! storage into per-worker shards:
//!
//! * **Capacity is a single atomic**, not a lock: `push` reserves a slot
//!   with a CAS on `len` and only then touches a shard mutex — two
//!   submitters racing for different shards never serialize on storage.
//! * **Pushes round-robin across shards**; each worker pops its own
//!   shard first and **steals** from its siblings (scan order
//!   `own, own+1, …`) when its deque is dry — an idle worker takes the
//!   next job the moment one exists anywhere in its group.
//! * **Blocking is the slow path only**: the `gate` mutex + condvar pair
//!   is touched when a pusher finds the queue full, a popper finds it
//!   empty, or a state change must wake them. Notifies happen with the
//!   gate held and waiters re-check `len`/`shutdown` under the gate
//!   before sleeping, so wakeups cannot be lost.
//!
//! One protocol subtlety: a pusher that reserved a slot publishes the
//! item with only a shard lock held, so a popper can observe `len > 0`
//! while every shard looks empty (the reserve→push window). The popper
//! treats that as "work is imminent" and spins with `yield_now` instead
//! of sleeping — the window is a few instructions long and contains no
//! blocking.
//!
//! Shutdown ordering mirrors the old queue: `shutdown()` beats a
//! concurrent deadline (a blocked pusher whose timeout and the shutdown
//! race resolves `Shutdown`, not `Timeout`), queued items still drain
//! (poppers return `None` only once shut down *and* empty), and
//! [`ShardedQueue::push_unbounded`] bypasses both depth and shutdown for
//! the coordinator's retry re-admission — a worker must never block or
//! drop a job it is holding.
//!
//! Two QoS-era entry points sit beside `push`/`pop` with the same
//! protocol: [`ShardedQueue::push_with`] defers item construction until
//! a slot is reserved (so an enqueue timestamp measures queue residency,
//! not submit-side blocking), and [`ShardedQueue::try_pop_for`] is a
//! deadline'd pop that lets an elastic worker notice a retire flag while
//! its queue is idle.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a bounded push did not enqueue; the item (or, for
/// [`ShardedQueue::push_with`], the deferred constructor) comes back to
/// the caller.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue shut down before a slot opened (or was already down).
    Shutdown(T),
    /// The deadline elapsed with the queue still full.
    Timeout(T),
}

/// Why a slot reservation failed (internal: `push`/`push_with` translate
/// this into [`PushError`] with the payload attached).
enum ReserveError {
    Shutdown,
    Timeout,
}

/// Outcome of a timed pop ([`ShardedQueue::try_pop_for`]).
#[derive(Debug)]
pub enum Popped<T> {
    Item(T),
    /// Still live, but nothing arrived within the timeout.
    Empty,
    /// Shut down *and* fully drained — the worker can exit.
    Closed,
}

/// Bounded multi-producer multi-consumer queue, sharded into per-worker
/// deques with work stealing. See the module docs for the protocol.
pub struct ShardedQueue<T> {
    shards: Vec<Mutex<VecDeque<T>>>,
    /// Items reserved or resident across all shards (may transiently
    /// exceed any shard-sum observation — see module docs).
    len: AtomicUsize,
    shutdown: AtomicBool,
    /// Round-robin push cursor.
    rr: AtomicUsize,
    /// Slow-path rendezvous: waiters sleep here, state changes notify
    /// here. Guards no data — `len`/`shutdown` are the state.
    gate: Mutex<()>,
    not_empty: Condvar,
    not_full: Condvar,
    depth: usize,
}

impl<T> ShardedQueue<T> {
    /// `shards` deques (≥1 forced) holding at most `depth` items total.
    pub fn new(shards: usize, depth: usize) -> ShardedQueue<T> {
        let shards = shards.max(1);
        ShardedQueue {
            shards: (0..shards).map(|_| Mutex::new(VecDeque::new())).collect(),
            len: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            rr: AtomicUsize::new(0),
            gate: Mutex::new(()),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            depth: depth.max(1),
        }
    }

    pub fn len(&self) -> usize {
        self.len.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Deposit a reserved item and wake one popper.
    fn publish(&self, item: T) {
        let s = self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        self.shards[s].lock().expect("shard poisoned").push_back(item);
        let _gate = self.gate.lock().expect("gate poisoned");
        self.not_empty.notify_one();
    }

    /// Reserve one capacity slot, blocking while the queue is at depth
    /// (until `deadline` when one is given). On `Ok` the caller *must*
    /// publish exactly one item. Shutdown wins every race — a full queue
    /// that shuts down resolves `Shutdown` even if the deadline expired
    /// in the same instant (matching the PR-5 single-queue semantics).
    fn reserve(&self, deadline: Option<Instant>) -> Result<(), ReserveError> {
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                return Err(ReserveError::Shutdown);
            }
            let cur = self.len.load(Ordering::SeqCst);
            if cur < self.depth {
                // Fast path: reserve a slot without any lock.
                if self
                    .len
                    .compare_exchange(cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
                {
                    return Ok(());
                }
                continue; // lost the CAS race — re-read
            }
            // Full: take the gate and re-check before sleeping (a pop or
            // shutdown between our load and the lock must not be missed).
            let gate = self.gate.lock().expect("gate poisoned");
            if self.shutdown.load(Ordering::SeqCst) {
                return Err(ReserveError::Shutdown);
            }
            if self.len.load(Ordering::SeqCst) < self.depth {
                continue; // drained while we took the gate — retry the CAS
            }
            match deadline {
                None => {
                    drop(self.not_full.wait(gate).expect("gate poisoned"));
                }
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        return Err(ReserveError::Timeout);
                    }
                    let (gate, timed_out) = self
                        .not_full
                        .wait_timeout(gate, d - now)
                        .expect("gate poisoned");
                    drop(gate);
                    if timed_out.timed_out()
                        && !self.shutdown.load(Ordering::SeqCst)
                        && self.len.load(Ordering::SeqCst) >= self.depth
                    {
                        return Err(ReserveError::Timeout);
                    }
                }
            }
        }
    }

    /// Bounded push: blocks while the queue is at depth (until `deadline`
    /// when one is given). See [`ShardedQueue::push_with`] when the item
    /// must be constructed only once a slot exists.
    pub fn push(&self, item: T, deadline: Option<Instant>) -> Result<(), PushError<T>> {
        match self.reserve(deadline) {
            Ok(()) => {
                self.publish(item);
                Ok(())
            }
            Err(ReserveError::Shutdown) => Err(PushError::Shutdown(item)),
            Err(ReserveError::Timeout) => Err(PushError::Timeout(item)),
        }
    }

    /// Bounded push with deferred construction: `make` runs only *after*
    /// a capacity slot is reserved, so anything it stamps (e.g. an
    /// enqueue timestamp) reflects actual queue entry, not submit-side
    /// backpressure blocking. On failure the unused constructor comes
    /// back to the caller.
    pub fn push_with<F>(&self, make: F, deadline: Option<Instant>) -> Result<(), PushError<F>>
    where
        F: FnOnce() -> T,
    {
        match self.reserve(deadline) {
            Ok(()) => {
                self.publish(make());
                Ok(())
            }
            Err(ReserveError::Shutdown) => Err(PushError::Shutdown(make)),
            Err(ReserveError::Timeout) => Err(PushError::Timeout(make)),
        }
    }

    /// Unbounded push: ignores depth *and* shutdown. The coordinator's
    /// retry path re-admits a job a worker is already holding — blocking
    /// on a full queue (possibly the worker's own) would deadlock, and a
    /// draining queue must still accept it so the ticket resolves.
    pub fn push_unbounded(&self, item: T) {
        self.len.fetch_add(1, Ordering::SeqCst);
        self.publish(item);
    }

    /// Pop for worker `shard`: its own deque first, then steal from
    /// siblings in ring order. Blocks while the queue is empty and live;
    /// returns `None` only once shut down *and* drained.
    pub fn pop(&self, shard: usize) -> Option<T> {
        loop {
            for i in 0..self.shards.len() {
                let s = (shard + i) % self.shards.len();
                let item = self.shards[s].lock().expect("shard poisoned").pop_front();
                if let Some(item) = item {
                    self.len.fetch_sub(1, Ordering::SeqCst);
                    let _gate = self.gate.lock().expect("gate poisoned");
                    self.not_full.notify_one();
                    return Some(item);
                }
            }
            let gate = self.gate.lock().expect("gate poisoned");
            if self.len.load(Ordering::SeqCst) > 0 {
                // Reserved but not yet published (or a racing push landed
                // after our scan): the item is an instruction away — spin,
                // don't sleep on a notify that may already have fired.
                drop(gate);
                std::thread::yield_now();
                continue;
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            drop(self.not_empty.wait(gate).expect("gate poisoned"));
        }
    }

    /// [`ShardedQueue::pop`] with a patience bound: blocks at most
    /// `timeout` before reporting [`Popped::Empty`]. Elastic workers poll
    /// with this instead of `pop` so a retire flag flipped while the
    /// queue is idle is noticed within one poll interval; `Closed` keeps
    /// the drain-after-shutdown contract (`Item` until empty).
    pub fn try_pop_for(&self, shard: usize, timeout: Duration) -> Popped<T> {
        let deadline = Instant::now() + timeout;
        loop {
            for i in 0..self.shards.len() {
                let s = (shard + i) % self.shards.len();
                let item = self.shards[s].lock().expect("shard poisoned").pop_front();
                if let Some(item) = item {
                    self.len.fetch_sub(1, Ordering::SeqCst);
                    let _gate = self.gate.lock().expect("gate poisoned");
                    self.not_full.notify_one();
                    return Popped::Item(item);
                }
            }
            let gate = self.gate.lock().expect("gate poisoned");
            if self.len.load(Ordering::SeqCst) > 0 {
                // Reserved-but-unpublished window — spin like `pop`, but
                // bounded by the deadline.
                drop(gate);
                if Instant::now() >= deadline {
                    return Popped::Empty;
                }
                std::thread::yield_now();
                continue;
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return Popped::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Popped::Empty;
            }
            drop(
                self.not_empty
                    .wait_timeout(gate, deadline - now)
                    .expect("gate poisoned"),
            );
        }
    }

    /// Stop intake: blocked pushers wake with [`PushError::Shutdown`],
    /// poppers drain what is queued and then get `None`. Idempotent.
    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _gate = self.gate.lock().expect("gate poisoned");
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_within_a_single_shard() {
        let q = ShardedQueue::new(1, 16);
        for i in 0..5 {
            q.push(i, None).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop(0), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn any_worker_reaches_items_on_any_shard() {
        // 4 shards, pushes round-robin: a single worker (fixed home
        // shard) must still drain everything by stealing.
        let q = ShardedQueue::new(4, 64);
        for i in 0..12 {
            q.push(i, None).unwrap();
        }
        let mut got: Vec<i32> = (0..12).map(|_| q.pop(2).unwrap()).collect();
        got.sort_unstable();
        assert_eq!(got, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn deadline_push_sheds_when_full() {
        let q = ShardedQueue::new(2, 2);
        q.push(1, None).unwrap();
        q.push(2, None).unwrap();
        let deadline = Instant::now() + Duration::from_millis(30);
        match q.push(3, Some(deadline)) {
            Err(PushError::Timeout(item)) => assert_eq!(item, 3),
            other => panic!("expected Timeout, got {other:?}"),
        }
        // Draining one slot lets the next deadline'd push through.
        assert!(q.pop(0).is_some());
        q.push(3, Some(Instant::now() + Duration::from_secs(5))).unwrap();
    }

    #[test]
    fn shutdown_wakes_blocked_pusher() {
        let q = Arc::new(ShardedQueue::new(2, 1));
        q.push(0, None).unwrap();
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || q2.push(1, None));
        std::thread::sleep(Duration::from_millis(50));
        q.shutdown();
        match pusher.join().unwrap() {
            Err(PushError::Shutdown(item)) => assert_eq!(item, 1),
            other => panic!("expected Shutdown, got {other:?}"),
        }
    }

    #[test]
    fn shutdown_beats_a_far_deadline() {
        // A pusher blocked with a generous deadline must resolve Shutdown
        // (not Timeout) when the queue goes down first.
        let q = Arc::new(ShardedQueue::new(1, 1));
        q.push(0, None).unwrap();
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || {
            q2.push(1, Some(Instant::now() + Duration::from_secs(30)))
        });
        std::thread::sleep(Duration::from_millis(50));
        q.shutdown();
        match pusher.join().unwrap() {
            Err(PushError::Shutdown(item)) => assert_eq!(item, 1),
            other => panic!("expected Shutdown, got {other:?}"),
        }
    }

    #[test]
    fn queued_items_drain_after_shutdown() {
        let q = ShardedQueue::new(3, 16);
        for i in 0..6 {
            q.push(i, None).unwrap();
        }
        q.shutdown();
        assert!(matches!(q.push(99, None), Err(PushError::Shutdown(99))));
        let mut got: Vec<i32> = (0..6).map(|_| q.pop(1).unwrap()).collect();
        got.sort_unstable();
        assert_eq!(got, (0..6).collect::<Vec<_>>());
        assert_eq!(q.pop(0), None);
        assert_eq!(q.pop(2), None);
    }

    #[test]
    fn unbounded_push_bypasses_depth_and_shutdown() {
        let q = ShardedQueue::new(2, 1);
        q.push(0, None).unwrap();
        q.push_unbounded(1); // over depth
        q.shutdown();
        q.push_unbounded(2); // into a draining queue
        let mut got: Vec<i32> = (0..3).map(|_| q.pop(0).unwrap()).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2]);
        assert_eq!(q.pop(0), None);
    }

    #[test]
    fn push_with_constructs_the_item_only_after_a_slot_opens() {
        // The queue-wait bugfix contract: a submitter blocked on a full
        // queue must not have its item (and its enqueue timestamp) built
        // until capacity actually opens.
        let q = Arc::new(ShardedQueue::new(1, 1));
        q.push(Instant::now(), None).unwrap();
        let q2 = q.clone();
        let pusher = std::thread::spawn(move || q2.push_with(Instant::now, None));
        std::thread::sleep(Duration::from_millis(60));
        let drained_at = Instant::now();
        assert!(q.pop(0).is_some());
        assert!(pusher.join().unwrap().is_ok(), "push_with succeeds once drained");
        match q.pop(0) {
            Some(stamped) => assert!(
                stamped >= drained_at,
                "item was constructed while the submitter was still blocked"
            ),
            None => panic!("the deferred item must be queued"),
        }
    }

    #[test]
    fn push_with_hands_the_constructor_back_on_shutdown() {
        let q: ShardedQueue<i32> = ShardedQueue::new(1, 1);
        q.push(1, None).unwrap();
        q.shutdown();
        match q.push_with(|| 2, None) {
            Err(PushError::Shutdown(make)) => assert_eq!(make(), 2),
            Err(PushError::Timeout(_)) => panic!("no deadline was set"),
            Ok(()) => panic!("push into a shut-down queue must fail"),
        }
    }

    #[test]
    fn try_pop_for_reports_empty_then_item_then_closed() {
        let q: ShardedQueue<i32> = ShardedQueue::new(2, 4);
        assert!(matches!(q.try_pop_for(0, Duration::from_millis(5)), Popped::Empty));
        q.push(7, None).unwrap();
        // Steal path: home shard 1 may be dry, the item still arrives.
        assert!(matches!(q.try_pop_for(1, Duration::from_millis(5)), Popped::Item(7)));
        q.shutdown();
        assert!(matches!(q.try_pop_for(0, Duration::from_millis(5)), Popped::Closed));
    }

    #[test]
    fn try_pop_for_drains_queued_items_before_closing() {
        let q: ShardedQueue<i32> = ShardedQueue::new(1, 4);
        q.push(1, None).unwrap();
        q.shutdown();
        assert!(matches!(q.try_pop_for(0, Duration::from_millis(5)), Popped::Item(1)));
        assert!(matches!(q.try_pop_for(0, Duration::from_millis(5)), Popped::Closed));
    }

    #[test]
    fn steal_vs_drain_race_loses_nothing() {
        // Many workers stealing across shards while shutdown lands
        // mid-stream: every item is popped exactly once, every worker
        // exits with None.
        const ITEMS: usize = 2000;
        const WORKERS: usize = 8;
        let q = Arc::new(ShardedQueue::new(WORKERS, ITEMS));
        let got = Arc::new(Mutex::new(Vec::new()));
        let workers: Vec<_> = (0..WORKERS)
            .map(|w| {
                let q = q.clone();
                let got = got.clone();
                std::thread::spawn(move || {
                    while let Some(item) = q.pop(w) {
                        got.lock().unwrap().push(item);
                    }
                })
            })
            .collect();
        let mut pushed = 0usize;
        for i in 0..ITEMS {
            if i == ITEMS / 2 {
                // Shut down with half the stream in flight and workers
                // mid-pop: the rest of the pushes must bounce, the queued
                // half must all land exactly once.
                q.shutdown();
            }
            match q.push(i, None) {
                Ok(()) => pushed += 1,
                Err(PushError::Shutdown(item)) => assert_eq!(item, i),
                Err(PushError::Timeout(_)) => panic!("no deadline was set"),
            }
        }
        for w in workers {
            w.join().unwrap();
        }
        let mut got = Arc::try_unwrap(got).unwrap().into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got.len(), pushed, "every accepted item popped");
        got.dedup();
        assert_eq!(got.len(), pushed, "no item popped twice");
        assert!(q.is_empty());
    }

    #[test]
    fn concurrent_pushers_and_poppers_balance() {
        // 4 pushers × 250 items through a shallow (depth 8) 4-shard queue
        // against 4 poppers: backpressure engages constantly and the
        // multiset in == multiset out.
        const PER: usize = 250;
        let q = Arc::new(ShardedQueue::new(4, 8));
        let got = Arc::new(Mutex::new(Vec::new()));
        let poppers: Vec<_> = (0..4)
            .map(|w| {
                let q = q.clone();
                let got = got.clone();
                std::thread::spawn(move || {
                    while let Some(item) = q.pop(w) {
                        got.lock().unwrap().push(item);
                    }
                })
            })
            .collect();
        let pushers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..PER {
                        q.push(p * PER + i, None).unwrap();
                    }
                })
            })
            .collect();
        for p in pushers {
            p.join().unwrap();
        }
        // Wait for the queue to drain, then release the poppers.
        while !q.is_empty() {
            std::thread::yield_now();
        }
        q.shutdown();
        for w in poppers {
            w.join().unwrap();
        }
        let mut got = Arc::try_unwrap(got).unwrap().into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..4 * PER).collect::<Vec<_>>());
    }
}
