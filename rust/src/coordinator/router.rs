//! QoS-aware admission routing: the decision function and its
//! observability counters.
//!
//! The PR-3 router picked the lowest-modeled-dynamic-power covering
//! variant *statically* — it never consulted queue depth or in-flight
//! work, so a saturated cheap variant shed `Saturated` while costlier
//! covering variants sat idle, and equal-power ties pinned all traffic
//! to the lower variant index. [`decide`] replaces it with a two-phase
//! scheme over live signals ([`VariantSignals`]: queue depth, in-flight
//! jobs, modeled dynamic power, shard health):
//!
//! 1. **Unpressured** (the common case): route exactly like the static
//!    router — cheapest covering variant by modeled power — except that
//!    bit-equal power ties spread **round-robin** instead of pinning,
//!    and variants with zero healthy (live, non-quarantined) shards are
//!    skipped while a healthy alternative exists. A fleet with one
//!    covering variant short-circuits before any signal is read, so
//!    homogeneous pools are bit-identical to the static path.
//! 2. **Pressured**: once the preferred variant's utilization crosses
//!    the job's class-specific spill threshold, every eligible variant
//!    is rescored as `w_load · u/(1−u) + w_power · (P/P_min)` and the
//!    cheapest *score* wins — an M/M/1-shaped congestion term against a
//!    normalized power term, weighted per [`QosClass`].
//!
//! The class also gates admission: a `Latency` job whose every covering
//! variant is saturated or unhealthy reports `gated`, which the
//! coordinator turns into an immediate `Saturated` shed for deadline'd
//! submits instead of burning the deadline blocked.
//!
//! Every decision lands in [`RoutingStats`] (lock-free atomics): routed
//! vs spilled vs tie-broken per variant, sheds, elastic scale events,
//! and per-class queue-wait histograms (log₂ buckets, geometric
//! interpolation for p50/p95) surfaced as [`RoutingSnapshot`] through
//! `GpgpuService::routing_stats()` / `service-demo` / `harness/qos.rs`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Per-job latency class: how much the router values queue slack vs
/// modeled power, and whether admission is gated when nothing healthy
/// has room.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QosClass {
    /// Interactive: spill early (threshold 0.5), weight congestion 8×
    /// over power, and shed immediately on a deadline'd submit when no
    /// healthy covering variant has queue slack.
    Latency,
    /// The default: balanced congestion/power weighting, spill at 0.75
    /// utilization.
    #[default]
    Throughput,
    /// Batch filler: stay on the cheapest variant until it is nearly
    /// saturated (0.95) — power efficiency dominates.
    BestEffort,
}

impl QosClass {
    pub const ALL: [QosClass; 3] = [QosClass::Latency, QosClass::Throughput, QosClass::BestEffort];

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Latency => "latency",
            QosClass::Throughput => "throughput",
            QosClass::BestEffort => "besteffort",
        }
    }

    pub(crate) fn index(self) -> usize {
        match self {
            QosClass::Latency => 0,
            QosClass::Throughput => 1,
            QosClass::BestEffort => 2,
        }
    }

    /// Signal weights (EXPERIMENTS.md §QoS carries the same table).
    fn weights(self) -> Weights {
        match self {
            QosClass::Latency => Weights { load: 4.0, power: 0.5, spill_util: 0.5 },
            QosClass::Throughput => Weights { load: 1.0, power: 1.0, spill_util: 0.75 },
            QosClass::BestEffort => Weights { load: 0.25, power: 2.0, spill_util: 0.95 },
        }
    }
}

/// How the fleet routes jobs to variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouterMode {
    /// The PR-3 behavior, kept as a measurable baseline: cheapest
    /// covering variant by modeled power, first index on ties, no load
    /// or health signals.
    Static,
    /// QoS scoring over live signals (the default).
    #[default]
    Qos,
}

struct Weights {
    load: f64,
    power: f64,
    /// Preferred-variant utilization at which the full rescore engages.
    spill_util: f64,
}

/// One variant's live state as the router sees it.
#[derive(Debug, Clone, Copy)]
pub(crate) struct VariantSignals {
    /// Capabilities cover the job's signature.
    pub covers: bool,
    /// Modeled dynamic power (W) — the static routing key.
    pub dyn_w: f64,
    /// Jobs waiting in the variant's queue.
    pub queued: usize,
    /// Jobs currently executing on the variant's shards.
    pub inflight: usize,
    /// Live shards not sitting out a quarantine.
    pub healthy: usize,
    /// The variant queue's capacity bound.
    pub depth: usize,
}

impl VariantSignals {
    /// Occupancy over total job slots (queue capacity + one executing
    /// job per healthy shard). A variant with no healthy shard is fully
    /// utilized by definition — queued work there waits on probation
    /// timers, not on compute.
    fn util(&self) -> f64 {
        if self.healthy == 0 {
            return 1.0;
        }
        let occ = (self.queued + self.inflight) as f64;
        (occ / (self.depth + self.healthy) as f64).min(1.0)
    }

    /// Room for one more job without blocking the submitter.
    fn slack(&self) -> bool {
        self.healthy > 0 && self.queued + self.inflight < self.depth + self.healthy
    }
}

/// How a routing decision diverged (or not) from the static choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum RouteKind {
    /// Same variant the static router would pick.
    Routed,
    /// A bit-equal power tie resolved by the round-robin cursor.
    TieBroken,
    /// Load or health moved the job off the static choice.
    Spilled,
}

#[derive(Debug, Clone, Copy)]
pub(crate) struct RouteDecision {
    pub target: usize,
    pub kind: RouteKind,
    /// No healthy covering variant had queue slack (meaningful for
    /// `Latency`: the coordinator sheds deadline'd submits immediately).
    pub gated: bool,
}

/// M/M/1-shaped congestion: u/(1−u), capped so a saturated variant is
/// expensive but still finitely comparable.
fn congestion(u: f64) -> f64 {
    const CAP: f64 = 15.0;
    if u >= CAP / (CAP + 1.0) {
        CAP
    } else {
        u / (1.0 - u)
    }
}

/// Pick the variant for one job. Pure over its inputs apart from the
/// round-robin tie cursor `rr`; the coordinator owns signal collection
/// and stats recording.
pub(crate) fn decide(
    mode: RouterMode,
    class: QosClass,
    signals: &[VariantSignals],
    fallback: usize,
    rr: &AtomicUsize,
) -> RouteDecision {
    let covering: Vec<usize> =
        (0..signals.len()).filter(|&i| signals[i].covers).collect();
    if covering.is_empty() {
        // Nothing covers: the most-capable variant's own launch admission
        // reports the structured `Unsupported` error.
        return RouteDecision { target: fallback, kind: RouteKind::Routed, gated: false };
    }
    // The choice the PR-3 static router would make: cheapest modeled
    // power, first index on bit-equal ties (`min_by` keeps the first
    // minimum) — the baseline every decision is classified against.
    let static_choice = *covering
        .iter()
        .min_by(|&&a, &&b| signals[a].dyn_w.total_cmp(&signals[b].dyn_w))
        .expect("covering is non-empty");
    if mode == RouterMode::Static || covering.len() == 1 {
        // Static mode, or a single covering variant (every homogeneous
        // pool): pure pass-through, no signals read, no tie to break.
        return RouteDecision { target: static_choice, kind: RouteKind::Routed, gated: false };
    }
    let w = class.weights();
    // Health (and, for Latency, slack) gate: skip variants that cannot
    // make progress. If that empties the candidate set, fall back to all
    // covering variants — routing somewhere beats routing nowhere — and
    // report the gate so deadline'd Latency submits can shed instead.
    let mut eligible: Vec<usize> = covering
        .iter()
        .copied()
        .filter(|&i| {
            signals[i].healthy > 0 && (class != QosClass::Latency || signals[i].slack())
        })
        .collect();
    let gated = eligible.is_empty();
    if gated {
        eligible = covering.clone();
    }
    let min_w = eligible
        .iter()
        .map(|&i| signals[i].dyn_w)
        .min_by(f64::total_cmp)
        .expect("eligible is non-empty");
    let ties: Vec<usize> = eligible
        .iter()
        .copied()
        .filter(|&i| signals[i].dyn_w.total_cmp(&min_w) == std::cmp::Ordering::Equal)
        .collect();
    let pick = if ties.len() > 1 {
        ties[rr.fetch_add(1, Ordering::Relaxed) % ties.len()]
    } else {
        ties[0]
    };
    // Spill phase: only once the preferred variant is pressured past the
    // class threshold does load enter the score — below it, routing is
    // exactly the static cheapest-power choice (plus RR on ties), which
    // keeps light-load fleets deterministic and inside the Table-6
    // energy envelope.
    let mut target = pick;
    let mut via_rescore = false;
    if signals[pick].util() >= w.spill_util {
        let score = |i: usize| {
            w.load * congestion(signals[i].util()) + w.power * (signals[i].dyn_w / min_w)
        };
        let best = eligible
            .iter()
            .copied()
            .min_by(|&a, &b| score(a).total_cmp(&score(b)))
            .expect("eligible is non-empty");
        if best != target {
            target = best;
            via_rescore = true;
        }
    }
    let kind = if target == static_choice {
        if ties.len() > 1 && !via_rescore {
            RouteKind::TieBroken
        } else {
            RouteKind::Routed
        }
    } else if !via_rescore && ties.contains(&static_choice) {
        // The static choice was in the tie set and the cursor went
        // elsewhere — a tie-break, not a load spill.
        RouteKind::TieBroken
    } else {
        RouteKind::Spilled
    };
    RouteDecision { target, kind, gated }
}

/// Number of log₂ wait buckets: bucket `i` holds waits in
/// `[2^i, 2^{i+1})` ns (bucket 0 also catches 0), bucket 39 is the
/// ~9-minute-plus overflow.
const WAIT_BUCKETS: usize = 40;

/// Per-class queue-wait histogram — log₂ buckets so recording is one
/// atomic increment on the dispatch path.
struct WaitHisto {
    buckets: [AtomicU64; WAIT_BUCKETS],
    count: AtomicU64,
}

impl WaitHisto {
    fn new() -> WaitHisto {
        WaitHisto {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
        }
    }

    fn record(&self, ns: u64) {
        let b = (ns.max(1).ilog2() as usize).min(WAIT_BUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    fn load(&self) -> ([u64; WAIT_BUCKETS], u64) {
        (
            std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            self.count.load(Ordering::Relaxed),
        )
    }
}

/// Quantile from a log₂ histogram, geometrically interpolated within the
/// landing bucket (so a p95 shift well under one bucket width is still
/// visible to the bench-regression gate).
fn quantile(buckets: &[u64; WAIT_BUCKETS], count: u64, q: f64) -> u64 {
    if count == 0 {
        return 0;
    }
    let target = ((q * count as f64).ceil() as u64).clamp(1, count);
    let mut cum = 0u64;
    for (i, &b) in buckets.iter().enumerate() {
        if b == 0 {
            continue;
        }
        cum += b;
        if cum >= target {
            let lower = 1u64 << i;
            let into = (target - (cum - b)) as f64 / b as f64; // (0, 1]
            return (lower as f64 * 2f64.powf(into)) as u64;
        }
    }
    1u64 << (WAIT_BUCKETS - 1)
}

struct VariantCounters {
    routed: AtomicU64,
    spilled: AtomicU64,
    tie_broken: AtomicU64,
    shed: AtomicU64,
}

/// Lock-free admission/rebalance observability, owned by the fleet.
pub(crate) struct RoutingStats {
    variants: Vec<VariantCounters>,
    pub(crate) scale_ups: AtomicU64,
    pub(crate) scale_downs: AtomicU64,
    waits: [WaitHisto; 3],
    rr: AtomicUsize,
}

impl RoutingStats {
    pub(crate) fn new(variants: usize) -> RoutingStats {
        RoutingStats {
            variants: (0..variants)
                .map(|_| VariantCounters {
                    routed: AtomicU64::new(0),
                    spilled: AtomicU64::new(0),
                    tie_broken: AtomicU64::new(0),
                    shed: AtomicU64::new(0),
                })
                .collect(),
            scale_ups: AtomicU64::new(0),
            scale_downs: AtomicU64::new(0),
            waits: [WaitHisto::new(), WaitHisto::new(), WaitHisto::new()],
            rr: AtomicUsize::new(0),
        }
    }

    pub(crate) fn rr(&self) -> &AtomicUsize {
        &self.rr
    }

    /// Count an *admitted* decision (sheds are recorded separately).
    pub(crate) fn record_decision(&self, target: usize, kind: RouteKind) {
        let c = &self.variants[target];
        match kind {
            RouteKind::Routed => c.routed.fetch_add(1, Ordering::Relaxed),
            RouteKind::Spilled => c.spilled.fetch_add(1, Ordering::Relaxed),
            RouteKind::TieBroken => c.tie_broken.fetch_add(1, Ordering::Relaxed),
        };
    }

    /// Count a job shed as `Saturated` (admission gate or queue timeout)
    /// against the variant it would have landed on.
    pub(crate) fn record_shed(&self, target: usize) {
        self.variants[target].shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one dispatched job's queue residency under its class.
    pub(crate) fn record_wait(&self, class: QosClass, ns: u64) {
        self.waits[class.index()].record(ns);
    }

    pub(crate) fn snapshot(&self, labels: &[String]) -> RoutingSnapshot {
        let variants = labels
            .iter()
            .zip(&self.variants)
            .map(|(label, c)| VariantRouting {
                label: label.clone(),
                routed: c.routed.load(Ordering::Relaxed),
                spilled: c.spilled.load(Ordering::Relaxed),
                tie_broken: c.tie_broken.load(Ordering::Relaxed),
                shed: c.shed.load(Ordering::Relaxed),
            })
            .collect();
        let mut merged = [0u64; WAIT_BUCKETS];
        let mut merged_count = 0u64;
        let classes = std::array::from_fn(|i| {
            let (buckets, count) = self.waits[i].load();
            for (m, b) in merged.iter_mut().zip(buckets.iter()) {
                *m += b;
            }
            merged_count += count;
            WaitQuantiles {
                jobs: count,
                p50_ns: quantile(&buckets, count, 0.50),
                p95_ns: quantile(&buckets, count, 0.95),
            }
        });
        RoutingSnapshot {
            variants,
            scale_ups: self.scale_ups.load(Ordering::Relaxed),
            scale_downs: self.scale_downs.load(Ordering::Relaxed),
            classes,
            overall: WaitQuantiles {
                jobs: merged_count,
                p50_ns: quantile(&merged, merged_count, 0.50),
                p95_ns: quantile(&merged, merged_count, 0.95),
            },
        }
    }
}

/// Admission counters for one variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VariantRouting {
    pub label: String,
    /// Jobs admitted on the static-equivalent choice.
    pub routed: u64,
    /// Jobs moved off the static choice by load or health.
    pub spilled: u64,
    /// Jobs landed here by round-robin among bit-equal power ties.
    pub tie_broken: u64,
    /// Jobs shed as `Saturated` that were headed here.
    pub shed: u64,
}

impl VariantRouting {
    /// Total jobs admitted to this variant.
    pub fn admitted(&self) -> u64 {
        self.routed + self.spilled + self.tie_broken
    }
}

/// Queue-wait quantiles for one latency class (or the merged fleet).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct WaitQuantiles {
    pub jobs: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
}

/// Point-in-time routing/rebalancing report
/// (`GpgpuService::routing_stats()`).
#[derive(Debug, Clone, PartialEq)]
pub struct RoutingSnapshot {
    pub variants: Vec<VariantRouting>,
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Indexed like [`QosClass::ALL`].
    pub classes: [WaitQuantiles; 3],
    pub overall: WaitQuantiles,
}

impl RoutingSnapshot {
    pub fn class(&self, class: QosClass) -> WaitQuantiles {
        self.classes[class.index()]
    }

    /// Fleet-wide spilled jobs.
    pub fn spilled(&self) -> u64 {
        self.variants.iter().map(|v| v.spilled).sum()
    }

    /// Fleet-wide tie-broken jobs.
    pub fn tie_broken(&self) -> u64 {
        self.variants.iter().map(|v| v.tie_broken).sum()
    }

    /// Fleet-wide sheds.
    pub fn shed(&self) -> u64 {
        self.variants.iter().map(|v| v.shed).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle(dyn_w: f64) -> VariantSignals {
        VariantSignals { covers: true, dyn_w, queued: 0, inflight: 0, healthy: 1, depth: 4 }
    }

    #[test]
    fn single_covering_variant_is_pure_pass_through() {
        let rr = AtomicUsize::new(0);
        let mut sick = idle(1.0);
        sick.healthy = 0; // even an unhealthy sole variant is the target
        let d = decide(RouterMode::Qos, QosClass::Latency, &[sick], 0, &rr);
        assert_eq!(d.target, 0);
        assert_eq!(d.kind, RouteKind::Routed);
        assert_eq!(rr.load(Ordering::Relaxed), 0, "no signal consulted");
    }

    #[test]
    fn uncovered_signature_lands_on_the_fallback() {
        let rr = AtomicUsize::new(0);
        let mut s = idle(1.0);
        s.covers = false;
        let d = decide(RouterMode::Qos, QosClass::Throughput, &[s, s], 1, &rr);
        assert_eq!(d.target, 1);
    }

    #[test]
    fn static_mode_pins_the_first_minimum_on_ties() {
        let rr = AtomicUsize::new(0);
        let signals = [idle(1.0), idle(1.0)];
        for _ in 0..8 {
            let d = decide(RouterMode::Static, QosClass::Throughput, &signals, 0, &rr);
            assert_eq!(d.target, 0, "static ties pin to the lower index");
            assert_eq!(d.kind, RouteKind::Routed);
        }
    }

    #[test]
    fn qos_mode_spreads_bit_equal_ties_round_robin() {
        let rr = AtomicUsize::new(0);
        let signals = [idle(1.0), idle(1.0)];
        let picks: Vec<usize> = (0..6)
            .map(|_| {
                let d = decide(RouterMode::Qos, QosClass::Throughput, &signals, 0, &rr);
                assert_eq!(d.kind, RouteKind::TieBroken);
                d.target
            })
            .collect();
        assert_eq!(picks, vec![0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn unhealthy_cheap_variant_spills_to_the_healthy_one() {
        let rr = AtomicUsize::new(0);
        let mut sick = idle(1.0);
        sick.healthy = 0;
        let healthy = idle(1.5);
        let d = decide(RouterMode::Qos, QosClass::Throughput, &[sick, healthy], 1, &rr);
        assert_eq!(d.target, 1);
        assert_eq!(d.kind, RouteKind::Spilled);
        assert!(!d.gated);
    }

    #[test]
    fn saturated_cheap_variant_spills_to_the_idle_costlier_one() {
        let rr = AtomicUsize::new(0);
        let mut busy = idle(1.0);
        busy.queued = 4; // depth 4, 1 healthy shard -> util 0.8
        let d = decide(RouterMode::Qos, QosClass::Throughput, &[busy, idle(1.5)], 1, &rr);
        assert_eq!(d.target, 1);
        assert_eq!(d.kind, RouteKind::Spilled);
    }

    #[test]
    fn besteffort_rides_the_cheap_variant_through_moderate_load() {
        // Same pressure as above, but BestEffort's 0.95 spill threshold
        // keeps it on the power-optimal variant where Latency leaves.
        let rr = AtomicUsize::new(0);
        let mut busy = idle(1.0);
        busy.queued = 4;
        let be = decide(RouterMode::Qos, QosClass::BestEffort, &[busy, idle(1.5)], 1, &rr);
        assert_eq!(be.target, 0);
        assert_eq!(be.kind, RouteKind::Routed);
        let lat = decide(RouterMode::Qos, QosClass::Latency, &[busy, idle(1.5)], 1, &rr);
        assert_eq!(lat.target, 1);
    }

    #[test]
    fn latency_gate_reports_when_nothing_healthy_has_slack() {
        let rr = AtomicUsize::new(0);
        let mut full = idle(1.0);
        full.queued = 4;
        full.inflight = 1; // occupancy 5 == depth 4 + 1 healthy -> no slack
        let d = decide(RouterMode::Qos, QosClass::Latency, &[full, full], 0, &rr);
        assert!(d.gated);
        // Throughput only gates on health, not slack.
        let d = decide(RouterMode::Qos, QosClass::Throughput, &[full, full], 0, &rr);
        assert!(!d.gated);
    }

    #[test]
    fn wait_histogram_quantiles_interpolate_geometrically() {
        let stats = RoutingStats::new(1);
        for _ in 0..90 {
            stats.record_wait(QosClass::Throughput, 1_000);
        }
        for _ in 0..10 {
            stats.record_wait(QosClass::Throughput, 1_000_000);
        }
        let snap = stats.snapshot(&["v".to_string()]);
        let q = snap.class(QosClass::Throughput);
        assert_eq!(q.jobs, 100);
        // p50 lands in the 1000ns bucket [512, 1024), p95 in the 1M
        // bucket [2^19, 2^20); geometric interpolation keeps both inside.
        assert!((512..2048).contains(&q.p50_ns), "p50 {} out of bucket", q.p50_ns);
        assert!((524_288..2_097_152).contains(&q.p95_ns), "p95 {} out of bucket", q.p95_ns);
        assert!(q.p95_ns > q.p50_ns);
        assert_eq!(snap.overall.jobs, 100);
        assert_eq!(snap.class(QosClass::Latency).jobs, 0);
    }

    #[test]
    fn decision_counters_split_by_kind() {
        let stats = RoutingStats::new(2);
        stats.record_decision(0, RouteKind::Routed);
        stats.record_decision(0, RouteKind::Routed);
        stats.record_decision(1, RouteKind::Spilled);
        stats.record_decision(1, RouteKind::TieBroken);
        stats.record_shed(0);
        let snap = stats.snapshot(&["a".to_string(), "b".to_string()]);
        assert_eq!(snap.variants[0].routed, 2);
        assert_eq!(snap.variants[0].shed, 1);
        assert_eq!(snap.variants[1].spilled, 1);
        assert_eq!(snap.variants[1].tie_broken, 1);
        assert_eq!(snap.variants[1].admitted(), 2);
        assert_eq!(snap.spilled(), 1);
        assert_eq!(snap.tie_broken(), 1);
        assert_eq!(snap.shed(), 1);
    }
}
