//! Application-class customization analyzer (paper §4.2, §5.2).
//!
//! "By performing an instruction analysis, we can determine the minimal
//! set of functions needed to support each benchmark" — the *static* half
//! of that analysis is the ISA-layer [`CapabilitySignature`] (shared with
//! the assembler, launch admission, and the fleet router); this module
//! adds the *dynamic* half ("profiling the application with
//! representative data sets", §4.1): a baseline run measuring the
//! warp-stack high-water mark and the dynamic multiplier usage. It then
//! recommends the minimal FlexGrip variant and quantifies the Table-6
//! area/energy savings with the implementation models.

use crate::asm::Kernel;
use crate::gpgpu::{Gpgpu, GpgpuConfig};
use crate::isa::CapabilitySignature;
use crate::kernels::{self, BenchId, RunOptions};
use crate::model::{area::area, power::power, ArchParams};
use crate::sim::{NativeAlu, SimError};

/// Static instruction analysis of an assembled kernel — the ISA-layer
/// capability signature (kept as a free function for API continuity; the
/// registry caches the same value per kernel).
pub fn analyze_kernel(k: &Kernel) -> CapabilitySignature {
    k.signature()
}

/// A customization recommendation with its modelled savings.
#[derive(Debug, Clone)]
pub struct CustomizationReport {
    pub bench: BenchId,
    pub n: u32,
    /// Static capability signature of the kernel binary.
    pub sig: CapabilitySignature,
    pub instruction_count: usize,
    /// Warp-stack high-water mark measured by the profiling run.
    pub measured_stack_depth: u32,
    /// Dynamic IMUL/IMAD count from the profiling run.
    pub multiplier_ops: u64,
    pub recommended: ArchParams,
    pub lut_reduction_pct: f64,
    pub dynamic_power_reduction_pct: f64,
}

impl CustomizationReport {
    /// The profile-refined signature: measured stack depth replaces the
    /// static bound, a dynamically-idle multiplier is dropped. This is
    /// what the coordinator registers with its fleet router.
    pub fn refined_signature(&self) -> CapabilitySignature {
        self.sig.refined(self.measured_stack_depth, self.multiplier_ops)
    }

    /// The recommended variant as a launchable device configuration
    /// (1 SM; multiplier removal also drops the third read-operand unit,
    /// §5.2).
    pub fn recommended_config(&self) -> GpgpuConfig {
        let mut cfg = GpgpuConfig::new(self.recommended.num_sms, self.recommended.num_sp);
        cfg.sm.warp_stack_depth = self.recommended.warp_stack_depth;
        cfg.sm.has_multiplier = self.recommended.has_multiplier;
        if !self.recommended.has_multiplier {
            cfg.sm.read_operands = 2;
        }
        cfg
    }
}

/// Profile `bench` at size `n` on the baseline 1 SM / 8 SP FlexGrip and
/// derive the minimal configuration (paper §5.2 methodology).
pub fn profile(bench: BenchId, n: u32, seed: u64) -> Result<CustomizationReport, SimError> {
    let workload = kernels::prepare(bench, n, seed);
    let sig = workload.kernel.sig;
    let instruction_count = workload.kernel.instrs.len();

    let gpgpu = Gpgpu::new(GpgpuConfig::new(1, 8));
    let mut gmem = workload.make_gmem();
    let run = workload.run(&gpgpu, &mut gmem, RunOptions::default())?;
    if let Err(e) = workload.verify(&gmem) {
        return Err(SimError::LimitExceeded(format!("profiling run invalid: {e}")));
    }

    let needs_mul = sig.uses_multiplier && run.stats.multiplier_ops() > 0;
    let recommended = ArchParams {
        num_sms: 1,
        num_sp: 8,
        warp_stack_depth: run.stats.max_stack_depth,
        has_multiplier: needs_mul,
        l1: None,
    };
    let base = ArchParams::baseline();
    let lut_red = area(&recommended).lut_reduction_pct(&area(&base));
    let dyn_red =
        100.0 * (1.0 - power(&recommended).dynamic_w / power(&base).dynamic_w);
    Ok(CustomizationReport {
        bench,
        n,
        sig,
        instruction_count,
        measured_stack_depth: run.stats.max_stack_depth,
        multiplier_ops: run.stats.multiplier_ops(),
        recommended,
        lut_reduction_pct: lut_red,
        dynamic_power_reduction_pct: dyn_red,
    })
}

/// Re-run the benchmark on the *recommended* configuration to prove the
/// customized hardware still executes it (the paper's embedded-bitstream
/// scenario: the right variant must be functionally sufficient).
pub fn validate(report: &CustomizationReport, seed: u64) -> Result<(), SimError> {
    let gpgpu = Gpgpu::new(report.recommended_config());
    let mut alu = NativeAlu;
    kernels::run_verified(report.bench, report.n, &gpgpu, &mut alu, seed)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Capability, StackBound};

    #[test]
    fn bitonic_gets_multiplier_free_shallow_stack() {
        let r = profile(BenchId::Bitonic, 64, 7).unwrap();
        assert!(!r.recommended.has_multiplier, "bitonic needs no multiplier");
        assert_eq!(r.recommended.warp_stack_depth, 2, "Table 6");
        assert!(r.lut_reduction_pct > 50.0, "paper: 62%");
        validate(&r, 7).unwrap();
    }

    #[test]
    fn matmul_keeps_multiplier_drops_stack() {
        let r = profile(BenchId::MatMul, 32, 7).unwrap();
        assert!(r.recommended.has_multiplier);
        assert_eq!(r.recommended.warp_stack_depth, 0, "uniform loops only");
        validate(&r, 7).unwrap();
    }

    #[test]
    fn autocorr_needs_deep_stack() {
        let r = profile(BenchId::Autocorr, 64, 7).unwrap();
        assert_eq!(r.recommended.warp_stack_depth, 16, "Table 6");
        assert!(r.recommended.has_multiplier);
        assert_eq!(
            r.refined_signature().stack_bound,
            StackBound::AtMost(16),
            "router signature carries the measured depth"
        );
        validate(&r, 7).unwrap();
    }

    #[test]
    fn static_signature_spots_branches_and_mads() {
        let w = kernels::prepare(BenchId::MatMul, 32, 0);
        let a = analyze_kernel(&w.kernel);
        assert!(a.uses_multiplier && a.uses_third_operand && a.uses_branches);
        let w = kernels::prepare(BenchId::VecAdd, 32, 0);
        let a = analyze_kernel(&w.kernel);
        assert!(!a.uses_branches, "vecadd is straight-line");
        assert_eq!(a.stack_bound, StackBound::AtMost(0));
    }

    #[test]
    fn recommended_config_fails_wrong_application() {
        // The bitonic-customized (multiplier-less) FlexGrip must REJECT
        // matmul — exactly why the paper stores several bitstreams. The
        // mismatch is now caught by pre-flight admission, before any
        // simulation.
        let r = profile(BenchId::Bitonic, 64, 7).unwrap();
        let gpgpu = Gpgpu::new(r.recommended_config());
        let w = kernels::prepare(BenchId::MatMul, 32, 7);
        assert!(!gpgpu.supports(&w.kernel.sig));
        let mut gmem = w.make_gmem();
        let err = w.run(&gpgpu, &mut gmem, RunOptions::default()).unwrap_err();
        assert!(matches!(
            err,
            SimError::Unsupported { capability: Capability::Multiplier, pc: None, .. }
        ));
    }
}
