//! Application-class customization analyzer (paper §4.2, §5.2).
//!
//! "By performing an instruction analysis, we can determine the minimal
//! set of functions needed to support each benchmark" — this module does
//! both halves: *static* analysis of the kernel binary (does it encode
//! IMUL/IMAD at all?) and *dynamic* profiling ("profiling the application
//! with representative data sets", §4.1) to find the warp-stack
//! high-water mark. It then recommends the minimal FlexGrip variant and
//! quantifies the Table-6 area/energy savings with the implementation
//! models.

use crate::asm::Kernel;
use crate::gpgpu::{Gpgpu, GpgpuConfig};
use crate::kernels::{self, BenchId};
use crate::model::{area::area, power::power, ArchParams};
use crate::sim::{NativeAlu, SimError};

/// Static instruction analysis of an assembled kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StaticAnalysis {
    /// Kernel encodes IMUL or IMAD -> multiplier required.
    pub uses_multiplier: bool,
    /// Kernel encodes IMAD -> third read operand required.
    pub uses_third_operand: bool,
    /// Kernel encodes SSY/BRA -> conditional hardware required at all.
    pub uses_branches: bool,
    pub instruction_count: usize,
}

pub fn analyze_kernel(k: &Kernel) -> StaticAnalysis {
    use crate::isa::Op;
    let mut a = StaticAnalysis {
        uses_multiplier: false,
        uses_third_operand: false,
        uses_branches: false,
        instruction_count: k.instrs.len(),
    };
    for (_, i) in &k.instrs {
        a.uses_multiplier |= i.op.uses_multiplier();
        a.uses_third_operand |= i.op == Op::Imad;
        a.uses_branches |= matches!(i.op, Op::Bra | Op::Ssy);
    }
    a
}

/// A customization recommendation with its modelled savings.
#[derive(Debug, Clone)]
pub struct CustomizationReport {
    pub bench: BenchId,
    pub n: u32,
    pub analysis: StaticAnalysis,
    /// Warp-stack high-water mark measured by the profiling run.
    pub measured_stack_depth: u32,
    /// Dynamic IMUL/IMAD count from the profiling run.
    pub multiplier_ops: u64,
    pub recommended: ArchParams,
    pub lut_reduction_pct: f64,
    pub dynamic_power_reduction_pct: f64,
}

/// Profile `bench` at size `n` on the baseline 1 SM / 8 SP FlexGrip and
/// derive the minimal configuration (paper §5.2 methodology).
pub fn profile(bench: BenchId, n: u32, seed: u64) -> Result<CustomizationReport, SimError> {
    let workload = kernels::prepare(bench, n, seed);
    let analysis = analyze_kernel(&workload.kernel);

    let gpgpu = Gpgpu::new(GpgpuConfig::new(1, 8));
    let mut alu = NativeAlu;
    let mut gmem = workload.make_gmem();
    let run = workload.run(&gpgpu, &mut gmem, &mut alu)?;
    if let Err(e) = workload.verify(&gmem) {
        return Err(SimError::LimitExceeded(format!("profiling run invalid: {e}")));
    }

    let needs_mul = analysis.uses_multiplier && run.stats.multiplier_ops() > 0;
    let recommended = ArchParams {
        num_sms: 1,
        num_sp: 8,
        warp_stack_depth: run.stats.max_stack_depth,
        has_multiplier: needs_mul,
    };
    let base = ArchParams::baseline();
    let lut_red = area(&recommended).lut_reduction_pct(&area(&base));
    let dyn_red =
        100.0 * (1.0 - power(&recommended).dynamic_w / power(&base).dynamic_w);
    Ok(CustomizationReport {
        bench,
        n,
        analysis,
        measured_stack_depth: run.stats.max_stack_depth,
        multiplier_ops: run.stats.multiplier_ops(),
        recommended,
        lut_reduction_pct: lut_red,
        dynamic_power_reduction_pct: dyn_red,
    })
}

/// Re-run the benchmark on the *recommended* configuration to prove the
/// customized hardware still executes it (the paper's embedded-bitstream
/// scenario: the right variant must be functionally sufficient).
pub fn validate(report: &CustomizationReport, seed: u64) -> Result<(), SimError> {
    let mut cfg = GpgpuConfig::new(1, 8);
    cfg.sm.warp_stack_depth = report.recommended.warp_stack_depth;
    cfg.sm.has_multiplier = report.recommended.has_multiplier;
    if !report.recommended.has_multiplier {
        cfg.sm.read_operands = 2;
    }
    let gpgpu = Gpgpu::new(cfg);
    let mut alu = NativeAlu;
    kernels::run_verified(report.bench, report.n, &gpgpu, &mut alu, seed)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitonic_gets_multiplier_free_shallow_stack() {
        let r = profile(BenchId::Bitonic, 64, 7).unwrap();
        assert!(!r.recommended.has_multiplier, "bitonic needs no multiplier");
        assert_eq!(r.recommended.warp_stack_depth, 2, "Table 6");
        assert!(r.lut_reduction_pct > 50.0, "paper: 62%");
        validate(&r, 7).unwrap();
    }

    #[test]
    fn matmul_keeps_multiplier_drops_stack() {
        let r = profile(BenchId::MatMul, 32, 7).unwrap();
        assert!(r.recommended.has_multiplier);
        assert_eq!(r.recommended.warp_stack_depth, 0, "uniform loops only");
        validate(&r, 7).unwrap();
    }

    #[test]
    fn autocorr_needs_deep_stack() {
        let r = profile(BenchId::Autocorr, 64, 7).unwrap();
        assert_eq!(r.recommended.warp_stack_depth, 16, "Table 6");
        assert!(r.recommended.has_multiplier);
        validate(&r, 7).unwrap();
    }

    #[test]
    fn static_analysis_spots_branches_and_mads() {
        let w = kernels::prepare(BenchId::MatMul, 32, 0);
        let a = analyze_kernel(&w.kernel);
        assert!(a.uses_multiplier && a.uses_third_operand && a.uses_branches);
        let w = kernels::prepare(BenchId::VecAdd, 32, 0);
        let a = analyze_kernel(&w.kernel);
        assert!(!a.uses_branches, "vecadd is straight-line");
    }

    #[test]
    fn recommended_config_fails_wrong_application() {
        // The bitonic-customized (multiplier-less) FlexGrip must REJECT
        // matmul — exactly why the paper stores several bitstreams.
        let r = profile(BenchId::Bitonic, 64, 7).unwrap();
        let mut cfg = GpgpuConfig::new(1, 8);
        cfg.sm.warp_stack_depth = r.recommended.warp_stack_depth;
        cfg.sm.has_multiplier = false;
        cfg.sm.read_operands = 2;
        let gpgpu = Gpgpu::new(cfg);
        let mut alu = NativeAlu;
        let w = kernels::prepare(BenchId::MatMul, 32, 7);
        let mut gmem = w.make_gmem();
        let err = w.run(&gpgpu, &mut gmem, &mut alu).unwrap_err();
        assert!(matches!(err, SimError::NoMultiplier { .. }));
    }
}
