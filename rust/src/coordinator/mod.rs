//! The coordinator: the paper's "MicroBlaze driver" role (§3.1) as a
//! long-lived service — it owns a pool of soft-GPGPU device shards,
//! accepts kernel-launch requests over a bounded submit queue, DMAs data
//! in and out of device memory, and reports per-job, per-shard, and
//! aggregate metrics.
//!
//! # Pool architecture
//!
//! `GpgpuService` runs `ServiceConfig::shards` worker threads. Each shard
//! owns one [`Gpgpu`] device instance and pulls jobs from a single shared
//! work queue (`Mutex<VecDeque>` + condvars — effectively work stealing:
//! an idle shard takes the next job the moment it frees up, so one slow
//! job never blocks the whole pool). `submit` applies backpressure once
//! `queue_depth` jobs are waiting. Each job's kernel launch itself uses
//! the parallel multi-SM path (`Gpgpu::launch_parallel`), so a 2-SM shard
//! simulates its SMs concurrently while other shards run other jobs.
//!
//! Shutdown is graceful: dropping the service stops intake, lets the
//! shards drain every queued job (each ticket still resolves), then joins
//! the worker threads.
//!
//! tokio is unavailable in this offline image (DESIGN.md §substitutions),
//! so the pool uses plain threads + std::sync::mpsc reply channels; the
//! API shape (submit -> ticket -> await) is what an async driver would
//! expose.

pub mod customize;

pub use customize::{analyze_kernel, profile, CustomizationReport, StaticAnalysis};

use crate::asm::Kernel;
use crate::gpgpu::{Gpgpu, GpgpuConfig, LaunchConfig};
use crate::kernels::{self, BenchId};
use crate::sim::{GlobalMem, NativeAlu, SimError, SmStats};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A kernel-launch request.
pub enum Request {
    /// Run a prepared paper benchmark (data generation + verification
    /// handled by the service).
    Bench { id: BenchId, n: u32, seed: u64 },
    /// Launch an arbitrary assembled kernel: the driver writes `inputs`
    /// into device memory, launches, and reads `read_back` words out.
    ///
    /// Executed through `Gpgpu::launch_parallel`. If the kernel's blocks
    /// overlap writes across SMs, the rejected merge leaves device memory
    /// untouched and the shard transparently retries on the sequential
    /// `Gpgpu::launch` (which permits overlapping writes, SM order). One
    /// contract remains on the caller for multi-SM devices: blocks must
    /// not *read* data written by blocks on another SM within the same
    /// launch — that dependency is undetectable (see `gpgpu` module docs)
    /// and such kernels should be split into phases or run on a 1-SM
    /// service.
    Kernel {
        kernel: Box<Kernel>,
        launch: LaunchConfig,
        params: Vec<i32>,
        gmem_bytes: u32,
        inputs: Vec<(u32, Vec<i32>)>,
        read_back: (u32, usize),
    },
}

/// What a completed job returns.
#[derive(Debug, Clone)]
pub struct JobOutput {
    pub label: String,
    pub cycles: u64,
    pub exec_time_ms: f64,
    pub stats: SmStats,
    /// For `Request::Kernel`: the words read back from device memory.
    pub data: Vec<i32>,
    /// For `Request::Bench`: golden verification outcome.
    pub verified: bool,
    /// Pool shard that executed the job.
    pub shard: u32,
}

/// Handle to an in-flight job.
pub struct JobTicket {
    rx: mpsc::Receiver<Result<JobOutput, String>>,
}

impl JobTicket {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobOutput, String> {
        self.rx.recv().map_err(|_| "coordinator shut down".to_string())?
    }
}

/// Pool shape: how many device shards serve the queue, and how many jobs
/// may wait before `submit` applies backpressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Worker threads, each owning one GPGPU device instance.
    pub shards: u32,
    /// Maximum queued (not yet running) jobs before `submit` blocks.
    pub queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { shards: 1, queue_depth: 64 }
    }
}

/// Aggregate counters for one shard.
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub total_cycles: AtomicU64,
    pub total_instructions: AtomicU64,
}

impl Metrics {
    fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            total_cycles: self.total_cycles.load(Ordering::Relaxed),
            total_instructions: self.total_instructions.load(Ordering::Relaxed),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MetricsSnapshot {
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub total_cycles: u64,
    pub total_instructions: u64,
}

impl MetricsSnapshot {
    /// Element-wise sum — aggregate view over shards.
    pub fn merged(&self, other: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_completed: self.jobs_completed + other.jobs_completed,
            jobs_failed: self.jobs_failed + other.jobs_failed,
            total_cycles: self.total_cycles + other.total_cycles,
            total_instructions: self.total_instructions + other.total_instructions,
        }
    }
}

type Job = (Request, mpsc::Sender<Result<JobOutput, String>>);

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    /// Signalled when a job is enqueued (workers wait here).
    not_empty: Condvar,
    /// Signalled when a job is dequeued (backpressured submitters wait here).
    not_full: Condvar,
    depth: usize,
}

/// The GPGPU service: a shard pool behind one submit queue.
pub struct GpgpuService {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    shard_metrics: Vec<Arc<Metrics>>,
    pub cfg: GpgpuConfig,
    pub pool: ServiceConfig,
}

impl GpgpuService {
    /// Single-shard service (the seed API — one worker owning one device).
    pub fn start(cfg: GpgpuConfig) -> GpgpuService {
        GpgpuService::start_pool(cfg, ServiceConfig::default())
    }

    /// Start a pool of `pool.shards` identical device shards.
    pub fn start_pool(cfg: GpgpuConfig, pool: ServiceConfig) -> GpgpuService {
        let shards = pool.shards.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            depth: pool.queue_depth.max(1),
        });
        let mut workers = Vec::with_capacity(shards as usize);
        let mut shard_metrics = Vec::with_capacity(shards as usize);
        for shard in 0..shards {
            let metrics = Arc::new(Metrics::default());
            shard_metrics.push(metrics.clone());
            let shared = shared.clone();
            workers.push(std::thread::spawn(move || {
                shard_worker(shard, cfg, &shared, &metrics);
            }));
        }
        GpgpuService { shared, workers, shard_metrics, cfg, pool }
    }

    /// Queue a job; returns immediately with a ticket unless the queue is
    /// at `queue_depth`, in which case it blocks until a shard drains it.
    pub fn submit(&self, req: Request) -> JobTicket {
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut q = self.shared.state.lock().expect("queue poisoned");
        while q.jobs.len() >= self.shared.depth && !q.shutdown {
            q = self.shared.not_full.wait(q).expect("queue poisoned");
        }
        q.jobs.push_back((req, reply_tx));
        drop(q);
        self.shared.not_empty.notify_one();
        JobTicket { rx: reply_rx }
    }

    /// Aggregate metrics over every shard.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shard_metrics
            .iter()
            .fold(MetricsSnapshot::default(), |acc, m| acc.merged(&m.snapshot()))
    }

    /// Per-shard metrics (index = shard id).
    pub fn shard_metrics(&self) -> Vec<MetricsSnapshot> {
        self.shard_metrics.iter().map(|m| m.snapshot()).collect()
    }
}

impl Drop for GpgpuService {
    fn drop(&mut self) {
        // Graceful shutdown: stop intake, let shards drain the queue
        // (every already-submitted ticket still resolves), then join.
        {
            let mut q = self.shared.state.lock().expect("queue poisoned");
            q.shutdown = true;
        }
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One shard: owns a device, pulls jobs until shutdown + empty queue.
fn shard_worker(shard: u32, cfg: GpgpuConfig, shared: &Shared, metrics: &Metrics) {
    let gpgpu = Gpgpu::new(cfg);
    loop {
        let job = {
            let mut q = shared.state.lock().expect("queue poisoned");
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break Some(j);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.not_empty.wait(q).expect("queue poisoned");
            }
        };
        let Some((req, reply)) = job else { break };
        shared.not_full.notify_one();
        // A panicking job (e.g. a malformed Bench size tripping an assert
        // in kernels::prepare) must fail its own ticket, not kill the
        // shard — a dead shard would leave later tickets hanging forever.
        let result = catch_unwind(AssertUnwindSafe(|| run_one(&gpgpu, shard, req)))
            .unwrap_or_else(|payload| {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic".to_string());
                Err(format!("job panicked: {msg}"))
            });
        match &result {
            Ok(out) => {
                metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                metrics.total_cycles.fetch_add(out.cycles, Ordering::Relaxed);
                metrics
                    .total_instructions
                    .fetch_add(out.stats.instructions, Ordering::Relaxed);
            }
            Err(_) => {
                metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        let _ = reply.send(result);
    }
}

fn run_one(gpgpu: &Gpgpu, shard: u32, req: Request) -> Result<JobOutput, String> {
    match req {
        Request::Bench { id, n, seed } => {
            let w = kernels::prepare(id, n, seed);
            let mut gmem = w.make_gmem();
            let run = w
                .run_parallel(gpgpu, &mut gmem, &NativeAlu)
                .map_err(|e| e.to_string())?;
            let verified = w.verify(&gmem).map(|_| true)?;
            Ok(JobOutput {
                label: format!("{} n={n}", id.name()),
                cycles: run.cycles,
                exec_time_ms: run.exec_time_ms(),
                stats: run.stats,
                data: Vec::new(),
                verified,
                shard,
            })
        }
        Request::Kernel {
            kernel,
            launch,
            params,
            gmem_bytes,
            inputs,
            read_back,
        } => {
            let mut gmem = GlobalMem::new(gmem_bytes);
            for (addr, words) in &inputs {
                gmem.write_words(*addr, words).map_err(|e| e.to_string())?;
            }
            let launched = match gpgpu
                .launch_parallel(&kernel, launch, &params, &mut gmem, &NativeAlu)
            {
                Err(SimError::WriteConflict { .. }) => {
                    // Arbitrary user kernels may legally overlap writes
                    // across SMs; the rejected merge left gmem untouched,
                    // so fall back to the sequential reference path.
                    let mut alu = NativeAlu;
                    gpgpu.launch(&kernel, launch, &params, &mut gmem, &mut alu)
                }
                other => other,
            };
            let r = launched.map_err(|e| e.to_string())?;
            let data =
                gmem.read_words(read_back.0, read_back.1).map_err(|e| e.to_string())?;
            Ok(JobOutput {
                label: kernel.name.clone(),
                cycles: r.total.cycles,
                exec_time_ms: r.exec_time_ms(),
                stats: r.total,
                data,
                verified: true,
                shard,
            })
        }
    }
}
