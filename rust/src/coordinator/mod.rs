//! The coordinator: the paper's "MicroBlaze driver" role (§3.1) as a
//! long-lived service — it owns the soft GPGPU, accepts kernel-launch
//! requests over a channel, DMAs data in and out of device memory, and
//! reports per-job and aggregate metrics.
//!
//! tokio is unavailable in this offline image (DESIGN.md §substitutions),
//! so the service uses a dedicated worker thread + std::sync::mpsc; the
//! API shape (submit -> ticket -> await) is what an async driver would
//! expose.

pub mod customize;

pub use customize::{analyze_kernel, profile, CustomizationReport, StaticAnalysis};

use crate::asm::Kernel;
use crate::gpgpu::{Gpgpu, GpgpuConfig, LaunchConfig};
use crate::kernels::{self, BenchId};
use crate::sim::{GlobalMem, NativeAlu, SmStats};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

/// A kernel-launch request.
pub enum Request {
    /// Run a prepared paper benchmark (data generation + verification
    /// handled by the service).
    Bench { id: BenchId, n: u32, seed: u64 },
    /// Launch an arbitrary assembled kernel: the driver writes `inputs`
    /// into device memory, launches, and reads `read_back` words out.
    Kernel {
        kernel: Box<Kernel>,
        launch: LaunchConfig,
        params: Vec<i32>,
        gmem_bytes: u32,
        inputs: Vec<(u32, Vec<i32>)>,
        read_back: (u32, usize),
    },
}

/// What a completed job returns.
#[derive(Debug, Clone)]
pub struct JobOutput {
    pub label: String,
    pub cycles: u64,
    pub exec_time_ms: f64,
    pub stats: SmStats,
    /// For `Request::Kernel`: the words read back from device memory.
    pub data: Vec<i32>,
    /// For `Request::Bench`: golden verification outcome.
    pub verified: bool,
}

/// Handle to an in-flight job.
pub struct JobTicket {
    rx: mpsc::Receiver<Result<JobOutput, String>>,
}

impl JobTicket {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobOutput, String> {
        self.rx.recv().map_err(|_| "coordinator shut down".to_string())?
    }
}

/// Aggregate service counters.
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub total_cycles: AtomicU64,
    pub total_instructions: AtomicU64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub total_cycles: u64,
    pub total_instructions: u64,
}

/// The GPGPU service: one worker thread owning the device.
pub struct GpgpuService {
    tx: Option<mpsc::Sender<(Request, mpsc::Sender<Result<JobOutput, String>>)>>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Metrics>,
    pub cfg: GpgpuConfig,
}

impl GpgpuService {
    pub fn start(cfg: GpgpuConfig) -> GpgpuService {
        let metrics = Arc::new(Metrics::default());
        let m = metrics.clone();
        let (tx, rx) =
            mpsc::channel::<(Request, mpsc::Sender<Result<JobOutput, String>>)>();
        let worker = std::thread::spawn(move || {
            let gpgpu = Gpgpu::new(cfg);
            let mut alu = NativeAlu;
            while let Ok((req, reply)) = rx.recv() {
                let result = Self::run_one(&gpgpu, &mut alu, req);
                match &result {
                    Ok(out) => {
                        m.jobs_completed.fetch_add(1, Ordering::Relaxed);
                        m.total_cycles.fetch_add(out.cycles, Ordering::Relaxed);
                        m.total_instructions
                            .fetch_add(out.stats.instructions, Ordering::Relaxed);
                    }
                    Err(_) => {
                        m.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
                let _ = reply.send(result);
            }
        });
        GpgpuService { tx: Some(tx), worker: Some(worker), metrics, cfg }
    }

    fn run_one(
        gpgpu: &Gpgpu,
        alu: &mut NativeAlu,
        req: Request,
    ) -> Result<JobOutput, String> {
        match req {
            Request::Bench { id, n, seed } => {
                let w = kernels::prepare(id, n, seed);
                let mut gmem = w.make_gmem();
                let run = w.run(gpgpu, &mut gmem, alu).map_err(|e| e.to_string())?;
                let verified = w.verify(&gmem).map(|_| true).map_err(|e| e)?;
                Ok(JobOutput {
                    label: format!("{} n={n}", id.name()),
                    cycles: run.cycles,
                    exec_time_ms: run.exec_time_ms(),
                    stats: run.stats,
                    data: Vec::new(),
                    verified,
                })
            }
            Request::Kernel {
                kernel,
                launch,
                params,
                gmem_bytes,
                inputs,
                read_back,
            } => {
                let mut gmem = GlobalMem::new(gmem_bytes);
                for (addr, words) in &inputs {
                    gmem.write_words(*addr, words).map_err(|e| e.to_string())?;
                }
                let r = gpgpu
                    .launch(&kernel, launch, &params, &mut gmem, alu)
                    .map_err(|e| e.to_string())?;
                let data =
                    gmem.read_words(read_back.0, read_back.1).map_err(|e| e.to_string())?;
                Ok(JobOutput {
                    label: kernel.name.clone(),
                    cycles: r.total.cycles,
                    exec_time_ms: r.exec_time_ms(),
                    stats: r.total,
                    data,
                    verified: true,
                })
            }
        }
    }

    /// Queue a job; returns immediately with a ticket.
    pub fn submit(&self, req: Request) -> JobTicket {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .as_ref()
            .expect("service running")
            .send((req, reply_tx))
            .expect("worker alive");
        JobTicket { rx: reply_rx }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_completed: self.metrics.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: self.metrics.jobs_failed.load(Ordering::Relaxed),
            total_cycles: self.metrics.total_cycles.load(Ordering::Relaxed),
            total_instructions: self.metrics.total_instructions.load(Ordering::Relaxed),
        }
    }
}

impl Drop for GpgpuService {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}
