//! The coordinator: the paper's "MicroBlaze driver" role (§3.1) as a
//! long-lived service — it owns a fleet of soft-GPGPU device shards,
//! accepts kernel-launch requests over bounded submit queues, DMAs data
//! in and out of device memory, and reports per-job, per-shard,
//! per-variant and aggregate metrics.
//!
//! # Fleet architecture
//!
//! `GpgpuService` hosts a *heterogeneous* fleet: each [`VariantSpec`]
//! names a (possibly §4.2-customized) device configuration and how many
//! shards of it to run. Every variant group has its own bounded work
//! queue served by its shards (`Mutex<VecDeque>` + condvars —
//! effectively work stealing inside a group: an idle shard takes the
//! next job the moment it frees up). `submit` computes the job's
//! [`CapabilitySignature`] (profiled when registered, static otherwise)
//! and **routes** it to the lowest-modeled-dynamic-power variant whose
//! capabilities cover the signature, falling back to the most-capable
//! (baseline) variant — the paper's stored-bitstream scenario (§5.2) as
//! a runtime scheduling concern. The routed signature travels with the
//! job and the shard's launch admits on exactly that signature
//! (`LaunchRequest::admit`), so a profile-refined requirement can never
//! be re-rejected by the static one on the variant the router chose; a
//! *lying* profile surfaces as the structured mid-run removed-unit or
//! stack-overflow trap, failing only its own ticket. Backpressure applies
//! per variant queue once `queue_depth` jobs are waiting.
//!
//! Kernel binaries reach the devices through the process-wide
//! [`KernelRegistry`], so repeat launches of the same benchmark skip
//! assembly, pre-decode and signature analysis; each job's launch uses
//! the parallel multi-SM path (`LaunchRequest::parallel`), so a 2-SM
//! shard simulates its SMs concurrently while other shards run other
//! jobs.
//!
//! Shutdown is graceful: dropping the service stops intake, lets every
//! group drain its queued jobs (each ticket still resolves), then joins
//! the worker threads.
//!
//! tokio is unavailable in this offline image (DESIGN.md §substitutions),
//! so the pool uses plain threads + std::sync::mpsc reply channels; the
//! API shape (submit -> ticket -> await) is what an async driver would
//! expose.

pub mod customize;

pub use customize::{analyze_kernel, profile, CustomizationReport};

use crate::asm::Kernel;
use crate::gpgpu::{Gpgpu, GpgpuConfig, LaunchConfig, LaunchRequest};
use crate::isa::CapabilitySignature;
use crate::kernels::{self, BenchId, RunOptions};
use crate::model::{power::power, ArchParams};
use crate::registry::{KernelRegistry, PreparedKernel};
use crate::sim::{GlobalMem, SimError, SmStats};
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A kernel-launch request.
pub enum Request {
    /// Run a prepared paper benchmark (data generation + verification
    /// handled by the service).
    Bench { id: BenchId, n: u32, seed: u64 },
    /// Launch an arbitrary assembled kernel: the driver writes `inputs`
    /// into device memory, launches, and reads `read_back` words out.
    ///
    /// Executed through the parallel mode of `Gpgpu::launch`. If the
    /// kernel's blocks overlap writes across SMs, the rejected merge
    /// leaves device memory untouched and the shard transparently retries
    /// the request in sequential mode (which permits overlapping writes,
    /// SM order). One contract remains on the caller
    /// for multi-SM devices: blocks must not *read* data written by
    /// blocks on another SM within the same launch — that dependency is
    /// undetectable (see `gpgpu` module docs) and such kernels should be
    /// split into phases or run on a 1-SM service.
    Kernel {
        kernel: Box<Kernel>,
        launch: LaunchConfig,
        params: Vec<i32>,
        gmem_bytes: u32,
        inputs: Vec<(u32, Vec<i32>)>,
        read_back: (u32, usize),
    },
}

/// What a completed job returns.
#[derive(Debug, Clone)]
pub struct JobOutput {
    pub label: String,
    pub cycles: u64,
    pub exec_time_ms: f64,
    pub stats: SmStats,
    /// For `Request::Kernel`: the words read back from device memory.
    pub data: Vec<i32>,
    /// For `Request::Bench`: golden verification outcome.
    pub verified: bool,
    /// Fleet shard that executed the job (global index, variant-major).
    pub shard: u32,
    /// Label of the variant the router admitted the job to.
    pub variant: String,
}

/// Handle to an in-flight job.
pub struct JobTicket {
    rx: mpsc::Receiver<Result<JobOutput, String>>,
}

impl JobTicket {
    /// Block until the job completes.
    pub fn wait(self) -> Result<JobOutput, String> {
        self.rx.recv().map_err(|_| "coordinator shut down".to_string())?
    }
}

/// Pool shape of a *homogeneous* service: how many identical shards serve
/// the queue, and how many jobs may wait before `submit` applies
/// backpressure. (Kept as the simple entry point; heterogeneous fleets
/// use [`FleetConfig`].)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Worker threads, each owning one GPGPU device instance.
    pub shards: u32,
    /// Maximum queued (not yet running) jobs before `submit` blocks.
    pub queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { shards: 1, queue_depth: 64 }
    }
}

/// One device variant in a heterogeneous fleet.
#[derive(Debug, Clone)]
pub struct VariantSpec {
    /// Display label (e.g. `ArchParams::label()`'s "1 SM - 8 SP, stack 2,
    /// no mul").
    pub label: String,
    pub cfg: GpgpuConfig,
    /// Shards (worker threads) hosting this variant.
    pub shards: u32,
}

impl VariantSpec {
    pub fn new(label: impl Into<String>, cfg: GpgpuConfig) -> VariantSpec {
        VariantSpec { label: label.into(), cfg, shards: 1 }
    }
}

/// A heterogeneous fleet: customized variants + (normally) the baseline.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub variants: Vec<VariantSpec>,
    /// Per-variant-queue depth before `submit` blocks.
    pub queue_depth: usize,
}

impl FleetConfig {
    /// A single-variant fleet — the homogeneous pool the seed service ran.
    pub fn homogeneous(cfg: GpgpuConfig, pool: ServiceConfig) -> FleetConfig {
        FleetConfig {
            variants: vec![VariantSpec {
                label: "baseline".to_string(),
                cfg,
                shards: pool.shards.max(1),
            }],
            queue_depth: pool.queue_depth.max(1),
        }
    }
}

/// Aggregate counters for one shard.
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub total_cycles: AtomicU64,
    pub total_instructions: AtomicU64,
}

impl Metrics {
    fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            total_cycles: self.total_cycles.load(Ordering::Relaxed),
            total_instructions: self.total_instructions.load(Ordering::Relaxed),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MetricsSnapshot {
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub total_cycles: u64,
    pub total_instructions: u64,
}

impl MetricsSnapshot {
    /// Element-wise sum — aggregate view over shards.
    pub fn merged(&self, other: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_completed: self.jobs_completed + other.jobs_completed,
            jobs_failed: self.jobs_failed + other.jobs_failed,
            total_cycles: self.total_cycles + other.total_cycles,
            total_instructions: self.total_instructions + other.total_instructions,
        }
    }
}

/// A queued job: the request, the signature the router admitted it on
/// (the shard launches with exactly this signature — see
/// `LaunchRequest::admit` — so profile refinement can never self-reject
/// on the routed variant), and the reply channel.
type Job = (Request, CapabilitySignature, mpsc::Sender<Result<JobOutput, String>>);

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    /// Signalled when a job is enqueued (workers wait here).
    not_empty: Condvar,
    /// Signalled when a job is dequeued (backpressured submitters wait here).
    not_full: Condvar,
    depth: usize,
}

impl Shared {
    fn new(depth: usize) -> Arc<Shared> {
        Arc::new(Shared {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), shutdown: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            depth,
        })
    }
}

/// One running variant group: its queue, its shards' metrics, and the
/// routing key (modeled dynamic power).
struct Variant {
    label: String,
    cfg: GpgpuConfig,
    dyn_w: f64,
    shared: Arc<Shared>,
    metrics: Vec<Arc<Metrics>>,
}

/// The GPGPU service: a capability-routed fleet of device-variant groups.
pub struct GpgpuService {
    variants: Vec<Variant>,
    workers: Vec<JoinHandle<()>>,
    /// Index of the most-capable variant — the routing fallback.
    fallback: usize,
    /// Profile-refined signatures registered per benchmark (paper §4.1:
    /// representative-data profiling decides which bitstream suffices).
    profiles: Mutex<HashMap<BenchId, CapabilitySignature>>,
    /// The fallback (most capable) variant's device configuration.
    pub cfg: GpgpuConfig,
    /// Aggregate pool shape (total shards across variants).
    pub pool: ServiceConfig,
}

impl GpgpuService {
    /// Single-shard service (the seed API — one worker owning one device).
    pub fn start(cfg: GpgpuConfig) -> GpgpuService {
        GpgpuService::start_pool(cfg, ServiceConfig::default())
    }

    /// Start a pool of `pool.shards` identical device shards.
    pub fn start_pool(cfg: GpgpuConfig, pool: ServiceConfig) -> GpgpuService {
        GpgpuService::start_fleet(FleetConfig::homogeneous(cfg, pool))
    }

    /// Start a heterogeneous fleet: one worker group per variant, jobs
    /// routed by capability signature.
    pub fn start_fleet(fleet: FleetConfig) -> GpgpuService {
        assert!(!fleet.variants.is_empty(), "fleet needs at least one variant");
        let depth = fleet.queue_depth.max(1);
        let mut variants = Vec::with_capacity(fleet.variants.len());
        let mut workers = Vec::new();
        let mut shard_base = 0u32;
        for spec in fleet.variants {
            let shards = spec.shards.max(1);
            let shared = Shared::new(depth);
            let mut metrics = Vec::with_capacity(shards as usize);
            for s in 0..shards {
                let m = Arc::new(Metrics::default());
                metrics.push(m.clone());
                let shared = shared.clone();
                let cfg = spec.cfg;
                let label = spec.label.clone();
                let shard = shard_base + s;
                workers.push(std::thread::spawn(move || {
                    shard_worker(shard, &label, cfg, &shared, &m);
                }));
            }
            let dyn_w = power(&ArchParams::from_config(&spec.cfg)).dynamic_w;
            variants.push(Variant { label: spec.label, cfg: spec.cfg, dyn_w, shared, metrics });
            shard_base += shards;
        }
        // Fallback: the most capable variant (multiplier before stack
        // depth before operand count) — "the full baseline device" in any
        // sensibly-specified fleet.
        let fallback = variants
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| {
                (v.cfg.sm.has_multiplier, v.cfg.sm.warp_stack_depth, v.cfg.sm.read_operands)
            })
            .map(|(i, _)| i)
            .expect("non-empty fleet");
        let cfg = variants[fallback].cfg;
        let pool = ServiceConfig { shards: shard_base, queue_depth: depth };
        GpgpuService {
            variants,
            workers,
            fallback,
            profiles: Mutex::new(HashMap::new()),
            cfg,
            pool,
        }
    }

    /// Register a profile-refined signature for a benchmark (from
    /// [`CustomizationReport::refined_signature`]). Subsequent `Bench`
    /// jobs route on the measured requirements instead of the
    /// conservative static ones — what lets autocorr land on a depth-16
    /// variant and matmul on a depth-0 one.
    pub fn register_profile(&self, id: BenchId, sig: CapabilitySignature) {
        self.profiles.lock().expect("profiles poisoned").insert(id, sig);
    }

    /// The signature the router admits a request on.
    fn job_signature(&self, req: &Request) -> CapabilitySignature {
        match req {
            Request::Bench { id, .. } => {
                if let Some(sig) = self.profiles.lock().expect("profiles poisoned").get(id) {
                    return *sig;
                }
                KernelRegistry::global()
                    .get_or_assemble(id.source())
                    .expect("benchmark kernels must assemble")
                    .sig
            }
            Request::Kernel { kernel, .. } => kernel.signature(),
        }
    }

    /// Route: the cheapest (lowest modeled dynamic power) variant whose
    /// capabilities cover the signature; the most-capable variant if none
    /// does (its own launch admission then reports the structured
    /// `Unsupported` error if even the fallback cannot run the kernel).
    fn route(&self, sig: &CapabilitySignature) -> usize {
        self.variants
            .iter()
            .enumerate()
            .filter(|(_, v)| v.cfg.sm.covers(sig))
            .min_by(|(_, a), (_, b)| {
                a.dyn_w.partial_cmp(&b.dyn_w).expect("finite modeled power")
            })
            .map(|(i, _)| i)
            .unwrap_or(self.fallback)
    }

    /// Queue a job on its routed variant; returns immediately with a
    /// ticket unless that variant's queue is at `queue_depth`, in which
    /// case it blocks until a shard drains it.
    pub fn submit(&self, req: Request) -> JobTicket {
        let sig = self.job_signature(&req);
        let shared = &self.variants[self.route(&sig)].shared;
        let (reply_tx, reply_rx) = mpsc::channel();
        let mut q = shared.state.lock().expect("queue poisoned");
        while q.jobs.len() >= shared.depth && !q.shutdown {
            q = shared.not_full.wait(q).expect("queue poisoned");
        }
        q.jobs.push_back((req, sig, reply_tx));
        drop(q);
        shared.not_empty.notify_one();
        JobTicket { rx: reply_rx }
    }

    /// Aggregate metrics over every shard of every variant.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shard_metrics()
            .iter()
            .fold(MetricsSnapshot::default(), |acc, m| acc.merged(m))
    }

    /// Per-shard metrics (index = global shard id, variant-major).
    pub fn shard_metrics(&self) -> Vec<MetricsSnapshot> {
        self.variants
            .iter()
            .flat_map(|v| v.metrics.iter().map(|m| m.snapshot()))
            .collect()
    }

    /// Per-variant metrics: (label, merged counters over its shards).
    pub fn variant_metrics(&self) -> Vec<(String, MetricsSnapshot)> {
        self.variants
            .iter()
            .map(|v| {
                let merged = v
                    .metrics
                    .iter()
                    .fold(MetricsSnapshot::default(), |acc, m| acc.merged(&m.snapshot()));
                (v.label.clone(), merged)
            })
            .collect()
    }

    /// (label, modeled dynamic power W) per variant — the routing order.
    pub fn variant_power(&self) -> Vec<(String, f64)> {
        self.variants.iter().map(|v| (v.label.clone(), v.dyn_w)).collect()
    }
}

impl Drop for GpgpuService {
    fn drop(&mut self) {
        // Graceful shutdown: stop intake on every variant queue, let the
        // shards drain (every already-submitted ticket still resolves),
        // then join.
        for v in &self.variants {
            let mut q = v.shared.state.lock().expect("queue poisoned");
            q.shutdown = true;
            drop(q);
            v.shared.not_empty.notify_all();
            v.shared.not_full.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One shard: owns a device, pulls jobs from its variant's queue until
/// shutdown + empty queue.
fn shard_worker(shard: u32, variant: &str, cfg: GpgpuConfig, shared: &Shared, metrics: &Metrics) {
    let gpgpu = Gpgpu::new(cfg);
    loop {
        let job = {
            let mut q = shared.state.lock().expect("queue poisoned");
            loop {
                if let Some(j) = q.jobs.pop_front() {
                    break Some(j);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.not_empty.wait(q).expect("queue poisoned");
            }
        };
        let Some((req, sig, reply)) = job else { break };
        shared.not_full.notify_one();
        // A panicking job (e.g. a malformed Bench size tripping an assert
        // in kernels::prepare) must fail its own ticket, not kill the
        // shard — a dead shard would leave later tickets hanging forever.
        let result =
            catch_unwind(AssertUnwindSafe(|| run_one(&gpgpu, shard, variant, req, sig)))
                .unwrap_or_else(|payload| {
                    let msg = payload
                        .downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "unknown panic".to_string());
                    Err(format!("job panicked: {msg}"))
                });
        match &result {
            Ok(out) => {
                metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                metrics.total_cycles.fetch_add(out.cycles, Ordering::Relaxed);
                metrics
                    .total_instructions
                    .fetch_add(out.stats.instructions, Ordering::Relaxed);
            }
            Err(_) => {
                metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        let _ = reply.send(result);
    }
}

/// Execute one routed job. `sig` is the signature the router admitted the
/// job on (profile-refined for registered benchmarks): the launch admits
/// on exactly that signature, and the mid-run removed-unit / stack traps
/// are the structured backstop if a registered profile over-promised.
fn run_one(
    gpgpu: &Gpgpu,
    shard: u32,
    variant: &str,
    req: Request,
    sig: CapabilitySignature,
) -> Result<JobOutput, String> {
    match req {
        Request::Bench { id, n, seed } => {
            let w = kernels::prepare(id, n, seed);
            let mut gmem = w.make_gmem();
            let run = w
                .run(gpgpu, &mut gmem, RunOptions::new().parallel().admit(sig))
                .map_err(|e| e.to_string())?;
            let verified = w.verify(&gmem).map(|_| true)?;
            Ok(JobOutput {
                label: format!("{} n={n}", id.name()),
                cycles: run.cycles,
                exec_time_ms: run.exec_time_ms(),
                stats: run.stats,
                data: Vec::new(),
                verified,
                shard,
                variant: variant.to_string(),
            })
        }
        Request::Kernel {
            kernel,
            launch,
            params,
            gmem_bytes,
            inputs,
            read_back,
        } => {
            // Pre-decode once per job (arbitrary kernels are not
            // interned); the signature was already derived at submit for
            // routing, so it is reused rather than re-walked.
            let pk = PreparedKernel::with_sig(*kernel, sig);
            let mut gmem = GlobalMem::new(gmem_bytes);
            for (addr, words) in &inputs {
                gmem.write_words(*addr, words).map_err(|e| e.to_string())?;
            }
            let launched = match gpgpu.launch(
                LaunchRequest::new(&pk, launch, &mut gmem).params(&params).parallel(),
            ) {
                Err(SimError::WriteConflict { .. }) => {
                    // Arbitrary user kernels may legally overlap writes
                    // across SMs; the rejected merge left gmem untouched,
                    // so fall back to the sequential reference path.
                    gpgpu.launch(
                        LaunchRequest::new(&pk, launch, &mut gmem).params(&params),
                    )
                }
                other => other,
            };
            let r = launched.map_err(|e| e.to_string())?;
            let data =
                gmem.read_words(read_back.0, read_back.1).map_err(|e| e.to_string())?;
            Ok(JobOutput {
                label: pk.kernel.name.clone(),
                cycles: r.total.cycles,
                exec_time_ms: r.exec_time_ms(),
                stats: r.total,
                data,
                verified: true,
                shard,
                variant: variant.to_string(),
            })
        }
    }
}
