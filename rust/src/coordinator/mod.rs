//! The coordinator: the paper's "MicroBlaze driver" role (§3.1) as a
//! long-lived service — it owns a fleet of soft-GPGPU device shards,
//! accepts kernel-launch requests over bounded submit queues, DMAs data
//! in and out of device memory, and reports per-job, per-shard,
//! per-variant and aggregate metrics.
//!
//! # Fleet architecture
//!
//! `GpgpuService` hosts a *heterogeneous* fleet: each [`VariantSpec`]
//! names a (possibly §4.2-customized) device configuration and how many
//! shards of it to run. Every variant group has its own bounded
//! work-stealing [`ShardedQueue`] (one deque per shard, CAS-reserved
//! capacity, round-robin pushes; a dry shard steals from its siblings,
//! so an idle shard takes the next job the moment one exists anywhere in
//! the group — see `coordinator/queue.rs` for the protocol). `submit`
//! computes the job's
//! [`CapabilitySignature`] (profiled when registered, static otherwise)
//! and **routes** it through the QoS scorer in `coordinator/router.rs`:
//! under light load the lowest-modeled-dynamic-power covering variant
//! wins (bit-equal power ties spread round-robin instead of pinning);
//! once that variant is pressured past the job's class-specific
//! threshold, live signals — queue depth, in-flight jobs, shard health
//! (quarantine state) — rescore every covering variant and the job
//! *spills* to the best one. [`Request::qos`] tags a job with a
//! [`QosClass`] (`Latency` / `Throughput` / `BestEffort`) that weights
//! the score and gates admission: a deadline'd `Latency` submit sheds
//! `Saturated` immediately when no healthy covering variant has queue
//! slack. Uncovered signatures still fall back to the most-capable
//! (baseline) variant — the paper's stored-bitstream scenario (§5.2) as
//! a runtime scheduling concern. The routed signature travels with the
//! job and the shard's launch admits on exactly that signature
//! (`LaunchRequest::admit`), so a profile-refined requirement can never
//! be re-rejected by the static one on the variant the router chose; a
//! *lying* profile surfaces as the structured mid-run removed-unit or
//! stack-overflow trap, failing only its own ticket. Backpressure applies
//! per variant queue once `queue_depth` jobs are waiting. Every
//! admission decision lands in [`RoutingSnapshot`]
//! (`GpgpuService::routing_stats()`): routed/spilled/tie-broken/shed per
//! variant, elastic scale events, per-class p50/p95 queue wait.
//!
//! # Elastic capacity
//!
//! With [`FleetConfig::with_elastic`] a supervisor thread samples each
//! variant's queue backlog every `sample_ms` and rebalances shard counts
//! within `[min_shards, max_shards]`: sustained backlog spins up a
//! parked shard slot (its worker thread starts on the spot), and a
//! variant idle for `idle_samples` consecutive samples retires its
//! highest-indexed live shard **drain-then-retire** — the retire flag
//! stops intake at the worker's next poll, any job it already holds
//! completes, and queued jobs remain for its siblings, so no ticket is
//! ever lost to a scale-down. Queue shards are pre-sized to
//! `max_shards`, so rebalancing never reallocates the queue.
//!
//! Kernel binaries reach the devices through the process-wide
//! [`KernelRegistry`], so repeat launches of the same benchmark skip
//! assembly, pre-decode and signature analysis; each job's launch uses
//! the parallel multi-SM path (`LaunchRequest::parallel`), so a 2-SM
//! shard simulates its SMs concurrently while other shards run other
//! jobs.
//!
//! # Resilience
//!
//! The service plane is self-healing on top of the `sim/fault.rs` SEU
//! model. Failures travel the job channel as a typed [`ServiceError`]
//! (the underlying [`SimError`] is preserved, not stringified), and a
//! [`RecoveryPolicy`] on the fleet turns detected upsets into recovery:
//! transient failures — a parity-detected `SimError::SoftError`, a
//! golden-verification mismatch, a DMR replica disagreement — are
//! retried up to `max_attempts` executions, each retry **re-routed** to
//! a different covering variant when one exists; a shard that faults
//! `quarantine_after` consecutive times is quarantined (it sits out
//! `quarantine_ms` while its peers absorb the queue) and returns on
//! probation, where a single further fault re-quarantines it.
//! [`VariantSpec::with_fault`] marks one shard of a variant sick with a
//! deterministic [`FaultPlan`], reseeded per execution so retries and
//! redundant replicas draw fresh fault sites. [`Request::dmr`] wraps any
//! request in dual-modular redundancy — run twice, compare outputs —
//! catching the silent data-path corruption class parity cannot see;
//! [`Request::tmr`] goes one further with triple-modular redundancy,
//! majority-voting three replicas so a single corrupted replica is
//! *masked* rather than merely detected (a three-way split fails with
//! [`ServiceError::TmrInconclusive`]). Redundancy wrappers do not nest:
//! `dmr().dmr()` or `tmr().dmr()` multiplies executions without adding
//! coverage, so submit rejects the shape with
//! [`ServiceError::NestedRedundancy`] before it reaches a queue.
//! [`FleetConfig::with_checkpoint`] arms every launch with the
//! barrier-checkpoint/restart policy from `sim/sm.rs`: uncorrectable
//! upsets replay from the last block-wide barrier instead of failing the
//! job, and an escaped `SoftError` on a checkpoint-armed fleet is treated
//! as a cheap re-admit — it re-routes without accruing a quarantine
//! strike, since the launch already burned its restart budget on genuine
//! fault pressure. [`GpgpuService::submit_timeout`] sheds load with
//! [`ServiceError::Saturated`] instead of blocking forever, and
//! submitters blocked on a full queue resolve their tickets with
//! [`ServiceError::Shutdown`] when the service drops mid-drain.
//!
//! Shutdown is graceful: dropping the service stops intake, lets every
//! group drain its queued jobs (each ticket still resolves), then joins
//! the worker threads.
//!
//! tokio is unavailable in this offline image (DESIGN.md §substitutions),
//! so the pool uses plain threads + std::sync::mpsc reply channels; the
//! API shape (submit -> ticket -> await) is what an async driver would
//! expose.

pub mod customize;
pub mod queue;
pub mod router;

pub use customize::{analyze_kernel, profile, CustomizationReport};
pub use queue::{Popped, PushError, ShardedQueue};
pub use router::{QosClass, RouterMode, RoutingSnapshot, VariantRouting, WaitQuantiles};

use crate::asm::Kernel;
use crate::gpgpu::{Gpgpu, GpgpuConfig, LaunchConfig, LaunchRequest};
use crate::isa::CapabilitySignature;
use crate::kernels::{self, BenchId, RunOptions};
use crate::model::{power::power, ArchParams};
use crate::registry::{KernelRegistry, PreparedKernel};
use crate::sim::{CheckpointPolicy, FaultPlan, GlobalMem, SimError, SmStats};
use router::{RouteDecision, RouteKind, RoutingStats, VariantSignals};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A kernel-launch request.
pub enum Request {
    /// Run a prepared paper benchmark (data generation + verification
    /// handled by the service).
    Bench { id: BenchId, n: u32, seed: u64 },
    /// Launch an arbitrary assembled kernel: the driver writes `inputs`
    /// into device memory, launches, and reads `read_back` words out.
    ///
    /// Executed through the parallel mode of `Gpgpu::launch`. If the
    /// kernel's blocks overlap writes across SMs, the rejected merge
    /// leaves device memory untouched and the shard transparently retries
    /// the request in sequential mode (which permits overlapping writes,
    /// SM order). One contract remains on the caller
    /// for multi-SM devices: blocks must not *read* data written by
    /// blocks on another SM within the same launch — that dependency is
    /// undetectable (see `gpgpu` module docs) and such kernels should be
    /// split into phases or run on a 1-SM service.
    Kernel {
        kernel: Box<Kernel>,
        launch: LaunchConfig,
        params: Vec<i32>,
        gmem_bytes: u32,
        inputs: Vec<(u32, Vec<i32>)>,
        read_back: (u32, usize),
    },
    /// Dual-modular redundancy: execute the inner request twice and
    /// compare outputs (cycles, read-back data, verification outcome).
    /// Disagreement fails the job with [`ServiceError::DmrMismatch`] —
    /// the detection net for silent data-path SEU corruption that the
    /// parity-modeled checks cannot see.
    Dmr(Box<Request>),
    /// Triple-modular redundancy: execute the inner request three times
    /// and majority-vote the outputs (cycles, read-back data,
    /// verification outcome). Where DMR only *detects* divergence, TMR
    /// *corrects* it — a single corrupted or failed replica is outvoted
    /// by the agreeing pair and masked
    /// ([`MetricsSnapshot::tmr_outvoted`] counts the masks); a three-way
    /// disagreement fails with [`ServiceError::TmrInconclusive`].
    Tmr(Box<Request>),
    /// Tag the inner request with a latency class for the QoS router
    /// (see [`Request::qos`]). Untagged requests default to
    /// [`QosClass::Throughput`].
    Qos { class: QosClass, inner: Box<Request> },
}

impl Request {
    /// Wrap this request in dual-modular-redundancy mode.
    pub fn dmr(self) -> Request {
        Request::Dmr(Box::new(self))
    }

    /// Wrap this request in triple-modular-redundancy mode: three
    /// replicas, majority vote. Unlike [`Request::dmr`] (detect-only),
    /// TMR masks a single corrupted replica and still serves the job.
    pub fn tmr(self) -> Request {
        Request::Tmr(Box::new(self))
    }

    /// Tag this request with a QoS latency class: `Latency` weighs queue
    /// slack heavily (and sheds deadline'd submits when nothing healthy
    /// has room), `Throughput` is the balanced default, `BestEffort`
    /// rides the power-cheapest variant until it is nearly saturated.
    pub fn qos(self, class: QosClass) -> Request {
        Request::Qos { class, inner: Box::new(self) }
    }
}

/// Peel `Qos` wrappers off a request (the outermost class wins; nesting
/// through `Dmr` is resolved at execution, which ignores the tag).
fn strip_qos(req: Request) -> (Request, QosClass) {
    match req {
        Request::Qos { class, inner } => (strip_qos(*inner).0, class),
        other => (other, QosClass::default()),
    }
}

/// Redundancy wrappers (`Dmr`/`Tmr`) along the request chain, looking
/// through QoS tags. More than one is a rejected shape: `dmr().dmr()`
/// runs the kernel four times to detect exactly what one wrapper already
/// detects, and `tmr().dmr()` votes on votes — cost without coverage.
fn redundancy_depth(req: &Request) -> u32 {
    match req {
        Request::Dmr(inner) | Request::Tmr(inner) => 1 + redundancy_depth(inner),
        Request::Qos { inner, .. } => redundancy_depth(inner),
        _ => 0,
    }
}

/// Structured job failure, replacing the stringly `Result<_, String>`
/// channel: the underlying [`SimError`] survives intact for callers that
/// match on it, while `Display` preserves the old message text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServiceError {
    /// The launch itself failed (structured simulator error — including
    /// `SimError::SoftError` for parity-detected upsets).
    Sim(SimError),
    /// Device output disagreed with the golden reference (a `Bench` job's
    /// built-in corruption check).
    Verify(String),
    /// The job panicked inside the shard (e.g. a malformed request
    /// tripping an assert in preparation).
    Panic(String),
    /// The coordinator shut down before the job could run (or while the
    /// submitter was blocked on a full queue).
    Shutdown,
    /// `submit_timeout` elapsed with the routed queue still full.
    Saturated,
    /// DMR replicas disagreed — silent corruption caught by redundancy.
    DmrMismatch { variant: String },
    /// All three TMR replicas produced distinct outputs — no majority to
    /// vote with, so redundancy cannot say which replica to trust.
    TmrInconclusive { variant: String },
    /// The request nested redundancy wrappers (`dmr().dmr()`,
    /// `tmr().dmr()`, ...) — rejected at submit: stacked redundancy
    /// multiplies executions without adding detection or correction.
    NestedRedundancy,
}

impl ServiceError {
    /// Transient, fault-class failures: eligible for retry/re-route and
    /// counted against shard health. Deterministic failures (unsupported
    /// ops, bad geometry, panics, watchdog) are not — re-running those
    /// wastes a shard.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            ServiceError::Sim(SimError::SoftError { .. })
                | ServiceError::Verify(_)
                | ServiceError::DmrMismatch { .. }
                | ServiceError::TmrInconclusive { .. }
        )
    }
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Sim(e) => write!(f, "{e}"),
            ServiceError::Verify(msg) => write!(f, "{msg}"),
            ServiceError::Panic(msg) => write!(f, "job panicked: {msg}"),
            ServiceError::Shutdown => write!(f, "coordinator shut down"),
            ServiceError::Saturated => write!(f, "service saturated: submit queue full"),
            ServiceError::DmrMismatch { variant } => {
                write!(f, "DMR mismatch on variant {variant}: replica outputs disagree")
            }
            ServiceError::TmrInconclusive { variant } => {
                write!(f, "TMR inconclusive on variant {variant}: all three replicas disagree")
            }
            ServiceError::NestedRedundancy => {
                write!(f, "nested redundancy wrappers rejected: DMR/TMR do not compose")
            }
        }
    }
}

impl std::error::Error for ServiceError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServiceError::Sim(e) => Some(e),
            _ => None,
        }
    }
}

/// What a completed job returns.
#[derive(Debug, Clone)]
pub struct JobOutput {
    pub label: String,
    pub cycles: u64,
    pub exec_time_ms: f64,
    pub stats: SmStats,
    /// For `Request::Kernel`: the words read back from device memory.
    pub data: Vec<i32>,
    /// For `Request::Bench`: golden verification outcome.
    pub verified: bool,
    /// Fleet shard that executed the job (global index, variant-major).
    pub shard: u32,
    /// Label of the variant the router admitted the job to.
    pub variant: String,
    /// Executions consumed (1 = first try succeeded; >1 means the job
    /// was rescued by retry/re-route).
    pub attempts: u32,
}

/// Handle to an in-flight job.
pub struct JobTicket {
    rx: mpsc::Receiver<Result<JobOutput, ServiceError>>,
}

impl JobTicket {
    /// Block until the job completes. A dropped reply channel (the shard
    /// exited mid-drain) resolves as [`ServiceError::Shutdown`].
    pub fn wait(self) -> Result<JobOutput, ServiceError> {
        self.rx.recv().unwrap_or(Err(ServiceError::Shutdown))
    }
}

/// Pool shape of a *homogeneous* service: how many identical shards serve
/// the queue, and how many jobs may wait before `submit` applies
/// backpressure. (Kept as the simple entry point; heterogeneous fleets
/// use [`FleetConfig`].)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Worker threads, each owning one GPGPU device instance.
    pub shards: u32,
    /// Maximum queued (not yet running) jobs before `submit` blocks.
    pub queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { shards: 1, queue_depth: 64 }
    }
}

/// One device variant in a heterogeneous fleet.
#[derive(Debug, Clone)]
pub struct VariantSpec {
    /// Display label (e.g. `ArchParams::label()`'s "1 SM - 8 SP, stack 2,
    /// no mul").
    pub label: String,
    pub cfg: GpgpuConfig,
    /// Shards (worker threads) hosting this variant.
    pub shards: u32,
    /// Deterministic SEU campaign applied to one shard of this variant
    /// (local shard index, plan) — the "sick shard" of a resilience
    /// experiment. The plan is reseeded per execution from a per-shard
    /// nonce so retries and DMR replicas draw fresh fault sites.
    pub fault: Option<(u32, FaultPlan)>,
}

impl VariantSpec {
    pub fn new(label: impl Into<String>, cfg: GpgpuConfig) -> VariantSpec {
        VariantSpec { label: label.into(), cfg, shards: 1, fault: None }
    }

    /// Host `shards` workers of this variant.
    pub fn with_shards(mut self, shards: u32) -> VariantSpec {
        self.shards = shards;
        self
    }

    /// Inject the plan's SEU campaign on local shard `shard`.
    pub fn with_fault(mut self, shard: u32, plan: FaultPlan) -> VariantSpec {
        self.fault = Some((shard, plan));
        self
    }
}

/// How the fleet reacts to transient (fault-class) job failures. The
/// default is the pre-resilience behavior: no retries, no quarantine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecoveryPolicy {
    /// Executions allowed per job (1 = fail on the first fault).
    pub max_attempts: u32,
    /// Consecutive transient faults before a shard is quarantined
    /// (0 disables quarantine).
    pub quarantine_after: u32,
    /// How long a quarantined shard sits out before returning on
    /// probation, in milliseconds.
    pub quarantine_ms: u64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy { max_attempts: 1, quarantine_after: 0, quarantine_ms: 20 }
    }
}

impl RecoveryPolicy {
    /// Retry-only policy: up to `max_attempts` executions, no quarantine.
    pub fn retry(max_attempts: u32) -> RecoveryPolicy {
        RecoveryPolicy { max_attempts: max_attempts.max(1), ..Default::default() }
    }

    /// Retry + quarantine after `quarantine_after` consecutive faults.
    pub fn retry_quarantine(max_attempts: u32, quarantine_after: u32) -> RecoveryPolicy {
        RecoveryPolicy {
            max_attempts: max_attempts.max(1),
            quarantine_after,
            ..Default::default()
        }
    }
}

/// Elastic rebalancing bounds and cadence ([`FleetConfig::with_elastic`]).
/// Every variant's live shard count floats within
/// `[min_shards, max_shards]`; its spec's `shards` is the starting point
/// (clamped into the band).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ElasticConfig {
    /// Live-shard floor per variant (≥ 1 — a variant never loses its
    /// last worker, so queued jobs always drain).
    pub min_shards: u32,
    /// Live-shard ceiling per variant; queue shards are pre-sized to
    /// this, so scaling never reallocates.
    pub max_shards: u32,
    /// Supervisor sampling period, milliseconds.
    pub sample_ms: u64,
    /// Queued jobs per live shard that trigger a scale-up.
    pub scale_up_backlog: f64,
    /// Consecutive idle (zero queued, zero in-flight) samples before a
    /// shard is retired.
    pub idle_samples: u32,
}

impl ElasticConfig {
    pub fn new(min_shards: u32, max_shards: u32) -> ElasticConfig {
        let min_shards = min_shards.max(1);
        ElasticConfig {
            min_shards,
            max_shards: max_shards.max(min_shards),
            sample_ms: 5,
            scale_up_backlog: 1.5,
            idle_samples: 3,
        }
    }

    pub fn with_sample_ms(mut self, ms: u64) -> ElasticConfig {
        self.sample_ms = ms.max(1);
        self
    }
}

/// A heterogeneous fleet: customized variants + (normally) the baseline.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub variants: Vec<VariantSpec>,
    /// Per-variant-queue depth before `submit` blocks.
    pub queue_depth: usize,
    /// Reaction to transient job failures (default: none).
    pub policy: RecoveryPolicy,
    /// Fleet-wide per-launch cycle-budget override (default: the device
    /// watchdog).
    pub watchdog: Option<u64>,
    /// Admission routing scheme (default: QoS scoring; `Static` keeps
    /// the PR-3 power-only router as a measurable baseline).
    pub mode: RouterMode,
    /// Elastic rebalancing (default: off — shard counts are fixed).
    pub elastic: Option<ElasticConfig>,
    /// Barrier checkpoint/restart policy applied to every launch
    /// (default: off — an uncorrectable upset fails the execution). When
    /// armed, escaped `SoftError`s also stop counting as quarantine
    /// strikes: the launch already replayed through its restart budget,
    /// so the escape reflects fault pressure, not a sick shard.
    pub checkpoint: Option<CheckpointPolicy>,
}

impl FleetConfig {
    /// A fleet with default depth/policy — extend with the `with_*`
    /// builders.
    pub fn new(variants: Vec<VariantSpec>) -> FleetConfig {
        FleetConfig {
            variants,
            queue_depth: 64,
            policy: RecoveryPolicy::default(),
            watchdog: None,
            mode: RouterMode::default(),
            elastic: None,
            checkpoint: None,
        }
    }

    /// A single-variant fleet — the homogeneous pool the seed service ran.
    pub fn homogeneous(cfg: GpgpuConfig, pool: ServiceConfig) -> FleetConfig {
        FleetConfig::new(vec![VariantSpec {
            label: "baseline".to_string(),
            cfg,
            shards: pool.shards.max(1),
            fault: None,
        }])
        .with_depth(pool.queue_depth)
    }

    pub fn with_depth(mut self, queue_depth: usize) -> FleetConfig {
        self.queue_depth = queue_depth.max(1);
        self
    }

    pub fn with_policy(mut self, policy: RecoveryPolicy) -> FleetConfig {
        self.policy = policy;
        self
    }

    pub fn with_watchdog(mut self, cycles: u64) -> FleetConfig {
        self.watchdog = Some(cycles);
        self
    }

    /// Select the admission routing scheme.
    pub fn with_router(mut self, mode: RouterMode) -> FleetConfig {
        self.mode = mode;
        self
    }

    /// Enable the elastic rebalancer with the given bounds/cadence.
    pub fn with_elastic(mut self, elastic: ElasticConfig) -> FleetConfig {
        self.elastic = Some(elastic);
        self
    }

    /// Arm every launch with barrier checkpoint/restart: uncorrectable
    /// upsets replay from the last block-wide barrier reconvergence
    /// instead of failing the job (`sim/sm.rs` checkpoint machinery).
    pub fn with_checkpoint(mut self, policy: CheckpointPolicy) -> FleetConfig {
        self.checkpoint = Some(policy);
        self
    }
}

/// Aggregate counters for one shard.
#[derive(Debug, Default)]
pub struct Metrics {
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub total_cycles: AtomicU64,
    pub total_instructions: AtomicU64,
    /// Transient (fault-class) failures observed on this shard.
    pub soft_errors: AtomicU64,
    /// Jobs this shard faulted that were re-admitted elsewhere.
    pub jobs_retried: AtomicU64,
    /// Times this shard entered quarantine.
    pub quarantines: AtomicU64,
    /// Times this shard returned from quarantine to probation.
    pub reinstatements: AtomicU64,
    /// DMR replica disagreements detected on this shard.
    pub dmr_mismatches: AtomicU64,
    /// TMR replicas outvoted (masked) on this shard — each one is a
    /// corrupted or failed replica the majority corrected through.
    pub tmr_outvoted: AtomicU64,
    /// Total nanoseconds jobs dispatched by this shard spent between
    /// submit and dispatch (queue wait, including submit backpressure).
    pub queue_wait_ns: AtomicU64,
}

impl Metrics {
    fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            total_cycles: self.total_cycles.load(Ordering::Relaxed),
            total_instructions: self.total_instructions.load(Ordering::Relaxed),
            soft_errors: self.soft_errors.load(Ordering::Relaxed),
            jobs_retried: self.jobs_retried.load(Ordering::Relaxed),
            quarantines: self.quarantines.load(Ordering::Relaxed),
            reinstatements: self.reinstatements.load(Ordering::Relaxed),
            dmr_mismatches: self.dmr_mismatches.load(Ordering::Relaxed),
            tmr_outvoted: self.tmr_outvoted.load(Ordering::Relaxed),
            queue_wait_ns: self.queue_wait_ns.load(Ordering::Relaxed),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MetricsSnapshot {
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub total_cycles: u64,
    pub total_instructions: u64,
    pub soft_errors: u64,
    pub jobs_retried: u64,
    pub quarantines: u64,
    pub reinstatements: u64,
    pub dmr_mismatches: u64,
    pub tmr_outvoted: u64,
    pub queue_wait_ns: u64,
}

impl MetricsSnapshot {
    /// Element-wise sum — aggregate view over shards.
    pub fn merged(&self, other: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_completed: self.jobs_completed + other.jobs_completed,
            jobs_failed: self.jobs_failed + other.jobs_failed,
            total_cycles: self.total_cycles + other.total_cycles,
            total_instructions: self.total_instructions + other.total_instructions,
            soft_errors: self.soft_errors + other.soft_errors,
            jobs_retried: self.jobs_retried + other.jobs_retried,
            quarantines: self.quarantines + other.quarantines,
            reinstatements: self.reinstatements + other.reinstatements,
            dmr_mismatches: self.dmr_mismatches + other.dmr_mismatches,
            tmr_outvoted: self.tmr_outvoted + other.tmr_outvoted,
            queue_wait_ns: self.queue_wait_ns + other.queue_wait_ns,
        }
    }
}

/// A queued job: the request, the signature the router admitted it on
/// (the shard launches with exactly this signature — see
/// `LaunchRequest::admit` — so profile refinement can never self-reject
/// on the routed variant), retry bookkeeping, and the reply channel.
struct Job {
    req: Request,
    sig: CapabilitySignature,
    /// Latency class the router admitted the job under (per-class wait
    /// accounting on dispatch).
    class: QosClass,
    /// Executions already consumed.
    attempts: u32,
    /// Variant indices that already faulted this job (re-route excludes
    /// them while an untried covering variant remains).
    tried: Vec<usize>,
    /// When this job entered (or re-entered) a queue — stamped only once
    /// a queue slot is reserved (`push_with`) and re-stamped on retry
    /// re-admission, so the shard that dispatches it accumulates pure
    /// queue residency into [`Metrics::queue_wait_ns`].
    enqueued_at: Instant,
    reply: mpsc::Sender<Result<JobOutput, ServiceError>>,
}

/// One shard position of a variant: its worker's metrics, health flags,
/// and (optional) SEU campaign. Elastic fleets pre-allocate
/// `max_shards` slots; `active` says whether a worker currently serves
/// the slot.
struct ShardSlot {
    metrics: Arc<Metrics>,
    /// Worker should keep taking jobs. Cleared by the rebalancer to
    /// retire the shard (drain-then-retire: the worker finishes the job
    /// it holds, leaves queued work to its siblings, and exits at its
    /// next poll).
    active: AtomicBool,
    /// A worker thread currently occupies this slot (spawned and not yet
    /// exited) — keeps a scale-up from doubling up on a slot whose
    /// retiring worker has not finished leaving.
    occupied: AtomicBool,
    /// The worker is sitting out a quarantine — the router treats the
    /// shard as unhealthy until it returns on probation.
    quarantined: AtomicBool,
    /// Deterministic SEU campaign (None = healthy hardware).
    fault: Option<FaultPlan>,
}

/// One running variant group: its queue, its shard slots, live-capacity
/// counters, and the routing key (modeled dynamic power).
struct Variant {
    label: String,
    cfg: GpgpuConfig,
    dyn_w: f64,
    /// Work-stealing submit queue: one deque per shard slot.
    queue: ShardedQueue<Job>,
    slots: Vec<ShardSlot>,
    /// Slots with a serving worker (≤ `slots.len()`).
    live: AtomicUsize,
    /// Jobs currently executing on this variant's shards.
    inflight: AtomicUsize,
    /// Global shard id of local slot 0 (ids are variant-major and stable
    /// across rebalancing because slots are pre-allocated).
    shard_base: u32,
}

impl Variant {
    /// Live shards not sitting out a quarantine.
    fn healthy(&self) -> usize {
        let quarantined =
            self.slots.iter().filter(|s| s.quarantined.load(Ordering::SeqCst)).count();
        self.live.load(Ordering::SeqCst).saturating_sub(quarantined)
    }
}

/// The fleet state shared between the service handle and every worker —
/// workers need the full variant table to re-route faulted jobs.
struct FleetInner {
    variants: Vec<Variant>,
    /// Index of the most-capable variant — the routing fallback.
    fallback: usize,
    policy: RecoveryPolicy,
    watchdog: Option<u64>,
    mode: RouterMode,
    /// Barrier checkpoint/restart policy every launch runs under
    /// ([`FleetConfig::with_checkpoint`]).
    checkpoint: Option<CheckpointPolicy>,
    /// Per-variant-queue capacity (the router's utilization denominator).
    depth: usize,
    routing: RoutingStats,
}

impl FleetInner {
    /// Live router inputs for one job signature.
    fn signals(&self, sig: &CapabilitySignature) -> Vec<VariantSignals> {
        self.variants
            .iter()
            .map(|v| VariantSignals {
                covers: v.cfg.sm.covers(sig),
                dyn_w: v.dyn_w,
                queued: v.queue.len(),
                inflight: v.inflight.load(Ordering::SeqCst),
                healthy: v.healthy(),
                depth: self.depth,
            })
            .collect()
    }

    fn decide(&self, class: QosClass, sig: &CapabilitySignature) -> RouteDecision {
        router::decide(self.mode, class, &self.signals(sig), self.fallback, self.routing.rr())
    }

    /// Re-admit a faulted job: the cheapest covering variant it has not
    /// faulted on yet — preferring one with a healthy shard, so a retry
    /// does not queue behind the very quarantine that failed it — or
    /// back in place when every covering variant has been tried. Retries
    /// bypass the depth limit *and* shutdown — a worker must never block
    /// on a full queue (possibly its own) while holding a job, and a
    /// re-admitted job's ticket must still resolve even mid-drain.
    fn readmit(&self, mut job: Job, from: usize) {
        let pick = |healthy_only: bool| {
            self.variants
                .iter()
                .enumerate()
                .filter(|(i, v)| {
                    !job.tried.contains(i)
                        && v.cfg.sm.covers(&job.sig)
                        && (!healthy_only || v.healthy() > 0)
                })
                .min_by(|(_, a), (_, b)| a.dyn_w.total_cmp(&b.dyn_w))
                .map(|(i, _)| i)
        };
        let target = pick(true).or_else(|| pick(false)).unwrap_or(from);
        // Re-stamp: the failed execution must not count as queue wait.
        job.enqueued_at = Instant::now();
        self.variants[target].queue.push_unbounded(job);
    }
}

/// The GPGPU service: a capability-routed fleet of device-variant groups.
pub struct GpgpuService {
    inner: Arc<FleetInner>,
    workers: Vec<JoinHandle<()>>,
    /// Workers spawned by the elastic rebalancer after construction.
    extra_workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// The rebalancer thread (elastic fleets only).
    supervisor: Option<JoinHandle<()>>,
    /// Profile-refined signatures registered per benchmark (paper §4.1:
    /// representative-data profiling decides which bitstream suffices).
    /// `RwLock` (read-mostly), with explicit poison recovery: a panicked
    /// writer must not brick every later submit.
    profiles: RwLock<HashMap<BenchId, CapabilitySignature>>,
    /// The fallback (most capable) variant's device configuration.
    pub cfg: GpgpuConfig,
    /// Aggregate pool shape (total shard slots across variants).
    pub pool: ServiceConfig,
}

impl GpgpuService {
    /// Single-shard service (the seed API — one worker owning one device).
    pub fn start(cfg: GpgpuConfig) -> GpgpuService {
        GpgpuService::start_pool(cfg, ServiceConfig::default())
    }

    /// Start a pool of `pool.shards` identical device shards.
    pub fn start_pool(cfg: GpgpuConfig, pool: ServiceConfig) -> GpgpuService {
        GpgpuService::start_fleet(FleetConfig::homogeneous(cfg, pool))
    }

    /// Start a heterogeneous fleet: one worker group per variant, jobs
    /// routed by capability signature + QoS score.
    pub fn start_fleet(fleet: FleetConfig) -> GpgpuService {
        assert!(!fleet.variants.is_empty(), "fleet needs at least one variant");
        let depth = fleet.queue_depth.max(1);
        let elastic = fleet.elastic;
        let mut variants = Vec::with_capacity(fleet.variants.len());
        let mut shard_base = 0u32;
        for spec in fleet.variants {
            let spec_shards = spec.shards.max(1) as usize;
            // Elastic fleets pre-allocate max_shards slots (queue shards
            // included) and start with the spec's count clamped into the
            // band; fixed fleets get exactly what the spec asked for.
            let (initial, slot_count) = match &elastic {
                Some(e) => (
                    spec_shards.clamp(e.min_shards as usize, e.max_shards as usize),
                    e.max_shards as usize,
                ),
                None => (spec_shards, spec_shards),
            };
            let mut slots: Vec<ShardSlot> = (0..slot_count)
                .map(|i| ShardSlot {
                    metrics: Arc::new(Metrics::default()),
                    active: AtomicBool::new(i < initial),
                    occupied: AtomicBool::new(false),
                    quarantined: AtomicBool::new(false),
                    fault: None,
                })
                .collect();
            if let Some((s, plan)) = spec.fault {
                if let Some(slot) = slots.get_mut(s as usize) {
                    slot.fault = Some(plan);
                }
            }
            let dyn_w = power(&ArchParams::from_config(&spec.cfg)).dynamic_w;
            variants.push(Variant {
                label: spec.label,
                cfg: spec.cfg,
                dyn_w,
                queue: ShardedQueue::new(slot_count, depth),
                live: AtomicUsize::new(initial),
                inflight: AtomicUsize::new(0),
                shard_base,
                slots,
            });
            shard_base += slot_count as u32;
        }
        // Fallback: the most capable variant (multiplier before stack
        // depth before operand count) — "the full baseline device" in any
        // sensibly-specified fleet.
        let fallback = variants
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| {
                (v.cfg.sm.has_multiplier, v.cfg.sm.warp_stack_depth, v.cfg.sm.read_operands)
            })
            .map(|(i, _)| i)
            .expect("non-empty fleet");
        let routing = RoutingStats::new(variants.len());
        let inner = Arc::new(FleetInner {
            variants,
            fallback,
            policy: fleet.policy,
            watchdog: fleet.watchdog,
            mode: fleet.mode,
            checkpoint: fleet.checkpoint,
            depth,
            routing,
        });
        let mut workers = Vec::new();
        for vidx in 0..inner.variants.len() {
            for local in 0..inner.variants[vidx].slots.len() {
                if inner.variants[vidx].slots[local].active.load(Ordering::SeqCst) {
                    workers.push(spawn_shard(&inner, vidx, local));
                }
            }
        }
        let extra_workers = Arc::new(Mutex::new(Vec::new()));
        let supervisor = elastic.map(|e| {
            let inner = inner.clone();
            let extra = extra_workers.clone();
            std::thread::spawn(move || rebalancer(&inner, e, &extra))
        });
        let cfg = inner.variants[inner.fallback].cfg;
        let pool = ServiceConfig { shards: shard_base, queue_depth: depth };
        GpgpuService {
            inner,
            workers,
            extra_workers,
            supervisor,
            profiles: RwLock::new(HashMap::new()),
            cfg,
            pool,
        }
    }

    /// Register a profile-refined signature for a benchmark (from
    /// [`CustomizationReport::refined_signature`]). Subsequent `Bench`
    /// jobs route on the measured requirements instead of the
    /// conservative static ones — what lets autocorr land on a depth-16
    /// variant and matmul on a depth-0 one.
    pub fn register_profile(&self, id: BenchId, sig: CapabilitySignature) {
        // A writer that panicked mid-insert poisons the lock; the map is
        // at worst missing that one entry (routing then falls back to the
        // conservative static signature), so recover instead of
        // propagating the poison to every later submit.
        self.profiles
            .write()
            .unwrap_or_else(|poisoned| poisoned.into_inner())
            .insert(id, sig);
    }

    /// The signature the router admits a request on.
    fn job_signature(&self, req: &Request) -> CapabilitySignature {
        match req {
            Request::Bench { id, .. } => {
                let profiles =
                    self.profiles.read().unwrap_or_else(|poisoned| poisoned.into_inner());
                if let Some(sig) = profiles.get(id) {
                    return *sig;
                }
                drop(profiles);
                KernelRegistry::global()
                    .get_or_assemble(id.source())
                    .expect("benchmark kernels must assemble")
                    .sig
            }
            Request::Kernel { kernel, .. } => kernel.signature(),
            Request::Dmr(inner) | Request::Tmr(inner) | Request::Qos { inner, .. } => {
                self.job_signature(inner)
            }
        }
    }

    fn enqueue(&self, req: Request, timeout: Option<Duration>) -> Result<JobTicket, ServiceError> {
        let (req, class) = strip_qos(req);
        if redundancy_depth(&req) > 1 {
            // Stacked DMR/TMR wrappers are a rejected shape, not a
            // queueable job: resolve the ticket with the typed error (like
            // the shutdown path) so `submit` callers still get a ticket.
            let (reply_tx, reply_rx) = mpsc::channel();
            let _ = reply_tx.send(Err(ServiceError::NestedRedundancy));
            return Ok(JobTicket { rx: reply_rx });
        }
        let sig = self.job_signature(&req);
        let decision = self.inner.decide(class, &sig);
        if decision.gated && class == QosClass::Latency && timeout.is_some() {
            // Latency admission gate: every covering variant is saturated
            // or unhealthy — shed now instead of burning the deadline
            // blocked on a queue that cannot make timely progress.
            self.inner.routing.record_shed(decision.target);
            return Err(ServiceError::Saturated);
        }
        let queue = &self.inner.variants[decision.target].queue;
        let (reply_tx, reply_rx) = mpsc::channel();
        let deadline = timeout.map(|t| Instant::now() + t);
        let reply = reply_tx.clone();
        // Deferred construction: `enqueued_at` is stamped only once a
        // queue slot is reserved, so submit-side backpressure blocking
        // never counts as queue residency (`Metrics::queue_wait_ns`).
        let make = move || Job {
            req,
            sig,
            class,
            attempts: 0,
            tried: Vec::new(),
            enqueued_at: Instant::now(),
            reply,
        };
        match queue.push_with(make, deadline) {
            Ok(()) => {
                self.inner.routing.record_decision(decision.target, decision.kind);
                Ok(JobTicket { rx: reply_rx })
            }
            Err(PushError::Shutdown(_)) => {
                // Intake stopped before (or while) this submitter waited:
                // resolve the ticket with a structured shutdown error
                // instead of enqueueing into a closing queue (which could
                // leave the ticket hanging after the shards exit).
                let _ = reply_tx.send(Err(ServiceError::Shutdown));
                Ok(JobTicket { rx: reply_rx })
            }
            Err(PushError::Timeout(_)) => {
                self.inner.routing.record_shed(decision.target);
                Err(ServiceError::Saturated)
            }
        }
    }

    /// Queue a job on its routed variant; returns immediately with a
    /// ticket unless that variant's queue is at `queue_depth`, in which
    /// case it blocks until a shard drains it. If the service shuts down
    /// while the submitter is blocked, the ticket resolves with
    /// [`ServiceError::Shutdown`] instead of hanging.
    pub fn submit(&self, req: Request) -> JobTicket {
        self.enqueue(req, None).expect("untimed submit never sheds")
    }

    /// `submit` with load-shedding: if the routed queue is still full
    /// after `timeout`, gives up with [`ServiceError::Saturated`] instead
    /// of blocking forever.
    pub fn submit_timeout(
        &self,
        req: Request,
        timeout: Duration,
    ) -> Result<JobTicket, ServiceError> {
        self.enqueue(req, Some(timeout))
    }

    /// Aggregate metrics over every shard of every variant.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shard_metrics()
            .iter()
            .fold(MetricsSnapshot::default(), |acc, m| acc.merged(m))
    }

    /// Per-shard metrics (index = global shard id, variant-major; elastic
    /// fleets report every pre-allocated slot, parked ones all-zero).
    pub fn shard_metrics(&self) -> Vec<MetricsSnapshot> {
        self.inner
            .variants
            .iter()
            .flat_map(|v| v.slots.iter().map(|s| s.metrics.snapshot()))
            .collect()
    }

    /// Per-variant metrics: (label, merged counters over its shards).
    pub fn variant_metrics(&self) -> Vec<(String, MetricsSnapshot)> {
        self.inner
            .variants
            .iter()
            .map(|v| {
                let merged = v
                    .slots
                    .iter()
                    .fold(MetricsSnapshot::default(), |acc, s| {
                        acc.merged(&s.metrics.snapshot())
                    });
                (v.label.clone(), merged)
            })
            .collect()
    }

    /// (label, modeled dynamic power W) per variant — the routing order.
    pub fn variant_power(&self) -> Vec<(String, f64)> {
        self.inner.variants.iter().map(|v| (v.label.clone(), v.dyn_w)).collect()
    }

    /// Admission/rebalance observability: per-variant
    /// routed/spilled/tie-broken/shed counts, elastic scale events, and
    /// per-class queue-wait quantiles.
    pub fn routing_stats(&self) -> RoutingSnapshot {
        let labels: Vec<String> =
            self.inner.variants.iter().map(|v| v.label.clone()).collect();
        self.inner.routing.snapshot(&labels)
    }

    /// Per-variant capacity: (label, live shards, total slots). For
    /// fixed fleets live == slots; elastic fleets float live within the
    /// configured band.
    pub fn variant_shards(&self) -> Vec<(String, u32, u32)> {
        self.inner
            .variants
            .iter()
            .map(|v| {
                (
                    v.label.clone(),
                    v.live.load(Ordering::SeqCst) as u32,
                    v.slots.len() as u32,
                )
            })
            .collect()
    }

    /// Stop intake on every variant queue: already-queued jobs still
    /// drain (their tickets resolve), submitters blocked on a full queue
    /// wake with [`ServiceError::Shutdown`], and later submits resolve
    /// the same way. Idempotent; `Drop` calls it before joining.
    pub fn shutdown(&self) {
        for v in &self.inner.variants {
            v.queue.shutdown();
        }
    }
}

impl Drop for GpgpuService {
    fn drop(&mut self) {
        // Graceful shutdown: stop intake on every variant queue, let the
        // shards drain (every already-submitted ticket still resolves),
        // then join. The supervisor goes first — once it is down, no new
        // workers can appear behind the drain of `extra_workers`.
        self.shutdown();
        if let Some(supervisor) = self.supervisor.take() {
            let _ = supervisor.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let extras: Vec<JoinHandle<()>> = std::mem::take(
            &mut *self.extra_workers.lock().unwrap_or_else(|poisoned| poisoned.into_inner()),
        );
        for w in extras {
            let _ = w.join();
        }
    }
}

/// Start a worker thread on `slots[local]` of variant `vidx`. Marks the
/// slot occupied before the thread runs so a racing scale-up cannot
/// double-book it.
fn spawn_shard(inner: &Arc<FleetInner>, vidx: usize, local: usize) -> JoinHandle<()> {
    inner.variants[vidx].slots[local].occupied.store(true, Ordering::SeqCst);
    let fleet = inner.clone();
    std::thread::spawn(move || {
        shard_worker(&fleet, vidx, local);
        fleet.variants[vidx].slots[local].occupied.store(false, Ordering::SeqCst);
    })
}

/// The elastic rebalancer: samples every variant's backlog each
/// `sample_ms` and floats live shard counts within
/// `[min_shards, max_shards]`. Scale-up activates the first parked slot
/// and spawns its worker; scale-down clears the highest live slot's
/// `active` flag (drain-then-retire — the worker exits at its next poll,
/// after finishing any job it holds). Exits once the fleet shuts down.
fn rebalancer(inner: &Arc<FleetInner>, cfg: ElasticConfig, extra: &Mutex<Vec<JoinHandle<()>>>) {
    let min = cfg.min_shards.max(1) as usize;
    let mut idle = vec![0u32; inner.variants.len()];
    loop {
        std::thread::sleep(Duration::from_millis(cfg.sample_ms.max(1)));
        if inner.variants.iter().any(|v| v.queue.is_shutdown()) {
            return;
        }
        for (vidx, v) in inner.variants.iter().enumerate() {
            let live = v.live.load(Ordering::SeqCst);
            let queued = v.queue.len();
            let inflight = v.inflight.load(Ordering::SeqCst);
            let backlog = queued as f64 / live.max(1) as f64;
            if backlog >= cfg.scale_up_backlog && live < v.slots.len() {
                // A parked slot whose previous worker has fully exited
                // (never double-book a slot mid-retirement).
                let parked = v.slots.iter().position(|s| {
                    !s.active.load(Ordering::SeqCst) && !s.occupied.load(Ordering::SeqCst)
                });
                if let Some(local) = parked {
                    v.slots[local].active.store(true, Ordering::SeqCst);
                    v.live.fetch_add(1, Ordering::SeqCst);
                    let handle = spawn_shard(inner, vidx, local);
                    extra
                        .lock()
                        .unwrap_or_else(|poisoned| poisoned.into_inner())
                        .push(handle);
                    inner.routing.scale_ups.fetch_add(1, Ordering::Relaxed);
                }
                idle[vidx] = 0;
            } else if queued == 0 && inflight == 0 && live > min {
                idle[vidx] += 1;
                if idle[vidx] >= cfg.idle_samples {
                    if let Some(local) =
                        v.slots.iter().rposition(|s| s.active.load(Ordering::SeqCst))
                    {
                        v.slots[local].active.store(false, Ordering::SeqCst);
                        v.live.fetch_sub(1, Ordering::SeqCst);
                        inner.routing.scale_downs.fetch_add(1, Ordering::Relaxed);
                    }
                    idle[vidx] = 0;
                }
            } else {
                idle[vidx] = 0;
            }
        }
    }
}

/// How long a worker waits on an empty queue before re-checking its
/// slot's retire flag — the upper bound on how stale a scale-down is.
const WORKER_POLL: Duration = Duration::from_millis(20);

/// Quarantine sleeps are sliced so a shutdown (or service drop) during a
/// long quarantine resolves within one slice, not `quarantine_ms`.
const QUARANTINE_SLICE: Duration = Duration::from_millis(10);

/// One shard: owns a device, pulls jobs from its variant's queue until
/// retired or shut down + drained, and tracks its own health
/// (consecutive-fault quarantine with probation-based reinstatement,
/// published to the router through the slot's `quarantined` flag).
fn shard_worker(fleet: &FleetInner, vidx: usize, local: usize) {
    let v = &fleet.variants[vidx];
    let slot = &v.slots[local];
    let metrics = &slot.metrics;
    let shard = v.shard_base + local as u32;
    let gpgpu = Gpgpu::new(v.cfg);
    let base_fault = slot.fault;
    let mut fault_nonce = 0u64;
    let mut consecutive = 0u32;
    let mut probation = false;
    loop {
        // Drain-then-retire: a cleared `active` flag stops intake here —
        // queued jobs stay for the siblings, the job just finished (if
        // any) already resolved its ticket.
        if !slot.active.load(Ordering::SeqCst) {
            break;
        }
        // Own deque first, then steal from sibling shards; bounded wait
        // so the retire flag is honored even while the queue is idle.
        let mut job = match v.queue.try_pop_for(local, WORKER_POLL) {
            Popped::Item(job) => job,
            Popped::Empty => continue,
            Popped::Closed => break,
        };
        v.inflight.fetch_add(1, Ordering::SeqCst);
        let wait_ns = job.enqueued_at.elapsed().as_nanos() as u64;
        metrics.queue_wait_ns.fetch_add(wait_ns, Ordering::Relaxed);
        fleet.routing.record_wait(job.class, wait_ns);
        job.attempts += 1;
        // A panicking job (e.g. a malformed Bench size tripping an assert
        // in kernels::prepare) must fail its own ticket, not kill the
        // shard — a dead shard would leave later tickets hanging forever.
        let nonce = &mut fault_nonce;
        let result = catch_unwind(AssertUnwindSafe(|| {
            execute(
                &gpgpu,
                shard,
                &v.label,
                &job.req,
                job.sig,
                fleet.watchdog,
                fleet.checkpoint,
                metrics,
                || {
                    base_fault.map(|p| {
                        *nonce = nonce.wrapping_add(1);
                        // Fresh fault sites per execution: replays and
                        // DMR/TMR replicas must not repeat the same upsets.
                        FaultPlan {
                            seed: p.seed ^ nonce.wrapping_mul(0x9E37_79B9_7F4A_7C15),
                            ..p
                        }
                    })
                },
            )
        }))
        .unwrap_or_else(|payload| {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "unknown panic".to_string());
            Err(ServiceError::Panic(msg))
        });
        v.inflight.fetch_sub(1, Ordering::SeqCst);
        match result {
            Ok(mut out) => {
                out.attempts = job.attempts;
                metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                metrics.total_cycles.fetch_add(out.cycles, Ordering::Relaxed);
                metrics
                    .total_instructions
                    .fetch_add(out.stats.instructions, Ordering::Relaxed);
                consecutive = 0;
                probation = false;
                let _ = job.reply.send(Ok(out));
            }
            Err(err) => {
                let transient = err.is_transient();
                // A checkpoint-armed fleet treats an escaped SoftError as
                // a cheap re-admit, not a health strike: the launch
                // already replayed through its restart budget, so the
                // escape measures fault pressure, not shard sickness.
                let strikes = transient
                    && !(fleet.checkpoint.is_some()
                        && matches!(err, ServiceError::Sim(SimError::SoftError { .. })));
                if transient {
                    metrics.soft_errors.fetch_add(1, Ordering::Relaxed);
                    if matches!(err, ServiceError::DmrMismatch { .. }) {
                        metrics.dmr_mismatches.fetch_add(1, Ordering::Relaxed);
                    }
                }
                if transient && job.attempts < fleet.policy.max_attempts {
                    metrics.jobs_retried.fetch_add(1, Ordering::Relaxed);
                    job.tried.push(vidx);
                    fleet.readmit(job, vidx);
                } else {
                    metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                    let _ = job.reply.send(Err(err));
                }
                if strikes && fleet.policy.quarantine_after > 0 {
                    consecutive += 1;
                    if probation || consecutive >= fleet.policy.quarantine_after {
                        // Quarantine: sit out while healthy peers absorb
                        // the queue, then return on probation (one more
                        // fault re-quarantines immediately). The slot's
                        // `quarantined` flag steers the QoS router away
                        // for the duration; the sleep is sliced so
                        // shutdown mid-quarantine resolves promptly.
                        metrics.quarantines.fetch_add(1, Ordering::Relaxed);
                        slot.quarantined.store(true, Ordering::SeqCst);
                        let until =
                            Instant::now() + Duration::from_millis(fleet.policy.quarantine_ms);
                        loop {
                            let remaining = until.saturating_duration_since(Instant::now());
                            if remaining.is_zero() || v.queue.is_shutdown() {
                                break;
                            }
                            std::thread::sleep(remaining.min(QUARANTINE_SLICE));
                        }
                        slot.quarantined.store(false, Ordering::SeqCst);
                        consecutive = 0;
                        probation = true;
                        metrics.reinstatements.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }
}

/// Execute one routed job, unwrapping redundancy: a DMR inner request
/// runs twice (each replica drawing its own fault plan) and the outputs
/// must agree; a TMR inner request runs three times and the majority
/// output wins ([`tmr_vote`]), with each masked replica counted into the
/// shard's `tmr_outvoted` metric.
#[allow(clippy::too_many_arguments)]
fn execute(
    gpgpu: &Gpgpu,
    shard: u32,
    variant: &str,
    req: &Request,
    sig: CapabilitySignature,
    watchdog: Option<u64>,
    checkpoint: Option<CheckpointPolicy>,
    metrics: &Metrics,
    mut fault: impl FnMut() -> Option<FaultPlan>,
) -> Result<JobOutput, ServiceError> {
    if let Request::Qos { inner, .. } = req {
        // The class was consumed at admission; execution ignores it.
        return execute(gpgpu, shard, variant, inner, sig, watchdog, checkpoint, metrics, fault);
    }
    if let Request::Dmr(inner) = req {
        let a = run_one(gpgpu, shard, variant, inner, sig, fault(), watchdog, checkpoint)?;
        let b = run_one(gpgpu, shard, variant, inner, sig, fault(), watchdog, checkpoint)?;
        return if a.cycles == b.cycles && a.data == b.data && a.verified == b.verified {
            Ok(a)
        } else {
            Err(ServiceError::DmrMismatch { variant: variant.to_string() })
        };
    }
    if let Request::Tmr(inner) = req {
        let replicas = [
            run_one(gpgpu, shard, variant, inner, sig, fault(), watchdog, checkpoint),
            run_one(gpgpu, shard, variant, inner, sig, fault(), watchdog, checkpoint),
            run_one(gpgpu, shard, variant, inner, sig, fault(), watchdog, checkpoint),
        ];
        let (voted, outvoted) = tmr_vote(replicas, variant);
        if outvoted > 0 {
            metrics.tmr_outvoted.fetch_add(outvoted, Ordering::Relaxed);
        }
        return voted;
    }
    run_one(gpgpu, shard, variant, req, sig, fault(), watchdog, checkpoint)
}

/// Majority-vote three TMR replica results. A pair of successful
/// replicas agreeing on (cycles, read-back data, verification outcome)
/// wins; every replica outside the winning key — a divergent output *or*
/// an outright failure — is masked and counted as outvoted. With no
/// agreeing pair, three clean-but-distinct outputs are
/// [`ServiceError::TmrInconclusive`] (redundancy cannot say which
/// replica to trust), and otherwise the first replica error surfaces
/// unchanged so retry classification still sees the underlying fault.
fn tmr_vote(
    replicas: [Result<JobOutput, ServiceError>; 3],
    variant: &str,
) -> (Result<JobOutput, ServiceError>, u64) {
    let mut winner = None;
    'search: for (i, a) in replicas.iter().enumerate() {
        let Ok(a) = a else { continue };
        for b in replicas.iter().skip(i + 1) {
            if let Ok(b) = b {
                if a.cycles == b.cycles && a.data == b.data && a.verified == b.verified {
                    winner = Some(i);
                    break 'search;
                }
            }
        }
    }
    match winner {
        Some(i) => {
            let Ok(w) = &replicas[i] else { unreachable!("winner is a success") };
            let (cycles, data, verified) = (w.cycles, w.data.clone(), w.verified);
            let agreeing = replicas
                .iter()
                .filter(|r| {
                    matches!(r, Ok(o) if o.cycles == cycles && o.data == data
                        && o.verified == verified)
                })
                .count() as u64;
            let out = replicas
                .into_iter()
                .nth(i)
                .and_then(Result::ok)
                .expect("winner index holds a success");
            (Ok(out), 3 - agreeing)
        }
        None if replicas.iter().all(Result::is_ok) => {
            (Err(ServiceError::TmrInconclusive { variant: variant.to_string() }), 0)
        }
        None => {
            let err = replicas
                .into_iter()
                .find_map(Result::err)
                .expect("no winning pair and not all succeeded");
            (Err(err), 0)
        }
    }
}

/// Execute one routed job. `sig` is the signature the router admitted the
/// job on (profile-refined for registered benchmarks): the launch admits
/// on exactly that signature, and the mid-run removed-unit / stack traps
/// are the structured backstop if a registered profile over-promised.
#[allow(clippy::too_many_arguments)]
fn run_one(
    gpgpu: &Gpgpu,
    shard: u32,
    variant: &str,
    req: &Request,
    sig: CapabilitySignature,
    fault: Option<FaultPlan>,
    watchdog: Option<u64>,
    checkpoint: Option<CheckpointPolicy>,
) -> Result<JobOutput, ServiceError> {
    match req {
        Request::Bench { id, n, seed } => {
            let w = kernels::prepare(*id, *n, *seed);
            let mut gmem = w.make_gmem();
            let mut opts = RunOptions::new().parallel().admit(sig);
            if let Some(plan) = &fault {
                opts = opts.fault(plan);
            }
            if let Some(cycles) = watchdog {
                opts = opts.watchdog(cycles);
            }
            if let Some(policy) = checkpoint {
                opts = opts.checkpoint(policy);
            }
            let run = w.run(gpgpu, &mut gmem, opts).map_err(ServiceError::Sim)?;
            let verified = w.verify(&gmem).map(|_| true).map_err(ServiceError::Verify)?;
            Ok(JobOutput {
                label: format!("{} n={n}", id.name()),
                cycles: run.cycles,
                exec_time_ms: run.exec_time_ms(),
                stats: run.stats,
                data: Vec::new(),
                verified,
                shard,
                variant: variant.to_string(),
                attempts: 1,
            })
        }
        Request::Kernel {
            kernel,
            launch,
            params,
            gmem_bytes,
            inputs,
            read_back,
        } => {
            // Pre-decode once per job (arbitrary kernels are not
            // interned); the signature was already derived at submit for
            // routing, so it is reused rather than re-walked.
            let pk = PreparedKernel::with_sig((**kernel).clone(), sig);
            let mut gmem = GlobalMem::new(*gmem_bytes);
            for (addr, words) in inputs {
                gmem.write_words(*addr, words).map_err(ServiceError::Sim)?;
            }
            let mut first = LaunchRequest::new(&pk, *launch, &mut gmem).params(params);
            if let Some(plan) = &fault {
                first = first.fault(plan);
            }
            if let Some(cycles) = watchdog {
                first = first.watchdog(cycles);
            }
            if let Some(policy) = checkpoint {
                first = first.checkpoint(policy);
            }
            let launched = match gpgpu.launch(first.parallel()) {
                Err(SimError::WriteConflict { .. }) => {
                    // Arbitrary user kernels may legally overlap writes
                    // across SMs; the rejected merge left gmem untouched,
                    // so fall back to the sequential reference path.
                    let mut second =
                        LaunchRequest::new(&pk, *launch, &mut gmem).params(params);
                    if let Some(plan) = &fault {
                        second = second.fault(plan);
                    }
                    if let Some(cycles) = watchdog {
                        second = second.watchdog(cycles);
                    }
                    if let Some(policy) = checkpoint {
                        second = second.checkpoint(policy);
                    }
                    gpgpu.launch(second)
                }
                other => other,
            };
            let r = launched.map_err(ServiceError::Sim)?;
            let data = gmem
                .read_words(read_back.0, read_back.1)
                .map_err(ServiceError::Sim)?;
            Ok(JobOutput {
                label: pk.kernel.name.clone(),
                cycles: r.total.cycles,
                exec_time_ms: r.exec_time_ms(),
                stats: r.total,
                data,
                verified: true,
                shard,
                variant: variant.to_string(),
                attempts: 1,
            })
        }
        Request::Dmr(inner) | Request::Tmr(inner) | Request::Qos { inner, .. } => {
            run_one(gpgpu, shard, variant, inner, sig, fault, watchdog, checkpoint)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{FaultSite, FaultState, FaultTargets};

    /// The worker's per-execution reseed constant: execution `k` on a
    /// sick shard draws its faults from `seed ^ k * GOLDEN`.
    const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

    fn bench() -> Request {
        Request::Bench { id: BenchId::VecAdd, n: 64, seed: 1 }
    }

    /// Clean cycle count of [`bench`] on the default device — the time
    /// base the fault-window seed searches below are anchored to.
    fn clean_cycles() -> u64 {
        let svc = GpgpuService::start(GpgpuConfig::default());
        svc.submit(bench()).wait().expect("clean bench runs").cycles
    }

    /// Search for a base seed whose *first-execution* fault schedule
    /// (nonce 1 on a fresh shard) fires exactly once inside a clean run:
    /// first upset before `clean / 2`, next one far beyond the replay.
    fn one_shot_plan(clean: u64, rate: f64) -> FaultPlan {
        (0u64..)
            .map(|n| {
                FaultPlan::new(n, rate).with_targets(FaultTargets {
                    instr_image: true,
                    ..FaultTargets::none()
                })
            })
            .find(|p| {
                let eff = FaultPlan { seed: p.seed ^ GOLDEN, ..*p };
                let mut fs = FaultState::new(&eff, 0).expect("enabled plan");
                let e1 = fs.next_event();
                fs.poll(e1);
                e1 < clean / 2 && fs.next_event() > e1 + 4 * clean
            })
            .expect("a one-shot seed exists")
    }

    #[test]
    fn strip_qos_takes_the_outermost_class() {
        let req = Request::Bench { id: BenchId::VecAdd, n: 16, seed: 1 }
            .qos(QosClass::BestEffort)
            .qos(QosClass::Latency);
        let (inner, class) = strip_qos(req);
        assert_eq!(class, QosClass::Latency);
        assert!(matches!(inner, Request::Bench { .. }));
        let (_, class) = strip_qos(Request::Bench { id: BenchId::VecAdd, n: 16, seed: 1 });
        assert_eq!(class, QosClass::Throughput, "untagged default");
    }

    #[test]
    fn poisoned_profile_lock_recovers_instead_of_bricking_submits() {
        let svc = Arc::new(GpgpuService::start(GpgpuConfig::default()));
        // Poison the profiles lock: a thread panics while holding the
        // write guard (the failure mode of a profiling writer dying
        // mid-registration).
        let svc2 = svc.clone();
        let poisoner = std::thread::spawn(move || {
            let _guard = svc2.profiles.write().unwrap();
            panic!("profiling writer dies while holding the lock");
        });
        assert!(poisoner.join().is_err(), "the poisoner must panic");
        assert!(svc.profiles.is_poisoned(), "the lock must actually be poisoned");
        // Registration and submission must both recover.
        let report = customize::profile(BenchId::VecAdd, 16, 3).expect("profiling runs");
        svc.register_profile(BenchId::VecAdd, report.refined_signature());
        let out = svc
            .submit(Request::Bench { id: BenchId::VecAdd, n: 16, seed: 3 })
            .wait()
            .expect("submit must survive a poisoned profiles lock");
        assert!(out.verified);
    }

    #[test]
    fn nested_redundancy_is_rejected_with_a_typed_error() {
        let svc = GpgpuService::start(GpgpuConfig::default());
        for req in [
            bench().dmr().dmr(),
            bench().tmr().dmr(),
            bench().tmr().tmr(),
            bench().dmr().qos(QosClass::Latency).tmr(),
        ] {
            let err = svc.submit(req).wait().unwrap_err();
            assert_eq!(err, ServiceError::NestedRedundancy);
            assert!(!err.is_transient(), "a rejected shape never earns a retry");
        }
        assert_eq!(svc.metrics().jobs_failed, 0, "rejected before reaching any shard");
        // Single wrappers (with or without a QoS tag) still run.
        assert!(svc.submit(bench().dmr()).wait().expect("dmr runs").verified);
        assert!(svc.submit(bench().tmr().qos(QosClass::BestEffort)).wait().unwrap().verified);
    }

    #[test]
    fn service_error_transience_classification_table() {
        let soft = ServiceError::Sim(SimError::SoftError {
            site: FaultSite::L1Tag { sm: 0, index: 3 },
            cycle: 17,
            bit: 5,
        });
        let table = [
            (soft, true),
            (ServiceError::Verify("golden mismatch".into()), true),
            (ServiceError::DmrMismatch { variant: "v".into() }, true),
            (ServiceError::TmrInconclusive { variant: "v".into() }, true),
            (ServiceError::Sim(SimError::Watchdog { cycles: 1 }), false),
            (
                ServiceError::Sim(SimError::MemFault {
                    space: "global",
                    addr: 4,
                    reason: "out of bounds",
                }),
                false,
            ),
            (ServiceError::Sim(SimError::LimitExceeded("block too big".into())), false),
            (ServiceError::Sim(SimError::RanOffCode { warp: 0, pc: 9 }), false),
            (ServiceError::Panic("assert tripped".into()), false),
            (ServiceError::Shutdown, false),
            (ServiceError::Saturated, false),
            (ServiceError::NestedRedundancy, false),
        ];
        for (err, want) in table {
            assert_eq!(err.is_transient(), want, "{err}");
        }
    }

    fn replica(cycles: u64, data: &[i32]) -> JobOutput {
        JobOutput {
            label: "t".into(),
            cycles,
            exec_time_ms: 0.0,
            stats: SmStats::default(),
            data: data.to_vec(),
            verified: true,
            shard: 0,
            variant: "v".into(),
            attempts: 1,
        }
    }

    #[test]
    fn tmr_vote_masks_one_corrupted_or_failed_replica() {
        // Corrupted middle replica: the agreeing pair wins, one mask.
        let (r, outvoted) =
            tmr_vote([Ok(replica(10, &[1])), Ok(replica(10, &[2])), Ok(replica(10, &[1]))], "v");
        assert_eq!(r.expect("majority wins").data, vec![1]);
        assert_eq!(outvoted, 1);
        // Failed middle replica: still a majority of successes.
        let (r, outvoted) = tmr_vote(
            [
                Ok(replica(10, &[1])),
                Err(ServiceError::Verify("corrupt".into())),
                Ok(replica(10, &[1])),
            ],
            "v",
        );
        assert!(r.is_ok());
        assert_eq!(outvoted, 1);
        // Unanimous vote: nothing masked.
        let (r, outvoted) =
            tmr_vote([Ok(replica(10, &[1])), Ok(replica(10, &[1])), Ok(replica(10, &[1]))], "v");
        assert!(r.is_ok());
        assert_eq!(outvoted, 0);
    }

    #[test]
    fn tmr_vote_without_a_majority_surfaces_the_right_error() {
        // Three clean but distinct outputs: no replica is trustworthy.
        let (r, outvoted) =
            tmr_vote([Ok(replica(1, &[1])), Ok(replica(2, &[2])), Ok(replica(3, &[3]))], "v");
        assert_eq!(r.unwrap_err(), ServiceError::TmrInconclusive { variant: "v".into() });
        assert_eq!(outvoted, 0);
        // A failure majority surfaces the first underlying fault intact,
        // so retry classification still sees the real error class.
        let (r, _) = tmr_vote(
            [
                Err(ServiceError::Verify("first".into())),
                Ok(replica(1, &[1])),
                Err(ServiceError::Verify("second".into())),
            ],
            "v",
        );
        assert_eq!(r.unwrap_err(), ServiceError::Verify("first".into()));
    }

    #[test]
    fn tmr_on_healthy_hardware_votes_unanimously() {
        let svc = GpgpuService::start(GpgpuConfig::default());
        let plain = svc.submit(bench()).wait().expect("plain run");
        let tmr = svc.submit(bench().tmr()).wait().expect("tmr run");
        assert!(tmr.verified);
        assert_eq!(tmr.cycles, plain.cycles, "replicas vote on the bit-identical output");
        assert_eq!(svc.metrics().tmr_outvoted, 0, "healthy replicas never outvote");
    }

    #[test]
    fn checkpointed_fleet_rescues_uncorrectable_faults() {
        let clean = clean_cycles();
        let plan = one_shot_plan(clean, 50.0);
        let fleet = FleetConfig::new(vec![
            VariantSpec::new("sick", GpgpuConfig::default()).with_fault(0, plan)
        ])
        .with_checkpoint(CheckpointPolicy::at_barriers());
        let svc = GpgpuService::start_fleet(fleet);
        let out = svc.submit(bench()).wait().expect("checkpoint rescues the launch");
        assert!(out.verified);
        assert!(out.stats.restarts >= 1, "the seeded upset must force a replay");
        assert!(out.cycles > clean, "replayed cycles are real wall-clock");
        let m = svc.metrics();
        assert_eq!(m.jobs_failed, 0);
        assert_eq!(m.soft_errors, 0, "the fault never escaped the launch");
    }

    #[test]
    fn checkpoint_armed_fleets_exempt_soft_error_escapes_from_quarantine() {
        let clean = clean_cycles();
        let plan = one_shot_plan(clean, 50.0);
        let sick = || {
            vec![VariantSpec::new("sick", GpgpuConfig::default()).with_fault(0, plan)]
        };
        let policy = RecoveryPolicy::retry_quarantine(1, 1);
        // Zero restart budget: the checkpoint machinery is armed but the
        // upset still escapes — the strike exemption alone is under test.
        let armed = GpgpuService::start_fleet(
            FleetConfig::new(sick())
                .with_policy(policy)
                .with_checkpoint(CheckpointPolicy::at_barriers().with_max_restarts(0)),
        );
        let err = armed.submit(bench()).wait().unwrap_err();
        assert!(matches!(err, ServiceError::Sim(SimError::SoftError { .. })), "{err}");
        // A (wrong) strike would land within microseconds of the reply;
        // a short grace makes a broken exemption show up here.
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(armed.metrics().quarantines, 0, "escape is fault pressure, not sickness");
        drop(armed);
        // Control: the identical escape on a checkpoint-less fleet is a
        // health strike and quarantines the shard. The counters land
        // after the reply resolves, so poll up to a deadline.
        let bare = GpgpuService::start_fleet(FleetConfig::new(sick()).with_policy(policy));
        let err = bare.submit(bench()).wait().unwrap_err();
        assert!(matches!(err, ServiceError::Sim(SimError::SoftError { .. })), "{err}");
        let deadline = Instant::now() + Duration::from_secs(5);
        while bare.metrics().reinstatements < 1 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        let m = bare.metrics();
        assert_eq!(m.quarantines, 1);
        assert_eq!(m.reinstatements, 1, "the shard returns on probation");
    }
}
