//! FlexGrip-RS instruction set architecture.
//!
//! A G80-subset integer SASS, mirroring the 27+ integer CUDA instructions
//! the paper reports testing (§5: "We tested 27 integer CUDA instructions").
//! Instructions are 4 or 8 bytes (paper §3.2: "fetching four or eight-byte
//! CUDA binary instructions"), fully predicated via 4-bit condition-code
//! predicate registers (paper §4.1, Fig. 2), with explicit divergence
//! management instructions (`SSY`/`JOIN`) driving the per-warp stack.
//!
//! Layout of the 8-byte encoding (little-endian words):
//!
//! ```text
//! word0: [0..7)  opcode      [7]      size8 flag
//!        [8..10) guard preg  [10..13) guard cond (0 = always)
//!        [13..19) dst reg    [19..25) src1 reg
//!        [25]    src2-is-imm [26]     set-predicate enable
//!        [27..29) set-pred idx        [29..32) embedded cond (ISET/SEL)
//! word1: imm32                        if src2-is-imm
//!        [0..6) src2  [6..12) src3  [12..28) off16  [28] use-areg
//!        [29..31) areg                       otherwise
//! ```
//!
//! Short (4-byte) forms carry only word0 (`NOP`, `EXIT`, `JOIN`, `BAR`,
//! `MOV` reg-reg, `NOT`, `S2R`, `R2A`, `A2R`).

mod cond;
pub mod decode;
pub mod disasm;
pub mod encode;
mod instr;
mod op;
pub mod sig;

pub use cond::{Cond, Flags};
pub use decode::{decode, decode_stream, DecodeError};
pub use disasm::{disassemble, disassemble_listing};
pub use encode::encode;
pub use instr::{Guard, Instr, MemSpace, Operand, SpecialReg};
pub use op::{Op, OpClass};
pub use sig::{Capability, CapabilitySignature, StackBound, MAX_STACK_BOUND};

/// General-purpose registers per thread (R0..=R62 usable, R63 is RZ).
pub const NUM_REGS: u8 = 64;
/// Register index that always reads zero and discards writes (like sm_2x RZ).
pub const RZ: u8 = 63;
/// Address registers per thread (FlexGrip address register file).
pub const NUM_AREGS: u8 = 4;
/// Predicate (condition-code) registers per thread (paper Fig. 2: p0..p3).
pub const NUM_PREGS: u8 = 4;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_count_covers_paper_claim() {
        // Paper §5: 27 integer instructions tested. We implement a superset.
        assert!(Op::ALL.len() >= 27, "ISA must cover the paper's 27 ops");
    }

    #[test]
    fn rz_is_last_register() {
        assert_eq!(RZ, NUM_REGS - 1);
    }
}
