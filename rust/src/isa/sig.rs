//! Capability signature: what a kernel *requires* from the hardware, as a
//! core ISA-layer concept (paper §4.2, §5.2).
//!
//! The paper derives minimal FlexGrip variants in two steps: a *static*
//! instruction analysis ("we can determine the minimal set of functions
//! needed to support each benchmark") decides whether the multiplier and
//! the third read-operand unit are needed at all, and *dynamic* profiling
//! with representative data finds the warp-stack high-water mark. This
//! module is the shared representation of both: [`CapabilitySignature`]
//! is computed statically from any instruction stream (the assembler and
//! launch admission use it directly) and can be *refined* by a profiling
//! run (the customization analyzer and the coordinator's fleet router use
//! the refined form).
//!
//! The static stack bound is a genuine upper bound: the analysis walks the
//! control-flow graph tracking the worst-case number of live warp-stack
//! entries, treating every guarded branch as potentially divergent. Code
//! whose pushes cannot be bounded statically (a push inside a loop — e.g.
//! autocorr's lane-retirement loop, which reaches depth 16 only at
//! runtime) saturates to [`StackBound::Unbounded`] rather than guessing.

use super::{Instr, Op};
use std::collections::HashMap;

/// Architectural warp-stack capacity (Table 1 / Table 6: depths 0..=32).
pub const MAX_STACK_BOUND: u32 = 32;

/// A hardware capability a kernel may require and a customized variant may
/// lack (§4.2). Carried by [`crate::sim::SimError::Unsupported`] for both
/// pre-flight admission rejects and mid-run traps.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Capability {
    /// The SP multiplier (DSP48E blocks; IMUL/IMAD).
    Multiplier,
    /// The third read-operand unit (IMAD only).
    ThirdReadOperand,
    /// Warp-stack capacity: the kernel needs `need` entries, the
    /// configuration provides `have`.
    StackDepth { need: u32, have: u32 },
}

impl std::fmt::Display for Capability {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Capability::Multiplier => write!(f, "the SP multiplier"),
            Capability::ThirdReadOperand => write!(f, "the third read-operand unit"),
            Capability::StackDepth { need, have } => {
                write!(f, "warp-stack depth {need} (configured {have})")
            }
        }
    }
}

/// Upper bound on the warp-stack high-water mark of a kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StackBound {
    /// The high-water mark provably (static analysis) or measuredly
    /// (profiling refinement) never exceeds this many entries (<= 32).
    AtMost(u32),
    /// Static analysis saturated (a push inside a loop): the depth is
    /// input-dependent. Pre-flight admission lets such kernels through —
    /// the runtime stack-overflow trap remains the backstop — but the
    /// conservative fleet router demands a full-depth device.
    Unbounded,
}

impl StackBound {
    /// The depth a device must provision to be *guaranteed* sufficient.
    pub fn required_depth(self) -> u32 {
        match self {
            StackBound::AtMost(b) => b,
            StackBound::Unbounded => MAX_STACK_BOUND,
        }
    }
}

/// What a kernel requires from the SM datapath — the paper's
/// customization axes, derived once and shared by the assembler, launch
/// admission ([`crate::sim::SmConfig::admit`]) and the coordinator's
/// variant router.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CapabilitySignature {
    /// Kernel encodes IMUL or IMAD -> multiplier required.
    pub uses_multiplier: bool,
    /// Kernel encodes IMAD -> third read operand required.
    pub uses_third_operand: bool,
    /// Kernel encodes SSY/BRA -> conditional hardware used at all.
    pub uses_branches: bool,
    /// Warp-stack requirement (static upper bound, or profiled).
    pub stack_bound: StackBound,
}

impl CapabilitySignature {
    /// Static analysis of a decoded instruction stream (the form stored in
    /// [`crate::asm::Kernel::instrs`]).
    pub fn of_program(instrs: &[(u32, Instr)]) -> CapabilitySignature {
        let mut uses_multiplier = false;
        let mut uses_third_operand = false;
        let mut uses_branches = false;
        let mut has_push_site = false;
        for (_, i) in instrs {
            uses_multiplier |= i.op.uses_multiplier();
            uses_third_operand |= i.op == Op::Imad;
            uses_branches |= matches!(i.op, Op::Bra | Op::Ssy);
            has_push_site |=
                i.op == Op::Ssy || (i.op == Op::Bra && !i.guard.is_unconditional());
        }
        let stack_bound = if has_push_site {
            static_stack_bound(instrs)
        } else {
            StackBound::AtMost(0)
        };
        CapabilitySignature { uses_multiplier, uses_third_operand, uses_branches, stack_bound }
    }

    /// Refine the static signature with a profiling run (paper §4.1:
    /// "profiling the application with representative data sets"): the
    /// measured warp-stack high-water mark replaces the static bound, and
    /// a multiplier that is encoded but never dynamically issued is
    /// dropped from the requirements.
    pub fn refined(self, measured_stack_depth: u32, multiplier_ops: u64) -> CapabilitySignature {
        let executed_mul = self.uses_multiplier && multiplier_ops > 0;
        CapabilitySignature {
            uses_multiplier: executed_mul,
            uses_third_operand: self.uses_third_operand && executed_mul,
            uses_branches: self.uses_branches,
            stack_bound: StackBound::AtMost(measured_stack_depth.min(MAX_STACK_BOUND)),
        }
    }
}

/// Worst-case warp-stack occupancy over every static control-flow path.
///
/// Depth-annotated reachability: each instruction is (re)visited whenever
/// it becomes reachable at a greater entry depth. `SSY` pushes one entry
/// (its reconvergence target later resumes at the push depth); a guarded
/// `BRA` may diverge, pushing one entry while both arms continue; `JOIN`
/// only pops (its successors are the addresses recorded at the matching
/// push sites); a (possibly guarded) `EXIT` may retire only part of the
/// warp, so its fall-through stays reachable. Any path that would exceed
/// [`MAX_STACK_BOUND`] entries saturates to [`StackBound::Unbounded`] —
/// that is what every push-inside-a-loop becomes, keeping the bound sound
/// without simulating trip counts.
fn static_stack_bound(instrs: &[(u32, Instr)]) -> StackBound {
    if instrs.is_empty() {
        return StackBound::AtMost(0);
    }
    let index: HashMap<u32, usize> =
        instrs.iter().enumerate().map(|(i, (pc, _))| (*pc, i)).collect();
    // Max entry depth seen per instruction (monotone -> termination).
    let mut best: Vec<Option<u32>> = vec![None; instrs.len()];
    let mut high = 0u32;
    let mut work: Vec<(usize, u32)> = vec![(0, 0)];
    while let Some((i, d)) = work.pop() {
        match best[i] {
            Some(b) if b >= d => continue,
            _ => best[i] = Some(d),
        }
        let (pc, instr) = &instrs[i];
        let next = pc + instr.size as u32;
        // Off-image targets are a fetch fault at runtime, not a stack
        // concern — their edges are simply dropped.
        let edge = |target: u32, depth: u32, work: &mut Vec<(usize, u32)>| {
            if let Some(&j) = index.get(&target) {
                work.push((j, depth));
            }
        };
        match instr.op {
            Op::Join => {}
            Op::Exit => edge(next, d, &mut work),
            Op::Ssy => {
                if d + 1 > MAX_STACK_BOUND {
                    return StackBound::Unbounded;
                }
                high = high.max(d + 1);
                let t = instr.branch_target().expect("SSY carries a target");
                edge(next, d + 1, &mut work);
                edge(t, d, &mut work);
            }
            Op::Bra => {
                let t = instr.branch_target().expect("BRA carries a target");
                if instr.guard.is_unconditional() {
                    edge(t, d, &mut work);
                } else {
                    if d + 1 > MAX_STACK_BOUND {
                        return StackBound::Unbounded;
                    }
                    high = high.max(d + 1);
                    edge(next, d + 1, &mut work);
                    edge(t, d + 1, &mut work);
                }
            }
            _ => edge(next, d, &mut work),
        }
    }
    StackBound::AtMost(high)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;

    fn sig_of(src: &str) -> CapabilitySignature {
        CapabilitySignature::of_program(&assemble(src).unwrap().instrs)
    }

    #[test]
    fn straight_line_kernel_needs_no_stack() {
        let s = sig_of("S2R R1, SR_GTID\nSHL R2, R1, #2\nGST [R2], R1\nEXIT");
        assert!(!s.uses_multiplier && !s.uses_third_operand && !s.uses_branches);
        assert_eq!(s.stack_bound, StackBound::AtMost(0));
    }

    #[test]
    fn mul_and_mad_detected() {
        let s = sig_of("IMUL R1, R2, R3\nEXIT");
        assert!(s.uses_multiplier && !s.uses_third_operand);
        let s = sig_of("IMAD R1, R2, R3, R4\nEXIT");
        assert!(s.uses_multiplier && s.uses_third_operand);
    }

    #[test]
    fn forward_divergence_bound_is_exact() {
        // SSY + one divergent BRA: runtime high-water is 2, and the static
        // walk proves exactly that on forward-only control flow.
        let s = sig_of(
            r#"
                S2R R0, SR_TID
                ISETP P0, R0, #4
                SSY reconv
                @P0.LT BRA then
                MOV R1, #222
                JOIN
            then:
                MOV R1, #111
                JOIN
            reconv:
                EXIT
            "#,
        );
        assert!(s.uses_branches);
        assert_eq!(s.stack_bound, StackBound::AtMost(2));
    }

    #[test]
    fn nested_ssy_counts_nesting() {
        let s = sig_of("SSY a\nSSY a\nSSY a\na:\nJOIN\nJOIN\nJOIN\nEXIT");
        assert_eq!(s.stack_bound, StackBound::AtMost(3));
    }

    #[test]
    fn partial_exit_keeps_fall_through_reachable() {
        // A guarded EXIT may retire only some lanes; the SSY after it must
        // still be counted.
        let s = sig_of("ISETP P0, R0, #4\n@P0.LT EXIT\nSSY e\nJOIN\ne:\nEXIT");
        assert_eq!(s.stack_bound, StackBound::AtMost(1));
    }

    #[test]
    fn push_inside_a_loop_saturates() {
        // Unbalanced: one SSY per iteration — depth is trip-count
        // dependent, so the static bound must refuse to guess.
        let s = sig_of("a:\nSSY b\nBRA a\nb:\nEXIT");
        assert_eq!(s.stack_bound, StackBound::Unbounded);
        // Guarded backward branch (every benchmark loop shape): same.
        let s = sig_of("top:\nISETP P0, R1, #0\n@P0.GT BRA top\nEXIT");
        assert_eq!(s.stack_bound, StackBound::Unbounded);
    }

    #[test]
    fn balanced_loop_stays_bounded() {
        // Push and pop per iteration, loop closed by a uniform branch:
        // the fixed point converges without saturating.
        let s = sig_of("top:\nSSY x\nJOIN\nx:\nBRA top\nEXIT");
        assert_eq!(s.stack_bound, StackBound::AtMost(1));
    }

    #[test]
    fn paper_benchmark_signatures() {
        use crate::kernels::BenchId;
        let sig = |id: BenchId| sig_of(id.source());
        assert!(!sig(BenchId::VecAdd).uses_branches);
        assert_eq!(sig(BenchId::VecAdd).stack_bound, StackBound::AtMost(0));
        assert!(!sig(BenchId::Bitonic).uses_multiplier, "paper §5.2");
        assert!(sig(BenchId::MatMul).uses_third_operand, "MAD loop");
        // Every looping benchmark's depth is dynamic (profiling's job).
        for id in [BenchId::Autocorr, BenchId::Bitonic, BenchId::MatMul] {
            assert_eq!(sig(id).stack_bound, StackBound::Unbounded, "{}", id.name());
        }
    }

    #[test]
    fn refinement_tightens_stack_and_drops_idle_multiplier() {
        let s = sig_of(crate::kernels::BenchId::MatMul.source());
        let r = s.refined(0, 12_345);
        assert_eq!(r.stack_bound, StackBound::AtMost(0));
        assert!(r.uses_multiplier, "dynamically used -> kept");
        let r = s.refined(2, 0);
        assert!(!r.uses_multiplier && !r.uses_third_operand, "never issued -> dropped");
        assert_eq!(r.stack_bound, StackBound::AtMost(2));
    }

    #[test]
    fn required_depth_saturates_unbounded() {
        assert_eq!(StackBound::AtMost(5).required_depth(), 5);
        assert_eq!(StackBound::Unbounded.required_depth(), MAX_STACK_BOUND);
    }
}
