//! Instruction decoder — the simulator's Decode stage (paper §3.2: "decodes
//! the binary instruction to generate several output tokens such as the
//! operation code, predicate data, source and destination operands").

use super::{Cond, Guard, Instr, Op, OpClass, Operand, SpecialReg};

/// Decode failures are architectural faults: the hardware would raise an
/// error condition to the driver; the simulator surfaces them to the
/// coordinator, which fails the kernel launch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// Opcode field does not name an implemented instruction.
    BadOpcode(u8),
    /// An 8-byte instruction was truncated by the end of instruction memory.
    Truncated { pc: u32 },
    /// A short encoding was used for an op that requires 8 bytes.
    BadShortForm(Op),
    /// S2R names a nonexistent special register.
    BadSpecial(u8),
    /// R2A/A2R/memory base names a nonexistent address register.
    BadAReg(u8),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::BadOpcode(v) => write!(f, "illegal opcode {v:#x}"),
            DecodeError::Truncated { pc } => {
                write!(f, "truncated 8-byte instruction at pc={pc:#x}")
            }
            DecodeError::BadShortForm(op) => {
                write!(f, "4-byte form illegal for {}", op.mnemonic())
            }
            DecodeError::BadSpecial(v) => write!(f, "bad special register {v}"),
            DecodeError::BadAReg(v) => write!(f, "bad address register {v}"),
        }
    }
}

impl std::error::Error for DecodeError {}

/// Decode the instruction starting at `pc` in `code`.
pub fn decode(code: &[u8], pc: u32) -> Result<Instr, DecodeError> {
    let at = pc as usize;
    if at + 4 > code.len() {
        return Err(DecodeError::Truncated { pc });
    }
    let word0 = u32::from_le_bytes(code[at..at + 4].try_into().unwrap());
    let opbits = (word0 & 0x7f) as u8;
    let op = Op::from_u8(opbits).ok_or(DecodeError::BadOpcode(opbits))?;
    let size8 = word0 & (1 << 7) != 0;
    if !size8 && !op.short_encodable() {
        return Err(DecodeError::BadShortForm(op));
    }
    let guard = Guard {
        preg: ((word0 >> 8) & 0x3) as u8,
        cond: Cond::from_u8(((word0 >> 10) & 0x7) as u8).unwrap(),
    };
    let dst_raw = ((word0 >> 13) & 0x3f) as u8;
    let s1_raw = ((word0 >> 19) & 0x3f) as u8;
    let s2imm = word0 & (1 << 25) != 0;
    let setp_en = word0 & (1 << 26) != 0;
    let setp_idx = ((word0 >> 27) & 0x3) as u8;
    let cond = Cond::from_u8(((word0 >> 29) & 0x7) as u8).unwrap();

    let (word1, size) = if size8 {
        if at + 8 > code.len() {
            return Err(DecodeError::Truncated { pc });
        }
        (u32::from_le_bytes(code[at + 4..at + 8].try_into().unwrap()), 8u8)
    } else {
        (0, 4)
    };

    // Raw word1 fields (non-immediate layout).
    let s2_raw = (word1 & 0x3f) as u8;
    let s3_raw = ((word1 >> 6) & 0x3f) as u8;
    let offset = ((word1 >> 12) & 0xffff) as u16 as i16;
    let use_areg = word1 & (1 << 28) != 0;
    let areg = ((word1 >> 29) & 0x3) as u8;

    let src2_imm = || Operand::Imm(word1 as i32);

    let mut i = Instr {
        op,
        guard,
        dst: dst_raw,
        src1: Operand::None,
        src2: Operand::None,
        src3: Operand::None,
        setp_en,
        setp_idx,
        cond,
        offset: 0,
        size,
    };

    match op.class() {
        OpClass::Control => {
            i.dst = 0;
            i.setp_en = false;
            i.setp_idx = 0;
            i.cond = Cond::Always;
        }
        OpClass::Unary => {
            i.src1 = match op {
                Op::S2r => Operand::Special(
                    SpecialReg::from_u8(s1_raw).ok_or(DecodeError::BadSpecial(s1_raw))?,
                ),
                Op::A2r => {
                    if s1_raw >= super::NUM_AREGS {
                        return Err(DecodeError::BadAReg(s1_raw));
                    }
                    Operand::AReg(s1_raw)
                }
                _ => Operand::Reg(s1_raw),
            };
            if op == Op::R2a && dst_raw >= super::NUM_AREGS {
                return Err(DecodeError::BadAReg(dst_raw));
            }
            // MOV with an immediate is the MVI form.
            if op == Op::Mov && s2imm {
                i.src1 = Operand::None;
                i.src2 = src2_imm();
            }
        }
        OpClass::Binary => {
            i.src1 = Operand::Reg(s1_raw);
            i.src2 = if s2imm { src2_imm() } else { Operand::Reg(s2_raw) };
        }
        OpClass::Ternary => {
            i.src1 = Operand::Reg(s1_raw);
            i.src2 = Operand::Reg(s2_raw);
            i.src3 = Operand::Reg(s3_raw);
        }
        OpClass::Branch => {
            i.dst = 0;
            i.src2 = src2_imm();
        }
        OpClass::Mem => {
            let base = if use_areg {
                if areg >= super::NUM_AREGS {
                    return Err(DecodeError::BadAReg(areg));
                }
                Operand::AReg(areg)
            } else {
                Operand::Reg(s1_raw)
            };
            i.src1 = base;
            i.offset = offset;
            if i.is_store() {
                i.dst = 0;
                i.src2 = Operand::Reg(s2_raw);
            }
        }
    }
    Ok(i)
}

/// Decode an entire code image into (byte_pc -> Instr), validating every
/// reachable encoding up front. Used by the simulator to pre-decode
/// kernels once per launch (performance: the Decode stage then indexes a
/// flat table instead of re-parsing bytes each issue).
pub fn decode_stream(code: &[u8]) -> Result<Vec<(u32, Instr)>, DecodeError> {
    let mut out = Vec::new();
    let mut pc = 0u32;
    while (pc as usize) < code.len() {
        let i = decode(code, pc)?;
        out.push((pc, i));
        pc += i.size as u32;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::encode::{encode, encode_program};
    use super::*;

    #[test]
    fn roundtrip_simple_alu() {
        let i = Instr {
            op: Op::Imad,
            dst: 5,
            src1: Operand::Reg(1),
            src2: Operand::Reg(2),
            src3: Operand::Reg(3),
            size: 8,
            ..Instr::NOP
        };
        assert_eq!(decode(&encode(&i), 0).unwrap(), i);
    }

    #[test]
    fn roundtrip_mem_with_areg_base() {
        let i = Instr {
            op: Op::Sst,
            src1: Operand::AReg(2),
            src2: Operand::Reg(9),
            offset: -64,
            size: 8,
            ..Instr::NOP
        };
        assert_eq!(decode(&encode(&i), 0).unwrap(), i);
    }

    #[test]
    fn roundtrip_mov_imm() {
        let i = Instr {
            op: Op::Mov,
            dst: 7,
            src2: Operand::Imm(i32::MIN),
            size: 8,
            ..Instr::NOP
        };
        assert_eq!(decode(&encode(&i), 0).unwrap(), i);
    }

    #[test]
    fn bad_opcode_detected() {
        let bytes = 0x7fu32.to_le_bytes();
        assert!(matches!(decode(&bytes, 0), Err(DecodeError::BadOpcode(0x7f))));
    }

    #[test]
    fn truncation_detected() {
        let i = Instr {
            op: Op::Bra,
            src2: Operand::Imm(0),
            size: 8,
            ..Instr::NOP
        };
        let b = encode(&i);
        assert!(matches!(
            decode(&b[..6], 0),
            Err(DecodeError::Truncated { pc: 0 })
        ));
    }

    #[test]
    fn stream_decoding_walks_mixed_sizes() {
        let prog = vec![
            Instr::NOP,
            Instr {
                op: Op::Iadd,
                dst: 1,
                src1: Operand::Reg(1),
                src2: Operand::Imm(1),
                size: 8,
                ..Instr::NOP
            },
            Instr { op: Op::Exit, ..Instr::NOP },
        ];
        let code = encode_program(&prog);
        let decoded = decode_stream(&code).unwrap();
        assert_eq!(decoded.len(), 3);
        assert_eq!(decoded[0].0, 0);
        assert_eq!(decoded[1].0, 4);
        assert_eq!(decoded[2].0, 12);
        assert_eq!(decoded[2].1.op, Op::Exit);
    }
}
