//! Condition-code flags and branch/guard conditions.
//!
//! Paper §4.1 / Fig. 2: "The execution of a conditional (predicate)
//! instruction results in the generation of a four-bit predicate for each
//! instruction (sign, zero, carry, and overflow). ... the value in the
//! selected predicate register and the condition for the instruction
//! (e.g. <, >, =) are used as an index into a lookup table to generate an
//! instruction mask." `Flags::eval` is exactly that lookup table.

/// The FlexGrip four-bit predicate: sign, zero, carry, overflow.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Flags {
    pub sign: bool,
    pub zero: bool,
    pub carry: bool,
    pub overflow: bool,
}

impl Flags {
    /// Flags of the subtraction `a - b`, the comparison primitive used by
    /// `ISETP`/`ISET` (signed compare semantics derive from sign/overflow).
    pub fn of_sub(a: i32, b: i32) -> Flags {
        let (res, ovf) = a.overflowing_sub(b);
        // Borrow convention: carry set when no borrow occurred (x86-style
        // inverted borrow keeps unsigned comparisons simple).
        let borrow = (a as u32) < (b as u32);
        Flags { sign: res < 0, zero: res == 0, carry: !borrow, overflow: ovf }
    }

    /// Pack into the 4-bit hardware representation (bit0=sign, bit1=zero,
    /// bit2=carry, bit3=overflow) — the format stored in the predicate
    /// register file and interchanged with the XLA ALU backend.
    pub fn pack(self) -> u8 {
        (self.sign as u8)
            | (self.zero as u8) << 1
            | (self.carry as u8) << 2
            | (self.overflow as u8) << 3
    }

    pub fn unpack(bits: u8) -> Flags {
        Flags {
            sign: bits & 1 != 0,
            zero: bits & 2 != 0,
            carry: bits & 4 != 0,
            overflow: bits & 8 != 0,
        }
    }

    /// The condition lookup table (Fig. 2): one mask bit per thread.
    pub fn eval(self, cond: Cond) -> bool {
        let lt = self.sign != self.overflow; // signed less-than
        match cond {
            Cond::Always => true,
            Cond::Eq => self.zero,
            Cond::Ne => !self.zero,
            Cond::Lt => lt,
            Cond::Le => self.zero || lt,
            Cond::Gt => !self.zero && !lt,
            Cond::Ge => !lt,
            Cond::Never => false,
        }
    }
}

/// Branch / guard conditions (3-bit field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Cond {
    /// Unconditional (no guard).
    Always = 0,
    Eq = 1,
    Ne = 2,
    Lt = 3,
    Le = 4,
    Gt = 5,
    Ge = 6,
    /// Never true — exists so failure-injection tests can encode dead code.
    Never = 7,
}

impl Cond {
    pub const ALL: [Cond; 8] = [
        Cond::Always, Cond::Eq, Cond::Ne, Cond::Lt, Cond::Le, Cond::Gt,
        Cond::Ge, Cond::Never,
    ];

    pub fn from_u8(v: u8) -> Option<Cond> {
        Cond::ALL.get(v as usize).copied()
    }

    pub fn name(self) -> &'static str {
        match self {
            Cond::Always => "T",
            Cond::Eq => "EQ",
            Cond::Ne => "NE",
            Cond::Lt => "LT",
            Cond::Le => "LE",
            Cond::Gt => "GT",
            Cond::Ge => "GE",
            Cond::Never => "NEVER",
        }
    }

    pub fn from_name(s: &str) -> Option<Cond> {
        Cond::ALL.iter().copied().find(|c| c.name() == s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check(a: i32, b: i32) {
        let f = Flags::of_sub(a, b);
        assert_eq!(f.eval(Cond::Eq), a == b, "{a} EQ {b}");
        assert_eq!(f.eval(Cond::Ne), a != b, "{a} NE {b}");
        assert_eq!(f.eval(Cond::Lt), a < b, "{a} LT {b}");
        assert_eq!(f.eval(Cond::Le), a <= b, "{a} LE {b}");
        assert_eq!(f.eval(Cond::Gt), a > b, "{a} GT {b}");
        assert_eq!(f.eval(Cond::Ge), a >= b, "{a} GE {b}");
        assert!(f.eval(Cond::Always));
        assert!(!f.eval(Cond::Never));
    }

    #[test]
    fn signed_compare_table_matches_rust_semantics() {
        let vals = [
            i32::MIN, i32::MIN + 1, -100, -1, 0, 1, 7, 100, i32::MAX - 1,
            i32::MAX,
        ];
        for &a in &vals {
            for &b in &vals {
                check(a, b);
            }
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        for bits in 0..16u8 {
            assert_eq!(Flags::unpack(bits).pack(), bits);
        }
    }

    #[test]
    fn cond_u8_roundtrip() {
        for (i, c) in Cond::ALL.iter().enumerate() {
            assert_eq!(*c as u8, i as u8);
            assert_eq!(Cond::from_u8(i as u8), Some(*c));
        }
    }

    #[test]
    fn overflow_case() {
        // i32::MIN - 1 overflows; signed LT must still be correct.
        let f = Flags::of_sub(i32::MIN, 1);
        assert!(f.eval(Cond::Lt));
        assert!(f.overflow);
    }
}
