//! Disassembler: decoded instructions back to assembler syntax. The
//! round-trip `assemble(disassemble(k)) == k.code` is tested below and
//! in the asm integration suite — the usual toolchain closure property.

use super::{Cond, Guard, Instr, Op, OpClass, Operand};

fn guard_str(g: Guard) -> String {
    if g.is_unconditional() {
        String::new()
    } else {
        format!("@P{}.{} ", g.preg, g.cond.name())
    }
}

fn src(o: Operand) -> String {
    match o {
        Operand::Reg(r) if r == super::RZ => "RZ".into(),
        Operand::Reg(r) => format!("R{r}"),
        Operand::Imm(v) => format!("#{v}"),
        Operand::Special(s) => s.name().into(),
        Operand::AReg(a) => format!("A{a}"),
        Operand::None => "<none>".into(),
    }
}

fn addr(i: &Instr) -> String {
    let base = src(i.src1);
    if i.offset == 0 {
        format!("[{base}]")
    } else if i.offset > 0 {
        format!("[{base}+{}]", i.offset)
    } else {
        format!("[{base}{}]", i.offset)
    }
}

/// Disassemble one instruction. Branch targets print as absolute-address
/// immediates (`BRA #64`), which the assembler accepts.
pub fn disassemble(i: &Instr) -> String {
    let g = guard_str(i.guard);
    let m = i.op.mnemonic();
    let body = match i.op.class() {
        OpClass::Control => m.to_string(),
        OpClass::Unary => match i.op {
            Op::Mov if matches!(i.src2, Operand::Imm(_)) => {
                format!("{m} R{}, {}", i.dst, src(i.src2))
            }
            Op::R2a => format!("{m} A{}, {}", i.dst, src(i.src1)),
            _ => format!("{m} R{}, {}", i.dst, src(i.src1)),
        },
        OpClass::Binary => match i.op {
            Op::Isetp => format!("{m} P{}, {}, {}", i.setp_idx, src(i.src1), src(i.src2)),
            Op::Iset => format!(
                "{m} R{}, {}, {}, {}",
                i.dst, src(i.src1), src(i.src2), i.cond.name()
            ),
            Op::Sel => format!(
                "{m} R{}, {}, {}, P{}.{}",
                i.dst, src(i.src1), src(i.src2), i.setp_idx, i.cond.name()
            ),
            _ => format!("{m} R{}, {}, {}", i.dst, src(i.src1), src(i.src2)),
        },
        OpClass::Ternary => format!(
            "{m} R{}, {}, {}, {}",
            i.dst, src(i.src1), src(i.src2), src(i.src3)
        ),
        OpClass::Branch => format!("{m} {}", src(i.src2)),
        OpClass::Mem => {
            if i.is_store() {
                format!("{m} {}, {}", addr(i), src(i.src2))
            } else {
                format!("{m} R{}, {}", i.dst, addr(i))
            }
        }
    };
    format!("{g}{body}")
}

/// Disassemble a whole decoded program as a listing with byte addresses.
pub fn disassemble_listing(instrs: &[(u32, Instr)]) -> String {
    instrs
        .iter()
        .map(|(pc, i)| format!("{pc:#06x}:  {}", disassemble(i)))
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;

    #[test]
    fn roundtrip_all_benchmark_kernels() {
        for id in crate::kernels::BenchId::ALL {
            let k = assemble(id.source()).unwrap();
            // Re-assemble the disassembly (plus resource directives) and
            // compare binaries.
            let listing: String = k
                .instrs
                .iter()
                .map(|(_, i)| disassemble(i))
                .collect::<Vec<_>>()
                .join("\n");
            let src = format!(".regs {}\n.smem {}\n{listing}\n", k.regs_per_thread, k.smem_bytes);
            let k2 = assemble(&src)
                .unwrap_or_else(|e| panic!("{}: {e}\n{src}", id.name()));
            assert_eq!(k.code, k2.code, "{} binary differs after roundtrip", id.name());
        }
    }

    #[test]
    fn formats_representative_instructions() {
        let k = assemble(
            "@P1.GE SEL R1, R2, #7, P3.LT\nGST [A2-8], R5\nSSY #16\nS2R R0, SR_TID\nEXIT",
        )
        .unwrap();
        let lines: Vec<String> = k.instrs.iter().map(|(_, i)| disassemble(i)).collect();
        assert_eq!(lines[0], "@P1.GE SEL R1, R2, #7, P3.LT");
        assert_eq!(lines[1], "GST [A2-8], R5");
        assert_eq!(lines[2], "SSY #16");
        assert_eq!(lines[3], "S2R R0, SR_TID");
        assert_eq!(lines[4], "EXIT");
    }

    #[test]
    fn listing_has_addresses() {
        let k = assemble("NOP\nMOV R1, #5\nEXIT").unwrap();
        let l = disassemble_listing(&k.instrs);
        assert!(l.contains("0x0000:  NOP"));
        assert!(l.contains("0x0004:  MOV R1, #5"));
        assert!(l.contains("0x000c:  EXIT"));
    }
}
