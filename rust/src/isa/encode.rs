//! Instruction encoder: `Instr` -> 4/8 binary bytes.
//!
//! The encoder is the assembler's backend and the decoder's test oracle —
//! `decode(encode(i)) == i` is property-tested in `rust/tests/isa_roundtrip.rs`.

use super::{Cond, Instr, Op, Operand};

fn src_reg_bits(op: Operand) -> u32 {
    match op {
        Operand::Reg(r) => r as u32,
        Operand::Special(s) => s as u32,
        Operand::AReg(a) => a as u32,
        Operand::None => super::RZ as u32,
        Operand::Imm(_) => panic!("immediate cannot occupy a register field"),
    }
}

/// Encode one instruction, appending 4 or 8 bytes to `out`.
///
/// Panics on malformed instructions (e.g. an immediate in src1); the
/// assembler only constructs well-formed `Instr`s, and the panic paths are
/// exercised by unit tests.
pub fn encode(i: &Instr) -> Vec<u8> {
    let mut word0: u32 = i.op as u32 & 0x7f;
    let size8 = i.size == 8;
    word0 |= (size8 as u32) << 7;
    word0 |= (i.guard.preg as u32 & 0x3) << 8;
    word0 |= (i.guard.cond as u32 & 0x7) << 10;
    word0 |= (i.dst as u32 & 0x3f) << 13;
    word0 |= (src_reg_bits(i.src1) & 0x3f) << 19;
    let s2imm = matches!(i.src2, Operand::Imm(_));
    word0 |= (s2imm as u32) << 25;
    word0 |= (i.setp_en as u32) << 26;
    word0 |= (i.setp_idx as u32 & 0x3) << 27;
    word0 |= (i.cond as u32 & 0x7) << 29;

    let mut out = word0.to_le_bytes().to_vec();
    if !size8 {
        assert!(
            i.op.short_encodable() && !s2imm,
            "op {:?} cannot use the 4-byte form",
            i.op
        );
        return out;
    }

    let word1: u32 = if let Operand::Imm(v) = i.src2 {
        v as u32
    } else {
        let use_areg = matches!(i.src1, Operand::AReg(_));
        let areg = match i.src1 {
            Operand::AReg(a) => a as u32,
            _ => 0,
        };
        (src_reg_bits(i.src2) & 0x3f)
            | (src_reg_bits(i.src3) & 0x3f) << 6
            | ((i.offset as u16) as u32) << 12
            | (use_areg as u32) << 28
            | (areg & 0x3) << 29
    };
    out.extend_from_slice(&word1.to_le_bytes());
    out
}

/// Encode a whole program (already laid out: branch targets are byte
/// offsets into the emitted stream).
pub fn encode_program(instrs: &[Instr]) -> Vec<u8> {
    let mut out = Vec::with_capacity(instrs.len() * 8);
    for i in instrs {
        out.extend_from_slice(&encode(i));
    }
    out
}

/// Compute each instruction's byte size without encoding — used by the
/// assembler's first pass for label layout.
pub fn instr_size(op: Op, src2_is_imm: bool) -> u8 {
    if op.short_encodable() && !src2_is_imm {
        4
    } else {
        8
    }
}

#[allow(unused)]
fn _cond_assert(c: Cond) -> u8 {
    c as u8
}

#[cfg(test)]
mod tests {
    use super::super::Guard;
    use super::*;

    #[test]
    fn nop_is_four_bytes() {
        assert_eq!(encode(&Instr::NOP).len(), 4);
    }

    #[test]
    fn imm_forces_eight_bytes() {
        let i = Instr {
            op: Op::Iadd,
            dst: 1,
            src1: Operand::Reg(2),
            src2: Operand::Imm(-7),
            size: 8,
            ..Instr::NOP
        };
        let b = encode(&i);
        assert_eq!(b.len(), 8);
        assert_eq!(i32::from_le_bytes(b[4..8].try_into().unwrap()), -7);
    }

    #[test]
    #[should_panic]
    fn short_form_rejects_binary_ops() {
        let i = Instr {
            op: Op::Iadd,
            src1: Operand::Reg(0),
            src2: Operand::Reg(1),
            size: 4,
            ..Instr::NOP
        };
        encode(&i);
    }

    #[test]
    fn guard_bits_land_in_word0() {
        let i = Instr {
            op: Op::Exit,
            guard: Guard { preg: 3, cond: Cond::Ge },
            ..Instr::NOP
        };
        let b = encode(&i);
        let w0 = u32::from_le_bytes(b[0..4].try_into().unwrap());
        assert_eq!((w0 >> 8) & 0x3, 3);
        assert_eq!((w0 >> 10) & 0x7, Cond::Ge as u32);
    }

    #[test]
    fn program_layout_is_packed() {
        let prog = vec![
            Instr::NOP,
            Instr {
                op: Op::Mov,
                dst: 1,
                src1: Operand::Reg(0),
                src2: Operand::Imm(5),
                size: 8,
                ..Instr::NOP
            },
            Instr { op: Op::Exit, ..Instr::NOP },
        ];
        assert_eq!(encode_program(&prog).len(), 4 + 8 + 4);
    }
}
