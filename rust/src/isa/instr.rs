//! Decoded instruction representation — the output of the Decode stage
//! ("operation code, predicate data, source and destination operands",
//! paper §3.2).

use super::{Cond, Op};

/// Special registers readable through `S2R`. FlexGrip's GPGPU controller
/// "initializes registers in the vector register file with respective
/// thread IDs" (paper §3.1); we expose the full CUDA-1.0 set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SpecialReg {
    /// Linear thread index within the block.
    TidX = 0,
    /// Threads per block.
    NtidX = 1,
    /// Block index, x dimension.
    CtaidX = 2,
    /// Grid size, x dimension.
    NctaidX = 3,
    /// Block index, y dimension.
    CtaidY = 4,
    /// Grid size, y dimension.
    NctaidY = 5,
    /// Lane within the warp (0..32).
    LaneId = 6,
    /// Warp index within the block.
    WarpId = 7,
    /// Streaming multiprocessor executing the thread.
    SmId = 8,
    /// Global linear thread id: (ctaid.y * nctaid.x + ctaid.x) * ntid + tid.
    GtId = 9,
}

impl SpecialReg {
    pub const ALL: [SpecialReg; 10] = [
        SpecialReg::TidX, SpecialReg::NtidX, SpecialReg::CtaidX,
        SpecialReg::NctaidX, SpecialReg::CtaidY, SpecialReg::NctaidY,
        SpecialReg::LaneId, SpecialReg::WarpId, SpecialReg::SmId,
        SpecialReg::GtId,
    ];

    pub fn from_u8(v: u8) -> Option<SpecialReg> {
        SpecialReg::ALL.get(v as usize).copied()
    }

    pub fn name(self) -> &'static str {
        match self {
            SpecialReg::TidX => "SR_TID",
            SpecialReg::NtidX => "SR_NTID",
            SpecialReg::CtaidX => "SR_CTAID",
            SpecialReg::NctaidX => "SR_NCTAID",
            SpecialReg::CtaidY => "SR_CTAID_Y",
            SpecialReg::NctaidY => "SR_NCTAID_Y",
            SpecialReg::LaneId => "SR_LANEID",
            SpecialReg::WarpId => "SR_WARPID",
            SpecialReg::SmId => "SR_SMID",
            SpecialReg::GtId => "SR_GTID",
        }
    }

    pub fn from_name(s: &str) -> Option<SpecialReg> {
        SpecialReg::ALL.iter().copied().find(|r| r.name() == s)
    }
}

/// A source operand after decode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    /// General-purpose register (R63 = RZ reads zero).
    Reg(u8),
    /// 32-bit immediate (second source slot only).
    Imm(i32),
    /// Special register (S2R source).
    Special(SpecialReg),
    /// Address register (A2R source / memory base).
    AReg(u8),
    /// Unused slot.
    None,
}

/// Execution guard: `@Pn.cond` — evaluated per-thread against the 4-bit
/// predicate register (paper Fig. 2 lookup table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Guard {
    pub preg: u8,
    pub cond: Cond,
}

impl Guard {
    pub const NONE: Guard = Guard { preg: 0, cond: Cond::Always };

    pub fn is_unconditional(self) -> bool {
        self.cond == Cond::Always
    }
}

/// Which memory a `Gld/Gst/Sld/Sst` touches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemSpace {
    Global,
    Shared,
}

/// Fully decoded instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instr {
    pub op: Op,
    pub guard: Guard,
    /// Destination register (GP reg for ALU/loads; A-reg index for R2A;
    /// ignored for stores/branches/control).
    pub dst: u8,
    pub src1: Operand,
    pub src2: Operand,
    pub src3: Operand,
    /// Predicate register written by `ISETP` (also the predicate *read* by
    /// `SEL`), when `setp_en`.
    pub setp_en: bool,
    pub setp_idx: u8,
    /// Embedded condition for `ISET` / `SEL`.
    pub cond: Cond,
    /// Byte offset for memory operands / branch target for `BRA`/`SSY`
    /// (branch targets live in `src2` as `Imm`).
    pub offset: i16,
    /// Encoded size in bytes (4 or 8) — the Fetch stage advances PC by this.
    pub size: u8,
}

impl Instr {
    /// A canonical NOP (also the default).
    pub const NOP: Instr = Instr {
        op: Op::Nop,
        guard: Guard::NONE,
        dst: 0,
        src1: Operand::None,
        src2: Operand::None,
        src3: Operand::None,
        setp_en: false,
        setp_idx: 0,
        cond: Cond::Always,
        offset: 0,
        size: 4,
    };

    /// Branch target in code bytes (BRA/SSY only).
    pub fn branch_target(&self) -> Option<u32> {
        match (self.op, self.src2) {
            (Op::Bra | Op::Ssy, Operand::Imm(t)) => Some(t as u32),
            _ => None,
        }
    }

    pub fn mem_space(&self) -> Option<MemSpace> {
        match self.op {
            Op::Gld | Op::Gst => Some(MemSpace::Global),
            Op::Sld | Op::Sst => Some(MemSpace::Shared),
            _ => None,
        }
    }

    pub fn is_store(&self) -> bool {
        matches!(self.op, Op::Gst | Op::Sst)
    }

    pub fn is_load(&self) -> bool {
        matches!(self.op, Op::Gld | Op::Sld)
    }
}

impl Default for Instr {
    fn default() -> Self {
        Instr::NOP
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn special_reg_roundtrip() {
        for (i, r) in SpecialReg::ALL.iter().enumerate() {
            assert_eq!(*r as u8, i as u8);
            assert_eq!(SpecialReg::from_u8(i as u8), Some(*r));
            assert_eq!(SpecialReg::from_name(r.name()), Some(*r));
        }
    }

    #[test]
    fn nop_is_short() {
        assert_eq!(Instr::NOP.size, 4);
        assert!(Instr::NOP.guard.is_unconditional());
    }

    #[test]
    fn branch_target_extraction() {
        let mut i = Instr::NOP;
        i.op = Op::Bra;
        i.src2 = Operand::Imm(0x40);
        assert_eq!(i.branch_target(), Some(0x40));
        i.op = Op::Iadd;
        assert_eq!(i.branch_target(), None);
    }
}
