//! Opcode definitions and static properties.

/// Operation codes. Mnemonics follow decuda/G80 conventions where one
/// exists; the set covers every instruction class the paper's five
/// benchmarks require (integer ALU, predicate set, branch/sync, memory).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Op {
    // -- no/short operand control --
    Nop = 0,
    /// Thread termination (sets the Finished bit in the warp's thread mask).
    Exit = 1,
    /// Pop the warp stack: DIV entry -> jump to taken path with saved mask;
    /// SYNC entry -> reconverge (paper §4.1).
    Join = 2,
    /// Block-wide barrier (`bar.sync 0`).
    Bar = 3,

    // -- moves --
    /// Rd = Rs | imm32.
    Mov = 4,
    /// Rd = special register (thread id, block id, dims...). FlexGrip's
    /// GPGPU controller seeds thread ids this way (paper §3.1).
    S2r = 5,
    /// Address-register transfer: A[n] = Rs.
    R2a = 6,
    /// Rd = A[n].
    A2r = 7,

    // -- integer arithmetic --
    Iadd = 8,
    Isub = 9,
    /// Low 32 bits of the signed product.
    Imul = 10,
    /// Rd = Ra * Rb + Rc (the only three-source-operand instruction; the
    /// paper's §4.2 operand-removal optimization hinges on this).
    Imad = 11,
    Imin = 12,
    Imax = 13,
    /// Rd = |Ra| (wrapping at i32::MIN, like CUDA).
    Iabs = 14,
    /// Rd = -Ra.
    Ineg = 15,

    // -- bitwise / shifts --
    And = 16,
    Or = 17,
    Xor = 18,
    Not = 19,
    Shl = 20,
    /// Logical right shift.
    Shr = 21,
    /// Arithmetic right shift.
    Sar = 22,

    // -- comparisons / predication --
    /// Set condition-code flags of (Ra - Srcb) into predicate register Pn.
    Isetp = 23,
    /// Rd = cond(Ra - Srcb) ? 0xFFFF_FFFF : 0 (CUDA integer set).
    Iset = 24,
    /// Rd = P[n].cond ? Ra : Srcb (predicate-select; the cond/setp fields
    /// name the source predicate, independent of the execution guard).
    Sel = 25,

    // -- control flow --
    /// Guarded branch; mixed per-lane outcome pushes a DIV warp-stack entry.
    Bra = 26,
    /// Push the SYNC reconvergence point (address operand) onto the stack.
    Ssy = 27,

    // -- memory --
    /// Global load: Rd = g[base + off16] (base = Ra or A[n]).
    Gld = 28,
    /// Global store: g[base + off16] = Rsrc2.
    Gst = 29,
    /// Shared load: Rd = s[base + off16].
    Sld = 30,
    /// Shared store: s[base + off16] = Rsrc2.
    Sst = 31,
}

/// Structural class of an opcode — drives decode field extraction, the
/// read-stage operand-fetch plan, and the customization analyzer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    /// No data operands (NOP, EXIT, JOIN, BAR).
    Control,
    /// dst + one source (MOV, NOT, IABS, INEG, S2R, R2A, A2R).
    Unary,
    /// dst + two sources (most ALU ops, ISETP, ISET).
    Binary,
    /// dst + three sources (IMAD only).
    Ternary,
    /// Branch-like with a code address (BRA, SSY).
    Branch,
    /// Memory access (GLD/GST/SLD/SST).
    Mem,
}

impl Op {
    /// Every opcode, in encoding order.
    pub const ALL: [Op; 32] = [
        Op::Nop, Op::Exit, Op::Join, Op::Bar, Op::Mov, Op::S2r, Op::R2a,
        Op::A2r, Op::Iadd, Op::Isub, Op::Imul, Op::Imad, Op::Imin, Op::Imax,
        Op::Iabs, Op::Ineg, Op::And, Op::Or, Op::Xor, Op::Not, Op::Shl,
        Op::Shr, Op::Sar, Op::Isetp, Op::Iset, Op::Sel, Op::Bra, Op::Ssy,
        Op::Gld, Op::Gst, Op::Sld, Op::Sst,
    ];

    pub fn from_u8(v: u8) -> Option<Op> {
        Op::ALL.get(v as usize).copied()
    }

    pub fn class(self) -> OpClass {
        use Op::*;
        match self {
            Nop | Exit | Join | Bar => OpClass::Control,
            Mov | S2r | R2a | A2r | Not | Iabs | Ineg => OpClass::Unary,
            Iadd | Isub | Imul | Imin | Imax | And | Or | Xor | Shl | Shr
            | Sar | Isetp | Iset | Sel => OpClass::Binary,
            Imad => OpClass::Ternary,
            Bra | Ssy => OpClass::Branch,
            Gld | Gst | Sld | Sst => OpClass::Mem,
        }
    }

    /// Number of source operands the read stage must fetch — the paper's
    /// §4.2 read-operand-unit count (3 for MAD, otherwise <= 2).
    pub fn num_source_operands(self) -> u8 {
        match self.class() {
            OpClass::Control => 0,
            OpClass::Unary => 1,
            OpClass::Binary => 2,
            OpClass::Ternary => 3,
            OpClass::Branch => 0,
            OpClass::Mem => match self {
                Op::Gst | Op::Sst => 2, // base + store data
                _ => 1,                 // base
            },
        }
    }

    /// Does this op use the SP multiplier (the DSP48E blocks in hardware)?
    pub fn uses_multiplier(self) -> bool {
        matches!(self, Op::Imul | Op::Imad)
    }

    /// Can this op be encoded in the 4-byte short form (operands fit word0)?
    pub fn short_encodable(self) -> bool {
        matches!(self.class(), OpClass::Control | OpClass::Unary)
    }

    pub fn mnemonic(self) -> &'static str {
        use Op::*;
        match self {
            Nop => "NOP",
            Exit => "EXIT",
            Join => "JOIN",
            Bar => "BAR",
            Mov => "MOV",
            S2r => "S2R",
            R2a => "R2A",
            A2r => "A2R",
            Iadd => "IADD",
            Isub => "ISUB",
            Imul => "IMUL",
            Imad => "IMAD",
            Imin => "IMIN",
            Imax => "IMAX",
            Iabs => "IABS",
            Ineg => "INEG",
            And => "AND",
            Or => "OR",
            Xor => "XOR",
            Not => "NOT",
            Shl => "SHL",
            Shr => "SHR",
            Sar => "SAR",
            Isetp => "ISETP",
            Iset => "ISET",
            Sel => "SEL",
            Bra => "BRA",
            Ssy => "SSY",
            Gld => "GLD",
            Gst => "GST",
            Sld => "SLD",
            Sst => "SST",
        }
    }

    pub fn from_mnemonic(s: &str) -> Option<Op> {
        Op::ALL.iter().copied().find(|o| o.mnemonic() == s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u8_roundtrip() {
        for (i, op) in Op::ALL.iter().enumerate() {
            assert_eq!(*op as u8, i as u8);
            assert_eq!(Op::from_u8(i as u8), Some(*op));
        }
        assert_eq!(Op::from_u8(32), None);
    }

    #[test]
    fn mnemonic_roundtrip() {
        for op in Op::ALL {
            assert_eq!(Op::from_mnemonic(op.mnemonic()), Some(op));
        }
        assert_eq!(Op::from_mnemonic("FADD"), None);
    }

    #[test]
    fn imad_is_only_three_operand_op() {
        for op in Op::ALL {
            assert_eq!(op.num_source_operands() == 3, op == Op::Imad);
        }
    }

    #[test]
    fn multiplier_ops() {
        let muls: Vec<Op> = Op::ALL.iter().copied().filter(|o| o.uses_multiplier()).collect();
        assert_eq!(muls, vec![Op::Imul, Op::Imad]);
    }
}
