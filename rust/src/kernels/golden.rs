//! Host-side golden references for the benchmark kernels, with the exact
//! wrapping-i32 semantics of the SP datapath. These are the first line of
//! verification; the XLA-executed JAX/Pallas golden models
//! (`runtime::golden`) independently cross-check the same outputs.

/// `r[k] = sum_{i=0}^{n-1-k} x[i] * x[i+k]` (wrapping).
pub fn autocorr(x: &[i32]) -> Vec<i32> {
    let n = x.len();
    (0..n)
        .map(|k| {
            let mut acc = 0i32;
            for i in 0..n - k {
                acc = acc.wrapping_add(x[i].wrapping_mul(x[i + k]));
            }
            acc
        })
        .collect()
}

/// Each `seg`-sized chunk sorted ascending (the segmented bitonic kernel's
/// contract).
pub fn bitonic_segments(data: &[i32], seg: usize) -> Vec<i32> {
    assert_eq!(data.len() % seg, 0);
    let mut out = data.to_vec();
    for chunk in out.chunks_mut(seg) {
        chunk.sort_unstable();
    }
    out
}

/// `C = A x B`, n x n row-major, wrapping i32.
pub fn matmul(a: &[i32], b: &[i32], n: usize) -> Vec<i32> {
    let mut c = vec![0i32; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            for j in 0..n {
                c[i * n + j] =
                    c[i * n + j].wrapping_add(aik.wrapping_mul(b[k * n + j]));
            }
        }
    }
    c
}

/// Wrapping sum.
pub fn reduction(x: &[i32]) -> i32 {
    x.iter().fold(0i32, |a, &v| a.wrapping_add(v))
}

/// `B = A^T`, n x n row-major.
pub fn transpose(a: &[i32], n: usize) -> Vec<i32> {
    let mut b = vec![0i32; n * n];
    for i in 0..n {
        for j in 0..n {
            b[j * n + i] = a[i * n + j];
        }
    }
    b
}

/// Element-wise wrapping add.
pub fn vecadd(a: &[i32], b: &[i32]) -> Vec<i32> {
    a.iter().zip(b).map(|(&x, &y)| x.wrapping_add(y)).collect()
}

/// `out[t] = sum_{j=0}^{7} in[(t + j*stride) & (n-1)]` (wrapping; `n`
/// must be a power of two) — the strided memory-stress kernel.
pub fn memstress(x: &[i32], stride: u32) -> Vec<i32> {
    let n = x.len();
    assert!(n.is_power_of_two());
    (0..n)
        .map(|t| {
            (0..8u32).fold(0i32, |acc, j| {
                let idx = (t as u32).wrapping_add(j.wrapping_mul(stride)) as usize & (n - 1);
                acc.wrapping_add(x[idx])
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn autocorr_known_values() {
        // x = [1,2,3]: r0=1+4+9=14, r1=1*2+2*3=8, r2=1*3=3
        assert_eq!(autocorr(&[1, 2, 3]), vec![14, 8, 3]);
    }

    #[test]
    fn bitonic_sorts_per_segment() {
        let got = bitonic_segments(&[4, 1, 3, 2, 9, 7, 8, 6], 4);
        assert_eq!(got, vec![1, 2, 3, 4, 6, 7, 8, 9]);
    }

    #[test]
    fn matmul_identity() {
        let n = 4;
        let mut id = vec![0; 16];
        for i in 0..n {
            id[i * n + i] = 1;
        }
        let a: Vec<i32> = (0..16).collect();
        assert_eq!(matmul(&a, &id, n), a);
    }

    #[test]
    fn transpose_involution() {
        let a: Vec<i32> = (0..16).collect();
        assert_eq!(transpose(&transpose(&a, 4), 4), a);
    }

    #[test]
    fn reduction_wraps() {
        assert_eq!(reduction(&[i32::MAX, 1]), i32::MIN);
        assert_eq!(reduction(&[1, 2, 3]), 6);
    }

    #[test]
    fn vecadd_elementwise() {
        assert_eq!(vecadd(&[1, 2], &[10, 20]), vec![11, 22]);
    }

    #[test]
    fn memstress_stride_wraps_the_index() {
        // n = 4, stride 1: out[t] = 8 trips over a 4-element ring = two
        // full passes of the input.
        let x = [1, 2, 3, 4];
        let total: i32 = x.iter().sum();
        assert_eq!(memstress(&x, 1), vec![2 * total; 4]);
        // stride 4 == n: every trip lands on in[t].
        assert_eq!(memstress(&x, 4), vec![8, 16, 24, 32]);
    }
}
