; bitonic: in-place ascending sort of one seg-element chunk per block
; (seg = block threads, a power of two). Classic bitonic network: for each
; (kk, j) step, thread t with (t & j) == 0 compare-exchanges with partner
; t ^ j in direction (t & kk). The compare-exchange uses a real divergent
; branch (SSY + BRA + JOIN), giving the paper's Table-6 warp-stack
; high-water mark of exactly 2; everything else is predicated or uniform.
; Integer-only address math (no IMUL/IMAD) keeps the multiplier idle, so
; the 2-operand customization applies (paper §5.2).
; params: [0] data base, [4] log2(seg)
.entry bitonic
.regs 14
    S2R  R0, SR_TID
    SLD  R1, [0]         ; data base
    SLD  R2, [4]         ; log2(seg)
    MOV  R3, #1
    SHL  R3, R3, R2      ; seg
    S2R  R4, SR_CTAID
    SHL  R4, R4, R2
    IADD R4, R4, R0
    SHL  R4, R4, #2
    IADD R4, R4, R1      ; &data[ctaid*seg + t]  (fixed per thread)
    MOV  R5, #2          ; kk
kk_loop:
    SHR  R6, R5, #1      ; j
j_loop:
    AND  R8, R0, R6
    ISETP P1, R8, #0     ; P1.EQ: this lane owns the pair (partner = t + j)
    SHL  R9, R6, #2
    IADD R9, R9, R4      ; &data[... + t + j] (valid for owning lanes)
    GLD  R10, [R4]       ; a = own element
    @P1.EQ GLD R11, [R9] ; b = partner element (owners only: stays in-bounds)
    AND  R12, R0, R5
    ISETP P2, R12, #0    ; P2.EQ: ascending half
    ISUB R13, R10, R11   ; a - b
    INEG R8, R13         ; b - a
    SEL  R13, R13, R8, P2.EQ   ; s = ascending ? a-b : b-a
    SEL  R13, R13, RZ, P1.EQ   ; non-owners never swap
    ISETP P0, R13, #0
    SSY  step_end
    @P0.GT BRA do_swap   ; out-of-order pairs take the swap path
    JOIN
do_swap:
    GST  [R4], R11
    GST  [R9], R10
    JOIN
step_end:
    BAR                  ; network step boundary
    SHR  R6, R6, #1
    ISETP P0, R6, #0
    @P0.GT BRA j_loop    ; uniform
    SHL  R5, R5, #1
    ISETP P0, R5, R3
    @P0.LE BRA kk_loop   ; uniform
    EXIT
