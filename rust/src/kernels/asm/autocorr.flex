; autocorr: r[k] = sum_{i=0}^{n-1-k} x[i] * x[i+k], one thread per lag k.
; The per-thread trip count (n - k) differs across every lane of a
; 16-thread block, so lanes retire from the loop one per iteration: each
; partial exit pushes a DIV entry that parks the exited lanes until the
; survivors finish. With 16 distinct trip counts per warp this reaches the
; paper's Table-6 warp-stack high-water mark of 16 (SSY + 15 DIV).
; params: [0] x base, [4] r base, [8] n
.entry autocorr
.regs 11
    S2R  R0, SR_GTID     ; k
    SLD  R1, [0]         ; x base
    SLD  R2, [4]         ; r base
    SLD  R3, [8]         ; n
    ISUB R4, R3, R0      ; trips = n - k  (>= 1)
    SHL  R5, R0, #2
    IADD R5, R5, R1      ; &x[i+k], i = 0
    MOV  R6, R1          ; &x[i],   i = 0
    MOV  R7, #0          ; acc
    SSY  fin
loop:
    GLD  R8, [R6]        ; x[i]
    GLD  R9, [R5]        ; x[i+k]
    IMAD R7, R8, R9, R7  ; acc += x[i] * x[i+k]  (wrapping)
    IADD R6, R6, #4
    IADD R5, R5, #4
    ISUB R4, R4, #1
    ISETP P0, R4, #0
    @P0.LE BRA done      ; finished lanes take the exit (parked on stack)
    BRA  loop            ; survivors loop uniformly
done:
    SHL  R10, R0, #2
    IADD R10, R10, R2
    GST  [R10], R7       ; r[k] = acc
    JOIN                 ; unwind one parked exit group (or the SSY)
fin:
    EXIT
