; memstress: strided/streaming memory stress for the L1/BRAM cache sweep.
; Each thread sums 8 input words at a configurable stride (wrapping the
; index into the power-of-two input with an AND mask):
;   out[t] = sum_{j=0}^{7} in[(t + j*stride) & (n-1)]
; stride 1 -> warps stream adjacent lines (line reuse, high hit rate);
; stride >= line_words -> every trip touches a fresh line (miss storm).
; The trip count is uniform across lanes, so the loop never diverges
; (warp-stack depth 0) and the kernel needs no multiplier.
; params: [0] in base, [4] out base, [8] n-1 index mask, [12] stride
.entry memstress
.regs 12
    S2R  R0, SR_GTID     ; t
    SLD  R1, [0]         ; in base
    SLD  R2, [4]         ; out base
    SLD  R3, [8]         ; n-1 (index mask)
    SLD  R4, [12]        ; stride
    MOV  R5, #8          ; trips
    MOV  R6, R0          ; idx = t
    MOV  R7, #0          ; acc
loop:
    AND  R8, R6, R3      ; idx & (n-1)
    SHL  R8, R8, #2
    IADD R8, R8, R1      ; &in[idx & (n-1)]
    GLD  R9, [R8]
    IADD R7, R7, R9      ; acc += in[...]
    IADD R6, R6, R4      ; idx += stride
    ISUB R5, R5, #1
    ISETP P0, R5, #0
    @P0.GT BRA loop      ; uniform trip count: never diverges
    SHL  R10, R0, #2
    IADD R10, R10, R2
    GST  [R10], R7       ; out[t] = acc
    EXIT
