; reduction: out[ctaid] = sum of 2*ntid consecutive inputs (wrapping).
; Each block loads two elements per thread, then tree-reduces the partials
; in shared memory. All conditionals are predicated and every loop trip
; count is uniform, so the warp stack is never touched (Table 6: depth 0).
; The host launches a second 1-block pass over the partials when grid > 1.
; params: [0] in base, [4] out base
.entry reduction
.regs 13
.smem 128
    S2R  R0, SR_TID
    S2R  R1, SR_NTID     ; T
    S2R  R2, SR_CTAID
    SLD  R3, [0]         ; in
    SLD  R4, [4]         ; out
    IMUL R5, R2, R1
    SHL  R5, R5, #3
    IADD R5, R5, R3      ; &in[ctaid * 2T]
    SHL  R6, R0, #2      ; tid*4
    IADD R7, R5, R6
    GLD  R8, [R7]        ; in[ctaid*2T + tid]
    SHL  R9, R1, #2
    IADD R7, R7, R9
    GLD  R10, [R7]       ; in[ctaid*2T + T + tid]
    IADD R8, R8, R10
    SST  [R6+64], R8     ; shared[tid] = pairwise partial
    BAR
    SHR  R11, R1, #1     ; off = T/2
loop:
    ISETP P0, R11, #0
    @P0.LE BRA fin       ; uniform exit — no divergence
    ISETP P1, R0, R11    ; active half: tid < off
    SHL  R12, R11, #2
    IADD R12, R12, R6
    @P1.LT SLD R10, [R12+64]   ; shared[tid + off]
    @P1.LT SLD R8, [R6+64]     ; shared[tid]
    @P1.LT IADD R8, R8, R10
    @P1.LT SST [R6+64], R8
    BAR
    SHR  R11, R11, #1
    BRA  loop
fin:
    SLD  R8, [64]        ; shared[0] = block total
    SHL  R12, R2, #2
    IADD R12, R12, R4
    ISETP P0, R0, #1
    @P0.LT GST [R12], R8 ; thread 0 writes out[ctaid]
    EXIT
