; transpose: B[j][i] = A[i][j] (n x n, row-major), 16x16 thread tiles.
; Straight-line per thread — warp-stack depth 0, no divergence.
; params: [0] A base, [4] B base, [8] n
.entry transpose
.regs 10
    S2R  R0, SR_TID
    SLD  R1, [0]         ; A
    SLD  R2, [4]         ; B
    SLD  R3, [8]         ; n
    S2R  R4, SR_CTAID_Y
    SHL  R4, R4, #4
    SHR  R5, R0, #4
    IADD R4, R4, R5      ; i = ctaid.y*16 + tid/16
    S2R  R5, SR_CTAID
    SHL  R5, R5, #4
    AND  R6, R0, #15
    IADD R5, R5, R6      ; j = ctaid.x*16 + tid%16
    IMUL R6, R4, R3
    IADD R6, R6, R5
    SHL  R6, R6, #2
    IADD R6, R6, R1
    GLD  R7, [R6]        ; A[i][j]
    IMUL R8, R5, R3
    IADD R8, R8, R4
    SHL  R8, R8, #2
    IADD R8, R8, R2
    GST  [R8], R7        ; B[j][i]
    EXIT
