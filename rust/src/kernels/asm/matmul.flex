; matmul: C = A x B (n x n, row-major, wrapping i32), 16x16 thread tiles.
; Thread (r, c) of block (bx, by) computes C[by*16+r][bx*16+c] with a
; uniform n-iteration MAD loop — no divergence, warp-stack depth 0.
; params: [0] A base, [4] B base, [8] C base, [12] n
.entry matmul
.regs 14
    S2R  R0, SR_TID
    SLD  R1, [0]         ; A
    SLD  R2, [4]         ; B
    SLD  R3, [8]         ; C
    SLD  R4, [12]        ; n
    S2R  R5, SR_CTAID_Y
    SHL  R5, R5, #4
    SHR  R6, R0, #4
    IADD R5, R5, R6      ; i = ctaid.y*16 + tid/16
    S2R  R6, SR_CTAID
    SHL  R6, R6, #4
    AND  R7, R0, #15
    IADD R6, R6, R7      ; j = ctaid.x*16 + tid%16
    IMUL R7, R5, R4
    SHL  R7, R7, #2
    IADD R7, R7, R1      ; &A[i][0]
    SHL  R8, R6, #2
    IADD R8, R8, R2      ; &B[0][j]
    SHL  R9, R4, #2      ; row stride in bytes
    MOV  R10, #0         ; acc
    MOV  R11, R4         ; k = n
loop:
    GLD  R12, [R7]       ; A[i][k]
    GLD  R13, [R8]       ; B[k][j]
    IMAD R10, R12, R13, R10
    IADD R7, R7, #4
    IADD R8, R8, R9
    ISUB R11, R11, #1
    ISETP P0, R11, #0
    @P0.GT BRA loop      ; uniform: every thread runs exactly n iterations
    IMUL R12, R5, R4
    IADD R12, R12, R6
    SHL  R12, R12, #2
    IADD R12, R12, R3
    GST  [R12], R10      ; C[i][j]
    EXIT
