; vecadd: out[g] = a[g] + b[g], one thread per element.
; Straight-line (no branches) — the customization analyzer relies on this
; being the branch-free reference kernel.
; params: [0] a base, [4] b base, [8] out base
.entry vecadd
.regs 8
    S2R  R1, SR_GTID
    SLD  R2, [0]
    SLD  R3, [4]
    SLD  R4, [8]
    SHL  R5, R1, #2
    IADD R2, R2, R5
    IADD R3, R3, R5
    IADD R4, R4, R5
    GLD  R6, [R2]
    GLD  R7, [R3]
    IADD R6, R6, R7
    GST  [R4], R6
    EXIT
