//! The paper's five CUDA benchmarks (§5: bitonic sort, autocorrelation,
//! matrix multiplication, parallel reduction, transpose — from ERCBench
//! and the NVIDIA Programmer's Guide) plus a vecadd quickstart, each as
//! FlexGrip assembly with a host-side workload harness (data generation,
//! launch geometry, golden verification).

pub mod golden;

use crate::gpgpu::{Gpgpu, LaunchConfig, LaunchResult};
use crate::registry::{KernelRegistry, PreparedKernel};
use crate::rng::XorShift64;
use crate::sim::{AluBackend, AluFactory, GlobalMem, SimError, SmStats};
use std::sync::Arc;

/// Device byte address where benchmark inputs begin.
pub const IN_BASE: u32 = 0x1000;

/// Benchmark identifiers. `PAPER` lists the five evaluated in the paper,
/// in its plot order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BenchId {
    Autocorr,
    Bitonic,
    MatMul,
    Reduction,
    Transpose,
    VecAdd,
}

impl BenchId {
    pub const PAPER: [BenchId; 5] = [
        BenchId::Autocorr,
        BenchId::Bitonic,
        BenchId::MatMul,
        BenchId::Reduction,
        BenchId::Transpose,
    ];

    pub const ALL: [BenchId; 6] = [
        BenchId::Autocorr,
        BenchId::Bitonic,
        BenchId::MatMul,
        BenchId::Reduction,
        BenchId::Transpose,
        BenchId::VecAdd,
    ];

    pub fn name(self) -> &'static str {
        match self {
            BenchId::Autocorr => "autocorr",
            BenchId::Bitonic => "bitonic",
            BenchId::MatMul => "matmul",
            BenchId::Reduction => "reduction",
            BenchId::Transpose => "transpose",
            BenchId::VecAdd => "vecadd",
        }
    }

    pub fn from_name(s: &str) -> Option<BenchId> {
        BenchId::ALL.iter().copied().find(|b| b.name() == s)
    }

    /// Assembly source (embedded; assembled on demand).
    pub fn source(self) -> &'static str {
        match self {
            BenchId::Autocorr => include_str!("asm/autocorr.flex"),
            BenchId::Bitonic => include_str!("asm/bitonic.flex"),
            BenchId::MatMul => include_str!("asm/matmul.flex"),
            BenchId::Reduction => include_str!("asm/reduction.flex"),
            BenchId::Transpose => include_str!("asm/transpose.flex"),
            BenchId::VecAdd => include_str!("asm/vecadd.flex"),
        }
    }

    /// Is the workload 2-D (`n` means an n x n matrix)?
    pub fn is_matrix(self) -> bool {
        matches!(self, BenchId::MatMul | BenchId::Transpose)
    }

    /// Number of input elements for problem size `n` (paper §5.1.1: sizes
    /// 32..256, matrices n x n).
    pub fn input_elems(self, n: u32) -> usize {
        match self {
            BenchId::Autocorr | BenchId::Bitonic | BenchId::Reduction => n as usize,
            BenchId::MatMul => 2 * (n * n) as usize, // A and B
            BenchId::Transpose => (n * n) as usize,
            BenchId::VecAdd => 2 * n as usize,
        }
    }
}

/// One kernel launch of a (possibly multi-phase) workload.
#[derive(Debug, Clone)]
pub struct Phase {
    pub launch: LaunchConfig,
    pub params: Vec<i32>,
}

/// A fully-prepared workload: assembled kernel, input data, launch phases,
/// and everything needed to verify the output.
#[derive(Debug, Clone)]
pub struct Workload {
    pub id: BenchId,
    pub n: u32,
    pub seed: u64,
    /// Registry-interned kernel: repeat `prepare` calls of the same
    /// benchmark share one assembled + pre-decoded image (`Deref`s to the
    /// inner [`crate::asm::Kernel`]).
    pub kernel: Arc<PreparedKernel>,
    pub phases: Vec<Phase>,
    pub gmem_bytes: u32,
    /// Input blob written at `IN_BASE` (layout is benchmark-specific).
    pub input: Vec<i32>,
    /// Byte address and length of the output region.
    out_base: u32,
    out_len: usize,
    /// Bitonic segment size (needed by verification).
    seg: u32,
}

/// Merged result of a multi-phase benchmark run. Phase launches are
/// sequential on the device, so cycles add.
#[derive(Debug, Clone)]
pub struct BenchRun {
    pub phases: Vec<LaunchResult>,
    pub cycles: u64,
    /// Aggregated counters across phases and SMs (cycles = summed phase
    /// critical paths).
    pub stats: SmStats,
}

impl BenchRun {
    pub fn exec_time_ms(&self) -> f64 {
        self.cycles as f64 / crate::gpgpu::CLOCK_HZ * 1e3
    }
}

/// Supported problem sizes (paper §5.1.1).
pub const PAPER_SIZES: [u32; 4] = [32, 64, 128, 256];

/// Build a workload for benchmark `id` at problem size `n` (power of two,
/// 32..=256) with deterministic `seed`.
pub fn prepare(id: BenchId, n: u32, seed: u64) -> Workload {
    assert!(
        n.is_power_of_two() && (32..=256).contains(&n),
        "problem size must be a power of two in 32..=256 (got {n})"
    );
    let kernel = KernelRegistry::global()
        .get_or_assemble(id.source())
        .expect("benchmark kernels must assemble");
    let mut rng = XorShift64::new(seed ^ (id as u64) << 32);
    let input: Vec<i32> = (0..id.input_elems(n)).map(|_| rng.small_i32()).collect();

    let b = |v: u32| IN_BASE + 4 * v; // element -> byte helper
    let (phases, out_base, out_len, seg) = match id {
        BenchId::VecAdd => {
            let (a, bb, out) = (IN_BASE, b(n), b(2 * n));
            let block = n.min(64);
            (
                vec![Phase {
                    launch: LaunchConfig::linear(n / block, block),
                    params: vec![a as i32, bb as i32, out as i32],
                }],
                out,
                n as usize,
                0,
            )
        }
        BenchId::Autocorr => {
            let (x, r) = (IN_BASE, b(n));
            (
                vec![Phase {
                    launch: LaunchConfig::linear(n / 16, 16),
                    params: vec![x as i32, r as i32, n as i32],
                }],
                r,
                n as usize,
                0,
            )
        }
        BenchId::Bitonic => {
            let seg = n.min(64);
            (
                vec![Phase {
                    launch: LaunchConfig::linear(n / seg, seg),
                    params: vec![IN_BASE as i32, seg.trailing_zeros() as i32],
                }],
                IN_BASE, // sorts in place
                n as usize,
                seg,
            )
        }
        BenchId::MatMul => {
            let (a, bb, c) = (IN_BASE, b(n * n), b(2 * n * n));
            let tiles = n / 16;
            (
                vec![Phase {
                    launch: LaunchConfig { grid_x: tiles, grid_y: tiles, block_threads: 256 },
                    params: vec![a as i32, bb as i32, c as i32, n as i32],
                }],
                c,
                (n * n) as usize,
                0,
            )
        }
        BenchId::Transpose => {
            let (a, out) = (IN_BASE, b(n * n));
            let tiles = n / 16;
            (
                vec![Phase {
                    launch: LaunchConfig { grid_x: tiles, grid_y: tiles, block_threads: 256 },
                    params: vec![a as i32, out as i32, n as i32],
                }],
                out,
                (n * n) as usize,
                0,
            )
        }
        BenchId::Reduction => {
            // Phase 1: each 32-thread block reduces 64 elements (n < 64:
            // one n/2-thread block). Phase 2 (grid > 1): one block reduces
            // the partials.
            let partials = b(n);
            let (grid1, block1) = if n < 64 { (1, n / 2) } else { (n / 64, 32) };
            let mut phases = vec![Phase {
                launch: LaunchConfig::linear(grid1, block1),
                params: vec![IN_BASE as i32, partials as i32],
            }];
            let mut out = partials;
            if grid1 > 1 {
                let fin = partials + 4 * grid1;
                phases.push(Phase {
                    launch: LaunchConfig::linear(1, grid1 / 2),
                    params: vec![partials as i32, fin as i32],
                });
                out = fin;
            }
            (phases, out, 1, 0)
        }
    };

    // Room for inputs + outputs + slack.
    let high = out_base + 4 * out_len as u32;
    let gmem_bytes = (high + 4096).next_power_of_two();

    Workload {
        id,
        n,
        seed,
        kernel,
        phases,
        gmem_bytes,
        input,
        out_base,
        out_len,
        seg,
    }
}

impl Workload {
    /// Allocate device memory and DMA the inputs in (driver behaviour).
    pub fn make_gmem(&self) -> GlobalMem {
        let mut g = GlobalMem::new(self.gmem_bytes);
        g.write_words(IN_BASE, &self.input).expect("input fits");
        g
    }

    /// Execute all phases on `gpgpu`, returning merged statistics.
    pub fn run(
        &self,
        gpgpu: &Gpgpu,
        gmem: &mut GlobalMem,
        alu: &mut dyn AluBackend,
    ) -> Result<BenchRun, SimError> {
        self.run_admitted(gpgpu, &self.kernel.sig, gmem, alu)
    }

    /// [`Workload::run`] admitted on an explicit (e.g. profile-refined)
    /// signature — the coordinator's routed launches use the same
    /// signature the router admitted on (see `Gpgpu::launch_admitted`).
    pub fn run_admitted(
        &self,
        gpgpu: &Gpgpu,
        sig: &crate::isa::CapabilitySignature,
        gmem: &mut GlobalMem,
        alu: &mut dyn AluBackend,
    ) -> Result<BenchRun, SimError> {
        let mut phases = Vec::with_capacity(self.phases.len());
        let mut cycles = 0u64;
        let mut stats = SmStats::default();
        for ph in &self.phases {
            let r = gpgpu
                .launch_admitted(&self.kernel, sig, ph.launch, &ph.params, gmem, alu)?;
            cycles += r.total.cycles;
            stats.merge(&r.total);
            phases.push(r);
        }
        stats.cycles = cycles;
        Ok(BenchRun { phases, cycles, stats })
    }

    /// Execute all phases with each SM simulated on its own thread
    /// (`Gpgpu::launch_parallel`); identical simulated cycles and memory
    /// image to [`Workload::run`], but wall-clock-parallel across SMs.
    pub fn run_parallel(
        &self,
        gpgpu: &Gpgpu,
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<BenchRun, SimError> {
        self.run_parallel_admitted(gpgpu, &self.kernel.sig, gmem, factory)
    }

    /// [`Workload::run_parallel`] admitted on an explicit signature (see
    /// [`Workload::run_admitted`]).
    pub fn run_parallel_admitted(
        &self,
        gpgpu: &Gpgpu,
        sig: &crate::isa::CapabilitySignature,
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<BenchRun, SimError> {
        let mut phases = Vec::with_capacity(self.phases.len());
        let mut cycles = 0u64;
        let mut stats = SmStats::default();
        for ph in &self.phases {
            let r = gpgpu.launch_parallel_admitted(
                &self.kernel,
                sig,
                ph.launch,
                &ph.params,
                gmem,
                factory,
            )?;
            cycles += r.total.cycles;
            stats.merge(&r.total);
            phases.push(r);
        }
        stats.cycles = cycles;
        Ok(BenchRun { phases, cycles, stats })
    }

    /// Expected output (golden reference on the host).
    pub fn expected(&self) -> Vec<i32> {
        let n = self.n as usize;
        match self.id {
            BenchId::Autocorr => golden::autocorr(&self.input),
            BenchId::Bitonic => golden::bitonic_segments(&self.input, self.seg as usize),
            BenchId::MatMul => {
                golden::matmul(&self.input[..n * n], &self.input[n * n..], n)
            }
            BenchId::Reduction => vec![golden::reduction(&self.input)],
            BenchId::Transpose => golden::transpose(&self.input, n),
            BenchId::VecAdd => golden::vecadd(&self.input[..n], &self.input[n..]),
        }
    }

    /// Compare device output against the golden reference.
    pub fn verify(&self, gmem: &GlobalMem) -> Result<(), String> {
        let got = gmem
            .read_words(self.out_base, self.out_len)
            .map_err(|e| format!("reading output: {e}"))?;
        let want = self.expected();
        if got == want {
            return Ok(());
        }
        let idx = got
            .iter()
            .zip(&want)
            .position(|(g, w)| g != w)
            .unwrap_or(0);
        Err(format!(
            "{} n={}: output mismatch at element {idx}: got {} want {} \
             ({} of {} wrong)",
            self.id.name(),
            self.n,
            got[idx],
            want[idx],
            got.iter().zip(&want).filter(|(g, w)| g != w).count(),
            want.len(),
        ))
    }
}

/// Convenience: prepare + run + verify in one call. Returns the merged run
/// statistics; panics on verification failure (tests/benches want loud
/// failures).
pub fn run_verified(
    id: BenchId,
    n: u32,
    gpgpu: &Gpgpu,
    alu: &mut dyn AluBackend,
    seed: u64,
) -> Result<BenchRun, SimError> {
    let w = prepare(id, n, seed);
    let mut gmem = w.make_gmem();
    let run = w.run(gpgpu, &mut gmem, alu)?;
    if let Err(e) = w.verify(&gmem) {
        panic!("verification failed: {e}");
    }
    Ok(run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;
    use crate::gpgpu::GpgpuConfig;
    use crate::sim::NativeAlu;

    fn run(id: BenchId, n: u32, sms: u32, sp: u32) -> BenchRun {
        let gpgpu = Gpgpu::new(GpgpuConfig::new(sms, sp));
        let mut alu = NativeAlu;
        run_verified(id, n, &gpgpu, &mut alu, 0xF00D).unwrap()
    }

    #[test]
    fn all_benchmarks_assemble() {
        for id in BenchId::ALL {
            let k = assemble(id.source()).unwrap();
            assert_eq!(k.name, id.name(), "entry name matches");
            assert!(k.regs_per_thread <= 16);
        }
    }

    #[test]
    fn vecadd_32_correct() {
        let r = run(BenchId::VecAdd, 32, 1, 8);
        assert!(r.cycles > 0);
    }

    #[test]
    fn autocorr_32_correct() {
        let r = run(BenchId::Autocorr, 32, 1, 8);
        // divergent loop exits must be observed
        assert!(r.stats.divergences > 0, "autocorr must diverge");
    }

    #[test]
    fn autocorr_stack_depth_is_paper_16() {
        let r = run(BenchId::Autocorr, 64, 1, 8);
        assert_eq!(r.stats.max_stack_depth, 16, "Table 6: autocorr depth 16");
    }

    #[test]
    fn bitonic_64_correct_depth_2() {
        let r = run(BenchId::Bitonic, 64, 1, 8);
        assert_eq!(r.stats.max_stack_depth, 2, "Table 6: bitonic depth 2");
        assert!(r.stats.divergences > 0);
    }

    #[test]
    fn bitonic_needs_no_multiplier() {
        let r = run(BenchId::Bitonic, 64, 1, 8);
        assert_eq!(r.stats.multiplier_ops(), 0, "paper §5.2");
    }

    #[test]
    fn matmul_32_correct_depth_0() {
        let r = run(BenchId::MatMul, 32, 1, 8);
        assert_eq!(r.stats.max_stack_depth, 0, "Table 6: matmul depth 0");
        assert_eq!(r.stats.divergences, 0);
    }

    #[test]
    fn reduction_two_phase_correct() {
        let r = run(BenchId::Reduction, 256, 1, 8);
        assert_eq!(r.phases.len(), 2, "256 elements need a partials pass");
        assert_eq!(r.stats.max_stack_depth, 0, "Table 6: reduction depth 0");
    }

    #[test]
    fn reduction_single_phase_small() {
        let r = run(BenchId::Reduction, 32, 1, 8);
        assert_eq!(r.phases.len(), 1);
    }

    #[test]
    fn transpose_32_correct_depth_0() {
        let r = run(BenchId::Transpose, 32, 1, 8);
        assert_eq!(r.stats.max_stack_depth, 0, "Table 6: transpose depth 0");
    }

    #[test]
    fn all_benchmarks_verify_on_two_sms() {
        for id in BenchId::PAPER {
            let r = run(id, 64, 2, 16);
            assert!(r.cycles > 0, "{}", id.name());
        }
    }

    #[test]
    fn seeds_change_data_not_correctness() {
        for seed in [1u64, 2, 3] {
            let gpgpu = Gpgpu::new(GpgpuConfig::new(1, 32));
            let mut alu = NativeAlu;
            run_verified(BenchId::Bitonic, 128, &gpgpu, &mut alu, seed).unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn bad_size_rejected() {
        prepare(BenchId::VecAdd, 48, 0);
    }
}
