//! The paper's five CUDA benchmarks (§5: bitonic sort, autocorrelation,
//! matrix multiplication, parallel reduction, transpose — from ERCBench
//! and the NVIDIA Programmer's Guide) plus a vecadd quickstart and a
//! strided memory-stress kernel (for the cache sweep), each as FlexGrip
//! assembly with a host-side workload harness (data generation, launch
//! geometry, golden verification).

pub mod golden;

use crate::gpgpu::{ExecMode, Gpgpu, LaunchConfig, LaunchRequest, LaunchResult};
use crate::isa::CapabilitySignature;
use crate::registry::{KernelRegistry, PreparedKernel};
use crate::rng::XorShift64;
use crate::sim::{
    AluBackend, AluFactory, CheckpointPolicy, EngineMode, FaultPlan, GlobalMem, MemoryConfig,
    NativeAlu, SimError, SmStats,
};
use std::sync::Arc;

/// Device byte address where benchmark inputs begin.
pub const IN_BASE: u32 = 0x1000;

/// Benchmark identifiers. `PAPER` lists the five evaluated in the paper,
/// in its plot order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BenchId {
    Autocorr,
    Bitonic,
    MatMul,
    Reduction,
    Transpose,
    VecAdd,
    /// Strided/streaming memory stress (not a paper benchmark): each
    /// thread sums 8 input words at a configurable stride, so the cache
    /// sweep can dial the hit rate from line-reuse to miss-storm.
    MemStress,
}

impl BenchId {
    pub const PAPER: [BenchId; 5] = [
        BenchId::Autocorr,
        BenchId::Bitonic,
        BenchId::MatMul,
        BenchId::Reduction,
        BenchId::Transpose,
    ];

    pub const ALL: [BenchId; 7] = [
        BenchId::Autocorr,
        BenchId::Bitonic,
        BenchId::MatMul,
        BenchId::Reduction,
        BenchId::Transpose,
        BenchId::VecAdd,
        BenchId::MemStress,
    ];

    pub fn name(self) -> &'static str {
        match self {
            BenchId::Autocorr => "autocorr",
            BenchId::Bitonic => "bitonic",
            BenchId::MatMul => "matmul",
            BenchId::Reduction => "reduction",
            BenchId::Transpose => "transpose",
            BenchId::VecAdd => "vecadd",
            BenchId::MemStress => "memstress",
        }
    }

    pub fn from_name(s: &str) -> Option<BenchId> {
        BenchId::ALL.iter().copied().find(|b| b.name() == s)
    }

    /// Assembly source (embedded; assembled on demand).
    pub fn source(self) -> &'static str {
        match self {
            BenchId::Autocorr => include_str!("asm/autocorr.flex"),
            BenchId::Bitonic => include_str!("asm/bitonic.flex"),
            BenchId::MatMul => include_str!("asm/matmul.flex"),
            BenchId::Reduction => include_str!("asm/reduction.flex"),
            BenchId::Transpose => include_str!("asm/transpose.flex"),
            BenchId::VecAdd => include_str!("asm/vecadd.flex"),
            BenchId::MemStress => include_str!("asm/memstress.flex"),
        }
    }

    /// Is the workload 2-D (`n` means an n x n matrix)?
    pub fn is_matrix(self) -> bool {
        matches!(self, BenchId::MatMul | BenchId::Transpose)
    }

    /// Number of input elements for problem size `n` (paper §5.1.1: sizes
    /// 32..256, matrices n x n).
    pub fn input_elems(self, n: u32) -> usize {
        match self {
            BenchId::Autocorr | BenchId::Bitonic | BenchId::Reduction | BenchId::MemStress => {
                n as usize
            }
            BenchId::MatMul => 2 * (n * n) as usize, // A and B
            BenchId::Transpose => (n * n) as usize,
            BenchId::VecAdd => 2 * n as usize,
        }
    }
}

/// One kernel launch of a (possibly multi-phase) workload.
#[derive(Debug, Clone)]
pub struct Phase {
    pub launch: LaunchConfig,
    pub params: Vec<i32>,
}

/// A fully-prepared workload: assembled kernel, input data, launch phases,
/// and everything needed to verify the output.
#[derive(Debug, Clone)]
pub struct Workload {
    pub id: BenchId,
    pub n: u32,
    pub seed: u64,
    /// Registry-interned kernel: repeat `prepare` calls of the same
    /// benchmark share one assembled + pre-decoded image (`Deref`s to the
    /// inner [`crate::asm::Kernel`]).
    pub kernel: Arc<PreparedKernel>,
    pub phases: Vec<Phase>,
    pub gmem_bytes: u32,
    /// Input blob written at `IN_BASE` (layout is benchmark-specific).
    pub input: Vec<i32>,
    /// Byte address and length of the output region.
    out_base: u32,
    out_len: usize,
    /// Bitonic segment size / memstress stride (needed by verification).
    seg: u32,
}

/// Merged result of a multi-phase benchmark run. Phase launches are
/// sequential on the device, so cycles add.
#[derive(Debug, Clone)]
pub struct BenchRun {
    pub phases: Vec<LaunchResult>,
    pub cycles: u64,
    /// Aggregated counters across phases and SMs (cycles = summed phase
    /// critical paths).
    pub stats: SmStats,
}

impl BenchRun {
    pub fn exec_time_ms(&self) -> f64 {
        self.cycles as f64 / crate::gpgpu::CLOCK_HZ * 1e3
    }
}

/// Per-run knobs for [`Workload::run`], mirroring the launch-level knobs
/// of [`crate::gpgpu::LaunchRequest`] (mode / admission signature /
/// memory hierarchy) so every phase of a workload launches the same way.
/// `RunOptions::default()` is a sequential run on the built-in native ALU
/// under the device's configured memory hierarchy.
#[derive(Default)]
pub struct RunOptions<'a> {
    mode: Option<ExecMode<'a>>,
    sig: Option<CapabilitySignature>,
    memory: Option<MemoryConfig>,
    fault: Option<&'a FaultPlan>,
    watchdog: Option<u64>,
    engine: Option<EngineMode>,
    checkpoint: Option<CheckpointPolicy>,
}

impl<'a> RunOptions<'a> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sequential execution on an explicit ALU backend.
    pub fn sequential(mut self, alu: &'a mut dyn AluBackend) -> Self {
        self.mode = Some(ExecMode::Sequential(alu));
        self
    }

    /// Thread-per-SM execution on the native ALU.
    pub fn parallel(self) -> Self {
        self.parallel_with(&NativeAlu)
    }

    /// Thread-per-SM execution with an explicit per-SM backend factory.
    pub fn parallel_with(mut self, factory: &'a dyn AluFactory) -> Self {
        self.mode = Some(ExecMode::Parallel(factory));
        self
    }

    /// Admit every phase on an explicit (e.g. profile-refined) signature
    /// instead of the kernel's own.
    pub fn admit(mut self, sig: CapabilitySignature) -> Self {
        self.sig = Some(sig);
        self
    }

    /// Override the device's memory hierarchy for this run.
    pub fn memory(mut self, memory: MemoryConfig) -> Self {
        self.memory = Some(memory);
        self
    }

    /// Inject soft errors from a deterministic [`FaultPlan`] on every phase.
    pub fn fault(mut self, plan: &'a FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }

    /// Override the device watchdog budget (cycles) for every phase.
    pub fn watchdog(mut self, cycles: u64) -> Self {
        self.watchdog = Some(cycles);
        self
    }

    /// Override the execute-stage engine for every phase (the default is
    /// the device's — [`EngineMode::Vector`] out of the box).
    pub fn engine(mut self, engine: EngineMode) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Force the per-lane scalar oracle loop — shorthand for
    /// `.engine(EngineMode::Scalar)`, used by the differential suite.
    pub fn scalar(self) -> Self {
        self.engine(EngineMode::Scalar)
    }

    /// Barrier checkpoint/restart on every phase (see
    /// [`LaunchRequest::checkpoint`]): uncorrectable faults restore the
    /// latest barrier snapshot instead of failing the launch.
    pub fn checkpoint(mut self, policy: CheckpointPolicy) -> Self {
        self.checkpoint = Some(policy);
        self
    }
}

/// Supported problem sizes (paper §5.1.1).
pub const PAPER_SIZES: [u32; 4] = [32, 64, 128, 256];

/// Build the memory-stress workload at problem size `n` with an explicit
/// element `stride` (see `asm/memstress.flex`): stride 1 streams adjacent
/// lines (high L1 hit rate), stride >= the line size touches a fresh line
/// per trip. `prepare(BenchId::MemStress, ..)` is the stride-1 form.
pub fn prepare_memstress(n: u32, seed: u64, stride: u32) -> Workload {
    assert!(
        n.is_power_of_two() && (32..=256).contains(&n),
        "problem size must be a power of two in 32..=256 (got {n})"
    );
    assert!(stride >= 1, "memstress stride must be >= 1");
    let id = BenchId::MemStress;
    let kernel = KernelRegistry::global()
        .get_or_assemble(id.source())
        .expect("benchmark kernels must assemble");
    let mut rng = XorShift64::new(seed ^ (id as u64) << 32);
    let input: Vec<i32> = (0..id.input_elems(n)).map(|_| rng.small_i32()).collect();

    let out = IN_BASE + 4 * n;
    let block = n.min(64);
    let phases = vec![Phase {
        launch: LaunchConfig::linear(n / block, block),
        params: vec![IN_BASE as i32, out as i32, (n - 1) as i32, stride as i32],
    }];
    let gmem_bytes = (out + 4 * n + 4096).next_power_of_two();

    Workload {
        id,
        n,
        seed,
        kernel,
        phases,
        gmem_bytes,
        input,
        out_base: out,
        out_len: n as usize,
        seg: stride,
    }
}

/// Build a workload for benchmark `id` at problem size `n` (power of two,
/// 32..=256) with deterministic `seed`.
pub fn prepare(id: BenchId, n: u32, seed: u64) -> Workload {
    if id == BenchId::MemStress {
        return prepare_memstress(n, seed, 1);
    }
    assert!(
        n.is_power_of_two() && (32..=256).contains(&n),
        "problem size must be a power of two in 32..=256 (got {n})"
    );
    let kernel = KernelRegistry::global()
        .get_or_assemble(id.source())
        .expect("benchmark kernels must assemble");
    let mut rng = XorShift64::new(seed ^ (id as u64) << 32);
    let input: Vec<i32> = (0..id.input_elems(n)).map(|_| rng.small_i32()).collect();

    let b = |v: u32| IN_BASE + 4 * v; // element -> byte helper
    let (phases, out_base, out_len, seg) = match id {
        BenchId::VecAdd => {
            let (a, bb, out) = (IN_BASE, b(n), b(2 * n));
            let block = n.min(64);
            (
                vec![Phase {
                    launch: LaunchConfig::linear(n / block, block),
                    params: vec![a as i32, bb as i32, out as i32],
                }],
                out,
                n as usize,
                0,
            )
        }
        BenchId::Autocorr => {
            let (x, r) = (IN_BASE, b(n));
            (
                vec![Phase {
                    launch: LaunchConfig::linear(n / 16, 16),
                    params: vec![x as i32, r as i32, n as i32],
                }],
                r,
                n as usize,
                0,
            )
        }
        BenchId::Bitonic => {
            let seg = n.min(64);
            (
                vec![Phase {
                    launch: LaunchConfig::linear(n / seg, seg),
                    params: vec![IN_BASE as i32, seg.trailing_zeros() as i32],
                }],
                IN_BASE, // sorts in place
                n as usize,
                seg,
            )
        }
        BenchId::MatMul => {
            let (a, bb, c) = (IN_BASE, b(n * n), b(2 * n * n));
            let tiles = n / 16;
            (
                vec![Phase {
                    launch: LaunchConfig { grid_x: tiles, grid_y: tiles, block_threads: 256 },
                    params: vec![a as i32, bb as i32, c as i32, n as i32],
                }],
                c,
                (n * n) as usize,
                0,
            )
        }
        BenchId::Transpose => {
            let (a, out) = (IN_BASE, b(n * n));
            let tiles = n / 16;
            (
                vec![Phase {
                    launch: LaunchConfig { grid_x: tiles, grid_y: tiles, block_threads: 256 },
                    params: vec![a as i32, out as i32, n as i32],
                }],
                out,
                (n * n) as usize,
                0,
            )
        }
        BenchId::Reduction => {
            // Phase 1: each 32-thread block reduces 64 elements (n < 64:
            // one n/2-thread block). Phase 2 (grid > 1): one block reduces
            // the partials.
            let partials = b(n);
            let (grid1, block1) = if n < 64 { (1, n / 2) } else { (n / 64, 32) };
            let mut phases = vec![Phase {
                launch: LaunchConfig::linear(grid1, block1),
                params: vec![IN_BASE as i32, partials as i32],
            }];
            let mut out = partials;
            if grid1 > 1 {
                let fin = partials + 4 * grid1;
                phases.push(Phase {
                    launch: LaunchConfig::linear(1, grid1 / 2),
                    params: vec![partials as i32, fin as i32],
                });
                out = fin;
            }
            (phases, out, 1, 0)
        }
        BenchId::MemStress => unreachable!("handled by prepare_memstress above"),
    };

    // Room for inputs + outputs + slack.
    let high = out_base + 4 * out_len as u32;
    let gmem_bytes = (high + 4096).next_power_of_two();

    Workload {
        id,
        n,
        seed,
        kernel,
        phases,
        gmem_bytes,
        input,
        out_base,
        out_len,
        seg,
    }
}

impl Workload {
    /// Allocate device memory and DMA the inputs in (driver behaviour).
    pub fn make_gmem(&self) -> GlobalMem {
        let mut g = GlobalMem::new(self.gmem_bytes);
        g.write_words(IN_BASE, &self.input).expect("input fits");
        g
    }

    /// Execute all phases on `gpgpu`, returning merged statistics. The
    /// [`RunOptions`] mirror the per-launch knobs of
    /// [`crate::gpgpu::LaunchRequest`] — execution mode (default:
    /// sequential on the built-in native ALU), admission signature
    /// (default: the kernel's own) and memory hierarchy (default: the
    /// device's) — applied to every phase launch:
    ///
    /// ```ignore
    /// w.run(&gpgpu, &mut gmem, RunOptions::default())?;          // sequential
    /// w.run(&gpgpu, &mut gmem, RunOptions::new().parallel())?;   // thread/SM
    /// ```
    pub fn run(
        &self,
        gpgpu: &Gpgpu,
        gmem: &mut GlobalMem,
        mut opts: RunOptions<'_>,
    ) -> Result<BenchRun, SimError> {
        let sig = opts.sig.unwrap_or(self.kernel.sig);
        let mut phases = Vec::with_capacity(self.phases.len());
        let mut cycles = 0u64;
        let mut stats = SmStats::default();
        for ph in &self.phases {
            let mut req = LaunchRequest::new(&*self.kernel, ph.launch, &mut *gmem)
                .params(&ph.params)
                .admit(sig);
            if let Some(m) = opts.memory {
                req = req.memory(m);
            }
            if let Some(plan) = opts.fault {
                req = req.fault(plan);
            }
            if let Some(cycles) = opts.watchdog {
                req = req.watchdog(cycles);
            }
            if let Some(engine) = opts.engine {
                req = req.engine(engine);
            }
            if let Some(policy) = opts.checkpoint {
                req = req.checkpoint(policy);
            }
            // Reborrow the mode per phase: a sequential backend is handed
            // out as a fresh `&mut` each launch.
            req = match &mut opts.mode {
                None => req,
                Some(ExecMode::Sequential(alu)) => req.sequential(&mut **alu),
                Some(ExecMode::Parallel(factory)) => req.parallel_with(&**factory),
            };
            let r = gpgpu.launch(req)?;
            cycles += r.total.cycles;
            stats.merge(&r.total);
            phases.push(r);
        }
        stats.cycles = cycles;
        Ok(BenchRun { phases, cycles, stats })
    }

    // ------------------------------------------------------------------
    // Pre-redesign entry points, kept as thin shims over `run`.
    // ------------------------------------------------------------------

    /// Sequential run admitted on an explicit signature.
    #[deprecated(note = "use Workload::run with RunOptions::admit")]
    pub fn run_admitted(
        &self,
        gpgpu: &Gpgpu,
        sig: &CapabilitySignature,
        gmem: &mut GlobalMem,
        alu: &mut dyn AluBackend,
    ) -> Result<BenchRun, SimError> {
        self.run(gpgpu, gmem, RunOptions::new().sequential(alu).admit(*sig))
    }

    /// Thread-per-SM run.
    #[deprecated(note = "use Workload::run with RunOptions::parallel_with")]
    pub fn run_parallel(
        &self,
        gpgpu: &Gpgpu,
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<BenchRun, SimError> {
        self.run(gpgpu, gmem, RunOptions::new().parallel_with(factory))
    }

    /// Thread-per-SM run admitted on an explicit signature.
    #[deprecated(note = "use Workload::run with RunOptions::parallel_with + admit")]
    pub fn run_parallel_admitted(
        &self,
        gpgpu: &Gpgpu,
        sig: &CapabilitySignature,
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<BenchRun, SimError> {
        self.run(gpgpu, gmem, RunOptions::new().parallel_with(factory).admit(*sig))
    }

    /// Expected output (golden reference on the host).
    pub fn expected(&self) -> Vec<i32> {
        let n = self.n as usize;
        match self.id {
            BenchId::Autocorr => golden::autocorr(&self.input),
            BenchId::Bitonic => golden::bitonic_segments(&self.input, self.seg as usize),
            BenchId::MatMul => {
                golden::matmul(&self.input[..n * n], &self.input[n * n..], n)
            }
            BenchId::Reduction => vec![golden::reduction(&self.input)],
            BenchId::Transpose => golden::transpose(&self.input, n),
            BenchId::VecAdd => golden::vecadd(&self.input[..n], &self.input[n..]),
            BenchId::MemStress => golden::memstress(&self.input, self.seg),
        }
    }

    /// Compare device output against the golden reference.
    pub fn verify(&self, gmem: &GlobalMem) -> Result<(), String> {
        let got = gmem
            .read_words(self.out_base, self.out_len)
            .map_err(|e| format!("reading output: {e}"))?;
        let want = self.expected();
        if got == want {
            return Ok(());
        }
        let idx = got
            .iter()
            .zip(&want)
            .position(|(g, w)| g != w)
            .unwrap_or(0);
        Err(format!(
            "{} n={}: output mismatch at element {idx}: got {} want {} \
             ({} of {} wrong)",
            self.id.name(),
            self.n,
            got[idx],
            want[idx],
            got.iter().zip(&want).filter(|(g, w)| g != w).count(),
            want.len(),
        ))
    }
}

/// Convenience: prepare + run + verify in one call. Returns the merged run
/// statistics; panics on verification failure (tests/benches want loud
/// failures).
pub fn run_verified(
    id: BenchId,
    n: u32,
    gpgpu: &Gpgpu,
    alu: &mut dyn AluBackend,
    seed: u64,
) -> Result<BenchRun, SimError> {
    let w = prepare(id, n, seed);
    let mut gmem = w.make_gmem();
    let run = w.run(gpgpu, &mut gmem, RunOptions::new().sequential(alu))?;
    if let Err(e) = w.verify(&gmem) {
        panic!("verification failed: {e}");
    }
    Ok(run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;
    use crate::gpgpu::GpgpuConfig;
    use crate::sim::NativeAlu;

    fn run(id: BenchId, n: u32, sms: u32, sp: u32) -> BenchRun {
        let gpgpu = Gpgpu::new(GpgpuConfig::new(sms, sp));
        let mut alu = NativeAlu;
        run_verified(id, n, &gpgpu, &mut alu, 0xF00D).unwrap()
    }

    #[test]
    fn all_benchmarks_assemble() {
        for id in BenchId::ALL {
            let k = assemble(id.source()).unwrap();
            assert_eq!(k.name, id.name(), "entry name matches");
            assert!(k.regs_per_thread <= 16);
        }
    }

    #[test]
    fn vecadd_32_correct() {
        let r = run(BenchId::VecAdd, 32, 1, 8);
        assert!(r.cycles > 0);
    }

    #[test]
    fn autocorr_32_correct() {
        let r = run(BenchId::Autocorr, 32, 1, 8);
        // divergent loop exits must be observed
        assert!(r.stats.divergences > 0, "autocorr must diverge");
    }

    #[test]
    fn autocorr_stack_depth_is_paper_16() {
        let r = run(BenchId::Autocorr, 64, 1, 8);
        assert_eq!(r.stats.max_stack_depth, 16, "Table 6: autocorr depth 16");
    }

    #[test]
    fn bitonic_64_correct_depth_2() {
        let r = run(BenchId::Bitonic, 64, 1, 8);
        assert_eq!(r.stats.max_stack_depth, 2, "Table 6: bitonic depth 2");
        assert!(r.stats.divergences > 0);
    }

    #[test]
    fn bitonic_needs_no_multiplier() {
        let r = run(BenchId::Bitonic, 64, 1, 8);
        assert_eq!(r.stats.multiplier_ops(), 0, "paper §5.2");
    }

    #[test]
    fn matmul_32_correct_depth_0() {
        let r = run(BenchId::MatMul, 32, 1, 8);
        assert_eq!(r.stats.max_stack_depth, 0, "Table 6: matmul depth 0");
        assert_eq!(r.stats.divergences, 0);
    }

    #[test]
    fn reduction_two_phase_correct() {
        let r = run(BenchId::Reduction, 256, 1, 8);
        assert_eq!(r.phases.len(), 2, "256 elements need a partials pass");
        assert_eq!(r.stats.max_stack_depth, 0, "Table 6: reduction depth 0");
    }

    #[test]
    fn reduction_single_phase_small() {
        let r = run(BenchId::Reduction, 32, 1, 8);
        assert_eq!(r.phases.len(), 1);
    }

    #[test]
    fn transpose_32_correct_depth_0() {
        let r = run(BenchId::Transpose, 32, 1, 8);
        assert_eq!(r.stats.max_stack_depth, 0, "Table 6: transpose depth 0");
    }

    #[test]
    fn all_benchmarks_verify_on_two_sms() {
        for id in BenchId::PAPER {
            let r = run(id, 64, 2, 16);
            assert!(r.cycles > 0, "{}", id.name());
        }
    }

    #[test]
    fn seeds_change_data_not_correctness() {
        for seed in [1u64, 2, 3] {
            let gpgpu = Gpgpu::new(GpgpuConfig::new(1, 32));
            let mut alu = NativeAlu;
            run_verified(BenchId::Bitonic, 128, &gpgpu, &mut alu, seed).unwrap();
        }
    }

    #[test]
    fn memstress_64_correct_depth_0() {
        let r = run(BenchId::MemStress, 64, 1, 8);
        // Uniform trip count: the guarded backward branch never diverges.
        assert_eq!(r.stats.max_stack_depth, 0, "memstress loop is uniform");
        assert_eq!(r.stats.multiplier_ops(), 0, "strides avoid the multiplier");
        assert!(r.stats.global_load_txns > 0);
    }

    #[test]
    fn memstress_strides_verify() {
        let gpgpu = Gpgpu::new(GpgpuConfig::new(2, 8));
        for stride in [1u32, 8, 32, 64] {
            let w = prepare_memstress(64, 0xF00D, stride);
            let mut gmem = w.make_gmem();
            w.run(&gpgpu, &mut gmem, RunOptions::default()).unwrap();
            w.verify(&gmem).unwrap_or_else(|e| panic!("stride {stride}: {e}"));
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_run_shims_match_the_unified_run() {
        let gpgpu = Gpgpu::new(GpgpuConfig::new(2, 8));
        let w = prepare(BenchId::VecAdd, 64, 7);

        let mut g0 = w.make_gmem();
        let base = w.run(&gpgpu, &mut g0, RunOptions::default()).unwrap();

        let mut alu = NativeAlu;
        let mut g1 = w.make_gmem();
        let r1 = w.run_admitted(&gpgpu, &w.kernel.sig, &mut g1, &mut alu).unwrap();
        assert_eq!(r1.cycles, base.cycles);

        let mut g2 = w.make_gmem();
        let r2 = w.run_parallel(&gpgpu, &mut g2, &NativeAlu).unwrap();
        assert_eq!(r2.cycles, base.cycles);

        let mut g3 = w.make_gmem();
        let r3 = w
            .run_parallel_admitted(&gpgpu, &w.kernel.sig, &mut g3, &NativeAlu)
            .unwrap();
        assert_eq!(r3.cycles, base.cycles);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn bad_size_rejected() {
        prepare(BenchId::VecAdd, 48, 0);
    }
}
