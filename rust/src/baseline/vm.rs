//! The scalar VM: a MicroBlaze-subset ISA with per-instruction cycle
//! costs, plus a small two-pass builder for writing programs in Rust.

/// Register index (r0 hardwired to zero, MicroBlaze convention).
pub type Reg = u8;
pub const NUM_MB_REGS: usize = 32;

/// MicroBlaze-subset operations. Branch targets are instruction indices
/// (resolved by the builder).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MbOp {
    /// rd = imm.
    Li(Reg, i32),
    Add(Reg, Reg, Reg),
    Addi(Reg, Reg, i32),
    Sub(Reg, Reg, Reg),
    Mul(Reg, Reg, Reg),
    And(Reg, Reg, Reg),
    Andi(Reg, Reg, i32),
    Or(Reg, Reg, Reg),
    Xor(Reg, Reg, Reg),
    /// rd = ra << imm (barrel shifter).
    Slli(Reg, Reg, u8),
    Srli(Reg, Reg, u8),
    Srai(Reg, Reg, u8),
    /// rd = mem[ra + rb] (byte address, word access).
    Lw(Reg, Reg, Reg),
    /// rd = mem[ra + imm].
    Lwi(Reg, Reg, i32),
    /// mem[ra + rb] = rd.
    Sw(Reg, Reg, Reg),
    /// mem[ra + imm] = rd.
    Swi(Reg, Reg, i32),
    Beq(Reg, Reg, u32),
    Bne(Reg, Reg, u32),
    Blt(Reg, Reg, u32),
    Bge(Reg, Reg, u32),
    Ble(Reg, Reg, u32),
    Bgt(Reg, Reg, u32),
    Br(u32),
    Halt,
}

impl MbOp {
    fn is_mem(self) -> bool {
        matches!(
            self,
            MbOp::Lw(..) | MbOp::Lwi(..) | MbOp::Sw(..) | MbOp::Swi(..)
        )
    }

    fn is_mul(self) -> bool {
        matches!(self, MbOp::Mul(..))
    }
}

/// Cycle costs (100 MHz soft core, uncached, DDR behind AXI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MbTiming {
    /// Instruction fetch from DDR (no I-cache) — dominates everything,
    /// and is what the paper's MicroBlaze numbers imply (DESIGN.md).
    pub ifetch: u32,
    /// Base execute cost.
    pub exec: u32,
    /// Extra cycles for a data load/store (no D-cache).
    pub mem: u32,
    /// Extra cycles for a taken branch (pipeline refill).
    pub branch_taken: u32,
    /// Extra cycles for the hardware multiplier.
    pub mul: u32,
}

impl Default for MbTiming {
    fn default() -> Self {
        // Calibrated so matmul-256 lands near the paper's 186 s (§5.1,
        // Table 5): ~1100 cycles per inner-loop iteration, dominated by
        // uncached DDR instruction fetches. See DESIGN.md §Calibration.
        MbTiming { ifetch: 75, exec: 1, mem: 75, branch_taken: 2, mul: 2 }
    }
}

#[derive(Debug, Clone, Default)]
pub struct MbStats {
    pub cycles: u64,
    pub instructions: u64,
    pub loads: u64,
    pub stores: u64,
    pub taken_branches: u64,
}

impl MbStats {
    pub fn exec_time_ms(&self, clock_hz: f64) -> f64 {
        self.cycles as f64 / clock_hz * 1e3
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MbError {
    MemFault { addr: u32 },
    /// PC ran past the end of the program without `Halt`.
    RanOff { pc: u32 },
    Watchdog { cycles: u64 },
    /// Output did not match the golden reference.
    WrongResult(&'static str),
}

impl std::fmt::Display for MbError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MbError::MemFault { addr } => write!(f, "memory fault at {addr:#x}"),
            MbError::RanOff { pc } => write!(f, "ran off program at pc={pc}"),
            MbError::Watchdog { cycles } => write!(f, "watchdog after {cycles} cycles"),
            MbError::WrongResult(b) => write!(f, "wrong result for benchmark {b}"),
        }
    }
}

impl std::error::Error for MbError {}

/// An assembled scalar program.
#[derive(Debug, Clone)]
pub struct MbProgram {
    pub ops: Vec<MbOp>,
}

/// Two-pass builder with forward labels.
#[derive(Debug, Default)]
pub struct MbBuilder {
    ops: Vec<MbOp>,
    /// label id -> instruction index.
    labels: Vec<Option<u32>>,
    /// (instruction index, label id) patch list.
    patches: Vec<(usize, usize)>,
}

impl MbBuilder {
    pub fn new() -> MbBuilder {
        MbBuilder::default()
    }

    pub fn label(&mut self) -> usize {
        self.labels.push(None);
        self.labels.len() - 1
    }

    pub fn bind(&mut self, label: usize) {
        assert!(self.labels[label].is_none(), "label bound twice");
        self.labels[label] = Some(self.ops.len() as u32);
    }

    pub fn push(&mut self, op: MbOp) {
        self.ops.push(op);
    }

    /// Push a branch to `label` (target patched at `build`).
    pub fn branch(&mut self, op: MbOp, label: usize) {
        self.patches.push((self.ops.len(), label));
        self.ops.push(op);
    }

    pub fn build(mut self) -> MbProgram {
        for (at, label) in self.patches {
            let target = self.labels[label].expect("unbound label");
            let op = &mut self.ops[at];
            match op {
                MbOp::Beq(_, _, t)
                | MbOp::Bne(_, _, t)
                | MbOp::Blt(_, _, t)
                | MbOp::Bge(_, _, t)
                | MbOp::Ble(_, _, t)
                | MbOp::Bgt(_, _, t)
                | MbOp::Br(t) => *t = target,
                other => panic!("patching non-branch {other:?}"),
            }
        }
        MbProgram { ops: self.ops }
    }
}

/// The scalar core + its DDR.
pub struct MicroBlaze {
    pub regs: [i32; NUM_MB_REGS],
    mem: Vec<i32>,
    timing: MbTiming,
    pub watchdog_cycles: u64,
}

impl MicroBlaze {
    pub fn new(mem_bytes: u32, timing: MbTiming) -> MicroBlaze {
        MicroBlaze {
            regs: [0; NUM_MB_REGS],
            mem: vec![0; (mem_bytes as usize).div_ceil(4)],
            timing,
            watchdog_cycles: 1_000_000_000_000,
        }
    }

    pub fn write_words(&mut self, byte_addr: u32, data: &[i32]) {
        let base = (byte_addr / 4) as usize;
        self.mem[base..base + data.len()].copy_from_slice(data);
    }

    pub fn read_words(&self, byte_addr: u32, count: usize) -> Vec<i32> {
        let base = (byte_addr / 4) as usize;
        self.mem[base..base + count].to_vec()
    }

    #[inline]
    fn r(&self, r: Reg) -> i32 {
        if r == 0 {
            0
        } else {
            self.regs[r as usize]
        }
    }

    #[inline]
    fn w(&mut self, r: Reg, v: i32) {
        if r != 0 {
            self.regs[r as usize] = v;
        }
    }

    #[inline]
    fn load(&self, addr: i64) -> Result<i32, MbError> {
        let a = addr as u32;
        if a % 4 != 0 || (a / 4) as usize >= self.mem.len() {
            return Err(MbError::MemFault { addr: a });
        }
        Ok(self.mem[(a / 4) as usize])
    }

    #[inline]
    fn store(&mut self, addr: i64, v: i32) -> Result<(), MbError> {
        let a = addr as u32;
        if a % 4 != 0 || (a / 4) as usize >= self.mem.len() {
            return Err(MbError::MemFault { addr: a });
        }
        self.mem[(a / 4) as usize] = v;
        Ok(())
    }

    /// Execute `prog` to `Halt`, accumulating the cycle model.
    pub fn run(&mut self, prog: &MbProgram) -> Result<MbStats, MbError> {
        let mut stats = MbStats::default();
        let t = self.timing;
        let mut pc: u32 = 0;
        loop {
            let op = *prog
                .ops
                .get(pc as usize)
                .ok_or(MbError::RanOff { pc })?;
            stats.instructions += 1;
            stats.cycles += (t.ifetch + t.exec) as u64;
            if op.is_mem() {
                stats.cycles += t.mem as u64;
            }
            if op.is_mul() {
                stats.cycles += t.mul as u64;
            }
            let mut next = pc + 1;
            let mut take = |cond: bool, target: u32, stats: &mut MbStats| {
                if cond {
                    next = target;
                    stats.taken_branches += 1;
                    stats.cycles += t.branch_taken as u64;
                }
            };
            match op {
                MbOp::Li(d, v) => self.w(d, v),
                MbOp::Add(d, a, b) => self.w(d, self.r(a).wrapping_add(self.r(b))),
                MbOp::Addi(d, a, v) => self.w(d, self.r(a).wrapping_add(v)),
                MbOp::Sub(d, a, b) => self.w(d, self.r(a).wrapping_sub(self.r(b))),
                MbOp::Mul(d, a, b) => self.w(d, self.r(a).wrapping_mul(self.r(b))),
                MbOp::And(d, a, b) => self.w(d, self.r(a) & self.r(b)),
                MbOp::Andi(d, a, v) => self.w(d, self.r(a) & v),
                MbOp::Or(d, a, b) => self.w(d, self.r(a) | self.r(b)),
                MbOp::Xor(d, a, b) => self.w(d, self.r(a) ^ self.r(b)),
                MbOp::Slli(d, a, s) => self.w(d, ((self.r(a) as u32) << (s & 31)) as i32),
                MbOp::Srli(d, a, s) => self.w(d, ((self.r(a) as u32) >> (s & 31)) as i32),
                MbOp::Srai(d, a, s) => self.w(d, self.r(a) >> (s & 31)),
                MbOp::Lw(d, a, b) => {
                    let v = self.load(self.r(a) as i64 + self.r(b) as i64)?;
                    self.w(d, v);
                    stats.loads += 1;
                }
                MbOp::Lwi(d, a, off) => {
                    let v = self.load(self.r(a) as i64 + off as i64)?;
                    self.w(d, v);
                    stats.loads += 1;
                }
                MbOp::Sw(d, a, b) => {
                    self.store(self.r(a) as i64 + self.r(b) as i64, self.r(d))?;
                    stats.stores += 1;
                }
                MbOp::Swi(d, a, off) => {
                    self.store(self.r(a) as i64 + off as i64, self.r(d))?;
                    stats.stores += 1;
                }
                MbOp::Beq(a, b, tgt) => take(self.r(a) == self.r(b), tgt, &mut stats),
                MbOp::Bne(a, b, tgt) => take(self.r(a) != self.r(b), tgt, &mut stats),
                MbOp::Blt(a, b, tgt) => take(self.r(a) < self.r(b), tgt, &mut stats),
                MbOp::Bge(a, b, tgt) => take(self.r(a) >= self.r(b), tgt, &mut stats),
                MbOp::Ble(a, b, tgt) => take(self.r(a) <= self.r(b), tgt, &mut stats),
                MbOp::Bgt(a, b, tgt) => take(self.r(a) > self.r(b), tgt, &mut stats),
                MbOp::Br(tgt) => take(true, tgt, &mut stats),
                MbOp::Halt => return Ok(stats),
            }
            pc = next;
            if stats.cycles > self.watchdog_cycles {
                return Err(MbError::Watchdog { cycles: stats.cycles });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r0_hardwired_zero() {
        let mut mb = MicroBlaze::new(64, MbTiming::default());
        let prog = MbProgram { ops: vec![MbOp::Li(0, 42), MbOp::Halt] };
        mb.run(&prog).unwrap();
        assert_eq!(mb.regs[0], 0);
    }

    #[test]
    fn loop_sums_and_counts_cycles() {
        // sum = 0; for i in 0..10 { sum += i } ; mem[0] = sum
        let mut b = MbBuilder::new();
        let top = b.label();
        b.push(MbOp::Li(1, 0)); // i
        b.push(MbOp::Li(2, 0)); // sum
        b.push(MbOp::Li(3, 10));
        b.bind(top);
        b.push(MbOp::Add(2, 2, 1));
        b.push(MbOp::Addi(1, 1, 1));
        b.branch(MbOp::Blt(1, 3, 0), top);
        b.push(MbOp::Swi(2, 0, 0));
        b.push(MbOp::Halt);
        let prog = b.build();
        let mut mb = MicroBlaze::new(64, MbTiming::default());
        let stats = mb.run(&prog).unwrap();
        assert_eq!(mb.read_words(0, 1), vec![45]);
        // 3 + 10*3 + 2 = 35 instructions
        assert_eq!(stats.instructions, 35);
        assert_eq!(stats.taken_branches, 9);
        let t = MbTiming::default();
        let want = 35 * (t.ifetch + t.exec) as u64
            + (t.mem as u64)
            + 9 * t.branch_taken as u64;
        assert_eq!(stats.cycles, want);
    }

    #[test]
    fn mem_fault_detected() {
        let prog = MbProgram { ops: vec![MbOp::Lwi(1, 0, 1 << 20), MbOp::Halt] };
        let mut mb = MicroBlaze::new(64, MbTiming::default());
        assert!(matches!(mb.run(&prog), Err(MbError::MemFault { .. })));
    }

    #[test]
    fn ran_off_detected() {
        let prog = MbProgram { ops: vec![MbOp::Li(1, 1)] };
        let mut mb = MicroBlaze::new(64, MbTiming::default());
        assert!(matches!(mb.run(&prog), Err(MbError::RanOff { .. })));
    }

    #[test]
    #[should_panic(expected = "unbound label")]
    fn unbound_label_panics() {
        let mut b = MbBuilder::new();
        let l = b.label();
        b.branch(MbOp::Br(0), l);
        b.build();
    }
}
