//! C-equivalent scalar programs for the paper's benchmarks, built with
//! `MbBuilder` — these are what `gcc -O2` would emit for the C versions
//! the paper ran on the MicroBlaze (§5.1), structured loop-for-loop.
//!
//! Memory layout matches the GPGPU workloads (`kernels::prepare`):
//! inputs at `IN_BASE`, outputs following, so both machines are verified
//! against the same golden references.

use super::vm::{MbBuilder, MbOp, MbProgram};
use crate::kernels::{BenchId, IN_BASE};

const IB: i32 = IN_BASE as i32;

/// Build the scalar program for `id` at problem size `n`.
pub fn build_program(id: BenchId, n: u32) -> MbProgram {
    match id {
        BenchId::VecAdd => vecadd(n),
        BenchId::Autocorr => autocorr(n),
        BenchId::Bitonic => bitonic(n),
        BenchId::MatMul => matmul(n),
        BenchId::Reduction => reduction(n),
        BenchId::Transpose => transpose(n),
        BenchId::MemStress => memstress(n),
    }
}

/// out[i] = a[i] + b[i]
fn vecadd(n: u32) -> MbProgram {
    let n = n as i32;
    let mut b = MbBuilder::new();
    let top = b.label();
    b.push(MbOp::Li(10, IB)); // a
    b.push(MbOp::Li(11, IB + 4 * n)); // b
    b.push(MbOp::Li(12, IB + 8 * n)); // out
    b.push(MbOp::Li(1, 0)); // i
    b.push(MbOp::Li(2, n));
    b.bind(top);
    b.push(MbOp::Slli(3, 1, 2));
    b.push(MbOp::Lw(4, 10, 3));
    b.push(MbOp::Lw(5, 11, 3));
    b.push(MbOp::Add(6, 4, 5));
    b.push(MbOp::Sw(6, 12, 3));
    b.push(MbOp::Addi(1, 1, 1));
    b.branch(MbOp::Blt(1, 2, 0), top);
    b.push(MbOp::Halt);
    b.build()
}

/// r[k] = sum_{i=0}^{n-1-k} x[i]*x[i+k]
fn autocorr(n: u32) -> MbProgram {
    let n = n as i32;
    let mut b = MbBuilder::new();
    let lk = b.label();
    let li = b.label();
    let istore = b.label();
    b.push(MbOp::Li(10, IB)); // x
    b.push(MbOp::Li(11, IB + 4 * n)); // r
    b.push(MbOp::Li(4, n));
    b.push(MbOp::Li(1, 0)); // k
    b.bind(lk);
    b.push(MbOp::Li(3, 0)); // acc
    b.push(MbOp::Li(2, 0)); // i
    b.push(MbOp::Sub(5, 4, 1)); // trips = n - k
    b.branch(MbOp::Ble(5, 0, 0), istore);
    b.bind(li);
    b.push(MbOp::Slli(6, 2, 2));
    b.push(MbOp::Lw(7, 10, 6)); // x[i]
    b.push(MbOp::Add(6, 2, 1));
    b.push(MbOp::Slli(6, 6, 2));
    b.push(MbOp::Lw(8, 10, 6)); // x[i+k]
    b.push(MbOp::Mul(7, 7, 8));
    b.push(MbOp::Add(3, 3, 7));
    b.push(MbOp::Addi(2, 2, 1));
    b.branch(MbOp::Blt(2, 5, 0), li);
    b.bind(istore);
    b.push(MbOp::Slli(6, 1, 2));
    b.push(MbOp::Sw(3, 11, 6)); // r[k] = acc
    b.push(MbOp::Addi(1, 1, 1));
    b.branch(MbOp::Blt(1, 4, 0), lk);
    b.push(MbOp::Halt);
    b.build()
}

/// Segmented in-place bitonic sort, ascending per segment — the same
/// contract as the GPGPU kernel.
fn bitonic(n: u32) -> MbProgram {
    let seg = n.min(64) as i32;
    let n = n as i32;
    let mut b = MbBuilder::new();
    let lsb = b.label(); // segment loop
    let lk = b.label();
    let lj = b.label();
    let lt = b.label();
    let ldesc = b.label();
    let ldoswap = b.label();
    let lskip = b.label();
    b.push(MbOp::Li(10, IB)); // data
    b.push(MbOp::Li(11, seg));
    b.push(MbOp::Li(12, n));
    b.push(MbOp::Li(1, 0)); // sb (segment base element)
    b.bind(lsb);
    b.push(MbOp::Li(2, 2)); // k
    b.bind(lk);
    b.push(MbOp::Srli(3, 2, 1)); // j = k/2
    b.bind(lj);
    b.push(MbOp::Li(4, 0)); // t
    b.bind(lt);
    b.push(MbOp::Xor(5, 4, 3)); // partner
    b.branch(MbOp::Ble(5, 4, 0), lskip);
    b.push(MbOp::Add(8, 1, 4));
    b.push(MbOp::Slli(8, 8, 2));
    b.push(MbOp::Add(13, 8, 10)); // &data[sb+t]
    b.push(MbOp::Lwi(6, 13, 0));
    b.push(MbOp::Add(8, 1, 5));
    b.push(MbOp::Slli(8, 8, 2));
    b.push(MbOp::Add(14, 8, 10)); // &data[sb+p]
    b.push(MbOp::Lwi(7, 14, 0));
    b.push(MbOp::And(8, 4, 2)); // direction
    b.branch(MbOp::Bne(8, 0, 0), ldesc);
    b.branch(MbOp::Ble(6, 7, 0), lskip); // ascending, already ordered
    b.branch(MbOp::Br(0), ldoswap);
    b.bind(ldesc);
    b.branch(MbOp::Bge(6, 7, 0), lskip); // descending, already ordered
    b.bind(ldoswap);
    b.push(MbOp::Swi(7, 13, 0));
    b.push(MbOp::Swi(6, 14, 0));
    b.bind(lskip);
    b.push(MbOp::Addi(4, 4, 1));
    b.branch(MbOp::Blt(4, 11, 0), lt);
    b.push(MbOp::Srli(3, 3, 1));
    b.branch(MbOp::Bgt(3, 0, 0), lj);
    b.push(MbOp::Slli(2, 2, 1));
    b.branch(MbOp::Ble(2, 11, 0), lk);
    b.push(MbOp::Addi(1, 1, seg));
    b.branch(MbOp::Blt(1, 12, 0), lsb);
    b.push(MbOp::Halt);
    b.build()
}

/// C[i][j] = sum_k A[i][k]*B[k][j]
fn matmul(n: u32) -> MbProgram {
    let n = n as i32;
    let mut b = MbBuilder::new();
    let li = b.label();
    let lj = b.label();
    let lk = b.label();
    b.push(MbOp::Li(10, IB)); // A
    b.push(MbOp::Li(11, IB + 4 * n * n)); // B
    b.push(MbOp::Li(12, IB + 8 * n * n)); // C
    b.push(MbOp::Li(4, n));
    b.push(MbOp::Li(1, 0)); // i
    b.bind(li);
    b.push(MbOp::Mul(5, 1, 4)); // i*n
    b.push(MbOp::Li(2, 0)); // j
    b.bind(lj);
    b.push(MbOp::Li(3, 0)); // acc
    b.push(MbOp::Li(6, 0)); // k
    b.bind(lk);
    b.push(MbOp::Add(7, 5, 6)); // i*n + k
    b.push(MbOp::Slli(7, 7, 2));
    b.push(MbOp::Lw(8, 10, 7)); // A[i][k]
    b.push(MbOp::Mul(9, 6, 4)); // k*n
    b.push(MbOp::Add(9, 9, 2));
    b.push(MbOp::Slli(9, 9, 2));
    b.push(MbOp::Lw(13, 11, 9)); // B[k][j]
    b.push(MbOp::Mul(8, 8, 13));
    b.push(MbOp::Add(3, 3, 8));
    b.push(MbOp::Addi(6, 6, 1));
    b.branch(MbOp::Blt(6, 4, 0), lk);
    b.push(MbOp::Add(7, 5, 2));
    b.push(MbOp::Slli(7, 7, 2));
    b.push(MbOp::Sw(3, 12, 7)); // C[i][j]
    b.push(MbOp::Addi(2, 2, 1));
    b.branch(MbOp::Blt(2, 4, 0), lj);
    b.push(MbOp::Addi(1, 1, 1));
    b.branch(MbOp::Blt(1, 4, 0), li);
    b.push(MbOp::Halt);
    b.build()
}

/// out = sum(x)
fn reduction(n: u32) -> MbProgram {
    let n = n as i32;
    let mut b = MbBuilder::new();
    let top = b.label();
    b.push(MbOp::Li(10, IB));
    b.push(MbOp::Li(4, n));
    b.push(MbOp::Li(1, 0)); // i
    b.push(MbOp::Li(3, 0)); // acc
    b.bind(top);
    b.push(MbOp::Slli(6, 1, 2));
    b.push(MbOp::Lw(7, 10, 6));
    b.push(MbOp::Add(3, 3, 7));
    b.push(MbOp::Addi(1, 1, 1));
    b.branch(MbOp::Blt(1, 4, 0), top);
    b.push(MbOp::Swi(3, 10, 4 * n)); // out at IN + 4n
    b.push(MbOp::Halt);
    b.build()
}

/// B[j][i] = A[i][j]
fn transpose(n: u32) -> MbProgram {
    let n = n as i32;
    let mut b = MbBuilder::new();
    let li = b.label();
    let lj = b.label();
    b.push(MbOp::Li(10, IB)); // A
    b.push(MbOp::Li(11, IB + 4 * n * n)); // B
    b.push(MbOp::Li(4, n));
    b.push(MbOp::Li(1, 0)); // i
    b.bind(li);
    b.push(MbOp::Mul(5, 1, 4)); // i*n
    b.push(MbOp::Li(2, 0)); // j
    b.bind(lj);
    b.push(MbOp::Add(7, 5, 2)); // i*n + j
    b.push(MbOp::Slli(7, 7, 2));
    b.push(MbOp::Lw(8, 10, 7));
    b.push(MbOp::Mul(9, 2, 4)); // j*n
    b.push(MbOp::Add(9, 9, 1));
    b.push(MbOp::Slli(9, 9, 2));
    b.push(MbOp::Sw(8, 11, 9));
    b.push(MbOp::Addi(2, 2, 1));
    b.branch(MbOp::Blt(2, 4, 0), lj);
    b.push(MbOp::Addi(1, 1, 1));
    b.branch(MbOp::Blt(1, 4, 0), li);
    b.push(MbOp::Halt);
    b.build()
}

/// out[t] = sum_{j=0}^{7} in[(t + j) & (n-1)] — the stride-1 form of
/// the memory-stress walk (strided variants exist only on the GPGPU
/// side, via `kernels::prepare_memstress`).
fn memstress(n: u32) -> MbProgram {
    let n = n as i32;
    let mut b = MbBuilder::new();
    let lt = b.label();
    let lj = b.label();
    b.push(MbOp::Li(10, IB)); // in
    b.push(MbOp::Li(11, IB + 4 * n)); // out
    b.push(MbOp::Li(12, n - 1)); // index mask (n is a power of two)
    b.push(MbOp::Li(13, n));
    b.push(MbOp::Li(14, 8)); // trips
    b.push(MbOp::Li(1, 0)); // t
    b.bind(lt);
    b.push(MbOp::Li(3, 0)); // acc
    b.push(MbOp::Li(2, 0)); // j
    b.bind(lj);
    b.push(MbOp::Add(4, 1, 2)); // t + j (stride 1)
    b.push(MbOp::And(4, 4, 12));
    b.push(MbOp::Slli(4, 4, 2));
    b.push(MbOp::Lw(5, 10, 4));
    b.push(MbOp::Add(3, 3, 5));
    b.push(MbOp::Addi(2, 2, 1));
    b.branch(MbOp::Blt(2, 14, 0), lj);
    b.push(MbOp::Slli(4, 1, 2));
    b.push(MbOp::Sw(3, 11, 4)); // out[t] = acc
    b.push(MbOp::Addi(1, 1, 1));
    b.branch(MbOp::Blt(1, 13, 0), lt);
    b.push(MbOp::Halt);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_programs_build() {
        for id in BenchId::ALL {
            for n in [32u32, 64, 128, 256] {
                let p = build_program(id, n);
                assert!(!p.ops.is_empty(), "{} n={n}", id.name());
                assert!(
                    matches!(p.ops.last(), Some(MbOp::Halt)),
                    "{} must end in Halt",
                    id.name()
                );
            }
        }
    }
}
