//! The MicroBlaze-class scalar soft-core baseline (paper §5.1: "a Xilinx
//! MicroBlaze soft-core processor with 3,252 LUTs running at 100 MHz using
//! C versions of the same benchmarks").
//!
//! We model an in-order single-issue core executing from board DDR with
//! no caches — the configuration the paper's absolute numbers imply (its
//! matmul-256 takes 186 s at 100 MHz, i.e. ~1.1 kcycles per inner-loop
//! iteration, which only an uncached-instruction-fetch MicroBlaze
//! exhibits; see DESIGN.md). Every instruction pays an instruction-fetch
//! latency from DDR; loads/stores pay a data latency on top.

pub mod programs;
pub mod vm;

pub use programs::build_program;
pub use vm::{MbBuilder, MbError, MbOp, MbProgram, MbStats, MbTiming, MicroBlaze, Reg};

use crate::kernels::{golden, BenchId, IN_BASE};
use crate::rng::XorShift64;

/// Run benchmark `id` at problem size `n` on the scalar baseline and
/// verify its output against the golden reference. Returns cycle stats.
pub fn run_verified(id: BenchId, n: u32, seed: u64, timing: MbTiming) -> Result<MbStats, MbError> {
    assert!(
        n.is_power_of_two() && (32..=256).contains(&n),
        "problem size must be a power of two in 32..=256 (got {n})"
    );
    let mut rng = XorShift64::new(seed ^ (id as u64) << 32);
    let input: Vec<i32> = (0..id.input_elems(n)).map(|_| rng.small_i32()).collect();

    let prog = build_program(id, n);
    let mem_bytes = (IN_BASE + 4 * (id.input_elems(n) as u32 + (n * n).max(n) + 64))
        .next_power_of_two();
    let mut mb = MicroBlaze::new(mem_bytes, timing);
    mb.write_words(IN_BASE, &input);
    let stats = mb.run(&prog)?;

    // Verify against the same golden references the GPGPU uses.
    let nn = n as usize;
    let b = |v: u32| IN_BASE + 4 * v;
    let ok = match id {
        BenchId::Autocorr => mb.read_words(b(n), nn) == golden::autocorr(&input),
        BenchId::Bitonic => {
            let seg = n.min(64) as usize;
            mb.read_words(IN_BASE, nn) == golden::bitonic_segments(&input, seg)
        }
        BenchId::MatMul => {
            mb.read_words(b(2 * n * n), nn * nn)
                == golden::matmul(&input[..nn * nn], &input[nn * nn..], nn)
        }
        BenchId::Reduction => mb.read_words(b(n), 1) == vec![golden::reduction(&input)],
        BenchId::Transpose => {
            mb.read_words(b(n * n), nn * nn) == golden::transpose(&input, nn)
        }
        BenchId::VecAdd => {
            mb.read_words(b(2 * n), nn) == golden::vecadd(&input[..nn], &input[nn..])
        }
        BenchId::MemStress => mb.read_words(b(n), nn) == golden::memstress(&input, 1),
    };
    if !ok {
        return Err(MbError::WrongResult(id.name()));
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_benchmarks_verify_on_baseline() {
        for id in BenchId::ALL {
            for n in [32u32, 64] {
                let s = run_verified(id, n, 0xF00D, MbTiming::default())
                    .unwrap_or_else(|e| panic!("{} n={n}: {e}", id.name()));
                assert!(s.cycles > 0, "{} n={n}", id.name());
            }
        }
    }

    #[test]
    fn cycles_grow_with_problem_size() {
        for id in BenchId::PAPER {
            let a = run_verified(id, 32, 1, MbTiming::default()).unwrap();
            let b = run_verified(id, 64, 1, MbTiming::default()).unwrap();
            assert!(b.cycles > a.cycles, "{}", id.name());
        }
    }

    #[test]
    fn matmul_scales_cubically() {
        let a = run_verified(BenchId::MatMul, 32, 1, MbTiming::default()).unwrap();
        let b = run_verified(BenchId::MatMul, 64, 1, MbTiming::default()).unwrap();
        let ratio = b.cycles as f64 / a.cycles as f64;
        assert!((6.0..10.0).contains(&ratio), "expected ~8x, got {ratio}");
    }

    #[test]
    fn faster_timing_fewer_cycles() {
        let slow = run_verified(BenchId::VecAdd, 64, 1, MbTiming::default()).unwrap();
        let fast = run_verified(
            BenchId::VecAdd,
            64,
            1,
            MbTiming { ifetch: 1, ..MbTiming::default() },
        )
        .unwrap();
        // vecadd is memory-heavy, so cutting ifetch 35 -> 1 gives ~3.3x.
        assert!(fast.cycles < slow.cycles / 3);
    }
}
