//! Register files: the partitioned vector register file ("each thread
//! assigned a set of general-purpose registers", paper §3.2), the address
//! register file, and the predicate register file (4 × 4-bit per thread,
//! paper Fig. 2).
//!
//! # Structure-of-arrays layout
//!
//! The general-purpose file is laid out **register-major, warp-major,
//! lane-minor**: word `(r * n_warps + warp) * 32 + lane` holds register
//! `r` of lane `lane` in warp `warp`. That puts the 32 lanes of one
//! warp's register `r` in one contiguous `[i32; 32]` slice — exactly the
//! shape the execute stage consumes — so the Read stage is a `memcpy`
//! ([`RegFile::read_vec`]) and the unguarded/uniform Write stage is a
//! `memcpy` too ([`RegFile::write_warp`]), both trivially
//! autovectorizable on stable Rust. The masked per-lane scatter
//! ([`RegFile::write_vec`]) remains for divergent/guarded issues and is
//! the scalar engine's differential oracle. Blocks whose size is not a
//! warp multiple pad the last warp's missing lanes (never read: the
//! enabled mask excludes them).
//!
//! Storage is flat `Vec`s indexed arithmetically — this is the hottest
//! data structure in the simulator, so no hashing, no bounds
//! recomputation beyond the construction-time invariants.

use super::alu::WARP_SIZE;
use crate::isa::{Flags, NUM_AREGS, NUM_PREGS, RZ};

/// Vector register file for one resident block: `threads × regs_per_thread`
/// general registers (SoA per-warp lane slices), plus address and
/// predicate files.
#[derive(Debug, Clone)]
pub struct RegFile {
    regs_per_thread: u32,
    /// Warps covered by the gp file (threads padded up to a warp multiple).
    n_warps: u32,
    /// SoA: `gp[(r * n_warps + warp) * 32 + lane]`.
    gp: Vec<i32>,
    addr: Vec<i32>,
    /// Packed 4-bit flags: pred[thread * NUM_PREGS + n].
    pred: Vec<u8>,
}

impl RegFile {
    pub fn new(threads: u32, regs_per_thread: u32) -> RegFile {
        let n_warps = threads.div_ceil(WARP_SIZE as u32);
        RegFile {
            regs_per_thread,
            n_warps,
            gp: vec![0; (n_warps * WARP_SIZE as u32 * regs_per_thread) as usize],
            addr: vec![0; (threads * NUM_AREGS as u32) as usize],
            pred: vec![0; (threads * NUM_PREGS as u32) as usize],
        }
    }

    pub fn regs_per_thread(&self) -> u32 {
        self.regs_per_thread
    }

    /// Word index of register `r` for `thread` in the SoA layout.
    #[inline]
    fn gp_idx(&self, thread: u32, r: u8) -> usize {
        let warp = thread / WARP_SIZE as u32;
        let lane = thread % WARP_SIZE as u32;
        ((r as u32 * self.n_warps + warp) * WARP_SIZE as u32 + lane) as usize
    }

    /// Start of the contiguous 32-lane slice of register `r` for the warp
    /// beginning at `base_thread` (must be warp-aligned).
    #[inline]
    fn warp_base(&self, base_thread: u32, r: u8) -> usize {
        debug_assert_eq!(base_thread % WARP_SIZE as u32, 0, "warp-aligned base");
        ((r as u32 * self.n_warps + base_thread / WARP_SIZE as u32) * WARP_SIZE as u32) as usize
    }

    /// Read general register `r` of `thread`. RZ reads zero; registers
    /// above the kernel's declared count read zero (hardware would simply
    /// not allocate them; reading is a benign codegen bug).
    #[inline]
    pub fn read(&self, thread: u32, r: u8) -> i32 {
        if r == RZ || r as u32 >= self.regs_per_thread {
            return 0;
        }
        self.gp[self.gp_idx(thread, r)]
    }

    /// Write general register `r` of `thread`. Writes to RZ or beyond the
    /// declared allocation are discarded.
    #[inline]
    pub fn write(&mut self, thread: u32, r: u8, v: i32) {
        if r == RZ || r as u32 >= self.regs_per_thread {
            return;
        }
        let idx = self.gp_idx(thread, r);
        self.gp[idx] = v;
    }

    /// Gather register `r` for `count` consecutive threads starting at
    /// the warp-aligned `base_thread` into `out[..count]` — the Read
    /// stage's vector fetch. One contiguous `memcpy` under the SoA layout
    /// (the seed layout strided this per lane; §Perf).
    #[inline]
    pub fn read_vec(&self, base_thread: u32, count: usize, r: u8, out: &mut [i32; 32]) {
        if r == RZ || r as u32 >= self.regs_per_thread {
            out[..count].fill(0);
            return;
        }
        let base = self.warp_base(base_thread, r);
        out[..count].copy_from_slice(&self.gp[base..base + count]);
    }

    /// Scatter `vals` into register `r` for the threads selected by
    /// `mask` (bit i -> thread `base_thread + i`) — the Write stage for
    /// divergent/guarded issues, and the scalar engine's oracle path.
    #[inline]
    pub fn write_vec(
        &mut self,
        base_thread: u32,
        count: usize,
        r: u8,
        mask: u32,
        vals: &[i32; 32],
    ) {
        if r == RZ || r as u32 >= self.regs_per_thread {
            return;
        }
        let base = self.warp_base(base_thread, r);
        let dst = &mut self.gp[base..base + count];
        for (lane, slot) in dst.iter_mut().enumerate() {
            if mask & (1 << lane) != 0 {
                *slot = vals[lane];
            }
        }
    }

    /// Full-warp writeback: store `vals[..count]` into register `r` of
    /// `count` consecutive threads with no mask — the vector engine's
    /// Write stage for batch-issued (all-lanes-active) micro-ops. One
    /// contiguous `memcpy`.
    #[inline]
    pub fn write_warp(&mut self, base_thread: u32, count: usize, r: u8, vals: &[i32; 32]) {
        if r == RZ || r as u32 >= self.regs_per_thread {
            return;
        }
        let base = self.warp_base(base_thread, r);
        self.gp[base..base + count].copy_from_slice(&vals[..count]);
    }

    /// SEU injection (`sim::fault`): flip `bit` of the general-register
    /// word selected by `sel` (reduced modulo the file size). Returns the
    /// flipped word index, or `None` for a zero-register allocation.
    /// Silent by design — no parity models the GP register BRAMs.
    pub(crate) fn seu_flip(&mut self, sel: u64, bit: u32) -> Option<u32> {
        if self.gp.is_empty() {
            return None;
        }
        let word = (sel % self.gp.len() as u64) as usize;
        self.gp[word] ^= 1i32 << (bit % 32);
        Some(word as u32)
    }

    /// Number of SEU-addressable general-register words (the modulus the
    /// injector reduces site selectors by).
    pub(crate) fn seu_words(&self) -> usize {
        self.gp.len()
    }

    /// Stuck-at re-corruption (`sim::fault` aging): force `bit` of `word`
    /// set, as a defective BRAM cell would on every access. Returns true
    /// when the word actually changed (the bit was previously clear).
    pub(crate) fn seu_set(&mut self, word: u32, bit: u32) -> bool {
        let Some(w) = self.gp.get_mut(word as usize) else {
            return false;
        };
        let mask = 1i32 << (bit % 32);
        let changed = *w & mask == 0;
        *w |= mask;
        changed
    }

    #[inline]
    pub fn read_areg(&self, thread: u32, a: u8) -> i32 {
        debug_assert!(a < NUM_AREGS);
        self.addr[(thread * NUM_AREGS as u32 + a as u32) as usize]
    }

    #[inline]
    pub fn write_areg(&mut self, thread: u32, a: u8, v: i32) {
        debug_assert!(a < NUM_AREGS);
        self.addr[(thread * NUM_AREGS as u32 + a as u32) as usize] = v;
    }

    #[inline]
    pub fn read_pred(&self, thread: u32, p: u8) -> Flags {
        debug_assert!(p < NUM_PREGS);
        Flags::unpack(self.pred[(thread * NUM_PREGS as u32 + p as u32) as usize])
    }

    #[inline]
    pub fn write_pred(&mut self, thread: u32, p: u8, f: Flags) {
        debug_assert!(p < NUM_PREGS);
        self.pred[(thread * NUM_PREGS as u32 + p as u32) as usize] = f.pack();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Cond;

    #[test]
    fn rz_reads_zero_discards_writes() {
        let mut rf = RegFile::new(4, 8);
        rf.write(1, RZ, 42);
        assert_eq!(rf.read(1, RZ), 0);
    }

    #[test]
    fn per_thread_isolation() {
        let mut rf = RegFile::new(4, 8);
        rf.write(0, 3, 10);
        rf.write(1, 3, 20);
        assert_eq!(rf.read(0, 3), 10);
        assert_eq!(rf.read(1, 3), 20);
        assert_eq!(rf.read(2, 3), 0);
    }

    #[test]
    fn over_allocation_reads_zero() {
        let mut rf = RegFile::new(2, 4);
        rf.write(0, 5, 99); // beyond .regs 4 -> discarded
        assert_eq!(rf.read(0, 5), 0);
    }

    #[test]
    fn predicate_flags_roundtrip() {
        let mut rf = RegFile::new(2, 4);
        let f = Flags::of_sub(3, 7); // 3 - 7 < 0
        rf.write_pred(1, 2, f);
        assert!(rf.read_pred(1, 2).eval(Cond::Lt));
        assert!(!rf.read_pred(0, 2).eval(Cond::Lt));
    }

    #[test]
    fn aregs_isolated_per_thread() {
        let mut rf = RegFile::new(2, 4);
        rf.write_areg(0, 1, 100);
        assert_eq!(rf.read_areg(0, 1), 100);
        assert_eq!(rf.read_areg(1, 1), 0);
    }

    #[test]
    fn soa_vector_fetch_matches_scalar_reads_across_warps() {
        // 2.5 warps, every (thread, reg) distinct: read_vec must agree
        // with per-lane read() on both warp-aligned bases.
        let mut rf = RegFile::new(80, 6);
        for t in 0..80u32 {
            for r in 0..6u8 {
                rf.write(t, r, (t as i32) * 100 + r as i32);
            }
        }
        for base in [0u32, 32, 64] {
            let count = (80 - base).min(32) as usize;
            for r in 0..6u8 {
                let mut out = [0i32; 32];
                rf.read_vec(base, count, r, &mut out);
                for lane in 0..count {
                    assert_eq!(out[lane], rf.read(base + lane as u32, r), "base {base} r {r}");
                }
            }
        }
    }

    #[test]
    fn write_warp_equals_full_mask_write_vec() {
        let vals = std::array::from_fn(|i| i as i32 * 7 - 3);
        let mut a = RegFile::new(48, 5);
        let mut b = RegFile::new(48, 5);
        // Partial last warp: count 16, full mask over existing lanes.
        a.write_warp(32, 16, 2, &vals);
        b.write_vec(32, 16, 2, 0xFFFF, &vals);
        for t in 0..48u32 {
            assert_eq!(a.read(t, 2), b.read(t, 2), "thread {t}");
        }
        // RZ / over-allocation writes are discarded on both paths.
        a.write_warp(0, 32, RZ, &vals);
        a.write_warp(0, 32, 5, &vals);
        assert_eq!(a.read(0, RZ), 0);
        assert_eq!(a.read(0, 5), 0);
    }

    #[test]
    fn masked_write_vec_leaves_unselected_lanes() {
        let mut rf = RegFile::new(32, 4);
        let ones = [1i32; 32];
        rf.write_warp(0, 32, 1, &ones);
        let twos = [2i32; 32];
        rf.write_vec(0, 32, 1, 0x0000_00F0, &twos);
        for t in 0..32u32 {
            let want = if (4..8).contains(&t) { 2 } else { 1 };
            assert_eq!(rf.read(t, 1), want, "thread {t}");
        }
    }
}
