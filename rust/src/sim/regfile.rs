//! Register files: the partitioned vector register file ("each thread
//! assigned a set of general-purpose registers", paper §3.2), the address
//! register file, and the predicate register file (4 × 4-bit per thread,
//! paper Fig. 2).
//!
//! Storage is flat `Vec`s indexed arithmetically — this is the hottest
//! data structure in the simulator, so no hashing, no bounds recomputation
//! beyond the construction-time invariants.

use crate::isa::{Flags, NUM_AREGS, NUM_PREGS, RZ};

/// Vector register file for one resident block: `threads × regs_per_thread`
/// general registers, plus address and predicate files.
#[derive(Debug, Clone)]
pub struct RegFile {
    regs_per_thread: u32,
    gp: Vec<i32>,
    addr: Vec<i32>,
    /// Packed 4-bit flags: pred[thread * NUM_PREGS + n].
    pred: Vec<u8>,
}

impl RegFile {
    pub fn new(threads: u32, regs_per_thread: u32) -> RegFile {
        RegFile {
            regs_per_thread,
            gp: vec![0; (threads * regs_per_thread) as usize],
            addr: vec![0; (threads * NUM_AREGS as u32) as usize],
            pred: vec![0; (threads * NUM_PREGS as u32) as usize],
        }
    }

    pub fn regs_per_thread(&self) -> u32 {
        self.regs_per_thread
    }

    /// Read general register `r` of `thread`. RZ reads zero; registers
    /// above the kernel's declared count read zero (hardware would simply
    /// not allocate them; reading is a benign codegen bug).
    #[inline]
    pub fn read(&self, thread: u32, r: u8) -> i32 {
        if r == RZ || r as u32 >= self.regs_per_thread {
            return 0;
        }
        self.gp[(thread * self.regs_per_thread + r as u32) as usize]
    }

    /// Write general register `r` of `thread`. Writes to RZ or beyond the
    /// declared allocation are discarded.
    #[inline]
    pub fn write(&mut self, thread: u32, r: u8, v: i32) {
        if r == RZ || r as u32 >= self.regs_per_thread {
            return;
        }
        self.gp[(thread * self.regs_per_thread + r as u32) as usize] = v;
    }

    /// Gather register `r` for `count` consecutive threads starting at
    /// `base_thread` into `out[..count]` — the Read stage's vector fetch
    /// (one stride computation per warp instead of per lane; §Perf).
    #[inline]
    pub fn read_vec(&self, base_thread: u32, count: usize, r: u8, out: &mut [i32; 32]) {
        if r == RZ || r as u32 >= self.regs_per_thread {
            out[..count].fill(0);
            return;
        }
        let stride = self.regs_per_thread as usize;
        let mut idx = base_thread as usize * stride + r as usize;
        for slot in out.iter_mut().take(count) {
            *slot = self.gp[idx];
            idx += stride;
        }
    }

    /// Scatter `vals` into register `r` for the threads selected by
    /// `mask` (bit i -> thread `base_thread + i`) — the Write stage.
    #[inline]
    pub fn write_vec(
        &mut self,
        base_thread: u32,
        count: usize,
        r: u8,
        mask: u32,
        vals: &[i32; 32],
    ) {
        if r == RZ || r as u32 >= self.regs_per_thread {
            return;
        }
        let stride = self.regs_per_thread as usize;
        let mut idx = base_thread as usize * stride + r as usize;
        for lane in 0..count {
            if mask & (1 << lane) != 0 {
                self.gp[idx] = vals[lane];
            }
            idx += stride;
        }
    }

    /// SEU injection (`sim::fault`): flip `bit` of the general-register
    /// word selected by `sel` (reduced modulo the file size). Returns the
    /// flipped word index, or `None` for a zero-register allocation.
    /// Silent by design — no parity models the GP register BRAMs.
    pub(crate) fn seu_flip(&mut self, sel: u64, bit: u32) -> Option<u32> {
        if self.gp.is_empty() {
            return None;
        }
        let word = (sel % self.gp.len() as u64) as usize;
        self.gp[word] ^= 1i32 << (bit % 32);
        Some(word as u32)
    }

    #[inline]
    pub fn read_areg(&self, thread: u32, a: u8) -> i32 {
        debug_assert!(a < NUM_AREGS);
        self.addr[(thread * NUM_AREGS as u32 + a as u32) as usize]
    }

    #[inline]
    pub fn write_areg(&mut self, thread: u32, a: u8, v: i32) {
        debug_assert!(a < NUM_AREGS);
        self.addr[(thread * NUM_AREGS as u32 + a as u32) as usize] = v;
    }

    #[inline]
    pub fn read_pred(&self, thread: u32, p: u8) -> Flags {
        debug_assert!(p < NUM_PREGS);
        Flags::unpack(self.pred[(thread * NUM_PREGS as u32 + p as u32) as usize])
    }

    #[inline]
    pub fn write_pred(&mut self, thread: u32, p: u8, f: Flags) {
        debug_assert!(p < NUM_PREGS);
        self.pred[(thread * NUM_PREGS as u32 + p as u32) as usize] = f.pack();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Cond;

    #[test]
    fn rz_reads_zero_discards_writes() {
        let mut rf = RegFile::new(4, 8);
        rf.write(1, RZ, 42);
        assert_eq!(rf.read(1, RZ), 0);
    }

    #[test]
    fn per_thread_isolation() {
        let mut rf = RegFile::new(4, 8);
        rf.write(0, 3, 10);
        rf.write(1, 3, 20);
        assert_eq!(rf.read(0, 3), 10);
        assert_eq!(rf.read(1, 3), 20);
        assert_eq!(rf.read(2, 3), 0);
    }

    #[test]
    fn over_allocation_reads_zero() {
        let mut rf = RegFile::new(2, 4);
        rf.write(0, 5, 99); // beyond .regs 4 -> discarded
        assert_eq!(rf.read(0, 5), 0);
    }

    #[test]
    fn predicate_flags_roundtrip() {
        let mut rf = RegFile::new(2, 4);
        let f = Flags::of_sub(3, 7); // 3 - 7 < 0
        rf.write_pred(1, 2, f);
        assert!(rf.read_pred(1, 2).eval(Cond::Lt));
        assert!(!rf.read_pred(0, 2).eval(Cond::Lt));
    }

    #[test]
    fn aregs_isolated_per_thread() {
        let mut rf = RegFile::new(2, 4);
        rf.write_areg(0, 1, 100);
        assert_eq!(rf.read_areg(0, 1), 100);
        assert_eq!(rf.read_areg(1, 1), 0);
    }
}
