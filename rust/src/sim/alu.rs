//! The scalar-processor ALU datapath — the Execute stage's arithmetic
//! portion (paper Fig. 3, right).
//!
//! A warp row of lanes executes one decoded operation in lock-step. The
//! datapath contract is defined once here (`AluFunc`, `WarpAluIn/Out`) and
//! implemented twice:
//!
//! * [`NativeAlu`] — plain Rust, the default high-speed path;
//! * `runtime::XlaAlu` — the AOT-compiled JAX/Pallas warp-ALU kernel
//!   executed through PJRT, proving the three-layer stack composes.
//!
//! The two are differentially tested against each other. **The `AluFunc`
//! discriminants are ABI**: they must match `OPC_*` in
//! `python/compile/kernels/warp_alu.py`.

use crate::isa::{Cond, Flags, Op};

/// Warp width — fixed at 32 by the architecture (paper Table 1).
pub const WARP_SIZE: usize = 32;

/// ALU function selector (ABI shared with the Pallas kernel).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(i32)]
pub enum AluFunc {
    Add = 0,
    Sub = 1,
    Mul = 2,
    /// a*b + c.
    Mad = 3,
    Min = 4,
    Max = 5,
    And = 6,
    Or = 7,
    Xor = 8,
    Not = 9,
    Shl = 10,
    /// Logical right shift.
    Shr = 11,
    /// Arithmetic right shift.
    Sar = 12,
    Abs = 13,
    Neg = 14,
    /// Pass-through of `a` (register/immediate moves).
    Mov = 15,
    /// Flags of `a - b`, packed S|Z<<1|C<<2|O<<3 in the output lane.
    Setp = 16,
    /// `cond(a - b) ? -1 : 0`.
    Set = 17,
    /// `c != 0 ? a : b`.
    Sel = 18,
}

impl AluFunc {
    pub const COUNT: usize = 19;

    /// Map an ISA opcode to its ALU function (None for non-ALU ops).
    pub fn from_op(op: Op) -> Option<AluFunc> {
        Some(match op {
            Op::Iadd => AluFunc::Add,
            Op::Isub => AluFunc::Sub,
            Op::Imul => AluFunc::Mul,
            Op::Imad => AluFunc::Mad,
            Op::Imin => AluFunc::Min,
            Op::Imax => AluFunc::Max,
            Op::And => AluFunc::And,
            Op::Or => AluFunc::Or,
            Op::Xor => AluFunc::Xor,
            Op::Not => AluFunc::Not,
            Op::Shl => AluFunc::Shl,
            Op::Shr => AluFunc::Shr,
            Op::Sar => AluFunc::Sar,
            Op::Iabs => AluFunc::Abs,
            Op::Ineg => AluFunc::Neg,
            Op::Mov => AluFunc::Mov,
            Op::Isetp => AluFunc::Setp,
            Op::Iset => AluFunc::Set,
            Op::Sel => AluFunc::Sel,
            _ => return None,
        })
    }
}

/// One warp's operand bundle for a single instruction.
#[derive(Debug, Clone)]
pub struct WarpAluIn {
    pub func: AluFunc,
    /// Comparison condition (SET only; encoded as `Cond as i32`).
    pub cond: Cond,
    pub a: [i32; WARP_SIZE],
    pub b: [i32; WARP_SIZE],
    /// Third source: MAD addend / SEL selector.
    pub c: [i32; WARP_SIZE],
}

/// Lane results. For `Setp` each lane holds the packed 4-bit flags.
pub type WarpAluOut = [i32; WARP_SIZE];

/// The pluggable SP-array datapath.
pub trait AluBackend {
    /// Execute one warp instruction across all 32 lanes. Lanes outside the
    /// active mask are computed anyway (lock-step hardware does the same;
    /// the writeback stage discards them).
    fn execute(&mut self, input: &WarpAluIn) -> WarpAluOut;

    /// Backend name for metrics / CLI display.
    fn name(&self) -> &'static str;

    /// True iff this backend is semantically [`NativeAlu`] — stateless,
    /// with `execute` a pure function of its input. The `gpgpu` launch
    /// boundary uses this to swap a `&mut dyn AluBackend` for a concrete
    /// `NativeAlu` before entering `Sm::run`, so the simulator hot path
    /// monomorphizes (and inlines the lane loop) instead of
    /// virtual-dispatching per warp instruction. Stateful or
    /// differentially-tested backends must keep the default `false`.
    fn is_native(&self) -> bool {
        false
    }
}

/// Per-SM-thread ALU factory for the parallel launch path. The sequential
/// path threads one `&mut dyn AluBackend` through every SM; the parallel
/// path instead hands each SM thread its own backend instance built from a
/// `Sync` factory, so backends never need interior synchronization.
///
/// [`NativeAlu`] is its own factory (it is a stateless unit struct).
/// Backends with heavyweight shared state (e.g. a PJRT client) implement
/// this by cloning an `Arc` of that state into each instance.
pub trait AluFactory: Sync {
    /// Build a fresh backend owned by one SM thread.
    fn make_alu(&self) -> Box<dyn AluBackend + Send>;

    /// Backend name for metrics / CLI display.
    fn backend_name(&self) -> &'static str;
}

impl AluFactory for NativeAlu {
    fn make_alu(&self) -> Box<dyn AluBackend + Send> {
        Box::new(NativeAlu)
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}

/// Scalar-evaluated reference datapath. Also the semantic ground truth for
/// the Pallas kernel's `ref.py` oracle (the Python side mirrors these
/// exact semantics: wrapping arithmetic, shift counts masked to 5 bits).
#[derive(Debug, Default, Clone)]
pub struct NativeAlu;

/// Scalar ALU semantics, shared by the native backend and the baseline VM.
#[inline]
pub fn eval_lane(func: AluFunc, cond: Cond, a: i32, b: i32, c: i32) -> i32 {
    match func {
        AluFunc::Add => a.wrapping_add(b),
        AluFunc::Sub => a.wrapping_sub(b),
        AluFunc::Mul => a.wrapping_mul(b),
        AluFunc::Mad => a.wrapping_mul(b).wrapping_add(c),
        AluFunc::Min => a.min(b),
        AluFunc::Max => a.max(b),
        AluFunc::And => a & b,
        AluFunc::Or => a | b,
        AluFunc::Xor => a ^ b,
        AluFunc::Not => !a,
        AluFunc::Shl => ((a as u32) << (b as u32 & 31)) as i32,
        AluFunc::Shr => ((a as u32) >> (b as u32 & 31)) as i32,
        AluFunc::Sar => a >> (b as u32 & 31),
        AluFunc::Abs => a.wrapping_abs(),
        AluFunc::Neg => a.wrapping_neg(),
        AluFunc::Mov => a,
        AluFunc::Setp => Flags::of_sub(a, b).pack() as i32,
        AluFunc::Set => {
            if Flags::of_sub(a, b).eval(cond) {
                -1
            } else {
                0
            }
        }
        AluFunc::Sel => {
            if c != 0 {
                a
            } else {
                b
            }
        }
    }
}

impl AluBackend for NativeAlu {
    fn execute(&mut self, input: &WarpAluIn) -> WarpAluOut {
        // Function dispatch is hoisted out of the lane loop (one `match`
        // per warp instruction, not 32) — the same structure the Pallas
        // kernel's select tree gives the VPU, and worth ~15% end-to-end
        // on the simulator (EXPERIMENTS.md §Perf).
        let mut out = [0i32; WARP_SIZE];
        let (a, b, c) = (&input.a, &input.b, &input.c);
        macro_rules! lanes {
            (|$x:ident, $y:ident, $z:ident| $e:expr) => {
                for i in 0..WARP_SIZE {
                    let ($x, $y, $z) = (a[i], b[i], c[i]);
                    let _ = ($y, $z);
                    out[i] = $e;
                }
            };
        }
        match input.func {
            AluFunc::Add => lanes!(|x, y, z| x.wrapping_add(y)),
            AluFunc::Sub => lanes!(|x, y, z| x.wrapping_sub(y)),
            AluFunc::Mul => lanes!(|x, y, z| x.wrapping_mul(y)),
            AluFunc::Mad => lanes!(|x, y, z| x.wrapping_mul(y).wrapping_add(z)),
            AluFunc::Min => lanes!(|x, y, z| x.min(y)),
            AluFunc::Max => lanes!(|x, y, z| x.max(y)),
            AluFunc::And => lanes!(|x, y, z| x & y),
            AluFunc::Or => lanes!(|x, y, z| x | y),
            AluFunc::Xor => lanes!(|x, y, z| x ^ y),
            AluFunc::Not => lanes!(|x, y, z| !x),
            AluFunc::Shl => lanes!(|x, y, z| ((x as u32) << (y as u32 & 31)) as i32),
            AluFunc::Shr => lanes!(|x, y, z| ((x as u32) >> (y as u32 & 31)) as i32),
            AluFunc::Sar => lanes!(|x, y, z| x >> (y as u32 & 31)),
            AluFunc::Abs => lanes!(|x, y, z| x.wrapping_abs()),
            AluFunc::Neg => lanes!(|x, y, z| x.wrapping_neg()),
            AluFunc::Mov => lanes!(|x, y, z| x),
            AluFunc::Setp => lanes!(|x, y, z| Flags::of_sub(x, y).pack() as i32),
            AluFunc::Set => {
                let cond = input.cond;
                lanes!(|x, y, z| if Flags::of_sub(x, y).eval(cond) { -1 } else { 0 })
            }
            AluFunc::Sel => lanes!(|x, y, z| if z != 0 { x } else { y }),
        }
        out
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn is_native(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bundle(func: AluFunc, a: i32, b: i32, c: i32) -> WarpAluIn {
        WarpAluIn { func, cond: Cond::Lt, a: [a; 32], b: [b; 32], c: [c; 32] }
    }

    #[test]
    fn wrapping_semantics() {
        let mut alu = NativeAlu;
        let out = alu.execute(&bundle(AluFunc::Add, i32::MAX, 1, 0));
        assert_eq!(out[0], i32::MIN);
        let out = alu.execute(&bundle(AluFunc::Mul, i32::MAX, 2, 0));
        assert_eq!(out[17], -2);
        let out = alu.execute(&bundle(AluFunc::Mad, 1 << 20, 1 << 20, 5));
        assert_eq!(out[0], 5); // 2^40 wraps to 0
    }

    #[test]
    fn shift_count_masking() {
        let mut alu = NativeAlu;
        assert_eq!(alu.execute(&bundle(AluFunc::Shl, 1, 33, 0))[0], 2);
        assert_eq!(alu.execute(&bundle(AluFunc::Shr, -1, 1, 0))[0], i32::MAX);
        assert_eq!(alu.execute(&bundle(AluFunc::Sar, -8, 2, 0))[0], -2);
    }

    #[test]
    fn setp_packs_flags() {
        let mut alu = NativeAlu;
        let out = alu.execute(&bundle(AluFunc::Setp, 3, 7, 0));
        let f = Flags::unpack(out[0] as u8);
        assert!(f.eval(Cond::Lt));
        assert!(!f.eval(Cond::Eq));
    }

    #[test]
    fn set_honours_condition() {
        let mut alu = NativeAlu;
        let lt = WarpAluIn { cond: Cond::Lt, ..bundle(AluFunc::Set, 3, 7, 0) };
        assert_eq!(alu.execute(&lt)[0], -1);
        let gt = WarpAluIn { cond: Cond::Gt, ..bundle(AluFunc::Set, 3, 7, 0) };
        assert_eq!(alu.execute(&gt)[0], 0);
    }

    #[test]
    fn sel_selects_by_c() {
        let mut alu = NativeAlu;
        assert_eq!(alu.execute(&bundle(AluFunc::Sel, 10, 20, 1))[0], 10);
        assert_eq!(alu.execute(&bundle(AluFunc::Sel, 10, 20, 0))[0], 20);
    }

    #[test]
    fn every_alu_op_maps_and_back() {
        use crate::isa::Op;
        let alu_ops: Vec<Op> = Op::ALL
            .iter()
            .copied()
            .filter(|o| AluFunc::from_op(*o).is_some())
            .collect();
        assert_eq!(alu_ops.len(), 19);
        assert_eq!(AluFunc::from_op(Op::Bra), None);
        assert_eq!(AluFunc::from_op(Op::Gld), None);
    }
}
