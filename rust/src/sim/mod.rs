//! The FlexGrip streaming-multiprocessor simulator.
//!
//! Cycle-driven, functionally atomic: each issued warp-instruction
//! executes architecturally in one step, while the cycle accounting models
//! the paper's microarchitecture — a 5-stage pipeline issuing one warp
//! *row* (`32 / num_sp` threads) per cycle, round-robin across ready
//! warps, with memory latencies overlapped across warps (paper §3.2).
//!
//! The engine is warp-wide and allocation-free on the hot path: kernels
//! are lowered once per launch to pre-resolved micro-ops ([`PreDecoded`]),
//! issue selection is event-driven ([`WarpScheduler`]: ready bitmask +
//! wake min-heap), per-SM parallel launches read through page-granular
//! copy-on-write snapshots ([`GmemSnapshot`]), and `Sm::run` is generic
//! over its memory port and ALU backend so concrete callers inline the
//! lane loops (trait objects survive only at the `gpgpu::launch`
//! boundary).

pub mod alu;
pub mod cache;
pub mod fault;
pub mod mem;
pub mod metrics;
pub mod regfile;
pub mod sched;
pub mod sm;
pub mod stack;
pub mod warp;

pub use alu::{
    eval_lane, AluBackend, AluFactory, AluFunc, NativeAlu, WarpAluIn, WarpAluOut, WARP_SIZE,
};
pub use cache::{CacheGeometry, CachedGmem, L1Cache, L1Config, MemoryConfig};
pub use fault::{
    upset_outcome, FaultEvent, FaultPlan, FaultSite, FaultState, FaultStats, FaultTarget,
    FaultTargets, Protection, ProtectionConfig, Scrubber, UpsetKind, UpsetOutcome,
    ECC_CORRECT_CYCLES,
};
pub use mem::{
    GlobalMem, GmemPort, GmemSnapshot, MemCost, MemTiming, SharedMem, WriteRecord,
    GMEM_PAGE_WORDS, PARAM_SEG_BYTES,
};
pub use metrics::{MemStats, SmStats};
pub use regfile::RegFile;
pub use sched::{WarpScheduler, MAX_RESIDENT_WARPS};
pub use sm::{BlockDesc, CheckpointPolicy, PreDecoded, Sm, SmLaunch};
pub use stack::{EntryType, StackEntry, WarpStack};
pub use warp::{Warp, WarpStatus};

use crate::isa::{Capability, CapabilitySignature, DecodeError, StackBound};

/// Architectural faults. In hardware these would be raised to the
/// MicroBlaze driver over AXI; the simulator propagates them to the
/// coordinator, which fails the launch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    Decode(DecodeError),
    MemFault { space: &'static str, addr: u32, reason: &'static str },
    /// Warp-stack push beyond the configured depth — the failure mode of
    /// running a control-heavy kernel on an over-customized FlexGrip
    /// (paper §5.2).
    StackOverflow { warp: u32, pc: u32, depth: u32 },
    /// `JOIN` on an empty warp stack (codegen bug).
    StackUnderflow { warp: u32, pc: u32 },
    /// PC left the code image without reaching `EXIT`.
    RanOffCode { warp: u32, pc: u32 },
    /// All live warps parked at a barrier that can never release
    /// (e.g. a barrier inside a divergent region).
    BarrierDeadlock { block: u32 },
    /// §4.2 capability mismatch between a kernel and a customized
    /// configuration. Raised with `pc: None` by pre-flight admission
    /// ([`SmConfig::admit`], before any simulation) and with `pc: Some`
    /// by the mid-run trap when an instruction reaches a removed unit.
    Unsupported { op: &'static str, capability: Capability, pc: Option<u32> },
    /// Kernel exceeds a physical limit (Table 1) — raised by the block
    /// scheduler before execution starts.
    LimitExceeded(String),
    /// Two SMs wrote the same global address within one parallel launch —
    /// the kernel violates the disjoint-write contract the parallel
    /// simulate phase requires (detected during the merge phase).
    WriteConflict { addr: u32, first_sm: u32, second_sm: u32 },
    /// Watchdog: simulation exceeded the configured cycle budget.
    Watchdog { cycles: u64 },
    /// A parity-detected single-event upset (SEU) in a modeled BRAM
    /// structure ([`fault::FaultPlan`] injection). Only tag-array and
    /// instruction-image upsets surface here — register-file and
    /// shared-memory upsets corrupt silently by design.
    SoftError { site: fault::FaultSite, cycle: u64, bit: u32 },
}

impl From<DecodeError> for SimError {
    fn from(e: DecodeError) -> SimError {
        SimError::Decode(e)
    }
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Decode(e) => write!(f, "decode fault: {e}"),
            SimError::MemFault { space, addr, reason } => {
                write!(f, "{space} memory fault at {addr:#x}: {reason}")
            }
            SimError::StackOverflow { warp, pc, depth } => write!(
                f,
                "warp {warp} stack overflow at pc={pc:#x} (configured depth {depth})"
            ),
            SimError::StackUnderflow { warp, pc } => {
                write!(f, "warp {warp} popped empty warp stack at pc={pc:#x}")
            }
            SimError::RanOffCode { warp, pc } => {
                write!(f, "warp {warp} ran off code image at pc={pc:#x}")
            }
            SimError::BarrierDeadlock { block } => {
                write!(f, "barrier deadlock in block {block}")
            }
            SimError::Unsupported { op, capability, pc: Some(pc) } => write!(
                f,
                "{op} at pc={pc:#x} requires {capability}, absent on this configuration"
            ),
            SimError::Unsupported { op, capability, pc: None } => write!(
                f,
                "kernel rejected at admission: {op} requires {capability}"
            ),
            SimError::LimitExceeded(s) => write!(f, "physical limit exceeded: {s}"),
            SimError::WriteConflict { addr, first_sm, second_sm } => write!(
                f,
                "write conflict at {addr:#x}: SM {first_sm} and SM {second_sm} \
                 both stored there in one parallel launch"
            ),
            SimError::Watchdog { cycles } => {
                write!(f, "watchdog expired after {cycles} cycles")
            }
            SimError::SoftError { site, cycle, bit } => {
                write!(f, "soft error: SEU detected in {site}, bit {bit}, cycle {cycle}")
            }
        }
    }
}

impl std::error::Error for SimError {}

/// Which execute-stage implementation the simulator uses. Both engines
/// are architecturally identical — bit-identical results *and* cycle
/// counts (pinned by `tests/simd_engine.rs`) — because they share the
/// timing model and the warp-ALU backend; they differ only in how the
/// data-movement loops are shaped.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineMode {
    /// Lane-vectorized batch execution (the default): guard-free,
    /// non-divergent micro-ops issue as whole-warp `[i32; 32]` batches
    /// over the structure-of-arrays register file — straight-line
    /// autovectorizable loops and `memcpy` writebacks on stable Rust.
    /// Divergent/guarded issues fall back to the masked scalar loop.
    #[default]
    Vector,
    /// Per-lane masked loops on every issue — the pre-SIMD engine, kept
    /// as the differential oracle for the vector fast path.
    Scalar,
}

/// Streaming-multiprocessor configuration — the architectural parameters
/// the paper varies (§5: SP count; §4/Table 6: warp-stack depth,
/// multiplier & third read-operand removal).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SmConfig {
    /// Scalar processors per SM: 8, 16 or 32 (warp rows = 32 / num_sp).
    pub num_sp: u32,
    /// Warp-stack depth, 0..=32 (Table 6 customization).
    pub warp_stack_depth: u32,
    /// §4.2: multiplier present? (false also removes MAD support).
    pub has_multiplier: bool,
    /// §4.2: parallel read-operand units (3 baseline, 2 without MAD).
    pub read_operands: u8,
    /// Execution pipeline depth (Fetch/Decode/Read/Execute/Write).
    pub pipeline_depth: u32,
    /// Memory timing parameters.
    pub mem: MemTiming,
    /// Simulation watchdog (cycles); guards against runaway kernels.
    pub watchdog_cycles: u64,
    /// Execute-stage implementation (simulator-side knob, not an
    /// architectural parameter: both modes model the same hardware).
    pub engine: EngineMode,
}

impl SmConfig {
    /// The paper's baseline: 8 SP, full 32-deep stack, MAD-capable.
    pub fn baseline() -> SmConfig {
        SmConfig {
            num_sp: 8,
            warp_stack_depth: 32,
            has_multiplier: true,
            read_operands: 3,
            pipeline_depth: 5,
            mem: MemTiming::default(),
            watchdog_cycles: 50_000_000_000,
            engine: EngineMode::Vector,
        }
    }

    pub fn with_sp(mut self, num_sp: u32) -> SmConfig {
        self.num_sp = num_sp;
        self
    }

    /// Run on the scalar (per-lane masked loop) engine — the differential
    /// oracle for the vectorized default.
    pub fn with_engine(mut self, engine: EngineMode) -> SmConfig {
        self.engine = engine;
        self
    }

    /// Threads per warp row; one row issues per cycle (paper §3.2:
    /// "a warp with 32 threads would be arranged in four rows" at 8 SP).
    pub fn rows_per_warp(&self) -> u32 {
        (WARP_SIZE as u32).div_ceil(self.num_sp)
    }

    pub fn validate(&self) -> Result<(), SimError> {
        if !matches!(self.num_sp, 8 | 16 | 32) {
            return Err(SimError::LimitExceeded(format!(
                "num_sp must be 8, 16 or 32 (got {})",
                self.num_sp
            )));
        }
        if self.warp_stack_depth > 32 {
            return Err(SimError::LimitExceeded(format!(
                "warp stack depth {} > 32",
                self.warp_stack_depth
            )));
        }
        if !matches!(self.read_operands, 2 | 3) {
            return Err(SimError::LimitExceeded(format!(
                "read_operands must be 2 or 3 (got {})",
                self.read_operands
            )));
        }
        if self.has_multiplier && self.read_operands < 3 {
            // Paper §5.2: "only the multiply-add (MAD) instruction requires
            // three operands, therefore by eliminating the multiply unit
            // the need for support of a third operand is removed" — the
            // converse configuration is not manufacturable.
            return Err(SimError::LimitExceeded(
                "a multiplier-equipped SM requires 3 read operands (MAD)".into(),
            ));
        }
        Ok(())
    }

    /// Pre-flight admission (§4.2): reject a kernel whose capability
    /// signature *provably* exceeds this SM, before any simulation. A
    /// statically unbounded stack requirement is let through — the
    /// runtime [`SimError::StackOverflow`] trap remains the backstop —
    /// which is exactly why the fleet router uses the stricter
    /// [`SmConfig::covers`] when it *chooses* hardware.
    pub fn admit(&self, sig: &CapabilitySignature) -> Result<(), SimError> {
        if sig.uses_multiplier && !self.has_multiplier {
            return Err(SimError::Unsupported {
                op: "IMUL/IMAD",
                capability: Capability::Multiplier,
                pc: None,
            });
        }
        if sig.uses_third_operand && self.read_operands < 3 {
            return Err(SimError::Unsupported {
                op: "IMAD",
                capability: Capability::ThirdReadOperand,
                pc: None,
            });
        }
        if let StackBound::AtMost(need) = sig.stack_bound {
            if need > self.warp_stack_depth {
                return Err(SimError::Unsupported {
                    op: "SSY/BRA",
                    capability: Capability::StackDepth {
                        need,
                        have: self.warp_stack_depth,
                    },
                    pc: None,
                });
            }
        }
        Ok(())
    }

    /// Conservative coverage: is this SM *guaranteed* sufficient for the
    /// signature? Same checks as [`SmConfig::admit`], except an unbounded
    /// stack requirement demands the full 32-deep stack. This is the
    /// predicate the coordinator's variant router uses.
    pub fn covers(&self, sig: &CapabilitySignature) -> bool {
        (!sig.uses_multiplier || self.has_multiplier)
            && (!sig.uses_third_operand || self.read_operands >= 3)
            && self.warp_stack_depth >= sig.stack_bound.required_depth()
    }
}

/// Device-level validation: every limit check a launch boundary needs, in
/// one place (`GpgpuConfig::validate` delegates here, so the gpgpu and
/// sim layers cannot drift apart).
pub fn validate_device(sm: &SmConfig, num_sms: u32) -> Result<(), SimError> {
    if num_sms == 0 {
        return Err(SimError::LimitExceeded("at least one SM required".into()));
    }
    sm.validate()
}

impl Default for SmConfig {
    fn default() -> Self {
        SmConfig::baseline()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_engine_is_the_default() {
        assert_eq!(SmConfig::baseline().engine, EngineMode::Vector);
        assert_eq!(EngineMode::default(), EngineMode::Vector);
        let c = SmConfig::baseline().with_engine(EngineMode::Scalar);
        assert_eq!(c.engine, EngineMode::Scalar);
        // The engine knob must not perturb architectural validation.
        assert!(c.validate().is_ok());
    }

    #[test]
    fn rows_per_warp_matches_paper() {
        assert_eq!(SmConfig::baseline().with_sp(8).rows_per_warp(), 4);
        assert_eq!(SmConfig::baseline().with_sp(16).rows_per_warp(), 2);
        assert_eq!(SmConfig::baseline().with_sp(32).rows_per_warp(), 1);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        assert!(SmConfig::baseline().validate().is_ok());
        assert!(SmConfig::baseline().with_sp(12).validate().is_err());
        let mut c = SmConfig::baseline();
        c.warp_stack_depth = 33;
        assert!(c.validate().is_err());
        let mut c = SmConfig::baseline();
        c.read_operands = 2; // keeps multiplier -> invalid
        assert!(c.validate().is_err());
        c.has_multiplier = false;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validate_device_requires_an_sm() {
        assert!(validate_device(&SmConfig::baseline(), 0).is_err());
        assert!(validate_device(&SmConfig::baseline(), 2).is_ok());
    }

    fn sig(mul: bool, mad: bool, stack: StackBound) -> CapabilitySignature {
        CapabilitySignature {
            uses_multiplier: mul,
            uses_third_operand: mad,
            uses_branches: true,
            stack_bound: stack,
        }
    }

    #[test]
    fn admit_rejects_only_provable_mismatches() {
        let mut c = SmConfig::baseline();
        c.warp_stack_depth = 8;
        assert!(c.admit(&sig(true, true, StackBound::AtMost(8))).is_ok());
        let err = c.admit(&sig(true, false, StackBound::AtMost(9))).unwrap_err();
        assert!(matches!(
            err,
            SimError::Unsupported {
                capability: Capability::StackDepth { need: 9, have: 8 },
                pc: None,
                ..
            }
        ));
        // Unbounded = statically unknown: admitted, runtime trap backstop.
        assert!(c.admit(&sig(true, true, StackBound::Unbounded)).is_ok());

        c.has_multiplier = false;
        c.read_operands = 2;
        let err = c.admit(&sig(true, false, StackBound::AtMost(0))).unwrap_err();
        assert!(matches!(
            err,
            SimError::Unsupported { capability: Capability::Multiplier, pc: None, .. }
        ));
    }

    #[test]
    fn covers_is_conservative_about_unbounded_stacks() {
        let mut c = SmConfig::baseline();
        c.warp_stack_depth = 16;
        assert!(c.covers(&sig(true, true, StackBound::AtMost(16))));
        assert!(!c.covers(&sig(true, true, StackBound::AtMost(17))));
        assert!(!c.covers(&sig(false, false, StackBound::Unbounded)));
        assert!(SmConfig::baseline().covers(&sig(true, true, StackBound::Unbounded)));
    }
}
