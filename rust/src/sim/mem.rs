//! Memory subsystem: global memory (board DDR behind the AXI bus) and
//! per-block shared memory (FPGA block RAM, 16 KB/SM — paper Table 1).
//!
//! All accesses are 32-bit and must be 4-byte aligned, matching the
//! integer-only G80 subset FlexGrip implements. Misaligned or
//! out-of-bounds accesses are architectural faults surfaced to the
//! coordinator (exercised by the failure-injection tests).

use super::metrics::MemStats;
use super::SimError;

/// Byte offset where kernel scratch shared memory begins; the driver
/// copies kernel parameters into `s[0..64)` at block launch (the G80
/// param-segment convention). Kernels address scratch at `PARAM_SEG_BYTES+`.
pub const PARAM_SEG_BYTES: u32 = 64;

fn word_index(addr: u32, len_words: usize, what: &'static str) -> Result<usize, SimError> {
    if addr % 4 != 0 {
        return Err(SimError::MemFault { space: what, addr, reason: "misaligned" });
    }
    let idx = (addr / 4) as usize;
    if idx >= len_words {
        return Err(SimError::MemFault { space: what, addr, reason: "out of bounds" });
    }
    Ok(idx)
}

/// Global (device) memory. One instance per kernel launch, shared by all
/// SMs — the paper's DDR behind the AXI interconnect.
#[derive(Debug, Clone)]
pub struct GlobalMem {
    words: Vec<i32>,
}

impl GlobalMem {
    pub fn new(bytes: u32) -> GlobalMem {
        GlobalMem { words: vec![0; (bytes as usize).div_ceil(4)] }
    }

    pub fn size_bytes(&self) -> u32 {
        (self.words.len() * 4) as u32
    }

    pub fn load(&self, addr: u32) -> Result<i32, SimError> {
        Ok(self.words[word_index(addr, self.words.len(), "global")?])
    }

    pub fn store(&mut self, addr: u32, value: i32) -> Result<(), SimError> {
        let idx = word_index(addr, self.words.len(), "global")?;
        self.words[idx] = value;
        Ok(())
    }

    /// Host-side bulk write (the driver's DMA into device memory).
    pub fn write_words(&mut self, byte_addr: u32, data: &[i32]) -> Result<(), SimError> {
        for (i, &w) in data.iter().enumerate() {
            self.store(byte_addr + (i as u32) * 4, w)?;
        }
        Ok(())
    }

    /// Host-side bulk read (the driver's DMA out of device memory).
    pub fn read_words(&self, byte_addr: u32, count: usize) -> Result<Vec<i32>, SimError> {
        (0..count).map(|i| self.load(byte_addr + (i as u32) * 4)).collect()
    }
}

/// Timing of one global-memory warp access, as computed by the device's
/// memory hierarchy (see [`GmemPort::access_cost`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct MemCost {
    /// Cycles the access occupies the SM pipeline (the issue port blocks).
    pub blocking: u64,
    /// Additional cycles the issuing warp parks waiting for data (line
    /// fills); other ready warps keep issuing meanwhile.
    pub park: u64,
}

/// Global-memory access port: what an SM executes its `GLD`/`GST` stream
/// against. The sequential launch path hands every SM the one true
/// [`GlobalMem`]; the parallel path hands each SM thread a private
/// [`GmemSnapshot`] so SMs can simulate concurrently without sharing
/// mutable state (see `gpgpu`'s partition → simulate → merge pipeline).
/// Either may additionally be wrapped in the L1 timing layer
/// (`sim::CachedGmem`), which overrides the two provided methods below —
/// values still pass through untouched, only cycles change.
pub trait GmemPort {
    fn load(&self, addr: u32) -> Result<i32, SimError>;
    fn store(&mut self, addr: u32, value: i32) -> Result<(), SimError>;

    /// Timing for one global warp access: `addrs[lane]` is active iff bit
    /// `lane` of `exec` is set. The flat default reproduces the seed
    /// simulator exactly — every access blocks the pipeline for
    /// [`MemTiming::blocking_cycles`] and nothing parks.
    fn access_cost(
        &mut self,
        timing: &MemTiming,
        rows: u32,
        exec: u32,
        _addrs: &[u32],
        _load: bool,
        _now: u64,
    ) -> MemCost {
        MemCost { blocking: timing.blocking_cycles(true, rows, exec.count_ones()), park: 0 }
    }

    /// Memory-hierarchy counters accumulated by [`GmemPort::access_cost`]
    /// calls so far; all-zero for flat ports.
    fn mem_stats(&self) -> MemStats {
        MemStats::default()
    }

    /// Number of L1 tag entries behind this port — the SEU injector's
    /// tag-array target surface. Flat ports have no tag BRAM, so a
    /// tag-targeted upset lands in unused fabric and is a no-op.
    fn l1_tag_count(&self) -> u32 {
        0
    }
}

impl GmemPort for GlobalMem {
    #[inline]
    fn load(&self, addr: u32) -> Result<i32, SimError> {
        GlobalMem::load(self, addr)
    }

    #[inline]
    fn store(&mut self, addr: u32, value: i32) -> Result<(), SimError> {
        GlobalMem::store(self, addr, value)
    }
}

/// One store captured by a [`GmemSnapshot`] during the parallel simulate
/// phase: `(byte address, value)`, in program order for its SM.
pub type WriteRecord = (u32, i32);

/// Copy-on-write page size for [`GmemSnapshot`], in 32-bit words (1 KiB).
pub const GMEM_PAGE_WORDS: usize = 256;

/// A per-SM view of global memory for the parallel launch path, built as
/// a **page-granular copy-on-write snapshot**: reads fall through to the
/// shared launch-time base image; the first store to a 1 KiB page faults
/// a private copy of that page in, so the SM's own loads observe its own
/// stores while the base stays untouched. Per-SM launch setup is
/// therefore O(touched pages) instead of the seed engine's O(mem) full
/// `GlobalMem` clone — what makes 4/8-SM sweeps cheap.
///
/// Every store is additionally logged so the merge phase can replay
/// writes deterministically in SM order and detect cross-SM write
/// conflicts. The base is shared by reference: the scoped-thread simulate
/// phase hands every SM the same `&GlobalMem`, with zero setup copies.
#[derive(Debug, Clone)]
pub struct GmemSnapshot<'a> {
    base: &'a GlobalMem,
    /// Lazily faulted private pages; index = word index / page size.
    pages: Vec<Option<Box<[i32; GMEM_PAGE_WORDS]>>>,
    log: Vec<WriteRecord>,
}

impl<'a> GmemSnapshot<'a> {
    pub fn new(base: &'a GlobalMem) -> GmemSnapshot<'a> {
        let n_pages = base.words.len().div_ceil(GMEM_PAGE_WORDS);
        GmemSnapshot { base, pages: vec![None; n_pages], log: Vec::new() }
    }

    pub fn log(&self) -> &[WriteRecord] {
        &self.log
    }

    pub fn into_log(self) -> Vec<WriteRecord> {
        self.log
    }

    /// Pages privately copied so far (the COW working-set size).
    pub fn touched_pages(&self) -> usize {
        self.pages.iter().filter(|p| p.is_some()).count()
    }
}

impl GmemPort for GmemSnapshot<'_> {
    #[inline]
    fn load(&self, addr: u32) -> Result<i32, SimError> {
        let idx = word_index(addr, self.base.words.len(), "global")?;
        Ok(match &self.pages[idx / GMEM_PAGE_WORDS] {
            Some(page) => page[idx % GMEM_PAGE_WORDS],
            None => self.base.words[idx],
        })
    }

    #[inline]
    fn store(&mut self, addr: u32, value: i32) -> Result<(), SimError> {
        let base = self.base;
        let idx = word_index(addr, base.words.len(), "global")?;
        let page = self.pages[idx / GMEM_PAGE_WORDS].get_or_insert_with(|| {
            // First write to this page: fault in a private copy of the
            // base image (the last page of a non-page-multiple image is
            // zero-padded; the padding is unreachable past the bounds
            // check above).
            let start = idx / GMEM_PAGE_WORDS * GMEM_PAGE_WORDS;
            let end = (start + GMEM_PAGE_WORDS).min(base.words.len());
            let mut p = Box::new([0i32; GMEM_PAGE_WORDS]);
            p[..end - start].copy_from_slice(&base.words[start..end]);
            p
        });
        page[idx % GMEM_PAGE_WORDS] = value;
        self.log.push((addr, value));
        Ok(())
    }
}

/// Per-resident-block shared memory (allocated out of the SM's 16 KB).
#[derive(Debug, Clone)]
pub struct SharedMem {
    words: Vec<i32>,
}

impl SharedMem {
    /// `bytes` includes the parameter segment.
    pub fn new(bytes: u32) -> SharedMem {
        SharedMem { words: vec![0; (bytes as usize).div_ceil(4)] }
    }

    pub fn size_bytes(&self) -> u32 {
        (self.words.len() * 4) as u32
    }

    pub fn load(&self, addr: u32) -> Result<i32, SimError> {
        Ok(self.words[word_index(addr, self.words.len(), "shared")?])
    }

    pub fn store(&mut self, addr: u32, value: i32) -> Result<(), SimError> {
        let idx = word_index(addr, self.words.len(), "shared")?;
        self.words[idx] = value;
        Ok(())
    }

    /// SEU injection (`sim::fault`): flip `bit` of the word selected by
    /// `sel` (reduced modulo the allocation). Returns the flipped word
    /// index, or `None` for a zero-byte allocation. Silent by design.
    pub(crate) fn seu_flip(&mut self, sel: u64, bit: u32) -> Option<u32> {
        if self.words.is_empty() {
            return None;
        }
        let word = (sel % self.words.len() as u64) as usize;
        self.words[word] ^= 1i32 << (bit % 32);
        Some(word as u32)
    }

    /// Number of SEU-addressable shared-memory words (the modulus the
    /// injector reduces site selectors by).
    pub(crate) fn seu_words(&self) -> usize {
        self.words.len()
    }

    /// Stuck-at re-corruption (`sim::fault` aging): force `bit` of `word`
    /// set, as a defective BRAM cell would on every access. Returns true
    /// when the word actually changed (the bit was previously clear).
    pub(crate) fn seu_set(&mut self, word: u32, bit: u32) -> bool {
        let Some(w) = self.words.get_mut(word as usize) else {
            return false;
        };
        let mask = 1i32 << (bit % 32);
        let changed = *w & mask == 0;
        *w |= mask;
        changed
    }

    /// Copy kernel parameters into the param segment (driver behaviour at
    /// block launch, paper §3.1).
    pub fn write_params(&mut self, params: &[i32]) -> Result<(), SimError> {
        assert!(
            params.len() * 4 <= PARAM_SEG_BYTES as usize,
            "at most {} kernel parameters",
            PARAM_SEG_BYTES / 4
        );
        for (i, &p) in params.iter().enumerate() {
            self.store((i as u32) * 4, p)?;
        }
        Ok(())
    }
}

/// Memory-path timing parameters (cycles at the 100 MHz overlay clock).
///
/// FlexGrip's Read/Write stages move data through a single AXI master one
/// warp **row** at a time (paper Fig. 3), blocking the pipeline while the
/// access drains. Each row pays a transaction-setup overhead (AXI
/// handshake + DDR access through the MIG) plus a per-thread streaming
/// beat. The defaults are calibrated against the paper's own Table 5
/// matmul times at 8/16/32 SP (2674/1667/1318 cycles per warp-iteration),
/// which fit `rows x 200 + threads x 15` almost exactly — see DESIGN.md
/// §Calibration and EXPERIMENTS.md.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemTiming {
    /// Global memory: per-row AXI transaction setup.
    pub global_row_overhead: u32,
    /// Global memory: per-thread streaming beat.
    pub global_per_thread: u32,
    /// Shared memory (BRAM): per-row overhead.
    pub shared_row_overhead: u32,
    /// Shared memory (BRAM): per-thread beat (banked, 1 port per SP).
    pub shared_per_thread: u32,
}

impl Default for MemTiming {
    fn default() -> Self {
        MemTiming {
            global_row_overhead: 200,
            global_per_thread: 15,
            shared_row_overhead: 4,
            shared_per_thread: 2,
        }
    }
}

impl MemTiming {
    /// Pipeline-blocking cycles for one memory instruction touching
    /// `threads` active lanes across `rows` warp rows.
    #[inline]
    pub fn blocking_cycles(&self, global: bool, rows: u32, threads: u32) -> u64 {
        let (row, per) = if global {
            (self.global_row_overhead, self.global_per_thread)
        } else {
            (self.shared_row_overhead, self.shared_per_thread)
        };
        rows as u64 * row as u64 + threads as u64 * per as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_roundtrip() {
        let mut m = GlobalMem::new(64);
        m.store(0, 7).unwrap();
        m.store(60, -1).unwrap();
        assert_eq!(m.load(0).unwrap(), 7);
        assert_eq!(m.load(60).unwrap(), -1);
    }

    #[test]
    fn misaligned_fault() {
        let m = GlobalMem::new(64);
        assert!(matches!(
            m.load(2),
            Err(SimError::MemFault { reason: "misaligned", .. })
        ));
    }

    #[test]
    fn oob_fault() {
        let mut m = GlobalMem::new(64);
        assert!(m.store(64, 0).is_err());
        assert!(m.load(1 << 30).is_err());
    }

    #[test]
    fn params_land_at_zero() {
        let mut s = SharedMem::new(PARAM_SEG_BYTES + 16);
        s.write_params(&[10, 20, 30]).unwrap();
        assert_eq!(s.load(0).unwrap(), 10);
        assert_eq!(s.load(8).unwrap(), 30);
    }

    #[test]
    #[should_panic]
    fn too_many_params_panics() {
        let mut s = SharedMem::new(256);
        s.write_params(&[0; 17]).unwrap();
    }

    #[test]
    fn bulk_io() {
        let mut m = GlobalMem::new(128);
        m.write_words(16, &[1, 2, 3]).unwrap();
        assert_eq!(m.read_words(16, 3).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn flat_access_cost_is_exactly_the_blocking_model() {
        // The provided GmemPort default must reproduce pre-cache timing
        // bit-for-bit: blocking = MemTiming::blocking_cycles, park = 0 —
        // for both the shared image and the COW snapshot.
        let t = MemTiming::default();
        let mut g = GlobalMem::new(256);
        let c = g.access_cost(&t, 4, 0xFFFF_FFFF, &[0; 32], true, 123);
        assert_eq!(c.blocking, t.blocking_cycles(true, 4, 32));
        assert_eq!(c.park, 0);
        assert_eq!(g.mem_stats(), MemStats::default());
        let base = GlobalMem::new(256);
        let mut snap = GmemSnapshot::new(&base);
        let c = snap.access_cost(&t, 2, 0b101, &[0, 4, 8], false, 0);
        assert_eq!(c.blocking, t.blocking_cycles(true, 2, 2));
        assert_eq!(c.park, 0);
    }

    #[test]
    fn snapshot_isolates_base_and_logs_stores() {
        let mut base = GlobalMem::new(64);
        base.store(0, 11).unwrap();
        let mut view = GmemSnapshot::new(&base);
        assert_eq!(GmemPort::load(&view, 0).unwrap(), 11, "snapshot sees base");
        GmemPort::store(&mut view, 4, 22).unwrap();
        GmemPort::store(&mut view, 4, 33).unwrap();
        assert_eq!(GmemPort::load(&view, 4).unwrap(), 33, "own writes visible");
        assert_eq!(base.load(4).unwrap(), 0, "base untouched until merge");
        assert_eq!(view.into_log(), vec![(4, 22), (4, 33)], "program order kept");
    }

    #[test]
    fn snapshot_propagates_faults_without_logging() {
        let base = GlobalMem::new(64);
        let mut view = GmemSnapshot::new(&base);
        assert!(GmemPort::store(&mut view, 2, 1).is_err());
        assert!(GmemPort::load(&view, 1 << 20).is_err());
        assert!(view.log().is_empty());
        assert_eq!(view.touched_pages(), 0, "faulting accesses copy nothing");
    }

    #[test]
    fn snapshot_faults_pages_on_first_write_only() {
        // 4 KiB = 4 pages. Writes to two addresses on page 0 and one on
        // page 2 must copy exactly two pages; reads elsewhere fall through.
        let mut base = GlobalMem::new(4096);
        for i in 0..1024 {
            base.store(i * 4, i as i32 + 1).unwrap();
        }
        let mut view = GmemSnapshot::new(&base);
        assert_eq!(view.touched_pages(), 0, "construction copies nothing");
        GmemPort::store(&mut view, 0, -1).unwrap();
        GmemPort::store(&mut view, 8, -2).unwrap();
        GmemPort::store(&mut view, 2 * 1024 + 4, -3).unwrap();
        assert_eq!(view.touched_pages(), 2);
        // COW page carries the base image around the written word.
        assert_eq!(GmemPort::load(&view, 4).unwrap(), 2, "page 0 preserved");
        assert_eq!(GmemPort::load(&view, 0).unwrap(), -1);
        assert_eq!(GmemPort::load(&view, 2 * 1024 + 4).unwrap(), -3);
        // Untouched pages read the live base values.
        assert_eq!(GmemPort::load(&view, 1024).unwrap(), 257, "page 1 falls through");
        assert_eq!(GmemPort::load(&view, 3 * 1024).unwrap(), 769, "page 3 falls through");
    }

    #[test]
    fn snapshot_handles_partial_last_page() {
        // 64 bytes = 16 words, far less than one 256-word page.
        let mut base = GlobalMem::new(64);
        base.store(60, 7).unwrap();
        let mut view = GmemSnapshot::new(&base);
        GmemPort::store(&mut view, 0, 1).unwrap();
        assert_eq!(view.touched_pages(), 1);
        assert_eq!(GmemPort::load(&view, 60).unwrap(), 7, "partial page copied");
        assert!(GmemPort::load(&view, 64).is_err(), "bounds still the base image");
        assert!(GmemPort::store(&mut view, 64, 1).is_err());
    }

    #[test]
    fn snapshot_page_boundary_writes_stay_on_their_page() {
        let base = GlobalMem::new(4096);
        let mut view = GmemSnapshot::new(&base);
        GmemPort::store(&mut view, 1020, 5).unwrap(); // last word of page 0
        GmemPort::store(&mut view, 1024, 6).unwrap(); // first word of page 1
        assert_eq!(view.touched_pages(), 2);
        assert_eq!(GmemPort::load(&view, 1020).unwrap(), 5);
        assert_eq!(GmemPort::load(&view, 1024).unwrap(), 6);
        assert_eq!(view.log(), [(1020, 5), (1024, 6)]);
    }
}
