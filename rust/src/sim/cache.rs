//! Per-SM L1/BRAM cache model and SM↔memory interconnect timing.
//!
//! The paper's architecture is "optimized for FPGA implementation to
//! support efficient use of embedded block memories"; this module gives
//! the simulator that memory system as a **timing layer**: a
//! set-associative tag array sized in BRAM-realistic units (ways × sets ×
//! line bytes), line fills streamed over the AXI interconnect, MSHR-style
//! outstanding-miss merging, and a per-partition fill port shared by the
//! SMs mapped to the same memory partition — so concurrent SMs contend
//! for memory instead of each seeing single-cycle global memory.
//!
//! The model holds **tags only, never data**: [`CachedGmem`] passes every
//! load and store straight through to the wrapped [`GmemPort`]
//! (write-through, no-write-allocate), so functional results are
//! bit-identical to flat memory by construction. The cache changes
//! cycles, never values — the differential suite in
//! `tests/memory_hierarchy.rs` pins exactly that.
//!
//! Determinism: every timing input (including the interconnect contention
//! factor) is a static function of the device configuration and this SM's
//! id, never of dynamic cross-SM state, so the sequential and parallel
//! launch paths stay bit-identical in timing too.

use super::mem::{GmemPort, MemCost, MemTiming};
use super::metrics::MemStats;
use super::SimError;

/// Bits in one Xilinx-class 36 Kb block RAM — the unit [`CacheGeometry::brams`]
/// sizes the data array in.
const BRAM_BITS: u64 = 36_864;

/// L1 cache shape: `ways × sets × line_bytes`, the three knobs the
/// memory sweep varies (`BENCH_memory.json`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheGeometry {
    /// Associativity (1..=16).
    pub ways: u32,
    /// Sets per way (power of two, <= 1024).
    pub sets: u32,
    /// Line size in bytes (power of two, 16..=128).
    pub line_bytes: u32,
}

impl CacheGeometry {
    /// Parse the CLI form `WAYSxSETSxLINE_BYTES`, e.g. `4x64x32`.
    pub fn parse(s: &str) -> Result<CacheGeometry, String> {
        let bad = || {
            format!(
                "invalid cache geometry '{s}': expected WAYSxSETSxLINE_BYTES \
                 (ways 1..=16, sets a power of two <= 1024, line bytes a \
                 power of two in 16..=128) — e.g. 2x16x32 (1 KiB), \
                 4x64x32 (8 KiB), 4x256x64 (64 KiB)"
            )
        };
        let mut it = s.split('x');
        let (a, b, c) = match (it.next(), it.next(), it.next(), it.next()) {
            (Some(a), Some(b), Some(c), None) => (a, b, c),
            _ => return Err(bad()),
        };
        let g = CacheGeometry {
            ways: a.trim().parse().map_err(|_| bad())?,
            sets: b.trim().parse().map_err(|_| bad())?,
            line_bytes: c.trim().parse().map_err(|_| bad())?,
        };
        g.validate().map_err(|_| bad())?;
        Ok(g)
    }

    pub fn validate(&self) -> Result<(), SimError> {
        if !(1..=16).contains(&self.ways) {
            return Err(SimError::LimitExceeded(format!(
                "cache ways {} not in 1..=16",
                self.ways
            )));
        }
        if !self.sets.is_power_of_two() || self.sets > 1024 {
            return Err(SimError::LimitExceeded(format!(
                "cache sets {} must be a power of two <= 1024",
                self.sets
            )));
        }
        if !self.line_bytes.is_power_of_two() || !(16..=128).contains(&self.line_bytes) {
            return Err(SimError::LimitExceeded(format!(
                "cache line {} bytes must be a power of two in 16..=128",
                self.line_bytes
            )));
        }
        Ok(())
    }

    /// The canonical `4x64x32` form (inverse of [`CacheGeometry::parse`]).
    pub fn label(&self) -> String {
        format!("{}x{}x{}", self.ways, self.sets, self.line_bytes)
    }

    pub fn size_bytes(&self) -> u32 {
        self.ways * self.sets * self.line_bytes
    }

    pub fn line_words(&self) -> u32 {
        self.line_bytes / 4
    }

    /// 36 Kb block RAMs the data array occupies; each way needs its own
    /// BRAM port for the parallel tag compare, so small caches still pay
    /// one BRAM per way.
    pub fn brams(&self) -> u32 {
        ((self.size_bytes() as u64 * 8).div_ceil(BRAM_BITS) as u32).max(self.ways)
    }

    /// Split a byte address into `(tag, set, offset)`.
    #[inline]
    pub fn decompose(&self, addr: u32) -> (u32, u32, u32) {
        let line = addr / self.line_bytes;
        (line / self.sets, line % self.sets, addr % self.line_bytes)
    }
}

/// Full L1 configuration: geometry plus the miss-handling resources.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct L1Config {
    pub geom: CacheGeometry,
    /// Outstanding-miss registers: distinct line fills in flight at once.
    pub mshrs: u32,
    /// Memory partitions behind the interconnect. SMs are mapped to
    /// partitions round-robin by SM id; SMs sharing a partition share one
    /// fill port, which is where multi-SM contention comes from.
    pub partitions: u32,
}

impl L1Config {
    /// Defaults sized like the paper's BRAM budget: 4 MSHRs, 2 partitions.
    pub fn new(geom: CacheGeometry) -> L1Config {
        L1Config { geom, mshrs: 4, partitions: 2 }
    }

    pub fn validate(&self) -> Result<(), SimError> {
        self.geom.validate()?;
        if self.mshrs == 0 {
            return Err(SimError::LimitExceeded("cache needs at least 1 MSHR".into()));
        }
        if self.partitions == 0 {
            return Err(SimError::LimitExceeded(
                "interconnect needs at least 1 memory partition".into(),
            ));
        }
        Ok(())
    }
}

/// Device-level memory hierarchy selection: flat (the seed behaviour,
/// [`MemTiming`] applied directly) or an L1 per SM.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MemoryConfig {
    pub l1: Option<L1Config>,
}

impl MemoryConfig {
    /// Flat single-latency global memory (identical to the pre-cache
    /// simulator: every access pays [`MemTiming::blocking_cycles`]).
    pub fn flat() -> MemoryConfig {
        MemoryConfig { l1: None }
    }

    pub fn with_l1(geom: CacheGeometry) -> MemoryConfig {
        MemoryConfig { l1: Some(L1Config::new(geom)) }
    }

    pub fn label(&self) -> String {
        match self.l1 {
            Some(c) => format!("l1 {}", c.geom.label()),
            None => "flat".into(),
        }
    }

    pub fn validate(&self) -> Result<(), SimError> {
        match &self.l1 {
            Some(c) => c.validate(),
            None => Ok(()),
        }
    }
}

/// One SM's L1 timing state: tag array (LRU stamps), MSHR list, and the
/// partition fill port this SM shares with its interconnect neighbours.
#[derive(Debug, Clone)]
pub struct L1Cache {
    cfg: L1Config,
    timing: MemTiming,
    /// Tag per (set, way) slot, `None` while invalid.
    tags: Vec<Option<u32>>,
    /// LRU use stamps, parallel to `tags`.
    stamps: Vec<u64>,
    use_stamp: u64,
    /// Outstanding line fills: `(line base address, ready cycle)`.
    inflight: Vec<(u32, u64)>,
    /// Next cycle this SM's partition fill port is free.
    fill_free_at: u64,
    /// SMs sharing this SM's partition fill port (static, so timing stays
    /// identical between the sequential and parallel launch paths).
    contention_k: u64,
    stats: MemStats,
}

impl L1Cache {
    pub fn new(cfg: L1Config, num_sms: u32, sm_id: u32, timing: MemTiming) -> L1Cache {
        let slots = (cfg.geom.sets * cfg.geom.ways) as usize;
        let sharers =
            (0..num_sms.max(1)).filter(|i| i % cfg.partitions == sm_id % cfg.partitions).count();
        L1Cache {
            cfg,
            timing,
            tags: vec![None; slots],
            stamps: vec![0; slots],
            use_stamp: 0,
            inflight: Vec::new(),
            fill_free_at: 0,
            contention_k: (sharers as u64).max(1),
            stats: MemStats::default(),
        }
    }

    pub fn stats(&self) -> MemStats {
        self.stats
    }

    /// Tag entries in this cache's tag array — the SEU injector's target
    /// surface (`sim::fault`). Tag upsets are parity-detected in the
    /// modeled hardware, so the injector raises `SimError::SoftError`
    /// instead of mutating a tag.
    pub fn tag_count(&self) -> u32 {
        self.tags.len() as u32
    }

    /// Cycles for one line fill alone on its partition port: the AXI row
    /// setup plus one streaming beat per line word.
    fn fill_service(&self) -> u64 {
        self.timing.global_row_overhead as u64
            + self.cfg.geom.line_words() as u64 * self.timing.global_per_thread as u64
    }

    /// Timing for one global warp access (`addrs[lane]` active iff bit
    /// `lane` of `exec` is set). Front-end occupancy runs at BRAM
    /// (shared-memory) speed; load misses park the warp until the fill
    /// lands.
    pub fn access(&mut self, rows: u32, exec: u32, addrs: &[u32], load: bool, now: u64) -> MemCost {
        let blocking = self.timing.blocking_cycles(false, rows, exec.count_ones());
        if !load {
            // Write-through, no-write-allocate: stores drain through a
            // write buffer (no park); present lines refresh their LRU
            // stamp so streaming stores don't age out live read lines.
            for (lane, &a) in addrs.iter().enumerate() {
                if exec & (1 << lane) == 0 {
                    continue;
                }
                let line = a / self.cfg.geom.line_bytes * self.cfg.geom.line_bytes;
                if let Some(slot) = self.lookup(line) {
                    self.use_stamp += 1;
                    self.stamps[slot] = self.use_stamp;
                }
            }
            return MemCost { blocking, park: 0 };
        }
        // Coalesce active lanes to unique lines (<= 32 lanes: a linear
        // scan beats hashing), then resolve each line once.
        let mut lines: Vec<u32> = Vec::with_capacity(4);
        for (lane, &a) in addrs.iter().enumerate() {
            if exec & (1 << lane) == 0 {
                continue;
            }
            let line = a / self.cfg.geom.line_bytes * self.cfg.geom.line_bytes;
            if !lines.contains(&line) {
                lines.push(line);
            }
        }
        let mut park = 0u64;
        for line in lines {
            let ready = self.access_line(line, now);
            park = park.max(ready.saturating_sub(now));
        }
        self.stats.fill_stall_cycles += park;
        MemCost { blocking, park }
    }

    fn lookup(&self, line: u32) -> Option<usize> {
        let (tag, set, _) = self.cfg.geom.decompose(line);
        let base = (set * self.cfg.geom.ways) as usize;
        (base..base + self.cfg.geom.ways as usize).find(|&i| self.tags[i] == Some(tag))
    }

    /// One load touching `line`; returns the cycle its data is available.
    fn access_line(&mut self, line: u32, now: u64) -> u64 {
        self.use_stamp += 1;
        if let Some(slot) = self.lookup(line) {
            self.stamps[slot] = self.use_stamp;
            self.stats.hits += 1;
            // Hit-under-fill: an earlier miss allocated this line and its
            // fill is still in flight — merge into that MSHR and wake
            // when the one outstanding fill lands (no second fill).
            if let Some(&(_, ready)) = self.inflight.iter().find(|&&(l, r)| l == line && r > now) {
                self.stats.mshr_merges += 1;
                return ready;
            }
            return now;
        }
        self.stats.misses += 1;
        // Allocate an MSHR; a full MSHR file stalls the fill until the
        // earliest outstanding fill retires.
        self.inflight.retain(|&(_, r)| r > now);
        let mshr_free = if self.inflight.len() >= self.cfg.mshrs as usize {
            self.inflight.iter().map(|&(_, r)| r).min().unwrap_or(now)
        } else {
            now
        };
        // Interconnect: fills from the SMs sharing this partition
        // interleave on one port, so each fill's effective occupancy is
        // `service × sharers`; the surplus is accounted as contention.
        let service = self.fill_service();
        let effective = service * self.contention_k;
        let start = now.max(mshr_free).max(self.fill_free_at);
        let ready = start + effective;
        self.fill_free_at = ready;
        self.stats.contention_cycles += effective - service;
        self.inflight.retain(|&(_, r)| r > start);
        self.inflight.push((line, ready));
        self.insert(line);
        ready
    }

    /// Install `line`'s tag: first invalid way, else evict the LRU way.
    fn insert(&mut self, line: u32) {
        let (tag, set, _) = self.cfg.geom.decompose(line);
        let base = (set * self.cfg.geom.ways) as usize;
        let ways = self.cfg.geom.ways as usize;
        let slot = (base..base + ways)
            .find(|&i| self.tags[i].is_none())
            .unwrap_or_else(|| (base..base + ways).min_by_key(|&i| self.stamps[i]).unwrap());
        if self.tags[slot].is_some() {
            self.stats.evictions += 1;
        }
        self.tags[slot] = Some(tag);
        self.stamps[slot] = self.use_stamp;
    }
}

/// A [`GmemPort`] adapter layering the L1 timing model over any inner
/// port. Loads and stores pass straight through to the wrapped port —
/// only [`GmemPort::access_cost`] and [`GmemPort::mem_stats`] change.
pub struct CachedGmem<'a, G: GmemPort + ?Sized> {
    inner: &'a mut G,
    cache: L1Cache,
}

impl<'a, G: GmemPort + ?Sized> CachedGmem<'a, G> {
    pub fn new(inner: &'a mut G, cache: L1Cache) -> CachedGmem<'a, G> {
        CachedGmem { inner, cache }
    }
}

impl<G: GmemPort + ?Sized> GmemPort for CachedGmem<'_, G> {
    #[inline]
    fn load(&self, addr: u32) -> Result<i32, SimError> {
        self.inner.load(addr)
    }

    #[inline]
    fn store(&mut self, addr: u32, value: i32) -> Result<(), SimError> {
        self.inner.store(addr, value)
    }

    fn access_cost(
        &mut self,
        _timing: &MemTiming,
        rows: u32,
        exec: u32,
        addrs: &[u32],
        load: bool,
        now: u64,
    ) -> MemCost {
        self.cache.access(rows, exec, addrs, load, now)
    }

    fn mem_stats(&self) -> MemStats {
        self.cache.stats()
    }

    fn l1_tag_count(&self) -> u32 {
        self.cache.tag_count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(s: &str) -> CacheGeometry {
        CacheGeometry::parse(s).unwrap()
    }

    #[test]
    fn parse_roundtrips_and_sizes() {
        let g = geom("4x64x32");
        assert_eq!(g, CacheGeometry { ways: 4, sets: 64, line_bytes: 32 });
        assert_eq!(g.label(), "4x64x32");
        assert_eq!(g.size_bytes(), 8192);
        assert_eq!(g.line_words(), 8);
        assert_eq!(geom("2x16x32").size_bytes(), 1024);
        assert_eq!(geom("4x256x64").size_bytes(), 65536);
    }

    #[test]
    fn parse_rejects_malformed_geometries() {
        for bad in ["", "4x64", "4x64x32x2", "0x64x32", "4x63x32", "4x64x8", "axbxc", "4x2048x32"]
        {
            let err = CacheGeometry::parse(bad).unwrap_err();
            assert!(err.contains("WAYSxSETSxLINE_BYTES"), "{bad}: {err}");
            assert!(err.contains("4x64x32"), "error must list examples: {err}");
        }
    }

    #[test]
    fn bram_sizing_in_36kb_units() {
        assert_eq!(geom("2x16x32").brams(), 2, "tiny cache still pays 1 BRAM/way");
        assert_eq!(geom("4x64x32").brams(), 4); // 8 KiB = 64 Kb -> ceil 2, ways 4
        assert_eq!(geom("4x256x64").brams(), 15); // 64 KiB = 512 Kb / 36 Kb
    }

    #[test]
    fn decompose_pins_tag_index_offset() {
        let g = geom("4x64x32");
        // 0x1234 / 32 = line 145; 145 % 64 = set 17; 145 / 64 = tag 2.
        assert_eq!(g.decompose(0x1234), (2, 17, 0x14));
        assert_eq!(g.decompose(0), (0, 0, 0));
        // Same set, different tag: 32-byte lines, 64 sets -> +2048 bytes.
        let (t0, s0, _) = g.decompose(0x100);
        let (t1, s1, _) = g.decompose(0x100 + 2048);
        assert_eq!(s0, s1);
        assert_eq!(t1, t0 + 1);
    }

    fn one_sm_cache(g: &str) -> L1Cache {
        L1Cache::new(L1Config::new(geom(g)), 1, 0, MemTiming::default())
    }

    #[test]
    fn miss_then_hit_on_one_line() {
        let mut c = one_sm_cache("2x16x32");
        // Miss at t=0: fill service = 200 + 8*15 = 320; front-end
        // blocking at BRAM speed = 4*4 + 1*2 = 18.
        let cost = c.access(4, 1, &[0x40], true, 0);
        assert_eq!(cost.blocking, 18);
        assert_eq!(cost.park, 320);
        // Same line after the fill landed: pure hit, no park.
        let cost = c.access(4, 1, &[0x44], true, 1_000);
        assert_eq!(cost.park, 0);
        let s = c.stats();
        assert_eq!((s.misses, s.hits, s.evictions), (1, 1, 0));
        assert_eq!(s.fill_stall_cycles, 320);
    }

    #[test]
    fn mshr_merges_outstanding_miss_single_fill() {
        let mut c = one_sm_cache("2x16x32");
        let first = c.access(4, 1, &[0x40], true, 0);
        assert_eq!(first.park, 320);
        // Second access to the same line while the fill is in flight:
        // merged into the outstanding MSHR, parks to the same ready time.
        let second = c.access(4, 1, &[0x48], true, 100);
        assert_eq!(second.park, 220, "wakes when the one fill lands");
        let s = c.stats();
        assert_eq!(s.misses, 1, "no second fill issued");
        assert_eq!(s.mshr_merges, 1);
        assert_eq!(s.hits, 1, "merge counts as a (hit-under-fill) hit");
    }

    #[test]
    fn lru_eviction_order() {
        // 2 ways x 1 set x 16-byte lines: lines 0x00, 0x10, 0x20 all
        // collide. Touch A, B, re-touch A, then C: B is LRU and evicted.
        let mut c = one_sm_cache("2x1x16");
        let mut t = 0u64;
        let mut load = |c: &mut L1Cache, addr: u32| {
            t += 100_000; // far apart: every fill completes in between
            c.access(4, 1, &[addr], true, t);
        };
        load(&mut c, 0x00); // miss, fills way 0
        load(&mut c, 0x10); // miss, fills way 1
        load(&mut c, 0x00); // hit, refreshes A
        load(&mut c, 0x20); // miss, evicts B (LRU)
        assert_eq!(c.stats().evictions, 1);
        load(&mut c, 0x00); // still resident
        load(&mut c, 0x10); // gone: miss again, evicts C
        let s = c.stats();
        assert_eq!(s.misses, 4);
        assert_eq!(s.hits, 2);
        assert_eq!(s.evictions, 2);
    }

    #[test]
    fn partition_contention_scales_with_sharers() {
        // 4 SMs over 2 partitions: SM 0 shares its port with SM 2.
        let mut c = L1Cache::new(L1Config::new(geom("2x16x32")), 4, 0, MemTiming::default());
        let cost = c.access(4, 1, &[0], true, 0);
        assert_eq!(cost.park, 640, "2 sharers double the 320-cycle fill");
        assert_eq!(c.stats().contention_cycles, 320);
        // A lone SM sees the raw service time and zero contention.
        let mut c1 = one_sm_cache("2x16x32");
        c1.access(4, 1, &[0], true, 0);
        assert_eq!(c1.stats().contention_cycles, 0);
    }

    #[test]
    fn warp_access_coalesces_lanes_to_unique_lines() {
        // 8 active lanes, stride 4 bytes: one 32-byte line covers lanes
        // 0..8 -> exactly one miss, and the fill port serializes nothing.
        let mut c = one_sm_cache("2x16x32");
        let addrs: Vec<u32> = (0..8u32).map(|l| l * 4).collect();
        c.access(4, 0xFF, &addrs, true, 0);
        let s = c.stats();
        assert_eq!((s.misses, s.hits), (1, 0));
        // Stride 32: every lane its own line -> 8 fills serialized on the
        // port; the warp parks until the last one lands.
        let mut c = one_sm_cache("2x16x32");
        let addrs: Vec<u32> = (0..8u32).map(|l| l * 32).collect();
        let cost = c.access(4, 0xFF, &addrs, true, 0);
        assert_eq!(c.stats().misses, 8);
        assert_eq!(cost.park, 8 * 320);
    }

    #[test]
    fn stores_never_allocate_or_park() {
        let mut c = one_sm_cache("2x16x32");
        let cost = c.access(4, 1, &[0x40], false, 0);
        assert_eq!(cost.park, 0);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 0), "no-write-allocate");
    }

    #[test]
    fn cached_gmem_passes_values_through() {
        use super::super::mem::GlobalMem;
        let mut base = GlobalMem::new(256);
        base.store(8, 42).unwrap();
        let cache = one_sm_cache("2x16x32");
        let stats = {
            let mut cg = CachedGmem::new(&mut base, cache);
            assert_eq!(GmemPort::load(&cg, 8).unwrap(), 42);
            GmemPort::store(&mut cg, 12, 7).unwrap();
            assert_eq!(GmemPort::load(&cg, 12).unwrap(), 7);
            cg.access_cost(&MemTiming::default(), 4, 1, &[8], true, 0);
            cg.mem_stats()
        };
        assert_eq!(stats.misses, 1);
        assert_eq!(base.load(12).unwrap(), 7, "write-through to the base");
    }

    #[test]
    fn memory_config_labels_and_validation() {
        assert_eq!(MemoryConfig::flat().label(), "flat");
        assert_eq!(MemoryConfig::default(), MemoryConfig::flat());
        let m = MemoryConfig::with_l1(geom("4x64x32"));
        assert_eq!(m.label(), "l1 4x64x32");
        m.validate().unwrap();
        let mut bad = m;
        bad.l1.as_mut().unwrap().mshrs = 0;
        assert!(bad.validate().is_err());
    }
}
