//! Event-driven warp scheduler — the warp unit's issue-selection logic,
//! factored out of [`super::Sm`] so it is unit-testable on its own.
//!
//! The seed engine re-derived every warp's status with an O(total-warps)
//! linear scan per issued instruction. This scheduler keeps the same
//! *observable* policy — positional round-robin over ready warps, starting
//! at a rotating pointer — but makes selection O(1) amortized:
//!
//! * **ready set**: one bit per flat warp index in a `u128`; the
//!   round-robin pick is a single masked `trailing_zeros`;
//! * **wake heap**: a min-heap of `(ready_at, flat)` for warps parked on a
//!   pipeline/memory hazard. Wakes are drained lazily into the ready set
//!   before each pick, so simultaneous wakes are still served in
//!   positional order (heap tie-order never leaks into issue order);
//! * **stall advance**: when nothing is ready, the heap top is exactly the
//!   seed engine's `min(ready_at)` over Waiting warps, so stall-cycle
//!   accounting is bit-identical to the linear scan.
//!
//! Round-robin fairness across block retirement is handled by
//! [`WarpScheduler::retire_range`]: the rotation pointer is rebased
//! against the shrunk warp population instead of being reset to slot 0
//! (the seed engine's fairness bug — `rr` restarted from 0 on every
//! `swap_remove`, silently favouring low-numbered blocks).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Hard cap on simultaneously resident warps per SM. The block scheduler's
/// Table 1 limits give at most 8 resident blocks x 8 warps = 64; the cap
/// leaves headroom for direct `Sm::run` callers with custom limits.
pub const MAX_RESIDENT_WARPS: u32 = 128;

/// O(1)-amortized round-robin warp scheduler (see module docs).
#[derive(Debug, Clone, Default)]
pub struct WarpScheduler {
    /// Bit `i` set = flat warp `i` is ready to issue.
    ready: u128,
    /// Parked warps: `(ready_at, flat)`, min first.
    wake: BinaryHeap<Reverse<(u64, u32)>>,
    /// Flat index the next pick starts scanning from.
    rr: u32,
    /// Flat warps currently tracked (resident, in slot order).
    n: u32,
}

impl WarpScheduler {
    pub fn new() -> WarpScheduler {
        WarpScheduler::default()
    }

    /// Warps currently tracked.
    pub fn len(&self) -> u32 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// A new block became resident: append `count` warps at the end of the
    /// flat order, all immediately ready (fresh warps have `ready_at = 0`).
    /// Existing flat indices are unaffected.
    pub fn extend_ready(&mut self, count: u32) {
        assert!(
            self.n + count <= MAX_RESIDENT_WARPS,
            "at most {MAX_RESIDENT_WARPS} resident warps per SM (got {})",
            self.n + count
        );
        for i in self.n..self.n + count {
            self.ready |= 1u128 << i;
        }
        self.n += count;
    }

    /// Park `flat` until `ready_at`. The warp must not be in the ready set
    /// (an issued warp's bit is cleared by [`WarpScheduler::pick`]).
    pub fn park(&mut self, flat: u32, ready_at: u64) {
        debug_assert_eq!(self.ready & (1u128 << flat), 0, "parking a ready warp");
        self.wake.push(Reverse((ready_at, flat)));
    }

    /// Immediately mark `flat` ready (barrier release of a warp whose
    /// pipeline hazard already drained).
    pub fn make_ready(&mut self, flat: u32) {
        debug_assert!(flat < self.n);
        self.ready |= 1u128 << flat;
    }

    /// Move every parked warp whose wake time has arrived (`ready_at <=
    /// now`) into the ready set. No wakeup is ever lost: entries stay in
    /// the heap until drained, and draining is monotonic in `now`.
    pub fn drain_wakes(&mut self, now: u64) {
        while let Some(&Reverse((t, flat))) = self.wake.peek() {
            if t > now {
                break;
            }
            self.wake.pop();
            self.ready |= 1u128 << flat;
        }
    }

    /// Earliest pending wake time, if any warp is parked. After
    /// [`WarpScheduler::drain_wakes`]`(now)` this is strictly greater than
    /// `now` — exactly the seed engine's `min(ready_at)` over Waiting
    /// warps, which drives stall-cycle accounting.
    pub fn next_wake(&self) -> Option<u64> {
        self.wake.peek().map(|&Reverse((t, _))| t)
    }

    /// Round-robin pick: the first ready warp at or after the rotation
    /// pointer, wrapping once. Clears the picked warp's ready bit and
    /// advances the pointer just past it. Returns `None` when no warp is
    /// ready (caller then advances time to [`WarpScheduler::next_wake`]).
    pub fn pick(&mut self) -> Option<u32> {
        if self.ready == 0 {
            return None;
        }
        // rr is always < n <= 128 (and 0 when n == 0), so the shift
        // amount is at most 127 and cannot overflow.
        let at_or_after = self.ready & (!0u128 << self.rr);
        let candidates = if at_or_after != 0 {
            at_or_after
        } else {
            self.ready
        };
        let idx = candidates.trailing_zeros();
        self.ready &= !(1u128 << idx);
        self.rr = if idx + 1 >= self.n { 0 } else { idx + 1 };
        Some(idx)
    }

    /// A block retired: remove flat indices `[base, base + count)` — all
    /// must be inactive (done warps are neither ready nor parked) — and
    /// shift every higher index down by `count`, preserving the relative
    /// order of the survivors.
    ///
    /// The rotation pointer is rebased, not reset: a pointer past the
    /// removed range slides down with its warp; a pointer inside the range
    /// lands on the first warp after it. Round-robin order therefore
    /// continues exactly where it left off (the seed engine's fairness
    /// bug reset it to 0 here).
    pub fn retire_range(&mut self, base: u32, count: u32) {
        if count == 0 {
            return;
        }
        debug_assert!(base + count <= self.n);
        let count_mask = if count >= 128 {
            !0u128
        } else {
            (1u128 << count) - 1
        };
        debug_assert_eq!(
            (self.ready >> base) & count_mask,
            0,
            "retired warps must be done (inactive)"
        );
        let low = self.ready & ((1u128 << base) - 1);
        let high = if base + count >= 128 {
            0
        } else {
            self.ready >> (base + count)
        };
        self.ready = (high << base) | low;

        if !self.wake.is_empty() {
            let mut entries = std::mem::take(&mut self.wake).into_vec();
            for Reverse((_, flat)) in entries.iter_mut() {
                debug_assert!(
                    *flat < base || *flat >= base + count,
                    "retired warps must not be parked"
                );
                if *flat >= base + count {
                    *flat -= count;
                }
            }
            self.wake = BinaryHeap::from(entries);
        }

        if self.rr >= base + count {
            self.rr -= count;
        } else if self.rr > base {
            self.rr = base;
        }
        self.n -= count;
        if self.n == 0 || self.rr >= self.n {
            self.rr = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference model: the seed engine's linear scan (with the fairness
    /// fix), kept deliberately naive so the event-driven scheduler can be
    /// differentially tested against it.
    #[derive(Debug, Clone)]
    struct LinearScan {
        /// ready_at per warp; `None` = removed (done).
        warps: Vec<Option<u64>>,
        rr: usize,
    }

    impl LinearScan {
        fn new() -> LinearScan {
            LinearScan { warps: Vec::new(), rr: 0 }
        }

        fn extend_ready(&mut self, count: u32) {
            for _ in 0..count {
                self.warps.push(Some(0));
            }
        }

        fn pick(&mut self, now: u64) -> Option<u32> {
            let n = self.warps.len();
            if n == 0 {
                return None;
            }
            let start = if self.rr >= n { 0 } else { self.rr };
            for k in 0..n {
                let i = (start + k) % n;
                if matches!(self.warps[i], Some(t) if t <= now) {
                    self.rr = (i + 1) % n;
                    self.warps[i] = None; // issued: caller re-parks or retires
                    return Some(i as u32);
                }
            }
            None
        }

        fn park(&mut self, flat: u32, ready_at: u64) {
            self.warps[flat as usize] = Some(ready_at);
        }

        fn next_wake(&self, now: u64) -> Option<u64> {
            self.warps.iter().flatten().copied().filter(|&t| t > now).min()
        }

        fn retire_range(&mut self, base: u32, count: u32) {
            let (base, count) = (base as usize, count as usize);
            self.warps.drain(base..base + count);
            if self.rr >= base + count {
                self.rr -= count;
            } else if self.rr > base {
                self.rr = base;
            }
            if self.warps.is_empty() || self.rr >= self.warps.len() {
                self.rr = 0;
            }
        }
    }

    #[test]
    fn round_robin_cycles_positionally() {
        let mut s = WarpScheduler::new();
        s.extend_ready(4);
        assert_eq!(s.pick(), Some(0));
        assert_eq!(s.pick(), Some(1));
        s.make_ready(0);
        s.make_ready(1);
        // Pointer sits at 2: lower-numbered ready warps must wait a lap.
        assert_eq!(s.pick(), Some(2));
        assert_eq!(s.pick(), Some(3));
        assert_eq!(s.pick(), Some(0));
        assert_eq!(s.pick(), Some(1));
        assert_eq!(s.pick(), None);
    }

    #[test]
    fn pointer_survives_block_retirement() {
        // Three 2-warp blocks, flat 0..6. Issue 0,1,2,3; block 1 (warps
        // 2,3) retires. The pointer was at 4 and must continue at the warp
        // that *was* flat 4 — not restart from slot 0 (the seed bug).
        let mut s = WarpScheduler::new();
        s.extend_ready(6);
        for want in 0..4 {
            assert_eq!(s.pick(), Some(want));
        }
        s.make_ready(0);
        s.make_ready(1);
        s.retire_range(2, 2);
        assert_eq!(s.len(), 4);
        // Old warp 4 is now flat 2 and must issue before warps 0/1.
        assert_eq!(s.pick(), Some(2), "round-robin must not restart at 0");
        assert_eq!(s.pick(), Some(3));
        assert_eq!(s.pick(), Some(0));
        assert_eq!(s.pick(), Some(1));
    }

    #[test]
    fn pointer_inside_retired_range_lands_after_it() {
        let mut s = WarpScheduler::new();
        s.extend_ready(6);
        for want in 0..6 {
            assert_eq!(s.pick(), Some(want));
        }
        // Warp 2 issues once more and is the block's last warp to finish:
        // the pointer (3) sits inside the retiring range [2, 4).
        s.make_ready(2);
        assert_eq!(s.pick(), Some(2));
        s.make_ready(0);
        s.make_ready(1);
        s.make_ready(4);
        s.make_ready(5);
        s.retire_range(2, 2);
        // rr rebased to the first survivor after the range: old warp 4,
        // now flat 2; rotation continues from there.
        assert_eq!(s.pick(), Some(2));
        assert_eq!(s.pick(), Some(3));
        assert_eq!(s.pick(), Some(0));
    }

    #[test]
    fn retiring_the_tail_wraps_the_pointer() {
        let mut s = WarpScheduler::new();
        s.extend_ready(4);
        for want in 0..4 {
            assert_eq!(s.pick(), Some(want));
        }
        s.make_ready(0);
        s.make_ready(1);
        s.retire_range(2, 2);
        assert_eq!(s.pick(), Some(0), "pointer past the end wraps to 0");
    }

    #[test]
    fn no_lost_wakeups() {
        let mut s = WarpScheduler::new();
        s.extend_ready(3);
        for f in 0..3 {
            assert_eq!(s.pick(), Some(f));
        }
        s.park(0, 10);
        s.park(1, 10); // simultaneous wake
        s.park(2, 25);
        assert_eq!(s.pick(), None);
        assert_eq!(s.next_wake(), Some(10));
        s.drain_wakes(9);
        assert_eq!(s.pick(), None, "nothing wakes before its time");
        s.drain_wakes(10);
        // Simultaneous wakes are served positionally, not in heap order.
        assert_eq!(s.pick(), Some(0));
        assert_eq!(s.pick(), Some(1));
        assert_eq!(s.pick(), None);
        assert_eq!(s.next_wake(), Some(25));
        s.drain_wakes(30);
        assert_eq!(s.pick(), Some(2));
        assert_eq!(s.next_wake(), None);
    }

    #[test]
    fn differential_vs_linear_scan_randomized() {
        // Drive both schedulers with the same random issue/park/retire
        // trace and assert identical pick sequences and stall advances —
        // the seed engine's observable behaviour (fairness fix included).
        let mut rng = crate::rng::XorShift64::new(0x5EED_5C4D);
        for case in 0..200 {
            let mut ev = WarpScheduler::new();
            let mut lin = LinearScan::new();
            let mut now = 0u64;
            let blocks = 1 + rng.below(4) as u32; // warps per block
            ev.extend_ready(blocks * 2);
            lin.extend_ready(blocks * 2);
            let mut live: Vec<u32> = vec![0; (blocks * 2) as usize];
            let mut issues = 0;
            while live.iter().any(|&d| d == 0) && issues < 500 {
                ev.drain_wakes(now);
                let a = ev.pick();
                let b = lin.pick(now);
                assert_eq!(a, b, "case {case} issue {issues} at {now}");
                match a {
                    Some(flat) => {
                        let fi = flat as usize;
                        if rng.below(8) == 0 {
                            // Warp finishes: drop it; retire its pair when
                            // both are done.
                            live[fi] = 1;
                            let pair = fi ^ 1;
                            if live[pair] == 1 {
                                let base = (fi & !1) as u32;
                                ev.retire_range(base, 2);
                                lin.retire_range(base, 2);
                                live.drain((base as usize)..(base as usize) + 2);
                            }
                        } else {
                            let delay = 1 + rng.below(20) as u64;
                            ev.park(flat, now + delay);
                            lin.park(flat, now + delay);
                        }
                    }
                    None => {
                        let (wa, wb) = (ev.next_wake(), lin.next_wake(now));
                        assert_eq!(wa, wb, "case {case} stall at {now}");
                        match wa {
                            Some(t) => now = t,
                            None => break,
                        }
                    }
                }
                issues += 1;
            }
        }
    }

    #[test]
    fn extend_after_retirement_appends_fresh_ready_warps() {
        let mut s = WarpScheduler::new();
        s.extend_ready(2);
        assert_eq!(s.pick(), Some(0));
        assert_eq!(s.pick(), Some(1));
        s.retire_range(0, 2);
        assert!(s.is_empty());
        s.extend_ready(3);
        assert_eq!(s.len(), 3);
        assert_eq!(s.pick(), Some(0));
    }

    #[test]
    #[should_panic(expected = "resident warps")]
    fn capacity_is_enforced() {
        let mut s = WarpScheduler::new();
        s.extend_ready(MAX_RESIDENT_WARPS + 1);
    }
}
