//! Execution statistics collected by the SM — the observability surface
//! used by the harness (cycle counts feed every speedup/energy number) and
//! by the customization analyzer (dynamic op mix, stack high-water mark).

use crate::isa::Op;
use crate::sim::fault::FaultStats;

/// Memory-hierarchy counters for one SM over one launch. All zero on
/// flat memory (the default [`crate::sim::GmemPort`] reports nothing);
/// populated by the L1/BRAM cache layer in `sim/cache.rs`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemStats {
    /// L1 load hits (line-granular: one count per unique line a warp
    /// access touches).
    pub hits: u64,
    /// L1 load misses (each schedules one line fill).
    pub misses: u64,
    /// Valid lines replaced by a fill (LRU victim had data).
    pub evictions: u64,
    /// Cycles warps spent parked waiting on line fills.
    pub fill_stall_cycles: u64,
    /// Extra fill cycles from SMs sharing a partition fill port.
    pub contention_cycles: u64,
    /// Misses merged into an already-outstanding fill (MSHR hits).
    pub mshr_merges: u64,
}

impl MemStats {
    /// Load hit rate in [0, 1]; 0 when no loads were observed.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn merge(&mut self, other: &MemStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.fill_stall_cycles += other.fill_stall_cycles;
        self.contention_cycles += other.contention_cycles;
        self.mshr_merges += other.mshr_merges;
    }
}

/// Counters for one SM over one kernel launch.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SmStats {
    /// Total cycles this SM was busy (its clock when its last block retired).
    pub cycles: u64,
    /// Warp-instructions issued.
    pub instructions: u64,
    /// Thread-instructions executed (sum of active lanes per issue).
    pub thread_instructions: u64,
    /// Divergent branches (mixed per-lane outcome -> DIV push).
    pub divergences: u64,
    /// Warp-stack high-water mark across all warps.
    pub max_stack_depth: u32,
    /// Global-memory row transactions (loads, stores).
    pub global_load_txns: u64,
    pub global_store_txns: u64,
    /// Shared-memory row transactions.
    pub shared_load_txns: u64,
    pub shared_store_txns: u64,
    /// Barrier releases.
    pub barriers: u64,
    /// Thread blocks retired by this SM.
    pub blocks: u64,
    /// Cycles the issue port idled waiting on memory/pipeline.
    pub stall_cycles: u64,
    /// Warp-instructions issued down the vectorized batch path (all
    /// existing lanes active, guard-free — see `EngineMode::Vector`).
    /// Always zero on the scalar engine; excluded from cross-engine
    /// bit-identity comparisons for exactly that reason.
    pub batched_uops: u64,
    /// Dynamic opcode histogram (indexed by `Op as u8`).
    pub op_histogram: [u64; 32],
    /// Memory-hierarchy counters (zero on flat memory).
    pub mem: MemStats,
    /// Protected-upset counters (zero without an ECC/scrub plan).
    pub fault: FaultStats,
    /// Checkpoint restarts taken after uncorrectable faults (zero
    /// without a checkpoint policy).
    pub restarts: u64,
    /// Cycles re-executed because of checkpoint restarts (progress
    /// between the restored checkpoint and the fault, paid twice).
    pub replayed_cycles: u64,
}

impl SmStats {
    #[inline]
    pub fn count_op(&mut self, op: Op, active_lanes: u32) {
        self.instructions += 1;
        self.thread_instructions += active_lanes as u64;
        self.op_histogram[op as usize] += 1;
    }

    /// Merge another SM's stats (for whole-GPGPU aggregates; `cycles`
    /// takes the max — SMs run concurrently in hardware).
    pub fn merge(&mut self, other: &SmStats) {
        self.cycles = self.cycles.max(other.cycles);
        self.instructions += other.instructions;
        self.thread_instructions += other.thread_instructions;
        self.divergences += other.divergences;
        self.max_stack_depth = self.max_stack_depth.max(other.max_stack_depth);
        self.global_load_txns += other.global_load_txns;
        self.global_store_txns += other.global_store_txns;
        self.shared_load_txns += other.shared_load_txns;
        self.shared_store_txns += other.shared_store_txns;
        self.barriers += other.barriers;
        self.blocks += other.blocks;
        self.stall_cycles += other.stall_cycles;
        self.batched_uops += other.batched_uops;
        for (mine, theirs) in self.op_histogram.iter_mut().zip(&other.op_histogram) {
            *mine += theirs;
        }
        self.mem.merge(&other.mem);
        self.fault.merge(&other.fault);
        self.restarts += other.restarts;
        self.replayed_cycles += other.replayed_cycles;
    }

    /// Dynamic count of multiplier-consuming instructions (IMUL/IMAD) —
    /// drives the §4.2 multiplier-removal decision.
    pub fn multiplier_ops(&self) -> u64 {
        Op::ALL
            .iter()
            .filter(|o| o.uses_multiplier())
            .map(|o| self.op_histogram[*o as usize])
            .sum()
    }

    /// Execution time in milliseconds at the overlay clock.
    pub fn exec_time_ms(&self, clock_hz: f64) -> f64 {
        self.cycles as f64 / clock_hz * 1e3
    }

    /// Mean fraction of the 32 warp lanes active per issued instruction,
    /// in [0, 1] — the SIMD-efficiency number the lane-vectorized engine
    /// is gated on (1.0 = every issue ran a full warp). 0 when nothing
    /// was issued.
    pub fn lane_occupancy(&self) -> f64 {
        if self.instructions == 0 {
            return 0.0;
        }
        self.thread_instructions as f64
            / (self.instructions as f64 * crate::sim::WARP_SIZE as f64)
    }

    /// Percentage of warp-instructions that issued down the vectorized
    /// batch path (0 on the scalar engine).
    pub fn batched_uop_pct(&self) -> f64 {
        if self.instructions == 0 {
            return 0.0;
        }
        100.0 * self.batched_uops as f64 / self.instructions as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_takes_max_cycles_sums_counts() {
        let mut a = SmStats { cycles: 100, instructions: 10, ..Default::default() };
        let b = SmStats { cycles: 80, instructions: 7, blocks: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.cycles, 100);
        assert_eq!(a.instructions, 17);
        assert_eq!(a.blocks, 2);
    }

    #[test]
    fn multiplier_counting() {
        let mut s = SmStats::default();
        s.count_op(Op::Imul, 32);
        s.count_op(Op::Imad, 32);
        s.count_op(Op::Iadd, 32);
        assert_eq!(s.multiplier_ops(), 2);
        assert_eq!(s.thread_instructions, 96);
    }

    #[test]
    fn mem_stats_sum_under_merge_and_report_hit_rate() {
        let mut a = SmStats {
            mem: MemStats { hits: 3, misses: 1, fill_stall_cycles: 40, ..Default::default() },
            ..Default::default()
        };
        let b = SmStats {
            mem: MemStats { hits: 1, misses: 1, contention_cycles: 9, ..Default::default() },
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.mem.hits, 4);
        assert_eq!(a.mem.misses, 2);
        assert_eq!(a.mem.fill_stall_cycles, 40);
        assert_eq!(a.mem.contention_cycles, 9);
        assert!((a.mem.hit_rate() - 4.0 / 6.0).abs() < 1e-12);
        assert_eq!(MemStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn lane_occupancy_and_batch_pct() {
        let s = SmStats {
            instructions: 10,
            thread_instructions: 10 * 32,
            batched_uops: 7,
            ..Default::default()
        };
        assert!((s.lane_occupancy() - 1.0).abs() < 1e-12);
        assert!((s.batched_uop_pct() - 70.0).abs() < 1e-12);
        let half = SmStats { instructions: 4, thread_instructions: 64, ..Default::default() };
        assert!((half.lane_occupancy() - 0.5).abs() < 1e-12);
        assert_eq!(half.batched_uop_pct(), 0.0);
        assert_eq!(SmStats::default().lane_occupancy(), 0.0);
        assert_eq!(SmStats::default().batched_uop_pct(), 0.0);
    }

    #[test]
    fn batched_uops_sum_under_merge() {
        let mut a = SmStats { batched_uops: 3, ..Default::default() };
        a.merge(&SmStats { batched_uops: 4, ..Default::default() });
        assert_eq!(a.batched_uops, 7);
    }

    #[test]
    fn fault_and_restart_counters_sum_under_merge() {
        let mut a = SmStats {
            fault: FaultStats { detected: 2, corrected: 1, ..Default::default() },
            restarts: 1,
            replayed_cycles: 100,
            ..Default::default()
        };
        let b = SmStats {
            fault: FaultStats { detected: 1, uncorrectable: 1, scrubbed: 3, ..Default::default() },
            restarts: 2,
            replayed_cycles: 50,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.fault.detected, 3);
        assert_eq!(a.fault.corrected, 1);
        assert_eq!(a.fault.uncorrectable, 1);
        assert_eq!(a.fault.scrubbed, 3);
        assert_eq!(a.restarts, 3);
        assert_eq!(a.replayed_cycles, 150);
        assert!(a.fault.any());
        assert!(!FaultStats::default().any());
    }

    #[test]
    fn exec_time_at_100mhz() {
        let s = SmStats { cycles: 1_000_000, ..Default::default() };
        assert!((s.exec_time_ms(100e6) - 10.0).abs() < 1e-9);
    }
}
