//! Warp state: "Each warp includes a program counter (PC), a thread mask,
//! and state. Each warp maintains its own PC and can follow its own
//! conditional path." (paper §3.2)

use super::stack::WarpStack;

/// Scheduling status of a warp, as the warp unit sees it.
///
/// The issue loop itself no longer re-derives this per issue — the
/// event-driven [`super::WarpScheduler`] tracks readiness incrementally
/// (ready bitmask + wake heap) — but the classification below is still
/// the architectural model: [`Warp::status`] is the reference predicate
/// the scheduler's behaviour is defined (and tested) against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WarpStatus {
    /// Eligible for issue.
    Ready,
    /// Waiting for a memory transaction / pipeline hazard to clear.
    Waiting,
    /// Parked at a block barrier.
    AtBarrier,
    /// All threads finished.
    Done,
}

/// One warp of 32 threads.
#[derive(Debug, Clone)]
pub struct Warp {
    /// Warp index within its block.
    pub id: u32,
    pub pc: u32,
    /// Threads that exist (a block whose size is not a multiple of 32 has
    /// a partial last warp).
    pub enabled: u32,
    /// Current SIMT active mask (manipulated by the divergence stack).
    pub active: u32,
    /// Threads that executed `EXIT` ("Finished" in the paper's Fig. 2
    /// thread mask).
    pub finished: u32,
    pub at_barrier: bool,
    /// Earliest cycle at which this warp may issue again.
    pub ready_at: u64,
    pub done: bool,
    pub stack: WarpStack,
}

impl Warp {
    pub fn new(id: u32, enabled: u32, stack_depth: u32) -> Warp {
        Warp {
            id,
            pc: 0,
            enabled,
            active: enabled,
            finished: 0,
            at_barrier: false,
            ready_at: 0,
            done: false,
            stack: WarpStack::new(stack_depth),
        }
    }

    /// The lanes that would execute an unguarded instruction now —
    /// the paper's "active-thread mask" (Fig. 2): active, not finished,
    /// existing.
    #[inline]
    pub fn effective(&self) -> u32 {
        self.active & !self.finished & self.enabled
    }

    pub fn status(&self, now: u64) -> WarpStatus {
        if self.done {
            WarpStatus::Done
        } else if self.at_barrier {
            WarpStatus::AtBarrier
        } else if self.ready_at > now {
            WarpStatus::Waiting
        } else {
            WarpStatus::Ready
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_mask_excludes_finished() {
        let mut w = Warp::new(0, 0xffff_ffff, 32);
        w.finished = 0x0000_00ff;
        assert_eq!(w.effective(), 0xffff_ff00);
        w.active = 0x0000_ffff;
        assert_eq!(w.effective(), 0x0000_ff00);
    }

    #[test]
    fn partial_warp_enabled_mask() {
        // 40-thread block -> warp 1 has 8 threads.
        let w = Warp::new(1, 0xff, 32);
        assert_eq!(w.effective(), 0xff);
    }

    #[test]
    fn status_transitions() {
        let mut w = Warp::new(0, 1, 32);
        assert_eq!(w.status(0), WarpStatus::Ready);
        w.ready_at = 10;
        assert_eq!(w.status(5), WarpStatus::Waiting);
        assert_eq!(w.status(10), WarpStatus::Ready);
        w.at_barrier = true;
        assert_eq!(w.status(10), WarpStatus::AtBarrier);
        w.done = true;
        assert_eq!(w.status(10), WarpStatus::Done);
    }
}
