//! The streaming multiprocessor: warp unit + 5-stage pipeline + control
//! flow unit (paper Fig. 1).
//!
//! Execution is functionally atomic per issued warp-instruction; timing
//! follows the paper's microarchitecture:
//!
//! * one warp **row** (`32 / num_sp` threads) enters the pipeline per
//!   cycle, so issuing one warp-instruction occupies the issue port for
//!   `rows` cycles;
//! * the same warp cannot issue again until its previous instruction
//!   clears the 5-stage pipeline (no forwarding) — round-robin across
//!   ready warps hides this, exactly the warp unit's job (§3.2);
//! * memory instructions park the warp for the AXI/BRAM latency while
//!   other warps keep issuing (latency hiding);
//! * `BAR` parks warps until every live warp of the block arrives.
//!
//! # The warp-wide hot path
//!
//! Three structural decisions keep the issue loop allocation-free and
//! branch-light (EXPERIMENTS.md §Perf):
//!
//! * [`Sm::run`]/`step` are **monomorphized** over `G: GmemPort` and
//!   `A: AluBackend` — trait objects exist only at the `gpgpu::launch`
//!   boundary, so per-lane loads/stores and the warp-ALU call inline
//!   instead of virtual-dispatching;
//! * issue selection is **event-driven** ([`super::WarpScheduler`]): a
//!   ready bitmask picked with one masked `trailing_zeros` plus a min-heap
//!   of wake times, replacing the seed engine's O(total-warps) status
//!   re-scan per issued instruction;
//! * the Decode stage runs **once per launch**: [`PreDecoded`] lowers
//!   every instruction to a micro-op ([`Uop`]) with operand kinds, guard,
//!   branch targets and fault flags pre-resolved, so `step` never
//!   re-matches `Operand`/`SpecialReg` per issue;
//! * the execute stage is **lane-vectorized** by default
//!   ([`super::EngineMode::Vector`]): pre-decode tags guard-free datapath
//!   micro-ops as batchable, and whenever the warp's lanes are all live
//!   such an op issues as one whole-warp `[i32; 32]` batch — contiguous
//!   SoA register-file slices in ([`super::RegFile`]), branch-free lane
//!   loops, `memcpy` writeback — with the masked per-lane loop retained
//!   as the divergent/guarded fallback and as the scalar differential
//!   oracle (`tests/simd_engine.rs` pins bit- and cycle-identity).

use super::alu::{AluBackend, AluFunc, WarpAluIn, WARP_SIZE};
use super::fault::{
    upset_outcome, FaultEvent, FaultPlan, FaultSite, FaultState, FaultTarget, ProtectionConfig,
    UpsetKind, UpsetOutcome,
};
use super::mem::{GmemPort, SharedMem, PARAM_SEG_BYTES};
use super::metrics::SmStats;
use super::regfile::RegFile;
use super::sched::{WarpScheduler, MAX_RESIDENT_WARPS};
use super::stack::{EntryType, StackEntry};
use super::warp::Warp;
use super::{EngineMode, SimError, SmConfig};
use crate::asm::Kernel;
use crate::isa::{Capability, Cond, Guard, Instr, Op, Operand, SpecialReg};

/// A vector-fetch source for the Read stage, resolved at pre-decode:
/// either a strided register-file gather or an immediate splat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum VecSrc {
    Reg(u8),
    Splat(i32),
}

/// Third-operand source (MAD addend / SEL selector).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CSrc {
    Reg(u8),
    /// SEL: selector lanes come from the predicate file (`setp_idx`,
    /// `cond` of the owning [`AluUop`]).
    Pred,
    Zero,
}

/// Memory-address base register kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MemBase {
    Reg(u8),
    AReg(u8),
}

/// Pre-resolved datapath instruction (everything the Read/Execute/Write
/// stages need, with operand dispatch done once per launch).
#[derive(Debug, Clone, Copy)]
struct AluUop {
    func: AluFunc,
    cond: Cond,
    a: VecSrc,
    b: VecSrc,
    c: CSrc,
    dst: u8,
    setp_idx: u8,
    /// `func == Setp`: write the predicate file instead of the GP file.
    setp_wb: bool,
}

/// Pre-resolved memory instruction.
#[derive(Debug, Clone, Copy)]
struct MemUop {
    global: bool,
    load: bool,
    base: MemBase,
    /// Byte offset, widened from the encoded i16 once.
    offset: i32,
    /// Load destination / store data register.
    reg: u8,
}

/// Micro-op kind: one variant per issue-loop dispatch arm.
#[derive(Debug, Clone, Copy)]
enum UopKind {
    Nop,
    Exit,
    Join,
    Bar,
    Ssy { target: u32 },
    Bra { target: u32 },
    S2r { sr: SpecialReg, dst: u8 },
    R2a { src: u8, dst: u8 },
    A2r { src: u8, dst: u8 },
    Mem(MemUop),
    Alu(AluUop),
}

/// One pre-decoded micro-op (see [`PreDecoded`]).
#[derive(Debug, Clone, Copy)]
struct Uop {
    kind: UopKind,
    /// Original opcode, kept for the dynamic histogram.
    op: Op,
    guard: Guard,
    /// `guard` is conditional (pre-tested so the common unguarded path is
    /// a single branch).
    guarded: bool,
    /// Uniform-op detector (resolved at pre-decode): guard-free datapath
    /// micro-op eligible for whole-warp batch issue on the vector engine
    /// whenever the warp's lanes are all live at issue time. Control
    /// flow, barriers and the address-register moves stay scalar — they
    /// carry no vectorizable data movement.
    batchable: bool,
    /// §4.2 customization faults, resolved to flags at pre-decode.
    needs_mul: bool,
    needs_3ops: bool,
    /// Fall-through PC (`pc + size`), precomputed.
    next_pc: u32,
}

impl Uop {
    fn from_instr(pc: u32, instr: &Instr) -> Uop {
        let kind = match instr.op {
            Op::Nop => UopKind::Nop,
            Op::Exit => UopKind::Exit,
            Op::Join => UopKind::Join,
            Op::Bar => UopKind::Bar,
            Op::Ssy => UopKind::Ssy { target: instr.branch_target().expect("SSY target") },
            Op::Bra => UopKind::Bra { target: instr.branch_target().expect("BRA target") },
            Op::S2r => match instr.src1 {
                Operand::Special(sr) => UopKind::S2r { sr, dst: instr.dst },
                _ => unreachable!("decoder guarantees S2R source"),
            },
            Op::R2a => match instr.src1 {
                Operand::Reg(r) => UopKind::R2a { src: r, dst: instr.dst },
                _ => unreachable!("decoder guarantees R2A source"),
            },
            Op::A2r => match instr.src1 {
                Operand::AReg(a) => UopKind::A2r { src: a, dst: instr.dst },
                _ => unreachable!("decoder guarantees A2R source"),
            },
            Op::Gld | Op::Sld | Op::Gst | Op::Sst => {
                let base = match instr.src1 {
                    Operand::Reg(r) => MemBase::Reg(r),
                    Operand::AReg(a) => MemBase::AReg(a),
                    _ => unreachable!("memory base is a register"),
                };
                let load = matches!(instr.op, Op::Gld | Op::Sld);
                let reg = if load {
                    instr.dst
                } else {
                    match instr.src2 {
                        Operand::Reg(r) => r,
                        _ => unreachable!("stores carry a register source"),
                    }
                };
                UopKind::Mem(MemUop {
                    global: matches!(instr.op, Op::Gld | Op::Gst),
                    load,
                    base,
                    offset: instr.offset as i32,
                    reg,
                })
            }
            _ => {
                let func = AluFunc::from_op(instr.op).expect("non-ALU ops handled above");
                let a = match instr.src1 {
                    Operand::Reg(r) => VecSrc::Reg(r),
                    // MOV #imm carries its immediate in src2 (splat to both
                    // source lanes, exactly the seed engine's fill).
                    Operand::None => match instr.src2 {
                        Operand::Imm(v) => VecSrc::Splat(v),
                        _ => VecSrc::Splat(0),
                    },
                    _ => VecSrc::Splat(0),
                };
                let b = match instr.src2 {
                    Operand::Reg(r) => VecSrc::Reg(r),
                    Operand::Imm(v) => VecSrc::Splat(v),
                    _ => VecSrc::Splat(0),
                };
                let c = if func == AluFunc::Sel {
                    CSrc::Pred
                } else {
                    match instr.src3 {
                        Operand::Reg(r) => CSrc::Reg(r),
                        _ => CSrc::Zero,
                    }
                };
                UopKind::Alu(AluUop {
                    func,
                    cond: instr.cond,
                    a,
                    b,
                    c,
                    dst: instr.dst,
                    setp_idx: instr.setp_idx,
                    setp_wb: func == AluFunc::Setp,
                })
            }
        };
        let guarded = !instr.guard.is_unconditional();
        let batchable = !guarded
            && matches!(kind, UopKind::Alu(_) | UopKind::Mem(_) | UopKind::S2r { .. });
        Uop {
            kind,
            op: instr.op,
            guard: instr.guard,
            guarded,
            batchable,
            needs_mul: instr.op.uses_multiplier(),
            needs_3ops: instr.op == Op::Imad,
            next_pc: pc + instr.size as u32,
        }
    }
}

/// Pre-decoded kernel image: the Decode stage run once per launch,
/// lowering every [`Instr`] to a dense micro-op. The issue loop then
/// indexes a flat table and never re-matches operand kinds — the single
/// biggest simulator speedup alongside monomorphization (see
/// EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct PreDecoded {
    /// Indexed by `pc / 4`; instructions are 4-byte aligned.
    by_pc: Vec<Option<Uop>>,
}

impl PreDecoded {
    pub fn from_kernel(k: &Kernel) -> PreDecoded {
        let words = k.code.len().div_ceil(4);
        let mut by_pc = vec![None; words];
        for (pc, instr) in &k.instrs {
            by_pc[(pc / 4) as usize] = Some(Uop::from_instr(*pc, instr));
        }
        PreDecoded { by_pc }
    }

    #[inline]
    fn fetch(&self, warp: u32, pc: u32) -> Result<&Uop, SimError> {
        match self.by_pc.get((pc / 4) as usize) {
            Some(Some(uop)) => Ok(uop),
            _ => Err(SimError::RanOffCode { warp, pc }),
        }
    }
}

/// One thread block as handed to an SM by the block scheduler.
#[derive(Debug, Clone, Copy)]
pub struct BlockDesc {
    pub ctaid_x: u32,
    pub ctaid_y: u32,
    pub nctaid_x: u32,
    pub nctaid_y: u32,
    /// Threads in this block (<= 256, paper §4.3).
    pub ntid: u32,
}

/// Everything one [`Sm::run`] call needs besides the device ports: the
/// pre-decoded kernel, its resource footprint, the launch parameters and
/// the blocks the block scheduler assigned to this SM.
#[derive(Debug, Clone, Copy)]
pub struct SmLaunch<'a> {
    pub pre: &'a PreDecoded,
    pub regs_per_thread: u32,
    pub smem_bytes: u32,
    pub params: &'a [i32],
    pub blocks: &'a [BlockDesc],
    /// Blocks resident at once (the Table 1 limit computed by the block
    /// scheduler).
    pub max_resident: usize,
    /// SEU injection campaign (`sim::fault`), or `None` for the fault-free
    /// engine. A disabled plan builds no per-SM state, so the only cost is
    /// one `Option` branch per issued instruction.
    pub fault: Option<&'a FaultPlan>,
    /// Barrier checkpoint/restart policy, or `None` (the default) for
    /// fail-on-fault. With a policy set, the SM snapshots live state at
    /// launch start and at every block-wide barrier reconvergence; an
    /// uncorrectable fault then restores the latest snapshot instead of
    /// failing the launch (`SmStats::{restarts, replayed_cycles}`).
    pub checkpoint: Option<CheckpointPolicy>,
}

/// When the SM may checkpoint and how many correct-and-continue restarts
/// an uncorrectable fault is allowed before it fails the launch anyway.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CheckpointPolicy {
    pub max_restarts: u32,
}

impl CheckpointPolicy {
    /// Checkpoint at block-wide barrier reconvergence (plus an implicit
    /// launch-start checkpoint), allowing up to 8 restarts.
    pub fn at_barriers() -> CheckpointPolicy {
        CheckpointPolicy { max_restarts: 8 }
    }

    pub fn with_max_restarts(mut self, max_restarts: u32) -> CheckpointPolicy {
        self.max_restarts = max_restarts;
        self
    }
}

impl Default for CheckpointPolicy {
    fn default() -> CheckpointPolicy {
        CheckpointPolicy::at_barriers()
    }
}

/// Per-issue execution context threaded into [`Sm::step`]: the decoded
/// kernel image plus the mutable device ports and counters.
struct ExecCtx<'a, G: GmemPort + ?Sized, A: AluBackend + ?Sized> {
    kernel: &'a PreDecoded,
    gmem: &'a mut G,
    alu: &'a mut A,
    stats: &'a mut SmStats,
}

/// A resident (scheduled) block: its register file partition, shared
/// memory allocation, and warps. `Clone` is the checkpoint snapshot:
/// register file, shared memory, and warp/stack state are all plain
/// value types.
#[derive(Clone)]
struct Resident {
    desc: BlockDesc,
    regs: RegFile,
    shared: SharedMem,
    warps: Vec<Warp>,
}

/// A barrier (or launch-start) checkpoint: everything `Sm::run` needs to
/// re-enter its main loop at a clean reconvergence boundary. Global
/// memory is *not* snapshotted: execution up to the checkpoint is
/// deterministic and uncorrupted (uncorrectable faults abort before
/// mutating state), so replay re-issues byte-identical stores.
struct Checkpoint {
    cycle: u64,
    next_block: usize,
    resident: Vec<Resident>,
    sched: WarpScheduler,
}

/// An aged stuck-at site in one of the silent-corruption classes: the
/// defective cell re-corrupts `word` on every subsequent access (modeled
/// at issue granularity for the owning slot) until a scrub pass repairs
/// it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct AgedSite {
    target: FaultTarget,
    slot: usize,
    word: u32,
    bit: u32,
}

impl Resident {
    fn all_done(&self) -> bool {
        self.warps.iter().all(|w| w.done)
    }
}

/// Map a scheduler flat index to `(slot, warp)` over the resident blocks
/// (flat order = slot order; at most 8 slots, so the walk is trivial).
#[inline]
fn locate(resident: &[Resident], flat: u32) -> (usize, usize) {
    let mut f = flat as usize;
    for (s, r) in resident.iter().enumerate() {
        if f < r.warps.len() {
            return (s, f);
        }
        f -= r.warps.len();
    }
    unreachable!("scheduler flat index {flat} out of range");
}

/// A streaming multiprocessor.
#[derive(Debug, Clone)]
pub struct Sm {
    pub cfg: SmConfig,
    pub sm_id: u32,
}

impl Sm {
    pub fn new(cfg: SmConfig, sm_id: u32) -> Sm {
        Sm { cfg, sm_id }
    }

    /// Execute `blocks` to completion, keeping at most `max_resident`
    /// blocks scheduled at once (the Table 1 limit computed by the block
    /// scheduler). Returns per-SM statistics; `stats.cycles` is this SM's
    /// busy time.
    ///
    /// `gmem` is any [`GmemPort`]: the shared [`super::GlobalMem`] on the
    /// sequential path, this SM's private copy-on-write
    /// [`super::GmemSnapshot`] on the parallel path, or either wrapped in
    /// [`super::CachedGmem`] when an L1 is configured. Both `gmem` and
    /// `alu` are generic (`?Sized`, so `&mut dyn` still works) — concrete
    /// callers get a fully monomorphized, inlined lane loop.
    pub fn run<G: GmemPort + ?Sized, A: AluBackend + ?Sized>(
        &self,
        launch: &SmLaunch<'_>,
        gmem: &mut G,
        alu: &mut A,
    ) -> Result<SmStats, SimError> {
        self.cfg.validate()?;
        let SmLaunch {
            pre: kernel,
            regs_per_thread,
            smem_bytes,
            params,
            blocks,
            max_resident,
            fault,
            checkpoint,
        } = *launch;
        assert!(max_resident >= 1, "block scheduler must allow one resident block");
        // SEU schedule: seeded from (plan.seed, sm_id) and advanced by this
        // SM's own cycle stream, which is identical on the sequential and
        // parallel launch paths — so fault sites are path-independent.
        let mut seu = fault.and_then(|p| FaultState::new(p, self.sm_id));
        // Protection session state (all inert without an enabled plan):
        // the per-class scheme, the aged stuck-at sites, and the scrub
        // clock.
        let protect: ProtectionConfig = fault.map(|p| p.protect).unwrap_or_default();
        let mut aged: Vec<AgedSite> = Vec::new();
        let scrub = if seu.is_some() { protect.scrubber } else { None };
        let mut next_scrub = scrub.map(|s| s.interval_cycles.max(1)).unwrap_or(u64::MAX);
        // Checkpoint/restart session state: the launch-start snapshot is
        // implicit (empty resident set, block cursor 0 — restoring it
        // re-deals every block), refreshed at each block-wide barrier
        // reconvergence.
        let mut ckpt: Option<Checkpoint> = checkpoint.map(|_| Checkpoint {
            cycle: 0,
            next_block: 0,
            resident: Vec::new(),
            sched: WarpScheduler::new(),
        });
        let mut restarts_left = checkpoint.map(|p| p.max_restarts).unwrap_or(0);

        let mut stats = SmStats::default();
        let mut cycle: u64 = 0;
        let rows = self.cfg.rows_per_warp() as u64;
        let mut next_block = 0usize;
        let mut resident: Vec<Resident> = Vec::with_capacity(max_resident);
        let mut sched = WarpScheduler::new();

        loop {
            // Block scheduler interface: fill free slots (§4.3 — "control
            // signals from the SM notify the block scheduler when all
            // thread blocks have completed and scheduling ... can begin").
            // New blocks append at the end of the flat warp order, so
            // existing scheduler indices stay valid.
            while resident.len() < max_resident && next_block < blocks.len() {
                let r = self.make_resident(
                    blocks[next_block],
                    regs_per_thread,
                    smem_bytes,
                    params,
                )?;
                let new_warps = r.warps.len() as u32;
                // Unreachable under the block scheduler's Table 1 limits
                // (<= 64 resident warps); direct callers with custom
                // limits get a structured fault, not a panic.
                if sched.len() + new_warps > MAX_RESIDENT_WARPS {
                    return Err(SimError::LimitExceeded(format!(
                        "{} resident warps exceed the scheduler cap of {}",
                        sched.len() + new_warps,
                        MAX_RESIDENT_WARPS
                    )));
                }
                sched.extend_ready(new_warps);
                resident.push(r);
                next_block += 1;
            }
            if resident.is_empty() {
                break;
            }

            // Warp unit: event-driven round-robin. Wakes whose time
            // arrived join the ready set; the pick is one bit-scan.
            sched.drain_wakes(cycle);
            match sched.pick() {
                Some(flat) => {
                    let (s, w) = locate(&resident, flat);
                    let slot_base = flat - w as u32;
                    cycle += rows;
                    // Background scrubber: every interval it repairs up to
                    // words_per_pass aged stuck-at sites, oldest first.
                    if let Some(scr) = scrub {
                        while cycle >= next_scrub {
                            let n = (scr.words_per_pass as usize).min(aged.len());
                            if n > 0 {
                                aged.drain(..n);
                                stats.fault.scrubbed += n as u64;
                            }
                            next_scrub += scr.interval_cycles.max(1);
                        }
                    }
                    // Fault aging: unscrubbed stuck-at sites in the issuing
                    // slot re-corrupt on every access (modeled at issue
                    // granularity) — silent bit-sets under parity, a
                    // per-access correction cost under ECC.
                    if !aged.is_empty() {
                        for a in &aged {
                            if a.slot != s {
                                continue;
                            }
                            match upset_outcome(protect.for_target(a.target), a.target, false) {
                                UpsetOutcome::SilentFlip => {
                                    let r = &mut resident[s];
                                    match a.target {
                                        FaultTarget::RegisterFile => {
                                            r.regs.seu_set(a.word, a.bit);
                                        }
                                        _ => {
                                            r.shared.seu_set(a.word, a.bit);
                                        }
                                    }
                                }
                                UpsetOutcome::Corrected { cycles } => {
                                    cycle += cycles;
                                    stats.fault.detected += 1;
                                    stats.fault.corrected += 1;
                                }
                                _ => {}
                            }
                        }
                    }
                    // SEU injection point: upsets land between issues, at
                    // the cycle the issue port advanced to. Detected upsets
                    // abort the launch (parity) or restore the latest
                    // checkpoint (uncorrectable under a checkpoint policy);
                    // ECC-corrected upsets cost cycles; silent data upsets
                    // mutate state and execution continues.
                    if let Some(st) = seu.as_mut() {
                        if let Some(ev) = st.poll(cycle) {
                            let pc = resident[s].warps[w].pc;
                            match self.apply_seu(
                                ev,
                                cycle,
                                pc,
                                &mut resident,
                                &*gmem,
                                &protect,
                                &mut aged,
                                &mut stats,
                            ) {
                                Ok(extra) => cycle += extra,
                                Err(e) => {
                                    let Some(restore) = ckpt.as_ref().filter(|_| restarts_left > 0)
                                    else {
                                        return Err(e);
                                    };
                                    // Correct-and-continue: roll architectural
                                    // state back to the last clean barrier
                                    // boundary and re-execute. The wall clock
                                    // keeps advancing — the progress between
                                    // checkpoint and fault is paid twice.
                                    restarts_left -= 1;
                                    stats.restarts += 1;
                                    stats.replayed_cycles += cycle - restore.cycle;
                                    resident = restore.resident.clone();
                                    sched = restore.sched.clone();
                                    next_block = restore.next_block;
                                    continue;
                                }
                            }
                        }
                    }
                    // Memory instructions drain through the single AXI
                    // master / BRAM port and block the pipeline (Fig. 3);
                    // `step` returns those extra cycles. Cache line fills
                    // instead park the warp (its `ready_at` moves out) so
                    // other ready warps keep issuing underneath the miss.
                    let mut cx =
                        ExecCtx { kernel, gmem: &mut *gmem, alu: &mut *alu, stats: &mut stats };
                    cycle += self.step(&mut resident[s], w, &mut cx, cycle)?;
                    {
                        let wp = &resident[s].warps[w];
                        if !wp.done && !wp.at_barrier {
                            sched.park(flat, wp.ready_at);
                        }
                    }
                    // Barrier release: all live warps of the block arrived?
                    let mut reconverged = false;
                    let r = &mut resident[s];
                    if r.warps.iter().any(|x| x.at_barrier)
                        && r.warps.iter().all(|x| x.done || x.at_barrier)
                    {
                        for (i, x) in r.warps.iter_mut().enumerate() {
                            if x.at_barrier {
                                x.at_barrier = false;
                                if !x.done {
                                    // Released warps whose pipeline hazard
                                    // already drained are ready now; the
                                    // rest wait out their hazard.
                                    if x.ready_at > cycle {
                                        sched.park(slot_base + i as u32, x.ready_at);
                                    } else {
                                        sched.make_ready(slot_base + i as u32);
                                    }
                                }
                            }
                        }
                        stats.barriers += 1;
                        reconverged = true;
                    }
                    // Retire the issued block if it just completed (only
                    // the block that issued can change state). Ordered
                    // removal keeps the surviving flat order intact so the
                    // round-robin pointer can be rebased, not reset.
                    if r.warps[w].done && r.all_done() {
                        for x in &r.warps {
                            stats.max_stack_depth =
                                stats.max_stack_depth.max(x.stack.max_depth());
                        }
                        let retired = r.warps.len() as u32;
                        resident.remove(s);
                        sched.retire_range(slot_base, retired);
                        stats.blocks += 1;
                        // Aged (stuck-at) sites live in the retiring block's
                        // BRAM allocation: drop them, and rebase the slot
                        // indices the ordered removal just shifted.
                        if !aged.is_empty() {
                            aged.retain(|a| a.slot != s);
                            for a in aged.iter_mut() {
                                if a.slot > s {
                                    a.slot -= 1;
                                }
                            }
                        }
                    }
                    // Block-wide reconvergence is the checkpoint boundary:
                    // every live warp just synchronized, so the snapshot is
                    // a consistent cut of architectural state. Global memory
                    // is deliberately not captured — replay from here
                    // re-issues byte-identical stores (deterministic
                    // engine), and uncorrectable faults abort before
                    // corrupting state.
                    if reconverged {
                        if let Some(c) = ckpt.as_mut() {
                            *c = Checkpoint {
                                cycle,
                                next_block,
                                resident: resident.clone(),
                                sched: sched.clone(),
                            };
                        }
                    }
                }
                None => {
                    // No warp ready: advance to the earliest wake-up.
                    match sched.next_wake() {
                        Some(t) => {
                            stats.stall_cycles += t - cycle;
                            cycle = t;
                        }
                        None => {
                            // Everything is Done or AtBarrier, yet the block
                            // didn't retire and the barrier didn't release.
                            let block = resident
                                .iter()
                                .position(|r| !r.all_done())
                                .unwrap_or(0);
                            return Err(SimError::BarrierDeadlock { block: block as u32 });
                        }
                    }
                }
            }

            if cycle > self.cfg.watchdog_cycles {
                return Err(SimError::Watchdog { cycles: cycle });
            }
        }

        stats.cycles = cycle;
        // Snapshot the memory-hierarchy counters accumulated by the gmem
        // port (all-zero on flat memory, populated by `CachedGmem`).
        stats.mem = gmem.mem_stats();
        Ok(stats)
    }

    /// Land one scheduled upset ([`FaultEvent`]) according to the BRAM
    /// class's [`Protection`](super::fault::Protection) mode. Under
    /// parity (the default) behavior is unchanged from the original
    /// injector: register-file and shared-memory upsets mutate state
    /// silently (no parity on those BRAMs); tag-array and
    /// instruction-image upsets are parity-detected and abort the launch
    /// with [`SimError::SoftError`]. Under ECC a fresh single-bit upset
    /// is corrected in place (no state flip) at a modeled cycle cost —
    /// the returned `Ok(extra)` — while a second upset at an already
    /// aged word exceeds SECDED's correction capability and aborts.
    /// Stuck-at upsets on the silent-corruption classes additionally
    /// register an [`AgedSite`] that re-corrupts on later issues until
    /// scrubbed. A tag upset on a tagless (flat) memory port lands in
    /// unused fabric and is a no-op.
    #[allow(clippy::too_many_arguments)]
    fn apply_seu<G: GmemPort + ?Sized>(
        &self,
        ev: FaultEvent,
        cycle: u64,
        pc: u32,
        resident: &mut [Resident],
        gmem: &G,
        protect: &ProtectionConfig,
        aged: &mut Vec<AgedSite>,
        stats: &mut SmStats,
    ) -> Result<u64, SimError> {
        let n_slots = resident.len() as u64;
        let mode = protect.for_target(ev.target);
        match ev.target {
            FaultTarget::RegisterFile | FaultTarget::SharedMem => {
                let slot = (ev.sel % n_slots) as usize;
                let word_sel = ev.sel / n_slots;
                let is_rf = ev.target == FaultTarget::RegisterFile;
                let words = if is_rf {
                    resident[slot].regs.seu_words()
                } else {
                    resident[slot].shared.seu_words()
                };
                if words == 0 {
                    return Ok(0);
                }
                let word = (word_sel % words as u64) as u32;
                let aged_hit = aged
                    .iter()
                    .any(|a| a.target == ev.target && a.slot == slot && a.word == word);
                let outcome = upset_outcome(mode, ev.target, aged_hit);
                // Stuck-at upsets leave a defective cell behind whenever the
                // word survives (corrected or silently flipped).
                let mut age = |aged: &mut Vec<AgedSite>| {
                    if ev.kind == UpsetKind::StuckAt && !aged_hit {
                        aged.push(AgedSite {
                            target: ev.target,
                            slot,
                            word,
                            bit: ev.bit % 32,
                        });
                    }
                };
                match outcome {
                    UpsetOutcome::SilentFlip => {
                        if is_rf {
                            resident[slot].regs.seu_flip(word_sel, ev.bit);
                        } else {
                            resident[slot].shared.seu_flip(word_sel, ev.bit);
                        }
                        age(aged);
                        Ok(0)
                    }
                    UpsetOutcome::Corrected { cycles } => {
                        stats.fault.detected += 1;
                        stats.fault.corrected += 1;
                        age(aged);
                        Ok(cycles)
                    }
                    UpsetOutcome::Uncorrectable => {
                        stats.fault.detected += 1;
                        stats.fault.uncorrectable += 1;
                        let site = if is_rf {
                            FaultSite::Register { sm: self.sm_id, slot: slot as u32, word }
                        } else {
                            FaultSite::Shared { sm: self.sm_id, slot: slot as u32, word }
                        };
                        Err(SimError::SoftError { site, cycle, bit: ev.bit })
                    }
                    // Silent classes never report plain parity detection:
                    // `upset_outcome` only yields it for L1/instr targets.
                    UpsetOutcome::Detected => unreachable!("parity-detected on a silent class"),
                }
            }
            FaultTarget::L1Tags => {
                let tags = gmem.l1_tag_count();
                if tags == 0 {
                    return Ok(0);
                }
                match upset_outcome(mode, ev.target, false) {
                    UpsetOutcome::Corrected { cycles } => {
                        stats.fault.detected += 1;
                        stats.fault.corrected += 1;
                        Ok(cycles)
                    }
                    _ => {
                        stats.fault.detected += 1;
                        Err(SimError::SoftError {
                            site: FaultSite::L1Tag {
                                sm: self.sm_id,
                                index: (ev.sel % u64::from(tags)) as u32,
                            },
                            cycle,
                            bit: ev.bit,
                        })
                    }
                }
            }
            FaultTarget::InstrImage => match upset_outcome(mode, ev.target, false) {
                UpsetOutcome::Corrected { cycles } => {
                    stats.fault.detected += 1;
                    stats.fault.corrected += 1;
                    Ok(cycles)
                }
                _ => {
                    stats.fault.detected += 1;
                    Err(SimError::SoftError {
                        site: FaultSite::Instr { sm: self.sm_id, pc },
                        cycle,
                        bit: ev.bit,
                    })
                }
            },
        }
    }

    fn make_resident(
        &self,
        desc: BlockDesc,
        regs_per_thread: u32,
        smem_bytes: u32,
        params: &[i32],
    ) -> Result<Resident, SimError> {
        let mut regs = RegFile::new(desc.ntid, regs_per_thread);
        // GPGPU controller seeds thread ids into the vector register file
        // (paper §3.1).
        for t in 0..desc.ntid {
            regs.write(t, 0, t as i32);
        }
        let mut shared = SharedMem::new(PARAM_SEG_BYTES + smem_bytes);
        shared.write_params(params)?;
        let n_warps = desc.ntid.div_ceil(WARP_SIZE as u32);
        let warps = (0..n_warps)
            .map(|id| {
                let lanes = desc.ntid - id * WARP_SIZE as u32;
                let enabled = if lanes >= WARP_SIZE as u32 {
                    u32::MAX
                } else {
                    (1u32 << lanes) - 1
                };
                Warp::new(id, enabled, self.cfg.warp_stack_depth)
            })
            .collect();
        Ok(Resident { desc, regs, shared, warps })
    }

    /// Execute one instruction for warp `wi` of `slot`. `issue_done` is
    /// the cycle at which the instruction's last row entered the pipeline.
    /// Returns extra pipeline-blocking cycles (memory serialization).
    fn step<G: GmemPort + ?Sized, A: AluBackend + ?Sized>(
        &self,
        slot: &mut Resident,
        wi: usize,
        cx: &mut ExecCtx<'_, G, A>,
        issue_done: u64,
    ) -> Result<u64, SimError> {
        let Resident { desc, regs, shared, warps } = slot;
        let w = &mut warps[wi];
        let uop = cx.kernel.fetch(w.id, w.pc)?;
        let eff = w.effective();
        debug_assert_ne!(eff, 0, "scheduler must not issue an empty warp");

        // Customization traps (§4.2): hardware without the multiplier /
        // third read-operand unit cannot execute these encodings. Launch
        // admission (`SmConfig::admit`) rejects statically-detectable
        // cases before simulation; this mid-run trap is the backstop for
        // direct `Sm::run` callers, with the same structured payload.
        if uop.needs_mul && !self.cfg.has_multiplier {
            return Err(SimError::Unsupported {
                op: uop.op.mnemonic(),
                capability: Capability::Multiplier,
                pc: Some(w.pc),
            });
        }
        if uop.needs_3ops && self.cfg.read_operands < 3 {
            return Err(SimError::Unsupported {
                op: uop.op.mnemonic(),
                capability: Capability::ThirdReadOperand,
                pc: Some(w.pc),
            });
        }

        // Guard evaluation (Fig. 2: predicate LUT -> instruction mask,
        // combined with the thread mask).
        let exec = if !uop.guarded {
            eff
        } else {
            let mut m = 0u32;
            for lane in 0..WARP_SIZE as u32 {
                if eff & (1 << lane) != 0 {
                    let t = w.id * WARP_SIZE as u32 + lane;
                    if regs.read_pred(t, uop.guard.preg).eval(uop.guard.cond) {
                        m |= 1 << lane;
                    }
                }
            }
            m
        };
        cx.stats.count_op(uop.op, exec.count_ones());

        // Batch issue (vector engine): a pre-decode-tagged uniform op
        // whose lanes are all live executes as one whole-warp batch —
        // branch-free lane loops and `memcpy` writeback over the SoA
        // register file. Divergent/guarded issues (and everything, on
        // the scalar oracle engine) take the masked per-lane loops.
        // Timing is computed identically on both paths, so engine choice
        // can never move a cycle count.
        let batched =
            uop.batchable && exec == w.enabled && self.cfg.engine == EngineMode::Vector;
        if batched {
            cx.stats.batched_uops += 1;
        }

        // Default hazard: same warp re-issues only after the pipeline
        // drains (write-back of this instruction).
        w.ready_at = issue_done + (self.cfg.pipeline_depth as u64 - 1);
        let mut next_pc = uop.next_pc;
        let mut blocking: u64 = 0;

        match uop.kind {
            UopKind::Nop => {}
            UopKind::Exit => {
                w.finished |= exec;
            }
            UopKind::Join => match w.stack.pop() {
                Some(e) => {
                    w.active = e.mask;
                    next_pc = e.addr;
                }
                None => return Err(SimError::StackUnderflow { warp: w.id, pc: w.pc }),
            },
            UopKind::Bar => {
                w.at_barrier = true;
            }
            UopKind::Ssy { target } => {
                let entry = StackEntry { typ: EntryType::Sync, addr: target, mask: eff };
                w.stack.push(entry).map_err(|_| SimError::StackOverflow {
                    warp: w.id,
                    pc: w.pc,
                    depth: self.cfg.warp_stack_depth,
                })?;
            }
            UopKind::Bra { target } => {
                let taken = exec;
                let not_taken = eff & !exec;
                if taken == 0 {
                    // uniform not-taken: fall through
                } else if not_taken == 0 {
                    next_pc = target;
                } else {
                    // Divergence (§4.1): save the taken path, run the
                    // not-taken path first.
                    cx.stats.divergences += 1;
                    let entry =
                        StackEntry { typ: EntryType::Div, addr: target, mask: taken };
                    w.stack.push(entry).map_err(|_| SimError::StackOverflow {
                        warp: w.id,
                        pc: w.pc,
                        depth: self.cfg.warp_stack_depth,
                    })?;
                    w.active = not_taken;
                }
            }
            UopKind::S2r { sr, dst } => {
                if batched {
                    let wbase = w.id * WARP_SIZE as u32;
                    let count = WARP_SIZE.min((desc.ntid - wbase) as usize);
                    let mut vals = [0i32; WARP_SIZE];
                    for (lane, slot) in vals.iter_mut().enumerate().take(count) {
                        let t = wbase + lane as u32;
                        *slot = special_value(sr, desc, w.id, lane as u32, t, self.sm_id);
                    }
                    regs.write_warp(wbase, count, dst, &vals);
                } else {
                    for lane in 0..WARP_SIZE as u32 {
                        if exec & (1 << lane) != 0 {
                            let t = w.id * WARP_SIZE as u32 + lane;
                            regs.write(
                                t,
                                dst,
                                special_value(sr, desc, w.id, lane, t, self.sm_id),
                            );
                        }
                    }
                }
            }
            UopKind::R2a { src, dst } => {
                for lane in 0..WARP_SIZE as u32 {
                    if exec & (1 << lane) != 0 {
                        let t = w.id * WARP_SIZE as u32 + lane;
                        let v = regs.read(t, src);
                        regs.write_areg(t, dst, v);
                    }
                }
            }
            UopKind::A2r { src, dst } => {
                for lane in 0..WARP_SIZE as u32 {
                    if exec & (1 << lane) != 0 {
                        let t = w.id * WARP_SIZE as u32 + lane;
                        let v = regs.read_areg(t, src);
                        regs.write(t, dst, v);
                    }
                }
            }
            UopKind::Mem(m) => {
                // Read stage: one vector fetch of the address base, one of
                // the store data; the per-lane loop then touches memory for
                // exec lanes only (operand dispatch resolved at pre-decode).
                let wbase = w.id * WARP_SIZE as u32;
                let count = WARP_SIZE.min((desc.ntid - wbase) as usize);
                let mut base = [0i32; WARP_SIZE];
                match m.base {
                    MemBase::Reg(r) => regs.read_vec(wbase, count, r, &mut base),
                    MemBase::AReg(a) => {
                        for (lane, slot) in base.iter_mut().enumerate().take(count) {
                            *slot = regs.read_areg(wbase + lane as u32, a);
                        }
                    }
                }
                let addr = |lane: usize| base[lane].wrapping_add(m.offset) as u32;
                if m.load {
                    let mut out = [0i32; WARP_SIZE];
                    if batched {
                        // Whole-warp batch: the space dispatch is hoisted
                        // out of the lane loop and no mask is tested.
                        if m.global {
                            for (lane, slot) in out.iter_mut().enumerate().take(count) {
                                *slot = cx.gmem.load(addr(lane))?;
                            }
                        } else {
                            for (lane, slot) in out.iter_mut().enumerate().take(count) {
                                *slot = shared.load(addr(lane))?;
                            }
                        }
                        regs.write_warp(wbase, count, m.reg, &out);
                    } else {
                        for (lane, slot) in out.iter_mut().enumerate().take(count) {
                            if exec & (1 << lane) != 0 {
                                *slot = if m.global {
                                    cx.gmem.load(addr(lane))?
                                } else {
                                    shared.load(addr(lane))?
                                };
                            }
                        }
                        regs.write_vec(wbase, count, m.reg, exec, &out);
                    }
                } else {
                    let mut data = [0i32; WARP_SIZE];
                    regs.read_vec(wbase, count, m.reg, &mut data);
                    if batched {
                        if m.global {
                            for lane in 0..count {
                                cx.gmem.store(addr(lane), data[lane])?;
                            }
                        } else {
                            for lane in 0..count {
                                shared.store(addr(lane), data[lane])?;
                            }
                        }
                    } else {
                        for lane in 0..count {
                            if exec & (1 << lane) != 0 {
                                if m.global {
                                    cx.gmem.store(addr(lane), data[lane])?;
                                } else {
                                    shared.store(addr(lane), data[lane])?;
                                }
                            }
                        }
                    }
                }
                // Timing: the gmem port prices global accesses — flat
                // memory blocks the pipeline for the full AXI drain
                // (Fig. 3; see MemTiming docs for the calibration), while
                // an L1 layer blocks only at BRAM speed and parks the warp
                // until its line fills land (latency hidden by other
                // ready warps). Shared memory is always BRAM-priced.
                let txns = exec.count_ones() as u64;
                let park;
                if m.global {
                    let mut addrs = [0u32; WARP_SIZE];
                    for (lane, slot) in addrs.iter_mut().enumerate().take(count) {
                        *slot = addr(lane);
                    }
                    let cost = cx.gmem.access_cost(
                        &self.cfg.mem,
                        self.cfg.rows_per_warp(),
                        exec,
                        &addrs[..count],
                        m.load,
                        issue_done,
                    );
                    blocking = cost.blocking;
                    park = cost.park;
                } else {
                    blocking = self.cfg.mem.blocking_cycles(
                        false,
                        self.cfg.rows_per_warp(),
                        exec.count_ones(),
                    );
                    park = 0;
                }
                w.ready_at =
                    issue_done + blocking + park + (self.cfg.pipeline_depth as u64 - 1);
                match (m.global, m.load) {
                    (true, true) => cx.stats.global_load_txns += txns,
                    (true, false) => cx.stats.global_store_txns += txns,
                    (false, true) => cx.stats.shared_load_txns += txns,
                    (false, false) => cx.stats.shared_store_txns += txns,
                }
            }
            // The SP-array datapath.
            UopKind::Alu(a) => {
                // Read stage: operand kinds were resolved at pre-decode;
                // each source is a strided vector fetch or an immediate
                // splat (one read-operand unit per source, exactly Fig. 3 —
                // also the simulator's hottest loop, see EXPERIMENTS.md
                // §Perf).
                let mut input = WarpAluIn {
                    func: a.func,
                    cond: a.cond,
                    a: [0; WARP_SIZE],
                    b: [0; WARP_SIZE],
                    c: [0; WARP_SIZE],
                };
                let wbase = w.id * WARP_SIZE as u32;
                let count = WARP_SIZE.min((desc.ntid - wbase) as usize);
                match a.a {
                    VecSrc::Reg(r) => regs.read_vec(wbase, count, r, &mut input.a),
                    VecSrc::Splat(v) => input.a[..count].fill(v),
                }
                match a.b {
                    VecSrc::Reg(r) => regs.read_vec(wbase, count, r, &mut input.b),
                    VecSrc::Splat(v) => input.b[..count].fill(v),
                }
                match a.c {
                    CSrc::Reg(r) => regs.read_vec(wbase, count, r, &mut input.c),
                    CSrc::Pred => {
                        // Selector lanes from the predicate register file.
                        for lane in 0..count {
                            input.c[lane] = regs
                                .read_pred(wbase + lane as u32, a.setp_idx)
                                .eval(a.cond) as i32;
                        }
                    }
                    CSrc::Zero => {}
                }
                let out = cx.alu.execute(&input);
                // Write stage: one `memcpy` for a batch issue, masked
                // vector scatter otherwise. The predicate file stays
                // per-lane (packed 4-bit flags, not a lane vector).
                if a.setp_wb {
                    for lane in 0..count {
                        if exec & (1 << lane) != 0 {
                            regs.write_pred(
                                wbase + lane as u32,
                                a.setp_idx,
                                crate::isa::Flags::unpack(out[lane] as u8),
                            );
                        }
                    }
                } else if batched {
                    regs.write_warp(wbase, count, a.dst, &out);
                } else {
                    regs.write_vec(wbase, count, a.dst, exec, &out);
                }
            }
        }

        // Reconvergence drain: if every lane on the current path finished
        // or diverged away, pop saved paths until live lanes appear — or
        // the warp retires.
        while w.effective() == 0 && !w.done {
            match w.stack.pop() {
                Some(StackEntry { addr, mask, .. }) => {
                    w.active = mask;
                    next_pc = addr;
                }
                None => {
                    w.done = true;
                }
            }
        }
        if !w.done {
            w.pc = next_pc;
        }
        Ok(blocking)
    }
}

fn special_value(
    sr: SpecialReg,
    desc: &BlockDesc,
    warp_id: u32,
    lane: u32,
    tid: u32,
    sm_id: u32,
) -> i32 {
    (match sr {
        SpecialReg::TidX => tid,
        SpecialReg::NtidX => desc.ntid,
        SpecialReg::CtaidX => desc.ctaid_x,
        SpecialReg::NctaidX => desc.nctaid_x,
        SpecialReg::CtaidY => desc.ctaid_y,
        SpecialReg::NctaidY => desc.nctaid_y,
        SpecialReg::LaneId => lane,
        SpecialReg::WarpId => warp_id,
        SpecialReg::SmId => sm_id,
        SpecialReg::GtId => {
            (desc.ctaid_y * desc.nctaid_x + desc.ctaid_x) * desc.ntid + tid
        }
    }) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;
    use crate::sim::{GlobalMem, NativeAlu};

    fn run_one_block(
        src: &str,
        params: &[i32],
        ntid: u32,
        gmem: &mut GlobalMem,
    ) -> Result<SmStats, SimError> {
        run_one_block_cfg(src, params, ntid, gmem, SmConfig::baseline())
    }

    fn run_one_block_cfg(
        src: &str,
        params: &[i32],
        ntid: u32,
        gmem: &mut GlobalMem,
        cfg: SmConfig,
    ) -> Result<SmStats, SimError> {
        let k = assemble(src).expect("assemble");
        let pre = PreDecoded::from_kernel(&k);
        let sm = Sm::new(cfg, 0);
        let blocks = [BlockDesc { ctaid_x: 0, ctaid_y: 0, nctaid_x: 1, nctaid_y: 1, ntid }];
        let mut alu = NativeAlu;
        let launch = SmLaunch {
            pre: &pre,
            regs_per_thread: k.regs_per_thread,
            smem_bytes: k.smem_bytes,
            params,
            blocks: &blocks,
            max_resident: 8,
            fault: None,
            checkpoint: None,
        };
        sm.run(&launch, gmem, &mut alu)
    }

    /// out[tid] = tid * 3 + param0
    const SCALE_SRC: &str = r#"
        .entry scale
        .regs 8
            S2R R0, SR_TID
            MOV R1, #3
            IMUL R2, R0, R1
            SLD R3, [0]       ; param0 = scalar addend
            IADD R2, R2, R3
            SLD R4, [4]       ; param1 = out base addr
            SHL R5, R0, #2
            IADD R4, R4, R5
            GST [R4], R2
            EXIT
    "#;

    #[test]
    fn simt_scale_kernel_writes_every_thread() {
        let mut g = GlobalMem::new(4096);
        let stats = run_one_block(SCALE_SRC, &[100, 0], 64, &mut g).unwrap();
        for t in 0..64 {
            assert_eq!(g.load(t * 4).unwrap(), (t as i32) * 3 + 100, "thread {t}");
        }
        assert_eq!(stats.blocks, 1);
        assert!(stats.cycles > 0);
        assert_eq!(stats.max_stack_depth, 0);
    }

    #[test]
    fn partial_warp_only_writes_existing_threads() {
        let mut g = GlobalMem::new(4096);
        run_one_block(SCALE_SRC, &[7, 0], 40, &mut g).unwrap();
        assert_eq!(g.load(39 * 4).unwrap(), 39 * 3 + 7);
        assert_eq!(g.load(40 * 4).unwrap(), 0, "thread 40 must not exist");
    }

    /// if (tid < 4) out[tid] = 111; else out[tid] = 222; then all: +=1
    const DIVERGE_SRC: &str = r#"
        .entry diverge
        .regs 8
            S2R R0, SR_TID
            SHL R4, R0, #2       ; addr = tid*4
            ISETP P0, R0, #4
            SSY reconv
            @P0.LT BRA then
            MOV R1, #222         ; else path (not-taken lanes run first)
            JOIN
        then:
            MOV R1, #111
            JOIN
        reconv:
            IADD R1, R1, #1
            GST [R4], R1
            EXIT
    "#;

    #[test]
    fn divergent_branch_both_paths_and_reconvergence() {
        let mut g = GlobalMem::new(4096);
        let stats = run_one_block(DIVERGE_SRC, &[], 32, &mut g).unwrap();
        for t in 0..32 {
            let want = if t < 4 { 112 } else { 223 };
            assert_eq!(g.load(t * 4).unwrap(), want, "thread {t}");
        }
        assert_eq!(stats.divergences, 1);
        assert_eq!(stats.max_stack_depth, 2); // SSY + DIV
    }

    #[test]
    fn uniform_branch_uses_no_stack() {
        // All 32 threads satisfy tid < 100 -> no divergence.
        let src = DIVERGE_SRC.replace("#4", "#100");
        let mut g = GlobalMem::new(4096);
        let stats = run_one_block(&src, &[], 32, &mut g).unwrap();
        assert_eq!(stats.divergences, 0);
        assert_eq!(g.load(0).unwrap(), 112);
        // SSY still pushes; uniform-taken path's JOIN pops it.
        assert_eq!(stats.max_stack_depth, 1);
    }

    #[test]
    fn stack_overflow_on_shallow_config() {
        let mut cfg = SmConfig::baseline();
        cfg.warp_stack_depth = 1; // SSY fits; the DIV push must overflow
        let mut g = GlobalMem::new(4096);
        let err = run_one_block_cfg(DIVERGE_SRC, &[], 32, &mut g, cfg).unwrap_err();
        assert!(matches!(err, SimError::StackOverflow { depth: 1, .. }));
    }

    #[test]
    fn multiplier_less_config_traps_on_imul_mid_run() {
        // Direct `Sm::run` bypasses launch admission, so the removed-unit
        // trap fires at issue time, carrying the faulting pc.
        let mut cfg = SmConfig::baseline();
        cfg.has_multiplier = false;
        cfg.read_operands = 2;
        let mut g = GlobalMem::new(4096);
        let err = run_one_block_cfg(SCALE_SRC, &[0, 0], 32, &mut g, cfg).unwrap_err();
        assert!(matches!(
            err,
            SimError::Unsupported {
                op: "IMUL",
                capability: Capability::Multiplier,
                pc: Some(_)
            }
        ));
    }

    /// Two warps exchange data through shared memory across a barrier:
    /// out[tid] = in_shared[ntid-1-tid].
    const BARRIER_SRC: &str = r#"
        .entry reverse
        .regs 8
        .smem 256
            S2R R0, SR_TID
            S2R R1, SR_NTID
            SHL R2, R0, #2
            IADD R2, R2, #64     ; scratch base (after param segment)
            SST [R2], R0         ; shared[tid] = tid
            BAR
            ISUB R3, R1, R0
            ISUB R3, R3, #1      ; ntid-1-tid
            SHL R3, R3, #2
            IADD R3, R3, #64
            SLD R4, [R3]         ; shared[ntid-1-tid]
            SHL R5, R0, #2
            GST [R5], R4
            EXIT
    "#;

    #[test]
    fn barrier_synchronizes_warps() {
        let mut g = GlobalMem::new(4096);
        let stats = run_one_block(BARRIER_SRC, &[], 64, &mut g).unwrap();
        for t in 0..64i32 {
            assert_eq!(g.load(t as u32 * 4).unwrap(), 63 - t, "thread {t}");
        }
        assert_eq!(stats.barriers, 1);
    }

    #[test]
    fn join_on_empty_stack_faults() {
        let mut g = GlobalMem::new(64);
        let err = run_one_block("JOIN\nEXIT", &[], 32, &mut g).unwrap_err();
        assert!(matches!(err, SimError::StackUnderflow { .. }));
    }

    #[test]
    fn run_off_code_faults() {
        let mut g = GlobalMem::new(64);
        let err = run_one_block("NOP", &[], 32, &mut g).unwrap_err();
        assert!(matches!(err, SimError::RanOffCode { .. }));
    }

    #[test]
    fn more_sps_fewer_cycles() {
        let mut cycles = Vec::new();
        for sp in [8u32, 16, 32] {
            let mut g = GlobalMem::new(4096);
            let stats = run_one_block_cfg(
                SCALE_SRC,
                &[0, 0],
                256,
                &mut g,
                SmConfig::baseline().with_sp(sp),
            )
            .unwrap();
            cycles.push(stats.cycles);
        }
        assert!(cycles[0] > cycles[1], "8 SP slower than 16 SP: {cycles:?}");
        assert!(cycles[1] > cycles[2], "16 SP slower than 32 SP: {cycles:?}");
    }

    #[test]
    fn r0_seeded_with_tid() {
        // Paper §3.1: controller initializes thread ids in the regfile.
        let src = r#"
            .regs 4
            SHL R1, R0, #2
            GST [R1], R0
            EXIT
        "#;
        let mut g = GlobalMem::new(1024);
        run_one_block(src, &[], 32, &mut g).unwrap();
        assert_eq!(g.load(5 * 4).unwrap(), 5);
    }

    #[test]
    fn exit_under_divergence_drains_stack() {
        // Lanes < 16 exit inside the taken path; others continue.
        let src = r#"
            .regs 8
            S2R R0, SR_TID
            ISETP P0, R0, #16
            SSY reconv
            @P0.LT BRA then
            JOIN
        then:
            EXIT                 ; 16 lanes die inside divergent region
        reconv:
            SHL R1, R0, #2
            MOV R2, #5
            GST [R1], R2
            EXIT
        "#;
        let mut g = GlobalMem::new(4096);
        run_one_block(src, &[], 32, &mut g).unwrap();
        assert_eq!(g.load(3 * 4).unwrap(), 0, "exited lane must not store");
        assert_eq!(g.load(20 * 4).unwrap(), 5, "surviving lane stores");
    }

    #[test]
    fn multi_block_retirement_preserves_round_robin_coverage() {
        // More blocks than residency slots: blocks retire and refill while
        // the round-robin pointer keeps rotating (the seed engine reset it
        // to slot 0 on every retirement — see WarpScheduler::retire_range
        // for the order-pinning unit tests). Every thread of every block
        // must still execute exactly once.
        let src = r#"
            .entry cover
            .regs 6
                S2R R1, SR_GTID
                SHL R2, R1, #2
                IADD R3, R1, #7
                GST [R2], R3
                EXIT
        "#;
        let k = assemble(src).unwrap();
        let pre = PreDecoded::from_kernel(&k);
        let sm = Sm::new(SmConfig::baseline(), 0);
        let blocks: Vec<BlockDesc> = (0..6)
            .map(|bx| BlockDesc {
                ctaid_x: bx,
                ctaid_y: 0,
                nctaid_x: 6,
                nctaid_y: 1,
                ntid: 64,
            })
            .collect();
        let mut g = GlobalMem::new(4096);
        let mut alu = NativeAlu;
        let launch = SmLaunch {
            pre: &pre,
            regs_per_thread: k.regs_per_thread,
            smem_bytes: k.smem_bytes,
            params: &[],
            blocks: &blocks,
            max_resident: 2,
            fault: None,
            checkpoint: None,
        };
        let stats = sm.run(&launch, &mut g, &mut alu).unwrap();
        assert_eq!(stats.blocks, 6);
        for t in 0..6 * 64 {
            assert_eq!(g.load(t * 4).unwrap(), t as i32 + 7, "thread {t}");
        }
    }

    #[test]
    fn warp_cap_overflow_is_a_structured_fault() {
        // 17 blocks x 8 warps = 136 resident warps with a custom
        // max_resident — beyond the scheduler cap. Must fault, not panic.
        let k = assemble(SCALE_SRC).unwrap();
        let pre = PreDecoded::from_kernel(&k);
        let sm = Sm::new(SmConfig::baseline(), 0);
        let blocks: Vec<BlockDesc> = (0..17u32)
            .map(|bx| BlockDesc {
                ctaid_x: bx,
                ctaid_y: 0,
                nctaid_x: 17,
                nctaid_y: 1,
                ntid: 256,
            })
            .collect();
        let mut g = GlobalMem::new(1 << 14);
        let mut alu = NativeAlu;
        let launch = SmLaunch {
            pre: &pre,
            regs_per_thread: k.regs_per_thread,
            smem_bytes: k.smem_bytes,
            params: &[0, 0],
            blocks: &blocks,
            max_resident: 17,
            fault: None,
            checkpoint: None,
        };
        let err = sm.run(&launch, &mut g, &mut alu).unwrap_err();
        assert!(matches!(err, SimError::LimitExceeded(_)), "{err}");
    }

    #[test]
    fn dyn_trait_objects_still_accepted_at_the_boundary() {
        // The generic engine must keep working through `&mut dyn` (the
        // gpgpu::launch boundary contract).
        let k = assemble(SCALE_SRC).unwrap();
        let pre = PreDecoded::from_kernel(&k);
        let sm = Sm::new(SmConfig::baseline(), 0);
        let blocks = [BlockDesc { ctaid_x: 0, ctaid_y: 0, nctaid_x: 1, nctaid_y: 1, ntid: 32 }];
        let mut g = GlobalMem::new(4096);
        let mut alu = NativeAlu;
        let gd: &mut dyn crate::sim::GmemPort = &mut g;
        let ad: &mut dyn AluBackend = &mut alu;
        let launch = SmLaunch {
            pre: &pre,
            regs_per_thread: k.regs_per_thread,
            smem_bytes: k.smem_bytes,
            params: &[5, 0],
            blocks: &blocks,
            max_resident: 8,
            fault: None,
            checkpoint: None,
        };
        let stats = sm.run(&launch, gd, ad).unwrap();
        assert_eq!(stats.blocks, 1);
        assert_eq!(g.load(0).unwrap(), 5);
    }

    fn run_one_block_fault(
        src: &str,
        params: &[i32],
        ntid: u32,
        gmem: &mut GlobalMem,
        fault: Option<&FaultPlan>,
    ) -> Result<SmStats, SimError> {
        let k = assemble(src).expect("assemble");
        let pre = PreDecoded::from_kernel(&k);
        let sm = Sm::new(SmConfig::baseline(), 0);
        let blocks = [BlockDesc { ctaid_x: 0, ctaid_y: 0, nctaid_x: 1, nctaid_y: 1, ntid }];
        let mut alu = NativeAlu;
        let launch = SmLaunch {
            pre: &pre,
            regs_per_thread: k.regs_per_thread,
            smem_bytes: k.smem_bytes,
            params,
            blocks: &blocks,
            max_resident: 8,
            fault,
            checkpoint: None,
        };
        sm.run(&launch, gmem, &mut alu)
    }

    #[test]
    fn instr_image_upset_is_parity_detected() {
        use crate::sim::FaultTargets;
        // Mean inter-arrival 1 cycle: the first upset lands within the
        // first few issues, long before the kernel completes.
        let plan =
            FaultPlan::new(0xBAD5EED, 1_000_000.0).with_targets(FaultTargets {
                instr_image: true,
                ..FaultTargets::none()
            });
        let mut g = GlobalMem::new(4096);
        let err = run_one_block_fault(SCALE_SRC, &[0, 0], 64, &mut g, Some(&plan)).unwrap_err();
        match err {
            SimError::SoftError { site: FaultSite::Instr { sm: 0, .. }, cycle, .. } => {
                assert!(cycle > 0);
            }
            other => panic!("expected instruction-image SoftError, got {other}"),
        }
    }

    #[test]
    fn tag_upsets_are_noops_on_flat_memory() {
        use crate::sim::FaultTargets;
        // A tag-only campaign against a tagless (flat) port lands in
        // unused fabric: the run must complete bit- and cycle-identical
        // to the fault-free run.
        let plan = FaultPlan::new(0xBAD5EED, 1_000_000.0)
            .with_targets(FaultTargets { l1_tags: true, ..FaultTargets::none() });
        let mut clean = GlobalMem::new(4096);
        let s0 = run_one_block_fault(SCALE_SRC, &[9, 0], 64, &mut clean, None).unwrap();
        let mut faulted = GlobalMem::new(4096);
        let s1 = run_one_block_fault(SCALE_SRC, &[9, 0], 64, &mut faulted, Some(&plan)).unwrap();
        assert_eq!(s0.cycles, s1.cycles);
        assert_eq!(clean.read_words(0, 64).unwrap(), faulted.read_words(0, 64).unwrap());
    }

    #[test]
    fn disabled_plan_is_bit_and_cycle_identical() {
        let zero_rate = FaultPlan::new(123, 0.0);
        let mut a = GlobalMem::new(4096);
        let sa = run_one_block_fault(SCALE_SRC, &[3, 0], 64, &mut a, None).unwrap();
        let mut b = GlobalMem::new(4096);
        let sb = run_one_block_fault(SCALE_SRC, &[3, 0], 64, &mut b, Some(&zero_rate)).unwrap();
        assert_eq!(sa.cycles, sb.cycles);
        assert_eq!(a.read_words(0, 64).unwrap(), b.read_words(0, 64).unwrap());
    }

    #[test]
    fn vector_and_scalar_engines_are_bit_and_cycle_identical() {
        // Uniform and divergent kernels, full and partial warps: the two
        // engines must agree on memory image, cycles and every counter
        // except batched_uops (vector-only by definition).
        for (src, params, ntid) in [
            (SCALE_SRC, &[100i32, 0][..], 64u32),
            (SCALE_SRC, &[7, 0][..], 40),
            (DIVERGE_SRC, &[][..], 32),
            (BARRIER_SRC, &[][..], 64),
        ] {
            let mut gv = GlobalMem::new(4096);
            let sv = run_one_block_cfg(src, params, ntid, &mut gv, SmConfig::baseline())
                .unwrap();
            let mut gs = GlobalMem::new(4096);
            let ss = run_one_block_cfg(
                src,
                params,
                ntid,
                &mut gs,
                SmConfig::baseline().with_engine(EngineMode::Scalar),
            )
            .unwrap();
            assert_eq!(ss.batched_uops, 0, "scalar engine must never batch");
            let mut sv_cmp = sv.clone();
            sv_cmp.batched_uops = 0;
            assert_eq!(sv_cmp, ss, "stats diverged on {src}");
            assert_eq!(
                gv.read_words(0, 256).unwrap(),
                gs.read_words(0, 256).unwrap(),
                "memory image diverged on {src}"
            );
        }
    }

    #[test]
    fn uniform_kernel_batches_on_the_vector_engine() {
        let mut g = GlobalMem::new(4096);
        let stats = run_one_block(SCALE_SRC, &[1, 0], 64, &mut g).unwrap();
        // Every issue except the two EXITs (one per warp) is guard-free
        // with all lanes live.
        assert_eq!(stats.batched_uops, stats.instructions - 2, "{stats:?}");
    }

    #[test]
    fn divergent_region_falls_back_to_the_scalar_loop() {
        let mut g = GlobalMem::new(4096);
        let stats = run_one_block(DIVERGE_SRC, &[], 32, &mut g).unwrap();
        // Inside the divergent region (MOV on each path) lanes are not
        // all live, so those issues must not batch; the guarded BRA and
        // control ops never batch by construction.
        assert!(stats.batched_uops > 0, "uniform prologue must batch: {stats:?}");
        assert!(
            stats.batched_uops + 6 <= stats.instructions,
            "divergent bodies must stay scalar: {stats:?}"
        );
    }

    #[test]
    fn silent_campaigns_are_deterministic_per_seed() {
        use crate::sim::FaultTargets;
        let plan = FaultPlan::new(0x51EE7, 50_000.0).with_targets(FaultTargets::silent());
        let run = || {
            let mut g = GlobalMem::new(4096);
            let r = run_one_block_fault(SCALE_SRC, &[11, 0], 64, &mut g, Some(&plan));
            (r, g.read_words(0, 64).unwrap())
        };
        let (r0, img0) = run();
        let (r1, img1) = run();
        assert_eq!(r0, r1, "same seed, same outcome");
        assert_eq!(img0, img1, "same seed, same memory image");
    }

    fn run_resilient(
        src: &str,
        params: &[i32],
        ntid: u32,
        gmem: &mut GlobalMem,
        fault: Option<&FaultPlan>,
        checkpoint: Option<CheckpointPolicy>,
    ) -> Result<SmStats, SimError> {
        let k = assemble(src).expect("assemble");
        let pre = PreDecoded::from_kernel(&k);
        let sm = Sm::new(SmConfig::baseline(), 0);
        let blocks = [BlockDesc { ctaid_x: 0, ctaid_y: 0, nctaid_x: 1, nctaid_y: 1, ntid }];
        let mut alu = NativeAlu;
        let launch = SmLaunch {
            pre: &pre,
            regs_per_thread: k.regs_per_thread,
            smem_bytes: k.smem_bytes,
            params,
            blocks: &blocks,
            max_resident: 8,
            fault,
            checkpoint,
        };
        sm.run(&launch, gmem, &mut alu)
    }

    #[test]
    fn ecc_corrects_silent_class_upsets_bit_identically() {
        use crate::sim::FaultTargets;
        let mut clean = GlobalMem::new(4096);
        let s0 = run_resilient(SCALE_SRC, &[17, 0], 256, &mut clean, None, None).unwrap();
        // Mean inter-arrival 10 cycles against a run hundreds of cycles
        // long: many upsets land. ECC repairs each in place, so the
        // memory image must match the clean run exactly — only time is
        // lost.
        let plan = FaultPlan::new(0x51EE7, 100_000.0)
            .with_targets(FaultTargets::silent())
            .with_protection(ProtectionConfig::ecc());
        let mut g = GlobalMem::new(4096);
        let s1 = run_resilient(SCALE_SRC, &[17, 0], 256, &mut g, Some(&plan), None).unwrap();
        assert!(s1.fault.corrected > 0, "{:?}", s1.fault);
        assert_eq!(s1.fault.detected, s1.fault.corrected);
        assert_eq!(s1.fault.uncorrectable, 0, "no aging without stuck-at faults");
        assert!(s1.cycles > s0.cycles, "corrections must cost cycles");
        assert_eq!(clean.read_words(0, 256).unwrap(), g.read_words(0, 256).unwrap());
    }

    #[test]
    fn stuck_at_sites_age_and_scrub_under_ecc() {
        use crate::sim::{FaultTargets, Scrubber};
        let mut clean = GlobalMem::new(4096);
        run_resilient(SCALE_SRC, &[5, 0], 256, &mut clean, None, None).unwrap();
        // Every upset is stuck-at: each ages its word, which then pays an
        // ECC correction on every subsequent issue until a scrub pass
        // (tight 16-cycle interval here) repairs it. A fresh upset on a
        // still-aged word is uncorrectable; the checkpoint policy turns
        // those rare collisions into restarts instead of failures.
        let protect = ProtectionConfig {
            scrubber: Some(Scrubber { interval_cycles: 16, words_per_pass: 2 }),
            ..ProtectionConfig::ecc()
        };
        let plan = FaultPlan::new(0xA6ED, 100_000.0)
            .with_targets(FaultTargets::silent())
            .with_protection(protect)
            .with_stuck_at(1.0);
        let mut g = GlobalMem::new(4096);
        let s = run_resilient(
            SCALE_SRC,
            &[5, 0],
            256,
            &mut g,
            Some(&plan),
            Some(CheckpointPolicy::at_barriers()),
        )
        .unwrap();
        assert!(s.fault.corrected > 0, "{:?}", s.fault);
        assert!(s.fault.scrubbed > 0, "{:?}", s.fault);
        assert_eq!(
            clean.read_words(0, 256).unwrap(),
            g.read_words(0, 256).unwrap(),
            "ECC never lets a flip reach architectural state"
        );
    }

    #[test]
    fn parity_stuck_at_campaigns_are_deterministic_and_uncounted() {
        use crate::sim::FaultTargets;
        // Under parity the silent classes corrupt without any bookkeeping:
        // the aging machinery must not perturb determinism, and the
        // protected-upset counters stay zero.
        let plan = FaultPlan::new(0x57CC, 50_000.0)
            .with_targets(FaultTargets::silent())
            .with_stuck_at(1.0);
        let run = || {
            let mut g = GlobalMem::new(4096);
            let r = run_resilient(SCALE_SRC, &[11, 0], 64, &mut g, Some(&plan), None);
            (r, g.read_words(0, 64).unwrap())
        };
        let (r0, img0) = run();
        let (r1, img1) = run();
        assert_eq!(r0, r1, "same seed, same outcome");
        assert_eq!(img0, img1, "same seed, same memory image");
        // Corruption may fault the run (bad addresses); either way parity
        // counts nothing.
        if let Ok(s) = r0 {
            assert_eq!(s.fault, crate::sim::FaultStats::default());
        }
    }

    #[test]
    fn checkpoint_restart_rescues_uncorrectable_faults_bit_identically() {
        use crate::sim::FaultTargets;
        let mut clean = GlobalMem::new(4096);
        let s0 = run_resilient(SCALE_SRC, &[21, 0], 64, &mut clean, None, None).unwrap();
        let c = s0.cycles;
        // Search the seed space for a campaign whose first (parity-fatal)
        // instruction upset lands mid-run and whose second lands far past
        // the replayed completion: exactly one restart, then clean sailing.
        let targets = FaultTargets { instr_image: true, ..FaultTargets::none() };
        let plan = (0u64..)
            .map(|n| FaultPlan::new(0xF00D + n, 50.0).with_targets(targets))
            .find(|p| {
                let mut st = FaultState::new(p, 0).unwrap();
                let e1 = st.next_event();
                e1 < c / 2 && {
                    st.poll(e1);
                    st.next_event() > e1 + 4 * c
                }
            })
            .expect("seed search is unbounded");
        // Without a checkpoint the upset kills the launch...
        let mut dead = GlobalMem::new(4096);
        let err =
            run_resilient(SCALE_SRC, &[21, 0], 64, &mut dead, Some(&plan), None).unwrap_err();
        assert!(matches!(err, SimError::SoftError { .. }), "{err}");
        // ...with one, the SM restores the launch-start snapshot, replays,
        // and completes bit-identical to the fault-free run.
        let mut g = GlobalMem::new(4096);
        let s1 = run_resilient(
            SCALE_SRC,
            &[21, 0],
            64,
            &mut g,
            Some(&plan),
            Some(CheckpointPolicy::at_barriers()),
        )
        .unwrap();
        assert_eq!(s1.restarts, 1);
        assert!(s1.replayed_cycles > 0);
        assert!(s1.cycles > c, "replayed progress is paid twice");
        assert_eq!(clean.read_words(0, 64).unwrap(), g.read_words(0, 64).unwrap());
    }

    #[test]
    fn barrier_checkpoint_bounds_replay_to_the_post_barrier_half() {
        use crate::sim::FaultTargets;
        let mut clean = GlobalMem::new(4096);
        let s0 = run_resilient(BARRIER_SRC, &[], 64, &mut clean, None, None).unwrap();
        let c = s0.cycles;
        assert_eq!(s0.barriers, 1);
        // A fatal upset in the last quarter of the run lands after the
        // barrier reconvergence (the barrier releases in the first half:
        // the post-barrier code is the longer side). Restoring the barrier
        // checkpoint must NOT re-execute the barrier.
        let targets = FaultTargets { instr_image: true, ..FaultTargets::none() };
        let plan = (0u64..)
            .map(|n| FaultPlan::new(0xBA12 + n, 50.0).with_targets(targets))
            .find(|p| {
                let mut st = FaultState::new(p, 0).unwrap();
                let e1 = st.next_event();
                e1 > c * 3 / 4 && e1 < c * 9 / 10 && {
                    st.poll(e1);
                    st.next_event() > e1 + 4 * c
                }
            })
            .expect("seed search is unbounded");
        let mut g = GlobalMem::new(4096);
        let s1 = run_resilient(
            BARRIER_SRC,
            &[],
            64,
            &mut g,
            Some(&plan),
            Some(CheckpointPolicy::at_barriers()),
        )
        .unwrap();
        assert_eq!(s1.restarts, 1);
        assert_eq!(s1.barriers, 1, "replay resumed past the barrier");
        assert!(s1.replayed_cycles < c, "replay bounded by the barrier checkpoint");
        assert_eq!(clean.read_words(0, 64).unwrap(), g.read_words(0, 64).unwrap());
    }

    #[test]
    fn restart_budget_exhaustion_still_fails_the_launch() {
        use crate::sim::FaultTargets;
        // Mean inter-arrival 1 cycle: every replay dies immediately. After
        // max_restarts the original error must surface.
        let targets = FaultTargets { instr_image: true, ..FaultTargets::none() };
        let plan = FaultPlan::new(0xDEAD, 1_000_000.0).with_targets(targets);
        let mut g = GlobalMem::new(4096);
        let err = run_resilient(
            SCALE_SRC,
            &[3, 0],
            64,
            &mut g,
            Some(&plan),
            Some(CheckpointPolicy::at_barriers().with_max_restarts(2)),
        )
        .unwrap_err();
        assert!(matches!(err, SimError::SoftError { .. }), "{err}");
    }
}
