//! The streaming multiprocessor: warp unit + 5-stage pipeline + control
//! flow unit (paper Fig. 1).
//!
//! Execution is functionally atomic per issued warp-instruction; timing
//! follows the paper's microarchitecture:
//!
//! * one warp **row** (`32 / num_sp` threads) enters the pipeline per
//!   cycle, so issuing one warp-instruction occupies the issue port for
//!   `rows` cycles;
//! * the same warp cannot issue again until its previous instruction
//!   clears the 5-stage pipeline (no forwarding) — round-robin across
//!   ready warps hides this, exactly the warp unit's job (§3.2);
//! * memory instructions park the warp for the AXI/BRAM latency while
//!   other warps keep issuing (latency hiding);
//! * `BAR` parks warps until every live warp of the block arrives.

use super::alu::{AluBackend, AluFunc, WarpAluIn, WARP_SIZE};
use super::mem::{GmemPort, SharedMem, PARAM_SEG_BYTES};
use super::metrics::SmStats;
use super::regfile::RegFile;
use super::stack::{EntryType, StackEntry};
use super::warp::{Warp, WarpStatus};
use super::{SimError, SmConfig};
use crate::asm::Kernel;
use crate::isa::{Instr, Op, Operand, SpecialReg};

/// Pre-decoded kernel image: the Decode stage run once per launch. The
/// issue loop then indexes a flat table — the single biggest simulator
/// speedup (see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct PreDecoded {
    /// Indexed by `pc / 4`; instructions are 4-byte aligned.
    by_pc: Vec<Option<Instr>>,
}

impl PreDecoded {
    pub fn from_kernel(k: &Kernel) -> PreDecoded {
        let words = k.code.len().div_ceil(4);
        let mut by_pc = vec![None; words];
        for &(pc, instr) in &k.instrs {
            by_pc[(pc / 4) as usize] = Some(instr);
        }
        PreDecoded { by_pc }
    }

    #[inline]
    fn fetch(&self, warp: u32, pc: u32) -> Result<Instr, SimError> {
        self.by_pc
            .get((pc / 4) as usize)
            .copied()
            .flatten()
            .ok_or(SimError::RanOffCode { warp, pc })
    }
}

/// One thread block as handed to an SM by the block scheduler.
#[derive(Debug, Clone, Copy)]
pub struct BlockDesc {
    pub ctaid_x: u32,
    pub ctaid_y: u32,
    pub nctaid_x: u32,
    pub nctaid_y: u32,
    /// Threads in this block (<= 256, paper §4.3).
    pub ntid: u32,
}

/// A resident (scheduled) block: its register file partition, shared
/// memory allocation, and warps.
struct Resident {
    desc: BlockDesc,
    regs: RegFile,
    shared: SharedMem,
    warps: Vec<Warp>,
}

impl Resident {
    fn all_done(&self) -> bool {
        self.warps.iter().all(|w| w.done)
    }
}

/// A streaming multiprocessor.
#[derive(Debug, Clone)]
pub struct Sm {
    pub cfg: SmConfig,
    pub sm_id: u32,
}

impl Sm {
    pub fn new(cfg: SmConfig, sm_id: u32) -> Sm {
        Sm { cfg, sm_id }
    }

    /// Execute `blocks` to completion, keeping at most `max_resident`
    /// blocks scheduled at once (the Table 1 limit computed by the block
    /// scheduler). Returns per-SM statistics; `stats.cycles` is this SM's
    /// busy time.
    ///
    /// `gmem` is a [`GmemPort`]: the shared [`super::GlobalMem`] on the
    /// sequential path, or this SM's private [`super::GmemSnapshot`] on
    /// the parallel path.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        kernel: &PreDecoded,
        regs_per_thread: u32,
        smem_bytes: u32,
        params: &[i32],
        blocks: &[BlockDesc],
        max_resident: usize,
        gmem: &mut dyn GmemPort,
        alu: &mut dyn AluBackend,
    ) -> Result<SmStats, SimError> {
        self.cfg.validate()?;
        assert!(max_resident >= 1, "block scheduler must allow one resident block");

        let mut stats = SmStats::default();
        let mut cycle: u64 = 0;
        let rows = self.cfg.rows_per_warp() as u64;
        let mut next_block = 0usize;
        let mut resident: Vec<Resident> = Vec::new();
        let mut rr: usize = 0;

        loop {
            // Block scheduler interface: fill free slots (§4.3 — "control
            // signals from the SM notify the block scheduler when all
            // thread blocks have completed and scheduling ... can begin").
            while resident.len() < max_resident && next_block < blocks.len() {
                resident.push(self.make_resident(
                    blocks[next_block],
                    regs_per_thread,
                    smem_bytes,
                    params,
                )?);
                next_block += 1;
            }
            if resident.is_empty() {
                break;
            }

            // Warp unit: round-robin pick of a ready warp. The scan is
            // allocation-free and indexes (slot, warp) directly — this
            // loop runs once per issued instruction (§Perf: the previous
            // Vec-per-issue version cost ~2x end-to-end).
            let total: usize = resident.iter().map(|r| r.warps.len()).sum();
            let mut chosen = None;
            {
                let mut flat = if rr >= total { 0 } else { rr };
                // locate starting slot/warp for `flat`
                let (mut s0, mut w0) = (0usize, flat);
                while w0 >= resident[s0].warps.len() {
                    w0 -= resident[s0].warps.len();
                    s0 += 1;
                }
                let (mut s, mut w) = (s0, w0);
                for _ in 0..total {
                    if resident[s].warps[w].status(cycle) == WarpStatus::Ready {
                        chosen = Some((s, w));
                        rr = flat + 1;
                        break;
                    }
                    flat += 1;
                    w += 1;
                    if w == resident[s].warps.len() {
                        w = 0;
                        s += 1;
                        if s == resident.len() {
                            s = 0;
                            flat = 0;
                        }
                    }
                }
            }

            match chosen {
                Some((s, w)) => {
                    cycle += rows;
                    // Memory instructions drain through the single AXI
                    // master / BRAM port and block the pipeline (Fig. 3);
                    // `step` returns those extra cycles.
                    cycle +=
                        self.step(&mut resident[s], w, kernel, gmem, alu, &mut stats, cycle)?;
                    let r = &mut resident[s];
                    // Barrier release: all live warps of the block arrived?
                    if r.warps.iter().any(|w| w.at_barrier)
                        && r.warps.iter().all(|w| w.done || w.at_barrier)
                    {
                        for w in &mut r.warps {
                            w.at_barrier = false;
                        }
                        stats.barriers += 1;
                    }
                    // Retire the issued block if it just completed (only
                    // the block that issued can change state).
                    if r.warps[w].done && r.all_done() {
                        for w in &r.warps {
                            stats.max_stack_depth =
                                stats.max_stack_depth.max(w.stack.max_depth());
                        }
                        resident.swap_remove(s);
                        stats.blocks += 1;
                        rr = 0;
                    }
                }
                None => {
                    // No warp ready: advance to the earliest wake-up.
                    let wake = resident
                        .iter()
                        .flat_map(|r| r.warps.iter())
                        .filter(|w| w.status(cycle) == WarpStatus::Waiting)
                        .map(|w| w.ready_at)
                        .min();
                    match wake {
                        Some(t) => {
                            stats.stall_cycles += t - cycle;
                            cycle = t;
                        }
                        None => {
                            // Everything is Done or AtBarrier, yet the block
                            // didn't retire and the barrier didn't release.
                            let block = resident
                                .iter()
                                .position(|r| !r.all_done())
                                .unwrap_or(0);
                            return Err(SimError::BarrierDeadlock { block: block as u32 });
                        }
                    }
                }
            }

            if cycle > self.cfg.watchdog_cycles {
                return Err(SimError::Watchdog { cycles: cycle });
            }
        }

        stats.cycles = cycle;
        Ok(stats)
    }

    fn make_resident(
        &self,
        desc: BlockDesc,
        regs_per_thread: u32,
        smem_bytes: u32,
        params: &[i32],
    ) -> Result<Resident, SimError> {
        let mut regs = RegFile::new(desc.ntid, regs_per_thread);
        // GPGPU controller seeds thread ids into the vector register file
        // (paper §3.1).
        for t in 0..desc.ntid {
            regs.write(t, 0, t as i32);
        }
        let mut shared = SharedMem::new(PARAM_SEG_BYTES + smem_bytes);
        shared.write_params(params)?;
        let n_warps = desc.ntid.div_ceil(WARP_SIZE as u32);
        let warps = (0..n_warps)
            .map(|id| {
                let lanes = desc.ntid - id * WARP_SIZE as u32;
                let enabled = if lanes >= WARP_SIZE as u32 {
                    u32::MAX
                } else {
                    (1u32 << lanes) - 1
                };
                Warp::new(id, enabled, self.cfg.warp_stack_depth)
            })
            .collect();
        Ok(Resident { desc, regs, shared, warps })
    }

    /// Execute one instruction for warp `wi` of `slot`. `issue_done` is
    /// the cycle at which the instruction's last row entered the pipeline.
    /// Returns extra pipeline-blocking cycles (memory serialization).
    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        slot: &mut Resident,
        wi: usize,
        kernel: &PreDecoded,
        gmem: &mut dyn GmemPort,
        alu: &mut dyn AluBackend,
        stats: &mut SmStats,
        issue_done: u64,
    ) -> Result<u64, SimError> {
        let Resident { desc, regs, shared, warps } = slot;
        let w = &mut warps[wi];
        let instr = kernel.fetch(w.id, w.pc)?;
        let eff = w.effective();
        debug_assert_ne!(eff, 0, "scheduler must not issue an empty warp");

        // Customization faults (§4.2): hardware without the multiplier /
        // third read-operand unit cannot execute these encodings.
        if instr.op.uses_multiplier() && !self.cfg.has_multiplier {
            return Err(SimError::NoMultiplier { pc: w.pc });
        }
        if instr.op == Op::Imad && self.cfg.read_operands < 3 {
            return Err(SimError::NoThirdOperand { pc: w.pc });
        }

        // Guard evaluation (Fig. 2: predicate LUT -> instruction mask,
        // combined with the thread mask).
        let exec = if instr.guard.is_unconditional() {
            eff
        } else {
            let mut m = 0u32;
            for lane in 0..WARP_SIZE as u32 {
                if eff & (1 << lane) != 0 {
                    let t = w.id * WARP_SIZE as u32 + lane;
                    if regs.read_pred(t, instr.guard.preg).eval(instr.guard.cond) {
                        m |= 1 << lane;
                    }
                }
            }
            m
        };
        stats.count_op(instr.op, exec.count_ones());

        // Default hazard: same warp re-issues only after the pipeline
        // drains (write-back of this instruction).
        w.ready_at = issue_done + (self.cfg.pipeline_depth as u64 - 1);
        let mut next_pc = w.pc + instr.size as u32;
        let mut blocking: u64 = 0;

        match instr.op {
            Op::Nop => {}
            Op::Exit => {
                w.finished |= exec;
            }
            Op::Join => match w.stack.pop() {
                Some(e) => {
                    w.active = e.mask;
                    next_pc = e.addr;
                }
                None => return Err(SimError::StackUnderflow { warp: w.id, pc: w.pc }),
            },
            Op::Bar => {
                w.at_barrier = true;
            }
            Op::Ssy => {
                let target = instr.branch_target().expect("SSY target");
                let entry = StackEntry { typ: EntryType::Sync, addr: target, mask: eff };
                w.stack.push(entry).map_err(|_| SimError::StackOverflow {
                    warp: w.id,
                    pc: w.pc,
                    depth: self.cfg.warp_stack_depth,
                })?;
            }
            Op::Bra => {
                let target = instr.branch_target().expect("BRA target");
                let taken = exec;
                let not_taken = eff & !exec;
                if taken == 0 {
                    // uniform not-taken: fall through
                } else if not_taken == 0 {
                    next_pc = target;
                } else {
                    // Divergence (§4.1): save the taken path, run the
                    // not-taken path first.
                    stats.divergences += 1;
                    let entry =
                        StackEntry { typ: EntryType::Div, addr: target, mask: taken };
                    w.stack.push(entry).map_err(|_| SimError::StackOverflow {
                        warp: w.id,
                        pc: w.pc,
                        depth: self.cfg.warp_stack_depth,
                    })?;
                    w.active = not_taken;
                }
            }
            Op::S2r => {
                let sr = match instr.src1 {
                    Operand::Special(sr) => sr,
                    _ => unreachable!("decoder guarantees S2R source"),
                };
                for lane in 0..WARP_SIZE as u32 {
                    if exec & (1 << lane) != 0 {
                        let t = w.id * WARP_SIZE as u32 + lane;
                        regs.write(t, instr.dst, special_value(sr, desc, w.id, lane, t, self.sm_id));
                    }
                }
            }
            Op::R2a => {
                for lane in 0..WARP_SIZE as u32 {
                    if exec & (1 << lane) != 0 {
                        let t = w.id * WARP_SIZE as u32 + lane;
                        let v = match instr.src1 {
                            Operand::Reg(r) => regs.read(t, r),
                            _ => unreachable!(),
                        };
                        regs.write_areg(t, instr.dst, v);
                    }
                }
            }
            Op::A2r => {
                for lane in 0..WARP_SIZE as u32 {
                    if exec & (1 << lane) != 0 {
                        let t = w.id * WARP_SIZE as u32 + lane;
                        let v = match instr.src1 {
                            Operand::AReg(a) => regs.read_areg(t, a),
                            _ => unreachable!(),
                        };
                        regs.write(t, instr.dst, v);
                    }
                }
            }
            Op::Gld | Op::Sld | Op::Gst | Op::Sst => {
                let is_global = matches!(instr.op, Op::Gld | Op::Gst);
                // Read stage: one vector fetch of the address base, one of
                // the store data; the per-lane loop then touches memory for
                // exec lanes only (operand dispatch hoisted; §Perf).
                let wbase = w.id * WARP_SIZE as u32;
                let count = WARP_SIZE.min((desc.ntid - wbase) as usize);
                let mut base = [0i32; WARP_SIZE];
                match instr.src1 {
                    Operand::Reg(r) => regs.read_vec(wbase, count, r, &mut base),
                    Operand::AReg(a) => {
                        for (lane, slot) in base.iter_mut().enumerate().take(count) {
                            *slot = regs.read_areg(wbase + lane as u32, a);
                        }
                    }
                    _ => unreachable!(),
                }
                let addr =
                    |lane: usize| base[lane].wrapping_add(instr.offset as i32) as u32;
                match instr.op {
                    Op::Gld | Op::Sld => {
                        let mut out = [0i32; WARP_SIZE];
                        for (lane, slot) in out.iter_mut().enumerate().take(count) {
                            if exec & (1 << lane) != 0 {
                                *slot = if is_global {
                                    gmem.load(addr(lane))?
                                } else {
                                    shared.load(addr(lane))?
                                };
                            }
                        }
                        regs.write_vec(wbase, count, instr.dst, exec, &out);
                    }
                    _ => {
                        let mut data = [0i32; WARP_SIZE];
                        if let Operand::Reg(r) = instr.src2 {
                            regs.read_vec(wbase, count, r, &mut data);
                        } else {
                            unreachable!("stores carry a register source");
                        }
                        for lane in 0..count {
                            if exec & (1 << lane) != 0 {
                                if is_global {
                                    gmem.store(addr(lane), data[lane])?;
                                } else {
                                    shared.store(addr(lane), data[lane])?;
                                }
                            }
                        }
                    }
                }
                // Timing: accesses drain through the single AXI master /
                // BRAM ports row by row and block the pipeline (Fig. 3;
                // see MemTiming docs for the calibration).
                let txns = exec.count_ones() as u64;
                blocking = self.cfg.mem.blocking_cycles(
                    is_global,
                    self.cfg.rows_per_warp(),
                    exec.count_ones(),
                );
                w.ready_at = issue_done + blocking + (self.cfg.pipeline_depth as u64 - 1);
                match instr.op {
                    Op::Gld => stats.global_load_txns += txns,
                    Op::Gst => stats.global_store_txns += txns,
                    Op::Sld => stats.shared_load_txns += txns,
                    Op::Sst => stats.shared_store_txns += txns,
                    _ => unreachable!(),
                }
            }
            // Everything else is the SP-array datapath.
            _ => {
                let func = AluFunc::from_op(instr.op)
                    .expect("non-ALU ops handled above");
                // Read stage: operand kind is resolved once per warp
                // instruction, then each source is a strided vector fetch
                // (one read-operand unit per source, exactly Fig. 3; also
                // the simulator's hottest loop — see EXPERIMENTS.md §Perf).
                let mut input = WarpAluIn {
                    func,
                    cond: instr.cond,
                    a: [0; WARP_SIZE],
                    b: [0; WARP_SIZE],
                    c: [0; WARP_SIZE],
                };
                let wbase = w.id * WARP_SIZE as u32;
                let count = WARP_SIZE.min((desc.ntid - wbase) as usize);
                match instr.src1 {
                    Operand::Reg(r) => regs.read_vec(wbase, count, r, &mut input.a),
                    // MOV #imm carries its immediate in src2.
                    Operand::None => {
                        if let Operand::Imm(v) = instr.src2 {
                            input.a[..count].fill(v);
                        }
                    }
                    _ => {}
                }
                match instr.src2 {
                    Operand::Reg(r) => regs.read_vec(wbase, count, r, &mut input.b),
                    Operand::Imm(v) => input.b[..count].fill(v),
                    _ => {}
                }
                if let Operand::Reg(r) = instr.src3 {
                    regs.read_vec(wbase, count, r, &mut input.c);
                }
                if func == AluFunc::Sel {
                    // Selector lanes from the predicate register file.
                    for lane in 0..count {
                        input.c[lane] = regs
                            .read_pred(wbase + lane as u32, instr.setp_idx)
                            .eval(instr.cond) as i32;
                    }
                }
                let out = alu.execute(&input);
                // Write stage: masked vector scatter.
                if func == AluFunc::Setp {
                    for lane in 0..count {
                        if exec & (1 << lane) != 0 {
                            regs.write_pred(
                                wbase + lane as u32,
                                instr.setp_idx,
                                crate::isa::Flags::unpack(out[lane] as u8),
                            );
                        }
                    }
                } else {
                    regs.write_vec(wbase, count, instr.dst, exec, &out);
                }
            }
        }

        // Reconvergence drain: if every lane on the current path finished
        // or diverged away, pop saved paths until live lanes appear — or
        // the warp retires.
        while w.effective() == 0 && !w.done {
            match w.stack.pop() {
                Some(StackEntry { addr, mask, .. }) => {
                    w.active = mask;
                    next_pc = addr;
                }
                None => {
                    w.done = true;
                }
            }
        }
        if !w.done {
            w.pc = next_pc;
        }
        Ok(blocking)
    }
}

fn special_value(
    sr: SpecialReg,
    desc: &BlockDesc,
    warp_id: u32,
    lane: u32,
    tid: u32,
    sm_id: u32,
) -> i32 {
    (match sr {
        SpecialReg::TidX => tid,
        SpecialReg::NtidX => desc.ntid,
        SpecialReg::CtaidX => desc.ctaid_x,
        SpecialReg::NctaidX => desc.nctaid_x,
        SpecialReg::CtaidY => desc.ctaid_y,
        SpecialReg::NctaidY => desc.nctaid_y,
        SpecialReg::LaneId => lane,
        SpecialReg::WarpId => warp_id,
        SpecialReg::SmId => sm_id,
        SpecialReg::GtId => {
            (desc.ctaid_y * desc.nctaid_x + desc.ctaid_x) * desc.ntid + tid
        }
    }) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;
    use crate::sim::{GlobalMem, NativeAlu};

    fn run_one_block(
        src: &str,
        params: &[i32],
        ntid: u32,
        gmem: &mut GlobalMem,
    ) -> Result<SmStats, SimError> {
        run_one_block_cfg(src, params, ntid, gmem, SmConfig::baseline())
    }

    fn run_one_block_cfg(
        src: &str,
        params: &[i32],
        ntid: u32,
        gmem: &mut GlobalMem,
        cfg: SmConfig,
    ) -> Result<SmStats, SimError> {
        let k = assemble(src).expect("assemble");
        let pre = PreDecoded::from_kernel(&k);
        let sm = Sm::new(cfg, 0);
        let blocks = [BlockDesc { ctaid_x: 0, ctaid_y: 0, nctaid_x: 1, nctaid_y: 1, ntid }];
        let mut alu = NativeAlu;
        sm.run(&pre, k.regs_per_thread, k.smem_bytes, params, &blocks, 8, gmem, &mut alu)
    }

    /// out[tid] = tid * 3 + param0
    const SCALE_SRC: &str = r#"
        .entry scale
        .regs 8
            S2R R0, SR_TID
            MOV R1, #3
            IMUL R2, R0, R1
            SLD R3, [0]       ; param0 = scalar addend
            IADD R2, R2, R3
            SLD R4, [4]       ; param1 = out base addr
            SHL R5, R0, #2
            IADD R4, R4, R5
            GST [R4], R2
            EXIT
    "#;

    #[test]
    fn simt_scale_kernel_writes_every_thread() {
        let mut g = GlobalMem::new(4096);
        let stats = run_one_block(SCALE_SRC, &[100, 0], 64, &mut g).unwrap();
        for t in 0..64 {
            assert_eq!(g.load(t * 4).unwrap(), (t as i32) * 3 + 100, "thread {t}");
        }
        assert_eq!(stats.blocks, 1);
        assert!(stats.cycles > 0);
        assert_eq!(stats.max_stack_depth, 0);
    }

    #[test]
    fn partial_warp_only_writes_existing_threads() {
        let mut g = GlobalMem::new(4096);
        run_one_block(SCALE_SRC, &[7, 0], 40, &mut g).unwrap();
        assert_eq!(g.load(39 * 4).unwrap(), 39 * 3 + 7);
        assert_eq!(g.load(40 * 4).unwrap(), 0, "thread 40 must not exist");
    }

    /// if (tid < 4) out[tid] = 111; else out[tid] = 222; then all: +=1
    const DIVERGE_SRC: &str = r#"
        .entry diverge
        .regs 8
            S2R R0, SR_TID
            SHL R4, R0, #2       ; addr = tid*4
            ISETP P0, R0, #4
            SSY reconv
            @P0.LT BRA then
            MOV R1, #222         ; else path (not-taken lanes run first)
            JOIN
        then:
            MOV R1, #111
            JOIN
        reconv:
            IADD R1, R1, #1
            GST [R4], R1
            EXIT
    "#;

    #[test]
    fn divergent_branch_both_paths_and_reconvergence() {
        let mut g = GlobalMem::new(4096);
        let stats = run_one_block(DIVERGE_SRC, &[], 32, &mut g).unwrap();
        for t in 0..32 {
            let want = if t < 4 { 112 } else { 223 };
            assert_eq!(g.load(t * 4).unwrap(), want, "thread {t}");
        }
        assert_eq!(stats.divergences, 1);
        assert_eq!(stats.max_stack_depth, 2); // SSY + DIV
    }

    #[test]
    fn uniform_branch_uses_no_stack() {
        // All 32 threads satisfy tid < 100 -> no divergence.
        let src = DIVERGE_SRC.replace("#4", "#100");
        let mut g = GlobalMem::new(4096);
        let stats = run_one_block(&src, &[], 32, &mut g).unwrap();
        assert_eq!(stats.divergences, 0);
        assert_eq!(g.load(0).unwrap(), 112);
        // SSY still pushes; uniform-taken path's JOIN pops it.
        assert_eq!(stats.max_stack_depth, 1);
    }

    #[test]
    fn stack_overflow_on_shallow_config() {
        let mut cfg = SmConfig::baseline();
        cfg.warp_stack_depth = 1; // SSY fits; the DIV push must overflow
        let mut g = GlobalMem::new(4096);
        let err = run_one_block_cfg(DIVERGE_SRC, &[], 32, &mut g, cfg).unwrap_err();
        assert!(matches!(err, SimError::StackOverflow { depth: 1, .. }));
    }

    #[test]
    fn multiplier_less_config_faults_on_imul() {
        let mut cfg = SmConfig::baseline();
        cfg.has_multiplier = false;
        cfg.read_operands = 2;
        let mut g = GlobalMem::new(4096);
        let err = run_one_block_cfg(SCALE_SRC, &[0, 0], 32, &mut g, cfg).unwrap_err();
        assert!(matches!(err, SimError::NoMultiplier { .. }));
    }

    /// Two warps exchange data through shared memory across a barrier:
    /// out[tid] = in_shared[ntid-1-tid].
    const BARRIER_SRC: &str = r#"
        .entry reverse
        .regs 8
        .smem 256
            S2R R0, SR_TID
            S2R R1, SR_NTID
            SHL R2, R0, #2
            IADD R2, R2, #64     ; scratch base (after param segment)
            SST [R2], R0         ; shared[tid] = tid
            BAR
            ISUB R3, R1, R0
            ISUB R3, R3, #1      ; ntid-1-tid
            SHL R3, R3, #2
            IADD R3, R3, #64
            SLD R4, [R3]         ; shared[ntid-1-tid]
            SHL R5, R0, #2
            GST [R5], R4
            EXIT
    "#;

    #[test]
    fn barrier_synchronizes_warps() {
        let mut g = GlobalMem::new(4096);
        let stats = run_one_block(BARRIER_SRC, &[], 64, &mut g).unwrap();
        for t in 0..64i32 {
            assert_eq!(g.load(t as u32 * 4).unwrap(), 63 - t, "thread {t}");
        }
        assert_eq!(stats.barriers, 1);
    }

    #[test]
    fn join_on_empty_stack_faults() {
        let mut g = GlobalMem::new(64);
        let err = run_one_block("JOIN\nEXIT", &[], 32, &mut g).unwrap_err();
        assert!(matches!(err, SimError::StackUnderflow { .. }));
    }

    #[test]
    fn run_off_code_faults() {
        let mut g = GlobalMem::new(64);
        let err = run_one_block("NOP", &[], 32, &mut g).unwrap_err();
        assert!(matches!(err, SimError::RanOffCode { .. }));
    }

    #[test]
    fn more_sps_fewer_cycles() {
        let mut cycles = Vec::new();
        for sp in [8u32, 16, 32] {
            let mut g = GlobalMem::new(4096);
            let stats = run_one_block_cfg(
                SCALE_SRC,
                &[0, 0],
                256,
                &mut g,
                SmConfig::baseline().with_sp(sp),
            )
            .unwrap();
            cycles.push(stats.cycles);
        }
        assert!(cycles[0] > cycles[1], "8 SP slower than 16 SP: {cycles:?}");
        assert!(cycles[1] > cycles[2], "16 SP slower than 32 SP: {cycles:?}");
    }

    #[test]
    fn r0_seeded_with_tid() {
        // Paper §3.1: controller initializes thread ids in the regfile.
        let src = r#"
            .regs 4
            SHL R1, R0, #2
            GST [R1], R0
            EXIT
        "#;
        let mut g = GlobalMem::new(1024);
        run_one_block(src, &[], 32, &mut g).unwrap();
        assert_eq!(g.load(5 * 4).unwrap(), 5);
    }

    #[test]
    fn exit_under_divergence_drains_stack() {
        // Lanes < 16 exit inside the taken path; others continue.
        let src = r#"
            .regs 8
            S2R R0, SR_TID
            ISETP P0, R0, #16
            SSY reconv
            @P0.LT BRA then
            JOIN
        then:
            EXIT                 ; 16 lanes die inside divergent region
        reconv:
            SHL R1, R0, #2
            MOV R2, #5
            GST [R1], R2
            EXIT
        "#;
        let mut g = GlobalMem::new(4096);
        run_one_block(src, &[], 32, &mut g).unwrap();
        assert_eq!(g.load(3 * 4).unwrap(), 0, "exited lane must not store");
        assert_eq!(g.load(20 * 4).unwrap(), 5, "surviving lane stores");
    }
}
