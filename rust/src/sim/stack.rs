//! The per-warp divergence stack (paper §4.1, Fig. 2): entries of
//! `{instruction address (32b), type identifier (2b), active-thread mask
//! (32b)}`, one stack per warp. Its depth is the paper's headline
//! customization parameter (Table 6: 32 → 16 → 2 → 0).

/// Entry type identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryType {
    /// Pushed by a divergent branch: `addr` is the start of the taken
    /// path, `mask` the taken lanes ("the instruction address of the taken
    /// branch and the active-thread mask prior to evaluation ... are
    /// stored on a warp stack for safekeeping").
    Div,
    /// Pushed by `SSY`: `addr` is the reconvergence point, `mask` the
    /// active mask to restore there.
    Sync,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StackEntry {
    pub typ: EntryType,
    pub addr: u32,
    pub mask: u32,
}

/// Fixed-capacity warp stack. In hardware this is `depth` registers of
/// 66 bits each (paper §5.2); a push beyond capacity is an architectural
/// fault — exactly what would go wrong if an application with deep control
/// nesting ran on an over-customized FlexGrip variant.
#[derive(Debug, Clone)]
pub struct WarpStack {
    entries: Vec<StackEntry>,
    capacity: u32,
    /// High-water mark, reported by the customization analyzer to pick the
    /// minimum viable depth (paper: "profiling the application with
    /// representative data sets").
    max_depth: u32,
}

impl WarpStack {
    pub fn new(capacity: u32) -> WarpStack {
        WarpStack { entries: Vec::with_capacity(capacity as usize), capacity, max_depth: 0 }
    }

    pub fn capacity(&self) -> u32 {
        self.capacity
    }

    pub fn depth(&self) -> u32 {
        self.entries.len() as u32
    }

    pub fn max_depth(&self) -> u32 {
        self.max_depth
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Push; `Err(())` on overflow (capacity exceeded).
    pub fn push(&mut self, e: StackEntry) -> Result<(), ()> {
        if self.entries.len() as u32 >= self.capacity {
            return Err(());
        }
        self.entries.push(e);
        self.max_depth = self.max_depth.max(self.entries.len() as u32);
        Ok(())
    }

    pub fn pop(&mut self) -> Option<StackEntry> {
        self.entries.pop()
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(addr: u32) -> StackEntry {
        StackEntry { typ: EntryType::Div, addr, mask: 0xff }
    }

    #[test]
    fn lifo_order() {
        let mut s = WarpStack::new(4);
        s.push(e(1)).unwrap();
        s.push(e(2)).unwrap();
        assert_eq!(s.pop().unwrap().addr, 2);
        assert_eq!(s.pop().unwrap().addr, 1);
        assert!(s.pop().is_none());
    }

    #[test]
    fn overflow_at_capacity() {
        let mut s = WarpStack::new(2);
        s.push(e(1)).unwrap();
        s.push(e(2)).unwrap();
        assert!(s.push(e(3)).is_err());
        assert_eq!(s.depth(), 2);
    }

    #[test]
    fn zero_capacity_rejects_all() {
        let mut s = WarpStack::new(0);
        assert!(s.push(e(1)).is_err());
    }

    #[test]
    fn high_water_mark_tracks() {
        let mut s = WarpStack::new(8);
        s.push(e(1)).unwrap();
        s.push(e(2)).unwrap();
        s.pop();
        s.push(e(3)).unwrap();
        assert_eq!(s.max_depth(), 2);
    }
}
