//! Deterministic SEU (single-event-upset) fault injection and the
//! protection models that answer it.
//!
//! The paper's soft GPGPU lives entirely in FPGA fabric — BRAMs hold the
//! register file, shared memory, cache tags and the pre-decoded
//! instruction image, exactly the structures embedded deployments lose
//! bits in. This module models those upsets *deterministically*: a
//! [`FaultPlan`] (seed + rate + target set) rides on a launch, and each
//! SM derives its private upset schedule from `(plan.seed, sm_id)` plus
//! its own simulated-cycle stream. Because the per-SM cycle streams are
//! identical on the sequential and parallel launch paths (the
//! bit-equivalence contract pinned by `tests/parallel_launch.rs`), fault
//! sites are identical on both paths too — same seed ⇒ byte-identical
//! upsets, reproducible in a test or a bug report.
//!
//! Each BRAM class carries a [`Protection`] scheme (via the plan's
//! [`ProtectionConfig`]):
//! - **`Parity`** (the default — exactly the pre-ECC behavior): tag
//!   array / instruction image upsets are *detected* and surface as
//!   `SimError::SoftError` so the service plane can retry; register
//!   file / shared memory upsets corrupt *silently* — only output
//!   verification or modular redundancy catches them.
//! - **`Ecc`** (SECDED-style): single-bit upsets are corrected in place
//!   at a modeled cycle cost and counted in [`FaultStats`]; a second bad
//!   bit in an already-aged word is detected but uncorrectable and stays
//!   `SimError::SoftError`.
//!
//! **Fault aging:** with a nonzero `stuck_at_fraction` each scheduled
//! upset is classified [`UpsetKind::Transient`] or [`UpsetKind::StuckAt`].
//! Stuck-at sites in the silent classes (register file, shared memory)
//! re-corrupt on every subsequent access until the background
//! [`Scrubber`] sweeps them — under parity that means persistent silent
//! corruption; under ECC a per-access correction cost (and double-bit
//! exposure) until the scrub pass repairs the word.
//!
//! A disabled plan (absent, rate 0, or no targets) never constructs a
//! [`FaultState`], so the engine's only overhead is one `Option` branch
//! per issue — provably bit- and cycle-identical to the fault-free
//! engine (`tests/fault_injection.rs`). The classification draw is gated
//! on `stuck_at_fraction > 0`, so default plans reproduce the exact
//! pinned RNG sequence of the pre-aging injector (mirrored by
//! `tools/verify/fault_diff.py`).

use crate::rng::XorShift64;

/// Golden-ratio mixing constant for per-SM stream separation.
const SM_STREAM_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// Parts-per-million scale for the stuck-at classification draw.
const PPM: u64 = 1_000_000;

/// Default modeled SECDED correction latency (cycles per corrected word):
/// the read-modify-write turnaround of the correction pipeline.
pub const ECC_CORRECT_CYCLES: u64 = 3;

/// Which modeled BRAM structures the injector may upset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultTargets {
    /// Per-block register file (silent corruption under parity).
    pub register_file: bool,
    /// Per-block shared memory (silent corruption under parity).
    pub shared_mem: bool,
    /// L1 tag array (parity-detected; no-op on tagless/flat memory).
    pub l1_tags: bool,
    /// Pre-decoded instruction image (parity-detected at issue).
    pub instr_image: bool,
}

impl FaultTargets {
    /// Every modeled structure.
    pub fn all() -> FaultTargets {
        FaultTargets {
            register_file: true,
            shared_mem: true,
            l1_tags: true,
            instr_image: true,
        }
    }

    /// No structure — combined with any rate this disables injection.
    pub fn none() -> FaultTargets {
        FaultTargets {
            register_file: false,
            shared_mem: false,
            l1_tags: false,
            instr_image: false,
        }
    }

    /// Only the silently-corrupting structures (register file + shared
    /// memory) — the class only DMR or output verification catches.
    pub fn silent() -> FaultTargets {
        FaultTargets { register_file: true, shared_mem: true, ..FaultTargets::none() }
    }

    /// Only the parity-detected structures (tags + instruction image).
    pub fn detected() -> FaultTargets {
        FaultTargets { l1_tags: true, instr_image: true, ..FaultTargets::none() }
    }

    pub fn any(&self) -> bool {
        self.register_file || self.shared_mem || self.l1_tags || self.instr_image
    }

    /// Enabled targets in pinned declaration order — the order is part of
    /// the deterministic contract (mirrored by `tools/verify/fault_diff.py`).
    fn enabled(&self) -> ([FaultTarget; 4], usize) {
        let mut kinds = [FaultTarget::RegisterFile; 4];
        let mut n = 0;
        if self.register_file {
            kinds[n] = FaultTarget::RegisterFile;
            n += 1;
        }
        if self.shared_mem {
            kinds[n] = FaultTarget::SharedMem;
            n += 1;
        }
        if self.l1_tags {
            kinds[n] = FaultTarget::L1Tags;
            n += 1;
        }
        if self.instr_image {
            kinds[n] = FaultTarget::InstrImage;
            n += 1;
        }
        (kinds, n)
    }
}

/// A structure class an upset can land in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTarget {
    RegisterFile,
    SharedMem,
    L1Tags,
    InstrImage,
}

/// Protection scheme applied to one BRAM class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Protection {
    /// Detect-only: tag/instruction upsets raise `SimError::SoftError`,
    /// register-file/shared-memory upsets corrupt silently. This is the
    /// pre-ECC behavior and the default.
    #[default]
    Parity,
    /// SECDED-style ECC: single-bit upsets are corrected in place at
    /// `correct_cycles` modeled cycles each; a second bad bit in an
    /// already-aged word is detected but uncorrectable.
    Ecc { correct_cycles: u64 },
}

/// Background scrubber sweeping the silent-corruption classes (register
/// file + shared memory): every `interval_cycles` it repairs up to
/// `words_per_pass` aged stuck-at sites, oldest first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scrubber {
    pub interval_cycles: u64,
    pub words_per_pass: u32,
}

impl Default for Scrubber {
    fn default() -> Scrubber {
        Scrubber { interval_cycles: 256, words_per_pass: 8 }
    }
}

/// Per-BRAM-class protection plus optional background scrubbing. The
/// default (`parity()`) reproduces pre-ECC behavior exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ProtectionConfig {
    pub register_file: Protection,
    pub shared_mem: Protection,
    pub l1_tags: Protection,
    pub instr_image: Protection,
    pub scrubber: Option<Scrubber>,
}

impl ProtectionConfig {
    /// Detect-only parity on every class (the default).
    pub fn parity() -> ProtectionConfig {
        ProtectionConfig::default()
    }

    /// SECDED ECC on every class at the default correction latency.
    pub fn ecc() -> ProtectionConfig {
        let p = Protection::Ecc { correct_cycles: ECC_CORRECT_CYCLES };
        ProtectionConfig {
            register_file: p,
            shared_mem: p,
            l1_tags: p,
            instr_image: p,
            scrubber: None,
        }
    }

    /// ECC everywhere plus the default background scrubber.
    pub fn ecc_scrub() -> ProtectionConfig {
        ProtectionConfig { scrubber: Some(Scrubber::default()), ..ProtectionConfig::ecc() }
    }

    /// The scheme protecting `target`'s BRAM class.
    pub fn for_target(&self, target: FaultTarget) -> Protection {
        match target {
            FaultTarget::RegisterFile => self.register_file,
            FaultTarget::SharedMem => self.shared_mem,
            FaultTarget::L1Tags => self.l1_tags,
            FaultTarget::InstrImage => self.instr_image,
        }
    }

    /// Parse a CLI protection spec: a preset (`parity` | `ecc` |
    /// `ecc+scrub`) or a comma-separated `CLASS=MODE` list with classes
    /// `rf` | `smem` | `l1` | `instr` (`ecc+scrub` as a MODE also enables
    /// the scrubber). Mirrors the `--cache` flag's parse-or-usage style.
    pub fn parse(s: &str) -> Result<ProtectionConfig, String> {
        fn mode(m: &str) -> Option<(Protection, bool)> {
            match m {
                "parity" => Some((Protection::Parity, false)),
                "ecc" => Some((Protection::Ecc { correct_cycles: ECC_CORRECT_CYCLES }, false)),
                "ecc+scrub" => {
                    Some((Protection::Ecc { correct_cycles: ECC_CORRECT_CYCLES }, true))
                }
                _ => None,
            }
        }
        let err = || {
            format!(
                "bad protection spec '{s}': expected a preset (parity | ecc | ecc+scrub) \
                 or a comma-separated CLASS=MODE list with classes rf|smem|l1|instr and \
                 modes parity|ecc|ecc+scrub, e.g. --protect ecc+scrub or \
                 --protect rf=ecc,smem=ecc+scrub,l1=parity"
            )
        };
        let mut cfg = ProtectionConfig::parity();
        for part in s.split(',') {
            let part = part.trim();
            if let Some((p, scrub)) = mode(part) {
                cfg.register_file = p;
                cfg.shared_mem = p;
                cfg.l1_tags = p;
                cfg.instr_image = p;
                if scrub {
                    cfg.scrubber = Some(Scrubber::default());
                }
                continue;
            }
            let Some((class, m)) = part.split_once('=') else {
                return Err(err());
            };
            let Some((p, scrub)) = mode(m.trim()) else {
                return Err(err());
            };
            match class.trim() {
                "rf" | "register-file" => cfg.register_file = p,
                "smem" | "shared" => cfg.shared_mem = p,
                "l1" | "l1-tags" => cfg.l1_tags = p,
                "instr" | "instr-image" => cfg.instr_image = p,
                _ => return Err(err()),
            }
            if scrub {
                cfg.scrubber = Some(Scrubber::default());
            }
        }
        Ok(cfg)
    }
}

/// How an upset ages: a transient flip happens once; a stuck-at defect
/// re-corrupts its word on every subsequent access until scrubbed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpsetKind {
    Transient,
    StuckAt,
}

/// Resolution of one upset (or one access to an aged site) under a
/// protection scheme. Pure decision logic — transliterated by
/// `tools/verify/fault_diff.py` so the correction table is verifiable
/// without a Rust toolchain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpsetOutcome {
    /// Unprotected silent class: the bit flips, nobody notices.
    SilentFlip,
    /// ECC detected and repaired the word in place, costing `cycles`.
    Corrected { cycles: u64 },
    /// Parity detected but cannot correct — `SimError::SoftError`.
    Detected,
    /// ECC saw a second bad bit in one word — detected, uncorrectable.
    Uncorrectable,
}

/// The SECDED/parity decision table: what happens when an upset (or an
/// aged-site re-corruption) hits a word of `target`'s class under
/// `protection`. `aged_site` = the word already carries an unscrubbed
/// stuck-at defect, so a fresh upset there makes two bad bits.
pub fn upset_outcome(
    protection: Protection,
    target: FaultTarget,
    aged_site: bool,
) -> UpsetOutcome {
    match protection {
        Protection::Ecc { correct_cycles } => {
            if aged_site {
                UpsetOutcome::Uncorrectable
            } else {
                UpsetOutcome::Corrected { cycles: correct_cycles }
            }
        }
        Protection::Parity => match target {
            FaultTarget::RegisterFile | FaultTarget::SharedMem => UpsetOutcome::SilentFlip,
            FaultTarget::L1Tags | FaultTarget::InstrImage => UpsetOutcome::Detected,
        },
    }
}

/// Counters for protected-upset handling, folded into `SmStats` (all
/// zero on fault-free or parity-silent runs, preserving `Eq` identity).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Upsets the protection logic saw: parity hits plus every ECC event
    /// (corrected or not).
    pub detected: u64,
    /// Single-bit upsets (and aged-site re-corruptions) ECC repaired.
    pub corrected: u64,
    /// Double-bit events ECC detected but could not repair.
    pub uncorrectable: u64,
    /// Aged stuck-at sites repaired by the background scrubber.
    pub scrubbed: u64,
}

impl FaultStats {
    pub fn merge(&mut self, other: &FaultStats) {
        self.detected += other.detected;
        self.corrected += other.corrected;
        self.uncorrectable += other.uncorrectable;
        self.scrubbed += other.scrubbed;
    }

    pub fn any(&self) -> bool {
        *self != FaultStats::default()
    }
}

/// A seeded soft-error campaign carried on a launch. Plans are plain
/// value types: the same plan on the same launch produces byte-identical
/// fault sites on every run and on both launch paths.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Campaign seed; each SM derives its stream from `(seed, sm_id)`.
    pub seed: u64,
    /// Expected upsets per million simulated cycles, per SM.
    pub rate: f64,
    /// Which structures may be upset.
    pub targets: FaultTargets,
    /// Per-class protection answering the upsets (default: parity —
    /// exactly the pre-ECC detect-or-silent split).
    pub protect: ProtectionConfig,
    /// Fraction of scheduled upsets that age into stuck-at sites
    /// (0.0 = all transient; the classification draw is skipped entirely
    /// at 0 so default plans keep the pinned RNG sequence).
    pub stuck_at_fraction: f64,
}

impl FaultPlan {
    /// A plan over every modeled structure.
    pub fn new(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan {
            seed,
            rate,
            targets: FaultTargets::all(),
            protect: ProtectionConfig::default(),
            stuck_at_fraction: 0.0,
        }
    }

    pub fn with_targets(mut self, targets: FaultTargets) -> FaultPlan {
        self.targets = targets;
        self
    }

    /// Answer this campaign with `protect` instead of default parity.
    pub fn with_protection(mut self, protect: ProtectionConfig) -> FaultPlan {
        self.protect = protect;
        self
    }

    /// Age `fraction` of upsets into stuck-at sites (clamped to [0, 1]).
    pub fn with_stuck_at(mut self, fraction: f64) -> FaultPlan {
        self.stuck_at_fraction = fraction.clamp(0.0, 1.0);
        self
    }

    /// An enabled plan constructs per-SM [`FaultState`]; a disabled one
    /// leaves the engine on its fault-free path.
    pub fn is_enabled(&self) -> bool {
        self.rate > 0.0 && self.targets.any()
    }
}

/// Where an upset landed — carried by `SimError::SoftError` for detected
/// upsets and by injection traces in tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Word `word` of resident slot `slot`'s register file on SM `sm`.
    Register { sm: u32, slot: u32, word: u32 },
    /// Word `word` of resident slot `slot`'s shared memory on SM `sm`.
    Shared { sm: u32, slot: u32, word: u32 },
    /// Tag entry `index` of SM `sm`'s L1 tag array.
    L1Tag { sm: u32, index: u32 },
    /// The pre-decoded image entry for `pc`, detected when SM `sm` issued
    /// from it.
    Instr { sm: u32, pc: u32 },
}

impl std::fmt::Display for FaultSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultSite::Register { sm, slot, word } => {
                write!(f, "SM {sm} register file (slot {slot}, word {word})")
            }
            FaultSite::Shared { sm, slot, word } => {
                write!(f, "SM {sm} shared memory (slot {slot}, word {word})")
            }
            FaultSite::L1Tag { sm, index } => {
                write!(f, "SM {sm} L1 tag array (entry {index})")
            }
            FaultSite::Instr { sm, pc } => {
                write!(f, "SM {sm} instruction image (pc={pc:#x})")
            }
        }
    }
}

/// One scheduled upset, before the engine resolves it to a concrete
/// [`FaultSite`]: a structure class, a raw site selector (reduced modulo
/// the live structure's size at the injection point), a bit index, and
/// its aging class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub target: FaultTarget,
    pub sel: u64,
    pub bit: u32,
    pub kind: UpsetKind,
}

/// Per-SM injection schedule. Built once per `Sm::run` from an enabled
/// plan; upset cycles are drawn from a uniform inter-arrival distribution
/// with mean `1e6 / rate` cycles.
#[derive(Debug, Clone)]
pub struct FaultState {
    rng: XorShift64,
    mean: u64,
    next_event: u64,
    kinds: [FaultTarget; 4],
    n_kinds: usize,
    /// Stuck-at classification threshold in parts per million; 0 skips
    /// the classification draw entirely (pinned-sequence compatibility).
    stuck_ppm: u64,
}

impl FaultState {
    /// `None` when the plan is disabled — the engine then carries no
    /// per-issue injection work at all.
    pub fn new(plan: &FaultPlan, sm_id: u32) -> Option<FaultState> {
        if !plan.is_enabled() {
            return None;
        }
        let stream = plan.seed ^ u64::from(sm_id + 1).wrapping_mul(SM_STREAM_MIX);
        let mut rng = XorShift64::new(stream);
        let mean = ((1_000_000.0 / plan.rate) as u64).max(1);
        let next_event = 1 + rng.below(2 * mean);
        let (kinds, n_kinds) = plan.targets.enabled();
        let stuck_ppm = (plan.stuck_at_fraction.clamp(0.0, 1.0) * PPM as f64) as u64;
        Some(FaultState { rng, mean, next_event, kinds, n_kinds, stuck_ppm })
    }

    /// Cycle of the next scheduled upset (test/diagnostic visibility).
    pub fn next_event(&self) -> u64 {
        self.next_event
    }

    /// Fires at most one upset per call: `Some(event)` when `cycle` has
    /// reached the scheduled upset, rescheduling the next one relative to
    /// `cycle`. The draw sequence depends only on `(seed, sm_id)` and the
    /// polled cycle values, which is what makes injection path-independent.
    /// Draw order per event is pinned: target, sel, bit, [aging class —
    /// only when `stuck_at_fraction > 0`], inter-arrival gap.
    pub fn poll(&mut self, cycle: u64) -> Option<FaultEvent> {
        if cycle < self.next_event {
            return None;
        }
        let target = self.kinds[self.rng.below(self.n_kinds as u64) as usize];
        let sel = self.rng.next_u64();
        let bit = (self.rng.next_u64() % 32) as u32;
        let kind = if self.stuck_ppm > 0 && self.rng.below(PPM) < self.stuck_ppm {
            UpsetKind::StuckAt
        } else {
            UpsetKind::Transient
        };
        self.next_event = cycle + 1 + self.rng.below(2 * self.mean);
        Some(FaultEvent { target, sel, bit, kind })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_plans_build_no_state() {
        assert!(FaultState::new(&FaultPlan::new(1, 0.0), 0).is_none());
        let no_targets = FaultPlan::new(1, 50.0).with_targets(FaultTargets::none());
        assert!(!no_targets.is_enabled());
        assert!(FaultState::new(&no_targets, 0).is_none());
        assert!(FaultPlan::new(1, 50.0).is_enabled());
    }

    /// Pinned against the transliterated model in
    /// `tools/verify/fault_diff.py` — if either side drifts, the
    /// cross-language determinism contract is broken.
    #[test]
    fn schedule_matches_pinned_golden_constants() {
        let plan = FaultPlan::new(0xC0FFEE, 100.0);
        let mut fs = FaultState::new(&plan, 0).unwrap();
        assert_eq!(fs.mean, 10_000);
        assert_eq!(fs.next_event(), 12_812);

        let expected = [
            (12_812u64, FaultTarget::RegisterFile, 0x097a_8c1c_8963_a82f_u64, 0u32),
            (14_584, FaultTarget::SharedMem, 0xf355_dfb0_5de6_d9df, 24),
            (22_709, FaultTarget::L1Tags, 0xd5c6_d2d5_a0bf_a0c3, 2),
            (24_679, FaultTarget::SharedMem, 0x1f5b_df16_4719_bbf4, 13),
        ];
        for (cycle, target, sel, bit) in expected {
            assert_eq!(fs.poll(cycle - 1), None);
            let ev = fs.poll(cycle).expect("event due");
            assert_eq!(ev.target, target);
            assert_eq!(ev.sel, sel);
            assert_eq!(ev.bit, bit);
            // Default plans never age: the classification draw is skipped.
            assert_eq!(ev.kind, UpsetKind::Transient);
        }

        // A different SM id on the same plan gets a different stream.
        let fs1 = FaultState::new(&plan, 1).unwrap();
        assert_eq!(fs1.next_event(), 6_986);
    }

    /// The aging plan's schedule, pinned against the same Python mirror:
    /// the first event shares the default plan's (cycle, target, sel,
    /// bit) — the classification draw comes *after* the bit draw — and
    /// everything after diverges because of that extra draw.
    #[test]
    fn stuck_at_schedule_matches_pinned_golden_constants() {
        let plan = FaultPlan::new(0xC0FFEE, 100.0).with_stuck_at(0.3);
        let mut fs = FaultState::new(&plan, 0).unwrap();
        assert_eq!(fs.next_event(), 12_812, "schedule start is aging-independent");

        let expected = [
            (12_812u64, FaultTarget::RegisterFile, 0x097a_8c1c_8963_a82f_u64, 0u32, UpsetKind::Transient),
            (21_610, FaultTarget::InstrImage, 0xe17a_7115_d43e_80b8, 28, UpsetKind::StuckAt),
            (21_966, FaultTarget::L1Tags, 0x63d3_ed82_c059_4791, 9, UpsetKind::Transient),
            (26_812, FaultTarget::L1Tags, 0x08bd_de03_1d98_9757, 28, UpsetKind::Transient),
            (32_664, FaultTarget::RegisterFile, 0xebf8_89d2_0144_4b61, 24, UpsetKind::Transient),
            (38_975, FaultTarget::SharedMem, 0x95d8_2dbd_a9e0_ce64, 2, UpsetKind::Transient),
        ];
        for (cycle, target, sel, bit, kind) in expected {
            assert_eq!(fs.poll(cycle - 1), None);
            let ev = fs.poll(cycle).expect("event due");
            assert_eq!((ev.target, ev.sel, ev.bit, ev.kind), (target, sel, bit, kind));
        }
    }

    #[test]
    fn stuck_fraction_matches_the_draw_over_many_events() {
        let plan = FaultPlan::new(0xC0FFEE, 100.0).with_stuck_at(0.3);
        let mut fs = FaultState::new(&plan, 0).unwrap();
        let mut stuck = 0u32;
        let total = 4_000;
        for _ in 0..total {
            let ev = fs.poll(fs.next_event()).unwrap();
            if ev.kind == UpsetKind::StuckAt {
                stuck += 1;
            }
        }
        // Pinned empirical value from the Python mirror (deterministic).
        assert_eq!(stuck, 1_211, "observed stuck fraction ~0.30275");
    }

    #[test]
    fn same_seed_same_schedule_across_instances() {
        let plan = FaultPlan::new(42, 250.0);
        let mut a = FaultState::new(&plan, 3).unwrap();
        let mut b = FaultState::new(&plan, 3).unwrap();
        let mut cycle = 0;
        for _ in 0..64 {
            cycle = a.next_event();
            assert_eq!(a.poll(cycle), b.poll(cycle));
        }
        assert!(cycle > 0);
    }

    #[test]
    fn target_order_is_pinned() {
        let (kinds, n) = FaultTargets::all().enabled();
        assert_eq!(n, 4);
        assert_eq!(
            &kinds[..n],
            &[
                FaultTarget::RegisterFile,
                FaultTarget::SharedMem,
                FaultTarget::L1Tags,
                FaultTarget::InstrImage,
            ]
        );
        let (kinds, n) = FaultTargets::detected().enabled();
        assert_eq!(&kinds[..n], &[FaultTarget::L1Tags, FaultTarget::InstrImage]);
        let (kinds, n) = FaultTargets::silent().enabled();
        assert_eq!(&kinds[..n], &[FaultTarget::RegisterFile, FaultTarget::SharedMem]);
    }

    #[test]
    fn poll_only_fires_once_per_due_cycle() {
        let plan = FaultPlan::new(7, 1000.0);
        let mut fs = FaultState::new(&plan, 0).unwrap();
        let due = fs.next_event();
        assert!(fs.poll(due).is_some());
        // Rescheduled strictly into the future.
        assert!(fs.next_event() > due);
        assert_eq!(fs.poll(due), None);
    }

    #[test]
    fn upset_outcome_table_is_pinned() {
        use FaultTarget::*;
        use UpsetOutcome::*;
        let par = Protection::Parity;
        let ecc = Protection::Ecc { correct_cycles: 5 };
        // Parity: silent classes flip, detected classes abort; aging is
        // invisible to the decision (the re-corruption loop handles it).
        for aged in [false, true] {
            assert_eq!(upset_outcome(par, RegisterFile, aged), SilentFlip);
            assert_eq!(upset_outcome(par, SharedMem, aged), SilentFlip);
            assert_eq!(upset_outcome(par, L1Tags, aged), Detected);
            assert_eq!(upset_outcome(par, InstrImage, aged), Detected);
        }
        // ECC: fresh single-bit corrects at the configured cost; a second
        // bit at an aged site is uncorrectable, regardless of class.
        for t in [RegisterFile, SharedMem, L1Tags, InstrImage] {
            assert_eq!(upset_outcome(ecc, t, false), Corrected { cycles: 5 });
            assert_eq!(upset_outcome(ecc, t, true), Uncorrectable);
        }
    }

    #[test]
    fn protection_presets_and_parse() {
        assert_eq!(ProtectionConfig::parity(), ProtectionConfig::default());
        let ecc = ProtectionConfig::ecc();
        assert_eq!(ecc.register_file, Protection::Ecc { correct_cycles: ECC_CORRECT_CYCLES });
        assert!(ecc.scrubber.is_none());
        assert!(ProtectionConfig::ecc_scrub().scrubber.is_some());

        assert_eq!(ProtectionConfig::parse("parity").unwrap(), ProtectionConfig::parity());
        assert_eq!(ProtectionConfig::parse("ecc").unwrap(), ProtectionConfig::ecc());
        assert_eq!(ProtectionConfig::parse("ecc+scrub").unwrap(), ProtectionConfig::ecc_scrub());

        let mixed = ProtectionConfig::parse("rf=ecc,smem=ecc+scrub,l1=parity").unwrap();
        assert_eq!(mixed.register_file, Protection::Ecc { correct_cycles: ECC_CORRECT_CYCLES });
        assert_eq!(mixed.shared_mem, Protection::Ecc { correct_cycles: ECC_CORRECT_CYCLES });
        assert_eq!(mixed.l1_tags, Protection::Parity);
        assert_eq!(mixed.instr_image, Protection::Parity);
        assert!(mixed.scrubber.is_some());

        for bad in ["", "eec", "rf=", "rf=parity2", "bogus=ecc"] {
            let e = ProtectionConfig::parse(bad).unwrap_err();
            assert!(e.contains("parity | ecc | ecc+scrub"), "{e}");
            assert!(e.contains("e.g."), "{e}");
        }
    }

    #[test]
    fn stuck_fraction_is_clamped_and_zero_is_free() {
        let p = FaultPlan::new(1, 10.0).with_stuck_at(7.5);
        assert_eq!(p.stuck_at_fraction, 1.0);
        let p = FaultPlan::new(1, 10.0).with_stuck_at(-1.0);
        assert_eq!(p.stuck_at_fraction, 0.0);
        // Zero fraction: identical draw sequence to a default plan.
        let base = FaultPlan::new(9, 500.0);
        let zero = FaultPlan::new(9, 500.0).with_stuck_at(0.0);
        let mut a = FaultState::new(&base, 2).unwrap();
        let mut b = FaultState::new(&zero, 2).unwrap();
        for _ in 0..32 {
            let c = a.next_event();
            assert_eq!(a.poll(c), b.poll(c));
        }
    }
}
