//! Deterministic SEU (single-event-upset) fault injection.
//!
//! The paper's soft GPGPU lives entirely in FPGA fabric — BRAMs hold the
//! register file, shared memory, cache tags and the pre-decoded
//! instruction image, exactly the structures embedded deployments lose
//! bits in. This module models those upsets *deterministically*: a
//! [`FaultPlan`] (seed + rate + target set) rides on a launch, and each
//! SM derives its private upset schedule from `(plan.seed, sm_id)` plus
//! its own simulated-cycle stream. Because the per-SM cycle streams are
//! identical on the sequential and parallel launch paths (the
//! bit-equivalence contract pinned by `tests/parallel_launch.rs`), fault
//! sites are identical on both paths too — same seed ⇒ byte-identical
//! upsets, reproducible in a test or a bug report.
//!
//! Detection is split the way real parity/ECC splits it:
//! - **tag array / instruction image** upsets are *detected* (those BRAMs
//!   carry parity in the modeled hardware) and surface as
//!   `SimError::SoftError` — the service plane can retry;
//! - **register file / shared memory** upsets corrupt *silently* — only
//!   output verification or dual-modular redundancy can catch them,
//!   which is the point of modeling them.
//!
//! A disabled plan (absent, rate 0, or no targets) never constructs a
//! [`FaultState`], so the engine's only overhead is one `Option` branch
//! per issue — provably bit- and cycle-identical to the fault-free
//! engine (`tests/fault_injection.rs`).

use crate::rng::XorShift64;

/// Golden-ratio mixing constant for per-SM stream separation.
const SM_STREAM_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// Which modeled BRAM structures the injector may upset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultTargets {
    /// Per-block register file (silent corruption).
    pub register_file: bool,
    /// Per-block shared memory (silent corruption).
    pub shared_mem: bool,
    /// L1 tag array (parity-detected; no-op on tagless/flat memory).
    pub l1_tags: bool,
    /// Pre-decoded instruction image (parity-detected at issue).
    pub instr_image: bool,
}

impl FaultTargets {
    /// Every modeled structure.
    pub fn all() -> FaultTargets {
        FaultTargets {
            register_file: true,
            shared_mem: true,
            l1_tags: true,
            instr_image: true,
        }
    }

    /// No structure — combined with any rate this disables injection.
    pub fn none() -> FaultTargets {
        FaultTargets {
            register_file: false,
            shared_mem: false,
            l1_tags: false,
            instr_image: false,
        }
    }

    /// Only the silently-corrupting structures (register file + shared
    /// memory) — the class only DMR or output verification catches.
    pub fn silent() -> FaultTargets {
        FaultTargets { register_file: true, shared_mem: true, ..FaultTargets::none() }
    }

    /// Only the parity-detected structures (tags + instruction image).
    pub fn detected() -> FaultTargets {
        FaultTargets { l1_tags: true, instr_image: true, ..FaultTargets::none() }
    }

    pub fn any(&self) -> bool {
        self.register_file || self.shared_mem || self.l1_tags || self.instr_image
    }

    /// Enabled targets in pinned declaration order — the order is part of
    /// the deterministic contract (mirrored by `tools/verify/fault_diff.py`).
    fn enabled(&self) -> ([FaultTarget; 4], usize) {
        let mut kinds = [FaultTarget::RegisterFile; 4];
        let mut n = 0;
        if self.register_file {
            kinds[n] = FaultTarget::RegisterFile;
            n += 1;
        }
        if self.shared_mem {
            kinds[n] = FaultTarget::SharedMem;
            n += 1;
        }
        if self.l1_tags {
            kinds[n] = FaultTarget::L1Tags;
            n += 1;
        }
        if self.instr_image {
            kinds[n] = FaultTarget::InstrImage;
            n += 1;
        }
        (kinds, n)
    }
}

/// A structure class an upset can land in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultTarget {
    RegisterFile,
    SharedMem,
    L1Tags,
    InstrImage,
}

/// A seeded soft-error campaign carried on a launch. Plans are plain
/// value types: the same plan on the same launch produces byte-identical
/// fault sites on every run and on both launch paths.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Campaign seed; each SM derives its stream from `(seed, sm_id)`.
    pub seed: u64,
    /// Expected upsets per million simulated cycles, per SM.
    pub rate: f64,
    /// Which structures may be upset.
    pub targets: FaultTargets,
}

impl FaultPlan {
    /// A plan over every modeled structure.
    pub fn new(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { seed, rate, targets: FaultTargets::all() }
    }

    pub fn with_targets(mut self, targets: FaultTargets) -> FaultPlan {
        self.targets = targets;
        self
    }

    /// An enabled plan constructs per-SM [`FaultState`]; a disabled one
    /// leaves the engine on its fault-free path.
    pub fn is_enabled(&self) -> bool {
        self.rate > 0.0 && self.targets.any()
    }
}

/// Where an upset landed — carried by `SimError::SoftError` for detected
/// upsets and by injection traces in tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Word `word` of resident slot `slot`'s register file on SM `sm`.
    Register { sm: u32, slot: u32, word: u32 },
    /// Word `word` of resident slot `slot`'s shared memory on SM `sm`.
    Shared { sm: u32, slot: u32, word: u32 },
    /// Tag entry `index` of SM `sm`'s L1 tag array.
    L1Tag { sm: u32, index: u32 },
    /// The pre-decoded image entry for `pc`, detected when SM `sm` issued
    /// from it.
    Instr { sm: u32, pc: u32 },
}

impl std::fmt::Display for FaultSite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultSite::Register { sm, slot, word } => {
                write!(f, "SM {sm} register file (slot {slot}, word {word})")
            }
            FaultSite::Shared { sm, slot, word } => {
                write!(f, "SM {sm} shared memory (slot {slot}, word {word})")
            }
            FaultSite::L1Tag { sm, index } => {
                write!(f, "SM {sm} L1 tag array (entry {index})")
            }
            FaultSite::Instr { sm, pc } => {
                write!(f, "SM {sm} instruction image (pc={pc:#x})")
            }
        }
    }
}

/// One scheduled upset, before the engine resolves it to a concrete
/// [`FaultSite`]: a structure class, a raw site selector (reduced modulo
/// the live structure's size at the injection point) and a bit index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    pub target: FaultTarget,
    pub sel: u64,
    pub bit: u32,
}

/// Per-SM injection schedule. Built once per `Sm::run` from an enabled
/// plan; upset cycles are drawn from a uniform inter-arrival distribution
/// with mean `1e6 / rate` cycles.
#[derive(Debug, Clone)]
pub struct FaultState {
    rng: XorShift64,
    mean: u64,
    next_event: u64,
    kinds: [FaultTarget; 4],
    n_kinds: usize,
}

impl FaultState {
    /// `None` when the plan is disabled — the engine then carries no
    /// per-issue injection work at all.
    pub fn new(plan: &FaultPlan, sm_id: u32) -> Option<FaultState> {
        if !plan.is_enabled() {
            return None;
        }
        let stream = plan.seed ^ u64::from(sm_id + 1).wrapping_mul(SM_STREAM_MIX);
        let mut rng = XorShift64::new(stream);
        let mean = ((1_000_000.0 / plan.rate) as u64).max(1);
        let next_event = 1 + rng.below(2 * mean);
        let (kinds, n_kinds) = plan.targets.enabled();
        Some(FaultState { rng, mean, next_event, kinds, n_kinds })
    }

    /// Cycle of the next scheduled upset (test/diagnostic visibility).
    pub fn next_event(&self) -> u64 {
        self.next_event
    }

    /// Fires at most one upset per call: `Some(event)` when `cycle` has
    /// reached the scheduled upset, rescheduling the next one relative to
    /// `cycle`. The draw sequence depends only on `(seed, sm_id)` and the
    /// polled cycle values, which is what makes injection path-independent.
    pub fn poll(&mut self, cycle: u64) -> Option<FaultEvent> {
        if cycle < self.next_event {
            return None;
        }
        let target = self.kinds[self.rng.below(self.n_kinds as u64) as usize];
        let sel = self.rng.next_u64();
        let bit = (self.rng.next_u64() % 32) as u32;
        self.next_event = cycle + 1 + self.rng.below(2 * self.mean);
        Some(FaultEvent { target, sel, bit })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_plans_build_no_state() {
        assert!(FaultState::new(&FaultPlan::new(1, 0.0), 0).is_none());
        let no_targets = FaultPlan::new(1, 50.0).with_targets(FaultTargets::none());
        assert!(!no_targets.is_enabled());
        assert!(FaultState::new(&no_targets, 0).is_none());
        assert!(FaultPlan::new(1, 50.0).is_enabled());
    }

    /// Pinned against the transliterated model in
    /// `tools/verify/fault_diff.py` — if either side drifts, the
    /// cross-language determinism contract is broken.
    #[test]
    fn schedule_matches_pinned_golden_constants() {
        let plan = FaultPlan::new(0xC0FFEE, 100.0);
        let mut fs = FaultState::new(&plan, 0).unwrap();
        assert_eq!(fs.mean, 10_000);
        assert_eq!(fs.next_event(), 12_812);

        let expected = [
            (12_812u64, FaultTarget::RegisterFile, 0x097a_8c1c_8963_a82f_u64, 0u32),
            (14_584, FaultTarget::SharedMem, 0xf355_dfb0_5de6_d9df, 24),
            (22_709, FaultTarget::L1Tags, 0xd5c6_d2d5_a0bf_a0c3, 2),
            (24_679, FaultTarget::SharedMem, 0x1f5b_df16_4719_bbf4, 13),
        ];
        for (cycle, target, sel, bit) in expected {
            assert_eq!(fs.poll(cycle - 1), None);
            let ev = fs.poll(cycle).expect("event due");
            assert_eq!(ev.target, target);
            assert_eq!(ev.sel, sel);
            assert_eq!(ev.bit, bit);
        }

        // A different SM id on the same plan gets a different stream.
        let fs1 = FaultState::new(&plan, 1).unwrap();
        assert_eq!(fs1.next_event(), 6_986);
    }

    #[test]
    fn same_seed_same_schedule_across_instances() {
        let plan = FaultPlan::new(42, 250.0);
        let mut a = FaultState::new(&plan, 3).unwrap();
        let mut b = FaultState::new(&plan, 3).unwrap();
        let mut cycle = 0;
        for _ in 0..64 {
            cycle = a.next_event();
            assert_eq!(a.poll(cycle), b.poll(cycle));
        }
        assert!(cycle > 0);
    }

    #[test]
    fn target_order_is_pinned() {
        let (kinds, n) = FaultTargets::all().enabled();
        assert_eq!(n, 4);
        assert_eq!(
            &kinds[..n],
            &[
                FaultTarget::RegisterFile,
                FaultTarget::SharedMem,
                FaultTarget::L1Tags,
                FaultTarget::InstrImage,
            ]
        );
        let (kinds, n) = FaultTargets::detected().enabled();
        assert_eq!(&kinds[..n], &[FaultTarget::L1Tags, FaultTarget::InstrImage]);
        let (kinds, n) = FaultTargets::silent().enabled();
        assert_eq!(&kinds[..n], &[FaultTarget::RegisterFile, FaultTarget::SharedMem]);
    }

    #[test]
    fn poll_only_fires_once_per_due_cycle() {
        let plan = FaultPlan::new(7, 1000.0);
        let mut fs = FaultState::new(&plan, 0).unwrap();
        let due = fs.next_event();
        assert!(fs.poll(due).is_some());
        // Rescheduled strictly into the future.
        assert!(fs.next_event() > due);
        assert_eq!(fs.poll(due), None);
    }
}
