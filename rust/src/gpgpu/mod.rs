//! The FlexGrip GPGPU top level: block scheduler + one or more streaming
//! multiprocessors (paper §3.1, §4.3).
//!
//! # Execution model: partition → simulate → merge
//!
//! Every kernel launch runs in three phases:
//!
//! 1. **Partition** — the block scheduler validates the configuration and
//!    kernel resources, runs pre-flight admission against the kernel's
//!    [`CapabilitySignature`] (a §4.2 capability the customized device
//!    lacks rejects the launch with [`SimError::Unsupported`] before any
//!    simulation — `Gpgpu::supports` is the query form), then deals
//!    thread blocks round-robin across SMs ("the block scheduler logic
//!    equally and automatically distributed thread blocks to the 2 SMs",
//!    §5.1.1).
//! 2. **Simulate** — each SM executes its block queue to completion.
//!    [`Gpgpu::launch`] simulates the SMs sequentially against the shared
//!    [`GlobalMem`] (the reference path, usable with any
//!    `&mut dyn AluBackend`). [`Gpgpu::launch_parallel`] instead runs each
//!    SM on its own scoped OS thread: every SM gets a private
//!    copy-on-write [`GmemSnapshot`] (reads fall through to the shared
//!    launch-time base; the first store to a 1 KiB page faults in a
//!    private copy; every store is logged) and its own ALU built from an
//!    [`AluFactory`], so no mutable simulation state is shared between
//!    threads and per-SM setup is O(touched pages), not O(mem).
//!
//!    Trait objects stop at this boundary: inside the simulate phase the
//!    engine is monomorphized over the concrete memory port and — when
//!    [`AluBackend::is_native`] — the concrete [`NativeAlu`], so the
//!    per-lane hot loops inline (EXPERIMENTS.md §Perf).
//! 3. **Merge** — per-SM statistics are aggregated (`cycles` = max over
//!    SMs, since real SMs run concurrently; counters summed). On the
//!    parallel path the write logs are additionally replayed into the real
//!    `GlobalMem` in SM-id order, and any global address stored by two
//!    different SMs raises [`SimError::WriteConflict`].
//!
//! The parallel path is bit-equivalent to the sequential path (identical
//! memory image and identical simulated cycles) for kernels whose SMs
//! write disjoint addresses and never read another SM's writes within one
//! launch — true of all five paper benchmarks. The *write-disjointness*
//! half of that contract is checked per launch by the conflict detector;
//! a cross-SM read of data another SM wrote in the same launch has no
//! write overlap, so it is **not** detectable — such kernels read the
//! launch-time snapshot and must use the sequential [`Gpgpu::launch`]
//! (or split the dependency across launches, as reduction's two phases
//! do). Inter-SM memory contention is not modelled (DESIGN.md §5).

pub mod limits;

pub use limits::KernelResources;

use crate::asm::Kernel;
use crate::isa::CapabilitySignature;
use crate::registry::PreparedKernel;
use crate::sim::{
    AluBackend, AluFactory, BlockDesc, GlobalMem, GmemPort, GmemSnapshot, NativeAlu, PreDecoded,
    SimError, Sm, SmConfig, SmStats, WriteRecord,
};
use std::collections::HashMap;

/// Run one SM with the hot path monomorphized as far as the boundary
/// allows: `G` is always a concrete memory port here (the shared
/// [`GlobalMem`] or a per-thread [`GmemSnapshot`]), and a backend that
/// reports [`AluBackend::is_native`] is swapped for a concrete
/// [`NativeAlu`] so the default configuration runs fully inlined. Only
/// genuinely foreign backends (e.g. the XLA executor) pay dyn dispatch —
/// once per warp instruction, never per lane.
#[allow(clippy::too_many_arguments)]
fn run_sm<G: GmemPort>(
    sm: &Sm,
    pre: &PreDecoded,
    regs_per_thread: u32,
    smem_bytes: u32,
    params: &[i32],
    blocks: &[BlockDesc],
    max_resident: usize,
    gmem: &mut G,
    alu: &mut dyn AluBackend,
) -> Result<SmStats, SimError> {
    if alu.is_native() {
        let mut native = NativeAlu;
        sm.run(pre, regs_per_thread, smem_bytes, params, blocks, max_resident, gmem, &mut native)
    } else {
        sm.run(pre, regs_per_thread, smem_bytes, params, blocks, max_resident, gmem, alu)
    }
}

/// Overlay clock: "All designs were evaluated at 100 MHz" (paper §5.1).
pub const CLOCK_HZ: f64 = 100e6;

/// Whole-GPGPU configuration: the SM microarchitecture plus how many SMs
/// are instantiated (the paper evaluates 1 and 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpgpuConfig {
    pub sm: SmConfig,
    pub num_sms: u32,
}

impl GpgpuConfig {
    pub fn new(num_sms: u32, num_sp: u32) -> GpgpuConfig {
        GpgpuConfig { sm: SmConfig::baseline().with_sp(num_sp), num_sms }
    }

    /// Validate the device configuration. All capability/limit checks
    /// live in `sim` ([`crate::sim::validate_device`]); this is a pure
    /// delegation so the two layers cannot drift.
    pub fn validate(&self) -> Result<(), SimError> {
        crate::sim::validate_device(&self.sm, self.num_sms)
    }

    pub fn label(&self) -> String {
        format!("{} SM, {} SP", self.num_sms, self.sm.num_sp)
    }
}

impl Default for GpgpuConfig {
    fn default() -> Self {
        GpgpuConfig::new(1, 8)
    }
}

/// Kernel launch geometry (grid may be 2-D; blocks are linear, <=256
/// threads, paper §4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaunchConfig {
    pub grid_x: u32,
    pub grid_y: u32,
    pub block_threads: u32,
}

impl LaunchConfig {
    pub fn linear(grid: u32, block_threads: u32) -> LaunchConfig {
        LaunchConfig { grid_x: grid, grid_y: 1, block_threads }
    }

    pub fn num_blocks(&self) -> u32 {
        self.grid_x * self.grid_y
    }

    pub fn total_threads(&self) -> u64 {
        self.num_blocks() as u64 * self.block_threads as u64
    }
}

/// Result of one kernel launch.
#[derive(Debug, Clone)]
pub struct LaunchResult {
    /// Per-SM statistics (index = SM id).
    pub per_sm: Vec<SmStats>,
    /// Aggregate: `cycles` = max over SMs (they run concurrently),
    /// counters summed.
    pub total: SmStats,
    /// Resident-block limit the scheduler computed (paper §4.3).
    pub max_resident_blocks: u32,
}

impl LaunchResult {
    /// Kernel execution time in milliseconds at the 100 MHz overlay clock.
    pub fn exec_time_ms(&self) -> f64 {
        self.total.exec_time_ms(CLOCK_HZ)
    }
}

/// The soft GPGPU.
pub struct Gpgpu {
    pub cfg: GpgpuConfig,
}

impl Gpgpu {
    pub fn new(cfg: GpgpuConfig) -> Gpgpu {
        Gpgpu { cfg }
    }

    /// The public capability check: can this device *guaranteed* execute a
    /// kernel with signature `sig`? (Conservative — see
    /// [`SmConfig::covers`]; the coordinator's fleet router and callers
    /// choosing among customized variants use this.)
    pub fn supports(&self, sig: &CapabilitySignature) -> bool {
        self.cfg.sm.covers(sig)
    }

    /// Phase 1 (partition): validate the device, admit the kernel's
    /// capability signature (§4.2 — a provable mismatch is rejected with
    /// [`SimError::Unsupported`] *before* any simulation), compute the
    /// residency limit, and deal blocks round-robin across SMs.
    fn partition(
        &self,
        kernel: &Kernel,
        sig: &CapabilitySignature,
        launch: LaunchConfig,
    ) -> Result<(Vec<Vec<BlockDesc>>, u32), SimError> {
        self.cfg.validate()?;
        self.cfg.sm.admit(sig)?;
        let res = KernelResources {
            regs_per_thread: kernel.regs_per_thread,
            smem_bytes: kernel.smem_bytes,
            block_threads: launch.block_threads,
        };
        res.validate()?;
        if launch.num_blocks() == 0 {
            return Err(SimError::LimitExceeded("empty grid".into()));
        }
        let max_resident = res.max_resident_blocks();
        debug_assert!(max_resident >= 1);

        let mut assignments: Vec<Vec<BlockDesc>> =
            vec![Vec::new(); self.cfg.num_sms as usize];
        let mut i = 0usize;
        for by in 0..launch.grid_y {
            for bx in 0..launch.grid_x {
                assignments[i % self.cfg.num_sms as usize].push(BlockDesc {
                    ctaid_x: bx,
                    ctaid_y: by,
                    nctaid_x: launch.grid_x,
                    nctaid_y: launch.grid_y,
                    ntid: launch.block_threads,
                });
                i += 1;
            }
        }
        Ok((assignments, max_resident))
    }

    /// Phase 3 (merge): aggregate per-SM statistics into a launch result.
    fn merge_stats(per_sm: Vec<SmStats>, max_resident: u32) -> LaunchResult {
        let mut total = SmStats::default();
        for s in &per_sm {
            total.merge(s);
        }
        LaunchResult { per_sm, total, max_resident_blocks: max_resident }
    }

    /// Launch `kernel` over `launch` geometry — the sequential reference
    /// path: SMs are simulated one after another against the shared global
    /// memory, all through the single `alu` backend. Kernel time is the
    /// max of the per-SM busy times.
    ///
    /// Derives the capability signature and micro-op lowering on the
    /// spot; repeat launches should go through a
    /// [`crate::registry::KernelRegistry`] and [`Gpgpu::launch_prepared`]
    /// to skip that work.
    pub fn launch(
        &self,
        kernel: &Kernel,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        alu: &mut dyn AluBackend,
    ) -> Result<LaunchResult, SimError> {
        let sig = kernel.signature();
        let (assignments, max_resident) = self.partition(kernel, &sig, launch)?;
        let pre = PreDecoded::from_kernel(kernel);
        self.simulate_seq(kernel, &pre, &assignments, max_resident, params, gmem, alu)
    }

    /// [`Gpgpu::launch`] for a registry-cached kernel: admission reads the
    /// cached signature and simulation reuses the cached pre-decode, so a
    /// repeat launch does no per-launch kernel analysis at all.
    pub fn launch_prepared(
        &self,
        pk: &PreparedKernel,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        alu: &mut dyn AluBackend,
    ) -> Result<LaunchResult, SimError> {
        self.launch_admitted(pk, &pk.sig, launch, params, gmem, alu)
    }

    /// [`Gpgpu::launch_prepared`] with an explicit admission signature —
    /// normally a profile-refined one (paper §4.1). The coordinator's
    /// routed launches admit on exactly the signature the router used, so
    /// refinement can never self-reject a job on the variant it chose; if
    /// the profile over-promised, the mid-run removed-unit trap (same
    /// structured [`SimError::Unsupported`] payload) and the runtime
    /// stack-overflow trap remain the backstop.
    pub fn launch_admitted(
        &self,
        pk: &PreparedKernel,
        sig: &CapabilitySignature,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        alu: &mut dyn AluBackend,
    ) -> Result<LaunchResult, SimError> {
        let (assignments, max_resident) = self.partition(&pk.kernel, sig, launch)?;
        self.simulate_seq(&pk.kernel, &pk.pre, &assignments, max_resident, params, gmem, alu)
    }

    /// Phase 2+3 of the sequential path.
    #[allow(clippy::too_many_arguments)]
    fn simulate_seq(
        &self,
        kernel: &Kernel,
        pre: &PreDecoded,
        assignments: &[Vec<BlockDesc>],
        max_resident: u32,
        params: &[i32],
        gmem: &mut GlobalMem,
        alu: &mut dyn AluBackend,
    ) -> Result<LaunchResult, SimError> {
        let mut per_sm = Vec::with_capacity(self.cfg.num_sms as usize);
        for (sm_id, blocks) in assignments.iter().enumerate() {
            let sm = Sm::new(self.cfg.sm, sm_id as u32);
            let stats = if blocks.is_empty() {
                SmStats::default()
            } else {
                run_sm(
                    &sm,
                    pre,
                    kernel.regs_per_thread,
                    kernel.smem_bytes,
                    params,
                    blocks,
                    max_resident as usize,
                    gmem,
                    alu,
                )?
            };
            per_sm.push(stats);
        }
        Ok(Self::merge_stats(per_sm, max_resident))
    }

    /// Launch `kernel` with each SM simulated on its own scoped thread —
    /// the wall-clock-parallel path.
    ///
    /// Each SM thread owns an ALU built by `factory` and a private
    /// [`GmemSnapshot`] of `gmem`; after every SM completes, the write
    /// logs are replayed into `gmem` in SM-id order, raising
    /// [`SimError::WriteConflict`] if two SMs stored the same address.
    /// For conflict-free kernels the result (memory image, per-SM stats,
    /// simulated cycles) is identical to [`Gpgpu::launch`].
    pub fn launch_parallel(
        &self,
        kernel: &Kernel,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<LaunchResult, SimError> {
        let sig = kernel.signature();
        let (assignments, max_resident) = self.partition(kernel, &sig, launch)?;
        let pre = PreDecoded::from_kernel(kernel);
        self.simulate_par(kernel, &pre, &assignments, max_resident, params, gmem, factory)
    }

    /// [`Gpgpu::launch_parallel`] for a registry-cached kernel (cached
    /// signature + pre-decode, like [`Gpgpu::launch_prepared`]).
    pub fn launch_parallel_prepared(
        &self,
        pk: &PreparedKernel,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<LaunchResult, SimError> {
        self.launch_parallel_admitted(pk, &pk.sig, launch, params, gmem, factory)
    }

    /// [`Gpgpu::launch_parallel_prepared`] with an explicit admission
    /// signature (see [`Gpgpu::launch_admitted`]).
    pub fn launch_parallel_admitted(
        &self,
        pk: &PreparedKernel,
        sig: &CapabilitySignature,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<LaunchResult, SimError> {
        let (assignments, max_resident) = self.partition(&pk.kernel, sig, launch)?;
        self.simulate_par(&pk.kernel, &pk.pre, &assignments, max_resident, params, gmem, factory)
    }

    /// Phase 2+3 of the parallel path.
    #[allow(clippy::too_many_arguments)]
    fn simulate_par(
        &self,
        kernel: &Kernel,
        pre: &PreDecoded,
        assignments: &[Vec<BlockDesc>],
        max_resident: u32,
        params: &[i32],
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<LaunchResult, SimError> {
        if self.cfg.num_sms == 1 {
            // One SM: no partitioning benefit; skip the snapshot entirely.
            let mut alu = factory.make_alu();
            let sm = Sm::new(self.cfg.sm, 0);
            let stats = run_sm(
                &sm,
                pre,
                kernel.regs_per_thread,
                kernel.smem_bytes,
                params,
                &assignments[0],
                max_resident as usize,
                gmem,
                alu.as_mut(),
            )?;
            return Ok(Self::merge_stats(vec![stats], max_resident));
        }

        // Phase 2 (simulate): one scoped thread per SM, no shared mutable
        // state. `base` is the shared launch-time image; each thread reads
        // it through a private copy-on-write view.
        let base: &GlobalMem = gmem;
        let cfg = self.cfg;
        let regs = kernel.regs_per_thread;
        let smem = kernel.smem_bytes;
        let results: Vec<Result<(SmStats, Vec<WriteRecord>), SimError>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = assignments
                    .iter()
                    .enumerate()
                    .map(|(sm_id, blocks)| {
                        scope.spawn(move || {
                            if blocks.is_empty() {
                                return Ok((SmStats::default(), Vec::new()));
                            }
                            let sm = Sm::new(cfg.sm, sm_id as u32);
                            let mut alu = factory.make_alu();
                            // Copy-on-write view: setup is O(touched
                            // pages), not O(mem) — reads fall through to
                            // the shared base.
                            let mut view = GmemSnapshot::new(base);
                            let stats = run_sm(
                                &sm,
                                pre,
                                regs,
                                smem,
                                params,
                                blocks,
                                max_resident as usize,
                                &mut view,
                                alu.as_mut(),
                            )?;
                            Ok((stats, view.into_log()))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("SM simulation thread panicked"))
                    .collect()
            });

        // Phase 3 (merge): replay write logs deterministically in SM order,
        // detecting cross-SM conflicts, then aggregate statistics.
        let mut per_sm = Vec::with_capacity(results.len());
        let mut logs = Vec::with_capacity(results.len());
        for r in results {
            let (stats, log) = r?;
            per_sm.push(stats);
            logs.push(log);
        }
        merge_write_logs(gmem, &logs)?;
        Ok(Self::merge_stats(per_sm, max_resident))
    }
}

/// Replay per-SM write logs into `gmem` in SM-id order (within one SM,
/// program order is preserved by the log itself). An address written by
/// two different SMs is a violation of the parallel launch's
/// disjoint-write contract and raises [`SimError::WriteConflict`] —
/// detected in a scan pass *before* any write is applied, so a rejected
/// launch leaves `gmem` exactly as it was (callers may recover by falling
/// back to the sequential [`Gpgpu::launch`] on the same memory).
fn merge_write_logs(gmem: &mut GlobalMem, logs: &[Vec<WriteRecord>]) -> Result<(), SimError> {
    let mut writer: HashMap<u32, u32> = HashMap::new();
    for (sm_id, log) in logs.iter().enumerate() {
        let sm_id = sm_id as u32;
        for &(addr, _) in log {
            match writer.get(&addr) {
                Some(&first) if first != sm_id => {
                    return Err(SimError::WriteConflict {
                        addr,
                        first_sm: first,
                        second_sm: sm_id,
                    });
                }
                _ => {
                    writer.insert(addr, sm_id);
                }
            }
        }
    }
    for log in logs {
        for &(addr, value) in log {
            gmem.store(addr, value)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;
    use crate::sim::NativeAlu;

    /// out[gtid] = gtid * 2 (multi-block).
    const SRC: &str = r#"
        .entry double
        .regs 6
            S2R R1, SR_GTID
            SHL R2, R1, #2
            IADD R3, R1, R1
            GST [R2], R3
            EXIT
    "#;

    fn launch(cfg: GpgpuConfig, grid: u32, block: u32) -> (GlobalMem, LaunchResult) {
        let k = assemble(SRC).unwrap();
        let mut g = GlobalMem::new(grid * block * 4 + 64);
        let mut alu = NativeAlu;
        let r = Gpgpu::new(cfg)
            .launch(&k, LaunchConfig::linear(grid, block), &[], &mut g, &mut alu)
            .unwrap();
        (g, r)
    }

    fn launch_par(cfg: GpgpuConfig, grid: u32, block: u32) -> (GlobalMem, LaunchResult) {
        let k = assemble(SRC).unwrap();
        let mut g = GlobalMem::new(grid * block * 4 + 64);
        let r = Gpgpu::new(cfg)
            .launch_parallel(&k, LaunchConfig::linear(grid, block), &[], &mut g, &NativeAlu)
            .unwrap();
        (g, r)
    }

    #[test]
    fn multi_block_kernel_covers_grid() {
        let (g, r) = launch(GpgpuConfig::new(1, 8), 8, 64);
        for t in 0..512 {
            assert_eq!(g.load(t * 4).unwrap(), (t * 2) as i32);
        }
        assert_eq!(r.total.blocks, 8);
    }

    #[test]
    fn two_sms_split_blocks_and_halve_time() {
        let (_, r1) = launch(GpgpuConfig::new(1, 8), 8, 64);
        let (g2, r2) = launch(GpgpuConfig::new(2, 8), 8, 64);
        for t in 0..512 {
            assert_eq!(g2.load(t * 4).unwrap(), (t * 2) as i32);
        }
        assert_eq!(r2.per_sm[0].blocks, 4);
        assert_eq!(r2.per_sm[1].blocks, 4);
        let speedup = r1.total.cycles as f64 / r2.total.cycles as f64;
        assert!(
            speedup > 1.5 && speedup <= 2.05,
            "2 SM speedup out of range: {speedup}"
        );
    }

    #[test]
    fn odd_block_count_distributes_round_robin() {
        let (_, r) = launch(GpgpuConfig::new(2, 8), 5, 64);
        assert_eq!(r.per_sm[0].blocks, 3);
        assert_eq!(r.per_sm[1].blocks, 2);
    }

    #[test]
    fn residency_limit_reported() {
        let (_, r) = launch(GpgpuConfig::new(1, 8), 4, 256);
        assert_eq!(r.max_resident_blocks, 3); // 768 threads / 256
    }

    #[test]
    fn launch_rejects_oversized_block() {
        let k = assemble(SRC).unwrap();
        let mut g = GlobalMem::new(1024);
        let mut alu = NativeAlu;
        let err = Gpgpu::new(GpgpuConfig::default())
            .launch(&k, LaunchConfig::linear(1, 512), &[], &mut g, &mut alu)
            .unwrap_err();
        assert!(matches!(err, SimError::LimitExceeded(_)));
    }

    #[test]
    fn exec_time_uses_100mhz_clock() {
        let (_, r) = launch(GpgpuConfig::new(1, 8), 1, 32);
        let want = r.total.cycles as f64 / 100e6 * 1e3;
        assert!((r.exec_time_ms() - want).abs() < 1e-12);
    }

    #[test]
    fn parallel_launch_matches_sequential_bit_for_bit() {
        for (sms, grid, block) in [(1u32, 5u32, 64u32), (2, 8, 64), (2, 5, 50)] {
            let (gs, rs) = launch(GpgpuConfig::new(sms, 8), grid, block);
            let (gp, rp) = launch_par(GpgpuConfig::new(sms, 8), grid, block);
            assert_eq!(rs.total.cycles, rp.total.cycles, "{sms} SM cycles");
            assert_eq!(rs.total.instructions, rp.total.instructions);
            for sm in 0..sms as usize {
                assert_eq!(rs.per_sm[sm].cycles, rp.per_sm[sm].cycles, "SM {sm}");
                assert_eq!(rs.per_sm[sm].blocks, rp.per_sm[sm].blocks, "SM {sm}");
            }
            let words = (gs.size_bytes() / 4) as usize;
            assert_eq!(
                gs.read_words(0, words).unwrap(),
                gp.read_words(0, words).unwrap(),
                "memory image {sms} SM {grid}x{block}"
            );
        }
    }

    #[test]
    fn parallel_launch_detects_cross_sm_write_conflict() {
        // Every block stores to address 0 — blocks land on both SMs, so
        // the merge phase must flag the overlapping write.
        let k = assemble("MOV R1, #0\nMOV R2, #7\nGST [R1], R2\nEXIT").unwrap();
        let mut g = GlobalMem::new(4096);
        let err = Gpgpu::new(GpgpuConfig::new(2, 8))
            .launch_parallel(&k, LaunchConfig::linear(2, 32), &[], &mut g, &NativeAlu)
            .unwrap_err();
        assert!(
            matches!(err, SimError::WriteConflict { addr: 0, .. }),
            "want WriteConflict, got {err}"
        );
    }

    #[test]
    fn parallel_launch_propagates_sm_faults() {
        let k = assemble("JOIN\nEXIT").unwrap();
        let mut g = GlobalMem::new(4096);
        let err = Gpgpu::new(GpgpuConfig::new(2, 8))
            .launch_parallel(&k, LaunchConfig::linear(4, 32), &[], &mut g, &NativeAlu)
            .unwrap_err();
        assert!(matches!(err, SimError::StackUnderflow { .. }));
    }

    #[test]
    fn admission_rejects_before_simulation() {
        // A multiply kernel on a multiplier-less variant must be refused
        // at the launch boundary with the structured payload — device
        // memory untouched, nothing simulated.
        let k = assemble("S2R R1, SR_GTID\nIMUL R2, R1, R1\nGST [R1], R2\nEXIT").unwrap();
        let mut cfg = GpgpuConfig::new(1, 8);
        cfg.sm.has_multiplier = false;
        cfg.sm.read_operands = 2;
        let gp = Gpgpu::new(cfg);
        assert!(!gp.supports(&k.signature()));
        let mut g = GlobalMem::new(4096);
        let mut alu = NativeAlu;
        let err = gp
            .launch(&k, LaunchConfig::linear(1, 32), &[], &mut g, &mut alu)
            .unwrap_err();
        assert!(matches!(
            err,
            SimError::Unsupported {
                capability: crate::isa::Capability::Multiplier,
                pc: None,
                ..
            }
        ));
    }

    #[test]
    fn prepared_launch_matches_raw_launch() {
        use crate::registry::PreparedKernel;
        let pk = PreparedKernel::new(assemble(SRC).unwrap());
        let gp = Gpgpu::new(GpgpuConfig::new(2, 8));
        let (g_raw, r_raw) = launch(GpgpuConfig::new(2, 8), 6, 64);
        let mut g = GlobalMem::new(6 * 64 * 4 + 64);
        let mut alu = NativeAlu;
        let r = gp
            .launch_prepared(&pk, LaunchConfig::linear(6, 64), &[], &mut g, &mut alu)
            .unwrap();
        assert_eq!(r.total.cycles, r_raw.total.cycles);
        let words = (g.size_bytes() / 4) as usize;
        assert_eq!(g.read_words(0, words).unwrap(), g_raw.read_words(0, words).unwrap());

        let mut g2 = GlobalMem::new(6 * 64 * 4 + 64);
        let rp = gp
            .launch_parallel_prepared(&pk, LaunchConfig::linear(6, 64), &[], &mut g2, &NativeAlu)
            .unwrap();
        assert_eq!(rp.total.cycles, r_raw.total.cycles);
        assert_eq!(g2.read_words(0, words).unwrap(), g_raw.read_words(0, words).unwrap());
    }
}
