//! The FlexGrip GPGPU top level: block scheduler + one or more streaming
//! multiprocessors (paper §3.1, §4.3).
//!
//! # One entry point: [`Gpgpu::launch`] with a [`LaunchRequest`]
//!
//! Every kernel launch goes through the single [`Gpgpu::launch`] method.
//! A [`LaunchRequest`] bundles the kernel (raw [`Kernel`] or
//! registry-cached [`PreparedKernel`]), the geometry, the parameters, the
//! target [`GlobalMem`], and three optional knobs:
//!
//! * **execution mode** — default is sequential with the built-in
//!   [`NativeAlu`]; [`LaunchRequest::sequential`] supplies a foreign
//!   `&mut dyn AluBackend`; [`LaunchRequest::parallel`] /
//!   [`LaunchRequest::parallel_with`] run one scoped OS thread per SM;
//! * **admission signature** — [`LaunchRequest::admit`] overrides the
//!   kernel-derived [`CapabilitySignature`] with a profile-refined one
//!   (the coordinator's routed launches use this);
//! * **memory hierarchy** — [`LaunchRequest::memory`] overrides the
//!   device's [`MemoryConfig`] (flat AXI vs. per-SM L1/BRAM cache).
//!
//! The pre-redesign entry points (`launch_prepared`, `launch_admitted`,
//! `launch_parallel`, `launch_parallel_prepared`,
//! `launch_parallel_admitted`) survive as thin `#[deprecated]` shims over
//! the same request type.
//!
//! # Execution model: partition → simulate → merge
//!
//! 1. **Partition** — the block scheduler validates the configuration and
//!    kernel resources, runs pre-flight admission against the kernel's
//!    [`CapabilitySignature`] (a §4.2 capability the customized device
//!    lacks rejects the launch with [`SimError::Unsupported`] before any
//!    simulation — `Gpgpu::supports` is the query form), then deals
//!    thread blocks round-robin across SMs ("the block scheduler logic
//!    equally and automatically distributed thread blocks to the 2 SMs",
//!    §5.1.1).
//! 2. **Simulate** — each SM executes its block queue to completion.
//!    Sequential mode simulates the SMs one after another against the
//!    shared [`GlobalMem`] (the reference path). Parallel mode runs each
//!    SM on its own scoped OS thread: every SM gets a private
//!    copy-on-write [`GmemSnapshot`] (reads fall through to the shared
//!    launch-time base; the first store to a 1 KiB page faults in a
//!    private copy; every store is logged) and its own ALU built from an
//!    [`AluFactory`], so no mutable simulation state is shared between
//!    threads and per-SM setup is O(touched pages), not O(mem).
//!
//!    When an L1 is configured ([`MemoryConfig`]), each SM's memory port
//!    is wrapped in [`crate::sim::CachedGmem`]: a tags-only BRAM cache
//!    layer that re-prices global accesses (hits block at BRAM speed,
//!    misses park the warp on a line fill, SMs sharing a partition fill
//!    port contend) but never holds data — values stay bit-identical to
//!    flat memory by construction, on both paths.
//!
//!    Trait objects stop at this boundary: inside the simulate phase the
//!    engine is monomorphized over the concrete memory port and — when
//!    [`AluBackend::is_native`] — the concrete [`NativeAlu`], so the
//!    per-lane hot loops inline (EXPERIMENTS.md §Perf).
//! 3. **Merge** — per-SM statistics are aggregated (`cycles` = max over
//!    SMs, since real SMs run concurrently; counters summed, including
//!    the per-SM [`crate::sim::MemStats`]). On the parallel path the
//!    write logs are additionally replayed into the real `GlobalMem` in
//!    SM-id order, and any global address stored by two different SMs
//!    raises [`SimError::WriteConflict`].
//!
//! The parallel path is bit-equivalent to the sequential path (identical
//! memory image and identical simulated cycles) for kernels whose SMs
//! write disjoint addresses and never read another SM's writes within one
//! launch — true of all paper benchmarks. The *write-disjointness*
//! half of that contract is checked per launch by the conflict detector;
//! a cross-SM read of data another SM wrote in the same launch has no
//! write overlap, so it is **not** detectable — such kernels read the
//! launch-time snapshot and must use a sequential-mode request (or split
//! the dependency across launches, as reduction's two phases do).

pub mod limits;

pub use limits::KernelResources;

use crate::asm::Kernel;
use crate::isa::CapabilitySignature;
use crate::registry::PreparedKernel;
use crate::sim::{
    AluBackend, AluFactory, BlockDesc, CachedGmem, CheckpointPolicy, EngineMode, FaultPlan,
    GlobalMem, GmemPort, GmemSnapshot, L1Cache, MemoryConfig, NativeAlu, PreDecoded, SimError, Sm,
    SmConfig, SmLaunch, SmStats, WriteRecord,
};
use std::collections::HashMap;

/// Run one SM with the hot path monomorphized as far as the boundary
/// allows: `G` is always a concrete memory port here (the shared
/// [`GlobalMem`] or a per-thread [`GmemSnapshot`]), an L1-configured
/// launch wraps it in a concrete [`CachedGmem`], and a backend that
/// reports [`AluBackend::is_native`] is swapped for a concrete
/// [`NativeAlu`] so the default configuration runs fully inlined. Only
/// genuinely foreign backends (e.g. the XLA executor) pay dyn dispatch —
/// once per warp instruction, never per lane.
fn run_sm<G: GmemPort>(
    sm: &Sm,
    launch: &SmLaunch<'_>,
    cache: Option<L1Cache>,
    gmem: &mut G,
    alu: &mut dyn AluBackend,
) -> Result<SmStats, SimError> {
    match cache {
        Some(l1) => {
            let mut cached = CachedGmem::new(gmem, l1);
            run_sm_mono(sm, launch, &mut cached, alu)
        }
        None => run_sm_mono(sm, launch, gmem, alu),
    }
}

fn run_sm_mono<G: GmemPort>(
    sm: &Sm,
    launch: &SmLaunch<'_>,
    gmem: &mut G,
    alu: &mut dyn AluBackend,
) -> Result<SmStats, SimError> {
    if alu.is_native() {
        let mut native = NativeAlu;
        sm.run(launch, gmem, &mut native)
    } else {
        sm.run(launch, gmem, alu)
    }
}

/// Overlay clock: "All designs were evaluated at 100 MHz" (paper §5.1).
pub const CLOCK_HZ: f64 = 100e6;

/// Whole-GPGPU configuration: the SM microarchitecture, how many SMs are
/// instantiated (the paper evaluates 1 and 2), and the global-memory
/// hierarchy (flat AXI by default, optional per-SM L1/BRAM cache).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpgpuConfig {
    pub sm: SmConfig,
    pub num_sms: u32,
    pub memory: MemoryConfig,
}

impl GpgpuConfig {
    pub fn new(num_sms: u32, num_sp: u32) -> GpgpuConfig {
        GpgpuConfig {
            sm: SmConfig::baseline().with_sp(num_sp),
            num_sms,
            memory: MemoryConfig::default(),
        }
    }

    /// Same device with a different memory hierarchy.
    pub fn with_memory(mut self, memory: MemoryConfig) -> GpgpuConfig {
        self.memory = memory;
        self
    }

    /// Validate the device configuration. All capability/limit checks
    /// live in `sim` ([`crate::sim::validate_device`],
    /// [`MemoryConfig::validate`]); this is a pure delegation so the two
    /// layers cannot drift.
    pub fn validate(&self) -> Result<(), SimError> {
        crate::sim::validate_device(&self.sm, self.num_sms)?;
        self.memory.validate()
    }

    pub fn label(&self) -> String {
        match self.memory.l1 {
            Some(_) => {
                format!("{} SM, {} SP, {}", self.num_sms, self.sm.num_sp, self.memory.label())
            }
            None => format!("{} SM, {} SP", self.num_sms, self.sm.num_sp),
        }
    }
}

impl Default for GpgpuConfig {
    fn default() -> Self {
        GpgpuConfig::new(1, 8)
    }
}

/// Kernel launch geometry (grid may be 2-D; blocks are linear, <=256
/// threads, paper §4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaunchConfig {
    pub grid_x: u32,
    pub grid_y: u32,
    pub block_threads: u32,
}

impl LaunchConfig {
    pub fn linear(grid: u32, block_threads: u32) -> LaunchConfig {
        LaunchConfig { grid_x: grid, grid_y: 1, block_threads }
    }

    pub fn num_blocks(&self) -> u32 {
        self.grid_x * self.grid_y
    }

    pub fn total_threads(&self) -> u64 {
        self.num_blocks() as u64 * self.block_threads as u64
    }
}

/// Result of one kernel launch.
#[derive(Debug, Clone)]
pub struct LaunchResult {
    /// Per-SM statistics (index = SM id).
    pub per_sm: Vec<SmStats>,
    /// Aggregate: `cycles` = max over SMs (they run concurrently),
    /// counters summed.
    pub total: SmStats,
    /// Resident-block limit the scheduler computed (paper §4.3).
    pub max_resident_blocks: u32,
}

impl LaunchResult {
    /// Kernel execution time in milliseconds at the 100 MHz overlay clock.
    pub fn exec_time_ms(&self) -> f64 {
        self.total.exec_time_ms(CLOCK_HZ)
    }

    /// Aggregate memory-hierarchy counters (all-zero on flat memory).
    pub fn mem_stats(&self) -> crate::sim::MemStats {
        self.total.mem
    }

    /// Checkpoint restarts taken across all SMs (zero without a
    /// [`LaunchRequest::checkpoint`] policy).
    pub fn restarts(&self) -> u64 {
        self.total.restarts
    }

    /// Cycles re-executed because of checkpoint restarts, summed over SMs.
    pub fn replayed_cycles(&self) -> u64 {
        self.total.replayed_cycles
    }
}

/// The kernel a [`LaunchRequest`] targets: a raw [`Kernel`] (signature and
/// micro-op lowering derived on the spot) or a registry-cached
/// [`PreparedKernel`] (both reused, so a repeat launch does no per-launch
/// kernel analysis at all).
#[derive(Clone, Copy)]
pub enum KernelRef<'a> {
    Source(&'a Kernel),
    Prepared(&'a PreparedKernel),
}

impl<'a> From<&'a Kernel> for KernelRef<'a> {
    fn from(k: &'a Kernel) -> Self {
        KernelRef::Source(k)
    }
}

impl<'a> From<&'a PreparedKernel> for KernelRef<'a> {
    fn from(pk: &'a PreparedKernel) -> Self {
        KernelRef::Prepared(pk)
    }
}

/// How the simulate phase runs (see the module docs): SMs one after
/// another through a single ALU backend, or one scoped OS thread per SM
/// with per-SM ALUs built from a factory.
pub enum ExecMode<'a> {
    Sequential(&'a mut dyn AluBackend),
    Parallel(&'a dyn AluFactory),
}

/// Everything one [`Gpgpu::launch`] needs, built fluent-style:
///
/// ```ignore
/// let r = gpgpu.launch(
///     LaunchRequest::new(&kernel, LaunchConfig::linear(8, 64), &mut gmem)
///         .params(&[n as i32])
///         .parallel(),
/// )?;
/// ```
///
/// Defaults: sequential execution on the built-in [`NativeAlu`], admission
/// on the kernel's own derived signature, and the device's configured
/// [`MemoryConfig`]. Migrating from the pre-redesign entry points:
/// `launch_parallel*` becomes `.parallel()` (or `.parallel_with(factory)`),
/// `launch_prepared` passes the `&PreparedKernel` as the kernel, and
/// `launch_admitted`'s explicit signature becomes `.admit(sig)`.
pub struct LaunchRequest<'a> {
    kernel: KernelRef<'a>,
    geometry: LaunchConfig,
    gmem: &'a mut GlobalMem,
    params: &'a [i32],
    mode: Option<ExecMode<'a>>,
    sig: Option<CapabilitySignature>,
    memory: Option<MemoryConfig>,
    fault: Option<&'a FaultPlan>,
    watchdog: Option<u64>,
    engine: Option<EngineMode>,
    checkpoint: Option<CheckpointPolicy>,
}

impl<'a> LaunchRequest<'a> {
    pub fn new(
        kernel: impl Into<KernelRef<'a>>,
        geometry: LaunchConfig,
        gmem: &'a mut GlobalMem,
    ) -> LaunchRequest<'a> {
        LaunchRequest {
            kernel: kernel.into(),
            geometry,
            gmem,
            params: &[],
            mode: None,
            sig: None,
            memory: None,
            fault: None,
            watchdog: None,
            engine: None,
            checkpoint: None,
        }
    }

    /// Kernel parameter words (the SLD-visible segment).
    pub fn params(mut self, params: &'a [i32]) -> Self {
        self.params = params;
        self
    }

    /// Sequential simulation through a caller-supplied ALU backend
    /// (foreign backends pay dyn dispatch once per warp instruction).
    pub fn sequential(mut self, alu: &'a mut dyn AluBackend) -> Self {
        self.mode = Some(ExecMode::Sequential(alu));
        self
    }

    /// One scoped OS thread per SM, each with its own [`NativeAlu`].
    pub fn parallel(mut self) -> Self {
        self.mode = Some(ExecMode::Parallel(&NativeAlu));
        self
    }

    /// One scoped OS thread per SM, per-SM ALUs built by `factory`.
    pub fn parallel_with(mut self, factory: &'a dyn AluFactory) -> Self {
        self.mode = Some(ExecMode::Parallel(factory));
        self
    }

    /// Admit on an explicit capability signature — normally a
    /// profile-refined one (paper §4.1) — instead of the kernel's own.
    /// The coordinator's routed launches admit on exactly the signature
    /// the router used, so refinement can never self-reject a job on the
    /// variant it chose; if the profile over-promised, the mid-run
    /// removed-unit trap (same structured [`SimError::Unsupported`]
    /// payload) and the runtime stack-overflow trap remain the backstop.
    pub fn admit(mut self, sig: CapabilitySignature) -> Self {
        self.sig = Some(sig);
        self
    }

    /// Override the device's memory hierarchy for this launch only.
    pub fn memory(mut self, memory: MemoryConfig) -> Self {
        self.memory = Some(memory);
        self
    }

    /// Run this launch under a seeded SEU injection campaign
    /// ([`FaultPlan`], `sim::fault`). Fault sites are derived from
    /// `(plan.seed, sm_id, cycle)`, so they are identical across runs and
    /// across the sequential and parallel paths.
    pub fn fault(mut self, plan: &'a FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }

    /// Per-request cycle-budget override: replaces the device's
    /// [`SmConfig::watchdog_cycles`] for this launch only (the service
    /// plane's deadline-enforcement knob — the 50e9 device default is
    /// effectively infinite).
    pub fn watchdog(mut self, cycles: u64) -> Self {
        self.watchdog = Some(cycles);
        self
    }

    /// Override the execute-stage engine for this launch only. The
    /// default is the device's configured engine ([`EngineMode::Vector`]
    /// out of the box); [`EngineMode::Scalar`] forces the per-lane oracle
    /// loop everywhere — the differential tests run every benchmark both
    /// ways and demand bit- and cycle-identical results.
    pub fn engine(mut self, engine: EngineMode) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Shorthand for `.engine(EngineMode::Scalar)`.
    pub fn scalar(self) -> Self {
        self.engine(EngineMode::Scalar)
    }

    /// Barrier checkpoint/restart for this launch: each SM snapshots live
    /// state at launch start and at every block-wide barrier
    /// reconvergence, and an uncorrectable fault restores the latest
    /// snapshot (up to `policy.max_restarts` times) instead of failing
    /// the launch. Restart counts and replayed-cycle overhead surface in
    /// [`LaunchResult::restarts`] / [`LaunchResult::replayed_cycles`].
    /// Replay is deterministic, so a rescued launch stays bit-identical
    /// to a fault-free run.
    pub fn checkpoint(mut self, policy: CheckpointPolicy) -> Self {
        self.checkpoint = Some(policy);
        self
    }
}

/// Post-partition simulate-phase inputs, bundled so the per-path drivers
/// stay well under the argument-count lint.
struct SimJob<'a> {
    kernel: &'a Kernel,
    pre: &'a PreDecoded,
    assignments: &'a [Vec<BlockDesc>],
    max_resident: u32,
    params: &'a [i32],
    memory: MemoryConfig,
    fault: Option<&'a FaultPlan>,
    watchdog: Option<u64>,
    engine: Option<EngineMode>,
    checkpoint: Option<CheckpointPolicy>,
}

impl SimJob<'_> {
    fn sm_launch<'b>(&'b self, blocks: &'b [BlockDesc]) -> SmLaunch<'b> {
        SmLaunch {
            pre: self.pre,
            regs_per_thread: self.kernel.regs_per_thread,
            smem_bytes: self.kernel.smem_bytes,
            params: self.params,
            blocks,
            max_resident: self.max_resident as usize,
            fault: self.fault,
            checkpoint: self.checkpoint,
        }
    }

    /// The SM configuration this job runs under: the device's, with the
    /// per-request watchdog and engine overrides applied (identically on
    /// both launch paths, so the overrides cannot break bit-equivalence).
    fn sm_config(&self, base: SmConfig) -> SmConfig {
        let mut cfg = base;
        if let Some(cycles) = self.watchdog {
            cfg.watchdog_cycles = cycles;
        }
        if let Some(engine) = self.engine {
            cfg.engine = engine;
        }
        cfg
    }
}

/// The soft GPGPU.
pub struct Gpgpu {
    pub cfg: GpgpuConfig,
}

impl Gpgpu {
    pub fn new(cfg: GpgpuConfig) -> Gpgpu {
        Gpgpu { cfg }
    }

    /// The public capability check: can this device *guaranteed* execute a
    /// kernel with signature `sig`? (Conservative — see
    /// [`SmConfig::covers`]; the coordinator's fleet router and callers
    /// choosing among customized variants use this.)
    pub fn supports(&self, sig: &CapabilitySignature) -> bool {
        self.cfg.sm.covers(sig)
    }

    /// Phase 1 (partition): validate the device, admit the kernel's
    /// capability signature (§4.2 — a provable mismatch is rejected with
    /// [`SimError::Unsupported`] *before* any simulation), compute the
    /// residency limit, and deal blocks round-robin across SMs.
    fn partition(
        &self,
        kernel: &Kernel,
        sig: &CapabilitySignature,
        launch: LaunchConfig,
    ) -> Result<(Vec<Vec<BlockDesc>>, u32), SimError> {
        self.cfg.validate()?;
        self.cfg.sm.admit(sig)?;
        let res = KernelResources {
            regs_per_thread: kernel.regs_per_thread,
            smem_bytes: kernel.smem_bytes,
            block_threads: launch.block_threads,
        };
        res.validate()?;
        if launch.num_blocks() == 0 {
            return Err(SimError::LimitExceeded("empty grid".into()));
        }
        let max_resident = res.max_resident_blocks();
        debug_assert!(max_resident >= 1);

        let mut assignments: Vec<Vec<BlockDesc>> =
            vec![Vec::new(); self.cfg.num_sms as usize];
        let mut i = 0usize;
        for by in 0..launch.grid_y {
            for bx in 0..launch.grid_x {
                assignments[i % self.cfg.num_sms as usize].push(BlockDesc {
                    ctaid_x: bx,
                    ctaid_y: by,
                    nctaid_x: launch.grid_x,
                    nctaid_y: launch.grid_y,
                    ntid: launch.block_threads,
                });
                i += 1;
            }
        }
        Ok((assignments, max_resident))
    }

    /// Phase 3 (merge): aggregate per-SM statistics into a launch result.
    fn merge_stats(per_sm: Vec<SmStats>, max_resident: u32) -> LaunchResult {
        let mut total = SmStats::default();
        for s in &per_sm {
            total.merge(s);
        }
        LaunchResult { per_sm, total, max_resident_blocks: max_resident }
    }

    /// The single launch entry point — the request carries the kernel,
    /// geometry, parameters, target memory and the optional mode /
    /// admission / memory-hierarchy knobs (see [`LaunchRequest`] and the
    /// module docs). Partition → simulate → merge; kernel time is the max
    /// of the per-SM busy times.
    pub fn launch(&self, req: LaunchRequest<'_>) -> Result<LaunchResult, SimError> {
        let LaunchRequest {
            kernel,
            geometry,
            gmem,
            params,
            mode,
            sig,
            memory,
            fault,
            watchdog,
            engine,
            checkpoint,
        } = req;
        let memory = memory.unwrap_or(self.cfg.memory);
        memory.validate()?;
        let derived_pre;
        let (k, pre, sig) = match kernel {
            KernelRef::Source(k) => {
                derived_pre = PreDecoded::from_kernel(k);
                (k, &derived_pre, sig.unwrap_or_else(|| k.signature()))
            }
            KernelRef::Prepared(pk) => (&pk.kernel, &pk.pre, sig.unwrap_or(pk.sig)),
        };
        let (assignments, max_resident) = self.partition(k, &sig, geometry)?;
        let job = SimJob {
            kernel: k,
            pre,
            assignments: &assignments,
            max_resident,
            params,
            memory,
            fault,
            watchdog,
            engine,
            checkpoint,
        };
        match mode {
            None => {
                let mut alu = NativeAlu;
                self.simulate_seq(&job, gmem, &mut alu)
            }
            Some(ExecMode::Sequential(alu)) => self.simulate_seq(&job, gmem, alu),
            Some(ExecMode::Parallel(factory)) => self.simulate_par(&job, gmem, factory),
        }
    }

    /// Phase 2+3 of the sequential path: SMs simulated one after another
    /// against the shared global memory, all through the single `alu`.
    fn simulate_seq(
        &self,
        job: &SimJob<'_>,
        gmem: &mut GlobalMem,
        alu: &mut dyn AluBackend,
    ) -> Result<LaunchResult, SimError> {
        let mut per_sm = Vec::with_capacity(self.cfg.num_sms as usize);
        for (sm_id, blocks) in job.assignments.iter().enumerate() {
            let sm = Sm::new(job.sm_config(self.cfg.sm), sm_id as u32);
            let stats = if blocks.is_empty() {
                SmStats::default()
            } else {
                let cache = sm_cache(&self.cfg, job.memory, sm_id as u32);
                run_sm(&sm, &job.sm_launch(blocks), cache, gmem, alu)?
            };
            per_sm.push(stats);
        }
        Ok(Self::merge_stats(per_sm, job.max_resident))
    }

    /// Phase 2+3 of the parallel path: each SM on its own scoped thread
    /// with an ALU built by the factory and a private [`GmemSnapshot`];
    /// write logs are replayed into `gmem` in SM-id order afterwards,
    /// raising [`SimError::WriteConflict`] if two SMs stored the same
    /// address. For conflict-free kernels the result (memory image,
    /// per-SM stats, simulated cycles) is identical to the sequential
    /// path — the L1 timing model is deterministic and purely per-SM
    /// (partition contention is a static sharer count), so this holds
    /// with and without a cache.
    fn simulate_par(
        &self,
        job: &SimJob<'_>,
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<LaunchResult, SimError> {
        if self.cfg.num_sms == 1 {
            // One SM: no partitioning benefit; skip the snapshot entirely.
            let mut alu = factory.make_alu();
            let sm = Sm::new(job.sm_config(self.cfg.sm), 0);
            let cache = sm_cache(&self.cfg, job.memory, 0);
            let stats =
                run_sm(&sm, &job.sm_launch(&job.assignments[0]), cache, gmem, alu.as_mut())?;
            return Ok(Self::merge_stats(vec![stats], job.max_resident));
        }

        // Phase 2 (simulate): one scoped thread per SM, no shared mutable
        // state. `base` is the shared launch-time image; each thread reads
        // it through a private copy-on-write view.
        let base: &GlobalMem = gmem;
        let cfg = self.cfg;
        let results: Vec<Result<(SmStats, Vec<WriteRecord>), SimError>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = job
                    .assignments
                    .iter()
                    .enumerate()
                    .map(|(sm_id, blocks)| {
                        scope.spawn(move || {
                            if blocks.is_empty() {
                                return Ok((SmStats::default(), Vec::new()));
                            }
                            let sm = Sm::new(job.sm_config(cfg.sm), sm_id as u32);
                            let mut alu = factory.make_alu();
                            let cache = sm_cache(&cfg, job.memory, sm_id as u32);
                            // Copy-on-write view: setup is O(touched
                            // pages), not O(mem) — reads fall through to
                            // the shared base.
                            let mut view = GmemSnapshot::new(base);
                            let stats = run_sm(
                                &sm,
                                &job.sm_launch(blocks),
                                cache,
                                &mut view,
                                alu.as_mut(),
                            )?;
                            Ok((stats, view.into_log()))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("SM simulation thread panicked"))
                    .collect()
            });

        // Phase 3 (merge): replay write logs deterministically in SM order,
        // detecting cross-SM conflicts, then aggregate statistics.
        let mut per_sm = Vec::with_capacity(results.len());
        let mut logs = Vec::with_capacity(results.len());
        for r in results {
            let (stats, log) = r?;
            per_sm.push(stats);
            logs.push(log);
        }
        merge_write_logs(gmem, &logs)?;
        Ok(Self::merge_stats(per_sm, job.max_resident))
    }

    // ------------------------------------------------------------------
    // Pre-redesign entry points, kept as thin shims over `launch`.
    // ------------------------------------------------------------------

    /// Sequential launch of a registry-cached kernel.
    #[deprecated(note = "use Gpgpu::launch with a LaunchRequest")]
    pub fn launch_prepared(
        &self,
        pk: &PreparedKernel,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        alu: &mut dyn AluBackend,
    ) -> Result<LaunchResult, SimError> {
        self.launch(LaunchRequest::new(pk, launch, gmem).params(params).sequential(alu))
    }

    /// Sequential launch with an explicit admission signature.
    #[deprecated(note = "use Gpgpu::launch with LaunchRequest::admit")]
    pub fn launch_admitted(
        &self,
        pk: &PreparedKernel,
        sig: &CapabilitySignature,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        alu: &mut dyn AluBackend,
    ) -> Result<LaunchResult, SimError> {
        self.launch(
            LaunchRequest::new(pk, launch, gmem).params(params).sequential(alu).admit(*sig),
        )
    }

    /// Thread-per-SM launch of a raw kernel.
    #[deprecated(note = "use Gpgpu::launch with LaunchRequest::parallel_with")]
    pub fn launch_parallel(
        &self,
        kernel: &Kernel,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<LaunchResult, SimError> {
        self.launch(LaunchRequest::new(kernel, launch, gmem).params(params).parallel_with(factory))
    }

    /// Thread-per-SM launch of a registry-cached kernel.
    #[deprecated(note = "use Gpgpu::launch with LaunchRequest::parallel_with")]
    pub fn launch_parallel_prepared(
        &self,
        pk: &PreparedKernel,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<LaunchResult, SimError> {
        self.launch(LaunchRequest::new(pk, launch, gmem).params(params).parallel_with(factory))
    }

    /// Thread-per-SM launch with an explicit admission signature.
    #[deprecated(note = "use Gpgpu::launch with LaunchRequest::parallel_with + admit")]
    pub fn launch_parallel_admitted(
        &self,
        pk: &PreparedKernel,
        sig: &CapabilitySignature,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        factory: &dyn AluFactory,
    ) -> Result<LaunchResult, SimError> {
        self.launch(
            LaunchRequest::new(pk, launch, gmem)
                .params(params)
                .parallel_with(factory)
                .admit(*sig),
        )
    }
}

/// Build the per-SM L1 timing layer for a launch, if one is configured.
/// Purely a function of static launch facts (device shape, SM id, AXI
/// calibration), so sequential and parallel simulation construct
/// identical caches — part of the bit-equivalence contract.
fn sm_cache(cfg: &GpgpuConfig, memory: MemoryConfig, sm_id: u32) -> Option<L1Cache> {
    memory.l1.map(|l1| L1Cache::new(l1, cfg.num_sms, sm_id, cfg.sm.mem))
}

/// Replay per-SM write logs into `gmem` in SM-id order (within one SM,
/// program order is preserved by the log itself). An address written by
/// two different SMs is a violation of the parallel launch's
/// disjoint-write contract and raises [`SimError::WriteConflict`] —
/// detected in a scan pass *before* any write is applied, so a rejected
/// launch leaves `gmem` exactly as it was (callers may recover by
/// re-issuing the request in sequential mode on the same memory).
fn merge_write_logs(gmem: &mut GlobalMem, logs: &[Vec<WriteRecord>]) -> Result<(), SimError> {
    let mut writer: HashMap<u32, u32> = HashMap::new();
    for (sm_id, log) in logs.iter().enumerate() {
        let sm_id = sm_id as u32;
        for &(addr, _) in log {
            match writer.get(&addr) {
                Some(&first) if first != sm_id => {
                    return Err(SimError::WriteConflict {
                        addr,
                        first_sm: first,
                        second_sm: sm_id,
                    });
                }
                _ => {
                    writer.insert(addr, sm_id);
                }
            }
        }
    }
    for log in logs {
        for &(addr, value) in log {
            gmem.store(addr, value)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;
    use crate::sim::NativeAlu;

    /// out[gtid] = gtid * 2 (multi-block).
    const SRC: &str = r#"
        .entry double
        .regs 6
            S2R R1, SR_GTID
            SHL R2, R1, #2
            IADD R3, R1, R1
            GST [R2], R3
            EXIT
    "#;

    fn launch(cfg: GpgpuConfig, grid: u32, block: u32) -> (GlobalMem, LaunchResult) {
        let k = assemble(SRC).unwrap();
        let mut g = GlobalMem::new(grid * block * 4 + 64);
        let r = Gpgpu::new(cfg)
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(grid, block), &mut g))
            .unwrap();
        (g, r)
    }

    fn launch_par(cfg: GpgpuConfig, grid: u32, block: u32) -> (GlobalMem, LaunchResult) {
        let k = assemble(SRC).unwrap();
        let mut g = GlobalMem::new(grid * block * 4 + 64);
        let r = Gpgpu::new(cfg)
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(grid, block), &mut g).parallel())
            .unwrap();
        (g, r)
    }

    #[test]
    fn multi_block_kernel_covers_grid() {
        let (g, r) = launch(GpgpuConfig::new(1, 8), 8, 64);
        for t in 0..512 {
            assert_eq!(g.load(t * 4).unwrap(), (t * 2) as i32);
        }
        assert_eq!(r.total.blocks, 8);
    }

    #[test]
    fn two_sms_split_blocks_and_halve_time() {
        let (_, r1) = launch(GpgpuConfig::new(1, 8), 8, 64);
        let (g2, r2) = launch(GpgpuConfig::new(2, 8), 8, 64);
        for t in 0..512 {
            assert_eq!(g2.load(t * 4).unwrap(), (t * 2) as i32);
        }
        assert_eq!(r2.per_sm[0].blocks, 4);
        assert_eq!(r2.per_sm[1].blocks, 4);
        let speedup = r1.total.cycles as f64 / r2.total.cycles as f64;
        assert!(
            speedup > 1.5 && speedup <= 2.05,
            "2 SM speedup out of range: {speedup}"
        );
    }

    #[test]
    fn odd_block_count_distributes_round_robin() {
        let (_, r) = launch(GpgpuConfig::new(2, 8), 5, 64);
        assert_eq!(r.per_sm[0].blocks, 3);
        assert_eq!(r.per_sm[1].blocks, 2);
    }

    #[test]
    fn residency_limit_reported() {
        let (_, r) = launch(GpgpuConfig::new(1, 8), 4, 256);
        assert_eq!(r.max_resident_blocks, 3); // 768 threads / 256
    }

    #[test]
    fn launch_rejects_oversized_block() {
        let k = assemble(SRC).unwrap();
        let mut g = GlobalMem::new(1024);
        let err = Gpgpu::new(GpgpuConfig::default())
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(1, 512), &mut g))
            .unwrap_err();
        assert!(matches!(err, SimError::LimitExceeded(_)));
    }

    #[test]
    fn exec_time_uses_100mhz_clock() {
        let (_, r) = launch(GpgpuConfig::new(1, 8), 1, 32);
        let want = r.total.cycles as f64 / 100e6 * 1e3;
        assert!((r.exec_time_ms() - want).abs() < 1e-12);
    }

    #[test]
    fn parallel_launch_matches_sequential_bit_for_bit() {
        for (sms, grid, block) in [(1u32, 5u32, 64u32), (2, 8, 64), (2, 5, 50)] {
            let (gs, rs) = launch(GpgpuConfig::new(sms, 8), grid, block);
            let (gp, rp) = launch_par(GpgpuConfig::new(sms, 8), grid, block);
            assert_eq!(rs.total.cycles, rp.total.cycles, "{sms} SM cycles");
            assert_eq!(rs.total.instructions, rp.total.instructions);
            for sm in 0..sms as usize {
                assert_eq!(rs.per_sm[sm].cycles, rp.per_sm[sm].cycles, "SM {sm}");
                assert_eq!(rs.per_sm[sm].blocks, rp.per_sm[sm].blocks, "SM {sm}");
            }
            let words = (gs.size_bytes() / 4) as usize;
            assert_eq!(
                gs.read_words(0, words).unwrap(),
                gp.read_words(0, words).unwrap(),
                "memory image {sms} SM {grid}x{block}"
            );
        }
    }

    #[test]
    fn parallel_launch_detects_cross_sm_write_conflict() {
        // Every block stores to address 0 — blocks land on both SMs, so
        // the merge phase must flag the overlapping write.
        let k = assemble("MOV R1, #0\nMOV R2, #7\nGST [R1], R2\nEXIT").unwrap();
        let mut g = GlobalMem::new(4096);
        let err = Gpgpu::new(GpgpuConfig::new(2, 8))
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(2, 32), &mut g).parallel())
            .unwrap_err();
        assert!(
            matches!(err, SimError::WriteConflict { addr: 0, .. }),
            "want WriteConflict, got {err}"
        );
    }

    #[test]
    fn parallel_launch_propagates_sm_faults() {
        let k = assemble("JOIN\nEXIT").unwrap();
        let mut g = GlobalMem::new(4096);
        let err = Gpgpu::new(GpgpuConfig::new(2, 8))
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(4, 32), &mut g).parallel())
            .unwrap_err();
        assert!(matches!(err, SimError::StackUnderflow { .. }));
    }

    #[test]
    fn admission_rejects_before_simulation() {
        // A multiply kernel on a multiplier-less variant must be refused
        // at the launch boundary with the structured payload — device
        // memory untouched, nothing simulated.
        let k = assemble("S2R R1, SR_GTID\nIMUL R2, R1, R1\nGST [R1], R2\nEXIT").unwrap();
        let mut cfg = GpgpuConfig::new(1, 8);
        cfg.sm.has_multiplier = false;
        cfg.sm.read_operands = 2;
        let gp = Gpgpu::new(cfg);
        assert!(!gp.supports(&k.signature()));
        let mut g = GlobalMem::new(4096);
        let err = gp
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(1, 32), &mut g))
            .unwrap_err();
        assert!(matches!(
            err,
            SimError::Unsupported {
                capability: crate::isa::Capability::Multiplier,
                pc: None,
                ..
            }
        ));
    }

    #[test]
    fn prepared_launch_matches_raw_launch() {
        use crate::registry::PreparedKernel;
        let pk = PreparedKernel::new(assemble(SRC).unwrap());
        let gp = Gpgpu::new(GpgpuConfig::new(2, 8));
        let (g_raw, r_raw) = launch(GpgpuConfig::new(2, 8), 6, 64);
        let mut g = GlobalMem::new(6 * 64 * 4 + 64);
        let r = gp.launch(LaunchRequest::new(&pk, LaunchConfig::linear(6, 64), &mut g)).unwrap();
        assert_eq!(r.total.cycles, r_raw.total.cycles);
        let words = (g.size_bytes() / 4) as usize;
        assert_eq!(g.read_words(0, words).unwrap(), g_raw.read_words(0, words).unwrap());

        let mut g2 = GlobalMem::new(6 * 64 * 4 + 64);
        let rp = gp
            .launch(LaunchRequest::new(&pk, LaunchConfig::linear(6, 64), &mut g2).parallel())
            .unwrap();
        assert_eq!(rp.total.cycles, r_raw.total.cycles);
        assert_eq!(g2.read_words(0, words).unwrap(), g_raw.read_words(0, words).unwrap());
    }

    #[test]
    fn cached_launch_keeps_values_and_reports_mem_stats() {
        use crate::sim::{CacheGeometry, MemoryConfig};
        let geom = CacheGeometry::parse("4x64x32").unwrap();
        let (g_flat, r_flat) = launch(GpgpuConfig::new(2, 8), 8, 64);
        assert_eq!(r_flat.mem_stats(), crate::sim::MemStats::default());

        let k = assemble(SRC).unwrap();
        let cfg = GpgpuConfig::new(2, 8).with_memory(MemoryConfig::with_l1(geom));
        let mut g = GlobalMem::new(8 * 64 * 4 + 64);
        let r = Gpgpu::new(cfg)
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(8, 64), &mut g))
            .unwrap();
        // Cache changes cycles, never values.
        let words = (g.size_bytes() / 4) as usize;
        assert_eq!(g.read_words(0, words).unwrap(), g_flat.read_words(0, words).unwrap());
        assert_ne!(r.total.cycles, r_flat.total.cycles);
        // This kernel only stores, so the write-through cache observes
        // traffic but no load hits/misses.
        assert_eq!(r.mem_stats().misses, 0);

        // A per-request memory override on a flat device behaves the same.
        let mut g2 = GlobalMem::new(8 * 64 * 4 + 64);
        let r2 = Gpgpu::new(GpgpuConfig::new(2, 8))
            .launch(
                LaunchRequest::new(&k, LaunchConfig::linear(8, 64), &mut g2)
                    .memory(MemoryConfig::with_l1(geom)),
            )
            .unwrap();
        assert_eq!(r2.total.cycles, r.total.cycles);
    }

    #[test]
    fn per_request_watchdog_override_trips_and_restores() {
        let k = assemble(SRC).unwrap();
        let mut g = GlobalMem::new(8 * 64 * 4 + 64);
        let err = Gpgpu::new(GpgpuConfig::new(1, 8))
            .launch(LaunchRequest::new(&k, LaunchConfig::linear(8, 64), &mut g).watchdog(10))
            .unwrap_err();
        assert!(matches!(err, SimError::Watchdog { .. }), "{err}");
        // The parallel path honors the same override...
        let mut g = GlobalMem::new(8 * 64 * 4 + 64);
        let err = Gpgpu::new(GpgpuConfig::new(2, 8))
            .launch(
                LaunchRequest::new(&k, LaunchConfig::linear(8, 64), &mut g)
                    .watchdog(10)
                    .parallel(),
            )
            .unwrap_err();
        assert!(matches!(err, SimError::Watchdog { .. }), "{err}");
        // ...and a request without the override still completes under the
        // device default.
        let (_, r) = launch(GpgpuConfig::new(1, 8), 8, 64);
        assert_eq!(r.total.blocks, 8);
    }

    #[test]
    fn fault_campaign_identical_on_both_launch_paths() {
        use crate::sim::{FaultPlan, FaultTargets};
        let k = assemble(SRC).unwrap();
        // Detected-class campaign at mean inter-arrival 1 cycle: both
        // paths must fail with byte-identical structured errors (the
        // per-SM cycle streams, and therefore the fault sites, are
        // path-independent).
        let plan = FaultPlan::new(0xDECAF, 1_000_000.0)
            .with_targets(FaultTargets { instr_image: true, ..FaultTargets::none() });
        let run = |parallel: bool| {
            let mut g = GlobalMem::new(8 * 64 * 4 + 64);
            let mut req =
                LaunchRequest::new(&k, LaunchConfig::linear(8, 64), &mut g).fault(&plan);
            if parallel {
                req = req.parallel();
            }
            Gpgpu::new(GpgpuConfig::new(2, 8)).launch(req).unwrap_err()
        };
        let seq = run(false);
        let par = run(true);
        assert!(matches!(seq, SimError::SoftError { .. }), "{seq}");
        assert_eq!(seq, par, "fault sites must be path-independent");
    }

    #[test]
    fn checkpoint_rescues_launches_on_both_paths_bit_identically() {
        use crate::sim::{CheckpointPolicy, FaultPlan, FaultState, FaultTargets};
        let k = assemble(SRC).unwrap();
        let (g_clean, r_clean) = launch(GpgpuConfig::new(1, 8), 4, 64);
        let c = r_clean.per_sm[0].cycles;
        // One parity-fatal instruction upset mid-run, the next far past the
        // replayed completion (same seed-search idea as the SM-level test).
        let targets = FaultTargets { instr_image: true, ..FaultTargets::none() };
        let plan = (0u64..)
            .map(|n| FaultPlan::new(0xCC + n, 50.0).with_targets(targets))
            .find(|p| {
                let mut st = FaultState::new(p, 0).unwrap();
                let e1 = st.next_event();
                e1 < c / 2 && {
                    st.poll(e1);
                    st.next_event() > e1 + 4 * c
                }
            })
            .expect("seed search is unbounded");
        let run = |parallel: bool| {
            let mut g = GlobalMem::new(4 * 64 * 4 + 64);
            let mut req = LaunchRequest::new(&k, LaunchConfig::linear(4, 64), &mut g)
                .fault(&plan)
                .checkpoint(CheckpointPolicy::at_barriers());
            if parallel {
                req = req.parallel();
            }
            let r = Gpgpu::new(GpgpuConfig::new(1, 8)).launch(req).unwrap();
            (r, g.read_words(0, 256).unwrap())
        };
        let (rs, img_s) = run(false);
        let (rp, img_p) = run(true);
        assert_eq!(rs.restarts(), 1, "exactly one rescue");
        assert!(rs.replayed_cycles() > 0);
        assert_eq!(rs.total.cycles, rp.total.cycles, "restart is path-independent");
        assert_eq!(rp.restarts(), 1);
        assert_eq!(img_s, img_p);
        assert_eq!(img_s, g_clean.read_words(0, 256).unwrap(), "rescued == fault-free");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_route_through_the_unified_launch() {
        use crate::registry::PreparedKernel;
        let pk = PreparedKernel::new(assemble(SRC).unwrap());
        let gp = Gpgpu::new(GpgpuConfig::new(2, 8));
        let (g_raw, r_raw) = launch(GpgpuConfig::new(2, 8), 6, 64);
        let words = (g_raw.size_bytes() / 4) as usize;
        let geometry = LaunchConfig::linear(6, 64);

        let mut alu = NativeAlu;
        let mut g = GlobalMem::new(6 * 64 * 4 + 64);
        let r = gp.launch_prepared(&pk, geometry, &[], &mut g, &mut alu).unwrap();
        assert_eq!(r.total.cycles, r_raw.total.cycles);

        let mut g = GlobalMem::new(6 * 64 * 4 + 64);
        let r = gp.launch_admitted(&pk, &pk.sig, geometry, &[], &mut g, &mut alu).unwrap();
        assert_eq!(r.total.cycles, r_raw.total.cycles);

        let mut g = GlobalMem::new(6 * 64 * 4 + 64);
        let r = gp.launch_parallel(&pk.kernel, geometry, &[], &mut g, &NativeAlu).unwrap();
        assert_eq!(r.total.cycles, r_raw.total.cycles);

        let mut g = GlobalMem::new(6 * 64 * 4 + 64);
        let r = gp.launch_parallel_prepared(&pk, geometry, &[], &mut g, &NativeAlu).unwrap();
        assert_eq!(r.total.cycles, r_raw.total.cycles);

        let mut g = GlobalMem::new(6 * 64 * 4 + 64);
        let r =
            gp.launch_parallel_admitted(&pk, &pk.sig, geometry, &[], &mut g, &NativeAlu).unwrap();
        assert_eq!(r.total.cycles, r_raw.total.cycles);
        assert_eq!(g.read_words(0, words).unwrap(), g_raw.read_words(0, words).unwrap());
    }
}
