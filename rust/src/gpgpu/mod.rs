//! The FlexGrip GPGPU top level: block scheduler + one or more streaming
//! multiprocessors (paper §3.1, §4.3).

pub mod limits;

pub use limits::KernelResources;

use crate::asm::Kernel;
use crate::sim::{
    AluBackend, BlockDesc, GlobalMem, PreDecoded, SimError, Sm, SmConfig, SmStats,
};

/// Overlay clock: "All designs were evaluated at 100 MHz" (paper §5.1).
pub const CLOCK_HZ: f64 = 100e6;

/// Whole-GPGPU configuration: the SM microarchitecture plus how many SMs
/// are instantiated (the paper evaluates 1 and 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpgpuConfig {
    pub sm: SmConfig,
    pub num_sms: u32,
}

impl GpgpuConfig {
    pub fn new(num_sms: u32, num_sp: u32) -> GpgpuConfig {
        GpgpuConfig { sm: SmConfig::baseline().with_sp(num_sp), num_sms }
    }

    pub fn validate(&self) -> Result<(), SimError> {
        if self.num_sms == 0 {
            return Err(SimError::LimitExceeded("at least one SM required".into()));
        }
        self.sm.validate()
    }

    pub fn label(&self) -> String {
        format!("{} SM, {} SP", self.num_sms, self.sm.num_sp)
    }
}

impl Default for GpgpuConfig {
    fn default() -> Self {
        GpgpuConfig::new(1, 8)
    }
}

/// Kernel launch geometry (grid may be 2-D; blocks are linear, <=256
/// threads, paper §4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaunchConfig {
    pub grid_x: u32,
    pub grid_y: u32,
    pub block_threads: u32,
}

impl LaunchConfig {
    pub fn linear(grid: u32, block_threads: u32) -> LaunchConfig {
        LaunchConfig { grid_x: grid, grid_y: 1, block_threads }
    }

    pub fn num_blocks(&self) -> u32 {
        self.grid_x * self.grid_y
    }

    pub fn total_threads(&self) -> u64 {
        self.num_blocks() as u64 * self.block_threads as u64
    }
}

/// Result of one kernel launch.
#[derive(Debug, Clone)]
pub struct LaunchResult {
    /// Per-SM statistics (index = SM id).
    pub per_sm: Vec<SmStats>,
    /// Aggregate: `cycles` = max over SMs (they run concurrently),
    /// counters summed.
    pub total: SmStats,
    /// Resident-block limit the scheduler computed (paper §4.3).
    pub max_resident_blocks: u32,
}

impl LaunchResult {
    /// Kernel execution time in milliseconds at the 100 MHz overlay clock.
    pub fn exec_time_ms(&self) -> f64 {
        self.total.exec_time_ms(CLOCK_HZ)
    }
}

/// The soft GPGPU.
pub struct Gpgpu {
    pub cfg: GpgpuConfig,
}

impl Gpgpu {
    pub fn new(cfg: GpgpuConfig) -> Gpgpu {
        Gpgpu { cfg }
    }

    /// Launch `kernel` over `launch` geometry. The block scheduler deals
    /// blocks round-robin across SMs ("the block scheduler logic equally
    /// and automatically distributed thread blocks to the 2 SMs", §5.1.1);
    /// each SM then keeps up to the Table-1 residency limit in flight.
    ///
    /// SMs are simulated sequentially against the shared global memory;
    /// kernel time is the max of the per-SM busy times. Inter-SM memory
    /// contention is not modelled (DESIGN.md §5).
    pub fn launch(
        &self,
        kernel: &Kernel,
        launch: LaunchConfig,
        params: &[i32],
        gmem: &mut GlobalMem,
        alu: &mut dyn AluBackend,
    ) -> Result<LaunchResult, SimError> {
        self.cfg.validate()?;
        let res = KernelResources {
            regs_per_thread: kernel.regs_per_thread,
            smem_bytes: kernel.smem_bytes,
            block_threads: launch.block_threads,
        };
        res.validate()?;
        if launch.num_blocks() == 0 {
            return Err(SimError::LimitExceeded("empty grid".into()));
        }
        let max_resident = res.max_resident_blocks();
        debug_assert!(max_resident >= 1);

        // Round-robin block distribution across SMs.
        let mut assignments: Vec<Vec<BlockDesc>> =
            vec![Vec::new(); self.cfg.num_sms as usize];
        let mut i = 0usize;
        for by in 0..launch.grid_y {
            for bx in 0..launch.grid_x {
                assignments[i % self.cfg.num_sms as usize].push(BlockDesc {
                    ctaid_x: bx,
                    ctaid_y: by,
                    nctaid_x: launch.grid_x,
                    nctaid_y: launch.grid_y,
                    ntid: launch.block_threads,
                });
                i += 1;
            }
        }

        let pre = PreDecoded::from_kernel(kernel);
        let mut per_sm = Vec::with_capacity(self.cfg.num_sms as usize);
        for (sm_id, blocks) in assignments.iter().enumerate() {
            let sm = Sm::new(self.cfg.sm, sm_id as u32);
            let stats = if blocks.is_empty() {
                SmStats::default()
            } else {
                sm.run(
                    &pre,
                    kernel.regs_per_thread,
                    kernel.smem_bytes,
                    params,
                    blocks,
                    max_resident as usize,
                    gmem,
                    alu,
                )?
            };
            per_sm.push(stats);
        }

        let mut total = SmStats::default();
        for s in &per_sm {
            total.merge(s);
        }
        Ok(LaunchResult { per_sm, total, max_resident_blocks: max_resident })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asm::assemble;
    use crate::sim::NativeAlu;

    /// out[gtid] = gtid * 2 (multi-block).
    const SRC: &str = r#"
        .entry double
        .regs 6
            S2R R1, SR_GTID
            SHL R2, R1, #2
            IADD R3, R1, R1
            GST [R2], R3
            EXIT
    "#;

    fn launch(cfg: GpgpuConfig, grid: u32, block: u32) -> (GlobalMem, LaunchResult) {
        let k = assemble(SRC).unwrap();
        let mut g = GlobalMem::new(grid * block * 4 + 64);
        let mut alu = NativeAlu;
        let r = Gpgpu::new(cfg)
            .launch(&k, LaunchConfig::linear(grid, block), &[], &mut g, &mut alu)
            .unwrap();
        (g, r)
    }

    #[test]
    fn multi_block_kernel_covers_grid() {
        let (g, r) = launch(GpgpuConfig::new(1, 8), 8, 64);
        for t in 0..512 {
            assert_eq!(g.load(t * 4).unwrap(), (t * 2) as i32);
        }
        assert_eq!(r.total.blocks, 8);
    }

    #[test]
    fn two_sms_split_blocks_and_halve_time() {
        let (_, r1) = launch(GpgpuConfig::new(1, 8), 8, 64);
        let (g2, r2) = launch(GpgpuConfig::new(2, 8), 8, 64);
        for t in 0..512 {
            assert_eq!(g2.load(t * 4).unwrap(), (t * 2) as i32);
        }
        assert_eq!(r2.per_sm[0].blocks, 4);
        assert_eq!(r2.per_sm[1].blocks, 4);
        let speedup = r1.total.cycles as f64 / r2.total.cycles as f64;
        assert!(
            speedup > 1.5 && speedup <= 2.05,
            "2 SM speedup out of range: {speedup}"
        );
    }

    #[test]
    fn odd_block_count_distributes_round_robin() {
        let (_, r) = launch(GpgpuConfig::new(2, 8), 5, 64);
        assert_eq!(r.per_sm[0].blocks, 3);
        assert_eq!(r.per_sm[1].blocks, 2);
    }

    #[test]
    fn residency_limit_reported() {
        let (_, r) = launch(GpgpuConfig::new(1, 8), 4, 256);
        assert_eq!(r.max_resident_blocks, 3); // 768 threads / 256
    }

    #[test]
    fn launch_rejects_oversized_block() {
        let k = assemble(SRC).unwrap();
        let mut g = GlobalMem::new(1024);
        let mut alu = NativeAlu;
        let err = Gpgpu::new(GpgpuConfig::default())
            .launch(&k, LaunchConfig::linear(1, 512), &[], &mut g, &mut alu)
            .unwrap_err();
        assert!(matches!(err, SimError::LimitExceeded(_)));
    }

    #[test]
    fn exec_time_uses_100mhz_clock() {
        let (_, r) = launch(GpgpuConfig::new(1, 8), 1, 32);
        let want = r.total.cycles as f64 / 100e6 * 1e3;
        assert!((r.exec_time_ms() - want).abs() < 1e-12);
    }
}
