//! FlexGrip physical limits — paper **Table 1**, verbatim:
//!
//! | Parameter                                | Constraint |
//! |------------------------------------------|-----------|
//! | Threads per warp                         | 32        |
//! | Warps per SM                             | 24        |
//! | Threads per SM                           | 768       |
//! | Thread blocks per SM                     | 8         |
//! | Total 32-bit registers per SM            | 8,192     |
//! | Shared memory per SM (bytes)             | 16,384    |
//!
//! The block scheduler computes, at the start of kernel execution, "the
//! maximum number of thread blocks that can be scheduled ... limited by
//! the number of allocated warps per SM, the number of registers per SM,
//! and the size of the shared memory per SM" (paper §4.3).

use crate::sim::{SimError, PARAM_SEG_BYTES};

pub const THREADS_PER_WARP: u32 = 32;
pub const WARPS_PER_SM: u32 = 24;
pub const THREADS_PER_SM: u32 = 768;
pub const BLOCKS_PER_SM: u32 = 8;
pub const REGS_PER_SM: u32 = 8192;
pub const SMEM_PER_SM_BYTES: u32 = 16384;
/// Paper §4.3: "A thread block of up to 256 threads can be assigned to any
/// available SM".
pub const MAX_BLOCK_THREADS: u32 = 256;

/// Per-kernel resource requirements, as stored in the GPGPU configuration
/// registers at launch.
#[derive(Debug, Clone, Copy)]
pub struct KernelResources {
    pub regs_per_thread: u32,
    /// Kernel scratch shared memory per block (excluding the param segment).
    pub smem_bytes: u32,
    pub block_threads: u32,
}

impl KernelResources {
    /// Shared memory actually allocated per block (scratch + param segment).
    pub fn smem_alloc_bytes(&self) -> u32 {
        self.smem_bytes + PARAM_SEG_BYTES
    }

    /// Validate against the hard physical limits (fail the launch early,
    /// as the hardware driver would).
    pub fn validate(&self) -> Result<(), SimError> {
        if self.block_threads == 0 {
            return Err(SimError::LimitExceeded("empty thread block".into()));
        }
        if self.block_threads > MAX_BLOCK_THREADS {
            return Err(SimError::LimitExceeded(format!(
                "block of {} threads > {MAX_BLOCK_THREADS}",
                self.block_threads
            )));
        }
        if self.regs_per_thread * self.block_threads > REGS_PER_SM {
            return Err(SimError::LimitExceeded(format!(
                "block needs {} registers > {REGS_PER_SM} per SM",
                self.regs_per_thread * self.block_threads
            )));
        }
        if self.smem_alloc_bytes() > SMEM_PER_SM_BYTES {
            return Err(SimError::LimitExceeded(format!(
                "block needs {} shared bytes > {SMEM_PER_SM_BYTES} per SM",
                self.smem_alloc_bytes()
            )));
        }
        Ok(())
    }

    /// Maximum concurrently-resident blocks per SM (paper §4.3).
    pub fn max_resident_blocks(&self) -> u32 {
        let warps_per_block = self.block_threads.div_ceil(THREADS_PER_WARP);
        let by_warps = WARPS_PER_SM / warps_per_block;
        let by_threads = THREADS_PER_SM / self.block_threads;
        let by_regs = REGS_PER_SM / (self.regs_per_thread * self.block_threads).max(1);
        let by_smem = SMEM_PER_SM_BYTES / self.smem_alloc_bytes().max(1);
        BLOCKS_PER_SM
            .min(by_warps)
            .min(by_threads)
            .min(by_regs)
            .min(by_smem)
            .max(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(regs: u32, smem: u32, threads: u32) -> KernelResources {
        KernelResources { regs_per_thread: regs, smem_bytes: smem, block_threads: threads }
    }

    #[test]
    fn small_blocks_hit_the_eight_block_cap() {
        // 32-thread, 8-reg blocks: warps allow 24, threads allow 24,
        // regs allow 32 -> capped at 8 (Table 1).
        assert_eq!(res(8, 0, 32).max_resident_blocks(), 8);
    }

    #[test]
    fn thread_limit_dominates_for_256_thread_blocks() {
        // 768 / 256 = 3 resident blocks.
        assert_eq!(res(8, 0, 256).max_resident_blocks(), 3);
    }

    #[test]
    fn register_pressure_limits_residency() {
        // 32 regs x 256 threads = 8192 -> exactly 1 block.
        assert_eq!(res(32, 0, 256).max_resident_blocks(), 1);
    }

    #[test]
    fn shared_memory_limits_residency() {
        // ~8KB/block -> 2 blocks per 16KB SM? (8128+64)*2 = 16384 -> 2.
        assert_eq!(res(4, 8128, 64).max_resident_blocks(), 2);
    }

    #[test]
    fn oversized_block_rejected() {
        assert!(res(8, 0, 257).validate().is_err());
        assert!(res(64, 0, 256).validate().is_err()); // 16384 regs
        assert!(res(8, 16384, 64).validate().is_err()); // smem + params
        assert!(res(8, 0, 0).validate().is_err());
        assert!(res(8, 0, 256).validate().is_ok());
    }
}
