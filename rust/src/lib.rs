//! # FlexGrip-RS
//!
//! A production-grade reproduction of *"Soft GPGPUs for Embedded FPGAs:
//! An Architectural Evaluation"* (Andryc, Thomas, Tessier, 2016) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the soft-GPGPU architecture itself: a
//!   cycle-driven simulator of the FlexGrip streaming multiprocessor
//!   (5-stage pipeline, warp unit, divergence stack), the multi-SM block
//!   scheduler, the MicroBlaze-class scalar baseline, calibrated
//!   area/power/energy models, and the evaluation harness that
//!   regenerates every table and figure in the paper.
//! * **L2/L1 (python/, build-time only)** — the SIMT execute stage
//!   expressed as a JAX graph calling a Pallas warp-ALU kernel, AOT-lowered
//!   to HLO text artifacts which this crate loads and runs through the
//!   PJRT CPU client (`runtime`), plus XLA-executed golden models for the
//!   five paper benchmarks.
//!
//! See `DESIGN.md` for the system inventory and per-experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

// Style decision, applied crate-wide rather than per-site: lane loops
// index fixed `[i32; 32]` arrays by mask bit, where the index *is* the
// lane id — iterator rewrites obscure that. (The launch plumbing that
// once needed `too_many_arguments` now travels in `LaunchRequest` /
// `SmLaunch` bundles.)
#![allow(clippy::needless_range_loop)]

pub mod asm;
pub mod baseline;
pub mod coordinator;
pub mod gpgpu;
pub mod harness;
pub mod kernels;
pub mod model;
pub mod registry;
pub mod runtime;
pub mod rng;
pub mod sim;
pub mod isa;
