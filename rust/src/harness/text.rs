//! Fixed-width text table rendering for the report CLI and bench output.

/// A simple aligned text table.
#[derive(Debug, Clone, Default)]
pub struct TextTable {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> TextTable {
        TextTable {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with sensible precision for tables.
pub fn f(v: f64) -> String {
    if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new("T", &["name", "x"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = TextTable::new("T", &["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn float_formatting() {
        assert_eq!(f(18604.1), "18604");
        assert_eq!(f(40.28), "40.3");
        assert_eq!(f(1.94), "1.94");
    }
}
