//! Wall-clock scaling measurements for the parallel execution paths, with
//! a machine-readable `BENCH_scaling.json` emitter so successive PRs can
//! track the host-side scaling trajectory (simulated cycles are asserted
//! equal across paths elsewhere; this file is about *wall-clock*).
//!
//! Eight points per report:
//! * `1sm_sequential`  — reference path, one SM, 8 SP;
//! * `1sm_16sp_sequential` / `1sm_32sp_sequential` — the SP-width sweep
//!   (paper §5.1: 8/16/32 SP), priced by the Table-2 area calibration;
//! * `2sm_sequential`  — reference path, two SMs simulated back-to-back;
//! * `2sm_parallel`    — parallel launch mode, one thread per SM;
//! * `4sm_parallel` / `8sm_parallel` — the >2-SM scaling study (ROADMAP):
//!   configurations beyond the paper's 2-SM evaluation, feasible to sweep
//!   because per-SM memory setup is copy-on-write (O(touched pages));
//!   each point carries the extrapolated FPGA area from `model/area.rs`
//!   so simulated speedup can be read against LUT cost;
//! * `pool_4shard`     — 4-shard coordinator pool absorbing a job batch.
//!
//! [`scaling_suite`] sweeps several benchmarks (beyond the original
//! matmul-only report) and [`write_suite_json`] emits them as one JSON
//! array, one framed report object per benchmark.

use crate::coordinator::{GpgpuService, Request, ServiceConfig};
use crate::gpgpu::{Gpgpu, GpgpuConfig};
use crate::kernels::{self, BenchId, RunOptions};
use crate::model::{area::area, ArchParams};
use std::time::Instant;

/// One measured configuration.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub label: &'static str,
    /// SP width of the measured device(s).
    pub sp: u32,
    /// Median wall-clock per run/batch, milliseconds.
    pub wall_ms: f64,
    /// Simulated device cycles of one run (summed over pool jobs).
    pub sim_cycles: u64,
    /// Jobs per measured batch (1 for the direct launches).
    pub jobs: u32,
    /// FPGA area-model LUT estimate for the device configuration (the
    /// Table 2 calibration for 1/2 SM, the marginal-SM extrapolation
    /// beyond; a pool of shards counts each shard's device once).
    pub luts: u32,
}

/// A full scaling measurement at one benchmark/size.
#[derive(Debug, Clone)]
pub struct ScalingReport {
    pub bench: &'static str,
    pub n: u32,
    pub seed: u64,
    pub points: Vec<ScalingPoint>,
}

impl ScalingReport {
    /// den-metric / num-metric for two labelled points (None if either
    /// label is missing or the numerator's metric is zero).
    fn ratio(&self, num: &str, den: &str, metric: fn(&ScalingPoint) -> f64) -> Option<f64> {
        let f = |l: &str| self.points.iter().find(|p| p.label == l).map(metric);
        match (f(den), f(num)) {
            (Some(d), Some(n)) if n > 0.0 => Some(d / n),
            _ => None,
        }
    }

    /// Wall-clock speedup of `num` over `den` (both by label).
    pub fn speedup(&self, num: &str, den: &str) -> Option<f64> {
        self.ratio(num, den, |p| p.wall_ms)
    }

    /// Simulated-cycle speedup of `num` over `den` (both by label) — the
    /// architectural scaling the >2-SM and SP-width studies read against
    /// area cost.
    pub fn sim_speedup(&self, num: &str, den: &str) -> Option<f64> {
        self.ratio(num, den, |p| p.sim_cycles as f64)
    }

    /// Hand-rolled JSON (the image has no serde): stable field order,
    /// suitable for line-diffing across PRs.
    pub fn to_json(&self) -> String {
        let header = [
            format!("\"bench\": \"{}\"", self.bench),
            format!("\"n\": {}", self.n),
            format!("\"seed\": {}", self.seed),
        ];
        let points: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    "{{\"label\": \"{}\", \"sp\": {}, \"wall_ms\": {:.3}, \
                     \"sim_cycles\": {}, \"jobs\": {}, \"luts\": {}}}",
                    p.label, p.sp, p.wall_ms, p.sim_cycles, p.jobs, p.luts
                )
            })
            .collect();
        super::jsonfmt::frame(&header, &points)
    }

    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Serialize a multi-benchmark sweep as one JSON array (shared framing
/// with the single-report emitter).
pub fn suite_json(reports: &[ScalingReport]) -> String {
    let docs: Vec<String> = reports.iter().map(ScalingReport::to_json).collect();
    super::jsonfmt::array(&docs)
}

/// Write a multi-benchmark sweep to `path` (`BENCH_scaling.json`).
pub fn write_suite_json(
    path: impl AsRef<std::path::Path>,
    reports: &[ScalingReport],
) -> std::io::Result<()> {
    std::fs::write(path, suite_json(reports))
}

fn median_ms(samples: usize, mut f: impl FnMut() -> u64) -> (f64, u64) {
    let mut walls = Vec::with_capacity(samples);
    let mut cycles = 0;
    for _ in 0..samples {
        let t0 = Instant::now();
        cycles = f();
        walls.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    walls.sort_by(|a, b| a.partial_cmp(b).expect("finite wall times"));
    (walls[walls.len() / 2], cycles)
}

/// Area-model LUT estimate for an `sms`-SM, `sp`-SP device (exact at the
/// paper's calibration points, marginal-cost extrapolation beyond 2 SMs).
fn luts_for(sms: u32, sp: u32) -> u32 {
    area(&ArchParams { num_sms: sms, num_sp: sp, ..ArchParams::baseline() }).luts
}

/// Measure all eight scaling points for `id` at size `n`. Every run is
/// verified against the host golden reference.
pub fn scaling_report(id: BenchId, n: u32, seed: u64, samples: usize) -> ScalingReport {
    let samples = samples.max(1);
    let w = kernels::prepare(id, n, seed);
    let mut points = Vec::with_capacity(8);

    let mut direct = |label: &'static str, sms: u32, sp: u32, parallel: bool| {
        let gpgpu = Gpgpu::new(GpgpuConfig::new(sms, sp));
        let (wall_ms, sim_cycles) = median_ms(samples, || {
            let mut gmem = w.make_gmem();
            let opts =
                if parallel { RunOptions::new().parallel() } else { RunOptions::default() };
            let result = w.run(&gpgpu, &mut gmem, opts);
            let run = result.unwrap_or_else(|e| panic!("{label}: {e}"));
            w.verify(&gmem).unwrap_or_else(|e| panic!("{label}: {e}"));
            run.cycles
        });
        points.push(ScalingPoint {
            label,
            sp,
            wall_ms,
            sim_cycles,
            jobs: 1,
            luts: luts_for(sms, sp),
        });
    };
    direct("1sm_sequential", 1, 8, false);
    // SP-width sweep (paper §5.1's second scaling axis): wider SP arrays
    // cut simulated cycles at a steep Table-2 LUT/DSP cost.
    direct("1sm_16sp_sequential", 1, 16, false);
    direct("1sm_32sp_sequential", 1, 32, false);
    direct("2sm_sequential", 2, 8, false);
    direct("2sm_parallel", 2, 8, true);
    // ROADMAP >2-SM study: beyond the paper's largest configuration,
    // priced by the area model's marginal-SM extrapolation.
    direct("4sm_parallel", 4, 8, true);
    direct("8sm_parallel", 8, 8, true);

    // Pool throughput: 4 shards absorbing 8 concurrent jobs of the same
    // benchmark (1-SM devices so shard-level parallelism dominates).
    const POOL_JOBS: u32 = 8;
    const POOL_SHARDS: u32 = 4;
    let (wall_ms, sim_cycles) = median_ms(samples, || {
        let svc = GpgpuService::start_pool(
            GpgpuConfig::new(1, 8),
            ServiceConfig { shards: POOL_SHARDS, queue_depth: POOL_JOBS as usize },
        );
        let tickets: Vec<_> = (0..POOL_JOBS)
            .map(|i| svc.submit(Request::Bench { id, n, seed: seed + i as u64 }))
            .collect();
        let mut cycles = 0;
        for t in tickets {
            let out = t.wait().expect("pool job");
            assert!(out.verified);
            cycles += out.cycles;
        }
        cycles
    });
    points.push(ScalingPoint {
        label: "pool_4shard",
        sp: 8,
        wall_ms,
        sim_cycles,
        jobs: POOL_JOBS,
        luts: POOL_SHARDS * luts_for(1, 8),
    });

    ScalingReport { bench: id.name(), n, seed, points }
}

/// Sweep several benchmarks at one size (the ROADMAP follow-up to the
/// matmul-only study).
pub fn scaling_suite(
    ids: &[BenchId],
    n: u32,
    seed: u64,
    samples: usize,
) -> Vec<ScalingReport> {
    ids.iter().map(|id| scaling_report(*id, n, seed, samples)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const LABELS: [&str; 8] = [
        "1sm_sequential",
        "1sm_16sp_sequential",
        "1sm_32sp_sequential",
        "2sm_sequential",
        "2sm_parallel",
        "4sm_parallel",
        "8sm_parallel",
        "pool_4shard",
    ];

    #[test]
    fn report_has_all_points_and_valid_json() {
        let r = scaling_report(BenchId::VecAdd, 32, 1, 1);
        assert_eq!(r.points.len(), LABELS.len());
        let json = r.to_json();
        for label in LABELS {
            assert!(json.contains(label), "{json}");
        }
        assert!(json.contains("\"bench\": \"vecadd\""));
        assert!(json.contains("\"luts\""));
        assert!(json.contains("\"sp\": 32"));
        assert!(r.points.iter().all(|p| p.sim_cycles > 0));
        assert!(r.points.iter().all(|p| p.luts > 0));
        assert!(r.speedup("2sm_parallel", "1sm_sequential").is_some());
    }

    #[test]
    fn area_grows_with_extrapolated_sm_count_and_sp_width() {
        let by_label = |r: &ScalingReport, l: &str| {
            r.points.iter().find(|p| p.label == l).map(|p| p.luts).unwrap()
        };
        let r = scaling_report(BenchId::VecAdd, 32, 2, 1);
        let (l1, l2) = (by_label(&r, "1sm_sequential"), by_label(&r, "2sm_parallel"));
        let (l4, l8) = (by_label(&r, "4sm_parallel"), by_label(&r, "8sm_parallel"));
        assert!(l1 < l2 && l2 < l4 && l4 < l8, "{l1}/{l2}/{l4}/{l8}");
        let (s16, s32) =
            (by_label(&r, "1sm_16sp_sequential"), by_label(&r, "1sm_32sp_sequential"));
        assert!(l1 < s16 && s16 < s32, "SP sweep LUTs: {l1}/{s16}/{s32}");
    }

    #[test]
    fn multi_sm_simulated_cycles_shrink_on_a_parallel_benchmark() {
        // vecadd-256 has 4 blocks: 4 SMs split them 1:1; the 8-SM device
        // leaves SMs idle but must not be slower.
        let r = scaling_report(BenchId::VecAdd, 256, 3, 1);
        let s4 = r.sim_speedup("4sm_parallel", "1sm_sequential").unwrap();
        let s8 = r.sim_speedup("8sm_parallel", "1sm_sequential").unwrap();
        assert!(s4 > 1.5, "4-SM simulated speedup: {s4:.2}");
        assert!(s8 >= s4 * 0.99, "8-SM must not regress: {s8:.2} vs {s4:.2}");
        // Wider SPs must also cut simulated cycles (paper Fig. 4 shape).
        let w16 = r.sim_speedup("1sm_16sp_sequential", "1sm_sequential").unwrap();
        assert!(w16 > 1.0, "16-SP speedup: {w16:.2}");
    }

    #[test]
    fn suite_emits_one_report_per_benchmark() {
        let reports = scaling_suite(&[BenchId::VecAdd, BenchId::Reduction], 32, 1, 1);
        assert_eq!(reports.len(), 2);
        let json = suite_json(&reports);
        assert!(json.starts_with("[\n{\n"));
        assert!(json.contains("\"bench\": \"vecadd\""));
        assert!(json.contains("\"bench\": \"reduction\""));
        assert!(json.ends_with("]\n"));
    }
}
