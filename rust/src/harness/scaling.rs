//! Wall-clock scaling measurements for the parallel execution paths, with
//! a machine-readable `BENCH_scaling.json` emitter so successive PRs can
//! track the host-side scaling trajectory (simulated cycles are asserted
//! equal across paths elsewhere; this file is about *wall-clock*).
//!
//! Four points per report:
//! * `1sm_sequential`  — seed path, one SM;
//! * `2sm_sequential`  — seed path, two SMs simulated back-to-back;
//! * `2sm_parallel`    — `launch_parallel`, one thread per SM;
//! * `pool_4shard`     — 4-shard coordinator pool absorbing a job batch.

use crate::coordinator::{GpgpuService, Request, ServiceConfig};
use crate::gpgpu::{Gpgpu, GpgpuConfig};
use crate::kernels::{self, BenchId};
use crate::sim::NativeAlu;
use std::time::Instant;

/// One measured configuration.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub label: &'static str,
    /// Median wall-clock per run/batch, milliseconds.
    pub wall_ms: f64,
    /// Simulated device cycles of one run (summed over pool jobs).
    pub sim_cycles: u64,
    /// Jobs per measured batch (1 for the direct launches).
    pub jobs: u32,
}

/// A full scaling measurement at one benchmark/size.
#[derive(Debug, Clone)]
pub struct ScalingReport {
    pub bench: &'static str,
    pub n: u32,
    pub seed: u64,
    pub points: Vec<ScalingPoint>,
}

impl ScalingReport {
    /// Wall-clock speedup of `num` over `den` (both by label).
    pub fn speedup(&self, num: &str, den: &str) -> Option<f64> {
        let f = |l: &str| self.points.iter().find(|p| p.label == l).map(|p| p.wall_ms);
        match (f(den), f(num)) {
            (Some(d), Some(n)) if n > 0.0 => Some(d / n),
            _ => None,
        }
    }

    /// Hand-rolled JSON (the image has no serde): stable field order,
    /// suitable for line-diffing across PRs.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", self.bench));
        out.push_str(&format!("  \"n\": {},\n", self.n));
        out.push_str(&format!("  \"seed\": {},\n", self.seed));
        out.push_str("  \"points\": [\n");
        for (i, p) in self.points.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"label\": \"{}\", \"wall_ms\": {:.3}, \"sim_cycles\": {}, \"jobs\": {}}}{}\n",
                p.label,
                p.wall_ms,
                p.sim_cycles,
                p.jobs,
                if i + 1 == self.points.len() { "" } else { "," }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

fn median_ms(samples: usize, mut f: impl FnMut() -> u64) -> (f64, u64) {
    let mut walls = Vec::with_capacity(samples);
    let mut cycles = 0;
    for _ in 0..samples {
        let t0 = Instant::now();
        cycles = f();
        walls.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    walls.sort_by(|a, b| a.partial_cmp(b).expect("finite wall times"));
    (walls[walls.len() / 2], cycles)
}

/// Measure all four scaling points for `id` at size `n`. Every run is
/// verified against the host golden reference.
pub fn scaling_report(id: BenchId, n: u32, seed: u64, samples: usize) -> ScalingReport {
    let samples = samples.max(1);
    let w = kernels::prepare(id, n, seed);
    let mut points = Vec::with_capacity(4);

    let mut direct = |label: &'static str, sms: u32, parallel: bool| {
        let gpgpu = Gpgpu::new(GpgpuConfig::new(sms, 8));
        let (wall_ms, sim_cycles) = median_ms(samples, || {
            let mut gmem = w.make_gmem();
            let result = if parallel {
                w.run_parallel(&gpgpu, &mut gmem, &NativeAlu)
            } else {
                let mut alu = NativeAlu;
                w.run(&gpgpu, &mut gmem, &mut alu)
            };
            let run = result.unwrap_or_else(|e| panic!("{label}: {e}"));
            w.verify(&gmem).unwrap_or_else(|e| panic!("{label}: {e}"));
            run.cycles
        });
        points.push(ScalingPoint { label, wall_ms, sim_cycles, jobs: 1 });
    };
    direct("1sm_sequential", 1, false);
    direct("2sm_sequential", 2, false);
    direct("2sm_parallel", 2, true);

    // Pool throughput: 4 shards absorbing 8 concurrent jobs of the same
    // benchmark (1-SM devices so shard-level parallelism dominates).
    const POOL_JOBS: u32 = 8;
    let (wall_ms, sim_cycles) = median_ms(samples, || {
        let svc = GpgpuService::start_pool(
            GpgpuConfig::new(1, 8),
            ServiceConfig { shards: 4, queue_depth: POOL_JOBS as usize },
        );
        let tickets: Vec<_> = (0..POOL_JOBS)
            .map(|i| svc.submit(Request::Bench { id, n, seed: seed + i as u64 }))
            .collect();
        let mut cycles = 0;
        for t in tickets {
            let out = t.wait().expect("pool job");
            assert!(out.verified);
            cycles += out.cycles;
        }
        cycles
    });
    points.push(ScalingPoint { label: "pool_4shard", wall_ms, sim_cycles, jobs: POOL_JOBS });

    ScalingReport { bench: id.name(), n, seed, points }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_has_all_points_and_valid_json() {
        let r = scaling_report(BenchId::VecAdd, 32, 1, 1);
        assert_eq!(r.points.len(), 4);
        let json = r.to_json();
        for label in ["1sm_sequential", "2sm_sequential", "2sm_parallel", "pool_4shard"] {
            assert!(json.contains(label), "{json}");
        }
        assert!(json.contains("\"bench\": \"vecadd\""));
        assert!(r.points.iter().all(|p| p.sim_cycles > 0));
        assert!(r.speedup("2sm_parallel", "1sm_sequential").is_some());
    }
}
