//! Fleet replay report (`BENCH_fleet.json`): the paper's headline
//! customization result (§4.2, §5.2, Table 6) executed end-to-end instead
//! of only modeled.
//!
//! Methodology (EXPERIMENTS.md §Fleet):
//! 1. profile the five paper benchmarks on the baseline 1 SM / 8 SP
//!    device (`coordinator::profile` — the §4.1 representative-data run);
//! 2. build a heterogeneous fleet from the distinct recommended variants
//!    plus the full baseline, register the profiled signatures, and
//!    replay a job mix through the capability router — every job must
//!    complete on its routed variant (zero mis-admissions: no mid-run
//!    `Unsupported` trap, no stack overflow);
//! 3. replay the same mix through a baseline-only pool and compare
//!    modeled dynamic energy (`P_dyn x t`, the §5.1.2 formula). The
//!    customized variants execute in identical simulated time (stack and
//!    multiplier removal change power/area, not the pipeline), so the
//!    fleet-wide saving is pure routed-power reduction — read against
//!    Table 6's per-application "% Dyn. Red." envelope (~3%..38%, ≈14%
//!    on the five-benchmark mix).

use crate::coordinator::{
    customize, FleetConfig, GpgpuService, Request, RouterMode, ServiceConfig, VariantSpec,
};
use crate::gpgpu::GpgpuConfig;
use crate::kernels::BenchId;
use crate::model::{power::power, ArchParams};
use crate::sim::{MemoryConfig, SimError};

/// Per-benchmark accumulation over the replayed mix.
#[derive(Debug, Clone)]
pub struct FleetBenchPoint {
    pub bench: &'static str,
    pub jobs: u32,
    /// Variant the router admitted this benchmark's jobs to.
    pub variant: String,
    pub variant_dyn_w: f64,
    /// Simulated cycles, summed over the jobs.
    pub cycles: u64,
    /// Execution time at the overlay clock, summed over the jobs (ms).
    pub exec_ms: f64,
    /// Modeled dynamic energy of the jobs on the baseline-only pool (mJ).
    pub baseline_mj: f64,
    /// Same jobs on the routed customized variant (mJ).
    pub fleet_mj: f64,
    pub reduction_pct: f64,
}

/// The whole replay.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub n: u32,
    pub jobs_per_bench: u32,
    pub seed: u64,
    /// Memory-hierarchy label shared by every shard (`flat` or `l1 WxSxL`).
    pub memory: String,
    pub baseline_dyn_w: f64,
    pub baseline_mj: f64,
    pub fleet_mj: f64,
    /// Fleet-wide modeled dynamic-energy reduction, percent.
    pub reduction_pct: f64,
    /// Jobs that failed on the customized fleet — mis-admissions. The
    /// acceptance bar is zero.
    pub misadmissions: u64,
    pub points: Vec<FleetBenchPoint>,
}

impl FleetReport {
    /// Hand-rolled JSON (shared `jsonfmt` framing; no serde offline).
    pub fn to_json(&self) -> String {
        let header = [
            format!("\"n\": {}", self.n),
            format!("\"jobs_per_bench\": {}", self.jobs_per_bench),
            format!("\"seed\": {}", self.seed),
            format!("\"memory\": \"{}\"", self.memory),
            format!("\"baseline_dyn_w\": {:.4}", self.baseline_dyn_w),
            format!("\"baseline_mj\": {:.4}", self.baseline_mj),
            format!("\"fleet_mj\": {:.4}", self.fleet_mj),
            format!("\"reduction_pct\": {:.2}", self.reduction_pct),
            format!("\"misadmissions\": {}", self.misadmissions),
        ];
        let points: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    "{{\"bench\": \"{}\", \"jobs\": {}, \"variant\": \"{}\", \
                     \"variant_dyn_w\": {:.4}, \"cycles\": {}, \"exec_ms\": {:.3}, \
                     \"baseline_mj\": {:.4}, \"fleet_mj\": {:.4}, \"reduction_pct\": {:.2}}}",
                    p.bench,
                    p.jobs,
                    p.variant,
                    p.variant_dyn_w,
                    p.cycles,
                    p.exec_ms,
                    p.baseline_mj,
                    p.fleet_mj,
                    p.reduction_pct
                )
            })
            .collect();
        super::jsonfmt::frame(&header, &points)
    }

    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Profile, build, replay, compare — see the module docs. `n` is the
/// problem size (power of two, 32..=256) used for both profiling and
/// replay; `jobs_per_bench` jobs of each paper benchmark are submitted.
pub fn fleet_report(n: u32, jobs_per_bench: u32, seed: u64) -> Result<FleetReport, SimError> {
    fleet_report_with_memory(n, jobs_per_bench, seed, MemoryConfig::default())
}

/// [`fleet_report`] with an explicit memory hierarchy applied to *every*
/// shard (baseline pool and all customized variants alike, so the
/// cycle-for-cycle comparison between them still holds — all shards are
/// 1-SM devices, so the cache's static contention factor is identical
/// too; only routed power differs).
pub fn fleet_report_with_memory(
    n: u32,
    jobs_per_bench: u32,
    seed: u64,
    memory: MemoryConfig,
) -> Result<FleetReport, SimError> {
    memory.validate()?;
    let jobs_per_bench = jobs_per_bench.max(1);
    let base_cfg = GpgpuConfig::new(1, 8).with_memory(memory);
    let baseline_dyn_w = power(&ArchParams::from_config(&base_cfg)).dynamic_w;

    // 1. Profile on the baseline (also validates each run's output).
    let mut profiles = Vec::with_capacity(BenchId::PAPER.len());
    for id in BenchId::PAPER {
        profiles.push(customize::profile(id, n, seed)?);
    }

    // 2. The heterogeneous fleet: baseline + every distinct recommended
    // variant, one shard each.
    let mut variants = vec![VariantSpec::new("baseline", base_cfg)];
    for p in &profiles {
        let cfg = p.recommended_config().with_memory(memory);
        if !variants.iter().any(|v| v.cfg == cfg) {
            variants.push(VariantSpec::new(p.recommended.label(), cfg));
        }
    }
    // Static routing on purpose: this harness is the Table-6 *energy*
    // experiment — every job must land on its power-optimal variant
    // deterministically, independent of burst-induced queue pressure.
    // The dynamic QoS router has its own sweep (`harness/qos.rs`).
    let fleet =
        GpgpuService::start_fleet(FleetConfig::new(variants).with_router(RouterMode::Static));
    for p in &profiles {
        fleet.register_profile(p.bench, p.refined_signature());
    }
    let baseline_pool =
        GpgpuService::start_pool(base_cfg, ServiceConfig { shards: 2, queue_depth: 64 });

    // 3. Replay the same mix through both.
    let submit_mix = |svc: &GpgpuService| -> Vec<(BenchId, crate::coordinator::JobTicket)> {
        let mut tickets = Vec::new();
        for k in 0..jobs_per_bench {
            for id in BenchId::PAPER {
                tickets.push((id, svc.submit(Request::Bench { id, n, seed: seed + k as u64 })));
            }
        }
        tickets
    };
    let fleet_tickets = submit_mix(&fleet);
    let base_tickets = submit_mix(&baseline_pool);

    let mut misadmissions = 0u64;
    let mut points: Vec<FleetBenchPoint> = BenchId::PAPER
        .iter()
        .map(|id| FleetBenchPoint {
            bench: id.name(),
            jobs: 0,
            variant: String::new(),
            variant_dyn_w: baseline_dyn_w,
            cycles: 0,
            exec_ms: 0.0,
            baseline_mj: 0.0,
            fleet_mj: 0.0,
            reduction_pct: 0.0,
        })
        .collect();
    let dyn_w_of = |label: &str| -> f64 {
        fleet
            .variant_power()
            .into_iter()
            .find(|(l, _)| l == label)
            .map(|(_, w)| w)
            .unwrap_or(baseline_dyn_w)
    };
    let idx_of =
        |id: BenchId| BenchId::PAPER.iter().position(|b| *b == id).expect("paper bench");

    let mut fleet_cycles: Vec<u64> = Vec::new();
    // Per submitted job: did its fleet run succeed? The baseline pass only
    // counts energy for jobs the fleet also completed, so a failure can
    // never *inflate* the reported reduction.
    let mut fleet_ok: Vec<bool> = Vec::new();
    for (id, t) in fleet_tickets {
        match t.wait() {
            Ok(out) => {
                assert!(out.verified, "{}: fleet job must verify", id.name());
                let p = &mut points[idx_of(id)];
                p.jobs += 1;
                p.cycles += out.cycles;
                p.exec_ms += out.exec_time_ms;
                if p.variant.is_empty() {
                    p.variant = out.variant.clone();
                    p.variant_dyn_w = dyn_w_of(&out.variant);
                } else {
                    assert_eq!(
                        p.variant,
                        out.variant,
                        "{}: router must be deterministic",
                        id.name()
                    );
                }
                fleet_cycles.push(out.cycles);
                fleet_ok.push(true);
            }
            Err(_) => {
                misadmissions += 1;
                fleet_ok.push(false);
            }
        }
    }
    let mut base_cycles: Vec<u64> = Vec::new();
    for ((id, t), ok) in base_tickets.into_iter().zip(&fleet_ok) {
        // A baseline-pool failure is a broken build, not a routing
        // outcome: surface it through the structured error path (the
        // fleet-demo CLI reports it and exits non-zero).
        let out = t.wait().map_err(|e| {
            SimError::LimitExceeded(format!("{} on the baseline pool: {e}", id.name()))
        })?;
        if *ok {
            base_cycles.push(out.cycles);
            let p = &mut points[idx_of(id)];
            p.baseline_mj += baseline_dyn_w * out.exec_time_ms;
        }
    }
    // Customization must not change simulated time — only power/area
    // (compared over the fleet-completed jobs; both mixes were submitted
    // in identical order).
    assert_eq!(
        fleet_cycles, base_cycles,
        "customized variants must match baseline cycles job-for-job"
    );

    let mut baseline_mj = 0.0;
    let mut fleet_mj = 0.0;
    for p in &mut points {
        p.fleet_mj = p.variant_dyn_w * p.exec_ms;
        p.reduction_pct = if p.baseline_mj > 0.0 {
            100.0 * (1.0 - p.fleet_mj / p.baseline_mj)
        } else {
            0.0
        };
        baseline_mj += p.baseline_mj;
        fleet_mj += p.fleet_mj;
    }
    let reduction_pct =
        if baseline_mj > 0.0 { 100.0 * (1.0 - fleet_mj / baseline_mj) } else { 0.0 };

    Ok(FleetReport {
        n,
        jobs_per_bench,
        seed,
        memory: memory.label(),
        baseline_dyn_w,
        baseline_mj,
        fleet_mj,
        reduction_pct,
        misadmissions,
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_replay_routes_and_saves_energy() {
        let r = fleet_report(32, 1, 7).unwrap();
        assert_eq!(r.misadmissions, 0, "zero mis-admissions (acceptance)");
        assert_eq!(r.points.len(), 5);
        for p in &r.points {
            assert_eq!(p.jobs, 1);
            assert!(p.cycles > 0 && p.exec_ms > 0.0, "{}", p.bench);
            assert!(!p.variant.is_empty(), "{}", p.bench);
        }
        // Routing lands each benchmark on its Table-6 variant, not the
        // baseline fallback.
        let by = |b: &str| r.points.iter().find(|p| p.bench == b).unwrap();
        assert!(by("bitonic").variant.contains("no mul"), "{}", by("bitonic").variant);
        assert!(by("autocorr").variant.contains("stack 16"), "{}", by("autocorr").variant);
        assert!(by("matmul").variant.contains("stack 0"), "{}", by("matmul").variant);
        for p in &r.points {
            assert_ne!(p.variant, "baseline", "{} must leave the fallback", p.bench);
        }
        // Fleet-wide modeled dynamic-energy reduction within the paper's
        // customization envelope (Table 6: 3%..38% per app, ~14% mix).
        assert!(
            (5.0..35.0).contains(&r.reduction_pct),
            "fleet-wide reduction {:.1}% outside the Table-6 envelope",
            r.reduction_pct
        );
        let json = r.to_json();
        for field in ["\"reduction_pct\"", "\"misadmissions\": 0", "\"variant\""] {
            assert!(json.contains(field), "{json}");
        }
    }

    #[test]
    fn cached_fleet_replay_matches_baseline_cycles_job_for_job() {
        use crate::sim::CacheGeometry;
        let mem = MemoryConfig::with_l1(CacheGeometry::parse("2x16x32").unwrap());
        // fleet_report_with_memory asserts fleet == baseline cycles
        // internally; a cached fleet must still satisfy it (the cache's
        // contention factor is static, so 1-SM shards agree exactly).
        let r = fleet_report_with_memory(32, 1, 7, mem).unwrap();
        assert_eq!(r.misadmissions, 0);
        assert!(r.memory.contains("2x16x32"), "{}", r.memory);
        assert!(r.to_json().contains("\"memory\": \"l1 2x16x32\""));
    }

    #[test]
    fn per_bench_reduction_tracks_the_variant_power() {
        let r = fleet_report(32, 1, 3).unwrap();
        let by = |b: &str| r.points.iter().find(|p| p.bench == b).unwrap();
        // bitonic (no mul, stack 2) saves the most; autocorr (stack 16
        // only) the least — Table 6's ordering.
        assert!(by("bitonic").reduction_pct > by("matmul").reduction_pct);
        assert!(by("matmul").reduction_pct > by("autocorr").reduction_pct);
        assert!(by("autocorr").reduction_pct > 0.0);
    }
}
