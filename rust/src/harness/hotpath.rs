//! Engine-throughput report (`BENCH_hot_path.json`): simulated
//! warp-instructions per second on the paper benchmarks — the ISSUE-2
//! acceptance metric, tracked across PRs (EXPERIMENTS.md §Perf).
//!
//! The measurement itself lives in `benches/hot_path.rs` (it needs the
//! wall-clock bench helper); this module owns the data shape and the
//! hand-rolled JSON emitter (no serde in the offline image — same
//! convention as [`super::scaling::ScalingReport`]) so the schema is
//! unit-tested and not duplicated inside a bench binary.

/// One engine-throughput measurement.
#[derive(Debug, Clone)]
pub struct HotPathPoint {
    pub bench: &'static str,
    pub n: u32,
    /// Simulated warp-instructions of one full (multi-phase) run.
    pub warp_instrs: u64,
    /// Active thread-instructions of one run (lane-level work).
    pub thread_instrs: u64,
    /// Median wall-clock of one run, milliseconds.
    pub wall_ms: f64,
    /// `warp_instrs` / median wall-clock.
    pub instrs_per_sec: f64,
    /// Mean fraction of the 32 lanes active per issued warp-instruction
    /// ([`crate::sim::SmStats::lane_occupancy`]).
    pub lane_occupancy: f64,
    /// Percentage of warp-instructions issued down the vectorized batch
    /// path ([`crate::sim::SmStats::batched_uop_pct`]).
    pub batched_uop_pct: f64,
    /// Mean submit-to-dispatch latency per job through the service
    /// plane's sharded queue, nanoseconds (0 when not measured).
    pub queue_wait_ns: u64,
}

/// A full engine-throughput report.
#[derive(Debug, Clone)]
pub struct HotPathReport {
    /// Measured at `FLEXGRIP_BENCH_FAST=1` smoke sizes?
    pub fast: bool,
    pub points: Vec<HotPathPoint>,
}

impl HotPathReport {
    /// Geometric mean of per-benchmark throughput — the headline number.
    pub fn geomean_instrs_per_sec(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        let log_sum: f64 = self.points.iter().map(|p| p.instrs_per_sec.ln()).sum();
        (log_sum / self.points.len() as f64).exp()
    }

    /// Hand-rolled JSON: stable field order, suitable for line-diffing
    /// across PRs (framing shared with `ScalingReport` via
    /// `super::jsonfmt`).
    pub fn to_json(&self) -> String {
        let header = [format!("\"fast\": {}", self.fast)];
        let points: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    "{{\"bench\": \"{}\", \"n\": {}, \"warp_instrs\": {}, \
                     \"thread_instrs\": {}, \"wall_ms\": {:.3}, \"instrs_per_sec\": {:.0}, \
                     \"lane_occupancy\": {:.3}, \"batched_uop_pct\": {:.1}, \
                     \"queue_wait_ns\": {}}}",
                    p.bench,
                    p.n,
                    p.warp_instrs,
                    p.thread_instrs,
                    p.wall_ms,
                    p.instrs_per_sec,
                    p.lane_occupancy,
                    p.batched_uop_pct,
                    p.queue_wait_ns
                )
            })
            .collect();
        super::jsonfmt::frame(&header, &points)
    }

    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(bench: &'static str, ips: f64) -> HotPathPoint {
        HotPathPoint {
            bench,
            n: 64,
            warp_instrs: 1000,
            thread_instrs: 32_000,
            wall_ms: 1.5,
            instrs_per_sec: ips,
            lane_occupancy: 1.0,
            batched_uop_pct: 87.5,
            queue_wait_ns: 12_345,
        }
    }

    #[test]
    fn json_schema_is_stable() {
        let r = HotPathReport {
            fast: true,
            points: vec![point("matmul", 2e6), point("bitonic", 1e6)],
        };
        let json = r.to_json();
        assert!(json.starts_with("{\n  \"fast\": true,\n  \"points\": [\n"));
        assert!(json.contains(
            "{\"bench\": \"matmul\", \"n\": 64, \"warp_instrs\": 1000, \
             \"thread_instrs\": 32000, \"wall_ms\": 1.500, \"instrs_per_sec\": 2000000, \
             \"lane_occupancy\": 1.000, \"batched_uop_pct\": 87.5, \
             \"queue_wait_ns\": 12345},"
        ));
        assert!(json.ends_with("  ]\n}\n"));
        assert_eq!(json.matches("\"bench\"").count(), 2);
        assert_eq!(json.matches("\"queue_wait_ns\"").count(), 2);
    }

    #[test]
    fn geomean_of_two_points() {
        let r = HotPathReport { fast: false, points: vec![point("a", 1e6), point("b", 4e6)] };
        assert!((r.geomean_instrs_per_sec() - 2e6).abs() < 1.0);
        let empty = HotPathReport { fast: false, points: vec![] };
        assert_eq!(empty.geomean_instrs_per_sec(), 0.0);
    }
}
