//! QoS routing sweep (`BENCH_qos.json`): the dynamic admission router
//! and the elastic rebalancer measured against the static baseline
//! (EXPERIMENTS.md §QoS).
//!
//! Scenarios:
//! 1. `homogeneous` — a single-variant pool per pure class mix: the
//!    pass-through guarantee (routing bit-identical to the static path —
//!    no spills, no tie-breaks) plus per-class queue-wait quantiles;
//! 2. `hetero-tie` — two bit-equal-power variants under a serial mixed
//!    class mix: every admission is a round-robin tie-break and both
//!    variants take work (the tie-starvation bugfix, measured);
//! 3. `sick-fleet` — an equal-power pair where the static favorite
//!    carries a saturating SEU campaign, swept in `static` and `qos`
//!    router modes with tight queues and deadline'd submits: the static
//!    router keeps feeding the quarantined favorite and sheds
//!    `Saturated`, the QoS router spills to the healthy peer and
//!    completes. This is the headline regression gate — `spill_rate` is
//!    the fraction of measured submissions shed as `Saturated`, and
//!    [`qos_report`] asserts the static mode sheds at least half the mix
//!    while the QoS mode completes ≥ 95% of it;
//! 4. `elastic` — a compute burst against a 1-shard elastic variant: the
//!    rebalancer scales up under backlog and retires the extra shards
//!    (drain-then-retire) once the burst drains.

use crate::coordinator::{
    ElasticConfig, FleetConfig, GpgpuService, QosClass, RecoveryPolicy, Request, RouterMode,
    VariantSpec,
};
use crate::gpgpu::GpgpuConfig;
use crate::kernels::BenchId;
use crate::sim::FaultPlan;
use std::time::{Duration, Instant};

/// One (scenario, router-mode, class-mix) cell of the sweep.
#[derive(Debug, Clone)]
pub struct QosPoint {
    pub scenario: &'static str,
    /// Router mode the fleet ran under (`static` or `qos`).
    pub mode: &'static str,
    /// Latency-class mix submitted (`latency` / `throughput` /
    /// `besteffort` / `mixed`).
    pub mix: &'static str,
    /// Measured submissions (warm-up jobs excluded).
    pub jobs: u32,
    pub completed: u64,
    /// Submissions shed as `Saturated` (admission gate or queue timeout).
    pub shed: u64,
    /// `shed / jobs` — the sick-fleet regression gate in
    /// `tools/bench_diff.py`.
    pub spill_rate: f64,
    /// Jobs the router moved off the static power choice (load/health).
    pub spilled: u64,
    /// Jobs landed by round-robin among bit-equal power ties.
    pub tie_broken: u64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub p50_wait_ns: u64,
    pub p95_wait_ns: u64,
}

/// The whole sweep.
#[derive(Debug, Clone)]
pub struct QosReport {
    pub n: u32,
    pub jobs_per_point: u32,
    pub seed: u64,
    pub points: Vec<QosPoint>,
}

impl QosReport {
    /// Hand-rolled JSON (shared `jsonfmt` framing; no serde offline).
    pub fn to_json(&self) -> String {
        let header = [
            format!("\"n\": {}", self.n),
            format!("\"jobs_per_point\": {}", self.jobs_per_point),
            format!("\"seed\": {}", self.seed),
        ];
        let points: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    "{{\"scenario\": \"{}\", \"mode\": \"{}\", \"mix\": \"{}\", \
                     \"jobs\": {}, \"completed\": {}, \"shed\": {}, \"spill_rate\": {:.4}, \
                     \"spilled\": {}, \"tie_broken\": {}, \"scale_ups\": {}, \
                     \"scale_downs\": {}, \"p50_wait_ns\": {}, \"p95_wait_ns\": {}}}",
                    p.scenario,
                    p.mode,
                    p.mix,
                    p.jobs,
                    p.completed,
                    p.shed,
                    p.spill_rate,
                    p.spilled,
                    p.tie_broken,
                    p.scale_ups,
                    p.scale_downs,
                    p.p50_wait_ns,
                    p.p95_wait_ns
                )
            })
            .collect();
        super::jsonfmt::frame(&header, &points)
    }

    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Fold a finished service's routing snapshot into a sweep point.
fn point(
    scenario: &'static str,
    mode: &'static str,
    mix: &'static str,
    jobs: u32,
    completed: u64,
    shed: u64,
    svc: &GpgpuService,
) -> QosPoint {
    let snap = svc.routing_stats();
    QosPoint {
        scenario,
        mode,
        mix,
        jobs,
        completed,
        shed,
        spill_rate: if jobs == 0 { 0.0 } else { shed as f64 / f64::from(jobs) },
        spilled: snap.spilled(),
        tie_broken: snap.tie_broken(),
        scale_ups: snap.scale_ups,
        scale_downs: snap.scale_downs,
        p50_wait_ns: snap.overall.p50_ns,
        p95_wait_ns: snap.overall.p95_ns,
    }
}

/// Scenario 1: a homogeneous 2-shard pool under one pure class mix. A
/// single covering variant short-circuits the router before any signal
/// is read, so this measures the pass-through path (and the per-class
/// wait accounting) only.
fn homogeneous_point(class: QosClass, n: u32, jobs: u32, seed: u64) -> QosPoint {
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![VariantSpec::new("pool", GpgpuConfig::new(1, 8)).with_shards(2)])
            .with_depth(16),
    );
    let tickets: Vec<_> = (0..jobs)
        .map(|k| {
            svc.submit(
                Request::Bench { id: BenchId::VecAdd, n, seed: seed + u64::from(k) }.qos(class),
            )
        })
        .collect();
    let completed = tickets.into_iter().filter_map(|t| t.wait().ok()).count() as u64;
    point("homogeneous", "qos", class.name(), jobs, completed, 0, &svc)
}

/// Scenario 2: two bit-equal-power variants, serial mixed-class replay.
/// Every admission is a power tie, so the round-robin cursor must
/// alternate — the regression surface of the old `min_by` pinning bug.
fn tie_point(n: u32, jobs: u32, seed: u64) -> QosPoint {
    let base = GpgpuConfig::new(1, 8);
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![VariantSpec::new("tie-a", base), VariantSpec::new("tie-b", base)])
            .with_depth(16),
    );
    let mut completed = 0u64;
    for k in 0..jobs {
        let class = QosClass::ALL[k as usize % QosClass::ALL.len()];
        let req = Request::Bench { id: BenchId::VecAdd, n, seed: seed + u64::from(k) }.qos(class);
        if svc.submit(req).wait().is_ok() {
            completed += 1;
        }
    }
    let snap = svc.routing_stats();
    assert_eq!(snap.tie_broken(), u64::from(jobs), "equal-power pair: every admission is a tie");
    assert!(snap.variants.iter().all(|v| v.admitted() > 0), "no variant starves on the tie");
    point("hetero-tie", "qos", "mixed", jobs, completed, 0, &svc)
}

fn mode_name(mode: RouterMode) -> &'static str {
    match mode {
        RouterMode::Static => "static",
        RouterMode::Qos => "qos",
    }
}

/// Scenario 3: the sick favorite. Both variants tie bit-for-bit on
/// modeled power and the sick one sits at the lower index, so the static
/// router pins every job to it — even while its only shard sits out a
/// quarantine, where tight queues + deadline'd submits turn the pin into
/// `Saturated` sheds. The QoS router sees the quarantine (zero healthy
/// shards) and spills the same mix to the healthy peer.
fn sick_point(mode: RouterMode, n: u32, jobs: u32, seed: u64) -> QosPoint {
    let base = GpgpuConfig::new(1, 8);
    let sick = VariantSpec::new("sick", base)
        .with_fault(0, FaultPlan::new(0xBAD_5EED ^ seed, 1_000_000.0));
    // One fault quarantines; 500 ms covers the whole deadline'd submit
    // loop (at most `jobs` × 25 ms) with ~2x margin.
    let policy = RecoveryPolicy { max_attempts: 2, quarantine_after: 1, quarantine_ms: 500 };
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![sick, VariantSpec::new("healthy", base)])
            .with_depth(2)
            .with_policy(policy)
            .with_router(mode),
    );
    // Warm-up: one job faults on the sick favorite, is rescued on the
    // healthy peer, and trips the sick shard into quarantine. The short
    // sleep lets the quarantine flag publish before the measured loop.
    svc.submit(Request::Bench { id: BenchId::VecAdd, n, seed })
        .wait()
        .expect("warm-up job is rescued on the healthy peer");
    std::thread::sleep(Duration::from_millis(10));
    let mut tickets = Vec::new();
    let mut shed = 0u64;
    for k in 0..jobs {
        let req = Request::Bench { id: BenchId::VecAdd, n, seed: seed + 1 + u64::from(k) };
        match svc.submit_timeout(req, Duration::from_millis(25)) {
            Ok(t) => tickets.push(t),
            Err(_) => shed += 1,
        }
    }
    let completed = tickets.into_iter().filter_map(|t| t.wait().ok()).count() as u64;
    point("sick-fleet", mode_name(mode), "throughput", jobs, completed, shed, &svc)
}

/// Scenario 4: a matmul burst against a 1-shard elastic variant
/// (`[1, 3]` band, 1 ms sampling). Backlog spins parked slots up;
/// after the drain the idle samples retire them again.
fn elastic_point(n: u32, jobs: u32, seed: u64) -> QosPoint {
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![VariantSpec::new("elastic", GpgpuConfig::new(1, 8))])
            .with_depth(64)
            .with_elastic(ElasticConfig::new(1, 3).with_sample_ms(1)),
    );
    // Multi-millisecond jobs so the burst outlives the sampling period.
    let n = n.max(64);
    let tickets: Vec<_> = (0..jobs)
        .map(|k| svc.submit(Request::Bench { id: BenchId::MatMul, n, seed: seed + u64::from(k) }))
        .collect();
    let completed = tickets.into_iter().filter_map(|t| t.wait().ok()).count() as u64;
    // Drain-then-retire is asynchronous: give the supervisor up to 2 s of
    // idle samples to retire the shards it spun up.
    let deadline = Instant::now() + Duration::from_secs(2);
    while svc.routing_stats().scale_downs == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    point("elastic", "qos", "throughput", jobs, completed, 0, &svc)
}

/// Run the full sweep: `jobs_per_point` jobs per cell (floored at 6 so
/// the tight sick-fleet queues are actually pressured), problem size `n`
/// (power of two, 32..=256). Asserts the sick-fleet acceptance gate:
/// static mode sheds at least half the mix, QoS mode completes ≥ 95%.
pub fn qos_report(n: u32, jobs_per_point: u32, seed: u64) -> QosReport {
    let jobs = jobs_per_point.max(6);
    let mut points = Vec::new();
    for class in QosClass::ALL {
        points.push(homogeneous_point(class, n, jobs, seed));
    }
    points.push(tie_point(n, jobs, seed));
    let sick_static = sick_point(RouterMode::Static, n, jobs, seed);
    let sick_qos = sick_point(RouterMode::Qos, n, jobs, seed);
    assert!(
        sick_static.shed >= u64::from(jobs / 2),
        "static router must shed under the quarantined favorite (shed {} of {jobs})",
        sick_static.shed
    );
    assert!(
        sick_qos.completed * 100 >= u64::from(jobs) * 95,
        "QoS router must complete >= 95% of the mix the static router sheds ({} of {jobs})",
        sick_qos.completed
    );
    points.push(sick_static);
    points.push(sick_qos);
    points.push(elastic_point(n, jobs, seed));
    QosReport { n, jobs_per_point: jobs, seed, points }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_the_scenarios_and_gates_the_sick_fleet() {
        let r = qos_report(32, 6, 7);
        assert_eq!(r.points.len(), 7);
        for p in &r.points {
            let at = format!("{} {} {}", p.scenario, p.mode, p.mix);
            assert_eq!(u64::from(p.jobs), p.completed + p.shed, "{at}: every submission resolves");
            if p.scenario == "homogeneous" {
                // Pass-through guarantee: one covering variant means the
                // QoS path is bit-identical to static routing.
                assert_eq!(p.completed, u64::from(p.jobs), "{at}");
                assert_eq!(p.spilled, 0, "{at}");
                assert_eq!(p.tie_broken, 0, "{at}");
            }
        }
        let find = |scenario: &str, mode: &str| {
            r.points
                .iter()
                .find(|p| p.scenario == scenario && p.mode == mode)
                .unwrap_or_else(|| panic!("missing point {scenario}/{mode}"))
        };
        let sick_static = find("sick-fleet", "static");
        let sick_qos = find("sick-fleet", "qos");
        assert!(sick_static.shed > 0, "static mode must shed into the quarantine");
        assert!(sick_static.spill_rate > sick_qos.spill_rate);
        assert!(sick_qos.spilled > 0, "QoS mode routes around the quarantine");
        let elastic = find("elastic", "qos");
        assert!(elastic.scale_ups >= 1, "burst backlog must spin up a shard");
        assert!(elastic.scale_downs >= 1, "idle drain must retire a shard");
        assert_eq!(elastic.completed, u64::from(elastic.jobs));
        let json = r.to_json();
        for field in [
            "\"scenario\": \"sick-fleet\"",
            "\"mode\": \"static\"",
            "\"mix\": \"besteffort\"",
            "\"spill_rate\"",
            "\"scale_downs\"",
            "\"p95_wait_ns\"",
        ] {
            assert!(json.contains(field), "{json}");
        }
    }
}
