//! Minimal criterion-style micro-benchmark helper (criterion is not
//! available offline; `cargo bench` binaries use this instead).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
}

impl BenchResult {
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        s[s.len() / 2]
    }

    pub fn min(&self) -> Duration {
        *self.samples.iter().min().unwrap()
    }

    pub fn mean(&self) -> Duration {
        self.samples.iter().sum::<Duration>() / self.samples.len() as u32
    }

    /// One-line criterion-style report.
    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>12?}  mean {:>12?}  min {:>12?}  ({} samples)",
            self.name,
            self.median(),
            self.mean(),
            self.min(),
            self.samples.len()
        )
    }
}

/// Run `f` for `samples` timed iterations (after one warm-up) and report.
/// The closure's return value is black-boxed to keep the optimizer honest.
pub fn bench<T>(name: &str, samples: usize, mut f: impl FnMut() -> T) -> BenchResult {
    let _warmup = std::hint::black_box(f());
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        out.push(t0.elapsed());
    }
    let r = BenchResult { name: name.to_string(), samples: out };
    println!("{}", r.report());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_stats() {
        let r = bench("noop", 5, || 1 + 1);
        assert_eq!(r.samples.len(), 5);
        assert!(r.median() >= r.min());
    }
}
