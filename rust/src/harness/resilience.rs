//! Resilience sweep (`BENCH_resilience.json`): correct-and-continue
//! policy comparison under seeded SEU campaigns (EXPERIMENTS.md
//! §Resilience).
//!
//! Methodology:
//! 1. build an *all-sick* single-variant fleet — one shard carrying a
//!    deterministic [`FaultPlan`] campaign and no healthy peer, so every
//!    retry re-lands on the faulted hardware. That is the paper's
//!    stranded-satellite scenario: when the deployed FPGA is the only
//!    FPGA, recovery has to come from protection and replay, not from
//!    re-routing;
//! 2. replay a small benchmark mix serially for every point of the
//!    {parity, ecc, ecc+scrub} x {transient, stuck-at} x
//!    {rerun, checkpoint, dmr, tmr} stress grid (plus one clean
//!    rate-0 row per policy), timing each ticket submit-to-wait;
//! 3. report availability (completed / jobs), jobs rescued (completed
//!    with `attempts > 1`), jobs lost, corrupted outputs served (must
//!    stay zero under every policy), ECC correction and checkpoint
//!    replay counters, retry latency overhead, and the fleet health
//!    counters (soft errors, retries, quarantines, reinstatements,
//!    DMR mismatches, TMR outvotes).
//!
//! The headline contrast: under stuck-at aging, parity + rerun keeps
//! re-executing into the same defective BRAM cells and loses a large
//! fraction of the mix, while ECC + scrubbing + barrier checkpointing
//! corrects the transients, drains the stuck sites, and replays through
//! the rare uncorrectable double hits — completing nearly everything on
//! the same sick hardware.
//!
//! Rate 0 disables the campaign entirely (the injector's zero-cost
//! contract), giving each policy a clean reference row.

use crate::coordinator::{FleetConfig, GpgpuService, RecoveryPolicy, Request, VariantSpec};
use crate::gpgpu::GpgpuConfig;
use crate::kernels::BenchId;
use crate::sim::{CheckpointPolicy, FaultPlan, ProtectionConfig};
use std::time::Instant;

/// Upsets per million simulated cycles on the stress rows: mean interval
/// 50 cycles — several upsets inside every launch of the mix, without
/// saturating the checkpoint replay budget.
pub const STRESS_RATE: f64 = 20_000.0;

/// Fraction of stress-row upsets that leave a stuck-at (aged) BRAM cell
/// behind on the `stuck-at` rows of the aging axis.
pub const STUCK_FRACTION: f64 = 0.3;

/// The recovery-policy axis. Every policy rides on `retry(3)`; they
/// differ in what each execution does about faults: `rerun` only
/// re-executes, `checkpoint` replays from barrier checkpoints,
/// `dmr`/`tmr` wrap the request in modular redundancy.
pub const POLICIES: [&str; 4] = ["rerun", "checkpoint", "dmr", "tmr"];

/// The protection axis for the stress rows.
pub const PROTECTIONS: [&str; 3] = ["parity", "ecc", "ecc+scrub"];

/// Optional restriction of the sweep grid (the CLI's `--protect`,
/// `--checkpoint`/`--tmr` and `--stuck-at` flags). Default = full grid.
#[derive(Debug, Clone, Default)]
pub struct SweepScope {
    /// Restrict the protection axis to one mode (None = all three).
    pub protection: Option<String>,
    /// Restrict the policy axis to these policies (empty = all four).
    pub policies: Vec<String>,
    /// Override the stuck-at fraction of the aging stress rows.
    pub stuck_fraction: Option<f64>,
}

/// One (policy, protection, aging, fault-rate) cell of the sweep grid.
#[derive(Debug, Clone)]
pub struct ResiliencePoint {
    pub policy: &'static str,
    /// Protection mode of the sick shard's BRAMs: `parity`, `ecc`, or
    /// `ecc+scrub` (clean rows report `parity`, the default hardware).
    pub protection: &'static str,
    /// Aging mode of the campaign: `transient` (every upset decays) or
    /// `stuck-at` (a fraction of upsets leave defective cells behind).
    pub aging: &'static str,
    pub fault_rate: f64,
    pub jobs: u32,
    pub completed: u64,
    /// `completed / jobs` — the headline availability number.
    pub availability: f64,
    /// Completed jobs that needed more than one execution.
    pub rescued: u64,
    /// Tickets that resolved with an error.
    pub lost: u64,
    /// Completed jobs whose output failed golden verification — corrupted
    /// results actually served. Must stay zero under every policy.
    pub corrupted: u64,
    /// ECC single-bit corrections inside completed launches.
    pub corrected: u64,
    /// Uncorrectable (aged-site) ECC hits inside completed launches.
    pub uncorrectable: u64,
    /// Checkpoint restarts inside completed launches.
    pub restarts: u64,
    /// Cycles replayed by those restarts.
    pub replayed_cycles: u64,
    /// Transient fault-class failures observed fleet-wide (detected SEUs,
    /// verify rejects, DMR mismatches, TMR inconclusives).
    pub soft_errors: u64,
    pub retries: u64,
    pub quarantines: u64,
    pub reinstatements: u64,
    pub dmr_mismatches: u64,
    /// TMR replicas outvoted (masked) by their majority.
    pub tmr_outvoted: u64,
    /// Mean submit-to-wait latency of first-try completions (ms).
    pub mean_clean_ms: f64,
    /// Mean submit-to-wait latency of rescued completions (ms).
    pub mean_rescued_ms: f64,
    /// Retry latency overhead: `mean_rescued_ms - mean_clean_ms` when both
    /// populations exist, else 0.
    pub retry_overhead_ms: f64,
}

/// The whole sweep.
#[derive(Debug, Clone)]
pub struct ResilienceReport {
    pub n: u32,
    pub jobs_per_point: u32,
    pub seed: u64,
    pub points: Vec<ResiliencePoint>,
}

impl ResilienceReport {
    /// Hand-rolled JSON (shared `jsonfmt` framing; no serde offline).
    pub fn to_json(&self) -> String {
        let header = [
            format!("\"n\": {}", self.n),
            format!("\"jobs_per_point\": {}", self.jobs_per_point),
            format!("\"seed\": {}", self.seed),
        ];
        let points: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    "{{\"policy\": \"{}\", \"protection\": \"{}\", \"aging\": \"{}\", \
                     \"fault_rate\": {:.1}, \"jobs\": {}, \"completed\": {}, \
                     \"availability\": {:.4}, \"rescued\": {}, \"lost\": {}, \
                     \"corrupted\": {}, \"corrected\": {}, \"uncorrectable\": {}, \
                     \"restarts\": {}, \"replayed_cycles\": {}, \"soft_errors\": {}, \
                     \"retries\": {}, \"quarantines\": {}, \"reinstatements\": {}, \
                     \"dmr_mismatches\": {}, \"tmr_outvoted\": {}, \
                     \"mean_clean_ms\": {:.3}, \"mean_rescued_ms\": {:.3}, \
                     \"retry_overhead_ms\": {:.3}}}",
                    p.policy,
                    p.protection,
                    p.aging,
                    p.fault_rate,
                    p.jobs,
                    p.completed,
                    p.availability,
                    p.rescued,
                    p.lost,
                    p.corrupted,
                    p.corrected,
                    p.uncorrectable,
                    p.restarts,
                    p.replayed_cycles,
                    p.soft_errors,
                    p.retries,
                    p.quarantines,
                    p.reinstatements,
                    p.dmr_mismatches,
                    p.tmr_outvoted,
                    p.mean_clean_ms,
                    p.mean_rescued_ms,
                    p.retry_overhead_ms
                )
            })
            .collect();
        super::jsonfmt::frame(&header, &points)
    }

    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

fn protection_config(label: &str) -> ProtectionConfig {
    match label {
        "ecc" => ProtectionConfig::ecc(),
        "ecc+scrub" => ProtectionConfig::ecc_scrub(),
        _ => ProtectionConfig::parity(),
    }
}

fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

#[allow(clippy::too_many_arguments)]
fn sweep_point(
    policy: &'static str,
    protection: &'static str,
    aging: &'static str,
    rate: f64,
    stuck: f64,
    n: u32,
    jobs: u32,
    seed: u64,
) -> ResiliencePoint {
    let base = GpgpuConfig::new(1, 8);
    let mut sick = VariantSpec::new("sick", base);
    if rate > 0.0 {
        let plan = FaultPlan::new(0xBAD5EED ^ seed, rate)
            .with_protection(protection_config(protection))
            .with_stuck_at(if aging == "stuck-at" { stuck } else { 0.0 });
        sick = sick.with_fault(0, plan);
    }
    let mut fleet = FleetConfig::new(vec![sick]).with_policy(RecoveryPolicy::retry(3));
    if policy == "checkpoint" {
        fleet = fleet.with_checkpoint(CheckpointPolicy::at_barriers());
    }
    let svc = GpgpuService::start_fleet(fleet);

    // Serial replay: each ticket is timed submit-to-wait, so rescued jobs
    // carry their full detect + re-admit + re-execute latency.
    let mix = [BenchId::VecAdd, BenchId::Reduction, BenchId::Bitonic];
    let (mut completed, mut rescued, mut lost, mut corrupted) = (0u64, 0u64, 0u64, 0u64);
    let (mut corrected, mut uncorrectable) = (0u64, 0u64);
    let (mut restarts, mut replayed_cycles) = (0u64, 0u64);
    let (mut clean_ms, mut rescued_ms) = (Vec::new(), Vec::new());
    for k in 0..jobs {
        let id = mix[k as usize % mix.len()];
        let req = Request::Bench { id, n, seed: seed + u64::from(k) };
        let req = match policy {
            "dmr" => req.dmr(),
            "tmr" => req.tmr(),
            _ => req,
        };
        let t0 = Instant::now();
        match svc.submit(req).wait() {
            Ok(out) => {
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                completed += 1;
                if !out.verified {
                    corrupted += 1;
                }
                corrected += out.stats.fault.corrected;
                uncorrectable += out.stats.fault.uncorrectable;
                restarts += out.stats.restarts;
                replayed_cycles += out.stats.replayed_cycles;
                if out.attempts > 1 {
                    rescued += 1;
                    rescued_ms.push(ms);
                } else {
                    clean_ms.push(ms);
                }
            }
            Err(_) => lost += 1,
        }
    }

    let m = svc.metrics();
    let mean_clean_ms = mean(&clean_ms);
    let mean_rescued_ms = mean(&rescued_ms);
    let retry_overhead_ms = if clean_ms.is_empty() || rescued_ms.is_empty() {
        0.0
    } else {
        mean_rescued_ms - mean_clean_ms
    };
    ResiliencePoint {
        policy,
        protection,
        aging,
        fault_rate: rate,
        jobs,
        completed,
        availability: completed as f64 / f64::from(jobs.max(1)),
        rescued,
        lost,
        corrupted,
        corrected,
        uncorrectable,
        restarts,
        replayed_cycles,
        soft_errors: m.soft_errors,
        retries: m.jobs_retried,
        quarantines: m.quarantines,
        reinstatements: m.reinstatements,
        dmr_mismatches: m.dmr_mismatches,
        tmr_outvoted: m.tmr_outvoted,
        mean_clean_ms,
        mean_rescued_ms,
        retry_overhead_ms,
    }
}

/// Run the sweep restricted by `scope`: one clean rate-0 row per selected
/// policy, then the {protection} x {transient, stuck-at} x {policy}
/// stress grid at [`STRESS_RATE`]. The full grid is 4 + 24 = 28 points.
pub fn resilience_report_scoped(
    n: u32,
    jobs_per_point: u32,
    seed: u64,
    scope: &SweepScope,
) -> ResilienceReport {
    let jobs = jobs_per_point.max(1);
    let stuck = scope.stuck_fraction.unwrap_or(STUCK_FRACTION);
    let policies: Vec<&'static str> = POLICIES
        .into_iter()
        .filter(|p| scope.policies.is_empty() || scope.policies.iter().any(|s| s == p))
        .collect();
    let protections: Vec<&'static str> = PROTECTIONS
        .into_iter()
        .filter(|p| match scope.protection.as_deref() {
            None => true,
            Some(s) => s == *p,
        })
        .collect();
    let mut points = Vec::new();
    // Clean reference rows: the zero-cost contract of a disabled campaign.
    for &policy in &policies {
        points.push(sweep_point(policy, "parity", "transient", 0.0, 0.0, n, jobs, seed));
    }
    for &protection in &protections {
        for aging in ["transient", "stuck-at"] {
            for &policy in &policies {
                points.push(sweep_point(
                    policy,
                    protection,
                    aging,
                    STRESS_RATE,
                    stuck,
                    n,
                    jobs,
                    seed,
                ));
            }
        }
    }
    ResilienceReport { n, jobs_per_point: jobs, seed, points }
}

/// Run the full {clean} + {protection} x {aging} x {policy} grid:
/// `jobs_per_point` jobs of the benchmark mix per cell, at problem size
/// `n` (power of two, 32..=256).
pub fn resilience_report(n: u32, jobs_per_point: u32, seed: u64) -> ResilienceReport {
    resilience_report_scoped(n, jobs_per_point, seed, &SweepScope::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn find<'a>(
        r: &'a ResilienceReport,
        policy: &str,
        protection: &str,
        aging: &str,
    ) -> &'a ResiliencePoint {
        r.points
            .iter()
            .find(|p| {
                p.policy == policy
                    && p.protection == protection
                    && p.aging == aging
                    && p.fault_rate > 0.0
            })
            .expect("grid point exists")
    }

    #[test]
    fn sweep_covers_the_grid_and_never_serves_corruption() {
        let r = resilience_report(32, 3, 7);
        assert_eq!(r.points.len(), 4 + 24, "4 clean rows + 3x2x4 stress grid");
        for p in &r.points {
            let at = format!("{}/{}/{} @ rate {}", p.policy, p.protection, p.aging, p.fault_rate);
            assert_eq!(u64::from(p.jobs), p.completed + p.lost, "{at}: every ticket resolves");
            assert_eq!(p.corrupted, 0, "{at}: verification gates completion");
            let avail = p.completed as f64 / f64::from(p.jobs);
            assert!((p.availability - avail).abs() < 1e-9, "{at}");
            if p.fault_rate == 0.0 {
                // The injector's zero-cost contract: a disabled campaign
                // behaves exactly like no campaign.
                assert_eq!(p.completed, u64::from(p.jobs), "{at}");
                assert_eq!(p.soft_errors, 0, "{at}");
                assert_eq!(p.rescued, 0, "{at}");
                assert_eq!(p.corrected, 0, "{at}");
                assert_eq!(p.restarts, 0, "{at}");
                assert_eq!(p.tmr_outvoted, 0, "{at}");
            }
            if p.aging == "transient" {
                // Aged sites only come from stuck-at upsets, and only
                // aged sites defeat SECDED.
                assert_eq!(p.uncorrectable, 0, "{at}");
            }
            if p.policy != "checkpoint" {
                assert_eq!(p.restarts, 0, "{at}: replay needs the checkpoint policy");
                assert_eq!(p.replayed_cycles, 0, "{at}");
            }
            if p.protection == "parity" {
                assert_eq!(p.corrected, 0, "{at}: parity detects, never corrects");
            }
        }
        // Headline contrast (test-scale): on stuck-at hardware, parity +
        // rerun loses at least a third of the mix, while ECC + scrubbing
        // + checkpointing completes more — on the very same sick shard.
        let pr = find(&r, "rerun", "parity", "stuck-at");
        let cc = find(&r, "checkpoint", "ecc+scrub", "stuck-at");
        assert!(
            3 * pr.lost >= u64::from(pr.jobs),
            "parity+rerun must lose >= 1/3 of the mix, lost {} of {}",
            pr.lost,
            pr.jobs
        );
        assert!(
            cc.completed >= u64::from(cc.jobs) - 1,
            "ecc+scrub+checkpoint must complete nearly everything, completed {} of {}",
            cc.completed,
            cc.jobs
        );
        assert!(cc.completed > pr.completed, "the tentpole stack beats parity+rerun");
        assert!(cc.corrected > 0, "ECC corrections must actually fire under stress");

        let json = r.to_json();
        for field in [
            "\"policy\": \"checkpoint\"",
            "\"protection\": \"ecc+scrub\"",
            "\"aging\": \"stuck-at\"",
            "\"availability\"",
            "\"tmr_outvoted\"",
            "\"replayed_cycles\"",
        ] {
            assert!(json.contains(field), "{json}");
        }
    }

    #[test]
    fn scoped_sweep_restricts_the_axes() {
        let scope = SweepScope {
            protection: Some("ecc".into()),
            policies: vec!["rerun".into(), "checkpoint".into()],
            stuck_fraction: Some(1.0),
        };
        let r = resilience_report_scoped(32, 1, 7, &scope);
        // 2 clean rows + {ecc} x {transient, stuck-at} x {rerun, checkpoint}.
        assert_eq!(r.points.len(), 2 + 4);
        assert!(r.points.iter().all(|p| p.policy == "rerun" || p.policy == "checkpoint"));
        assert!(r
            .points
            .iter()
            .filter(|p| p.fault_rate > 0.0)
            .all(|p| p.protection == "ecc"));
    }
}
