//! Resilience sweep (`BENCH_resilience.json`): recovery-policy comparison
//! under seeded SEU campaigns (EXPERIMENTS.md §Resilience).
//!
//! Methodology:
//! 1. build a two-variant fleet — a "sick" shard carrying a deterministic
//!    [`FaultPlan`] campaign and an equal-power healthy peer (the QoS
//!    router spreads the bit-equal power tie round-robin, so the sick
//!    shard sees every other job until quarantine steers traffic away);
//! 2. replay a small benchmark mix serially for every point of the
//!    {fault-rate} x {no-recovery, retry, retry+quarantine, DMR} grid,
//!    timing each ticket submit-to-wait;
//! 3. report jobs rescued (completed with `attempts > 1`), jobs lost,
//!    corrupted outputs served (completed but unverified — the acceptance
//!    bar is zero under every policy), retry latency overhead (mean
//!    rescued-job latency minus mean first-try latency), and the shard
//!    health counters (soft errors, retries, quarantines, reinstatements,
//!    DMR mismatches).
//!
//! Rate 0 disables the campaign entirely (the injector's zero-cost
//! contract), giving each policy a clean reference row.

use crate::coordinator::{FleetConfig, GpgpuService, RecoveryPolicy, Request, VariantSpec};
use crate::gpgpu::GpgpuConfig;
use crate::kernels::BenchId;
use crate::sim::FaultPlan;
use std::time::Instant;

/// Upsets per million simulated cycles, swept per policy. 0 = campaign
/// disabled; 200k = mean interval 5 cycles (faults within any launch);
/// 1M = mean interval 1 cycle (saturating).
pub const FAULT_RATES: [f64; 3] = [0.0, 200_000.0, 1_000_000.0];

/// One (policy, fault-rate) cell of the sweep grid.
#[derive(Debug, Clone)]
pub struct ResiliencePoint {
    pub policy: &'static str,
    pub fault_rate: f64,
    pub jobs: u32,
    pub completed: u64,
    /// Completed jobs that needed more than one execution.
    pub rescued: u64,
    /// Tickets that resolved with an error.
    pub lost: u64,
    /// Completed jobs whose output failed golden verification — corrupted
    /// results actually served. Must stay zero under every policy.
    pub corrupted: u64,
    /// Transient fault-class failures observed fleet-wide (detected SEUs,
    /// verify rejects, DMR mismatches).
    pub soft_errors: u64,
    pub retries: u64,
    pub quarantines: u64,
    pub reinstatements: u64,
    pub dmr_mismatches: u64,
    /// Mean submit-to-wait latency of first-try completions (ms).
    pub mean_clean_ms: f64,
    /// Mean submit-to-wait latency of rescued completions (ms).
    pub mean_rescued_ms: f64,
    /// Retry latency overhead: `mean_rescued_ms - mean_clean_ms` when both
    /// populations exist, else 0.
    pub retry_overhead_ms: f64,
}

/// The whole sweep.
#[derive(Debug, Clone)]
pub struct ResilienceReport {
    pub n: u32,
    pub jobs_per_point: u32,
    pub seed: u64,
    pub points: Vec<ResiliencePoint>,
}

impl ResilienceReport {
    /// Hand-rolled JSON (shared `jsonfmt` framing; no serde offline).
    pub fn to_json(&self) -> String {
        let header = [
            format!("\"n\": {}", self.n),
            format!("\"jobs_per_point\": {}", self.jobs_per_point),
            format!("\"seed\": {}", self.seed),
        ];
        let points: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    "{{\"policy\": \"{}\", \"fault_rate\": {:.1}, \"jobs\": {}, \
                     \"completed\": {}, \"rescued\": {}, \"lost\": {}, \"corrupted\": {}, \
                     \"soft_errors\": {}, \"retries\": {}, \"quarantines\": {}, \
                     \"reinstatements\": {}, \"dmr_mismatches\": {}, \
                     \"mean_clean_ms\": {:.3}, \"mean_rescued_ms\": {:.3}, \
                     \"retry_overhead_ms\": {:.3}}}",
                    p.policy,
                    p.fault_rate,
                    p.jobs,
                    p.completed,
                    p.rescued,
                    p.lost,
                    p.corrupted,
                    p.soft_errors,
                    p.retries,
                    p.quarantines,
                    p.reinstatements,
                    p.dmr_mismatches,
                    p.mean_clean_ms,
                    p.mean_rescued_ms,
                    p.retry_overhead_ms
                )
            })
            .collect();
        super::jsonfmt::frame(&header, &points)
    }

    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// The four compared policies. DMR rides on a retry policy so a mismatch
/// (or a detected replica fault) re-routes instead of losing the job.
fn policies() -> [(&'static str, RecoveryPolicy, bool); 4] {
    [
        ("no-recovery", RecoveryPolicy::default(), false),
        ("retry", RecoveryPolicy::retry(3), false),
        ("retry-quarantine", RecoveryPolicy::retry_quarantine(3, 2), false),
        ("dmr", RecoveryPolicy::retry(3), true),
    ]
}

fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

fn sweep_point(
    policy: (&'static str, RecoveryPolicy, bool),
    rate: f64,
    n: u32,
    jobs: u32,
    seed: u64,
) -> ResiliencePoint {
    let (label, recovery, dmr) = policy;
    let base = GpgpuConfig::new(1, 8);
    let mut sick = VariantSpec::new("sick", base);
    if rate > 0.0 {
        sick = sick.with_fault(0, FaultPlan::new(0xBAD5EED ^ seed, rate));
    }
    let svc = GpgpuService::start_fleet(
        FleetConfig::new(vec![sick, VariantSpec::new("healthy", base)]).with_policy(recovery),
    );

    // Serial replay: each ticket is timed submit-to-wait, so rescued jobs
    // carry their full detect + re-route + re-execute latency.
    let mix = [BenchId::VecAdd, BenchId::Reduction, BenchId::Bitonic];
    let (mut completed, mut rescued, mut lost, mut corrupted) = (0u64, 0u64, 0u64, 0u64);
    let (mut clean_ms, mut rescued_ms) = (Vec::new(), Vec::new());
    for k in 0..jobs {
        let id = mix[k as usize % mix.len()];
        let req = Request::Bench { id, n, seed: seed + u64::from(k) };
        let req = if dmr { req.dmr() } else { req };
        let t0 = Instant::now();
        match svc.submit(req).wait() {
            Ok(out) => {
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                completed += 1;
                if !out.verified {
                    corrupted += 1;
                }
                if out.attempts > 1 {
                    rescued += 1;
                    rescued_ms.push(ms);
                } else {
                    clean_ms.push(ms);
                }
            }
            Err(_) => lost += 1,
        }
    }

    let m = svc.metrics();
    let mean_clean_ms = mean(&clean_ms);
    let mean_rescued_ms = mean(&rescued_ms);
    let retry_overhead_ms = if clean_ms.is_empty() || rescued_ms.is_empty() {
        0.0
    } else {
        mean_rescued_ms - mean_clean_ms
    };
    ResiliencePoint {
        policy: label,
        fault_rate: rate,
        jobs,
        completed,
        rescued,
        lost,
        corrupted,
        soft_errors: m.soft_errors,
        retries: m.jobs_retried,
        quarantines: m.quarantines,
        reinstatements: m.reinstatements,
        dmr_mismatches: m.dmr_mismatches,
        mean_clean_ms,
        mean_rescued_ms,
        retry_overhead_ms,
    }
}

/// Run the full {rate} x {policy} grid: `jobs_per_point` jobs of the
/// benchmark mix per cell, at problem size `n` (power of two, 32..=256).
pub fn resilience_report(n: u32, jobs_per_point: u32, seed: u64) -> ResilienceReport {
    let jobs = jobs_per_point.max(1);
    let mut points = Vec::with_capacity(FAULT_RATES.len() * policies().len());
    for rate in FAULT_RATES {
        for policy in policies() {
            points.push(sweep_point(policy, rate, n, jobs, seed));
        }
    }
    ResilienceReport { n, jobs_per_point: jobs, seed, points }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_the_grid_and_never_serves_corruption() {
        let r = resilience_report(32, 3, 7);
        assert_eq!(r.points.len(), FAULT_RATES.len() * 4);
        for p in &r.points {
            let at = format!("{} @ rate {}", p.policy, p.fault_rate);
            assert_eq!(u64::from(p.jobs), p.completed + p.lost, "{at}: every ticket resolves");
            assert_eq!(p.corrupted, 0, "{at}: verification gates completion");
            if p.fault_rate == 0.0 {
                // The injector's zero-cost contract: a disabled campaign
                // behaves exactly like no campaign.
                assert_eq!(p.completed, u64::from(p.jobs), "{at}");
                assert_eq!(p.soft_errors, 0, "{at}");
                assert_eq!(p.rescued, 0, "{at}");
                assert_eq!(p.quarantines, 0, "{at}");
            }
            if p.policy == "no-recovery" {
                assert_eq!(p.retries, 0, "{at}: max_attempts 1 never retries");
                assert_eq!(p.rescued, 0, "{at}");
            }
            if !p.policy.contains("quarantine") {
                assert_eq!(p.quarantines, 0, "{at}: policy has quarantine disabled");
            }
        }
        let json = r.to_json();
        for field in
            ["\"policy\": \"retry-quarantine\"", "\"fault_rate\": 1000000.0", "\"rescued\""]
        {
            assert!(json.contains(field), "{json}");
        }
    }
}
