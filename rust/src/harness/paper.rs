//! The paper's published numbers, embedded for side-by-side comparison in
//! every regenerated table ("paper" columns) and for the shape assertions
//! in `rust/tests/models_calibration.rs`.

use crate::kernels::BenchId;

/// Table 2: (sms, sp) -> (LUTs, FFs, BRAM, DSP48E).
pub const TABLE2: [((u32, u32), (u32, u32, u32, u32)); 6] = [
    ((1, 8), (60_375, 103_776, 124, 156)),
    ((1, 16), (113_504, 149_297, 132, 300)),
    ((1, 32), (231_436, 240_230, 156, 588)),
    ((2, 8), (135_392, 196_063, 238, 306)),
    ((2, 16), (232_064, 287_042, 262, 594)),
    ((2, 32), (413_094, 468_959, 310, 1_170)),
];

/// Table 3: speedup of 2 SM vs 1 SM at size 256, per benchmark per SP.
pub fn table3(bench: BenchId, sp: u32) -> f64 {
    let row = match bench {
        BenchId::Autocorr => [1.94, 1.94, 1.94],
        BenchId::Bitonic => [1.82, 1.83, 1.85],
        BenchId::MatMul => [1.98, 1.98, 1.98],
        BenchId::Reduction => [1.78, 1.77, 1.77],
        BenchId::Transpose => [1.98, 1.98, 1.98],
        BenchId::VecAdd | BenchId::MemStress => [f64::NAN; 3],
    };
    row[match sp {
        8 => 0,
        16 => 1,
        32 => 2,
        _ => return f64::NAN,
    }]
}

/// Table 4: (design label, dynamic W, static W).
pub const TABLE4: [(&str, f64, f64); 4] = [
    ("1 SM, 8 SP", 0.84, 3.45),
    ("1 SM, 16 SP", 1.08, 3.46),
    ("1 SM, 32 SP", 1.39, 3.46),
    ("MicroBlaze", 0.37, 3.45),
];

/// Table 5 (size 256): per benchmark — MicroBlaze (exec ms, dyn mJ) and
/// FlexGrip (exec ms, dyn mJ, energy reduction %) at 8/16/32 SP.
pub struct Table5Row {
    pub bench: BenchId,
    pub mb_ms: f64,
    pub mb_mj: f64,
    /// (exec ms, dyn mJ, reduction %) for 8, 16, 32 SP.
    pub fg: [(f64, f64, f64); 3],
}

pub const fn table5() -> [Table5Row; 5] {
    [
        Table5Row {
            bench: BenchId::Autocorr,
            mb_ms: 277.0,
            mb_mj: 102.49,
            fg: [(40.28, 33.84, 67.0), (32.20, 34.78, 66.0), (24.89, 34.60, 66.0)],
        },
        Table5Row {
            bench: BenchId::Bitonic,
            mb_ms: 118.0,
            mb_mj: 43.66,
            fg: [(9.39, 7.88, 82.0), (5.95, 6.43, 85.0), (4.64, 6.44, 85.0)],
        },
        Table5Row {
            bench: BenchId::MatMul,
            mb_ms: 186_041.0,
            mb_mj: 68_835.17,
            fg: [
                (14_098.02, 11_842.34, 82.0),
                (8_735.90, 9_434.77, 86.0),
                (6_904.07, 9_596.66, 86.0),
            ],
        },
        Table5Row {
            bench: BenchId::Reduction,
            mb_ms: 11.0,
            mb_mj: 4.07,
            fg: [(0.66, 0.55, 86.0), (0.47, 0.51, 87.0), (0.38, 0.53, 87.0)],
        },
        Table5Row {
            bench: BenchId::Transpose,
            mb_ms: 705.0,
            mb_mj: 260.85,
            fg: [(57.79, 48.54, 81.0), (38.74, 41.84, 84.0), (31.48, 43.75, 83.0)],
        },
    ]
}

/// Table 6 (1 SM, 8 SP): per configuration — (label, num operands, warp
/// depth, LUTs, FFs, BRAM, DSP, area red %, dyn red %).
pub const TABLE6: [(&str, u8, u32, u32, u32, u32, u32, f64, f64); 7] = [
    ("Baseline", 3, 32, 60_375, 103_776, 124, 156, 0.0, 0.0),
    ("Autocorr.", 3, 16, 52_121, 82_017, 124, 156, 14.0, 3.0),
    ("Mat. Mult.", 3, 0, 42_536, 60_161, 124, 156, 30.0, 9.0),
    ("Reduction", 3, 0, 42_536, 60_161, 124, 156, 30.0, 9.0),
    ("Transpose", 3, 0, 42_536, 60_161, 124, 156, 30.0, 9.0),
    ("Bitonic", 3, 2, 39_189, 57_301, 124, 156, 35.0, 15.0),
    ("Bitonic", 2, 2, 22_937, 27_136, 120, 12, 62.0, 38.0),
];

/// Fig. 4 (1 SM, size 256): speedup vs MicroBlaze per benchmark at
/// 8/16/32 SP, read off the plot (approximate — the paper publishes the
/// figure, not a table).
pub fn fig4(bench: BenchId, sp: u32) -> f64 {
    let row = match bench {
        BenchId::Autocorr => [6.9, 8.6, 11.1],
        BenchId::Bitonic => [12.6, 19.8, 25.4],
        BenchId::MatMul => [13.2, 21.3, 26.9],
        BenchId::Reduction => [16.7, 23.4, 28.9],
        BenchId::Transpose => [12.2, 18.2, 22.4],
        BenchId::VecAdd | BenchId::MemStress => [f64::NAN; 3],
    };
    row[match sp {
        8 => 0,
        16 => 1,
        32 => 2,
        _ => return f64::NAN,
    }]
}

/// Fig. 5 (2 SM, size 256) ≈ fig4 x table3.
pub fn fig5(bench: BenchId, sp: u32) -> f64 {
    fig4(bench, sp) * table3(bench, sp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_energy_is_power_times_time() {
        // The paper's own arithmetic: dyn energy = P_dyn x t.
        for row in table5() {
            assert!((row.mb_ms * 0.37 - row.mb_mj).abs() / row.mb_mj < 0.01, "{:?}", row.bench);
            for (i, p) in [0.84, 1.08, 1.39].iter().enumerate() {
                let (ms, mj, _) = row.fg[i];
                assert!((ms * p - mj).abs() / mj < 0.01, "{:?} sp idx {i}", row.bench);
            }
        }
    }

    #[test]
    fn fig5_peaks_over_40x() {
        // Paper §5.1.1: "peak speedups for the 2 SM, 32-SP implementations
        // offer over a 40x speedup for four out of the five benchmarks".
        let over40 = crate::kernels::BenchId::PAPER
            .iter()
            .filter(|b| fig5(**b, 32) > 40.0)
            .count();
        assert_eq!(over40, 4);
    }
}
