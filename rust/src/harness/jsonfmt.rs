//! Shared framing for the hand-rolled machine-readable reports
//! (`BENCH_scaling.json`, `BENCH_hot_path.json`, `BENCH_fleet.json`).
//! The offline image has no serde, so each report formats its own fields
//! — but the document shape (header fields, then a `points` array with
//! trailing-comma handling, and the multi-report array wrapper) lives
//! here once so the schemas cannot drift in framing.

/// Build `{ header_fields..., "points": [ point_lines... ] }` with the
/// stable indentation/trailing-comma conventions the cross-PR diffing
/// relies on. `header_fields` are preformatted `"key": value` strings;
/// `point_lines` are preformatted one-line JSON objects.
pub(crate) fn frame(header_fields: &[String], point_lines: &[String]) -> String {
    let mut out = String::from("{\n");
    for f in header_fields {
        out.push_str(&format!("  {f},\n"));
    }
    out.push_str("  \"points\": [\n");
    for (i, p) in point_lines.iter().enumerate() {
        let comma = if i + 1 == point_lines.len() { "" } else { "," };
        out.push_str(&format!("    {p}{comma}\n"));
    }
    out.push_str("  ]\n}\n");
    out
}

/// Wrap independently-framed JSON documents into a top-level array — the
/// multi-benchmark suite emitters (`BENCH_scaling.json` carries one
/// [`super::scaling::ScalingReport`] object per swept benchmark).
pub(crate) fn array(docs: &[String]) -> String {
    let mut out = String::from("[\n");
    for (i, d) in docs.iter().enumerate() {
        let comma = if i + 1 == docs.len() { "" } else { "," };
        out.push_str(d.trim_end());
        out.push_str(comma);
        out.push('\n');
    }
    out.push_str("]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_header_and_points_with_trailing_commas() {
        let doc = frame(
            &["\"a\": 1".into(), "\"b\": \"x\"".into()],
            &["{\"p\": 1}".into(), "{\"p\": 2}".into()],
        );
        assert_eq!(
            doc,
            "{\n  \"a\": 1,\n  \"b\": \"x\",\n  \"points\": [\n    {\"p\": 1},\n    {\"p\": 2}\n  ]\n}\n"
        );
    }

    #[test]
    fn empty_points_array_is_valid() {
        let doc = frame(&["\"a\": 1".into()], &[]);
        assert_eq!(doc, "{\n  \"a\": 1,\n  \"points\": [\n  ]\n}\n");
    }

    #[test]
    fn array_wraps_framed_documents() {
        let a = frame(&["\"x\": 1".into()], &[]);
        let b = frame(&["\"x\": 2".into()], &[]);
        let doc = array(&[a, b]);
        assert!(doc.starts_with("[\n{\n"));
        assert!(doc.contains("},\n{\n"), "{doc}");
        assert!(doc.ends_with("}\n]\n"));
        assert_eq!(array(&[]), "[\n]\n");
    }
}
