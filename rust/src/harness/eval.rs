//! Shared evaluation engine: runs (and caches) FlexGrip and MicroBlaze
//! benchmark executions so Tables 3/5 and Figures 4/5 reuse the same
//! simulations.

use crate::baseline::{self, MbStats, MbTiming};
use crate::gpgpu::{Gpgpu, GpgpuConfig};
use crate::kernels::{self, BenchId, BenchRun};
use crate::sim::NativeAlu;
use std::collections::HashMap;

/// Default seed for all reported experiments (EXPERIMENTS.md records it).
pub const EVAL_SEED: u64 = 0xF1E6;

/// Lazily-computed, cached benchmark executions at one problem size.
pub struct Evaluation {
    pub size: u32,
    pub seed: u64,
    fg: HashMap<(BenchId, u32, u32), BenchRun>,
    mb: HashMap<BenchId, MbStats>,
}

impl Evaluation {
    pub fn new(size: u32) -> Evaluation {
        Evaluation { size, seed: EVAL_SEED, fg: HashMap::new(), mb: HashMap::new() }
    }

    /// FlexGrip run (verified against the host golden) on `sms` x `sp`.
    pub fn fg(&mut self, id: BenchId, sms: u32, sp: u32) -> &BenchRun {
        let size = self.size;
        let seed = self.seed;
        self.fg.entry((id, sms, sp)).or_insert_with(|| {
            let gpgpu = Gpgpu::new(GpgpuConfig::new(sms, sp));
            let mut alu = NativeAlu;
            kernels::run_verified(id, size, &gpgpu, &mut alu, seed)
                .unwrap_or_else(|e| panic!("{} n={size} {sms}x{sp}: {e}", id.name()))
        })
    }

    /// MicroBlaze run (verified) with the calibrated timing.
    pub fn mb(&mut self, id: BenchId) -> &MbStats {
        let size = self.size;
        let seed = self.seed;
        self.mb.entry(id).or_insert_with(|| {
            baseline::run_verified(id, size, seed, MbTiming::default())
                .unwrap_or_else(|e| panic!("{} n={size} baseline: {e}", id.name()))
        })
    }

    /// Speedup of a FlexGrip config vs the MicroBlaze (same 100 MHz clock).
    pub fn speedup(&mut self, id: BenchId, sms: u32, sp: u32) -> f64 {
        let mb_cycles = self.mb(id).cycles as f64;
        let fg_cycles = self.fg(id, sms, sp).cycles as f64;
        mb_cycles / fg_cycles
    }

    /// Speedup of the 2 SM configuration over 1 SM (Table 3).
    pub fn sm_scaling(&mut self, id: BenchId, sp: u32) -> f64 {
        let one = self.fg(id, 1, sp).cycles as f64;
        let two = self.fg(id, 2, sp).cycles as f64;
        one / two
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caches_runs() {
        let mut ev = Evaluation::new(32);
        let a = ev.fg(BenchId::VecAdd, 1, 8).cycles;
        let b = ev.fg(BenchId::VecAdd, 1, 8).cycles;
        assert_eq!(a, b);
        assert_eq!(ev.fg.len(), 1);
    }

    #[test]
    fn speedup_exceeds_one_for_all_benchmarks_small() {
        let mut ev = Evaluation::new(64);
        for id in BenchId::PAPER {
            let s = ev.speedup(id, 1, 8);
            assert!(s > 1.0, "{}: {s}", id.name());
        }
    }

    #[test]
    fn two_sm_scaling_in_paper_band_small() {
        let mut ev = Evaluation::new(128);
        for id in [BenchId::MatMul, BenchId::Transpose] {
            let s = ev.sm_scaling(id, 8);
            assert!((1.5..=2.05).contains(&s), "{}: {s}", id.name());
        }
    }
}
