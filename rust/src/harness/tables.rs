//! Regeneration of every table and figure in the paper's evaluation
//! (§5). Each function returns a [`TextTable`] whose rows put our
//! measured/modelled values next to the paper's published ones.

use super::eval::Evaluation;
use super::paper;
use super::text::{f, TextTable};
use crate::gpgpu::limits;
use crate::kernels::BenchId;
use crate::model::{self, area::area, power::power, ArchParams};

const SPS: [u32; 3] = [8, 16, 32];

/// Table 1: FlexGrip physical limits (constants — regenerated from
/// `gpgpu::limits`).
pub fn table1() -> TextTable {
    let mut t = TextTable::new("Table 1: FlexGrip physical limits", &["Parameter", "Constraint"]);
    t.row(vec!["Threads Per Warp".into(), limits::THREADS_PER_WARP.to_string()]);
    t.row(vec!["Warps Per SM".into(), limits::WARPS_PER_SM.to_string()]);
    t.row(vec!["Threads Per SM".into(), limits::THREADS_PER_SM.to_string()]);
    t.row(vec!["Thread Blocks Per SM".into(), limits::BLOCKS_PER_SM.to_string()]);
    t.row(vec![
        "Total Number of 32-bit Registers per SM".into(),
        limits::REGS_PER_SM.to_string(),
    ]);
    t.row(vec![
        "Shared Memory Per SM (bytes)".into(),
        limits::SMEM_PER_SM_BYTES.to_string(),
    ]);
    t
}

/// Table 2: area of the baseline configurations — model vs paper.
pub fn table2() -> TextTable {
    let mut t = TextTable::new(
        "Table 2: area of baseline FlexGrip implementations (model | paper)",
        &["Config", "LUTs", "LUTs(p)", "FFs", "FFs(p)", "BRAM", "BRAM(p)", "DSP", "DSP(p)"],
    );
    for ((sms, sp), (luts, ffs, bram, dsp)) in paper::TABLE2 {
        let a = area(&ArchParams { num_sms: sms, num_sp: sp, ..ArchParams::baseline() });
        t.row(vec![
            format!("{sms} SM - {sp} SP"),
            a.luts.to_string(),
            luts.to_string(),
            a.ffs.to_string(),
            ffs.to_string(),
            a.bram.to_string(),
            bram.to_string(),
            a.dsp.to_string(),
            dsp.to_string(),
        ]);
    }
    t
}

/// Table 3: speedup of 2 SM over 1 SM, size 256 — measured vs paper.
pub fn table3(ev: &mut Evaluation) -> TextTable {
    let mut t = TextTable::new(
        format!("Table 3: 2 SM vs 1 SM speedup, size {} (measured | paper)", ev.size),
        &["Benchmark", "8 SP", "8(p)", "16 SP", "16(p)", "32 SP", "32(p)"],
    );
    for id in BenchId::PAPER {
        let mut row = vec![id.name().to_string()];
        for sp in SPS {
            row.push(f(ev.sm_scaling(id, sp)));
            row.push(f(paper::table3(id, sp)));
        }
        t.row(row);
    }
    t
}

/// Table 4: power estimates at 100 MHz — model vs paper.
pub fn table4() -> TextTable {
    let mut t = TextTable::new(
        "Table 4: FPGA power estimates (W) at 100 MHz (model | paper)",
        &["Design", "Dyn", "Dyn(p)", "Static", "Static(p)"],
    );
    for (label, dyn_p, stat_p) in paper::TABLE4 {
        if label == "MicroBlaze" {
            t.row(vec![
                label.into(),
                f(model::MICROBLAZE_DYNAMIC_W),
                f(dyn_p),
                f(model::MICROBLAZE_STATIC_W),
                f(stat_p),
            ]);
            continue;
        }
        let sp: u32 = label
            .split(", ")
            .nth(1)
            .and_then(|s| s.split(' ').next())
            .and_then(|s| s.parse().ok())
            .unwrap();
        let p = power(&ArchParams { num_sp: sp, ..ArchParams::baseline() });
        t.row(vec![label.into(), f(p.dynamic_w), f(dyn_p), f(p.static_w), f(stat_p)]);
    }
    t
}

/// Table 5: MicroBlaze vs FlexGrip execution time and dynamic energy,
/// size 256 — measured/modelled vs paper.
pub fn table5(ev: &mut Evaluation) -> TextTable {
    let mut t = TextTable::new(
        format!(
            "Table 5: MicroBlaze vs FlexGrip energy, size {} (measured | paper)",
            ev.size
        ),
        &[
            "Benchmark", "MB ms", "MB ms(p)", "MB mJ", "SP", "FG ms", "FG ms(p)",
            "FG mJ", "FG mJ(p)", "Red%", "Red%(p)",
        ],
    );
    let clock = crate::gpgpu::CLOCK_HZ;
    for row in paper::table5() {
        let id = row.bench;
        let mb = ev.mb(id);
        let mb_ms = mb.exec_time_ms(clock);
        let mb_mj = model::dynamic_energy_mj(model::MICROBLAZE_DYNAMIC_W, mb_ms);
        for (i, sp) in SPS.iter().enumerate() {
            let fg = ev.fg(id, 1, *sp);
            let fg_ms = fg.exec_time_ms();
            let p = power(&ArchParams { num_sp: *sp, ..ArchParams::baseline() });
            let fg_mj = model::dynamic_energy_mj(p.dynamic_w, fg_ms);
            let red = model::energy_reduction_pct(mb_mj, fg_mj);
            let (pms, pmj, pred) = row.fg[i];
            t.row(vec![
                if i == 0 { id.name().into() } else { String::new() },
                if i == 0 { f(mb_ms) } else { String::new() },
                if i == 0 { f(row.mb_ms) } else { String::new() },
                if i == 0 { f(mb_mj) } else { String::new() },
                sp.to_string(),
                f(fg_ms),
                f(pms),
                f(fg_mj),
                f(pmj),
                f(red),
                f(pred),
            ]);
        }
    }
    t
}

/// Table 6: architectural customization at 1 SM / 8 SP — profiled minimal
/// configuration per benchmark, with modelled area/energy vs paper.
pub fn table6(ev: &mut Evaluation) -> TextTable {
    let mut t = TextTable::new(
        format!(
            "Table 6: FlexGrip customization, 1 SM 8 SP, size {} (measured/model | paper)",
            ev.size
        ),
        &[
            "Config", "Ops", "Depth", "Depth(p)", "LUTs", "LUTs(p)", "DSP", "DSP(p)",
            "Area Red%", "(p)", "Dyn Red%", "(p)",
        ],
    );
    let baseline = ArchParams::baseline();
    let base_area = area(&baseline);
    let base_power = power(&baseline).dynamic_w;

    // Paper Table 6 rows: baseline + per-benchmark minimal configs.
    let paper_rows = paper::TABLE6;
    t.row(vec![
        "Baseline".into(),
        "3".into(),
        "32".into(),
        "32".into(),
        base_area.luts.to_string(),
        paper_rows[0].3.to_string(),
        base_area.dsp.to_string(),
        paper_rows[0].6.to_string(),
        "-".into(),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);

    let cases: [(BenchId, usize); 6] = [
        (BenchId::Autocorr, 1),
        (BenchId::MatMul, 2),
        (BenchId::Reduction, 3),
        (BenchId::Transpose, 4),
        (BenchId::Bitonic, 5),
        (BenchId::Bitonic, 6), // 2-operand row
    ];
    for (id, prow) in cases {
        let run_stats = ev.fg(id, 1, 8).stats.clone();
        let (_, p_ops, p_depth, p_luts, _p_ffs, _p_bram, p_dsp, p_area, p_dyn) =
            paper_rows[prow];
        let two_op = p_ops == 2;
        let keep_mul = !(two_op && run_stats.multiplier_ops() == 0);
        let params = ArchParams {
            num_sms: 1,
            num_sp: 8,
            warp_stack_depth: run_stats.max_stack_depth,
            has_multiplier: keep_mul,
            l1: None,
        };
        let a = area(&params);
        let pw = power(&params).dynamic_w;
        t.row(vec![
            format!("{}{}", id.name(), if two_op { " (2-op)" } else { "" }),
            if keep_mul { "3" } else { "2" }.into(),
            run_stats.max_stack_depth.to_string(),
            p_depth.to_string(),
            a.luts.to_string(),
            p_luts.to_string(),
            a.dsp.to_string(),
            p_dsp.to_string(),
            f(a.lut_reduction_pct(&base_area)),
            f(p_area),
            f(100.0 * (1.0 - pw / base_power)),
            f(p_dyn),
        ]);
    }
    t
}

/// Fig. 4: speedup vs MicroBlaze, 1 SM, varying SPs — measured vs paper.
pub fn fig4(ev: &mut Evaluation) -> TextTable {
    fig_speedup(ev, 1, "Fig. 4", paper::fig4)
}

/// Fig. 5: speedup vs MicroBlaze, 2 SM, varying SPs — measured vs paper.
pub fn fig5(ev: &mut Evaluation) -> TextTable {
    fig_speedup(ev, 2, "Fig. 5", paper::fig5)
}

fn fig_speedup(
    ev: &mut Evaluation,
    sms: u32,
    label: &str,
    paper_fn: fn(BenchId, u32) -> f64,
) -> TextTable {
    let mut t = TextTable::new(
        format!(
            "{label}: speedup vs MicroBlaze, {sms} SM, size {} (measured | paper)",
            ev.size
        ),
        &["Benchmark", "8 SP", "8(p)", "16 SP", "16(p)", "32 SP", "32(p)"],
    );
    let mut avg = [0.0f64; 3];
    for id in BenchId::PAPER {
        let mut row = vec![id.name().to_string()];
        for (i, sp) in SPS.iter().enumerate() {
            let s = ev.speedup(id, sms, *sp);
            avg[i] += s / BenchId::PAPER.len() as f64;
            row.push(f(s));
            row.push(f(paper_fn(id, *sp)));
        }
        t.row(row);
    }
    let mut row = vec!["average".to_string()];
    for (i, sp) in SPS.iter().enumerate() {
        row.push(f(avg[i]));
        let pavg: f64 = BenchId::PAPER.iter().map(|b| paper_fn(*b, *sp)).sum::<f64>()
            / BenchId::PAPER.len() as f64;
        row.push(f(pavg));
    }
    t.row(row);
    t
}

/// §5.1.1 input-size scaling sweep (1 SM, 8 SP): speedup vs MicroBlaze
/// across the paper's four input sizes.
pub fn sweep(seed_sizes: &[u32]) -> TextTable {
    let mut t = TextTable::new(
        "Input-size scaling (1 SM, 8 SP): speedup vs MicroBlaze",
        &["Benchmark", "n=32", "n=64", "n=128", "n=256"],
    );
    let mut evs: Vec<Evaluation> = seed_sizes.iter().map(|&n| Evaluation::new(n)).collect();
    for id in BenchId::PAPER {
        let mut row = vec![id.name().to_string()];
        for ev in evs.iter_mut() {
            row.push(f(ev.speedup(id, 1, 8)));
        }
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_constants() {
        let t = table1();
        let s = t.render();
        for v in ["32", "24", "768", "8", "8192", "16384"] {
            assert!(s.contains(v), "missing {v}");
        }
    }

    #[test]
    fn table2_and_4_render() {
        assert_eq!(table2().rows.len(), 6);
        assert_eq!(table4().rows.len(), 4);
    }

    #[test]
    fn fig4_small_size_renders_with_paper_columns() {
        let mut ev = Evaluation::new(32);
        let t = fig4(&mut ev);
        assert_eq!(t.rows.len(), 6); // 5 benchmarks + average
        assert!(t.render().contains("average"));
    }

    #[test]
    fn table6_profiles_depths() {
        let mut ev = Evaluation::new(64);
        let t = table6(&mut ev);
        let s = t.render();
        // measured depths: autocorr 16, matmul/reduction/transpose 0, bitonic 2
        assert!(s.contains("autocorr"));
        assert!(s.contains("bitonic (2-op)"));
        assert_eq!(t.rows.len(), 7);
    }
}
