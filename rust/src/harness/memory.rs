//! Memory-hierarchy sweep report (`BENCH_memory.json`): the paper's
//! benchmarks re-run under the modeled per-SM L1/BRAM cache at several
//! geometries, against the flat-memory baseline.
//!
//! For every benchmark x geometry point the sweep records the L1 hit
//! rate, fill-stall and interconnect-contention cycles, total simulated
//! cycles, and the modeled dynamic energy (`P_dyn x t`, §5.1.2 — the
//! cache's additive power term against the cycles it saves). The cache is
//! tags-only (values are bit-identical to flat memory by construction),
//! and the sweep *asserts* that: every cached run's full memory image
//! must equal the flat run's before the point is recorded.

use crate::gpgpu::{Gpgpu, GpgpuConfig};
use crate::kernels::{self, BenchId, RunOptions, Workload};
use crate::model::{dynamic_energy_mj, power::power, ArchParams};
use crate::sim::{CacheGeometry, GlobalMem, MemoryConfig};

/// Swept cache geometries (`WAYSxSETSxLINE_BYTES`), small to large:
/// 1 KiB, 8 KiB, 64 KiB per SM.
pub const SWEEP_GEOMETRIES: [&str; 3] = ["2x16x32", "4x64x32", "4x256x64"];

/// One benchmark x memory-configuration measurement.
#[derive(Debug, Clone)]
pub struct MemoryPoint {
    /// Benchmark label (`memstress_s32` is the strided variant).
    pub bench: String,
    /// Memory label: `flat` or `l1 WxSxL`.
    pub cache: String,
    pub hits: u64,
    pub misses: u64,
    pub hit_rate: f64,
    pub evictions: u64,
    pub mshr_merges: u64,
    pub fill_stall_cycles: u64,
    pub contention_cycles: u64,
    pub cycles: u64,
    pub exec_ms: f64,
    /// Modeled dynamic power of the device with this memory config (W).
    pub dyn_w: f64,
    /// Modeled dynamic energy of the run (mJ).
    pub energy_mj: f64,
}

/// The full sweep at one problem size.
#[derive(Debug, Clone)]
pub struct MemoryReport {
    pub n: u32,
    pub seed: u64,
    pub num_sms: u32,
    pub points: Vec<MemoryPoint>,
}

impl MemoryReport {
    /// Hand-rolled JSON (shared `jsonfmt` framing; no serde offline).
    pub fn to_json(&self) -> String {
        let header = [
            format!("\"n\": {}", self.n),
            format!("\"seed\": {}", self.seed),
            format!("\"num_sms\": {}", self.num_sms),
        ];
        let points: Vec<String> = self
            .points
            .iter()
            .map(|p| {
                format!(
                    "{{\"bench\": \"{}\", \"cache\": \"{}\", \"hits\": {}, \
                     \"misses\": {}, \"hit_rate\": {:.4}, \"evictions\": {}, \
                     \"mshr_merges\": {}, \"fill_stall_cycles\": {}, \
                     \"contention_cycles\": {}, \"cycles\": {}, \
                     \"exec_ms\": {:.3}, \"dyn_w\": {:.4}, \"energy_mj\": {:.4}}}",
                    p.bench,
                    p.cache,
                    p.hits,
                    p.misses,
                    p.hit_rate,
                    p.evictions,
                    p.mshr_merges,
                    p.fill_stall_cycles,
                    p.contention_cycles,
                    p.cycles,
                    p.exec_ms,
                    p.dyn_w,
                    p.energy_mj
                )
            })
            .collect();
        super::jsonfmt::frame(&header, &points)
    }

    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Point lookup by (bench, cache) labels.
    pub fn point(&self, bench: &str, cache: &str) -> Option<&MemoryPoint> {
        self.points.iter().find(|p| p.bench == bench && p.cache == cache)
    }
}

/// Full memory image of a device (for the bit-identity assertion).
fn image(gmem: &GlobalMem) -> Vec<i32> {
    gmem.read_words(0, gmem.size_bytes() as usize / 4).expect("whole image reads")
}

/// Run `w` under `memory` on a fresh `num_sms`-SM device, verify it, and
/// record one point. `flat_image` is the reference memory image the run
/// must reproduce exactly (None when this *is* the flat run).
fn measure(
    bench: &str,
    w: &Workload,
    num_sms: u32,
    memory: MemoryConfig,
    flat_image: Option<&[i32]>,
) -> (MemoryPoint, Vec<i32>) {
    let cfg = GpgpuConfig::new(num_sms, 8).with_memory(memory);
    let gpgpu = Gpgpu::new(cfg);
    let mut gmem = w.make_gmem();
    let run = w
        .run(&gpgpu, &mut gmem, RunOptions::default())
        .unwrap_or_else(|e| panic!("{bench} under {}: {e}", memory.label()));
    w.verify(&gmem)
        .unwrap_or_else(|e| panic!("{bench} under {}: {e}", memory.label()));
    let img = image(&gmem);
    if let Some(want) = flat_image {
        assert!(
            img == want,
            "{bench} under {}: cached memory image diverged from flat",
            memory.label()
        );
    }
    let m = run.stats.mem;
    let dyn_w = power(&ArchParams::from_config(&cfg)).dynamic_w;
    let exec_ms = run.exec_time_ms();
    let point = MemoryPoint {
        bench: bench.to_string(),
        cache: memory.label(),
        hits: m.hits,
        misses: m.misses,
        hit_rate: m.hit_rate(),
        evictions: m.evictions,
        mshr_merges: m.mshr_merges,
        fill_stall_cycles: m.fill_stall_cycles,
        contention_cycles: m.contention_cycles,
        cycles: run.cycles,
        exec_ms,
        dyn_w,
        energy_mj: dynamic_energy_mj(dyn_w, exec_ms),
    };
    (point, img)
}

/// Sweep the five paper benchmarks plus two memstress stride variants
/// over flat memory and [`SWEEP_GEOMETRIES`] on a 2-SM device. Every
/// cached run is verified against the golden reference *and* asserted
/// bit-identical to the flat run's memory image.
pub fn memory_report(n: u32, seed: u64) -> MemoryReport {
    let num_sms = 2;
    let mut workloads: Vec<(String, Workload)> = BenchId::PAPER
        .iter()
        .map(|id| (id.name().to_string(), kernels::prepare(*id, n, seed)))
        .collect();
    // Stride 1 streams adjacent lines (reuse); stride 32 (128 bytes)
    // touches a fresh line per trip on every swept line size.
    workloads.push(("memstress".into(), kernels::prepare_memstress(n, seed, 1)));
    workloads.push(("memstress_s32".into(), kernels::prepare_memstress(n, seed, 32)));

    let mut points = Vec::new();
    for (bench, w) in &workloads {
        let (flat_point, flat_img) = measure(bench, w, num_sms, MemoryConfig::flat(), None);
        points.push(flat_point);
        for geom in SWEEP_GEOMETRIES {
            let memory =
                MemoryConfig::with_l1(CacheGeometry::parse(geom).expect("swept geometry"));
            let (p, _) = measure(bench, w, num_sms, memory, Some(&flat_img));
            points.push(p);
        }
    }
    MemoryReport { n, seed, num_sms, points }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_every_bench_and_geometry() {
        let r = memory_report(32, 7);
        // 5 paper benchmarks + 2 memstress variants, flat + 3 geometries.
        assert_eq!(r.points.len(), 7 * (1 + SWEEP_GEOMETRIES.len()));
        for p in &r.points {
            assert!(p.cycles > 0 && p.energy_mj > 0.0, "{} {}", p.bench, p.cache);
            if p.cache == "flat" {
                assert_eq!(p.hits + p.misses, 0, "flat memory has no L1 to hit");
            } else {
                assert!(p.hits + p.misses > 0, "{} {}", p.bench, p.cache);
            }
        }
        let json = r.to_json();
        for field in ["\"hit_rate\"", "\"fill_stall_cycles\"", "\"energy_mj\""] {
            assert!(json.contains(field), "{json}");
        }
    }

    #[test]
    fn streaming_stride_hits_more_than_line_skipping_stride() {
        let r = memory_report(64, 3);
        for geom in SWEEP_GEOMETRIES {
            let cache = format!("l1 {geom}");
            let stream = r.point("memstress", &cache).unwrap();
            let skip = r.point("memstress_s32", &cache).unwrap();
            assert!(
                stream.hit_rate > skip.hit_rate,
                "{cache}: stream {:.2} <= skip {:.2}",
                stream.hit_rate,
                skip.hit_rate
            );
        }
    }

    #[test]
    fn cache_power_grows_with_geometry_and_flat_is_cheapest() {
        let r = memory_report(32, 1);
        let flat = r.point("matmul", "flat").unwrap();
        let small = r.point("matmul", "l1 2x16x32").unwrap();
        let large = r.point("matmul", "l1 4x256x64").unwrap();
        assert!(flat.dyn_w < small.dyn_w && small.dyn_w < large.dyn_w);
    }
}
