//! Evaluation harness: regenerates every table and figure in the paper's
//! §5 (see DESIGN.md §Per-experiment index) and provides the
//! criterion-style micro-benchmark helper used by `cargo bench`
//! (criterion itself is not available in this offline image).

pub mod eval;
pub mod fleet;
pub mod hotpath;
mod jsonfmt;
pub mod memory;
pub mod microbench;
pub mod paper;
pub mod qos;
pub mod resilience;
pub mod scaling;
pub mod tables;
pub mod text;

pub use eval::Evaluation;
pub use fleet::{fleet_report, fleet_report_with_memory, FleetBenchPoint, FleetReport};
pub use hotpath::{HotPathPoint, HotPathReport};
pub use memory::{memory_report, MemoryPoint, MemoryReport};
pub use microbench::{bench, BenchResult};
pub use qos::{qos_report, QosPoint, QosReport};
pub use resilience::{
    resilience_report, resilience_report_scoped, ResiliencePoint, ResilienceReport, SweepScope,
};
pub use scaling::{
    scaling_report, scaling_suite, suite_json, write_suite_json, ScalingPoint, ScalingReport,
};
pub use text::TextTable;
