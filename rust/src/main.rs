//! `flexgrip` — the leader binary: CLI over the soft-GPGPU coordinator.
//!
//! Subcommands (clap is unavailable offline; parsing is hand-rolled):
//!   run        run one benchmark on a chosen configuration
//!   report     regenerate the paper's tables and figures
//!   customize  profile a benchmark and print its minimal configuration
//!   limits     print the Table-1 physical limits
//!   asm        assemble a .flex file and dump the binary layout

use flexgrip::coordinator::{
    self, FleetConfig, GpgpuService, QosClass, RecoveryPolicy, Request, VariantSpec,
};
use flexgrip::gpgpu::GpgpuConfig;
use flexgrip::harness::{tables, Evaluation};
use flexgrip::kernels::{self, BenchId, RunOptions};
use flexgrip::model::{area::area, power::power, ArchParams};
use flexgrip::runtime::{Artifacts, XlaAlu};
use flexgrip::sim::{CacheGeometry, CheckpointPolicy, FaultPlan, MemoryConfig, ProtectionConfig};
use std::collections::HashMap;
use std::process::ExitCode;

fn usage() -> ! {
    eprintln!(
        "usage:\n  \
         flexgrip run --bench <name> [--n 256] [--sms 1] [--sp 8] [--seed N] [--backend native|xla] [--parallel] [--cache WxSxL] [--watchdog CYCLES] [--fault-rate R] [--fault-seed N] [--protect MODE] [--stuck-at FRAC] [--checkpoint] [--tmr]\n  \
         flexgrip report [--all] [--table 1..6] [--fig 4|5] [--sweep] [--size 256]\n  \
         flexgrip customize --bench <name> [--n 64]\n  \
         flexgrip limits\n  \
         flexgrip asm --file <kernel.flex>\n  \
         flexgrip service-demo [--shards 2] [--jobs 8] [--n 64] [--sms 1] [--cache WxSxL] [--watchdog CYCLES] [--fault-rate R] [--fault-seed N] [--protect MODE] [--stuck-at FRAC] [--checkpoint] [--tmr] [--retries K] [--qos CLASS]\n  \
         flexgrip fleet-demo [--n 64] [--jobs 4] [--seed N] [--cache WxSxL] [--out BENCH_fleet.json]\n  \
         flexgrip resilience [--n 32] [--jobs 6] [--seed N] [--protect MODE] [--stuck-at FRAC] [--checkpoint] [--tmr] [--out BENCH_resilience.json]\n  \
         flexgrip qos [--n 32] [--jobs 12] [--seed N] [--out BENCH_qos.json]\n\n\
         benchmarks: autocorr bitonic matmul reduction transpose vecadd memstress\n\
         --cache takes an L1 geometry WAYSxSETSxLINE_BYTES, e.g. 4x64x32\n\
         --fault-rate is expected SEU upsets per million simulated cycles (seeded, deterministic)\n\
         --protect picks the BRAM protection: parity|ecc|ecc+scrub, or per-class rf|smem|l1|instr=MODE pairs\n\
         --stuck-at ages that fraction of upsets into stuck-at BRAM cells; --checkpoint arms barrier checkpoint/restart\n\
         --tmr runs triple-modular redundancy (majority vote over three replicas)\n\
         --qos tags submitted jobs with a latency class: latency|throughput|besteffort"
    );
    std::process::exit(2);
}

/// Parse the optional `--cache WxSxL` flag into a memory configuration
/// (flat when absent; exits with the valid-geometry message on a bad
/// value).
fn memory_flag(flags: &HashMap<String, String>) -> MemoryConfig {
    match flags.get("cache") {
        None => MemoryConfig::flat(),
        Some(s) => match CacheGeometry::parse(s) {
            Ok(geom) => MemoryConfig::with_l1(geom),
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        },
    }
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let val = args.get(i + 1).filter(|v| !v.starts_with("--"));
            match val {
                Some(v) => {
                    out.insert(key.to_string(), v.clone());
                    i += 2;
                }
                None => {
                    out.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            }
        } else {
            eprintln!("unexpected argument `{a}`");
            usage();
        }
    }
    out
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    match flags.get(key) {
        Some(v) => v.parse().unwrap_or_else(|_| {
            eprintln!("bad value for --{key}: {v}");
            std::process::exit(2);
        }),
        None => default,
    }
}

fn get_opt<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str) -> Option<T> {
    flags.get(key).map(|v| {
        v.parse().unwrap_or_else(|_| {
            eprintln!("bad value for --{key}: {v}");
            std::process::exit(2);
        })
    })
}

/// Apply the optional per-request SEU campaign, cycle budget and
/// checkpoint policy to a launch's options.
fn decorate<'a>(
    mut opts: RunOptions<'a>,
    fault: Option<&'a FaultPlan>,
    watchdog: Option<u64>,
    checkpoint: Option<CheckpointPolicy>,
) -> RunOptions<'a> {
    if let Some(plan) = fault {
        opts = opts.fault(plan);
    }
    if let Some(cycles) = watchdog {
        opts = opts.watchdog(cycles);
    }
    if let Some(policy) = checkpoint {
        opts = opts.checkpoint(policy);
    }
    opts
}

/// Assemble the optional SEU campaign from `--fault-rate`, `--fault-seed`,
/// `--protect` and `--stuck-at` (exits with a parse message on a bad
/// protection spec).
fn fault_flag(flags: &HashMap<String, String>) -> Option<FaultPlan> {
    get_opt::<f64>(flags, "fault-rate").map(|rate| {
        let mut plan = FaultPlan::new(get(flags, "fault-seed", 1), rate);
        if let Some(spec) = flags.get("protect") {
            match ProtectionConfig::parse(spec) {
                Ok(protect) => plan = plan.with_protection(protect),
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            }
        }
        if let Some(fraction) = get_opt::<f64>(flags, "stuck-at") {
            plan = plan.with_stuck_at(fraction);
        }
        plan
    })
}

/// `--checkpoint` arms the barrier checkpoint/restart policy.
fn checkpoint_flag(flags: &HashMap<String, String>) -> Option<CheckpointPolicy> {
    flags.contains_key("checkpoint").then(CheckpointPolicy::at_barriers)
}

/// Parse the optional `--qos CLASS` flag (jobs stay untagged when
/// absent).
fn qos_flag(flags: &HashMap<String, String>) -> Option<QosClass> {
    flags.get("qos").map(|v| match v.as_str() {
        "latency" => QosClass::Latency,
        "throughput" => QosClass::Throughput,
        "besteffort" => QosClass::BestEffort,
        other => {
            eprintln!("unknown QoS class `{other}` (latency|throughput|besteffort)");
            std::process::exit(2);
        }
    })
}

fn bench_id(flags: &HashMap<String, String>) -> BenchId {
    let name = flags.get("bench").unwrap_or_else(|| usage());
    BenchId::from_name(name).unwrap_or_else(|| {
        eprintln!("unknown benchmark `{name}`");
        usage();
    })
}

fn cmd_run(flags: HashMap<String, String>) -> ExitCode {
    let id = bench_id(&flags);
    let n: u32 = get(&flags, "n", 256);
    let sms: u32 = get(&flags, "sms", 1);
    let sp: u32 = get(&flags, "sp", 8);
    let seed: u64 = get(&flags, "seed", flexgrip::harness::eval::EVAL_SEED);
    let backend = flags.get("backend").map(String::as_str).unwrap_or("native");

    let parallel = flags.contains_key("parallel");
    if parallel && backend != "native" {
        eprintln!("--parallel requires --backend native (no {backend} ALU factory exists)");
        return ExitCode::FAILURE;
    }

    let watchdog: Option<u64> = get_opt(&flags, "watchdog");
    let fault = fault_flag(&flags);
    let checkpoint = checkpoint_flag(&flags);

    let cfg = GpgpuConfig::new(sms, sp).with_memory(memory_flag(&flags));
    if flags.contains_key("tmr") {
        if backend != "native" {
            eprintln!("--tmr requires --backend native (replicas run in-process)");
            return ExitCode::FAILURE;
        }
        return run_tmr(id, n, seed, cfg, parallel, fault, watchdog, checkpoint);
    }
    let gpgpu = flexgrip::gpgpu::Gpgpu::new(cfg);
    let w = kernels::prepare(id, n, seed);
    let mut gmem = w.make_gmem();
    let run = match backend {
        "native" if parallel => w.run(
            &gpgpu,
            &mut gmem,
            decorate(RunOptions::new().parallel(), fault.as_ref(), watchdog, checkpoint),
        ),
        "native" => w.run(
            &gpgpu,
            &mut gmem,
            decorate(RunOptions::default(), fault.as_ref(), watchdog, checkpoint),
        ),
        "xla" => {
            let arts = match Artifacts::open_default() {
                Ok(a) => std::sync::Arc::new(a),
                Err(e) => {
                    eprintln!("xla backend unavailable: {e}");
                    return ExitCode::FAILURE;
                }
            };
            let mut alu = match XlaAlu::new(arts) {
                Ok(a) => a,
                Err(e) => {
                    eprintln!("xla backend unavailable: {e}");
                    return ExitCode::FAILURE;
                }
            };
            w.run(
                &gpgpu,
                &mut gmem,
                decorate(
                    RunOptions::new().sequential(&mut alu),
                    fault.as_ref(),
                    watchdog,
                    checkpoint,
                ),
            )
        }
        other => {
            eprintln!("unknown backend `{other}`");
            usage();
        }
    };
    let run = match run {
        Ok(r) => r,
        Err(e) => {
            eprintln!("launch failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    match w.verify(&gmem) {
        Ok(()) => println!("verification: OK (host golden reference)"),
        Err(e) => {
            eprintln!("verification FAILED: {e}");
            return ExitCode::FAILURE;
        }
    }
    let s = &run.stats;
    println!(
        "{} n={n} on {} [{backend}]: {} cycles = {:.3} ms @100MHz",
        id.name(),
        cfg.label(),
        run.cycles,
        run.exec_time_ms()
    );
    println!(
        "  warp instrs {}  thread instrs {}  divergences {}  max stack {}  blocks {}",
        s.instructions, s.thread_instructions, s.divergences, s.max_stack_depth, s.blocks
    );
    println!(
        "  global txns {}/{}  shared txns {}/{}  barriers {}",
        s.global_load_txns, s.global_store_txns, s.shared_load_txns, s.shared_store_txns,
        s.barriers
    );
    if cfg.memory.l1.is_some() {
        let m = &s.mem;
        println!(
            "  l1: {} hits / {} misses ({:.1}% hit rate)  {} evictions  \
             {} mshr merges  {} fill-stall + {} contention cycles",
            m.hits,
            m.misses,
            100.0 * m.hit_rate(),
            m.evictions,
            m.mshr_merges,
            m.fill_stall_cycles,
            m.contention_cycles
        );
    }
    let p = power(&ArchParams::from_config(&cfg));
    println!(
        "  model: {:.2} W dynamic -> {:.2} mJ dynamic energy",
        p.dynamic_w,
        p.dynamic_w * run.exec_time_ms()
    );
    ExitCode::SUCCESS
}

/// `run --tmr`: launch three in-process replicas of the benchmark with
/// decorrelated fault seeds and majority-vote on (cycles, verified
/// output). One corrupted or failed replica is masked; a three-way
/// disagreement prints an inconclusive verdict and fails the run.
#[allow(clippy::too_many_arguments)]
fn run_tmr(
    id: BenchId,
    n: u32,
    seed: u64,
    cfg: GpgpuConfig,
    parallel: bool,
    fault: Option<FaultPlan>,
    watchdog: Option<u64>,
    checkpoint: Option<CheckpointPolicy>,
) -> ExitCode {
    let gpgpu = flexgrip::gpgpu::Gpgpu::new(cfg);
    let mut votes: Vec<(u64, bool)> = Vec::with_capacity(3);
    for r in 0..3u64 {
        let plan = fault.map(|p| FaultPlan { seed: p.seed.wrapping_add(r), ..p });
        let w = kernels::prepare(id, n, seed);
        let mut gmem = w.make_gmem();
        let base = if parallel { RunOptions::new().parallel() } else { RunOptions::default() };
        match w.run(&gpgpu, &mut gmem, decorate(base, plan.as_ref(), watchdog, checkpoint)) {
            Ok(run) => {
                let verified = w.verify(&gmem).is_ok();
                println!("replica {r}: {} cycles, verified={verified}", run.cycles);
                votes.push((run.cycles, verified));
            }
            Err(e) => {
                eprintln!("replica {r} failed: {e}");
                votes.push((0, false));
            }
        }
    }
    let winner = votes.iter().copied().find(|&(cycles, verified)| {
        verified && votes.iter().filter(|&&v| v == (cycles, verified)).count() >= 2
    });
    match winner {
        Some((cycles, _)) => {
            println!(
                "TMR vote: majority agreed on {cycles} cycles (verified against the host golden \
                 reference)"
            );
            ExitCode::SUCCESS
        }
        None => {
            eprintln!("TMR inconclusive: no verified majority across the three replicas");
            ExitCode::FAILURE
        }
    }
}

fn cmd_report(flags: HashMap<String, String>) -> ExitCode {
    let size: u32 = get(&flags, "size", 256);
    let all = flags.contains_key("all") || flags.len() <= 1;
    let mut ev = Evaluation::new(size);

    let want_table = |n: u32| all || flags.get("table").is_some_and(|v| v.parse() == Ok(n));
    let want_fig = |n: u32| all || flags.get("fig").is_some_and(|v| v.parse() == Ok(n));

    if want_table(1) {
        println!("{}", tables::table1().render());
    }
    if want_table(2) {
        println!("{}", tables::table2().render());
    }
    if want_table(3) {
        println!("{}", tables::table3(&mut ev).render());
    }
    if want_table(4) {
        println!("{}", tables::table4().render());
    }
    if want_table(5) {
        println!("{}", tables::table5(&mut ev).render());
    }
    if want_table(6) {
        println!("{}", tables::table6(&mut ev).render());
    }
    if want_fig(4) {
        println!("{}", tables::fig4(&mut ev).render());
    }
    if want_fig(5) {
        println!("{}", tables::fig5(&mut ev).render());
    }
    if all || flags.contains_key("sweep") {
        println!("{}", tables::sweep(&kernels::PAPER_SIZES).render());
    }
    ExitCode::SUCCESS
}

fn cmd_customize(flags: HashMap<String, String>) -> ExitCode {
    let id = bench_id(&flags);
    let n: u32 = get(&flags, "n", 64);
    let seed: u64 = get(&flags, "seed", flexgrip::harness::eval::EVAL_SEED);
    let r = match coordinator::profile(id, n, seed) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("profiling failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    println!("customization report: {} (n={n})", id.name());
    println!(
        "  static signature: multiplier={} third-operand={} branches={} stack {:?} ({} instrs)",
        r.sig.uses_multiplier,
        r.sig.uses_third_operand,
        r.sig.uses_branches,
        r.sig.stack_bound,
        r.instruction_count
    );
    println!(
        "  profiled: warp-stack high-water {}  dynamic mul/mad ops {}",
        r.measured_stack_depth, r.multiplier_ops
    );
    println!("  recommended: {}", r.recommended.label());
    let a = area(&r.recommended);
    println!(
        "  model: {} LUTs / {} DSP ({:.0}% LUT reduction), {:.0}% dynamic power reduction",
        a.luts, a.dsp, r.lut_reduction_pct, r.dynamic_power_reduction_pct
    );
    match coordinator::customize::validate(&r, seed) {
        Ok(()) => println!("  validation: benchmark verified on the customized configuration"),
        Err(e) => {
            eprintln!("  validation FAILED: {e}");
            return ExitCode::FAILURE;
        }
    }
    ExitCode::SUCCESS
}

fn cmd_asm(flags: HashMap<String, String>) -> ExitCode {
    let path = flags.get("file").unwrap_or_else(|| usage());
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{path}: {e}");
            return ExitCode::FAILURE;
        }
    };
    match flexgrip::asm::assemble(&src) {
        Ok(k) => {
            println!(
                ".entry {}  ({} bytes, {} instructions, {} regs/thread, {} smem bytes)",
                k.name,
                k.code.len(),
                k.instrs.len(),
                k.regs_per_thread,
                k.smem_bytes
            );
            println!("{}", flexgrip::isa::disassemble_listing(&k.instrs));
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("assembly error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Coordinator pool smoke: submit a batch of mixed benchmark jobs across
/// N device shards and print per-shard + aggregate metrics. `--fault-rate`
/// injects a seeded SEU campaign on shard 0 (pair with `--retries` to
/// watch the recovery plane rescue the jobs, `--protect`/`--stuck-at` to
/// shape the campaign, `--checkpoint` to arm barrier checkpoint/restart,
/// or `--tmr` to triple every job and majority-vote); `--watchdog` caps
/// every job's cycle budget; `--qos` tags every job with a latency class.
fn cmd_service_demo(flags: HashMap<String, String>) -> ExitCode {
    let shards: u32 = get(&flags, "shards", 2);
    let jobs: u32 = get(&flags, "jobs", 8);
    let n: u32 = get(&flags, "n", 64);
    let sms: u32 = get(&flags, "sms", 1);
    let retries: u32 = get(&flags, "retries", 1);
    let qos = qos_flag(&flags);
    let mut spec =
        VariantSpec::new("pool", GpgpuConfig::new(sms, 8).with_memory(memory_flag(&flags)))
            .with_shards(shards.max(1));
    if let Some(plan) = fault_flag(&flags) {
        spec = spec.with_fault(0, plan);
    }
    let mut fleet = FleetConfig::new(vec![spec]).with_depth(16);
    if retries > 1 {
        fleet = fleet.with_policy(RecoveryPolicy::retry(retries));
    }
    if let Some(cycles) = get_opt(&flags, "watchdog") {
        fleet = fleet.with_watchdog(cycles);
    }
    if let Some(policy) = checkpoint_flag(&flags) {
        fleet = fleet.with_checkpoint(policy);
    }
    let tmr = flags.contains_key("tmr");
    let svc = GpgpuService::start_fleet(fleet);
    let mix = [
        BenchId::VecAdd,
        BenchId::Reduction,
        BenchId::Bitonic,
        BenchId::Transpose,
        BenchId::Autocorr,
    ];
    let tickets: Vec<_> = (0..jobs)
        .map(|i| {
            let mut req = Request::Bench { id: mix[i as usize % mix.len()], n, seed: i as u64 + 1 };
            if tmr {
                req = req.tmr();
            }
            svc.submit(match qos {
                Some(class) => req.qos(class),
                None => req,
            })
        })
        .collect();
    for t in tickets {
        match t.wait() {
            Ok(o) => println!(
                "shard {}: {} -> {} cycles, verified={}",
                o.shard, o.label, o.cycles, o.verified
            ),
            Err(e) => eprintln!("job failed: {e}"),
        }
    }
    for (i, m) in svc.shard_metrics().iter().enumerate() {
        println!(
            "shard {i}: {} ok / {} failed, {} cycles",
            m.jobs_completed, m.jobs_failed, m.total_cycles
        );
    }
    let m = svc.metrics();
    println!(
        "aggregate: {} ok / {} failed, {} cycles, {} instructions",
        m.jobs_completed, m.jobs_failed, m.total_cycles, m.total_instructions
    );
    if m.tmr_outvoted > 0 || m.dmr_mismatches > 0 {
        println!(
            "redundancy: {} TMR replica(s) outvoted, {} DMR mismatch(es)",
            m.tmr_outvoted, m.dmr_mismatches
        );
    }
    let rs = svc.routing_stats();
    for (v, (label, live, slots)) in rs.variants.iter().zip(svc.variant_shards()) {
        println!(
            "routing[{label}]: {} routed, {} spilled, {} tie-broken, {} shed  \
             ({live}/{slots} shards live)",
            v.routed, v.spilled, v.tie_broken, v.shed
        );
    }
    println!("scale events: {} up / {} down", rs.scale_ups, rs.scale_downs);
    for class in QosClass::ALL {
        let q = rs.class(class);
        if q.jobs > 0 {
            println!(
                "queue wait [{:<10}]: p50 {} ns, p95 {} ns over {} jobs",
                class.name(),
                q.p50_ns,
                q.p95_ns,
                q.jobs
            );
        }
    }
    ExitCode::SUCCESS
}

/// Fleet replay: profile the five paper benchmarks, build the
/// heterogeneous variant fleet, route a job mix through it, and read the
/// modeled dynamic-energy saving against the baseline-only pool
/// (EXPERIMENTS.md §Fleet; `BENCH_fleet.json` when --out is given).
fn cmd_fleet_demo(flags: HashMap<String, String>) -> ExitCode {
    let n: u32 = get(&flags, "n", 64);
    let jobs: u32 = get(&flags, "jobs", 4);
    let seed: u64 = get(&flags, "seed", flexgrip::harness::eval::EVAL_SEED);
    let memory = memory_flag(&flags);
    let r = match flexgrip::harness::fleet_report_with_memory(n, jobs, seed, memory) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fleet replay failed: {e}");
            return ExitCode::FAILURE;
        }
    };
    println!(
        "fleet replay: {} jobs/bench at n={n} (seed {seed}, memory {})",
        r.jobs_per_bench, r.memory
    );
    for p in &r.points {
        println!(
            "  {:<10} -> {:<28} {:.4} W  {:>10} cycles  {:>8.3} ms  \
             {:.2} mJ vs {:.2} mJ  ({:.1}% dyn. energy red.)",
            p.bench,
            p.variant,
            p.variant_dyn_w,
            p.cycles,
            p.exec_ms,
            p.fleet_mj,
            p.baseline_mj,
            p.reduction_pct
        );
    }
    println!(
        "  fleet-wide: {:.2} mJ vs {:.2} mJ baseline -> {:.1}% dynamic-energy \
         reduction (paper Table 6 mix ~14%), {} mis-admissions",
        r.fleet_mj, r.baseline_mj, r.reduction_pct, r.misadmissions
    );
    if let Some(path) = flags.get("out") {
        if let Err(e) = r.write_json(path) {
            eprintln!("writing {path}: {e}");
            return ExitCode::FAILURE;
        }
        println!("  wrote {path}");
    }
    if r.misadmissions > 0 {
        eprintln!("{} job(s) failed on their routed variant", r.misadmissions);
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}

/// Resilience sweep: replay a job mix through every recovery policy ×
/// BRAM protection mode × fault-aging profile and print the
/// availability table (EXPERIMENTS.md §Resilience;
/// `BENCH_resilience.json` when --out is given). `--protect` pins the
/// protection axis to one mode; `--checkpoint`/`--tmr` restrict the
/// policy axis; `--stuck-at` overrides the aged-upset fraction.
fn cmd_resilience(flags: HashMap<String, String>) -> ExitCode {
    let n: u32 = get(&flags, "n", 32);
    let jobs: u32 = get(&flags, "jobs", 6);
    let seed: u64 = get(&flags, "seed", flexgrip::harness::eval::EVAL_SEED);
    let mut scope = flexgrip::harness::SweepScope::default();
    if let Some(mode) = flags.get("protect") {
        if !flexgrip::harness::resilience::PROTECTIONS.contains(&mode.as_str()) {
            eprintln!("unknown protection mode `{mode}` (parity|ecc|ecc+scrub)");
            std::process::exit(2);
        }
        scope.protection = Some(mode.clone());
    }
    scope.stuck_fraction = get_opt(&flags, "stuck-at");
    if flags.contains_key("checkpoint") {
        scope.policies.push("checkpoint".to_string());
    }
    if flags.contains_key("tmr") {
        scope.policies.push("tmr".to_string());
    }
    let r = flexgrip::harness::resilience_report_scoped(n, jobs, seed, &scope);
    println!("resilience sweep: {} jobs/point at n={n} (seed {seed})", r.jobs_per_point);
    for p in &r.points {
        println!(
            "  {:<10} {:<9} {:<9} rate {:>7.0}  {}/{} completed ({} rescued, {} lost, \
             {} corrupted)  {} corrected, {} uncorrectable, {} restarts  \
             {} soft errors, {} retries  (+{:.1} ms retry overhead)",
            p.policy,
            p.protection,
            p.aging,
            p.fault_rate,
            p.completed,
            p.jobs,
            p.rescued,
            p.lost,
            p.corrupted,
            p.corrected,
            p.uncorrectable,
            p.restarts,
            p.soft_errors,
            p.retries,
            p.retry_overhead_ms
        );
    }
    if let Some(path) = flags.get("out") {
        if let Err(e) = r.write_json(path) {
            eprintln!("writing {path}: {e}");
            return ExitCode::FAILURE;
        }
        println!("  wrote {path}");
    }
    let corrupted: u64 = r.points.iter().map(|p| p.corrupted).sum();
    if corrupted > 0 {
        eprintln!("{corrupted} corrupted output(s) served — the verification gate is broken");
        return ExitCode::FAILURE;
    }
    ExitCode::SUCCESS
}

/// QoS routing sweep: the dynamic admission router and the elastic
/// rebalancer measured against the static baseline (EXPERIMENTS.md
/// §QoS; `BENCH_qos.json` when --out is given). The harness itself
/// asserts the sick-fleet acceptance gate (static mode sheds, QoS mode
/// completes ≥ 95% of the same mix).
fn cmd_qos(flags: HashMap<String, String>) -> ExitCode {
    let n: u32 = get(&flags, "n", 32);
    let jobs: u32 = get(&flags, "jobs", 12);
    let seed: u64 = get(&flags, "seed", flexgrip::harness::eval::EVAL_SEED);
    let r = flexgrip::harness::qos_report(n, jobs, seed);
    println!("qos sweep: {} jobs/point at n={n} (seed {seed})", r.jobs_per_point);
    for p in &r.points {
        println!(
            "  {:<11} [{:<6}] mix {:<10} {:>2}/{} completed, {} shed (spill rate {:.2})  \
             {} spilled, {} tie-broken, {}+/{}- scale  p95 wait {} ns",
            p.scenario,
            p.mode,
            p.mix,
            p.completed,
            p.jobs,
            p.shed,
            p.spill_rate,
            p.spilled,
            p.tie_broken,
            p.scale_ups,
            p.scale_downs,
            p.p95_wait_ns
        );
    }
    if let Some(path) = flags.get("out") {
        if let Err(e) = r.write_json(path) {
            eprintln!("writing {path}: {e}");
            return ExitCode::FAILURE;
        }
        println!("  wrote {path}");
    }
    ExitCode::SUCCESS
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => usage(),
    };
    match cmd {
        "run" => cmd_run(parse_flags(&rest)),
        "report" => cmd_report(parse_flags(&rest)),
        "customize" => cmd_customize(parse_flags(&rest)),
        "limits" => {
            println!("{}", tables::table1().render());
            ExitCode::SUCCESS
        }
        "asm" => cmd_asm(parse_flags(&rest)),
        "service-demo" => cmd_service_demo(parse_flags(&rest)),
        "fleet-demo" => cmd_fleet_demo(parse_flags(&rest)),
        "resilience" => cmd_resilience(parse_flags(&rest)),
        "qos" => cmd_qos(parse_flags(&rest)),
        _ => usage(),
    }
}
