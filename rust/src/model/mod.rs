//! FPGA implementation models: area (LUT/FF/BRAM/DSP), dynamic/static
//! power, and energy — the quantities Xilinx ISE and XPower produced for
//! the paper (Tables 2, 4, 5, 6). Component-based, calibrated to the
//! paper's published points; every calibration point is asserted in
//! `rust/tests/models_calibration.rs`.

pub mod area;
pub mod energy;
pub mod power;

pub use area::{Area, MICROBLAZE_LUTS};
pub use energy::{dynamic_energy_mj, energy_reduction_pct};
pub use power::{PowerEstimate, MICROBLAZE_DYNAMIC_W, MICROBLAZE_STATIC_W};

use crate::gpgpu::GpgpuConfig;
use crate::sim::CacheGeometry;

/// The architectural parameters the implementation models depend on —
/// the paper's customization axes (§4, §5.2) plus the optional per-SM
/// L1/BRAM cache (not in the paper's tables; modelled as a strictly
/// additive term so all published calibration points are unchanged when
/// `l1` is `None`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArchParams {
    pub num_sms: u32,
    pub num_sp: u32,
    /// Warp-stack depth 0..=32 (Table 6).
    pub warp_stack_depth: u32,
    /// Multiplier + third read-operand unit present (§4.2).
    pub has_multiplier: bool,
    /// Per-SM L1/BRAM cache geometry, if the device models one.
    pub l1: Option<CacheGeometry>,
}

impl ArchParams {
    /// The paper's baseline FlexGrip (Table 2 row 1).
    pub fn baseline() -> ArchParams {
        ArchParams {
            num_sms: 1,
            num_sp: 8,
            warp_stack_depth: 32,
            has_multiplier: true,
            l1: None,
        }
    }

    pub fn from_config(cfg: &GpgpuConfig) -> ArchParams {
        ArchParams {
            num_sms: cfg.num_sms,
            num_sp: cfg.sm.num_sp,
            warp_stack_depth: cfg.sm.warp_stack_depth,
            has_multiplier: cfg.sm.has_multiplier,
            l1: cfg.memory.l1.map(|c| c.geom),
        }
    }

    pub fn label(&self) -> String {
        let mut s = format!("{} SM - {} SP", self.num_sms, self.num_sp);
        if self.warp_stack_depth != 32 {
            s += &format!(", stack {}", self.warp_stack_depth);
        }
        if !self.has_multiplier {
            s += ", no mul";
        }
        if let Some(geom) = self.l1 {
            s += &format!(", l1 {}", geom.label());
        }
        s
    }
}
