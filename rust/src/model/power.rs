//! Power model (Xilinx XPower methodology, 100 MHz).
//!
//! Calibration (paper Table 4):
//!
//! | design       | dynamic (W) | static (W) |
//! |--------------|-------------|------------|
//! | 1 SM, 8 SP   | 0.84        | 3.45       |
//! | 1 SM, 16 SP  | 1.08        | 3.46       |
//! | 1 SM, 32 SP  | 1.39        | 3.46       |
//! | MicroBlaze   | 0.37        | 3.45       |
//!
//! Customization effects come from Table 6's "% Dyn. Red." column for the
//! 1 SM / 8 SP system: removing the full 32-entry warp stack saves ~9% of
//! baseline dynamic power; removing the multiplier + third read operand
//! saves a further ~23 percentage points (the paper's §5.2 text), scaled
//! per SP.

use super::ArchParams;

/// Paper Table 4, MicroBlaze row.
pub const MICROBLAZE_DYNAMIC_W: f64 = 0.37;
pub const MICROBLAZE_STATIC_W: f64 = 3.45;

/// Dynamic-power calibration points for one SM (full stack, multiplier).
const SM1_DYN: [(u32, f64); 3] = [(8, 0.84), (16, 1.08), (32, 1.39)];
/// Top-level (block scheduler + AXI + clocking) share of the 1-SM number;
/// the remainder replicates per SM.
const TOP_LEVEL_W: f64 = 0.20;

/// Warp-stack dynamic power at full depth, as a fraction of the 1 SM/8 SP
/// baseline (Table 6 depth-0 rows: 9% reduction).
const STACK_FULL_FRACTION: f64 = 0.09;
/// Multiplier + third-operand dynamic power, fraction of baseline per
/// 8 SP (Table 6 / §5.2: 38% − 15% = 23 points at 8 SP).
const MUL_FRACTION_8SP: f64 = 0.23;
const BASE_8SP_W: f64 = 0.84;
/// L1 cache dynamic power per SM: controller fixed cost + per-BRAM toggle
/// cost (additive; not a paper calibration point — zero when no cache).
const CACHE_CTRL_W: f64 = 0.01;
const CACHE_W_PER_BRAM: f64 = 0.005;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerEstimate {
    pub dynamic_w: f64,
    pub static_w: f64,
}

impl PowerEstimate {
    pub fn total_w(&self) -> f64 {
        self.dynamic_w + self.static_w
    }
}

fn sm_dyn_baseline(sp: u32) -> f64 {
    // Exact at the calibration points, linear between/beyond.
    let pts = SM1_DYN;
    let x = sp as f64;
    let seg = if x <= pts[1].0 as f64 { (pts[0], pts[1]) } else { (pts[1], pts[2]) };
    let ((x0, y0), (x1, y1)) = ((seg.0 .0 as f64, seg.0 .1), (seg.1 .0 as f64, seg.1 .1));
    y0 + (y1 - y0) * (x - x0) / (x1 - x0)
}

/// Dynamic + static power estimate for a FlexGrip configuration.
pub fn power(p: &ArchParams) -> PowerEstimate {
    let per_sm_full = sm_dyn_baseline(p.num_sp) - TOP_LEVEL_W;

    // Customization deltas, per SM.
    let stack_w = STACK_FULL_FRACTION * BASE_8SP_W * (p.warp_stack_depth as f64 / 32.0)
        - STACK_FULL_FRACTION * BASE_8SP_W; // relative to full depth
    let mul_w = if p.has_multiplier {
        0.0
    } else {
        -MUL_FRACTION_8SP * BASE_8SP_W * (p.num_sp as f64 / 8.0)
    };
    // Strictly additive cache term: all Table 4/6 points hold at `None`.
    let cache_w = p
        .l1
        .map(|g| CACHE_CTRL_W + CACHE_W_PER_BRAM * g.brams() as f64)
        .unwrap_or(0.0);

    let dynamic_w =
        TOP_LEVEL_W + p.num_sms as f64 * (per_sm_full + stack_w + mul_w + cache_w);
    // Static power is a device property, essentially flat (Table 4).
    let static_w = if p.num_sp >= 16 || p.num_sms >= 2 { 3.46 } else { 3.45 };
    PowerEstimate { dynamic_w: dynamic_w.max(0.05), static_w }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(sp: u32) -> ArchParams {
        ArchParams {
            num_sms: 1,
            num_sp: sp,
            warp_stack_depth: 32,
            has_multiplier: true,
            l1: None,
        }
    }

    #[test]
    fn table4_exact_at_calibration_points() {
        for (sp, want) in SM1_DYN {
            let got = power(&base(sp)).dynamic_w;
            assert!((got - want).abs() < 1e-9, "{sp} SP: {got} != {want}");
        }
        assert_eq!(power(&base(8)).static_w, 3.45);
        assert_eq!(power(&base(16)).static_w, 3.46);
    }

    #[test]
    fn table6_stack_reductions_in_band() {
        // depth 16 -> paper 3%; depth 0 -> paper 9%.
        let b = power(&base(8)).dynamic_w;
        let mut p = base(8);
        p.warp_stack_depth = 16;
        let red16 = 100.0 * (1.0 - power(&p).dynamic_w / b);
        assert!((2.0..6.0).contains(&red16), "depth 16: {red16:.1}%");
        p.warp_stack_depth = 0;
        let red0 = 100.0 * (1.0 - power(&p).dynamic_w / b);
        assert!((red0 - 9.0).abs() < 0.5, "depth 0: {red0:.1}%");
    }

    #[test]
    fn table6_no_multiplier_reduction() {
        // Bitonic 2-op row: 38% total vs baseline (stack 2 + no mul).
        let b = power(&base(8)).dynamic_w;
        let p = ArchParams {
            num_sms: 1,
            num_sp: 8,
            warp_stack_depth: 2,
            has_multiplier: false,
            l1: None,
        };
        let red = 100.0 * (1.0 - power(&p).dynamic_w / b);
        assert!((28.0..42.0).contains(&red), "no-mul total reduction {red:.1}%");
    }

    #[test]
    fn two_sm_power_exceeds_one_sm() {
        let one = power(&base(8)).dynamic_w;
        let two = power(&ArchParams { num_sms: 2, ..base(8) }).dynamic_w;
        assert!(two > 1.4 * one && two < 2.0 * one, "2 SM = {two:.2} W");
    }

    #[test]
    fn power_monotonic_in_sp() {
        assert!(power(&base(16)).dynamic_w > power(&base(8)).dynamic_w);
        assert!(power(&base(32)).dynamic_w > power(&base(16)).dynamic_w);
    }

    #[test]
    fn microblaze_constants_match_table4() {
        assert_eq!(MICROBLAZE_DYNAMIC_W, 0.37);
        assert_eq!(MICROBLAZE_STATIC_W, 3.45);
    }

    #[test]
    fn l1_cache_adds_modest_dynamic_power() {
        use crate::sim::CacheGeometry;
        let flat = power(&base(8)).dynamic_w;
        let mut p = base(8);
        p.l1 = Some(CacheGeometry::parse("4x64x32").unwrap());
        let cached = power(&p).dynamic_w;
        assert!(cached > flat, "cache must cost something");
        assert!(cached - flat < 0.1, "but well under a baseline SP array");
    }
}
