//! FPGA area model (Virtex-6 VLX240T, Xilinx ISE 14.2 synthesis).
//!
//! Calibration (paper Table 2, baseline depth-32 MAD-capable designs):
//!
//! | config      | LUTs    | FFs     | BRAM | DSP48E |
//! |-------------|---------|---------|------|--------|
//! | 1 SM - 8 SP | 60,375  | 103,776 | 124  | 156    |
//! | 1 SM - 16 SP| 113,504 | 149,297 | 132  | 300    |
//! | 1 SM - 32 SP| 231,436 | 240,230 | 156  | 588    |
//! | 2 SM - 8 SP | 135,392 | 196,063 | 238  | 306    |
//! | 2 SM - 16 SP| 232,064 | 287,042 | 262  | 594    |
//! | 2 SM - 32 SP| 413,094 | 468,959 | 310  | 1,170  |
//!
//! DSP48Es follow `n_sm * (12 + 18*sp) - 6*(n_sm - 1)` *exactly* (the 12
//! is the paper's "12 DSP blocks ... used for address calculation in the
//! FlexGrip control circuitry"). LUT/FF/BRAM use the calibration table at
//! the published points and interpolate elsewhere.
//!
//! Customization deltas come from Table 6 (1 SM, 8 SP):
//! * warp stack: linear, (60,375 - 42,536)/32 ≈ 557 LUTs and
//!   (103,776 - 60,161)/32 ≈ 1,363 FFs per stack entry per SM;
//! * multiplier + third read operand (bitonic 3-op → 2-op rows):
//!   −16,252 LUTs, −30,165 FFs, −4 BRAM, −18·SP DSPs at 8 SP, scaled
//!   per SP.
//!
//! Known paper inconsistency, reproduced as-is: Table 6 lists bitonic at
//! depth 2 with *fewer* LUTs (39,189) than the depth-0 rows (42,536). A
//! monotonic component model cannot hit both; we stay linear in depth and
//! accept ~11% error on that one row (asserted in the calibration tests).

use super::ArchParams;

/// Paper §5.1: MicroBlaze baseline footprint.
pub const MICROBLAZE_LUTS: u32 = 3252;

/// FPGA resource usage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Area {
    pub luts: u32,
    pub ffs: u32,
    pub bram: u32,
    pub dsp: u32,
}

impl Area {
    /// LUT reduction vs. another (baseline) area, in percent.
    pub fn lut_reduction_pct(&self, baseline: &Area) -> f64 {
        100.0 * (1.0 - self.luts as f64 / baseline.luts as f64)
    }
}

/// Table 2 calibration rows: (sp, luts, ffs, bram) for one SM including
/// its share of the top-level control.
const SM1: [(u32, u32, u32, u32); 3] =
    [(8, 60_375, 103_776, 124), (16, 113_504, 149_297, 132), (32, 231_436, 240_230, 156)];
/// Two-SM totals at the same SP counts.
const SM2: [(u32, u32, u32, u32); 3] =
    [(8, 135_392, 196_063, 238), (16, 232_064, 287_042, 262), (32, 413_094, 468_959, 310)];

/// Per-stack-entry LUT/FF cost per SM (Table 6 derivation).
const LUT_PER_STACK_ENTRY: f64 = (60_375.0 - 42_536.0) / 32.0;
const FF_PER_STACK_ENTRY: f64 = (103_776.0 - 60_161.0) / 32.0;
/// Multiplier + third-operand removal at 8 SP (Table 6 bitonic rows),
/// scaled per SP.
const LUT_PER_MUL_SP: f64 = (39_189.0 - 22_937.0) / 8.0;
const FF_PER_MUL_SP: f64 = (57_301.0 - 27_136.0) / 8.0;
const BRAM_MUL_REMOVAL: u32 = 4;
/// L1 cache controller fixed cost per SM and per-tag-entry compare/mux
/// cost (additive; not a paper calibration point).
const CACHE_CTRL_LUTS: f64 = 150.0;
const CACHE_CTRL_FFS: f64 = 120.0;
const LUT_PER_TAG_ENTRY: f64 = 2.0;
const FF_PER_TAG_ENTRY: f64 = 1.0;

fn interp(
    table: &[(u32, u32, u32, u32); 3],
    sp: u32,
    field: fn(&(u32, u32, u32, u32)) -> u32,
) -> f64 {
    // Exact at table points, linear between / beyond.
    let pts: Vec<(f64, f64)> =
        table.iter().map(|row| (row.0 as f64, field(row) as f64)).collect();
    let x = sp as f64;
    if x <= pts[1].0 {
        let (x0, y0) = pts[0];
        let (x1, y1) = pts[1];
        y0 + (y1 - y0) * (x - x0) / (x1 - x0)
    } else {
        let (x0, y0) = pts[1];
        let (x1, y1) = pts[2];
        y0 + (y1 - y0) * (x - x0) / (x1 - x0)
    }
}

/// Estimate the FPGA area of a FlexGrip configuration.
pub fn area(p: &ArchParams) -> Area {
    assert!(matches!(p.num_sp, 8 | 16 | 32), "calibrated for 8/16/32 SP");
    // Baseline (depth 32, with multiplier) at the requested SM/SP point.
    let (mut luts, mut ffs, mut bram) = match p.num_sms {
        1 => (
            interp(&SM1, p.num_sp, |r| r.1),
            interp(&SM1, p.num_sp, |r| r.2),
            interp(&SM1, p.num_sp, |r| r.3),
        ),
        2 => (
            interp(&SM2, p.num_sp, |r| r.1),
            interp(&SM2, p.num_sp, |r| r.2),
            interp(&SM2, p.num_sp, |r| r.3),
        ),
        n => {
            // Beyond the paper's evaluation: replicate the marginal cost of
            // the second SM.
            let one = (
                interp(&SM1, p.num_sp, |r| r.1),
                interp(&SM1, p.num_sp, |r| r.2),
                interp(&SM1, p.num_sp, |r| r.3),
            );
            let two = (
                interp(&SM2, p.num_sp, |r| r.1),
                interp(&SM2, p.num_sp, |r| r.2),
                interp(&SM2, p.num_sp, |r| r.3),
            );
            let k = (n - 2) as f64;
            (
                two.0 + k * (two.0 - one.0),
                two.1 + k * (two.1 - one.1),
                two.2 + k * (two.2 - one.2),
            )
        }
    };

    // Customizations scale per SM.
    let sms = p.num_sms as f64;
    let removed_entries = (32 - p.warp_stack_depth) as f64;
    luts -= sms * removed_entries * LUT_PER_STACK_ENTRY;
    ffs -= sms * removed_entries * FF_PER_STACK_ENTRY;
    if !p.has_multiplier {
        luts -= sms * p.num_sp as f64 * LUT_PER_MUL_SP;
        ffs -= sms * p.num_sp as f64 * FF_PER_MUL_SP;
        bram -= sms * BRAM_MUL_REMOVAL as f64;
    }
    // Optional per-SM L1/BRAM cache (not in the paper's tables): strictly
    // additive, so every published calibration point above is untouched
    // when `l1` is `None`. Tag compare + hit mux scale with the tag array
    // (ways * sets entries); line storage maps to BRAM.
    if let Some(geom) = p.l1 {
        let tag_entries = (geom.ways * geom.sets) as f64;
        luts += sms * (CACHE_CTRL_LUTS + LUT_PER_TAG_ENTRY * tag_entries);
        ffs += sms * (CACHE_CTRL_FFS + FF_PER_TAG_ENTRY * tag_entries);
        bram += sms * geom.brams() as f64;
    }

    // DSP48E closed form (exact on all Table 2 points + Table 6 rows).
    let dsp_per_sm = 12 + if p.has_multiplier { 18 * p.num_sp } else { 0 };
    let dsp = p.num_sms * dsp_per_sm - 6 * (p.num_sms - 1);

    Area { luts: luts.round() as u32, ffs: ffs.round() as u32, bram: bram.round() as u32, dsp }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(sms: u32, sp: u32) -> ArchParams {
        ArchParams {
            num_sms: sms,
            num_sp: sp,
            warp_stack_depth: 32,
            has_multiplier: true,
            l1: None,
        }
    }

    #[test]
    fn table2_exact_at_calibration_points() {
        for (rows, sms) in [(SM1, 1u32), (SM2, 2u32)] {
            for (sp, luts, ffs, bram) in rows {
                let a = area(&params(sms, sp));
                assert_eq!(a.luts, luts, "{sms} SM {sp} SP LUTs");
                assert_eq!(a.ffs, ffs, "{sms} SM {sp} SP FFs");
                assert_eq!(a.bram, bram, "{sms} SM {sp} SP BRAM");
            }
        }
    }

    #[test]
    fn dsp_closed_form_matches_table2() {
        for (sms, sp, want) in [
            (1u32, 8u32, 156u32), (1, 16, 300), (1, 32, 588),
            (2, 8, 306), (2, 16, 594), (2, 32, 1170),
        ] {
            assert_eq!(area(&params(sms, sp)).dsp, want, "{sms} SM {sp} SP");
        }
    }

    #[test]
    fn table6_stack_rows_within_tolerance() {
        // (depth, paper LUTs, paper FFs, tolerance %)
        for (depth, luts, ffs, tol) in [
            (16u32, 52_121u32, 82_017u32, 2.0),
            (0, 42_536, 60_161, 0.5),
            (2, 39_189, 57_301, 12.0), // the paper's non-monotonic row
        ] {
            let mut p = params(1, 8);
            p.warp_stack_depth = depth;
            let a = area(&p);
            let lut_err = 100.0 * (a.luts as f64 - luts as f64).abs() / luts as f64;
            let ff_err = 100.0 * (a.ffs as f64 - ffs as f64).abs() / ffs as f64;
            assert!(lut_err <= tol, "depth {depth}: LUT err {lut_err:.1}% > {tol}%");
            assert!(ff_err <= tol + 5.0, "depth {depth}: FF err {ff_err:.1}%");
        }
    }

    #[test]
    fn table6_no_multiplier_row() {
        // Bitonic 2-operand row: 22,937 LUTs / 27,136 FFs / 120 BRAM / 12 DSP.
        let p = ArchParams {
            num_sms: 1,
            num_sp: 8,
            warp_stack_depth: 2,
            has_multiplier: false,
            l1: None,
        };
        let a = area(&p);
        assert_eq!(a.dsp, 12, "only the address-calculation DSPs remain");
        assert_eq!(a.bram, 120);
        // The absolute LUT count inherits the paper's non-monotonic
        // depth-2 anomaly (see module docs); the *multiplier-removal
        // delta* itself is exact (16,252 LUTs), so the row lands within
        // ~20% while every monotonic row is within 2%.
        let err = 100.0 * (a.luts as f64 - 22_937.0).abs() / 22_937.0;
        assert!(err < 20.0, "no-mul LUT err {err:.1}%");
        let delta = area(&ArchParams { has_multiplier: true, ..p }).luts - a.luts;
        assert_eq!(delta, 39_189 - 22_937, "mul-removal delta is exact");
    }

    #[test]
    fn area_monotonic_in_every_axis() {
        let base = area(&params(1, 8));
        assert!(area(&params(1, 16)).luts > base.luts);
        assert!(area(&params(2, 8)).luts > base.luts);
        let mut shallow = params(1, 8);
        shallow.warp_stack_depth = 4;
        assert!(area(&shallow).luts < base.luts);
        let mut nomul = shallow;
        nomul.has_multiplier = false;
        assert!(area(&nomul).luts < area(&shallow).luts);
    }

    #[test]
    fn lut_reduction_pct_sanity() {
        // Paper conclusion: customization reduces LUT area by 33% on
        // average, up to 62% (bitonic no-mul).
        let base = area(&params(1, 8));
        let nomul = area(&ArchParams {
            num_sms: 1,
            num_sp: 8,
            warp_stack_depth: 2,
            has_multiplier: false,
            l1: None,
        });
        let red = nomul.lut_reduction_pct(&base);
        assert!((50.0..70.0).contains(&red), "bitonic-style reduction {red:.0}%");
    }

    #[test]
    fn extrapolates_beyond_two_sms() {
        let a2 = area(&params(2, 8));
        let a4 = area(&params(4, 8));
        assert!(a4.luts > a2.luts);
        assert_eq!(a4.dsp, 4 * 156 - 18);
    }

    #[test]
    fn l1_cache_is_a_strictly_additive_per_sm_term() {
        use crate::sim::CacheGeometry;
        let geom = CacheGeometry::parse("4x64x32").unwrap();
        for sms in [1u32, 2] {
            let flat = area(&params(sms, 8));
            let mut p = params(sms, 8);
            p.l1 = Some(geom);
            let cached = area(&p);
            assert!(cached.luts > flat.luts && cached.ffs > flat.ffs);
            assert_eq!(cached.dsp, flat.dsp, "cache uses no DSPs");
            assert_eq!(
                cached.bram - flat.bram,
                sms * geom.brams(),
                "line storage is BRAM, one array per SM"
            );
        }
    }
}
