//! Energy model — the paper's §5.1.2 methodology verbatim: "Since static
//! power is largely a function of the device size, we evaluate the dynamic
//! energy consumption ... determined by multiplying dynamic power by
//! application execution time." Table 5's numbers check out exactly under
//! this formula (e.g. autocorr 8 SP: 40.28 ms x 0.84 W = 33.84 mJ).

/// Dynamic energy in millijoules: `P_dyn [W] x t [ms]`.
pub fn dynamic_energy_mj(dynamic_w: f64, exec_time_ms: f64) -> f64 {
    dynamic_w * exec_time_ms
}

/// Percentage energy reduction of `ours` vs a `baseline` (Table 5's
/// "Ene. Red." column).
pub fn energy_reduction_pct(baseline_mj: f64, ours_mj: f64) -> f64 {
    100.0 * (1.0 - ours_mj / baseline_mj)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table5_rows_check_out() {
        // Verify the paper's own arithmetic (MicroBlaze dyn = 0.37 W,
        // FlexGrip 8 SP dyn = 0.84 W).
        // Autocorr: MB 277 ms -> 102.49 mJ; FG 40.28 ms -> 33.84 mJ, 67%.
        let mb = dynamic_energy_mj(0.37, 277.0);
        assert!((mb - 102.49).abs() < 0.01);
        let fg = dynamic_energy_mj(0.84, 40.28);
        assert!((fg - 33.84).abs() < 0.01);
        let red = energy_reduction_pct(mb, fg);
        assert!((red - 67.0).abs() < 0.5);
    }

    #[test]
    fn reduction_of_equal_is_zero() {
        assert_eq!(energy_reduction_pct(10.0, 10.0), 0.0);
    }

    #[test]
    fn bitonic_row_checks_out() {
        // Bitonic: MB 118 ms -> 43.66 mJ; FG 16 SP 5.95 ms x 1.08 = 6.43, 85%.
        let mb = dynamic_energy_mj(0.37, 118.0);
        assert!((mb - 43.66).abs() < 0.01);
        let fg = dynamic_energy_mj(1.08, 5.95);
        assert!((fg - 6.43).abs() < 0.01);
        assert!((energy_reduction_pct(mb, fg) - 85.0).abs() < 0.5);
    }
}
