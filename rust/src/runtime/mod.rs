//! PJRT runtime: load the AOT-compiled HLO artifacts (produced once by
//! `make artifacts` from the JAX/Pallas layers) and execute them from the
//! Rust request path.
//!
//! * [`Artifacts`] — artifact store rooted at a directory of `*.hlo.txt`
//!   files, fronting one PJRT CPU client;
//! * [`XlaAlu`] / [`XlaBatchAlu`] — the L1 Pallas warp-ALU kernel as an
//!   [`AluBackend`] (select with `flexgrip run --backend xla`);
//! * [`golden`] — XLA-executed benchmark golden models for end-to-end
//!   output cross-checking.
//!
//! # Offline build
//!
//! The PJRT bindings (the `xla` crate) are **not vendored in this image**,
//! so this build ships the API surface with a stub executor: artifact
//! discovery, error reporting, and every type the CLI/benches/tests link
//! against work, but executing an artifact returns
//! [`RuntimeError::Unavailable`]. Restoring the real path is a matter of
//! vendoring the `xla` crate and swapping the bodies of
//! [`Artifacts::run_i32`], [`XlaAlu`], and [`XlaBatchAlu::execute_batch`]
//! back in (see git history of this file for the PJRT implementation),
//! plus flipping [`PJRT_AVAILABLE`]. Callers must treat any
//! `RuntimeError` as "skip the XLA path" — `rust/tests/xla_runtime.rs`
//! and `benches/hot_path.rs` do exactly that, keeping CI hermetic.

pub mod golden;

use crate::sim::{AluBackend, WarpAluIn, WarpAluOut};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Is the PJRT executor compiled into this build?
pub const PJRT_AVAILABLE: bool = false;

/// Runtime faults: artifact IO, missing PJRT support, execution errors.
#[derive(Debug)]
pub enum RuntimeError {
    MissingArtifact { path: PathBuf },
    /// The PJRT executor is not compiled into this build.
    Unavailable { reason: &'static str },
    Io(std::io::Error),
    /// Executable returned a shape we did not expect.
    BadOutput { artifact: String, detail: String },
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::MissingArtifact { path } => write!(
                f,
                "missing AOT artifact {} — run `make artifacts` first",
                path.display()
            ),
            RuntimeError::Unavailable { reason } => write!(
                f,
                "PJRT executor unavailable in this build: {reason}"
            ),
            RuntimeError::Io(e) => write!(f, "io: {e}"),
            RuntimeError::BadOutput { artifact, detail } => {
                write!(f, "artifact {artifact} returned unexpected output: {detail}")
            }
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

/// Default artifact directory (relative to the repo root / CWD), or
/// `$FLEXGRIP_ARTIFACTS`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("FLEXGRIP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// An artifact store rooted at a directory of `name.hlo.txt` files.
pub struct Artifacts {
    dir: PathBuf,
}

impl Artifacts {
    pub fn open(dir: impl AsRef<Path>) -> Result<Artifacts, RuntimeError> {
        Ok(Artifacts { dir: dir.as_ref().to_path_buf() })
    }

    pub fn open_default() -> Result<Artifacts, RuntimeError> {
        Artifacts::open(default_artifact_dir())
    }

    /// PJRT platform name, or a marker when the executor is stubbed out.
    pub fn platform(&self) -> String {
        "unavailable (PJRT not compiled in)".to_string()
    }

    /// Can artifacts actually be executed in this build?
    pub fn available(&self) -> bool {
        PJRT_AVAILABLE
    }

    /// Resolve and validate the on-disk path of a named artifact.
    pub fn artifact_path(&self, name: &str) -> Result<PathBuf, RuntimeError> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(RuntimeError::MissingArtifact { path });
        }
        Ok(path)
    }

    /// Execute an artifact on int32 inputs; returns the flattened int32
    /// output. Stubbed: artifact discovery works, execution reports
    /// [`RuntimeError::Unavailable`].
    pub fn run_i32(
        &self,
        name: &str,
        _inputs: &[(&[i32], &[usize])],
    ) -> Result<Vec<i32>, RuntimeError> {
        self.artifact_path(name)?;
        Err(RuntimeError::Unavailable {
            reason: "vendor the `xla` crate to execute AOT artifacts",
        })
    }
}

/// The AOT-compiled JAX/Pallas warp ALU as a simulator execute-stage
/// backend. Construction fails in a PJRT-less build, so an instance is a
/// proof the executor works; callers fall back to [`crate::sim::NativeAlu`]
/// when `new` errors.
pub struct XlaAlu {
    arts: Arc<Artifacts>,
    calls: u64,
}

impl XlaAlu {
    pub fn new(arts: Arc<Artifacts>) -> Result<XlaAlu, RuntimeError> {
        // Probe eagerly so launch-time faults surface immediately.
        arts.artifact_path("warp_alu")?;
        if !arts.available() {
            return Err(RuntimeError::Unavailable {
                reason: "vendor the `xla` crate to execute AOT artifacts",
            });
        }
        Ok(XlaAlu { arts, calls: 0 })
    }

    pub fn calls(&self) -> u64 {
        self.calls
    }
}

impl AluBackend for XlaAlu {
    fn execute(&mut self, input: &WarpAluIn) -> WarpAluOut {
        self.calls += 1;
        let _ = (&self.arts, input);
        unreachable!("XlaAlu cannot be constructed in a PJRT-less build");
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Batched interface over the `warp_alu_batch64` artifact: amortizes the
/// PJRT call across 64 instruction slots (the §Perf configuration).
pub struct XlaBatchAlu {
    arts: Arc<Artifacts>,
}

pub const XLA_BATCH: usize = 64;

impl XlaBatchAlu {
    pub fn new(arts: Arc<Artifacts>) -> Result<XlaBatchAlu, RuntimeError> {
        arts.artifact_path("warp_alu_batch64")?;
        if !arts.available() {
            return Err(RuntimeError::Unavailable {
                reason: "vendor the `xla` crate to execute AOT artifacts",
            });
        }
        Ok(XlaBatchAlu { arts })
    }

    /// Execute 64 independent instruction slots in one PJRT call.
    /// Stubbed: unconditionally [`RuntimeError::Unavailable`] (restoring
    /// PJRT must swap this body back in alongside `run_i32` / `XlaAlu`).
    pub fn execute_batch(
        &self,
        inputs: &[WarpAluIn],
    ) -> Result<Vec<WarpAluOut>, RuntimeError> {
        assert_eq!(inputs.len(), XLA_BATCH);
        let _ = &self.arts;
        Err(RuntimeError::Unavailable {
            reason: "vendor the `xla` crate to execute AOT artifacts",
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_error_names_path_and_fix() {
        let arts = Artifacts::open("/nonexistent-dir").unwrap();
        let err = arts.artifact_path("warp_alu").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("warp_alu.hlo.txt"), "{msg}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn stub_reports_unavailable_not_panic() {
        let dir = std::env::temp_dir().join("flexgrip-artifact-stub-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("probe.hlo.txt"), "HloModule probe").unwrap();
        let arts = Artifacts::open(&dir).unwrap();
        assert!(arts.artifact_path("probe").is_ok());
        let err = arts.run_i32("probe", &[]).unwrap_err();
        assert!(matches!(err, RuntimeError::Unavailable { .. }), "{err}");
        assert!(!arts.available());
    }
}
